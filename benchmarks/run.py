"""Benchmark harness: one entry per paper table/figure + roofline + beyond.

  PYTHONPATH=src python -m benchmarks.run             # all
  PYTHONPATH=src python -m benchmarks.run --only b4
  REPRO_BENCH_SCALE=full ... python -m benchmarks.run # paper-scale (1M)
"""

from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    from benchmarks import beyond_paper, paper_tables, roofline, substrates
    from benchmarks.common import SCALE

    suites = dict(paper_tables.ALL)
    suites.update(beyond_paper.ALL)
    suites.update(substrates.ALL)

    print(f"== repro benchmarks (scale={SCALE}) ==\n")
    for key, (title, fn) in suites.items():
        if args.only and key != args.only:
            continue
        print(f"-- {key}: {title} --")
        t0 = time.perf_counter()
        fn()
        print(f"({key} took {time.perf_counter() - t0:.1f}s)\n")

    if not args.only and not args.skip_roofline:
        print("-- roofline (from dry-run artifacts) --")
        roofline.main()


if __name__ == "__main__":
    main()
