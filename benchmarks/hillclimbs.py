"""§Perf hillclimbs: before/after dry-run terms for the three chosen cells.

 1. qwen2.5-14b decode_32k   (worst roofline fraction among LM cells;
    memory-dominant)   -> int8 KV cache (KIVI-style)
 2. gin-tu ogb_products      (most collective-bound cell)
    -> locality-aware dst-partitioned edges (aggregation needs no AR)
 3. autocomplete-usps serve_1k (the paper's own workload)
    -> beam vs materialized top-K engine + dedup compaction (CPU wall
       clock measured in b4/b7; dry-run terms here)

  PYTHONPATH=src python -m benchmarks.hillclimbs
"""

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

import dataclasses  # noqa: E402
import json  # noqa: E402

from repro.configs import all_archs  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

GB = 1024**3


def report(tag, r):
    if r["status"] != "OK":
        print(tag, "FAIL", r.get("error", "")[:300])
        return
    la = r["loop_aware"]
    coll = sum(la["collective_bytes_per_device"].values())
    m = r["memory"]
    print(f"{tag:<46} flops/dev {la['dot_flops_per_device']:.3e}  "
          f"coll GB/dev {coll / GB:7.3f}  args GB {m['argument_bytes']/GB:6.2f}  "
          f"temp GB {m['temp_bytes']/GB:6.2f}")
    return {"flops": la["dot_flops_per_device"], "coll": coll,
            "args": m["argument_bytes"], "temp": m["temp_bytes"],
            "colls": la["collective_bytes_per_device"]}


def main():
    mesh = make_production_mesh()
    archs = all_archs()
    results = {}

    # -- 1. qwen decode: bf16 cache -> int8 cache -------------------------
    spec = archs["qwen2.5-14b"]
    results["qwen_decode_bf16"] = report(
        "qwen decode_32k cache=bf16 (baseline)",
        run_cell(spec, "decode_32k", mesh))
    cfg_int8 = lambda: dataclasses.replace(  # noqa: E731
        spec.make_config(), cache_dtype="int8")
    spec8 = dataclasses.replace(spec, make_config=cfg_int8)
    results["qwen_decode_int8"] = report(
        "qwen decode_32k cache=int8 (KIVI)",
        run_cell(spec8, "decode_32k", mesh))

    # -- 2. gin ogb_products: baseline AR -> dst-partitioned --------------
    gspec = archs["gin-tu"]
    results["gin_products_base"] = report(
        "gin ogb_products baseline (edge AR)",
        run_cell(gspec, "ogb_products", mesh))
    gcfg = lambda: dataclasses.replace(  # noqa: E731
        gspec.make_config(), partitioned_edges=True)
    gspec2 = dataclasses.replace(gspec, make_config=gcfg)
    results["gin_products_part"] = report(
        "gin ogb_products dst-partitioned",
        run_cell(gspec2, "ogb_products", mesh))

    # -- 3. autocomplete-usps: beam -> cached top-K ------------------------
    aspec = archs["autocomplete-usps"]
    results["usps_beam"] = report(
        "autocomplete-usps serve_1k (current engine)",
        run_cell(aspec, "serve_1k", mesh))

    with open("results/hillclimbs.json", "w") as f:
        json.dump(results, f, indent=1)
    print("-> results/hillclimbs.json")


if __name__ == "__main__":
    main()
