"""Beyond-paper optimizations, benchmarked against the paper-faithful
baseline (B7/B8):

B7: cached per-node top-K (materialized, cf. Li[9]) vs the beam engine —
    the TPU-native trade the paper rejected for CPU (DESIGN §2.3).
B8: Pallas kernel microbenches (interpret-mode iteration counts only on
    CPU; structural VMEM/block shapes reported for the TPU target).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (SIZES, build_index, dataset, emit,
                               fixed_batches, time_batches)
from repro.data.strings import make_workload


def b7_cached_vs_beam(k: int = 10, batch: int = 256, name: str = "usps"):
    ds = dataset(name)
    qs = make_workload(ds, SIZES["queries"] // 2, seed=11, max_len=14)
    rows = []
    for label, kw in [("et_beam(paper)", {}),
                      ("et_cached_k16(beyond)", {"cache_k": 16})]:
        idx = build_index(ds, "et", **kw)
        batches = fixed_batches(qs, batch)
        sec = time_batches(lambda b: idx.complete(b, k=k), batches)
        rows.append([label, round(idx.stats.bytes_per_string, 1),
                     round(sec * 1e6, 1)])
    emit(rows, ["engine", "bytes_per_string", "us_per_q"])
    return rows


def b8_kernels(reps: int = 3):
    from repro.core import CompletionIndex, make_rules
    from repro.core.alphabet import pad_queries
    from repro.kernels import ops, ref

    rows = []
    strings = [f"entry {i:06d} payload" for i in range(20_000)]
    idx = CompletionIndex.build(strings, list(range(len(strings))),
                                make_rules([]), kind="plain")
    t = idx.device
    qs, qlens = pad_queries([s[:10] for s in strings[:1024]], 16)
    qs, qlens = jnp.asarray(qs), jnp.asarray(qlens)

    def timeit(fn, *a, **kw):
        fn(*a, **kw)
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*a, **kw))
        return (time.perf_counter() - t0) / reps * 1e3

    rows.append(["trie_walk(pallas-interp)", round(timeit(
        ops.trie_walk, t.first_child, t.edge_char, t.edge_child, qs, qlens), 2)])
    rows.append(["trie_walk(jnp-ref)", round(timeit(
        jax.jit(ref.trie_walk_ref), t.first_child, t.edge_char,
        t.edge_child, qs, qlens), 2)])

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=128).astype(np.float32))
    cand = jnp.asarray(rng.normal(size=(65536, 128)).astype(np.float32))
    rows.append(["candidate_topk(pallas-interp)", round(timeit(
        ops.candidate_topk, q, cand, 100), 2)])
    rows.append(["candidate_topk(jnp-ref)", round(timeit(
        jax.jit(ref.candidate_topk_ref, static_argnames="k"),
        q, cand, 100), 2)])
    emit(rows, ["kernel", "ms_per_call"])
    return rows


ALL = {
    "b7": ("cached top-K vs beam engine (beyond paper)", b7_cached_vs_beam),
    "b8": ("Pallas kernel microbench (interpret mode)", b8_kernels),
}
