"""The paper's tables/figures, one function each (deliverable d).

B1 Table 1: dataset characteristics
B2 Table 2 + Fig 5: structure size (bytes/string) incl. BL baseline + breakdown
B3 Fig 6: construction time
B4 Fig 7: top-10 lookup time vs query length (TT/ET/HT)
B5 Fig 8: HT lookup time vs alpha
B6 Fig 9: size + lookup time vs #strings (scalability)

Run: PYTHONPATH=src python -m benchmarks.run [--only b4]
Scales with REPRO_BENCH_SCALE={small,medium,full} (CPU default: small).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (SIZES, build_index, dataset, emit,
                               fixed_batches, time_batches)
from repro.data.strings import make_workload

DATASET_NAMES = ("dblp", "usps", "sprot")
KINDS = ("tt", "et", "ht")


def _queries_by_len(ds, n, lens=(2, 6, 10, 14, 18, 22)):
    qs = make_workload(ds, n * 3, seed=7, min_len=2, max_len=max(lens) + 2)
    by = {}
    for L in lens:
        sel = [q[:L] for q in qs if len(q) >= L][:n]
        if sel:
            by[L] = sel
    return by


def b1_datasets():
    rows = []
    for name in DATASET_NAMES:
        ds = dataset(name)
        lens = [len(s) for s in ds.strings]
        # rules applicable per string (sampled)
        import random
        rnd = random.Random(0)
        sample = rnd.sample(ds.strings, min(500, len(ds.strings)))
        apps = [sum(1 for lhs, rhs in ds.rules if rhs in s) for s in sample]
        rows.append([ds.name, len(ds.strings),
                     f"{np.mean(lens):.0f}/{np.max(lens)}", len(ds.rules),
                     f"{np.mean(apps):.2f}/{np.max(apps)}"])
    emit(rows, ["dataset", "n_strings", "len avg/max", "n_rules",
                "rules_per_string avg/max"])
    return rows


def b2_space(include_bl: bool = True, compression: str = "packed"):
    """Bytes per string; BL = naive expand-all-rewritings baseline (expected
    to blow up -- capped and reported as a lower bound when it does).

    When ``compression`` is ``"packed"`` (the default) each kind also
    gets a format-v4 compressed column (``*_v4``) so the compressed
    footprint sits next to the paper's reported 160-200 B/string for
    the uncompressed C++ structures (Table 2).
    """
    packed = compression == "packed"
    rows = []
    for name in DATASET_NAMES:
        ds = dataset(name)
        row = [ds.name]
        if include_bl:
            row.append(_bl_bytes_per_string(ds))
        for kind in KINDS:
            idx = build_index(ds, kind, alpha=0.5)
            row.append(round(idx.stats.bytes_per_string, 1))
            if packed:
                pix = build_index(ds, kind, alpha=0.5,
                                  compression="packed")
                row.append(round(pix.stats.bytes_per_string, 1))
        # Fig 5 breakdown for the paper's SPROT plot equivalent
        idx = build_index(ds, "ht", alpha=0.5)
        row += [idx.stats.bytes_dict_nodes // max(idx.stats.n_strings, 1),
                idx.stats.bytes_syn_nodes // max(idx.stats.n_strings, 1),
                idx.stats.bytes_rule_side // max(idx.stats.n_strings, 1)]
        rows.append(row)
    kind_cols = [c for k in KINDS
                 for c in ([k.upper(), f"{k.upper()}_v4"] if packed
                           else [k.upper()])]
    emit(rows, ["dataset", "BL"] + kind_cols
         + ["ht_dict_B", "ht_syn_B", "ht_rule_B"])
    if packed:
        print("(paper Table 2 reports 160-200 B/string for the "
              "uncompressed structures; *_v4 columns are the packed "
              "format-v4 layout)\n")
    return rows


def _bl_bytes_per_string(ds, cap: int = 2_000_000):
    """Baseline: materialize every rewriting as a plain trie entry."""
    from repro.core import CompletionIndex, make_rules

    out = []
    scores = []
    inv = {}
    for lhs, rhs in ds.rules:
        inv.setdefault(rhs, []).append(lhs)
    blew_up = False
    for s, r in zip(ds.strings, ds.scores):
        variants = {s}
        for rhs, lhss in inv.items():
            if rhs in s and len(variants) < 64:
                for lhs in lhss:
                    variants |= {v.replace(rhs, lhs, 1) for v in list(variants)}
        out.extend(variants)
        scores.extend([int(r)] * len(variants))
        if len(out) > cap:
            blew_up = True
            break
    idx = CompletionIndex.build(out, scores, make_rules([]), kind="plain")
    per = idx.stats.bytes_total / max(len(ds.strings), 1)
    return f">{per:.0f}(failed)" if blew_up else round(per, 1)


def b3_construction():
    rows = []
    for name in DATASET_NAMES:
        ds = dataset(name)
        row = [ds.name]
        for kind in KINDS:
            t0 = time.perf_counter()
            build_index(ds, kind, alpha=0.5)
            row.append(round(time.perf_counter() - t0, 2))
        rows.append(row)
    emit(rows, ["dataset", "tt_s", "et_s", "ht_s"])
    return rows


def b4_lookup(k: int = 10, batch: int = 256):
    rows = []
    for name in DATASET_NAMES:
        ds = dataset(name)
        by_len = _queries_by_len(ds, SIZES["queries"] // 4)
        idxs = {kind: build_index(ds, kind, alpha=0.5) for kind in KINDS}
        for L, qs in by_len.items():
            row = [ds.name, L]
            for kind in KINDS:
                batches = fixed_batches(qs, batch)
                if not batches:
                    row.append("")
                    continue
                sec = time_batches(
                    lambda b, ix=idxs[kind]: ix.complete(b, k=k), batches)
                row.append(round(sec * 1e6, 1))
            rows.append(row)
    emit(rows, ["dataset", "query_len", "tt_us", "et_us", "ht_us"])
    return rows


def b5_alpha(k: int = 10, batch: int = 256, name: str = "sprot"):
    ds = dataset(name)
    qs = make_workload(ds, SIZES["queries"] // 2, seed=3, max_len=18)
    rows = []
    for alpha in (0.0, 0.25, 0.5, 0.75, 1.0):
        idx = build_index(ds, "ht", alpha=alpha)
        batches = fixed_batches(qs, batch)
        sec = time_batches(lambda b: idx.complete(b, k=k), batches)
        rows.append([alpha, round(idx.stats.bytes_per_string, 1),
                     idx.stats.n_rules_expanded,
                     round(sec * 1e6, 1)])
    emit(rows, ["alpha", "bytes_per_string", "rules_expanded", "us_per_q"])
    return rows


def b6_scalability(k: int = 10, batch: int = 256):
    from repro.data.strings import make_usps

    full = SIZES["usps"]
    fracs = (0.2, 0.4, 0.6, 0.8, 1.0)
    rows = []
    base = make_usps(n=full, seed=0)
    order = np.argsort(-base.scores)   # paper: top-N by decreasing score
    for f in fracs:
        n = max(int(full * f), 1000)
        sel = order[:n]
        strings = [base.strings[i] for i in sel]
        scores = base.scores[sel]
        from repro.core import CompletionIndex, make_rules
        row = [n]
        qs = None
        for kind in KINDS:
            idx = CompletionIndex.build(strings, scores,
                                        make_rules(base.rules), kind=kind,
                                        alpha=0.5)
            if qs is None:
                from repro.data.strings import StringDataset
                sub = StringDataset("USPS", strings, scores, base.rules)
                qs = make_workload(sub, SIZES["queries"] // 4, seed=5)
            batches = fixed_batches(qs, batch)
            sec = time_batches(lambda b, ix=idx: ix.complete(b, k=k), batches)
            row += [round(idx.stats.bytes_per_string, 1),
                    round(sec * 1e6, 1)]
        rows.append(row)
    emit(rows, ["n_strings", "tt_B", "tt_us", "et_B", "et_us",
                "ht_B", "ht_us"])
    return rows


ALL = {
    "b1": ("Table 1: dataset characteristics", b1_datasets),
    "b2": ("Table 2 + Fig 5: bytes per string", b2_space),
    "b3": ("Fig 6: construction time (s)", b3_construction),
    "b4": ("Fig 7: top-10 lookup vs query length (us)", b4_lookup),
    "b5": ("Fig 8: HT alpha sweep (us)", b5_alpha),
    "b6": ("Fig 9: scalability on USPS", b6_scalability),
}


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(ALL))
    ap.add_argument("--compression", default="packed",
                    choices=["none", "packed"],
                    help="layout for the b2 space table's extra columns: "
                         "packed adds a format-v4 bytes/string column "
                         "per kind next to the paper's 160-200 B target; "
                         "none reproduces the paper table verbatim")
    args = ap.parse_args()
    for key, (title, fn) in ALL.items():
        if args.only and key != args.only:
            continue
        print(f"-- {key}: {title} --")
        if key == "b2":
            fn(compression=args.compression)
        else:
            fn()


if __name__ == "__main__":
    main()
