"""Shared benchmark helpers: dataset sizing, timing, CSV emit.

Benchmarks default to CPU-friendly scales (REPRO_BENCH_SCALE=small);
REPRO_BENCH_SCALE=full reproduces the paper's 1M-string sizes.
"""

from __future__ import annotations

import os
import time

import numpy as np

SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")

SIZES = {
    "small": {"dblp": 3000, "usps": 20000, "sprot": 20000, "queries": 2000},
    "medium": {"dblp": 24810, "usps": 200_000, "sprot": 200_000,
               "queries": 10_000},
    "full": {"dblp": 24810, "usps": 1_000_000, "sprot": 1_000_000,
             "queries": 50_000},
}[SCALE]


def dataset(name: str):
    from repro.data.strings import DATASETS

    return DATASETS[name](n=SIZES[name], seed=0)


def build_index(ds, kind: str, **kw):
    from repro.core import CompletionIndex, make_rules

    return CompletionIndex.build(ds.strings, ds.scores,
                                 make_rules(ds.rules), kind=kind, **kw)


def time_batches(fn, batches, warmup: int = 1) -> float:
    """Mean seconds per item over batched calls (steady state)."""
    for b in batches[:warmup]:
        fn(b)
    n = 0
    t0 = time.perf_counter()
    for b in batches:
        fn(b)
        n += len(b)
    return (time.perf_counter() - t0) / max(n, 1)


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print()


def fixed_batches(queries, batch: int, length: int = 64):
    """Pre-padded query batches of identical shape (no recompiles)."""
    out = [queries[i : i + batch] for i in range(0, len(queries), batch)]
    return [b for b in out if len(b) == batch]
