"""Perf trajectory: fold substrate smoke runs into one repo-root history,
render it, and gate on it.

Each CI run of ``benchmarks.substrates --smoke --out substrates-smoke.json``
produces a point-in-time JSON; this tool appends it to
``BENCH_substrates.json`` at the repo root so the jnp-vs-pallas (and
rule-bearing vs rule-free walk) numbers accumulate into a trajectory that
is *read* on every run, not just uploaded.  Entries are keyed by commit
when available so re-runs of the same commit update in place instead of
duplicating.

Three modes (CI runs all three, in order):

  # 1. append the fresh smoke run to the history (default mode)
  PYTHONPATH=src python -m benchmarks.trajectory substrates-smoke.json

  # 2. render the trajectory as a markdown table (us/query per workload
  #    row, one column per commit) — CI appends it to $GITHUB_STEP_SUMMARY
  PYTHONPATH=src python -m benchmarks.trajectory substrates-smoke.json \
      --render >> "$GITHUB_STEP_SUMMARY"

  # 3. gate: compare the fresh run against the history median and fail
  #    on a >1.5x slowdown in any fused-kernel (pallas) row; jnp
  #    reference rows only warn
  PYTHONPATH=src python -m benchmarks.trajectory substrates-smoke.json \
      --check
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import time

DEFAULT_HISTORY = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_substrates.json")


def _commit() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            check=True).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def load_history(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        hist = json.load(f)
    if not isinstance(hist, list):
        raise ValueError(f"{path}: trajectory must be a JSON list")
    return hist


def _load_runs(smoke_paths) -> list[dict]:
    if isinstance(smoke_paths, str):
        smoke_paths = [smoke_paths]
    runs = []
    for path in smoke_paths:
        with open(path) as f:
            runs.append(json.load(f))
    return runs


def append_run(smoke_paths, history_path: str = DEFAULT_HISTORY,
               commit: str | None = None, timestamp: float | None = None):
    """Append one or more smoke JSONs to the trajectory as a single
    entry; returns the new history.

    Entries are replaced per commit, so smoke files from different
    benchmarks (substrates, serving, ...) of the same CI run must be
    folded into one entry here — appending them one call at a time would
    leave only the last file's rows."""
    runs = _load_runs(smoke_paths)
    entry = {
        "timestamp": timestamp if timestamp is not None else time.time(),
        "commit": commit or _commit(),
        "backend": runs[0].get("backend"),
        "smoke": runs[0].get("smoke"),
        "rows": [row for run in runs for row in run.get("rows", [])],
    }
    hist = load_history(history_path)
    hist = [e for e in hist if e.get("commit") != entry["commit"]
            or entry["commit"] == "unknown"]
    hist.append(entry)
    with open(history_path, "w") as f:
        json.dump(hist, f, indent=2)
        f.write("\n")
    return hist


def _row_key(row: dict) -> tuple:
    """Workload identity of a smoke row (what us/query is comparable
    across runs): engine x kind x substrate on one backend, *including*
    which fused paths the substrate claimed and which execution tier
    (VMEM-resident vs DMA-streamed) served them — when a PR lands a
    kernel that changes what a row measures (e.g. the beam rows once the
    fused beam kernel claims them, or a row moving to the streamed
    tier), the row starts a fresh history instead of being gated against
    timings of a different code path.  Rows predating a flag read it as
    False, so their keys are stable across tool upgrades.  The on-device
    layout (``compression``) is part of the identity for the same
    reason: packed rows start fresh histories instead of being gated
    against the uncompressed layout's timings; rows predating the column
    read it as ``"none"``."""
    return (row.get("engine"), row.get("kind"), row.get("substrate"),
            row.get("backend"), bool(row.get("fused_walk")),
            bool(row.get("fused_beam")), bool(row.get("streamed_walk")),
            bool(row.get("streamed_beam")),
            row.get("compression") or "none")


def _key_label(key: tuple) -> str:
    engine, kind, substrate, _, fw, fb, sw, sb, compression = key
    fused = "+".join(n for n, f in (("fw", fw), ("fb", fb), ("sw", sw),
                                    ("sb", sb)) if f)
    label = f"{engine}/{kind}/{substrate}"
    if compression != "none":
        label += f"/{compression}"
    return label + (f" [{fused}]" if fused else "")


def render_markdown(hist: list[dict], max_commits: int = 8) -> str:
    """Markdown table of the trajectory: one row per workload
    (engine/kind/substrate), one column per commit (oldest -> newest,
    capped at the newest ``max_commits``), cells in us/query."""
    if not hist:
        return "### Substrate perf trajectory\n\n_(no runs recorded)_\n"
    runs = hist[-max_commits:]
    keys: list[tuple] = []
    for entry in runs:
        for row in entry.get("rows", []):
            if _row_key(row) not in keys:
                keys.append(_row_key(row))
    cells = {}          # (key, commit) -> us/query
    space = {}          # key -> newest bytes/string on record
    for entry in runs:
        for row in entry.get("rows", []):
            cells[(_row_key(row), entry["commit"])] = row.get("us_per_q")
            if row.get("bytes_per_string") is not None:
                space[_row_key(row)] = row["bytes_per_string"]
    backend = runs[-1].get("backend", "?")
    lines = [f"### Substrate perf trajectory (us/query, backend={backend})",
             ""]
    heads = (["workload"] + [str(e["commit"])[:8] for e in runs]
             + ["B/str"])
    lines.append("| " + " | ".join(heads) + " |")
    lines.append("|" + "---|" * len(heads))
    for key in keys:
        row_cells = [_key_label(key)]
        for entry in runs:
            v = cells.get((key, entry["commit"]))
            row_cells.append("-" if v is None else f"{v:g}")
        bs = space.get(key)
        row_cells.append("-" if bs is None else f"{bs:g}")
        lines.append("| " + " | ".join(row_cells) + " |")
    if len(hist) > max_commits:
        lines.append("")
        lines.append(f"_({len(hist)} runs total; newest {len(runs)} shown;"
                     f" pallas rows run in interpret mode off-TPU;"
                     f" [fw]/[fb] = fused walk/beam claimed,"
                     f" [sw]/[sb] = DMA-streamed tier)_")
    else:
        lines.append("")
        lines.append("_(pallas rows run in interpret mode off-TPU; "
                     "[fw]/[fb] = fused walk/beam claimed, "
                     "[sw]/[sb] = DMA-streamed tier)_")
    return "\n".join(lines) + "\n"


def check_run(smoke_paths, history_path: str = DEFAULT_HISTORY,
              commit: str | None = None, threshold: float = 1.5,
              space_threshold: float = 1.2):
    """Gate the fresh smoke run(s) against the trajectory median.

    For every row of the smoke run, compares us/query against the median
    of the same workload (engine x kind x substrate x backend) over all
    *prior* runs (the current commit's own history entry is excluded, so
    the append step can run first).  Returns (failures, warnings) —
    slowdowns beyond ``threshold`` in fused-kernel (``pallas``) rows are
    failures; jnp reference rows are warn-only (interpret-mode dispatch
    overhead is what the pallas rows measure off-TPU, but the jnp rows
    track ambient CI noise too closely to gate on).  A row hard-fails
    only once its history holds at least two prior samples — a lone
    sample (e.g. the committed seed, recorded on a different machine)
    gives the median no noise robustness, so it warns instead.

    Index *space* is gated too, warn-only: a row whose bytes/string
    grows beyond ``space_threshold`` x its history median warns
    (layout changes are deliberate and land with a new compression key,
    so drift under the same key is worth flagging but build-order
    noise should never fail CI).
    """
    rows = [row for run in _load_runs(smoke_paths)
            for row in run.get("rows", [])]
    commit = commit or _commit()
    prior: dict[tuple, list[float]] = {}
    prior_space: dict[tuple, list[float]] = {}
    for entry in load_history(history_path):
        if entry.get("commit") == commit:
            continue
        for row in entry.get("rows", []):
            if row.get("us_per_q") is not None:
                prior.setdefault(_row_key(row), []).append(
                    float(row["us_per_q"]))
            if row.get("bytes_per_string") is not None:
                prior_space.setdefault(_row_key(row), []).append(
                    float(row["bytes_per_string"]))
    failures, warnings = [], []
    for row in rows:
        base = prior_space.get(_row_key(row))
        if not base or row.get("bytes_per_string") is None:
            continue
        median = statistics.median(base)
        now = float(row["bytes_per_string"])
        if median > 0 and now > space_threshold * median:
            warnings.append(
                f"{_key_label(_row_key(row))}: index grew to {now:g} "
                f"bytes/string vs history median {median:g} over "
                f"{len(base)} run(s) "
                f"({now / median:.2f}x > {space_threshold}x)")
    for row in rows:
        key = _row_key(row)
        base = prior.get(key)
        if not base or row.get("us_per_q") is None:
            continue        # new workload row or no history yet: no gate
        median = statistics.median(base)
        now = float(row["us_per_q"])
        if median <= 0 or now <= threshold * median:
            continue
        msg = (f"{_key_label(key)}: {now:g} us/q vs history median "
               f"{median:g} us/q over {len(base)} run(s) "
               f"({now / median:.2f}x > {threshold}x)")
        gate = row.get("substrate") == "pallas" and len(base) >= 2
        (failures if gate else warnings).append(msg)
    return failures, warnings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("smoke_json", nargs="+",
                    help="output(s) of benchmarks.substrates / "
                         "benchmarks.serving --smoke --out <path>; "
                         "multiple files fold into one trajectory entry")
    ap.add_argument("--history", default=DEFAULT_HISTORY,
                    help="trajectory file to append to / read "
                         "(default: BENCH_substrates.json at repo root)")
    ap.add_argument("--commit", default=None,
                    help="commit id to key this run by (default: "
                         "$GITHUB_SHA or git rev-parse HEAD)")
    ap.add_argument("--render", action="store_true",
                    help="print the trajectory as a markdown table "
                         "(for $GITHUB_STEP_SUMMARY) instead of appending")
    ap.add_argument("--check", action="store_true",
                    help="compare the smoke run against the history median"
                         " and exit 1 on a >threshold slowdown in any "
                         "pallas row (jnp rows warn only)")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="slowdown factor that fails --check (default 1.5)")
    args = ap.parse_args()

    if args.render:
        print(render_markdown(load_history(args.history)), end="")
        return
    if args.check:
        failures, warnings = check_run(args.smoke_json, args.history,
                                       args.commit, args.threshold)
        for msg in warnings:
            print(f"WARN (not gated): {msg}")
        for msg in failures:
            print(f"FAIL (fused-kernel row regressed): {msg}")
        if failures:
            sys.exit(1)
        print(f"perf-trajectory check passed ({len(warnings)} warning(s))")
        return
    hist = append_run(args.smoke_json, args.history, args.commit)
    last = hist[-1]
    print(f"appended run {last['commit'][:12]} "
          f"({len(last['rows'])} rows) -> {args.history} "
          f"[{len(hist)} runs total]")


if __name__ == "__main__":
    main()
