"""Perf trajectory: fold substrate smoke runs into one repo-root history.

Each CI run of ``benchmarks.substrates --smoke --out substrates-smoke.json``
produces a point-in-time JSON; this tool appends it to
``BENCH_substrates.json`` at the repo root so the jnp-vs-pallas (and
rule-bearing vs rule-free walk) numbers accumulate into a trajectory that
can be read across PRs (ROADMAP open item).  Entries are keyed by commit
when available so re-runs of the same commit update in place instead of
duplicating.

  PYTHONPATH=src python -m benchmarks.trajectory substrates-smoke.json
  PYTHONPATH=src python -m benchmarks.trajectory smoke.json \
      --history BENCH_substrates.json --commit "$GITHUB_SHA"
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import time

DEFAULT_HISTORY = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_substrates.json")


def _commit() -> str:
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            check=True).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def load_history(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        hist = json.load(f)
    if not isinstance(hist, list):
        raise ValueError(f"{path}: trajectory must be a JSON list")
    return hist


def append_run(smoke_path: str, history_path: str = DEFAULT_HISTORY,
               commit: str | None = None, timestamp: float | None = None):
    """Append one smoke JSON to the trajectory; returns the new history."""
    with open(smoke_path) as f:
        run = json.load(f)
    entry = {
        "timestamp": timestamp if timestamp is not None else time.time(),
        "commit": commit or _commit(),
        "backend": run.get("backend"),
        "smoke": run.get("smoke"),
        "rows": run.get("rows", []),
    }
    hist = load_history(history_path)
    hist = [e for e in hist if e.get("commit") != entry["commit"]
            or entry["commit"] == "unknown"]
    hist.append(entry)
    with open(history_path, "w") as f:
        json.dump(hist, f, indent=2)
        f.write("\n")
    return hist


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("smoke_json", help="output of benchmarks.substrates "
                                       "--smoke --out <path>")
    ap.add_argument("--history", default=DEFAULT_HISTORY,
                    help="trajectory file to append to "
                         "(default: BENCH_substrates.json at repo root)")
    ap.add_argument("--commit", default=None,
                    help="commit id to key this run by (default: "
                         "$GITHUB_SHA or git rev-parse HEAD)")
    args = ap.parse_args()
    hist = append_run(args.smoke_json, args.history, args.commit)
    last = hist[-1]
    print(f"appended run {last['commit'][:12]} "
          f"({len(last['rows'])} rows) -> {args.history} "
          f"[{len(hist)} runs total]")


if __name__ == "__main__":
    main()
