"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from results/*.json.

  PYTHONPATH=src python -m benchmarks.render_experiments > results/tables.md
"""

from __future__ import annotations

import json
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
GB = 1024**3

BF16_PROGRAMS = {"granite-moe-1b-a400m", "arctic-480b", "mistral-nemo-12b",
                 "h2o-danube-1.8b", "qwen2.5-14b"}

MOVE_NOTES = {
    "compute": "more chips / lower precision; compute term already dominant "
               "means the cell is near its best placement",
    "memory": "cut resident reads: quantize weights/KV (int8), larger "
              "arithmetic-intensity tiles, fuse elementwise chains",
    "collective": "reshard to cut exchanged bytes: RS+AG instead of AR, "
                  "int8 grad compression, locality-aware partitioning, "
                  "overlap with compute",
}


def load(path):
    with open(path) as f:
        return json.load(f)


def dryrun_table(cells):
    out = ["| arch | shape | status | args GB/dev | temp GB/dev (raw / TPU-adj) | "
           "HLO GFLOP/dev (loop-aware) | collective GB/dev (loop-aware) |",
           "|---|---|---|---|---|---|---|"]
    for r in cells:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | "
                       f"{r['reason'][:60]} |")
            continue
        m = r["memory"]
        la = r.get("loop_aware", {})
        coll = sum(la.get("collective_bytes_per_device",
                          r["collective_bytes_per_device"]).values())
        out.append(
            f"| {r['arch']} | {r['shape']} | OK | "
            f"{m['argument_bytes']/GB:.2f} | "
            f"{m['temp_bytes']/GB:.2f} / "
            f"{m.get('temp_bytes_tpu_adjusted', m['temp_bytes'])/GB:.2f} | "
            f"{la.get('dot_flops_per_device', r['flops_per_device'])/1e9:,.0f} | "
            f"{coll/GB:.2f} |")
    return "\n".join(out)


def roofline_rows(cells):
    rows = []
    for r in cells:
        if r["status"] != "OK":
            if r["status"] == "SKIP":
                rows.append({"arch": r["arch"], "shape": r["shape"],
                             "skip": r["reason"]})
            continue
        corr = 0.5 if r["arch"] in BF16_PROGRAMS else 1.0
        la = r.get("loop_aware", {})
        flops = la.get("dot_flops_per_device", r["flops_per_device"])
        coll_map = la.get("collective_bytes_per_device",
                          r["collective_bytes_per_device"])
        coll = sum(coll_map.values()) * corr
        # memory proxy: max(cost_analysis bytes [loop-unaware floor],
        # loop-aware dot operand traffic) with bf16 correction
        bytes_dev = max(r["bytes_per_device"],
                        la.get("dot_bytes_per_device", 0.0)) * corr
        t = {"compute": flops / PEAK_FLOPS, "memory": bytes_dev / HBM_BW,
             "collective": coll / LINK_BW}
        dom = max(t, key=t.get)
        useful = r["model_flops"] / (flops * r["n_devices"]) if flops else 0
        step = max(t.values())
        mfu = (r["model_flops"] / r["n_devices"] / step / PEAK_FLOPS
               if step > 0 else 0)
        rows.append({"arch": r["arch"], "shape": r["shape"], **t,
                     "dominant": dom, "useful": useful, "mfu": mfu})
    return rows


def roofline_table(cells):
    rows = roofline_rows(cells)
    out = ["| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | MODEL/HLO flops | roofline fraction | "
           "what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skip" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"SKIP | — | — | {r['skip'][:70]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute']:.2e} | "
            f"{r['memory']:.2e} | {r['collective']:.2e} | {r['dominant']} | "
            f"{r['useful']:.2f} | {r['mfu']:.3f} | "
            f"{MOVE_NOTES[r['dominant']][:70]} |")
    return "\n".join(out)


def main():
    single = load("results/dryrun_singlepod.json")
    print("## Dry-run (single-pod 16x16 = 256 chips)\n")
    print(dryrun_table(single))
    try:
        multi = load("results/dryrun_multipod.json")
        print("\n## Dry-run (multi-pod 2x16x16 = 512 chips)\n")
        print(dryrun_table(multi))
    except FileNotFoundError:
        pass
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(single))


if __name__ == "__main__":
    main()
