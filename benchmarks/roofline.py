"""Roofline analysis (deliverable g): three terms per (arch x shape) from
the dry-run artifacts in results/dryrun_singlepod.json.

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF/s bf16)
  memory_s     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective_s = collective_bytes_per_device / link_bw    (50 GB/s/link)

CPU-backend correction: XLA CPU FloatNormalization upcasts every bf16
tensor to f32, so byte-based measurements of bf16 programs (the five LM
archs) are ~2x a TPU execution; we report raw and corrected (x0.5) values.
FLOP counts are dtype-independent and need no correction.
"""

from __future__ import annotations

import json
import os

PEAK_FLOPS = 197e12      # TPU v5e bf16 per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per ICI link

BF16_PROGRAMS = {"granite-moe-1b-a400m", "arctic-480b", "mistral-nemo-12b",
                 "h2o-danube-1.8b", "qwen2.5-14b"}


def analyze(path: str = "results/dryrun_singlepod.json"):
    with open(path) as f:
        cells = json.load(f)
    rows = []
    for r in cells:
        if r.get("status") != "OK":
            if r.get("status") == "SKIP":
                rows.append({"arch": r["arch"], "shape": r["shape"],
                             "skip": r["reason"]})
            continue
        corr = 0.5 if r["arch"] in BF16_PROGRAMS else 1.0
        la = r.get("loop_aware", {})
        flops = la.get("dot_flops_per_device", r["flops_per_device"])
        bytes_dev = max(r["bytes_per_device"],
                        la.get("dot_bytes_per_device", 0.0)) * corr
        coll = sum(la.get("collective_bytes_per_device",
                          r["collective_bytes_per_device"]).values()) * corr
        compute_s = flops / PEAK_FLOPS
        memory_s = bytes_dev / HBM_BW
        coll_s = coll / LINK_BW
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        dom = max(terms, key=terms.get)
        total_flops = flops * r["n_devices"]
        useful = r["model_flops"] / total_flops if total_flops else 0.0
        step_s = max(terms.values())
        mfu = (r["model_flops"] / r["n_devices"] / step_s / PEAK_FLOPS
               if step_s > 0 else 0.0)
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll_s, "dominant": dom,
            "useful_flops_ratio": useful,
            "roofline_fraction": mfu,
            "bf16_corrected": corr != 1.0,
            "collectives": r["collective_bytes_per_device"],
        })
    return rows


def main(path: str = "results/dryrun_singlepod.json"):
    if not os.path.exists(path):
        print(f"(roofline: {path} missing — run repro.launch.dryrun first)")
        return []
    rows = analyze(path)
    hdr = (f"{'arch':>24s} {'shape':<14s} {'compute_s':>10s} {'memory_s':>10s}"
           f" {'coll_s':>10s} {'dominant':>10s} {'useful':>7s} {'roofline':>8s}")
    print(hdr)
    for r in rows:
        if "skip" in r:
            print(f"{r['arch']:>24s} {r['shape']:<14s} SKIP({r['skip'][:40]})")
            continue
        print(f"{r['arch']:>24s} {r['shape']:<14s} {r['compute_s']:>10.2e}"
              f" {r['memory_s']:>10.2e} {r['collective_s']:>10.2e}"
              f" {r['dominant']:>10s} {r['useful_flops_ratio']:>7.2f}"
              f" {r['roofline_fraction']:>8.3f}")
    print()
    return rows


if __name__ == "__main__":
    main()
