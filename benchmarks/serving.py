"""B10: serving layer — per-session sequential dispatch vs the
continuous-batching keystroke scheduler, under a Zipf multi-session load.

Replays one interleaved multi-session keystroke stream
(:func:`repro.data.strings.make_keystroke_events`) twice through the same
index: once with every keystroke paying its own device dispatch
(stateful :class:`~repro.api.session.Session` per typist) and once
through the :class:`~repro.serving.scheduler.KeystrokeScheduler`'s
coalesced micro-batches.  Demuxed per-keystroke results are checked
bit-identical; both rows land in the perf trajectory
(``BENCH_substrates.json``) so the batched path's us/keystroke is gated
against its own history like the kernel rows.

Timing takes the best of ``repeats`` full replays per path (the
sequential path's thousands of tiny dispatches are noisy on shared CI
machines; the tail percentiles come from the last repeat's stats).

  PYTHONPATH=src python -m benchmarks.serving               # table
  PYTHONPATH=src python -m benchmarks.serving --smoke \
      --out serving-smoke.json                              # CI artifact
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from benchmarks.common import build_index, dataset, emit
from repro.data.strings import make_keystroke_events
from repro.launch.serve import _replay_batched, _replay_sequential
from repro.serving import BatchStats, CompletionService


def _bench_overlay(ds, events, sessions: int, repeats: int):
    """us/keystroke with a pending mutation batch on the index.

    Every keystroke answers through the overlay-merged one-shot path
    (base over-fetch + side-index + fused rank merge), so this row prices
    the mutated steady state between compactions; the serving_seq /
    serving_batch rows price the unmutated hot path (their per-keystroke
    ``has_mutations`` check is the only overlay cost they carry)."""
    from benchmarks.common import build_index as build
    from repro.launch.serve import _replay_sequential

    idx = build(ds, "et", cache_k=10)
    base = idx.strings   # sorted unique bytes, unlike the raw dataset
    for i in range(32):
        idx.insert(b"zz~overlay-%d" % i, 100 + i)
    for s in base[:32:2]:
        idx.delete(s)
    for s in base[1:33:2]:
        idx.update_score(s, 7)
    # the merged path re-dispatches one-shot per keystroke; a slice of
    # the stream keeps the row's wall cost in smoke range while still
    # touching every prefix-length bucket
    events = events[:max(len(events) // 4, 1)]
    svc = CompletionService(idx)
    _replay_sequential(svc, events, sessions)   # compile/warmup
    best = float("inf")
    for _ in range(repeats):
        svc.stats.reset_keystrokes()
        t0 = time.perf_counter()
        out = _replay_sequential(svc, events, sessions)
        best = min(best, time.perf_counter() - t0)
    return idx, svc, len(out), best


def bench_serving(smoke: bool = False, sessions: int = 16, block: int = 16,
                  repeats: int = 3):
    """Returns three trajectory rows: serving_seq, serving_batch and
    serving_overlay (the mutated steady state)."""
    ds = dataset("dblp")
    if smoke:
        ds = type(ds)(name=ds.name, strings=ds.strings[:2000],
                      scores=ds.scores[:2000], rules=ds.rules)
    # long enough streams that the startup ramp and final drain (which
    # run below full occupancy) are a small share of the replay
    n_queries = 128 if smoke else 512
    idx = build_index(ds, "et", cache_k=10)
    events = make_keystroke_events(ds, sessions, n_queries, seed=1)

    seq = CompletionService(idx)
    bat = CompletionService(idx, batching=True, block=block,
                            max_wait_ms=100.0, max_queue=16 * block)
    # one untimed replay per path compiles every jit shape both will hit
    seq_results = _replay_sequential(seq, events, sessions)
    bat_results = _replay_batched(bat, events, sessions)
    assert seq_results == bat_results, \
        "batched demux diverged from sequential replay"
    n = len(seq_results)

    def timed_once(svc, replay):
        svc.stats.reset_keystrokes()
        if svc.batching:
            svc.scheduler.stats = BatchStats()
        t0 = time.perf_counter()
        replay(svc, events, sessions)
        return time.perf_counter() - t0

    # interleave the repeats so ambient machine drift hits both paths
    # alike instead of biasing whichever ran second
    seq_s = bat_s = float("inf")
    for _ in range(repeats):
        seq_s = min(seq_s, timed_once(seq, _replay_sequential))
        bat_s = min(bat_s, timed_once(bat, _replay_batched))
    bstats = bat.scheduler.stats

    ov_idx, ov_svc, ov_n, ov_s = _bench_overlay(ds, events, sessions,
                                                repeats)

    base = {
        "kind": idx.kind,
        "substrate": idx.substrate,
        "backend": jax.default_backend(),
        "interpret_mode": False,
        "fused_walk": False, "fused_beam": False,
        "streamed_walk": False, "streamed_beam": False,
        "compression": idx.compression,
        "memory_budget": idx.memory_budget,
        "bytes_per_string": round(idx.stats.bytes_per_string, 1),
        "sessions": sessions, "keystrokes": n,
    }
    return [
        dict(base, engine="serving_seq",
             us_per_q=round(seq_s / max(n, 1) * 1e6, 1),
             p50_ms=round(seq.stats.p50_keystroke_ms(), 3),
             p99_ms=round(seq.stats.p99_keystroke_ms(), 3)),
        dict(base, engine="serving_batch", block=block,
             us_per_q=round(bat_s / max(n, 1) * 1e6, 1),
             p50_ms=round(bat.stats.p50_keystroke_ms(), 3),
             p99_ms=round(bat.stats.p99_keystroke_ms(), 3),
             mean_occupancy=round(bstats.mean_occupancy, 2),
             speedup_vs_seq=round(seq_s / max(bat_s, 1e-9), 2)),
        dict(base, engine="serving_overlay",
             keystrokes=ov_n,
             overlay_backlog=ov_idx.mutation_backlog,
             us_per_q=round(ov_s / max(ov_n, 1) * 1e6, 1),
             p50_ms=round(ov_svc.stats.p50_keystroke_ms(), 3),
             p99_ms=round(ov_svc.stats.p99_keystroke_ms(), 3)),
    ]


def _table(rows):
    emit([[r["engine"], r["kind"], r["substrate"], r["us_per_q"],
           r["p50_ms"], r["p99_ms"], r.get("speedup_vs_seq", "-")]
          for r in rows],
         ["engine", "kind", "substrate", "us_per_keystroke", "p50_ms",
          "p99_ms", "speedup"])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI; pairs with --out for the "
                         "perf-trajectory artifact")
    ap.add_argument("--out", default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--block", type=int, default=16)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    rows = bench_serving(smoke=args.smoke, sessions=args.sessions,
                         block=args.block, repeats=args.repeats)
    _table(rows)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "serving",
                       "backend": jax.default_backend(),
                       "smoke": args.smoke, "rows": rows}, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
