"""B9: execution substrates — jnp vs pallas, end-to-end on the B7 workload.

Times ``CompletionIndex.complete`` through both registered substrates on
the same built indexes (the substrate switch is a config flip; host/device
structures are shared), across two axes:

- *phase-2 engine* (from B7): the paper-faithful beam vs the beyond-paper
  cached top-K (``cached_k16``), on the ET index;
- *rule-bearing walk* (the fused locus-DP kernel's workload): tt/et/ht
  with the dataset's synonym rule set, where phase 1 is the synonym-aware
  frontier sweep rather than the rule-free prefix walk;
- *beam phase 2* (the fused beam kernel's workload): every ``beam`` row
  runs the generator-pool priority search, and the rule-free ``plain``
  row isolates it behind the trivial prefix walk.  Each row records
  whether the pallas substrate claimed the beam natively (``fused_beam``,
  from the ``can_beam_batch`` probe);
- *DMA-streamed tier*: two pallas-only rows re-run the plain and ht beam
  workloads under a VMEM budget that evicts the dictionary-sized tables,
  so phase 1 and phase 2 go through the HBM-streaming kernels
  (``streamed_walk``/``streamed_beam`` columns, from the
  ``walk_variant``/``beam_variant`` probes).  Off-TPU these measure the
  interpret-mode emulation of the DMA pipeline, not real overlap;
- *compressed layout* (format v4): packed twins of the ET rows record
  the bytes/string drop (``compression``/``bytes_per_string`` columns),
  and a fixed-budget pair shows the tier flip — at the same
  ``FLIP_BUDGET`` the uncompressed ET index runs DMA-streamed while the
  packed layout fits VMEM-resident.

On CPU the pallas column runs the kernels in interpret mode — that
measures dispatch correctness and overhead, not kernel speed; the TPU run
is where the comparison is meaningful (see README "choosing a
substrate").  Each row records whether the pallas substrate claimed the
walk natively (``fused_walk``, from the ``can_walk_batch`` probe).

  PYTHONPATH=src python -m benchmarks.substrates            # table
  PYTHONPATH=src python -m benchmarks.substrates --smoke \
      --out substrates-smoke.json                            # CI artifact
"""

from __future__ import annotations

import argparse
import json

import jax

from benchmarks.common import (SIZES, build_index, dataset, emit,
                               fixed_batches, time_batches)
from repro.data.strings import make_workload

# a fixed VMEM budget sized between the packed and uncompressed resident
# footprints of the smoke-scale ET index: at the same budget the
# uncompressed layout is forced onto the DMA-streamed tier while the
# packed (format v4) layout fits VMEM-resident — the tier flip the
# compressed layout exists to buy
FLIP_BUDGET = 1 << 20

# (label, index kind, build kwargs, streamed, compression, budget) — the
# two phase-2 engines benchmarked in B7 on ET, the rule-bearing walk
# workloads for the fused locus-DP kernel (tt = link store, ht = links +
# teleports), a rule-free beam row where phase 1 is the trivial prefix
# walk so the beam phase-2 kernel dominates the measurement, two
# DMA-streamed-tier rows (the same workloads under a VMEM budget that
# evicts every dictionary-sized table, so the HBM streaming path is what
# gets timed), compressed (format v4) twins of the ET rows, and the
# fixed-budget flip pair described at FLIP_BUDGET above
CASES = [
    ("beam", "et", {}, False, "none", None),
    ("cached_k16", "et", {"cache_k": 16}, False, "none", None),
    ("beam", "tt", {}, False, "none", None),
    ("beam", "ht", {}, False, "none", None),
    ("beam", "plain", {}, False, "none", None),
    ("beam", "plain", {}, True, "none", None),
    ("beam", "ht", {}, True, "none", None),
    ("beam", "et", {}, False, "packed", None),
    ("cached_k16", "et", {"cache_k": 16}, False, "packed", None),
    ("beam", "et", {}, False, "none", FLIP_BUDGET),
    ("beam", "et", {}, False, "packed", FLIP_BUDGET),
    # query-mode rows: the typo-tolerant walk (edit_budget=1 widens the
    # frontier sweep with substitute/insert/delete transitions) and the
    # multi-term index (build-time token-skip rule synthesis; the beam
    # phase is unchanged, the walk consumes the synthesized teleports)
    ("edit1_walk", "et", {"edit_budget": 1}, False, "none", None),
    ("multiterm_beam", "multiterm", {}, False, "none", None),
]
SUBSTRATES = ("jnp", "pallas")


def _streamed_budget(idx):
    """A VMEM budget that forces the DMA-streamed tier: room for the
    rule trie (the streamed locus kernel keeps it resident) but for none
    of the dictionary-sized tables."""
    from repro.core import engine as eng

    return eng.get_substrate("pallas").min_streamed_budget(idx.device)


def bench_substrates(k: int = 10, batch: int = 256, name: str = "usps",
                     smoke: bool = False):
    """Returns one row dict per (engine, kind, substrate) with us/query."""
    from repro.core import engine as eng

    n_queries = 200 if smoke else SIZES["queries"] // 2
    ds = dataset(name)
    if smoke:
        ds = type(ds)(name=ds.name, strings=ds.strings[:2000],
                      scores=ds.scores[:2000], rules=ds.rules)
    qs = make_workload(ds, n_queries, seed=11, max_len=14)
    if smoke:
        batch = 64
    rows = []
    # the probe must see the padded length complete() will actually jit
    # with, or the fused_walk column could misreport the timed path
    from repro.api.compile_cache import bucket_size
    seq_len = bucket_size(max(len(q) for q in qs))
    for engine, kind, kw, streamed, compression, budget in CASES:
        idx = build_index(ds, kind, compression=compression, **kw)
        if streamed:
            idx.reconfigure(memory_budget=_streamed_budget(idx))
        elif budget is not None:
            idx.reconfigure(memory_budget=budget)
        # streamed and fixed-budget rows only make sense on the pallas
        # substrate (the jnp reference ignores the VMEM budget) — the
        # resident cases keep the jnp twin as the reference column
        for substrate in (SUBSTRATES if not streamed and budget is None
                          else ("pallas",)):
            idx.reconfigure(substrate=substrate)
            sub = eng.get_substrate(substrate)
            walk_v = sub.walk_variant(idx.device, idx.cfg, seq_len) \
                if substrate == "pallas" else None
            beam_v = sub.beam_variant(idx.device, idx.cfg, k) \
                if substrate == "pallas" and engine == "beam" else None
            batches = fixed_batches(qs, batch)
            sec = time_batches(lambda b: idx.complete(b, k=k), batches)
            rows.append({
                "engine": engine,
                "kind": kind,
                "substrate": substrate,
                "backend": jax.default_backend(),
                "interpret_mode": jax.default_backend() != "tpu"
                and substrate == "pallas",
                "fused_walk": walk_v is not None,
                "fused_beam": beam_v is not None,
                "streamed_walk": walk_v == "streamed",
                "streamed_beam": beam_v == "streamed",
                "compression": compression,
                "memory_budget": idx.memory_budget,
                "bytes_per_string": round(idx.stats.bytes_per_string, 1),
                "us_per_q": round(sec * 1e6, 1),
            })
    return rows


def _table(rows):
    emit([[r["engine"], r["kind"], r["substrate"], r["compression"],
           r["bytes_per_string"], r["us_per_q"]]
          for r in rows],
         ["engine", "kind", "substrate", "compression", "bytes_per_string",
          "us_per_q"])


def b9_substrates():
    rows = bench_substrates()
    _table(rows)
    return rows


ALL = {
    "b9": ("execution substrates: jnp vs pallas end-to-end", b9_substrates),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI; pairs with --out for the "
                         "perf-trajectory artifact")
    ap.add_argument("--out", default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    rows = bench_substrates(k=args.k, batch=args.batch, smoke=args.smoke)
    _table(rows)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "substrates",
                       "backend": jax.default_backend(),
                       "smoke": args.smoke, "rows": rows}, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
