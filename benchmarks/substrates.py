"""B9: execution substrates — jnp vs pallas, end-to-end on the B7 workload.

Times ``CompletionIndex.complete`` through both registered substrates on
the same built index (the substrate switch is a config flip; host/device
structures are shared), for both phase-2 engines from B7: the
paper-faithful beam and the beyond-paper cached top-K.  On CPU the pallas
column runs the kernels in interpret mode — that measures dispatch
correctness and overhead, not kernel speed; the TPU run is where the
comparison is meaningful (see README "choosing a substrate").

  PYTHONPATH=src python -m benchmarks.substrates            # table
  PYTHONPATH=src python -m benchmarks.substrates --smoke \
      --out substrates-smoke.json                            # CI artifact
"""

from __future__ import annotations

import argparse
import json

import jax

from benchmarks.common import (SIZES, build_index, dataset, emit,
                               fixed_batches, time_batches)
from repro.data.strings import make_workload

# (label, build kwargs) — the two phase-2 engines benchmarked in B7
ENGINES = [("beam", {}), ("cached_k16", {"cache_k": 16})]
SUBSTRATES = ("jnp", "pallas")


def bench_substrates(k: int = 10, batch: int = 256, name: str = "usps",
                     smoke: bool = False):
    """Returns one row dict per (engine, substrate) with us/query."""
    n_queries = 200 if smoke else SIZES["queries"] // 2
    ds = dataset(name)
    if smoke:
        ds = type(ds)(name=ds.name, strings=ds.strings[:2000],
                      scores=ds.scores[:2000], rules=ds.rules)
    qs = make_workload(ds, n_queries, seed=11, max_len=14)
    if smoke:
        batch = 64
    rows = []
    for engine, kw in ENGINES:
        idx = build_index(ds, "et", **kw)
        for substrate in SUBSTRATES:
            idx.set_substrate(substrate)
            batches = fixed_batches(qs, batch)
            sec = time_batches(lambda b: idx.complete(b, k=k), batches)
            rows.append({
                "engine": engine,
                "substrate": substrate,
                "backend": jax.default_backend(),
                "interpret_mode": jax.default_backend() != "tpu"
                and substrate == "pallas",
                "bytes_per_string": round(idx.stats.bytes_per_string, 1),
                "us_per_q": round(sec * 1e6, 1),
            })
    return rows


def b9_substrates():
    rows = bench_substrates()
    emit([[r["engine"], r["substrate"], r["us_per_q"]] for r in rows],
         ["engine", "substrate", "us_per_q"])
    return rows


ALL = {
    "b9": ("execution substrates: jnp vs pallas end-to-end", b9_substrates),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI; pairs with --out for the "
                         "perf-trajectory artifact")
    ap.add_argument("--out", default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    rows = bench_substrates(k=args.k, batch=args.batch, smoke=args.smoke)
    emit([[r["engine"], r["substrate"], r["us_per_q"]] for r in rows],
         ["engine", "substrate", "us_per_q"])
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "substrates",
                       "backend": jax.default_backend(),
                       "smoke": args.smoke, "rows": rows}, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
