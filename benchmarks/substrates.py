"""B9: execution substrates — jnp vs pallas, end-to-end on the B7 workload.

Times ``CompletionIndex.complete`` through both registered substrates on
the same built indexes (the substrate switch is a config flip; host/device
structures are shared), across two axes:

- *phase-2 engine* (from B7): the paper-faithful beam vs the beyond-paper
  cached top-K (``cached_k16``), on the ET index;
- *rule-bearing walk* (the fused locus-DP kernel's workload): tt/et/ht
  with the dataset's synonym rule set, where phase 1 is the synonym-aware
  frontier sweep rather than the rule-free prefix walk;
- *beam phase 2* (the fused beam kernel's workload): every ``beam`` row
  runs the generator-pool priority search, and the rule-free ``plain``
  row isolates it behind the trivial prefix walk.  Each row records
  whether the pallas substrate claimed the beam natively (``fused_beam``,
  from the ``can_beam_batch`` probe).

On CPU the pallas column runs the kernels in interpret mode — that
measures dispatch correctness and overhead, not kernel speed; the TPU run
is where the comparison is meaningful (see README "choosing a
substrate").  Each row records whether the pallas substrate claimed the
walk natively (``fused_walk``, from the ``can_walk_batch`` probe).

  PYTHONPATH=src python -m benchmarks.substrates            # table
  PYTHONPATH=src python -m benchmarks.substrates --smoke \
      --out substrates-smoke.json                            # CI artifact
"""

from __future__ import annotations

import argparse
import json

import jax

from benchmarks.common import (SIZES, build_index, dataset, emit,
                               fixed_batches, time_batches)
from repro.data.strings import make_workload

# (label, index kind, build kwargs) — the two phase-2 engines benchmarked
# in B7 on ET, the rule-bearing walk workloads for the fused locus-DP
# kernel (tt = link store, ht = links + teleports), and a rule-free beam
# row where phase 1 is the trivial prefix walk so the beam phase-2 kernel
# dominates the measurement
CASES = [
    ("beam", "et", {}),
    ("cached_k16", "et", {"cache_k": 16}),
    ("beam", "tt", {}),
    ("beam", "ht", {}),
    ("beam", "plain", {}),
]
SUBSTRATES = ("jnp", "pallas")


def bench_substrates(k: int = 10, batch: int = 256, name: str = "usps",
                     smoke: bool = False):
    """Returns one row dict per (engine, kind, substrate) with us/query."""
    from repro.core import engine as eng

    n_queries = 200 if smoke else SIZES["queries"] // 2
    ds = dataset(name)
    if smoke:
        ds = type(ds)(name=ds.name, strings=ds.strings[:2000],
                      scores=ds.scores[:2000], rules=ds.rules)
    qs = make_workload(ds, n_queries, seed=11, max_len=14)
    if smoke:
        batch = 64
    rows = []
    # the probe must see the padded length complete() will actually jit
    # with, or the fused_walk column could misreport the timed path
    from repro.api.compile_cache import bucket_size
    seq_len = bucket_size(max(len(q) for q in qs))
    for engine, kind, kw in CASES:
        idx = build_index(ds, kind, **kw)
        for substrate in SUBSTRATES:
            idx.set_substrate(substrate)
            sub = eng.get_substrate(substrate)
            fused = substrate == "pallas" and sub.can_walk_batch(
                idx.device, idx.cfg, seq_len)
            # beam rows route phase 2 through the fused beam kernel when
            # the probe claims it (cached rows never touch the beam)
            fused_beam = substrate == "pallas" and engine == "beam" \
                and sub.can_beam_batch(idx.device, idx.cfg, k)
            batches = fixed_batches(qs, batch)
            sec = time_batches(lambda b: idx.complete(b, k=k), batches)
            rows.append({
                "engine": engine,
                "kind": kind,
                "substrate": substrate,
                "backend": jax.default_backend(),
                "interpret_mode": jax.default_backend() != "tpu"
                and substrate == "pallas",
                "fused_walk": bool(fused),
                "fused_beam": bool(fused_beam),
                "bytes_per_string": round(idx.stats.bytes_per_string, 1),
                "us_per_q": round(sec * 1e6, 1),
            })
    return rows


def _table(rows):
    emit([[r["engine"], r["kind"], r["substrate"], r["us_per_q"]]
          for r in rows], ["engine", "kind", "substrate", "us_per_q"])


def b9_substrates():
    rows = bench_substrates()
    _table(rows)
    return rows


ALL = {
    "b9": ("execution substrates: jnp vs pallas end-to-end", b9_substrates),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI; pairs with --out for the "
                         "perf-trajectory artifact")
    ap.add_argument("--out", default=None,
                    help="also write rows as JSON to this path")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    rows = bench_substrates(k=args.k, batch=args.batch, smoke=args.smoke)
    _table(rows)
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"benchmark": "substrates",
                       "backend": jax.default_backend(),
                       "smoke": args.smoke, "rows": rows}, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
