"""Trie proposes, model re-ranks (DESIGN §3.1): the paper's completion index
fetches cheap candidates; a SASRec-style user model re-scores them by
per-user affinity. This is how the technique composes with the assigned
recsys architectures.

  PYTHONPATH=src python examples/autocomplete_rerank.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompletionIndex, make_rules
from repro.models import recsys
from repro.serving import CompletionService

# --- a tiny product-title catalogue with abbreviations -----------------------
products = [
    "mechanical keyboard rgb", "mechanical keyboard silent",
    "memory card 128gb", "memory card 256gb", "monitor 27 inch 4k",
    "monitor 32 inch curved", "mouse wireless ergonomic",
    "mouse pad extended", "microphone usb condenser",
    "macbook case 14 inch",
]
scores = [90, 70, 85, 60, 95, 55, 80, 40, 75, 65]
rules = make_rules([("mech", "mechanical"), ("kb", "keyboard"),
                    ("mem", "memory"), ("mon", "monitor"),
                    ("mic", "microphone"), ("wl", "wireless")])
index = CompletionIndex.build(products, scores, rules, kind="et")

# --- a user-affinity reranker (SASRec user embedding vs title embedding) ----
cfg = recsys.SASRecConfig(vocab=len(products), seq_len=8, d_embed=16)
params, _ = recsys.init_sasrec(jax.random.PRNGKey(0), cfg)
title_to_id = {t: i for i, t in enumerate(products)}
# pretend the user recently browsed monitors
user_hist = jnp.asarray([[title_to_id["monitor 27 inch 4k"],
                          title_to_id["monitor 32 inch curved"],
                          -1, -1, -1, -1, -1, -1]])
user_vec = recsys.sasrec_user_embedding(params, {"hist": user_hist}, cfg)[0]
item_emb = params["items"]


def rerank(query, candidates):
    if not candidates:
        return candidates
    ids = jnp.asarray([title_to_id[s] for _, s in candidates])
    affinity = item_emb[ids] @ user_vec
    order = np.argsort(-np.asarray(affinity))
    return [(float(affinity[i]), candidates[i][1]) for i in order]


service = CompletionService(index, reranker=rerank, overfetch=2)
plain = CompletionService(index)

for q in ("m", "mon", "mem c", "mech kb"):
    a = [s for _, s in plain.complete([q], k=3)[0]]
    b = [s for _, s in service.complete([q], k=3)[0]]
    print(f"{q!r:8} popularity: {a}")
    print(f"{'':8} user-aware: {b}\n")
