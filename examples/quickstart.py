"""Quickstart: the paper's Fig. 1 example through the v2 API.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

from repro.api import CompletionIndex, IndexSpec, build_index
from repro.core import make_rules

strings = ["Andrew Pavlo", "Andrew Parker", "Andrew Packard",
           "Andy Warhol Museum", "William Smith"]
scores = [50, 40, 30, 25, 20]
rules = make_rules([("Andy", "Andrew"), ("Bill", "William")])

# -- declarative builds: one IndexSpec per structure --------------------------
for kind in ("tt", "et", "ht"):
    index = build_index(strings, scores, rules, IndexSpec(kind=kind))
    print(f"\n== {kind.upper()} "
          f"({index.stats.bytes_per_string:.0f} bytes/string) ==")
    for query in ("Andy Pa", "Bill", "Andrew P"):
        suggestions = index.complete([query], k=3)[0]
        print(f"  {query!r:12} -> "
              + (", ".join(f"{s}:{score}" for score, s in suggestions)
                 or "(no match)"))

# -- persistence: build once, restore without reconstruction ------------------
index = build_index(strings, scores, rules, IndexSpec(kind="ht", alpha=0.5))
path = os.path.join(tempfile.mkdtemp(), "fig1.npz")
index.save(path)
restored = CompletionIndex.load(path)
assert restored.complete(["Andy Pa"], k=3) == index.complete(["Andy Pa"], k=3)
print(f"\nsaved + restored from {path} "
      f"({os.path.getsize(path)} bytes on disk)")

# -- incremental typing: a session advances the frontier per keystroke --------
session = restored.session(k=3)
print("\ntyping 'Andy Pa' one keystroke at a time:")
for ch in "Andy Pa":
    suggestions = session.type(ch)
    print(f"  {session.prefix!r:12} -> "
          + (", ".join(s for _, s in suggestions) or "(no match)"))
session.backspace(2)
print(f"  after 2x backspace {session.prefix!r}: "
      + ", ".join(s for _, s in session.topk()))
