"""Quickstart: the paper's Fig. 1 example in ten lines.

  PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import CompletionIndex, make_rules

strings = ["Andrew Pavlo", "Andrew Parker", "Andrew Packard",
           "Andy Warhol Museum", "William Smith"]
scores = [50, 40, 30, 25, 20]
rules = make_rules([("Andy", "Andrew"), ("Bill", "William")])

for kind in ("tt", "et", "ht"):
    index = CompletionIndex.build(strings, scores, rules, kind=kind)
    print(f"\n== {kind.upper()} "
          f"({index.stats.bytes_per_string:.0f} bytes/string) ==")
    for query in ("Andy Pa", "Bill", "Andrew P"):
        suggestions = index.complete([query], k=3)[0]
        print(f"  {query!r:12} -> "
              + (", ".join(f"{s}:{score}" for score, s in suggestions)
                 or "(no match)"))
