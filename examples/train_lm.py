"""Train a ~100M-parameter LM for a few hundred steps (deliverable b).

Uses the full framework stack: data pipeline, sharded train step, AdamW,
checkpointing + restart supervisor. The config is a scaled granite-family
MoE so the paper-adjacent serving example can rerank with it afterwards.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.cells import make_train_step
from repro.data.lm import LMDataConfig, TokenStream
from repro.distributed.fault_tolerance import TrainSupervisor
from repro.models.transformer import TransformerConfig, init_lm, loss_fn
from repro.optim import OptimizerConfig, init_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12L x 512d with 8 small experts
    cfg = TransformerConfig(
        name="lm100m", n_layers=12, d_model=512, n_heads=8, n_kv=4,
        d_head=64, d_ff=1024, vocab=8192, moe_experts=8, moe_top_k=2,
        loss_chunk=128)
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params "
          f"({cfg.active_param_count() / 1e6:.1f}M active)")

    opt_cfg = OptimizerConfig(name="adamw", lr=1e-3, warmup_steps=50,
                              decay_steps=args.steps)
    opt = init_optimizer(opt_cfg, params)
    step_fn = jax.jit(make_train_step(loss_fn, cfg, opt_cfg),
                      donate_argnums=(0, 1))
    stream = TokenStream(LMDataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                      global_batch=args.batch))

    sup = TrainSupervisor(args.ckpt, ckpt_every=100)
    losses = []

    def one_step(state, i):
        batch = jax.tree.map(jnp.asarray, stream.next_batch())
        t0 = time.perf_counter()
        p, o, m = step_fn(state["params"], state["opt"], batch)
        losses.append(float(m["loss"]))
        if i % 25 == 0:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"{(time.perf_counter() - t0) * 1e3:.0f}ms", flush=True)
        return {"params": p, "opt": o}

    state, report = sup.run(init_state={"params": params, "opt": opt},
                            step_fn=one_step, n_steps=args.steps,
                            extra_from_state=lambda s: {
                                "data_step": stream.state()})
    print(f"done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({report.steps_run} steps, {report.restarts} restarts)")
    assert losses[-1] < losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
