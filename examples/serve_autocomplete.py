"""End-to-end serving driver (deliverable b): build a USPS-scale synonym
completion index, replay a batched query workload through the
CompletionService, report latency + throughput per structure.

  PYTHONPATH=src python examples/serve_autocomplete.py [--n 100000]
"""

import argparse
import time

from repro.api import IndexSpec, build_index
from repro.core import make_rules
from repro.data.strings import make_usps, make_workload
from repro.serving import CompletionService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--queries", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=512)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    print(f"building USPS-like dataset: {args.n} strings ...")
    ds = make_usps(n=args.n, seed=0)
    queries = make_workload(ds, args.queries, seed=1, max_len=16)
    batches = [queries[i : i + args.batch]
               for i in range(0, len(queries), args.batch)
               if len(queries[i : i + args.batch]) == args.batch]

    for kind, kw in [("tt", {}), ("et", {}), ("ht", {"alpha": 0.5}),
                     ("et+cache", {"cache_k": 16})]:
        spec = IndexSpec(kind=kind.split("+")[0], **kw)
        t0 = time.perf_counter()
        idx = build_index(ds.strings, ds.scores, make_rules(ds.rules), spec)
        build_s = time.perf_counter() - t0
        svc = CompletionService(idx)
        svc.complete(batches[0], k=args.k)            # compile/warmup
        t0 = time.perf_counter()
        n = 0
        for b in batches:
            svc.complete(b, k=args.k)
            n += len(b)
        dt = time.perf_counter() - t0
        print(f"{kind:9s} build {build_s:6.1f}s  "
              f"{idx.stats.bytes_per_string:7.1f} B/string  "
              f"{dt / n * 1e6:8.1f} us/completion  "
              f"{n / dt:8.0f} q/s")

    # incremental typing through a stateful serving session: each keystroke
    # advances the saved locus frontier instead of rescanning the prefix
    idx = build_index(ds.strings, ds.scores, make_rules(ds.rules),
                      IndexSpec(kind="et", cache_k=16))
    svc = CompletionService(idx)
    sess = svc.open_session(k=3)
    sess.type(queries[0])                               # compile/warmup
    svc.stats.reset_keystrokes()
    for q in queries[:64]:
        sess.reset()
        sess.type(q)
    print(f"keystroke sessions: {svc.stats.n_keystrokes} keystrokes  "
          f"{svc.stats.mean_keystroke_ms * 1e3:8.1f} us/keystroke  "
          f"p99 {svc.stats.p99_keystroke_ms():6.2f} ms")

    # show a few suggestions
    for q in queries[:5]:
        out = idx.complete([q], k=3)[0]
        print(f"  {q!r} -> {[s for _, s in out]}")


if __name__ == "__main__":
    main()
