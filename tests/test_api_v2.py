"""repro.api v2 behaviour: IndexSpec registry dispatch, npz persistence
round-trips, incremental Session-vs-batch equivalence, and the bounded
compile cache."""

import dataclasses

import numpy as np
import pytest

from repro.api import (CompileCache, CompletionIndex, IndexSpec, Session,
                       bucket_size, build_index, register_builder,
                       registered_kinds)
from repro.core import make_rules
from repro.data.strings import make_usps, make_workload

KINDS = ["tt", "et", "ht", "plain"]


@pytest.fixture(scope="module")
def paper_example():
    strings = ["andrew pavlo", "andrew parker", "andrew packard",
               "william smith", "bill of rights"]
    scores = [50, 40, 30, 20, 10]
    rules = make_rules([("andy", "andrew"), ("bill", "william")])
    return strings, scores, rules


@pytest.fixture(scope="module")
def usps():
    ds = make_usps(n=1200, seed=0)
    return ds, make_rules(ds.rules)


# -- IndexSpec + registry -----------------------------------------------------


def test_spec_registry_dispatch_all_kinds(paper_example):
    """Every registered kind builds through the registry and the spec is
    recorded on the result."""
    strings, scores, rules = paper_example
    assert set(KINDS) <= set(registered_kinds())
    for kind in KINDS:
        spec = IndexSpec(kind=kind, alpha=0.4, cache_k=4)
        idx = build_index(strings, scores, rules, spec)
        assert idx.spec == spec
        assert idx.kind == kind
        assert idx.stats.kind == kind
    # kind-specific structure invariants prove per-kind builders really ran
    tt = build_index(strings, scores, rules, IndexSpec(kind="tt"))
    et = build_index(strings, scores, rules, IndexSpec(kind="et"))
    plain = build_index(strings, scores, rules, IndexSpec(kind="plain"))
    assert tt.stats.n_syn_nodes == 0 and tt.stats.n_links > 0
    assert et.stats.n_links == 0 and et.stats.n_syn_nodes > 0
    assert plain.stats.n_links == 0 and plain.stats.n_syn_nodes == 0


def test_spec_validation_errors():
    with pytest.raises(ValueError, match="unknown index kind"):
        IndexSpec(kind="bogus").validate()
    with pytest.raises(ValueError, match="alpha"):
        IndexSpec(kind="ht", alpha=1.5).validate()
    with pytest.raises(ValueError, match="frontier"):
        IndexSpec(frontier=0).validate()
    with pytest.raises(TypeError):
        build_index(["a"], [1], [], spec=IndexSpec(), kind="et")


def test_register_builder_additive(paper_example):
    """A new kind is an additive registration, no core edits."""
    strings, scores, rules = paper_example
    name = "test-links-only"
    if name not in registered_kinds():
        @register_builder(name)
        def _links_only(ctx):
            n = len(ctx.rules)
            return np.zeros(n, bool), np.ones(n, bool)

    idx = build_index(strings, scores, rules, IndexSpec(kind=name))
    tt = build_index(strings, scores, rules, IndexSpec(kind="tt"))
    assert idx.complete(["andy pa"], k=3) == tt.complete(["andy pa"], k=3)


def test_build_backcompat_kwargs(paper_example):
    """Old keyword surface still works and matches the spec path."""
    strings, scores, rules = paper_example
    old = CompletionIndex.build(strings, scores, rules, kind="ht", alpha=0.3,
                                cache_k=8)
    new = build_index(strings, scores, rules,
                      IndexSpec(kind="ht", alpha=0.3, cache_k=8))
    assert old.spec == new.spec
    qs = ["andy", "bill", "a", "w"]
    assert old.complete(qs, 5) == new.complete(qs, 5)


# -- persistence --------------------------------------------------------------


def test_save_load_roundtrip(tmp_path, usps):
    """A loaded index answers identically to the freshly built one, with
    byte-identical BuildStats, and without re-running construction."""
    ds, rules = usps
    idx = build_index(ds.strings, ds.scores, rules,
                      IndexSpec(kind="ht", alpha=0.5, cache_k=8))
    path = str(tmp_path / "usps.npz")
    idx.save(path)
    loaded = CompletionIndex.load(path)
    assert dataclasses.asdict(loaded.stats) == dataclasses.asdict(idx.stats)
    assert loaded.spec == idx.spec
    assert loaded.cfg == idx.cfg
    assert loaded.strings == idx.strings
    for f in dataclasses.fields(idx.trie):
        a, b = getattr(idx.trie, f.name), getattr(loaded.trie, f.name)
        if isinstance(a, np.ndarray):
            np.testing.assert_array_equal(a, b, err_msg=f.name)
    qs = make_workload(ds, 48, seed=2)
    assert loaded.complete(qs, k=10) == idx.complete(qs, k=10)


def test_save_load_roundtrip_no_cache_no_rules(tmp_path):
    idx = build_index(["alpha", "beta", "betamax"], [3, 2, 1], [],
                      IndexSpec(kind="plain"))
    path = str(tmp_path / "plain.npz")
    idx.save(path)
    loaded = CompletionIndex.load(path)
    assert loaded.trie.topk_score is None
    assert loaded.complete(["b"], k=5) == idx.complete(["b"], k=5)


def test_load_rejects_bad_container(tmp_path):
    bad = str(tmp_path / "bad.npz")
    np.savez(bad, x=np.zeros(3))
    with pytest.raises(ValueError, match="not a repro completion-index"):
        CompletionIndex.load(bad)


# -- sessions -----------------------------------------------------------------


@pytest.mark.parametrize("kind", ["tt", "et", "ht"])
def test_session_matches_oneshot_per_keystroke(kind, usps):
    """Typing char-by-char through a Session yields exactly the one-shot
    ``complete`` answer at every prefix."""
    ds, rules = usps
    idx = build_index(ds.strings, ds.scores, rules,
                      IndexSpec(kind=kind, alpha=0.5))
    sess = idx.session(k=5)
    for q in make_workload(ds, 8, seed=3, max_len=10):
        sess.reset()
        for i, ch in enumerate(q):
            got = sess.type(ch)
            want = idx.complete([q[:i + 1]], k=5)[0]
            assert got == want, (q, q[:i + 1], kind)


def test_session_multichar_rules_and_backspace(paper_example):
    strings, scores, rules = paper_example
    idx = build_index(strings, scores, rules, IndexSpec(kind="tt"))
    sess = idx.session(k=3)
    assert sess.type("andy pa") == idx.complete(["andy pa"], k=3)[0]
    assert sess.prefix == "andy pa"
    assert sess.backspace(3) == idx.complete(["andy"], k=3)[0]
    assert sess.prefix == "andy"
    # keep typing after backspace
    assert sess.type(" pav") == idx.complete(["andy pav"], k=3)[0]
    sess.reset()
    assert sess.prefix == ""
    assert sess.type("bill") == idx.complete(["bill"], k=3)[0]


def test_session_cached_topk_path(paper_example):
    strings, scores, rules = paper_example
    idx = build_index(strings, scores, rules, IndexSpec(kind="et", cache_k=8))
    sess = Session(idx, k=3)
    for prefix in ("a", "an", "andy", "andy p"):
        sess.reset()
        assert sess.type(prefix) == idx.complete([prefix], k=3)[0], prefix


def test_advance_loci_scan_matches_locus_dp(paper_example):
    """The batched engine entry point (scan over a padded char vector) must
    land on the same loci/top-k as the per-char step and the one-shot DP."""
    import jax.numpy as jnp

    from repro.core import engine as eng
    from repro.core.alphabet import pad_queries

    strings, scores, rules = paper_example
    idx = build_index(strings, scores, rules, IndexSpec(kind="tt"))
    t, cfg = idx.device, idx.cfg
    for q in ("andy pa", "bill", "a", "xyz"):
        qs, qlens = pad_queries([q], 8)          # -1 padded beyond len(q)
        state = eng.advance_loci(t, cfg, eng.init_locus_state(t, cfg),
                                 jnp.asarray(qs[0]))
        assert int(state.length) == len(q)       # pads were no-ops
        s_inc, i_inc, e_inc = eng.topk_from_loci(t, cfg, state, 3)
        s_one, i_one, e_one = eng.complete_one(
            t, cfg, jnp.asarray(qs[0]), jnp.asarray(qlens[0]), 3)
        np.testing.assert_array_equal(np.asarray(s_inc), np.asarray(s_one), q)
        np.testing.assert_array_equal(np.asarray(i_inc), np.asarray(i_one), q)
        assert bool(e_inc) == bool(e_one), q


def test_service_session_stats(paper_example):
    from repro.serving import CompletionService

    strings, scores, rules = paper_example
    idx = build_index(strings, scores, rules, IndexSpec(kind="et"))
    svc = CompletionService(idx)
    sess = svc.open_session(k=3)
    out = sess.type("andy")
    assert [s for s, _ in out] == [50, 40, 30]
    assert svc.stats.n_keystrokes == 4
    assert len(svc.stats.keystroke_latencies_ms) == 4
    assert svc.stats.mean_keystroke_ms > 0
    assert svc.stats.p99_keystroke_ms() > 0
    svc.stats.reset_keystrokes()
    assert svc.stats.n_keystrokes == 0
    assert svc.stats.keystroke_latencies_ms == []


def test_inexact_retry_path_recovers(paper_example):
    """Deliberately starved widths force the exactness retry (regression:
    the widened pass used to crash writing into read-only jit output)."""
    strings, scores, rules = paper_example
    tiny = build_index(strings, scores, rules,
                       IndexSpec(kind="tt", frontier=2, gens=2, expand=2,
                                 max_steps=4))
    wide = build_index(strings, scores, rules, IndexSpec(kind="tt"))
    qs = ["an", "andy pa", "bill", "a"]
    assert tiny.complete(qs, k=3) == wide.complete(qs, k=3)
    # session fallback routes through the same retry machinery
    sess = tiny.session(k=3)
    assert sess.type("andy pa") == wide.complete(["andy pa"], k=3)[0]


def test_service_latency_window_bounded(paper_example):
    from repro.serving import completion_service as cs

    strings, scores, rules = paper_example
    idx = build_index(strings, scores, rules, IndexSpec(kind="et"))
    svc = cs.CompletionService(idx)
    stats = svc.stats
    stats.latencies_ms.extend([0.1] * cs.LATENCY_WINDOW)
    stats.keystroke_latencies_ms.extend([0.1] * cs.LATENCY_WINDOW)
    svc.complete(["a"], k=3)
    svc.open_session(k=3).type("an")
    assert len(stats.latencies_ms) == cs.LATENCY_WINDOW
    assert len(stats.keystroke_latencies_ms) == cs.LATENCY_WINDOW
    assert stats.n_keystrokes == 2          # counters unaffected by the cap


# -- compile cache ------------------------------------------------------------


def test_bucket_size():
    assert bucket_size(1) == 8
    assert bucket_size(8) == 8
    assert bucket_size(9) == 16
    assert bucket_size(100) == 128
    assert bucket_size(3, minimum=1) == 4


def test_compile_cache_lru_bounded():
    cache = CompileCache(maxsize=2)
    a = cache.get("a", lambda: "va")
    assert cache.get("a", lambda: "XX") == "va"          # hit
    cache.get("b", lambda: "vb")
    cache.get("a", lambda: "XX")                          # refresh a
    cache.get("c", lambda: "vc")                          # evicts b (LRU)
    assert "b" not in cache and "a" in cache and "c" in cache
    assert len(cache) == 2
    assert cache.stats()["evictions"] == 1
    assert a == "va"


def test_index_compile_cache_buckets_batches(paper_example):
    """Nearby batch sizes share one compiled executable."""
    strings, scores, rules = paper_example
    idx = build_index(strings, scores, rules, IndexSpec(kind="et"))
    idx.complete(["a"], k=3)                            # batch bucket 1
    misses0 = idx._compile_cache.misses
    idx.complete(["a", "an", "and"], k=3)               # B=3 -> bucket 4
    idx.complete(["a", "an", "and", "andy"], k=3)       # B=4 -> bucket 4: hit
    assert idx._compile_cache.misses == misses0 + 1
    assert idx._compile_cache.hits >= 1
