"""Shared hypothesis strategies for the property suites.

Promoted out of ``test_core_oracle.py`` so the oracle property tests and
the cross-substrate differential harness (``test_differential.py``) draw
from one vocabulary of random dictionaries, rule sets and query streams.

hypothesis is an optional dev dependency (requirements-dev.txt): when it
is absent every strategy name is ``None`` and ``HAVE_HYPOTHESIS`` is
False — test modules guard with ``needs_hypothesis`` so the gap surfaces
as explicit skips, not collection errors.

The ``differential`` settings profile is **derandomized**: hypothesis
draws the same examples on every run, so a CI failure reproduces locally
with nothing but the test id.  ``DIFF_MAX_EXAMPLES`` bounds the example
count per property (interpret-mode kernel compiles dominate the cost).
"""

from __future__ import annotations

import os

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic tests still run without hypothesis
    given = settings = st = None
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="hypothesis not installed (requirements-dev.txt)")

#: every registered index kind (the differential harness parametrizes
#: over these explicitly so coverage does not depend on random draws)
ALL_KINDS = ["plain", "tt", "et", "ht"]
#: the rule-bearing kinds (the oracle property tests sample these)
RULE_KINDS = ["tt", "et", "ht"]


def max_examples(default: int) -> int:
    """Per-property example budget; ``DIFF_MAX_EXAMPLES`` overrides (CI
    pins it so the differential suite has a known cost)."""
    return int(os.environ.get("DIFF_MAX_EXAMPLES", default))


if HAVE_HYPOTHESIS:
    #: dictionary entries: short words over a tiny alphabet, so random
    #: dictionaries collide on prefixes often (the interesting regime)
    words = st.text(alphabet="abcd", min_size=1, max_size=8)

    #: a random dictionary (unique strings; scores are drawn separately)
    dictionaries = st.lists(words, min_size=1, max_size=25, unique=True)

    #: random (lhs, rhs) rule pairs; lhs may use chars outside the
    #: dictionary alphabet so some rules never anchor
    rule_sets = st.lists(
        st.tuples(st.text(alphabet="abcdxy", min_size=1, max_size=3),
                  st.text(alphabet="abcd", min_size=1, max_size=3)),
        max_size=5)

    #: random query streams, again over the widened alphabet so queries
    #: miss, hit literally, and hit only through rules
    query_streams = st.lists(
        st.text(alphabet="abcdxy", min_size=1, max_size=6),
        min_size=1, max_size=5)

    #: top-k depths worth exercising (k < |dict|, k ~ |dict|, k >)
    topk_values = st.sampled_from([1, 3, 10])

    score_seeds = st.integers(0, 2**31 - 1)

    #: query streams for the bounded-edit differential: longer and over
    #: the widened alphabet, so draws land near-misses (one substitution
    #: / insertion / deletion away from dictionary prefixes) as often as
    #: exact hits and outright misses
    edit_query_streams = st.lists(
        st.text(alphabet="abcdxy", min_size=0, max_size=7),
        min_size=1, max_size=4)

    settings.register_profile(
        "differential", derandomize=True, deadline=None,
        print_blob=True)
else:
    words = dictionaries = rule_sets = query_streams = None
    topk_values = score_seeds = edit_query_streams = None


def clean_rules(pairs):
    """Drop degenerate lhs == rhs pairs (the builders reject identity
    rewrites by construction elsewhere)."""
    return [(l, r) for l, r in pairs if l != r]
