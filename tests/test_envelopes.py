"""Envelope-boundary regressions for the resident/streamed/jnp probes.

The ``walk_variant`` / ``beam_variant`` probes pick an execution tier
per call: VMEM-resident kernels inside the byte budget, the DMA-streamed
tier above it, and the jnp fallback outside the static shape envelope.
These tests sit parametrized cases *exactly on* the byte-budget and
W/P/k/F edges and assert (a) the probe picks the expected tier on each
side, and (b) results agree bit-for-bit across every boundary — a probe
that flips tiers must never flip answers.  The PR 4 host-side
doubled-width retry re-probes per round; its behavior under a streamed
budget is covered too.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import IndexSpec, Session, build_index
from repro.core import engine as eng
from repro.core import make_rules
from repro.core.alphabet import pad_queries

QUERIES = ["andy pa", "andrew pa", "bil", "a", "w", "andrew pavlo", "xyz",
           ""]


@pytest.fixture(scope="module")
def paper_data():
    strings = ["andrew pavlo", "andrew parker", "andrew packard",
               "william smith", "bill of rights"]
    scores = [50, 40, 30, 20, 10]
    rules = make_rules([("andy", "andrew"), ("bill", "william")])
    return strings, scores, rules


def _build(paper_data, kind, **kw):
    strings, scores, rules = paper_data
    return build_index(strings, scores, rules, IndexSpec(kind=kind, **kw))


def _sub():
    return eng.get_substrate("pallas")


def _complete_parity(idx, budgets, k=3):
    """The same index must answer identically under every budget (i.e.
    across whatever tier each budget lands on), on both substrates."""
    expect = idx.set_substrate("jnp").complete(QUERIES, k=k)
    idx.set_substrate("pallas")
    for b in budgets:
        assert idx.set_memory_budget(b).complete(QUERIES, k=k) == expect, b
    return expect


# -- byte-budget edges --------------------------------------------------------


@pytest.mark.parametrize("kind", ["plain", "tt", "et", "ht"])
def test_walk_budget_edge_resident_vs_streamed(paper_data, kind):
    """A budget exactly equal to the walk tables' bytes keeps the
    resident tier; one byte less tips into the streamed tier; results
    agree on both sides of the edge."""
    sub = _sub()
    idx = _build(paper_data, kind)
    t, cfg = idx.device, idx.cfg
    if sub._rule_free(t, cfg):
        edge = sub._table_bytes(t, sub._PREFIX_FIELDS)
    else:
        edge = sub._table_bytes(
            t, sub._WALK_STREAM_FIELDS + sub._WALK_RESIDENT_FIELDS)
    from dataclasses import replace
    at = replace(cfg, memory_budget=edge)
    below = replace(cfg, memory_budget=edge - 1)
    assert sub.walk_variant(t, at, 16) == "resident"
    assert sub.walk_variant(t, below, 16) == "streamed"
    _complete_parity(idx, [edge, edge - 1])


def test_walk_streamed_requires_resident_rule_trie(paper_data):
    """The streamed locus tier keeps the rule trie in VMEM: a budget too
    small even for that refuses the kernel (jnp fallback), and the
    fallback still answers identically."""
    sub = _sub()
    idx = _build(paper_data, "ht")
    t, cfg = idx.device, idx.cfg
    rule_bytes = sub._table_bytes(t, sub._WALK_RESIDENT_FIELDS)
    from dataclasses import replace
    at = replace(cfg, memory_budget=rule_bytes)
    below = replace(cfg, memory_budget=rule_bytes - 1)
    assert sub.walk_variant(t, at, 16) == "streamed"
    assert sub.walk_variant(t, below, 16) is None
    assert not sub.can_walk_batch(t, below, 16)
    _complete_parity(idx, [rule_bytes, rule_bytes - 1])


def test_beam_budget_edge_resident_vs_streamed(paper_data):
    sub = _sub()
    idx = _build(paper_data, "et")
    t, cfg = idx.device, idx.cfg
    edge = sub._table_bytes(t, sub._BEAM_FIELDS)
    from dataclasses import replace
    assert sub.beam_variant(t, replace(cfg, memory_budget=edge), 3) \
        == "resident"
    assert sub.beam_variant(t, replace(cfg, memory_budget=edge - 1), 3) \
        == "streamed"
    _complete_parity(idx, [edge, edge - 1])


def test_default_budget_used_when_unset(paper_data):
    """memory_budget=0 means the substrate default: small tries stay
    resident (today's behavior, unchanged)."""
    sub = _sub()
    idx = _build(paper_data, "ht")
    assert idx.cfg.memory_budget == 0
    assert sub._budget(idx.cfg) == sub._DEFAULT_VMEM_BUDGET
    assert sub.walk_variant(idx.device, idx.cfg, 16) == "resident"
    assert sub.beam_variant(idx.device, idx.cfg, 3) == "resident"


# -- W/P/k/F shape edges ------------------------------------------------------


def test_beam_k_edge(paper_data):
    sub = _sub()
    idx = _build(paper_data, "et")
    t, cfg = idx.device, idx.cfg
    assert sub.beam_variant(t, cfg, sub._BEAM_MAX_K) == "resident"
    assert sub.beam_variant(t, cfg, sub._BEAM_MAX_K + 1) is None


def test_beam_gens_expand_edges(paper_data):
    sub = _sub()
    t = _build(paper_data, "et").device
    at = _build(paper_data, "et", gens=sub._BEAM_MAX_GENS)
    over = _build(paper_data, "et", gens=sub._BEAM_MAX_GENS + 1)
    assert sub.beam_variant(at.device, at.cfg, 3) is not None
    assert sub.beam_variant(over.device, over.cfg, 3) is None
    # P <= W precondition: expand == gens is the last admissible width;
    # past it the probe must refuse (P > W cannot even pop the
    # reference's pool, so refusal is the contract, not a fallback)
    eq = _build(paper_data, "et", frontier=8, gens=8, expand=8)
    from dataclasses import replace
    assert sub.beam_variant(eq.device, eq.cfg, 3) is not None
    assert sub.beam_variant(eq.device, replace(eq.cfg, expand=9), 3) is None
    assert eq.set_substrate("pallas").complete(QUERIES, k=3) == \
        eq.set_substrate("jnp").complete(QUERIES, k=3)


def test_beam_frontier_pool_edge(paper_data):
    """F <= W: the pool must hold the seed antichain.  frontier == gens
    is the last admissible width; one past it the probe must refuse
    (F > W cannot even seed the reference's pool, so there is no
    fallback parity to check — refusing is the whole contract)."""
    sub = _sub()
    fit = _build(paper_data, "et", frontier=8, gens=8)
    over = _build(paper_data, "et", frontier=9, gens=9)
    from dataclasses import replace
    over_cfg = replace(over.cfg, gens=8)
    assert sub.beam_variant(fit.device, fit.cfg, 3) is not None
    assert sub.beam_variant(over.device, over_cfg, 3) is None
    assert fit.set_substrate("pallas").complete(QUERIES, k=3) == \
        fit.set_substrate("jnp").complete(QUERIES, k=3)


def test_walk_frontier_edge(paper_data):
    sub = _sub()
    at = _build(paper_data, "ht", frontier=sub._FUSE_MAX_FRONTIER,
                gens=2 * sub._FUSE_MAX_FRONTIER)
    over = _build(paper_data, "ht", frontier=sub._FUSE_MAX_FRONTIER + 1,
                  gens=2 * sub._FUSE_MAX_FRONTIER)
    assert sub.walk_variant(at.device, at.cfg, 16) == "resident"
    assert sub.walk_variant(over.device, over.cfg, 16) is None
    from repro.core.alphabet import pad_queries as pq
    qs, qlens = pq(QUERIES[:4], 16)
    qs, qlens = jnp.asarray(qs), jnp.asarray(qlens)
    for idx in (at, over):
        a = sub.walk_batch(idx.device, idx.cfg, qs, qlens)
        b = eng.get_substrate("jnp").walk_batch(idx.device, idx.cfg, qs,
                                                qlens)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_walk_seq_len_edge(paper_data):
    """The padded query length is part of the walk envelope: one past
    _FUSE_MAX_SEQ refuses the kernel on rule-bearing tries regardless of
    budget."""
    sub = _sub()
    idx = _build(paper_data, "tt")
    t, cfg = idx.device, idx.cfg
    assert sub.walk_variant(t, cfg, sub._FUSE_MAX_SEQ) == "resident"
    assert sub.walk_variant(t, cfg, sub._FUSE_MAX_SEQ + 1) is None


# -- retry-reprobe under a streamed budget ------------------------------------


@pytest.mark.parametrize("kind", ["tt", "ht"])
def test_retry_reprobe_streamed_budget(paper_data, kind):
    """Starved widths force the host-side doubled-width retry; under a
    budget that keeps the index on the streamed tier, every retry round
    re-probes (streamed round 1, jnp fallback later) and converges to
    the wide reference answers."""
    sub = _sub()
    wide = _build(paper_data, kind)
    expect = wide.complete(QUERIES, k=3)
    tiny = _build(paper_data, kind, frontier=2, gens=2, expand=2,
                  max_steps=4)
    budget = sub._table_bytes(tiny.device, sub._WALK_RESIDENT_FIELDS)
    tiny.set_memory_budget(budget)
    assert sub.walk_variant(tiny.device, tiny.cfg, 16) == "streamed"
    assert sub.beam_variant(tiny.device, tiny.cfg, 3) == "streamed"
    # round 1 of the retry (F x2, W x4) must still be claimed streamed
    from dataclasses import replace
    cfg1 = replace(tiny.cfg, frontier=tiny.cfg.frontier * 2,
                   gens=tiny.cfg.gens * 4,
                   max_steps=tiny.cfg.max_steps * 4, use_cache=False)
    assert sub.beam_variant(tiny.device, cfg1, 3) == "streamed"
    for substrate in ("jnp", "pallas"):
        assert tiny.set_substrate(substrate).complete(QUERIES, k=3) \
            == expect
    # session fallback routes through the same retry machinery
    sess = Session(tiny.set_substrate("pallas"), k=3)
    assert sess.type("andy pa") == expect[0]


def test_cached_merge_over_budget_falls_back_to_jnp(paper_data):
    """The fused cached-top-K merge kernels hold the (N, K) cache tables
    whole in VMEM (no streamed cached tier yet): caches over the budget
    must answer through the jnp reference merge, identically, instead of
    routing to an unfittable kernel."""
    sub = _sub()
    idx = _build(paper_data, "et", cache_k=8)
    t = idx.device
    cache_bytes = sub._table_bytes(t, sub._CACHE_FIELDS)
    forcing = sub.min_streamed_budget(t)
    # kernel at the edge, jnp fallback one byte under, and the forcing
    # budget where the walk streams while the cached merge steps down
    expect = _complete_parity(idx, [cache_bytes, cache_bytes - 1, forcing])
    from dataclasses import replace
    assert sub.walk_variant(t, replace(idx.cfg, memory_budget=forcing),
                            16) == "streamed"
    assert expect[0]    # the cached path actually answered


def test_memory_budget_rides_compile_cache_key(paper_data):
    """Flipping the budget at runtime re-probes without rebuilding:
    executables for both tiers coexist in the compile cache."""
    sub = _sub()
    idx = _build(paper_data, "et").set_substrate("pallas")
    streamed_budget = sub._table_bytes(idx.device,
                                       sub._WALK_RESIDENT_FIELDS)
    r1 = idx.complete(["andy pa"], k=3)
    misses0 = idx._compile_cache.misses
    idx.set_memory_budget(streamed_budget)
    assert idx.complete(["andy pa"], k=3) == r1
    assert idx._compile_cache.misses == misses0 + 1
    idx.set_memory_budget(0)
    assert idx.complete(["andy pa"], k=3) == r1   # resident exe still cached
    assert idx._compile_cache.misses == misses0 + 1
