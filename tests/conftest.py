"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; only launch/dryrun.py
(and explicit subprocess tests) force 512 host devices."""

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
