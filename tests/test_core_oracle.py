"""Core correctness: TT / ET / HT / cached engines vs the Problem-1 oracle.

Includes hypothesis property tests over random dictionaries, rule sets and
queries — the central invariant of the whole system: every index kind
returns exactly the oracle's top-k score multiset.
"""

import numpy as np
import pytest

from repro.core import CompletionIndex, OracleIndex, make_rules

import strategies as strat
from strategies import given, settings, st

KINDS = strat.RULE_KINDS


def build_all(strings, scores, rules, **kw):
    return {k: CompletionIndex.build(strings, scores, rules, kind=k, **kw)
            for k in KINDS}


@pytest.fixture(scope="module")
def paper_example():
    strings = ["andrew pavlo", "andrew parker", "andrew packard",
               "william smith", "bill of rights"]
    scores = [50, 40, 30, 20, 10]
    rules = make_rules([("andy", "andrew"), ("bill", "william")])
    return strings, scores, rules


def test_paper_example_fig1(paper_example):
    """The paper's Fig. 1 scenario: 'Andy Pa' completes to Andrew *."""
    strings, scores, rules = paper_example
    for kind, idx in build_all(strings, scores, rules).items():
        out = idx.complete(["andy pa"], k=3)[0]
        assert [s for s, _ in out] == [50, 40, 30], kind
        assert {x for _, x in out} == {
            "andrew pavlo", "andrew parker", "andrew packard"}, kind


def test_prefix_only_still_works(paper_example):
    strings, scores, rules = paper_example
    for kind, idx in build_all(strings, scores, rules).items():
        out = idx.complete(["andrew pa"], k=10)[0]
        assert len(out) == 3, kind


def test_no_match(paper_example):
    strings, scores, rules = paper_example
    for kind, idx in build_all(strings, scores, rules).items():
        assert idx.complete(["xyz"], k=5)[0] == [], kind


def test_multi_rule_application():
    strings = ["database management systems conference"]
    rules = make_rules([("db", "database"), ("mgmt", "management"),
                        ("sys", "systems")])
    oracle = OracleIndex(strings, [7], rules)
    assert oracle.topk_scores("db mgmt sys", 3) == [7]
    for kind, idx in build_all(strings, [7], rules).items():
        out = idx.complete(["db mgmt sys", "db management sys"], k=3)
        assert [s for s, _ in out[0]] == [7], kind
        assert [s for s, _ in out[1]] == [7], kind


def test_rule_output_cannot_feed_rule():
    """Generated text never participates in a later application."""
    strings = ["xyz"]
    # 'a' -> 'x', then 'xb' -> 'xyz' would need the generated x
    rules = make_rules([("a", "x"), ("xb", "xyz")])
    oracle = OracleIndex(strings, [5], rules)
    assert oracle.matches("ab") == set()
    for kind, idx in build_all(strings, [5], rules).items():
        assert idx.complete(["ab"], k=3)[0] == [], kind
    # but the un-chained forms work
    assert oracle.matches("a") == {b"xyz"}
    for kind, idx in build_all(strings, [5], rules).items():
        assert [s for s, _ in idx.complete(["a"], k=3)[0]] == [5], kind


def test_ht_alpha_extremes_match_tt_et():
    strings = [f"record {i:03d} common" for i in range(50)]
    scores = list(range(1, 51))
    rules = make_rules([("rec", "record"), ("cmn", "common")])
    ht0 = CompletionIndex.build(strings, scores, rules, kind="ht", alpha=0.0)
    ht1 = CompletionIndex.build(strings, scores, rules, kind="ht", alpha=1.0)
    assert ht0.stats.n_syn_nodes == 0            # alpha=0 == TT
    assert ht1.stats.n_links == 0                # alpha=1 == ET
    tt = CompletionIndex.build(strings, scores, rules, kind="tt")
    et = CompletionIndex.build(strings, scores, rules, kind="et")
    qs = ["rec 00", "record 04", "cmn", "rec"]
    for a, b in [(ht0, tt), (ht1, et)]:
        ra, rb = a.complete(qs, k=5), b.complete(qs, k=5)
        assert [[s for s, _ in r] for r in ra] == \
            [[s for s, _ in r] for r in rb]


def test_cached_topk_equals_beam(paper_example):
    strings, scores, rules = paper_example
    plain = CompletionIndex.build(strings, scores, rules, kind="et")
    cached = CompletionIndex.build(strings, scores, rules, kind="et",
                                   cache_k=8)
    qs = ["andy pa", "bil", "a", "w", ""]
    qs = [q for q in qs if q]
    assert plain.complete(qs, 5) == cached.complete(qs, 5)


def test_space_ordering_tt_le_ht_le_et():
    """Paper Table 2: TT smallest, ET largest, HT between."""
    strings = [f"the {w} of entry {i:04d}" for i, w in enumerate(
        ["database", "management", "system", "record"] * 100)]
    scores = list(range(1, len(strings) + 1))
    rules = make_rules([("db", "database"), ("mgmt", "management"),
                        ("sys", "system"), ("rec", "record"),
                        ("entr.", "entry")])
    idx = build_all(strings, scores, rules, alpha=0.5)
    tt = idx["tt"].stats.bytes_total
    ht = idx["ht"].stats.bytes_total
    et = idx["et"].stats.bytes_total
    assert tt <= ht <= et
    assert idx["et"].stats.n_links == 0
    assert idx["tt"].stats.n_syn_nodes == 0


# -- hypothesis property tests (shared strategies: tests/strategies.py) ------

if st is not None:
    @settings(max_examples=40, deadline=None)
    @given(
        strings=strat.dictionaries,
        scores_seed=strat.score_seeds,
        rules=strat.rule_sets,
        queries=strat.query_streams,
        k=strat.topk_values,
        kind=st.sampled_from(KINDS),
        cache=st.booleans(),
    )
    def test_property_matches_oracle(strings, scores_seed, rules, queries, k,
                                     kind, cache):
        rules = strat.clean_rules(rules)
        rng = np.random.default_rng(scores_seed)
        scores = rng.integers(1, 1000, len(strings)).tolist()
        oracle = OracleIndex(strings, scores, make_rules(rules))
        idx = CompletionIndex.build(strings, scores, make_rules(rules),
                                    kind=kind, alpha=0.5,
                                    cache_k=16 if cache else 0)
        got = idx.complete(queries, k=k)
        for q, row in zip(queries, got):
            expect = oracle.topk_scores(q, k)
            assert [s for s, _ in row] == expect, (q, kind)
            # returned strings must actually match the query per the oracle
            valid = oracle.matches(q)
            for _, s in row:
                assert s.encode() in valid, (q, s, kind)

    @settings(max_examples=15, deadline=None)
    @given(
        strings=st.lists(strat.words, min_size=2, max_size=15, unique=True),
        rules=st.lists(
            st.tuples(st.text(alphabet="abcd", min_size=1, max_size=2),
                      st.text(alphabet="abcd", min_size=1, max_size=2)),
            min_size=1, max_size=4),
        alpha=st.floats(0, 1),
    )
    def test_property_ht_equals_et_results(strings, rules, alpha):
        """HT must return identical results to ET for any alpha."""
        rules = make_rules(strat.clean_rules(rules))
        scores = list(range(1, len(strings) + 1))
        et = CompletionIndex.build(strings, scores, rules, kind="et")
        ht = CompletionIndex.build(strings, scores, rules, kind="ht",
                                   alpha=alpha)
        queries = [s[:2] for s in strings[:5]]
        assert et.complete(queries, 5) == ht.complete(queries, 5)
else:  # hypothesis absent: surface the gap as explicit skips, not an error
    @strat.needs_hypothesis
    def test_property_matches_oracle():
        pass

    @strat.needs_hypothesis
    def test_property_ht_equals_et_results():
        pass
