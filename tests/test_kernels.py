"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in kernels/ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompletionIndex, make_rules
from repro.core.alphabet import pad_queries
from repro.kernels import ops, ref


@pytest.mark.parametrize("n_strings,qlen,block_q", [
    (20, 8, 4), (200, 16, 64), (500, 32, 128),
])
def test_trie_walk_sweep(n_strings, qlen, block_q, rng):
    strings = [f"{rng.integers(0, 10)}entry {i:05d} suffix"
               for i in range(n_strings)]
    idx = CompletionIndex.build(strings, list(range(n_strings)),
                                make_rules([]), kind="plain")
    t = idx.device
    queries = [s[: int(rng.integers(1, qlen))] for s in strings[:33]] + \
        ["zzz", "entry"]
    qs, qlens = pad_queries(queries, qlen)
    a = ops.trie_walk(t.first_child, t.edge_char, t.edge_child,
                      jnp.asarray(qs), jnp.asarray(qlens), block_q=block_q)
    b = ref.trie_walk_ref(t.first_child, t.edge_char, t.edge_child,
                          jnp.asarray(qs), jnp.asarray(qlens))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("b,c,k,block_b", [
    (8, 16, 4, 4), (16, 100, 10, 8), (5, 64, 8, 8), (32, 256, 16, 16),
])
def test_topk_select_sweep(b, c, k, block_b, rng):
    scores = rng.integers(-1000, 1000, (b, c)).astype(np.int32)
    payload = rng.integers(0, 10**6, (b, c)).astype(np.int32)
    a = ops.topk_select(jnp.asarray(scores), jnp.asarray(payload), k,
                        block_b=block_b)
    bref = ref.topk_select_ref(jnp.asarray(scores), jnp.asarray(payload), k)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(bref[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(bref[1]))


def test_topk_select_ties_deterministic(rng):
    scores = np.zeros((4, 32), np.int32)
    payload = np.arange(4 * 32, dtype=np.int32).reshape(4, 32)
    a = ops.topk_select(jnp.asarray(scores), jnp.asarray(payload), 5)
    b = ref.topk_select_ref(jnp.asarray(scores), jnp.asarray(payload), 5)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("v,d,n_bags", [(50, 16, 7), (500, 64, 32)])
def test_embedding_bag_sweep(dtype, mode, v, d, n_bags, rng):
    table = rng.normal(size=(v, d)).astype(np.float32)
    lens = rng.integers(0, 9, n_bags)
    offsets = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    indices = rng.integers(0, v, int(lens.sum())).astype(np.int32)
    weights = rng.normal(size=len(indices)).astype(np.float32)
    tab = jnp.asarray(table, dtype)
    a = ops.embedding_bag(tab, indices, offsets, weights, mode=mode)
    b = ref.embedding_bag_ref(tab, jnp.asarray(indices),
                              jnp.asarray(offsets), jnp.asarray(weights),
                              mode=mode)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("c,d,k,block_c", [
    (256, 32, 5, 64), (1024, 64, 10, 256), (4096, 128, 100, 1024),
])
def test_candidate_topk_sweep(c, d, k, block_c, rng):
    q = rng.normal(size=d).astype(np.float32)
    cand = rng.normal(size=(c, d)).astype(np.float32)
    a = ops.candidate_topk(jnp.asarray(q), jnp.asarray(cand), k,
                           block_c=block_c)
    b = ref.candidate_topk_ref(jnp.asarray(q), jnp.asarray(cand), k)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_engine_uses_same_semantics_as_trie_walk(rng):
    """trie_walk locus == engine's pure-prefix locus on rule-free tries."""
    strings = ["abc", "abd", "ab", "b"]
    idx = CompletionIndex.build(strings, [4, 3, 2, 1], make_rules([]),
                                kind="plain")
    t = idx.device
    qs, qlens = pad_queries(["ab", "abc", "abx", "c"], 8)
    nodes, depth = ops.trie_walk(t.first_child, t.edge_char, t.edge_child,
                                 jnp.asarray(qs), jnp.asarray(qlens))
    assert list(np.asarray(depth)) == [2, 3, 2, 0]


@pytest.mark.parametrize("b,f,n,kk,k,block_b", [
    (8, 4, 50, 8, 5, 4), (16, 32, 300, 16, 10, 8), (5, 8, 40, 4, 3, 8),
])
def test_cached_topk_merge_sweep(b, f, n, kk, k, block_b, rng):
    loci = rng.integers(-1, n, (b, f)).astype(np.int32)
    ts = np.sort(rng.integers(0, 10**6, (n, kk)).astype(np.int32),
                 axis=1)[:, ::-1].copy()      # per-node lists score-desc
    ti = rng.integers(0, 10**6, (n, kk)).astype(np.int32)
    a = ops.cached_topk_merge(jnp.asarray(loci), jnp.asarray(ts),
                              jnp.asarray(ti), k, block_b=block_b)
    bref = ref.cached_topk_merge_ref(jnp.asarray(loci), jnp.asarray(ts),
                                     jnp.asarray(ti), k)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(bref[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(bref[1]))


def test_cached_topk_merge_empty_rows_and_ties(rng):
    """All-empty loci rows give -1 results; equal scores resolve to the
    lower flat (loci-major) candidate index, matching lax.top_k."""
    n, kk = 20, 4
    ts = np.zeros((n, kk), np.int32)          # all scores tie at 0
    ti = np.arange(n * kk, dtype=np.int32).reshape(n, kk)
    loci = np.array([[3, 7, -1, -1], [-1, -1, -1, -1]], np.int32)
    s, p = ops.cached_topk_merge(jnp.asarray(loci), jnp.asarray(ts),
                                 jnp.asarray(ti), 6)
    rs, rp = ref.cached_topk_merge_ref(jnp.asarray(loci), jnp.asarray(ts),
                                       jnp.asarray(ti), 6)
    np.testing.assert_array_equal(np.asarray(s), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(rp))
    assert (np.asarray(s)[1] == -1).all() and (np.asarray(p)[1] == -1).all()


def test_cached_topk_merge_k_saturates_union(rng):
    """k >= F*K falls back to sorting the whole union, padded to k."""
    loci = np.array([[1, -1]], np.int32)
    ts = np.array([[9, 5], [7, 3]], np.int32)
    ti = np.array([[10, 11], [20, 21]], np.int32)
    s, p = ops.cached_topk_merge(jnp.asarray(loci), jnp.asarray(ts),
                                 jnp.asarray(ti), 6)
    assert s.shape == (1, 6) and p.shape == (1, 6)
    assert list(np.asarray(s)[0][:2]) == [7, 3]
    assert list(np.asarray(p)[0][:2]) == [20, 21]
    assert (np.asarray(s)[0][2:] == -1).all()


@pytest.mark.parametrize("streamed", [False, True])
@pytest.mark.parametrize("bsz", [1, 3, 13, 130])
def test_trie_walk_nonmultiple_batch_sizes(bsz, streamed, rng):
    """Regression (ops.py padding invariant): batch sizes off the block
    grid must pad with rows that walk to the root and slice off cleanly —
    on the resident kernel AND the DMA-streamed variant (which shares
    ``_pad_query_batch`` but runs its own pallas_call)."""
    strings = [f"key {i:04d} tail" for i in range(300)]
    idx = CompletionIndex.build(strings, list(range(300)), make_rules([]),
                                kind="plain")
    t, cfg = idx.device, idx.cfg
    queries = [strings[int(rng.integers(0, 300))][: int(rng.integers(0, 9))]
               for _ in range(bsz)]
    qs, qlens = pad_queries(queries, 12)
    a = ops.trie_walk(t.first_child, t.edge_char, t.edge_child,
                      jnp.asarray(qs), jnp.asarray(qlens), block_q=8,
                      streamed=streamed, walk_tile=cfg.walk_tile)
    b = ref.trie_walk_ref(t.first_child, t.edge_char, t.edge_child,
                          jnp.asarray(qs), jnp.asarray(qlens))
    assert a[0].shape == (bsz,)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("streamed", [False, True])
def test_trie_walk_empty_dictionary_short_circuit(streamed):
    """Zero-edge tries short-circuit before any pallas_call (there is no
    CSR row to stream): every query walks to the root with depth 0."""
    idx = CompletionIndex.build([], [], make_rules([]), kind="plain")
    t, cfg = idx.device, idx.cfg
    assert int(t.edge_char.shape[0]) == 0
    qs, qlens = pad_queries(["abc", ""], 4)
    node, depth = ops.trie_walk(t.first_child, t.edge_char, t.edge_child,
                                jnp.asarray(qs), jnp.asarray(qlens),
                                streamed=streamed, walk_tile=cfg.walk_tile)
    assert (np.asarray(node) == 0).all() and (np.asarray(depth) == 0).all()


@pytest.mark.parametrize("kind,frontier,block_q", [
    ("tt", 16, 4), ("et", 32, 8), ("ht", 8, 8), ("ht", 2, 4),
])
def test_locus_walk_sweep(kind, frontier, block_q, rng):
    """Fused locus-DP kernel vs the reference frontier DP on rule-bearing
    tries (incl. a starved frontier that forces overflow drops)."""
    words = ["st", "saint", "street", "ave", "avenue", "dr", "drive"]
    strings = [f"{words[int(rng.integers(0, len(words)))]} "
               f"{words[int(rng.integers(0, len(words)))]} {i % 23:02d}"
               for i in range(150)]
    idx = CompletionIndex.build(
        strings, list(rng.integers(0, 1000, len(strings))),
        make_rules([("st", "saint"), ("st", "street"), ("ave", "avenue"),
                    ("dr", "drive")]), kind=kind, frontier=frontier)
    t, cfg = idx.device, idx.cfg
    queries = [s[: int(rng.integers(1, 11))] for s in strings[:29]] + \
        ["st st", "zzz", ""]
    qs, qlens = pad_queries(queries, 12)
    a = ops.locus_walk(t, cfg, jnp.asarray(qs), jnp.asarray(qlens),
                       block_q=block_q)
    b = ref.locus_walk_ref(t, cfg, jnp.asarray(qs), jnp.asarray(qlens))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.streamed
@pytest.mark.parametrize("kind,frontier", [("tt", 8), ("et", 8), ("ht", 4)])
def test_locus_walk_streamed_sweep(kind, frontier, rng):
    """DMA-streamed locus-DP tier vs the reference DP: link store (tt),
    teleports (et) and both (ht), incl. starved-frontier overflow — loci
    AND overflow counts bit-identical with HBM-resident tables."""
    words = ["st", "saint", "street", "ave", "avenue", "dr", "drive"]
    strings = [f"{words[int(rng.integers(0, len(words)))]} "
               f"{words[int(rng.integers(0, len(words)))]} {i % 23:02d}"
               for i in range(120)]
    idx = CompletionIndex.build(
        strings, list(rng.integers(0, 1000, len(strings))),
        make_rules([("st", "saint"), ("st", "street"), ("ave", "avenue")]),
        kind=kind, frontier=frontier)
    t, cfg = idx.device, idx.cfg
    queries = [s[: int(rng.integers(1, 11))] for s in strings[:9]] + \
        ["st st", "zzz", ""]
    qs, qlens = pad_queries(queries, 12)
    a = ops.locus_walk(t, cfg, jnp.asarray(qs), jnp.asarray(qlens),
                       streamed=True)
    b = ref.locus_walk_ref(t, cfg, jnp.asarray(qs), jnp.asarray(qlens))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.streamed
@pytest.mark.parametrize("bsz", [1, 3, 13])
def test_locus_walk_streamed_nonmultiple_batch_sizes(bsz, rng):
    """The streamed locus tier shares ``_pad_query_batch``: off-grid
    batches pad with root-walking rows and slice off cleanly."""
    words = ["st", "saint", "ave", "avenue"]
    strings = [f"{words[int(rng.integers(0, 4))]} {i % 13:02d}"
               for i in range(60)]
    idx = CompletionIndex.build(
        strings, list(rng.integers(0, 100, len(strings))),
        make_rules([("st", "saint"), ("ave", "avenue")]), kind="ht",
        frontier=4)
    t, cfg = idx.device, idx.cfg
    queries = (["st 0", "ave", "zzz", "", "saint 1"] * 3)[:bsz]
    qs, qlens = pad_queries(queries, 8)
    a = ops.locus_walk(t, cfg, jnp.asarray(qs), jnp.asarray(qlens),
                       streamed=True)
    b = ref.locus_walk_ref(t, cfg, jnp.asarray(qs), jnp.asarray(qlens))
    assert a[0].shape == (bsz, cfg.frontier)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def _beam_fixture(rng, kind="et", n=150, **spec_kw):
    """Rule-bearing index + a locus batch for beam phase-2 kernel tests."""
    from repro.api import IndexSpec, build_index
    from repro.core.engine import get_substrate

    words = ["st", "saint", "street", "ave", "avenue", "dr", "drive"]
    strings = [f"{words[int(rng.integers(0, len(words)))]} "
               f"{words[int(rng.integers(0, len(words)))]} {i % 23:02d}"
               for i in range(n)]
    idx = build_index(
        strings, list(rng.integers(0, 1000, len(strings))),
        make_rules([("st", "saint"), ("ave", "avenue")]),
        IndexSpec(kind=kind, **spec_kw))
    queries = [s[: int(rng.integers(1, 9))] for s in strings[:21]] + \
        ["st", "zzz", ""]
    qs, qlens = pad_queries(queries, 10)
    loci, _ = get_substrate("jnp").walk_batch(
        idx.device, idx.cfg, jnp.asarray(qs), jnp.asarray(qlens))
    return idx, loci


def _assert_beam_parity(idx, loci, k, block_b=8):
    a = ops.beam_topk(idx.device, idx.cfg, loci, k, block_b=block_b)
    b = ref.beam_topk_ref(idx.device, idx.cfg, loci, k)
    for x, y, nm in zip(a, b, ("scores", "sids", "exact")):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=nm)
    return np.asarray(b[2])


@pytest.mark.parametrize("kind,gens,expand,frontier,k,block_b", [
    ("plain", 8, 2, 4, 3, 4), ("tt", 16, 4, 8, 5, 8),
    ("et", 48, 8, 32, 10, 8), ("ht", 4, 2, 4, 3, 4),
])
def test_beam_topk_sweep(kind, gens, expand, frontier, k, block_b, rng):
    """Fused beam kernel vs the vmapped reference priority search across
    index kinds and (W, P, k) shapes — scores, sids AND exact flags."""
    idx, loci = _beam_fixture(rng, kind=kind, gens=gens, expand=expand,
                              frontier=frontier, max_steps=64)
    _assert_beam_parity(idx, loci, k, block_b=block_b)


def test_beam_topk_starved_widths_inexact_parity(rng):
    """Starved pool widths force drops above the k-th score; the kernel's
    dropped_max tracking must reproduce the inexact flags exactly (they
    gate the host-side doubled-width retry)."""
    idx, loci = _beam_fixture(rng, kind="ht", gens=4, expand=2, frontier=4,
                              max_steps=8)
    exact = _assert_beam_parity(idx, loci, 5)
    assert (~exact).any()   # the starved search must actually go inexact


def test_beam_topk_single_generator(rng):
    """W=1, P=1: pool of one generator, popped and re-armed in place."""
    idx, loci = _beam_fixture(rng, kind="tt", gens=1, expand=1, frontier=1,
                              max_steps=32)
    _assert_beam_parity(idx, loci, 3, block_b=4)


def test_beam_topk_max_steps_clamp(rng):
    """max_steps=1 truncates the search mid-flight; the fixed-trip loop
    must stop exactly where the reference while_loop stops (unfinished
    queries flagged inexact)."""
    idx, loci = _beam_fixture(rng, kind="et", max_steps=1)
    exact = _assert_beam_parity(idx, loci, 5)
    assert (~exact).any()


def test_beam_topk_k_exceeds_live_emissions(rng):
    """k larger than the total completion count pads the heap with -1."""
    idx, loci = _beam_fixture(rng, kind="et", n=3)
    exact = _assert_beam_parity(idx, loci, 10)
    assert exact.all()
    s, _, _ = ops.beam_topk(idx.device, idx.cfg, loci, 10)
    assert (np.asarray(s) == -1).any()       # -1 padded tails


@pytest.mark.parametrize("streamed", [False, True])
@pytest.mark.parametrize("bsz", [1, 3, 13])
def test_beam_topk_nonmultiple_batch_sizes(bsz, streamed, rng):
    """Batch sizes off the block grid pad with all-(-1) locus rows (dead
    pool, exact) and slice off cleanly — on the resident kernel AND the
    DMA-streamed variant (shared ``_pad_rows``, separate pallas_call)."""
    idx, loci = _beam_fixture(rng, kind="ht", gens=8, expand=2, frontier=8,
                              max_steps=48)
    a = ops.beam_topk(idx.device, idx.cfg, loci[:bsz], 5, streamed=streamed)
    b = ref.beam_topk_ref(idx.device, idx.cfg, loci[:bsz], 5)
    for x, y, nm in zip(a, b, ("scores", "sids", "exact")):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=nm)


@pytest.mark.parametrize("streamed", [False, True])
def test_beam_topk_empty_dictionary(streamed):
    """The degenerate empty dictionary short-circuits like the reference
    (before any pallas_call — there is no emission row to stream): all
    -1 results, exact everywhere."""
    from repro.api import IndexSpec, build_index

    idx = build_index([], [], make_rules([]), IndexSpec(kind="plain"))
    loci = jnp.full((3, idx.cfg.frontier), -1, jnp.int32)
    a = ops.beam_topk(idx.device, idx.cfg, loci, 4, streamed=streamed)
    b = ref.beam_topk_ref(idx.device, idx.cfg, loci, 4)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert (np.asarray(a[0]) == -1).all() and np.asarray(a[2]).all()


@pytest.mark.streamed
@pytest.mark.parametrize("kind,gens,expand,frontier,k", [
    ("plain", 8, 2, 4, 3), ("tt", 8, 4, 8, 5), ("ht", 4, 2, 4, 3),
])
def test_beam_topk_streamed_sweep(kind, gens, expand, frontier, k, rng):
    """DMA-streamed beam tier vs the vmapped reference priority search —
    scores, sids AND exact flags bit-identical with HBM-resident
    emission tables (incl. the starved ht shape that goes inexact)."""
    idx, loci = _beam_fixture(rng, kind=kind, gens=gens, expand=expand,
                              frontier=frontier, max_steps=48)
    a = ops.beam_topk(idx.device, idx.cfg, loci, k, streamed=True)
    b = ref.beam_topk_ref(idx.device, idx.cfg, loci, k)
    for x, y, nm in zip(a, b, ("scores", "sids", "exact")):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=nm)


def test_pad_query_batch_invariant():
    """Padded rows carry qlen 0 AND chars -1 — each alone keeps the walk
    at the root, so the padded outputs are inert before slicing."""
    qs = jnp.asarray(np.full((3, 4), 7, np.int32))
    qlens = jnp.asarray(np.full((3,), 4, np.int32))
    q, ql, b = ops._pad_query_batch(qs, qlens, 8)
    assert b == 3 and q.shape == (8, 4) and ql.shape == (8,)
    assert (np.asarray(q[3:]) == -1).all()
    assert (np.asarray(ql[3:]) == 0).all()
    # real rows untouched
    np.testing.assert_array_equal(np.asarray(q[:3]), np.asarray(qs))
