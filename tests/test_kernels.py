"""Per-kernel shape/dtype sweeps: Pallas (interpret mode on CPU) vs the
pure-jnp oracles in kernels/ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompletionIndex, make_rules
from repro.core.alphabet import pad_queries
from repro.kernels import ops, ref


@pytest.mark.parametrize("n_strings,qlen,block_q", [
    (20, 8, 4), (200, 16, 64), (500, 32, 128),
])
def test_trie_walk_sweep(n_strings, qlen, block_q, rng):
    strings = [f"{rng.integers(0, 10)}entry {i:05d} suffix"
               for i in range(n_strings)]
    idx = CompletionIndex.build(strings, list(range(n_strings)),
                                make_rules([]), kind="plain")
    t = idx.device
    queries = [s[: int(rng.integers(1, qlen))] for s in strings[:33]] + \
        ["zzz", "entry"]
    qs, qlens = pad_queries(queries, qlen)
    a = ops.trie_walk(t.first_child, t.edge_char, t.edge_child,
                      jnp.asarray(qs), jnp.asarray(qlens), block_q=block_q)
    b = ref.trie_walk_ref(t.first_child, t.edge_char, t.edge_child,
                          jnp.asarray(qs), jnp.asarray(qlens))
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("b,c,k,block_b", [
    (8, 16, 4, 4), (16, 100, 10, 8), (5, 64, 8, 8), (32, 256, 16, 16),
])
def test_topk_select_sweep(b, c, k, block_b, rng):
    scores = rng.integers(-1000, 1000, (b, c)).astype(np.int32)
    payload = rng.integers(0, 10**6, (b, c)).astype(np.int32)
    a = ops.topk_select(jnp.asarray(scores), jnp.asarray(payload), k,
                        block_b=block_b)
    bref = ref.topk_select_ref(jnp.asarray(scores), jnp.asarray(payload), k)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(bref[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(bref[1]))


def test_topk_select_ties_deterministic(rng):
    scores = np.zeros((4, 32), np.int32)
    payload = np.arange(4 * 32, dtype=np.int32).reshape(4, 32)
    a = ops.topk_select(jnp.asarray(scores), jnp.asarray(payload), 5)
    b = ref.topk_select_ref(jnp.asarray(scores), jnp.asarray(payload), 5)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mode", ["sum", "mean"])
@pytest.mark.parametrize("v,d,n_bags", [(50, 16, 7), (500, 64, 32)])
def test_embedding_bag_sweep(dtype, mode, v, d, n_bags, rng):
    table = rng.normal(size=(v, d)).astype(np.float32)
    lens = rng.integers(0, 9, n_bags)
    offsets = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    indices = rng.integers(0, v, int(lens.sum())).astype(np.int32)
    weights = rng.normal(size=len(indices)).astype(np.float32)
    tab = jnp.asarray(table, dtype)
    a = ops.embedding_bag(tab, indices, offsets, weights, mode=mode)
    b = ref.embedding_bag_ref(tab, jnp.asarray(indices),
                              jnp.asarray(offsets), jnp.asarray(weights),
                              mode=mode)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("c,d,k,block_c", [
    (256, 32, 5, 64), (1024, 64, 10, 256), (4096, 128, 100, 1024),
])
def test_candidate_topk_sweep(c, d, k, block_c, rng):
    q = rng.normal(size=d).astype(np.float32)
    cand = rng.normal(size=(c, d)).astype(np.float32)
    a = ops.candidate_topk(jnp.asarray(q), jnp.asarray(cand), k,
                           block_c=block_c)
    b = ref.candidate_topk_ref(jnp.asarray(q), jnp.asarray(cand), k)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_engine_uses_same_semantics_as_trie_walk(rng):
    """trie_walk locus == engine's pure-prefix locus on rule-free tries."""
    strings = ["abc", "abd", "ab", "b"]
    idx = CompletionIndex.build(strings, [4, 3, 2, 1], make_rules([]),
                                kind="plain")
    t = idx.device
    qs, qlens = pad_queries(["ab", "abc", "abx", "c"], 8)
    nodes, depth = ops.trie_walk(t.first_child, t.edge_char, t.edge_child,
                                 jnp.asarray(qs), jnp.asarray(qlens))
    assert list(np.asarray(depth)) == [2, 3, 2, 0]
