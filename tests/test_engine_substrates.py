"""Substrate parity: `complete` / `Session` results must be bit-identical
across the `jnp` reference and `pallas` (interpret mode on CPU) substrates
for every index kind, both phase-2 engines, and the exactness-retry path.

Parity here is the acceptance gate for the pluggable-substrate seam: any
kernel routed in by the pallas substrate (batched trie walk, topk_select,
cached locus gather+merge) must reproduce the reference engine exactly.
"""

import numpy as np
import pytest

import jax

from repro.api import IndexSpec, Session, build_index
from repro.core import engine as eng
from repro.core import make_rules
from repro.core.oracle import OracleIndex

KINDS = ["plain", "tt", "et", "ht"]

QUERIES = ["andy pa", "andrew pa", "bil", "bill of", "a", "w", "andrew",
           "andrew pavlo", "xyz", "", "andy pavloz"]


@pytest.fixture(scope="module")
def paper_data():
    strings = ["andrew pavlo", "andrew parker", "andrew packard",
               "william smith", "bill of rights"]
    scores = [50, 40, 30, 20, 10]
    rules = make_rules([("andy", "andrew"), ("bill", "william")])
    return strings, scores, rules


def _build(paper_data, kind, **kw):
    strings, scores, rules = paper_data
    return build_index(strings, scores, rules, IndexSpec(kind=kind, **kw))


# -- registry / resolution ----------------------------------------------------


def test_registry_has_both_substrates():
    assert {"jnp", "pallas"} <= set(eng.available_substrates())
    assert isinstance(eng.get_substrate("pallas"), eng.PallasSubstrate)
    with pytest.raises(ValueError, match="unknown substrate"):
        eng.get_substrate("cuda")


def test_auto_resolves_by_backend():
    expect = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert eng.resolve_substrate("auto") == expect
    assert eng.resolve_substrate("pallas") == "pallas"
    with pytest.raises(ValueError, match="unknown substrate"):
        eng.resolve_substrate("nope")


def test_spec_validates_substrate(paper_data):
    with pytest.raises(ValueError, match="unknown substrate"):
        IndexSpec(kind="et", substrate="cuda").validate()
    idx = _build(paper_data, "et", substrate="pallas")
    assert idx.substrate == "pallas"
    assert idx.cfg.substrate == "pallas"    # rides the jit key


def test_substrate_joins_compile_cache_key(paper_data):
    idx = _build(paper_data, "et")
    idx.set_substrate("jnp")
    idx.complete(["an"], k=3)
    misses0 = idx._compile_cache.misses
    idx.set_substrate("pallas")
    idx.complete(["an"], k=3)               # same shapes, new substrate
    assert idx._compile_cache.misses == misses0 + 1
    idx.set_substrate("jnp")
    idx.complete(["an"], k=3)               # old executable still cached
    assert idx._compile_cache.misses == misses0 + 1


# -- batch parity -------------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("cache_k", [0, 8])
def test_complete_parity_all_kinds(paper_data, kind, cache_k):
    idx = _build(paper_data, kind, cache_k=cache_k)
    r_jnp = idx.set_substrate("jnp").complete(QUERIES, k=3)
    r_pal = idx.set_substrate("pallas").complete(QUERIES, k=3)
    assert r_jnp == r_pal
    # and both match the host-side oracle (plain kind ignores rules)
    strings, scores, rules = paper_data
    oracle = OracleIndex(strings, scores, rules if kind != "plain" else [])
    for q, row in zip(QUERIES, r_jnp):
        assert [s for s, _ in row] == [s for s, _ in oracle.complete(q, 3)], q


@pytest.mark.parametrize("cache_k", [0, 4])
def test_complete_parity_nonbucket_batches(paper_data, cache_k):
    """Batch sizes off the kernel block grid exercise the ops.py padding."""
    idx = _build(paper_data, "plain", cache_k=cache_k)
    for qs in (["andrew"], QUERIES[:5], QUERIES[:9], QUERIES * 3):
        assert idx.set_substrate("jnp").complete(qs, k=2) == \
            idx.set_substrate("pallas").complete(qs, k=2)


# -- session parity -----------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_session_parity_all_kinds(paper_data, kind):
    idx = _build(paper_data, kind, cache_k=8)
    typed = "andy pa"
    outs = {}
    for substrate in ("jnp", "pallas"):
        idx.set_substrate(substrate)
        sess = Session(idx, k=3)
        rows = [sess.type(ch) for ch in typed]
        rows.append(sess.backspace(2))
        rows.append(sess.type("v"))
        outs[substrate] = rows
    assert outs["jnp"] == outs["pallas"]
    # per-keystroke results equal the one-shot path (on the last substrate)
    assert outs["pallas"][-1] == idx.complete(["andy v"], k=3)[0]


# -- exactness-retry parity ---------------------------------------------------


@pytest.mark.parametrize("kind", ["tt", "ht"])
def test_retry_path_parity(paper_data, kind):
    """Starved widths force the inexact flag; the widened host-side retry
    must converge to identical results on both substrates."""
    tiny = _build(paper_data, kind, frontier=2, gens=2, expand=2,
                  max_steps=4)
    wide = _build(paper_data, kind)
    qs = ["an", "andy pa", "bill", "a"]
    expect = wide.complete(qs, k=3)
    for substrate in ("jnp", "pallas"):
        assert tiny.set_substrate(substrate).complete(qs, k=3) == expect
    # session fallback routes through the same retry machinery
    sess = Session(tiny.set_substrate("pallas"), k=3)
    assert sess.type("andy pa") == expect[1]


# -- engine-level entry points ------------------------------------------------


def test_complete_batch_matches_complete_one(paper_data):
    from repro.core.alphabet import pad_queries

    idx = _build(paper_data, "et", cache_k=4)
    qs, qlens = pad_queries(["andy", "bil", "zz", ""], 8)
    for substrate in ("jnp", "pallas"):
        sub = eng.get_substrate(substrate)
        bs, bi, be = eng.complete_batch(idx.device, idx.cfg, qs, qlens, 3,
                                        sub)
        for b in range(qs.shape[0]):
            s1, i1, e1 = eng.complete_one(idx.device, idx.cfg, qs[b],
                                          qlens[b], 3, sub)
            np.testing.assert_array_equal(np.asarray(bs[b]), np.asarray(s1))
            np.testing.assert_array_equal(np.asarray(bi[b]), np.asarray(i1))
            assert bool(be[b]) == bool(e1)


def test_pallas_rule_free_walk_matches_locus_dp(paper_data):
    """The pallas trie-walk fast path (plain kind) must land on the same
    loci as the reference frontier DP."""
    from repro.core.alphabet import pad_queries

    idx = _build(paper_data, "plain")
    t, cfg = idx.device, idx.cfg
    sub = eng.get_substrate("pallas")
    assert sub._rule_free(t, cfg)
    qs, qlens = pad_queries(["andrew", "andrew pa", "x", ""], 12)
    loci_p, ov_p = sub.walk_batch(t, cfg, qs, qlens)
    loci_j, ov_j = eng.get_substrate("jnp").walk_batch(t, cfg, qs, qlens)
    np.testing.assert_array_equal(np.asarray(loci_p), np.asarray(loci_j))
    np.testing.assert_array_equal(np.asarray(ov_p), np.asarray(ov_j))


# -- fused locus-DP kernel (rule-bearing walk) --------------------------------


def _walk_parity(idx, queries, max_len):
    """Assert pallas walk_batch == jnp walk_batch bit-for-bit; returns the
    (jnp) overflow vector for extra assertions."""
    from repro.core.alphabet import pad_queries

    t, cfg = idx.device, idx.cfg
    qs, qlens = pad_queries(queries, max_len)
    loci_p, ov_p = eng.get_substrate("pallas").walk_batch(t, cfg, qs, qlens)
    loci_j, ov_j = eng.get_substrate("jnp").walk_batch(t, cfg, qs, qlens)
    np.testing.assert_array_equal(np.asarray(loci_p), np.asarray(loci_j))
    np.testing.assert_array_equal(np.asarray(ov_p), np.asarray(ov_j))
    return np.asarray(ov_j)


@pytest.mark.parametrize("kind", ["tt", "et", "ht"])
def test_fused_walk_claims_rule_bearing_kinds(paper_data, kind):
    """tt/et/ht walks are no longer a jnp fallback: the pallas substrate
    probes capable and its fused kernel reproduces the reference DP."""
    idx = _build(paper_data, kind)
    t, cfg = idx.device, idx.cfg
    sub = eng.get_substrate("pallas")
    assert not sub._rule_free(t, cfg)
    assert sub.can_walk_batch(t, cfg, 16)
    assert sub.walk_variant(t, cfg, 16) == "resident"
    _walk_parity(idx, QUERIES, 16)


@pytest.mark.parametrize("kind", ["tt", "et", "ht"])
def test_fused_walk_overflow_frontier_parity(paper_data, kind):
    """A starved frontier forces dedup-compaction drops; the kernel's
    overflow accounting must match the reference exactly (it gates the
    exactness flag and thus the host-side retry)."""
    idx = _build(paper_data, kind, frontier=1)
    ov = _walk_parity(idx, QUERIES + ["andy", "bill", "bill of ri"], 16)
    assert (ov > 0).any()   # F=1 cannot hold literal node + rule target


def test_fused_walk_nonbucket_batches(paper_data):
    """Rule-bearing batches off the kernel block grid exercise the ops.py
    padding (padded rows walk to the root and slice off)."""
    idx = _build(paper_data, "ht")
    for qs in (["andy"], QUERIES[:3], QUERIES[:7], QUERIES * 2):
        _walk_parity(idx, qs, 12)


def test_fused_walk_probe_envelope_falls_back(paper_data):
    """Configs outside the kernel's static envelope are refused by the
    probe, and walk_batch still answers (via the inherited jnp DP) with
    identical results."""
    sub = eng.get_substrate("pallas")
    idx = _build(paper_data, "ht",
                 frontier=2 * sub._FUSE_MAX_FRONTIER)
    t, cfg = idx.device, idx.cfg
    assert not sub.can_walk_batch(t, cfg, 16)
    _walk_parity(idx, QUERIES[:4], 16)
    # the probe is about width/length, not kind: the same trie at default
    # widths is claimed
    assert sub.can_walk_batch(_build(paper_data, "ht").device,
                              _build(paper_data, "ht").cfg, 16)


def test_fused_walk_session_and_batch_agree(paper_data):
    """End-to-end: per-keystroke sessions (which reuse the packed rule
    planes incrementally) and the fused batch walk give the same answers
    on the pallas substrate."""
    idx = _build(paper_data, "ht", cache_k=8).set_substrate("pallas")
    sess = Session(idx, k=3)
    rows = [sess.type(ch) for ch in "andy pa"]
    assert rows[-1] == idx.complete(["andy pa"], k=3)[0]


# -- fused beam phase-2 kernel ------------------------------------------------


def _beam_parity(idx, queries, k, max_len=16):
    """Assert pallas beam_topk_batch == jnp beam_topk_batch bit-for-bit
    (scores, sids, exact); returns the (jnp) exact vector."""
    from repro.core.alphabet import pad_queries

    t, cfg = idx.device, idx.cfg
    qs, qlens = pad_queries(queries, max_len)
    loci, _ = eng.get_substrate("jnp").walk_batch(t, cfg, qs, qlens)
    a = eng.get_substrate("pallas").beam_topk_batch(t, cfg, loci, k)
    b = eng.get_substrate("jnp").beam_topk_batch(t, cfg, loci, k)
    for x, y, nm in zip(a, b, ("scores", "sids", "exact")):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=nm)
    return np.asarray(b[2])


@pytest.mark.parametrize("kind", KINDS)
def test_fused_beam_claims_all_kinds(paper_data, kind):
    """Beam phase 2 is no longer a jnp-everywhere phase: the pallas
    substrate probes capable at the default widths and its fused kernel
    reproduces the reference priority search on every index kind."""
    idx = _build(paper_data, kind)
    sub = eng.get_substrate("pallas")
    assert sub.can_beam_batch(idx.device, idx.cfg, 3)
    _beam_parity(idx, QUERIES, 3)


def test_fused_beam_probe_envelope_falls_back(paper_data):
    """Configs outside the kernel's static envelope are refused by the
    probe, and beam_topk_batch still answers (via the inherited vmapped
    reference) with identical results."""
    sub = eng.get_substrate("pallas")
    idx = _build(paper_data, "et", gens=2 * sub._BEAM_MAX_GENS)
    assert not sub.can_beam_batch(idx.device, idx.cfg, 3)
    _beam_parity(idx, QUERIES[:4], 3)
    # k is part of the probe too
    small = _build(paper_data, "et")
    assert sub.can_beam_batch(small.device, small.cfg, 3)
    assert not sub.can_beam_batch(small.device, small.cfg,
                                  sub._BEAM_MAX_K + 1)


def test_fused_beam_retry_rounds_reprobe(paper_data):
    """The host-side exactness retry widens the config 4x per round and
    re-dispatches through the substrate: round 1 stays inside the kernel
    envelope at default widths, later rounds fall back to jnp."""
    from dataclasses import replace

    idx = _build(paper_data, "tt")
    sub = eng.get_substrate("pallas")
    cfg1 = replace(idx.cfg, frontier=idx.cfg.frontier * 2,
                   gens=idx.cfg.gens * 4, max_steps=idx.cfg.max_steps * 4,
                   use_cache=False)
    assert sub.can_beam_batch(idx.device, cfg1, 3)
    cfg2 = replace(cfg1, frontier=cfg1.frontier * 2, gens=cfg1.gens * 4,
                   max_steps=cfg1.max_steps * 4)
    assert not sub.can_beam_batch(idx.device, cfg2, 3)


# -- exactness: strict admissible bound on score ties -------------------------


def test_beam_tie_drop_stays_exact():
    """Regression (strict dropped_max bound): a pool drop whose bound
    EQUALS the final k-th score ties at best — it must stay exact on both
    substrates instead of triggering a spurious doubled-width retry."""
    from repro.core.alphabet import pad_queries

    strings = [f"a{chr(98 + i)}x" for i in range(10)]
    idx = build_index(strings, [5] * 10, make_rules([]),
                      IndexSpec(kind="plain", gens=2, expand=1, frontier=2,
                                max_steps=64))
    qs, qlens = pad_queries(["a"], 4)
    for substrate in ("jnp", "pallas"):
        sub = eng.get_substrate(substrate)
        s, i, e = eng.complete_batch(idx.device, idx.cfg, qs, qlens, 2, sub)
        assert np.asarray(s)[0].tolist() == [5, 5], substrate
        # the starved (W=2, P=1) pool provably drops bound-5 candidates
        # here; with a non-strict bound this flag flips to False
        assert bool(np.asarray(e)[0]), substrate
    # end-to-end: exactly one compiled executable — no widened retry
    idx.set_substrate("pallas")
    assert [s for s, _ in idx.complete(["a"], k=2)[0]] == [5, 5]
    assert idx._compile_cache.misses == 1


# -- persistence: rule-plane container migration ------------------------------


def _rewrite_as_v1(path):
    """Strip the packed rule plane from a saved container and stamp it as
    format_version 1 — byte-level shape of a pre-relayout index."""
    import json

    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    for k in ("trie__tele_plane", "trie__link_ptr", "rule_trie__term_plane"):
        assert k in arrays, f"v2 container should carry {k}"
        del arrays[k]
    meta = json.loads(arrays["__meta__"].tobytes().decode())
    meta["format_version"] = 1
    for key in ("tele_width", "term_width"):
        meta["cfg"].pop(key, None)
    arrays["__meta__"] = np.frombuffer(json.dumps(meta).encode(),
                                       dtype=np.uint8)
    np.savez_compressed(path, **arrays)


@pytest.mark.parametrize("kind", ["tt", "ht"])
def test_load_v1_container_rebuilds_rule_planes(paper_data, kind, tmp_path):
    from repro.api import CompletionIndex

    idx = _build(paper_data, kind, cache_k=4)
    expect = idx.complete(QUERIES, k=3)
    path = str(tmp_path / "idx.npz")
    idx.save(path)
    _rewrite_as_v1(path)
    loaded = CompletionIndex.load(path)
    assert loaded.trie.tele_plane is not None
    assert loaded.trie.link_ptr is not None
    assert loaded.rule_trie.term_plane is not None
    assert loaded.cfg.tele_width == idx.cfg.tele_width
    assert loaded.cfg.term_width == idx.cfg.term_width
    for substrate in ("jnp", "pallas"):
        assert loaded.set_substrate(substrate).complete(QUERIES, k=3) \
            == expect


def test_load_rejects_mismatched_rule_plane(paper_data, tmp_path):
    """A container whose planes disagree with the recorded static widths
    must fail loudly at load, not mis-gather on device."""
    import json

    idx = _build(paper_data, "ht")
    path = str(tmp_path / "idx.npz")
    idx.save(path)
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    plane = arrays["trie__tele_plane"]
    arrays["trie__tele_plane"] = np.concatenate(
        [plane, np.full_like(plane[:, :1], -1)], axis=1)
    meta = json.loads(arrays["__meta__"].tobytes().decode())
    np.savez_compressed(path, **arrays)
    from repro.api import CompletionIndex
    with pytest.raises(ValueError, match="rule plane"):
        CompletionIndex.load(path)


def test_persist_reresolves_substrate(paper_data, tmp_path):
    idx = _build(paper_data, "ht", cache_k=4)    # spec.substrate == "auto"
    path = str(tmp_path / "idx.npz")
    idx.save(path)
    from repro.api import CompletionIndex

    loaded = CompletionIndex.load(path)
    assert loaded.spec.substrate == "auto"
    assert loaded.substrate == eng.resolve_substrate("auto")
    assert loaded.complete(["andy pa"], k=3) == idx.complete(["andy pa"], k=3)
    # an explicitly pinned substrate survives the round-trip
    idx.set_substrate("pallas").save(path)
    assert CompletionIndex.load(path).substrate == "pallas"
