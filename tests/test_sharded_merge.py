"""Substrate-routed cross-shard top-k merge, single-process local mode.

Unlike :mod:`tests.test_distributed` (skip-gated on the modern shard_map
APIs), everything here runs on the container jax: the local path stacks
every shard's trie on one device and fuses the per-shard answers through
the same :func:`repro.core.distributed.merge_shard_topk` the mesh path
uses, so the sharded index stays fully exercised without a mesh."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import IndexSpec, build_index
from repro.core import engine as eng
from repro.core import make_rules
from repro.core.distributed import ShardedCompletionIndex, merge_shard_topk
from repro.core.oracle import OracleIndex
from repro.data.strings import make_usps, make_workload


@pytest.fixture(scope="module")
def corpus():
    strings = [f"record {i:03d} entry" for i in range(64)] + [
        "andrew pavlo", "william smith"]
    scores = list(range(1, len(strings) + 1))
    rules = make_rules([("andy", "andrew"), ("bill", "william"),
                        ("rec", "record")])
    return strings, scores, rules


QUERIES = ["andy", "bill s", "rec 00", "record 01", "zzz", "entry", "r",
           "re", "", "record 063 entry x"]


# -- merge primitive ----------------------------------------------------------


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
def test_merge_shard_topk_matches_lax_oracle(substrate):
    """[S, B, k] per-shard answers fuse to the same global top-k as a
    plain lax.top_k over the concatenated candidates, payloads aligned."""
    rng = np.random.default_rng(0)
    S, B, k = 4, 6, 5
    scores = rng.integers(-1, 1000, (S, B, k)).astype(np.int32)
    # descending within each shard row, like real per-shard answers
    scores = -np.sort(-scores, axis=-1)
    gsids = rng.integers(0, 10_000, (S, B, k)).astype(np.int32)
    sub = eng.get_substrate(substrate)
    got_s, got_i = merge_shard_topk(jnp.asarray(scores), jnp.asarray(gsids),
                                    k, sub)
    flat = np.moveaxis(scores, 0, 1).reshape(B, S * k)
    flat_i = np.moveaxis(gsids, 0, 1).reshape(B, S * k)
    ref_s, ref_pos = jax.lax.top_k(jnp.asarray(flat), k)
    np.testing.assert_array_equal(np.asarray(got_s), np.asarray(ref_s))
    np.testing.assert_array_equal(
        np.asarray(got_i), np.take_along_axis(flat_i, np.asarray(ref_pos),
                                              axis=1))


# -- local mode vs oracle ------------------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 3])
def test_local_sharded_matches_oracle(corpus, n_shards):
    strings, scores, rules = corpus
    oracle = OracleIndex(strings, scores, rules)
    idx = ShardedCompletionIndex(strings, scores, rules, n_shards=n_shards,
                                 kind="ht", alpha=0.5)
    assert idx.mesh is None
    got = idx.complete(QUERIES, k=5)
    for q, row in zip(QUERIES, got):
        assert [s for s, _ in row] == \
            [s for s, _ in oracle.complete(q, 5)], q


def test_local_sharded_matches_single_index(corpus):
    """Hash-sharding + merge must be invisible: identical answers (scores
    and strings) to one unsharded index over the same dictionary."""
    strings, scores, rules = corpus
    single = build_index(strings, scores, rules, IndexSpec(kind="et"))
    sharded = ShardedCompletionIndex(strings, scores, rules, n_shards=3,
                                     kind="et")
    assert sharded.complete(QUERIES, k=5) == single.complete(QUERIES, k=5)


def test_local_sharded_usps_workload():
    ds = make_usps(n=600, seed=1)
    rules = make_rules(ds.rules)
    single = build_index(ds.strings, ds.scores, rules, IndexSpec(kind="et"))
    sharded = ShardedCompletionIndex(ds.strings, ds.scores, rules,
                                     n_shards=4, kind="et")
    qs = make_workload(ds, 24, seed=7)
    assert sharded.complete(qs, k=10) == single.complete(qs, k=10)


def test_local_batch_bucketing_reuses_compiles(corpus):
    strings, scores, rules = corpus
    idx = ShardedCompletionIndex(strings, scores, rules, n_shards=2,
                                 kind="et")
    idx.complete(["an", "re", "w"], k=5)        # B=3 -> bucket 4
    misses0 = idx._local_cache.misses
    idx.complete(["andy", "bill", "rec", "en"], k=5)   # B=4: same bucket
    assert idx._local_cache.misses == misses0
    assert idx._local_cache.hits >= 1


# -- construction / persistence ------------------------------------------------


def test_requires_mesh_or_n_shards(corpus):
    strings, scores, rules = corpus
    with pytest.raises(TypeError, match="mesh= .*or n_shards="):
        ShardedCompletionIndex(strings, scores, rules, kind="et")


def test_save_load_roundtrip_local(tmp_path, corpus):
    strings, scores, rules = corpus
    idx = ShardedCompletionIndex(strings, scores, rules, n_shards=3,
                                 kind="et", cache_k=4)
    path = str(tmp_path / "sharded")
    idx.save(path)
    loaded = ShardedCompletionIndex.load(path)
    assert loaded.mesh is None
    assert loaded.spec == idx.spec
    assert len(loaded.shards) == 3
    assert loaded.complete(QUERIES, k=5) == idx.complete(QUERIES, k=5)


# -- targeted serving errors ---------------------------------------------------


def test_session_raises_targeted_error(corpus):
    strings, scores, rules = corpus
    idx = ShardedCompletionIndex(strings, scores, rules, n_shards=2,
                                 kind="et")
    with pytest.raises(NotImplementedError, match="locus frontier"):
        idx.session(k=5)


def test_service_open_session_raises_targeted_error(corpus):
    """CompletionService.open_session on a sharded index must fail with
    the explanation (and point at complete()), not an AttributeError from
    deep inside the session plumbing — batch serving keeps working."""
    from repro.serving import CompletionService

    strings, scores, rules = corpus
    svc = CompletionService(ShardedCompletionIndex(
        strings, scores, rules, n_shards=2, kind="et"))
    with pytest.raises(NotImplementedError,
                       match="local CompletionIndex") as ei:
        svc.open_session(k=5)
    assert "complete()" in str(ei.value)
    out = svc.complete(["andy"], k=3)
    assert out[0][0][1] == "andrew pavlo"


def test_targeted_errors_are_the_dedicated_type(corpus):
    """The session-shaped entry points raise UnsupportedOnShardedIndex
    (a NotImplementedError subclass, so older match-based callers keep
    working) rather than a bare NotImplementedError."""
    from repro.core.distributed import UnsupportedOnShardedIndex

    assert issubclass(UnsupportedOnShardedIndex, NotImplementedError)
    strings, scores, rules = corpus
    idx = ShardedCompletionIndex(strings, scores, rules, n_shards=2,
                                 kind="et")
    with pytest.raises(UnsupportedOnShardedIndex):
        idx.session(k=5)
    with pytest.raises(UnsupportedOnShardedIndex):
        idx.open_session(k=5)


def test_service_compact_raises_targeted_error(corpus):
    """compact() is an overlay operation; on a sharded index the service
    points at the per-shard workaround instead of AttributeError-ing."""
    from repro.core.distributed import UnsupportedOnShardedIndex
    from repro.serving import CompletionService

    strings, scores, rules = corpus
    svc = CompletionService(ShardedCompletionIndex(
        strings, scores, rules, n_shards=2, kind="et"))
    with pytest.raises(UnsupportedOnShardedIndex, match="per-shard"):
        svc.compact()


# -- packed layout is rejected at spec validation, not deep in stacking --------


def test_packed_spec_rejected_at_construction(corpus):
    strings, scores, rules = corpus
    with pytest.raises(ValueError, match="unsupported on sharded"):
        ShardedCompletionIndex(strings, scores, rules, n_shards=2,
                               kind="et", compression="packed")


def test_packed_shards_rejected_by_from_shards(corpus):
    """Pre-built packed shards fail at wrap time with the workaround in
    the message (build with compression='none'), before any stacking."""
    strings, scores, rules = corpus
    spec = IndexSpec(kind="et", compression="packed")
    shards = [build_index(strings[i::2], scores[i::2], rules, spec)
              for i in range(2)]
    with pytest.raises(ValueError, match="compression='none'"):
        ShardedCompletionIndex.from_shards(shards)


def test_packed_spec_still_validates_unsharded():
    """The rejection is sharded-only: the same spec stays buildable as a
    local index (regression guard for the validate/validate_sharded
    split)."""
    spec = IndexSpec(kind="et", compression="packed")
    assert spec.validate() is spec
