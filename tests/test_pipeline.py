"""Pipeline parallelism (GPipe over the `pod` axis): exactness vs the
non-pipelined loss, and gradient flow through every stage."""

import pytest

from repro.distributed.pipeline import (HAS_MODERN_SHARDING,
                                        SHARDING_SKIP_REASON)
from tests.test_distributed import run_subprocess


@pytest.mark.skipif(not HAS_MODERN_SHARDING, reason=SHARDING_SKIP_REASON)
def test_pp_loss_matches_plain_and_grads_flow():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import sharding as sh
from repro.distributed.pipeline import (make_pp_loss_fn, stack_stages,
                                        pipeline_bubble_fraction)
from repro.models import transformer as tf

cfg = tf.TransformerConfig(n_layers=4, d_model=64, n_heads=4, n_kv=2,
                           d_head=16, d_ff=128, vocab=97, loss_chunk=16)
params, _ = tf.init_lm(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
B, S = 8, 32
batch = {"tokens": jnp.asarray(rng.integers(0, 97, (B, S))),
         "targets": jnp.asarray(rng.integers(0, 97, (B, S))),
         "mask": jnp.ones((B, S), bool)}
loss_ref, _ = tf.loss_fn(params, batch, cfg)

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
pp_params = stack_stages(params, 2)
pp_loss = make_pp_loss_fn(cfg, n_micro=4)
with sh.use_mesh(mesh):
    loss_pp, _ = jax.jit(lambda p, b: pp_loss(p, b))(pp_params, batch)
    g = jax.jit(jax.grad(lambda p, b: pp_loss(p, b)[0]))(pp_params, batch)
assert abs(float(loss_ref) - float(loss_pp)) < 2e-2, \
    (float(loss_ref), float(loss_pp))
gn = float(jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree.leaves(g))))
assert np.isfinite(gn) and gn > 0
assert float(jnp.abs(g["embed"]).max()) > 0          # stage 0
assert float(jnp.abs(g["unembed"]).max()) > 0        # last stage
assert float(jnp.abs(g["layers"]["mlp"]["w_gate"]).max()) > 0
assert abs(pipeline_bubble_fraction(2, 4) - 0.2) < 1e-9
print("OK")
"""
    assert "OK" in run_subprocess(code)
