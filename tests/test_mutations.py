"""Online mutable indexes: overlay semantics, compaction hot-swap, epoch
migration, and the ``reconfigure()`` runtime surface.

The core invariant — locked here both by deterministic cases and by a
hypothesis differential property — is that a mutated index answers
**bit-identically** (scores AND strings) to an index rebuilt from scratch
over the same live contents, across both substrates and both on-device
layouts.  The hot-swap half is covered end to end: a sequential
:class:`~repro.api.session.Session` and the continuous-batching scheduler
both migrate across a mid-stream ``compact()`` without losing keystrokes
or changing any answer for untouched strings.
"""

import sys
import warnings

import numpy as np
import pytest

import strategies as strat
from strategies import given, settings, st

from repro.api import CompletionIndex, IndexSpec, Session, build_index
from repro.core import make_rules

# small static widths: the overlay merge itself is width-independent, and
# the hypothesis matrix includes interpret-mode pallas
SPEC = dict(frontier=8, gens=8, expand=2, max_steps=48)
K = 3

STRINGS = ["andrew pavlo", "andy gray", "android update", "william smith",
           "willow tree", "record entry", "rec room", "banana", "band"]
SCORES = [50, 40, 30, 20, 10, 60, 5, 15, 25]
RULES = [("andy", "andrew"), ("bill", "william"), ("rec", "record")]
QUERIES = ["an", "andy", "bill", "rec", "w", "ba", "record e", "zzz"]


def _build(strings=STRINGS, scores=SCORES, **spec_kw):
    spec = IndexSpec(kind="et", **SPEC).replace(**spec_kw)
    return build_index(strings, scores, make_rules(RULES), spec)


def _assert_matches_rebuild(idx, queries=QUERIES, k=K):
    """The differential invariant: identical answers to a from-scratch
    build over the index's current live contents."""
    live = idx.live_items()
    strings = sorted(live)
    rebuilt = build_index(strings, [live[s] for s in strings], idx.rules,
                          idx.spec)
    assert idx.complete(queries, k=k) == rebuilt.complete(queries, k=k)


# -- overlay semantics ---------------------------------------------------------


def test_mutation_batch_matches_rebuild():
    idx = _build()
    idx.insert("andrew zimmer", 70)        # new, reachable via andy->andrew
    idx.insert("zz~trending", 999)         # new, plain prefix only
    idx.delete("record entry")             # tombstone a base hit
    idx.update_score("banana", 500)        # re-score: tombstone + carry
    assert idx.has_mutations
    assert idx.mutation_backlog == 5       # 3 added + 2 tombstones
    _assert_matches_rebuild(idx)


def test_synonym_rule_reaches_overlay_insert():
    """Overlay hits obey the same rules as base hits: an inserted string
    must surface for a query that only matches it through a rewrite."""
    idx = _build()
    idx.insert("andrew zimmer", 999)
    row = idx.complete(["andy"], k=K)[0]
    assert row[0] == (999, "andrew zimmer")


def test_insert_is_upsert():
    idx = _build()
    idx.insert("banana", 1)                # demote an existing string
    idx.insert("banana", 777)              # then re-score the re-score
    assert idx.live_items()[b"banana"] == 777
    _assert_matches_rebuild(idx)


def test_delete_raises_on_missing_and_double_delete():
    idx = _build()
    with pytest.raises(KeyError):
        idx.delete("never there")
    idx.delete("banana")
    with pytest.raises(KeyError):
        idx.delete("banana")
    idx.insert("banana", 9)                # resurrect, then delete again
    idx.delete("banana")
    assert b"banana" not in idx.live_items()


def test_update_score_requires_live_string():
    idx = _build()
    with pytest.raises(KeyError, match="use insert"):
        idx.update_score("never there", 5)
    idx.delete("banana")
    with pytest.raises(KeyError, match="use insert"):
        idx.update_score("banana", 5)


def test_rejects_empty_string_and_negative_score():
    idx = _build()
    with pytest.raises(ValueError, match="empty string"):
        idx.insert("", 5)
    with pytest.raises(ValueError, match="non-negative"):
        idx.insert("fine", -1)
    assert not idx.has_mutations


def test_insert_then_delete_cancels_out():
    baseline = _build().complete(QUERIES, k=K)
    idx = _build()
    idx.insert("zz~ephemeral", 999)
    idx.delete("zz~ephemeral")
    assert not idx.has_mutations           # overlay nets out to a no-op
    assert idx.complete(QUERIES, k=K) == baseline


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("compression", ["none", "packed"])
def test_mutations_match_rebuild_across_matrix(substrate, compression):
    """The deterministic arm of the differential matrix (the hypothesis
    property above it draws random batches when hypothesis is installed):
    one fixed mutation batch, every substrate x layout combination."""
    idx = _build(substrate=substrate, compression=compression)
    idx.insert("andrew zimmer", 70)
    idx.insert("zz~trending", 999)
    idx.delete("record entry")
    idx.update_score("banana", 500)
    _assert_matches_rebuild(idx)


# -- compaction / hot-swap -----------------------------------------------------


def test_save_with_mutations_refuses():
    idx = _build()
    idx.insert("zz~pending", 1)
    with pytest.raises(ValueError, match="uncompacted mutations"):
        idx.save("/dev/null")


def test_compact_folds_overlay_and_bumps_epoch():
    idx = _build()
    idx.insert("andrew zimmer", 70)
    idx.delete("record entry")
    idx.update_score("banana", 500)
    before = idx.complete(QUERIES, k=K)
    epoch0 = idx.epoch
    idx.compact()
    assert idx.epoch == epoch0 + 1
    assert not idx.has_mutations and idx.mutation_backlog == 0
    assert b"andrew zimmer" in idx.strings          # folded into the base
    assert idx.complete(QUERIES, k=K) == before     # answers are invariant


def test_compact_handoff_writes_loadable_container(tmp_path):
    path = str(tmp_path / "folded.npz")
    idx = _build()
    idx.insert("zz~persisted", 42)
    idx.compact(handoff_path=path)
    loaded = CompletionIndex.load(path)
    assert loaded.complete(QUERIES + ["zz"], k=K) == \
        idx.complete(QUERIES + ["zz"], k=K)


def test_epoch_survives_save_load(tmp_path):
    path = str(tmp_path / "epoch.npz")
    idx = _build()
    idx.insert("zz~x", 1)
    idx.compact()
    assert idx.epoch == 1
    idx.save(path)
    assert CompletionIndex.load(path).epoch == 1


def test_mutations_after_prepare_survive_the_swap():
    """apply_compaction re-applies whatever landed after the snapshot as
    a fresh overlay — the racy half of a background compaction."""
    idx = _build()
    idx.insert("zz~early", 10)
    prepared = idx.prepare_compaction()
    idx.insert("zz~late", 20)              # lands between prepare and apply
    idx.delete("banana")
    idx.apply_compaction(prepared)
    assert b"zz~early" in idx.strings      # folded by the prepare
    assert idx.has_mutations               # the late pair re-applied on top
    live = idx.live_items()
    assert live[b"zz~late"] == 20 and b"banana" not in live
    _assert_matches_rebuild(idx)


# -- epoch migration under live sessions ---------------------------------------


def test_session_answers_through_overlay_then_migrates():
    idx = _build()
    sess = Session(idx, k=K)
    sess.type("an")
    idx.insert("antelope", 999)
    # pending mutations route the compiled session through the merged
    # one-shot path immediately — no compact needed to see the insert
    assert sess.topk()[0] == (999, "antelope")
    epoch0 = idx.epoch
    idx.compact()
    assert idx.epoch == epoch0 + 1
    # next keystroke migrates: replayed prefix, fresh epoch, same answers
    got = sess.type("t")
    assert got == idx.complete(["ant"], k=K)[0]
    assert sess._epoch == idx.epoch


def test_session_backspace_after_hot_swap():
    idx = _build()
    sess = Session(idx, k=K)
    sess.type("and")
    idx.insert("zz~x", 1)
    idx.compact()
    assert sess.backspace() == idx.complete(["an"], k=K)[0]


def test_scheduler_hot_swap_mid_stream():
    """A compact() under a live scheduler loses no keystrokes and changes
    no answers: only zz-prefixed strings are mutated, so every typed
    prefix's expected results equal a mutation-free baseline."""
    from repro.serving import CompletionService

    baseline = _build()                       # never mutated
    idx = _build()
    svc = CompletionService(idx, batching=True, block=4,
                            max_wait_ms=1000.0)
    texts = ["andy p", "willow", "record", "banana"]
    sessions = [svc.open_session(k=K) for _ in texts]
    tickets = []
    for step in range(max(len(t) for t in texts)):
        if step == 2:                         # mutations land mid-stream
            idx.insert("zz~hot-1", 901)
            idx.insert("zz~hot-2", 902)
            idx.delete("zz~hot-2")
        if step == 4:                         # hot-swap mid-stream
            svc.compact()
        for sess, text in zip(sessions, texts):
            if step < len(text):
                tickets.append((sess.submit(text[step]), text[:step + 1]))
    svc.drain()
    assert svc.scheduler.stats.migrations >= 1
    assert all(t.done for t, _ in tickets)
    lost = sum(t.results is None for t, _ in tickets)
    assert lost == 0
    expected = {p: baseline.complete([p], k=K)[0]
                for p in {p for _, p in tickets}}
    for t, p in tickets:
        assert t.results == expected[p], p
    assert b"zz~hot-1" in idx.strings         # the compact really folded


# -- reconfigure / deprecations ------------------------------------------------


def test_reconfigure_changes_runtime_knobs_and_bumps_epoch():
    idx = _build()
    epoch0 = idx.epoch
    idx.reconfigure(substrate="jnp", memory_budget=1 << 14)
    assert idx.substrate == "jnp" and idx.memory_budget == 1 << 14
    assert idx.epoch == epoch0 + 1
    idx.reconfigure(substrate="jnp")          # no-op: nothing changed
    assert idx.epoch == epoch0 + 1


def test_reconfigure_rejects_build_time_and_unknown_fields():
    idx = _build()
    with pytest.raises(ValueError, match="build-time"):
        idx.reconfigure(kind="ht")
    with pytest.raises(ValueError, match="build-time"):
        idx.reconfigure(compression="packed")
    with pytest.raises(ValueError, match="unknown reconfigure"):
        idx.reconfigure(bogus=1)
    with pytest.raises(ValueError, match="unknown substrate"):
        idx.reconfigure(substrate="nope")
    assert idx.epoch == 0                     # rejected calls change nothing


def test_deprecated_setters_warn_and_still_work():
    idx = _build()
    with pytest.warns(DeprecationWarning, match="set_substrate"):
        idx.set_substrate("jnp")
    with pytest.warns(DeprecationWarning, match="set_memory_budget"):
        idx.set_memory_budget(1 << 14)
    assert idx.substrate == "jnp" and idx.memory_budget == 1 << 14


def test_core_api_shim_warns_on_import():
    sys.modules.pop("repro.core.api", None)
    with pytest.warns(DeprecationWarning, match="repro.core.api is "
                                                "deprecated"):
        import repro.core.api as shim
    import repro.api as api
    assert shim.CompletionIndex is api.CompletionIndex


def test_core_package_attrs_stay_warning_free():
    import repro.api as api
    import repro.core as core

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert core.CompletionIndex is api.CompletionIndex
        assert core.build_index is api.build_index


# -- hypothesis differential ---------------------------------------------------

if strat.HAVE_HYPOTHESIS:
    diff_settings = settings(
        settings.get_profile("differential"),
        max_examples=strat.max_examples(4))

    #: random mutation batches over the dictionaries' alphabet, so ops
    #: collide with base strings (and each other) often
    mutation_ops = st.lists(
        st.tuples(st.sampled_from(["insert", "delete", "rescore"]),
                  strat.words, st.integers(0, 999)),
        min_size=1, max_size=10)

    @pytest.mark.streamed
    @pytest.mark.parametrize("substrate,compression",
                             [("jnp", "none"), ("jnp", "packed"),
                              ("pallas", "none"), ("pallas", "packed")])
    @diff_settings
    @given(strings=strat.dictionaries, scores_seed=strat.score_seeds,
           rules=strat.rule_sets, ops=mutation_ops,
           queries=strat.query_streams)
    def test_differential_mutations_match_rebuild(
            substrate, compression, strings, scores_seed, rules, ops,
            queries):
        """Random mutation batches == from-scratch rebuild, bit for bit,
        on both substrates and both layouts (the overlay side-index runs
        uncompressed even when the base is packed)."""
        rules = make_rules(strat.clean_rules(rules))
        rng = np.random.default_rng(scores_seed)
        scores = rng.integers(1, 1000, len(strings)).tolist()
        spec = IndexSpec(kind="et", substrate=substrate,
                         compression=compression, **SPEC)
        idx = build_index(strings, scores, rules, spec)
        shadow = {s: int(r) for s, r in zip(
            idx.strings, np.asarray(idx.scores).tolist())}
        for op, word, score in ops:
            b = word.encode()
            if op == "insert":
                idx.insert(b, score)
                shadow[b] = score
            elif op == "delete":
                if b in shadow:
                    idx.delete(b)
                    del shadow[b]
                else:
                    with pytest.raises(KeyError):
                        idx.delete(b)
            else:
                if b in shadow:
                    idx.update_score(b, score)
                    shadow[b] = score
                else:
                    with pytest.raises(KeyError):
                        idx.update_score(b, score)
        assert idx.live_items() == shadow
        if not shadow:                         # everything deleted
            return
        rebuilt = build_index(sorted(shadow),
                              [shadow[s] for s in sorted(shadow)],
                              rules, spec)
        assert idx.complete(queries, k=K) == rebuilt.complete(queries, k=K)
else:  # hypothesis absent: explicit skip, not a collection error
    @strat.needs_hypothesis
    def test_differential_mutations_match_rebuild():
        pass
