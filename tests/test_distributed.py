"""Distributed behaviour on simulated multi-device meshes (subprocesses set
XLA_FLAGS before jax init; the main pytest process stays single-device)."""

import json
import os
import subprocess
import sys

import pytest

from repro.core.distributed import HAS_MODERN_SHARDING, SHARDING_SKIP_REASON

# every test here builds an AxisType mesh / traces through shard_map in its
# subprocess (same interpreter + jax as this process), so skip them all on
# old jax with the feature-detected reason instead of CI deselection
pytestmark = pytest.mark.skipif(not HAS_MODERN_SHARDING,
                                reason=SHARDING_SKIP_REASON)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str, n_devices: int = 8, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_completion_matches_oracle():
    code = """
import jax, json
from repro.core import make_rules
from repro.core.distributed import ShardedCompletionIndex
from repro.core.oracle import OracleIndex

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
strings = [f"record {i:03d} entry" for i in range(64)] + [
    "andrew pavlo", "william smith"]
scores = list(range(1, len(strings) + 1))
rules = make_rules([("andy", "andrew"), ("bill", "william"), ("rec", "record")])
oracle = OracleIndex(strings, scores, rules)
idx = ShardedCompletionIndex(strings, scores, rules, mesh=mesh, kind="ht",
                             alpha=0.5)
qs = ["andy", "bill s", "rec 00", "record 01", "zzz", "entry", "r", "re"]
got = idx.complete(qs, k=5)
for q, row in zip(qs, got):
    exp = [s for s, _ in oracle.complete(q, 5)]
    assert [s for s, _ in row] == exp, (q, row, exp)
print("OK")
"""
    assert "OK" in run_subprocess(code)


def test_lm_sharded_train_step_matches_single_device():
    """The sharded train step must be numerically equivalent (small tol) to
    single-device execution: same loss for same batch."""
    code = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import all_archs
from repro.configs.cells import make_train_step
from repro.distributed import sharding as sh
from repro.models import transformer as tf
from repro.optim import init_optimizer

spec = all_archs()["granite-moe-1b-a400m"]
cfg = dataclasses.replace(spec.make_smoke_config(), moe_experts=4)
params, _ = tf.init_lm(jax.random.PRNGKey(0), cfg)
opt = init_optimizer(spec.optimizer, params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32))),
         "targets": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32))),
         "mask": jnp.ones((8, 32), bool)}
step = make_train_step(tf.loss_fn, cfg, spec.optimizer)

# single device
_, _, m1 = jax.jit(step)(params, opt, batch)
loss1 = float(m1["loss"])

# sharded over (2 data x 2 model)
mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
cfg2 = dataclasses.replace(cfg, tp_heads=2)
params2, _ = tf.init_lm(jax.random.PRNGKey(0), cfg2)
opt2 = init_optimizer(spec.optimizer, params2)
step2 = make_train_step(tf.loss_fn, cfg2, spec.optimizer)
with sh.use_mesh(mesh):
    _, _, m2 = jax.jit(step2)(params2, opt2, batch)
loss2 = float(m2["loss"])
# tp=2 padded-head layout is mathematically identical GQA; same init seed
assert abs(loss1 - loss2) < 5e-2, (loss1, loss2)
print("OK", loss1, loss2)
"""
    out = run_subprocess(code, n_devices=4)
    assert "OK" in out


def test_flash_decode_sharded_matches_dense():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import sharding as sh
from repro.models import layers as L

rng = np.random.default_rng(0)
B, H, KV, Sc, hd = 4, 4, 2, 32, 16
q = jnp.asarray(rng.normal(size=(B, H, 1, hd)).astype(np.float32))
k = jnp.asarray(rng.normal(size=(B, KV, 1, hd)).astype(np.float32))
v = jnp.asarray(rng.normal(size=(B, KV, 1, hd)).astype(np.float32))
ck = jnp.asarray(rng.normal(size=(B, KV, Sc, hd)).astype(np.float32))
cv = jnp.asarray(rng.normal(size=(B, KV, Sc, hd)).astype(np.float32))
pos = jnp.int32(17)

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
with sh.use_mesh(mesh):
    out_s, (ck_s, cv_s) = jax.jit(
        lambda *a: L._flash_decode_sharded(*a, None, mesh))(q, k, v, ck, cv, pos)

# dense reference (single device semantics)
g = H // KV
ck2 = ck.at[:, :, 17, :].set(k[:, :, 0, :])
cv2 = cv.at[:, :, 17, :].set(v[:, :, 0, :])
kk = jnp.repeat(ck2, g, axis=1)
vv = jnp.repeat(cv2, g, axis=1)
s = jnp.einsum("bnqh,bnkh->bnqk", q, kk) / np.sqrt(hd)
valid = jnp.arange(Sc)[None, :] <= 17
s = jnp.where(valid[:, None, None, :], s, -1e30)
w = jax.nn.softmax(s, axis=-1)
ref = jnp.einsum("bnqk,bnkh->bnqh", w, vv)
err = float(jnp.abs(out_s - ref).max())
assert err < 1e-5, err
assert float(jnp.abs(ck_s - ck2).max()) == 0.0
print("OK", err)
"""
    assert "OK" in run_subprocess(code)


def test_compressed_allreduce_error_feedback():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed import sharding as sh
from repro.distributed.compression import (compress_grads, init_error_state)

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rng = np.random.default_rng(0)
g = {"w": jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))}
with sh.use_mesh(mesh):
    err = init_error_state(g, "data")
    out, err = compress_grads(g, err, "data")
    # replicated input => mean == input, up to int8 quantization error
    diff = float(jnp.abs(out["w"] - g["w"]).max())
    scale = float(jnp.abs(g["w"]).max()) / 127
    assert diff <= scale + 1e-6, (diff, scale)
    # error feedback: compressing the same grad repeatedly converges so the
    # *accumulated* mean approaches the true value
    acc = jnp.zeros_like(g["w"])
    e = init_error_state(g, "data")
    for _ in range(8):
        o, e = compress_grads(g, e, "data")
        acc = acc + o["w"]
    mean_err = float(jnp.abs(acc / 8 - g["w"]).max())
    assert mean_err < scale / 2, (mean_err, scale)
print("OK")
"""
    assert "OK" in run_subprocess(code)


@pytest.mark.slow
def test_dryrun_smoke_production_mesh():
    """One cell per family on the real (16,16) mesh with smoke configs."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.configs import all_archs
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_production_mesh

mesh = make_production_mesh()
archs = all_archs()
for aid, shape in [("granite-moe-1b-a400m", "train_4k"),
                   ("gin-tu", "molecule"),
                   ("dlrm-rm2", "serve_p99"),
                   ("autocomplete-dblp", "serve_1k")]:
    r = run_cell(archs[aid], shape, mesh, smoke=True)
    assert r["status"] == "OK", (aid, shape, r.get("error"))
print("OK")
"""
    assert "OK" in run_subprocess(code, n_devices=1, timeout=1800)
