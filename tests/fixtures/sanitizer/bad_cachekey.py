"""Planted compile-cache-key violations (analyzed, never imported)."""

from functools import partial

import jax


class _Cache(dict):
    def get(self, key, factory=None):        # the analyzer keys on the name
        return dict.get(self, key)


compile_cache = _Cache()


@partial(jax.jit, static_argnames=("width",))
def build_kernel(x, *, width=8, depth=4):
    return x


def jitted_path(cfg, x):
    return build_kernel(x, width=cfg.walk_tile, depth=cfg.emit_tile)  # PLANT: KEY003


def lookup(cfg, batch):
    key = ("batch", batch, cfg.walk_tile)  # PLANT: KEY001
    return compile_cache.get(key, None)
