"""Planted probe/envelope violations (analyzed, never imported)."""

from typing import NamedTuple

import jax.numpy as jnp


class DeviceTrie(NamedTuple):
    first_child: object
    edge_char: object
    edge_child: object
    tele_plane: object
    # compressed (format v4) planes
    p_flags: object
    pc_score: object
    pc_sid: object


class FixtureSubstrate:
    _WALK_FIELDS = ("first_child", "edge_char", "edge_child")
    _CACHE_FIELDS = ("pc_sid",)
    _MAX_FRONTIER = 1 << 20

    @staticmethod
    def _table_bytes(t, fields):
        return 4 * len(fields)

    def walk_variant(self, t, cfg, seq_len):
        if cfg.frontier > self._MAX_FRONTIER:
            return None
        if self._table_bytes(t, self._WALK_FIELDS) <= cfg.memory_budget:
            return "resident"
        return "streamed"

    def walk_batch(self, t, cfg, qs):  # PLANT: ENV001
        from bad_kernels import walk_kernel

        cols = t.tele_plane               # read but not in _WALK_FIELDS
        node = t.first_child
        return walk_kernel(qs, cols, node, walk_tile=cfg.walk_tile)

    def cached_topk_batch(self, t, cfg, loci, k):  # PLANT: ENV001
        if self._table_bytes(t, self._CACHE_FIELDS) > cfg.memory_budget:
            return None
        flags = t.p_flags      # compressed planes read but the byte
        enc = t.pc_score       # accounting only claims pc_sid
        return flags, enc, loci, k


def beam_seed_pool(loci, gens=16):
    bq, f = loci.shape
    pool = jnp.zeros((bq, gens - f), jnp.int32)  # PLANT: ENV004
    return pool
