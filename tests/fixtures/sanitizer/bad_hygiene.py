"""Planted traced-code hygiene violations (analyzed, never imported)."""

import jax
import jax.numpy as jnp                              # noqa: F401
from jax.experimental import pallas as pl            # noqa: F401


def frozen_branch(x):
    if x.sum() > 0:  # PLANT: TRC001
        x = x + 1
    return x


def frozen_ternary(x):
    return x + 1 if x.any() else x  # PLANT: TRC001


def dynamic_python_loop(x, n):
    for _ in range(n):  # PLANT: TRC002
        x = x + 1
    return x


def dynamic_while(cond, body, x):
    return jax.lax.while_loop(cond, body, x)  # PLANT: TRC002
