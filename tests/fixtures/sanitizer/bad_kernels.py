"""Planted VMEM-scratch envelope violations (analyzed, never imported)."""

import jax.numpy as jnp
from jax.experimental import pallas as pl            # noqa: F401
from jax.experimental.pallas import tpu as pltpu


def walk_kernel(qs, cols, node, *, walk_tile=8, frontier=4):
    scratch = pltpu.VMEM((frontier, walk_tile), jnp.int32)  # PLANT: ENV002 ENV003
    return qs, cols, node, scratch


def edit_sweep_kernel(qs, *, edit_budget=2):
    # nothing bounds the edit budget here: the (node, edits-used) state
    # plane scales scratch by edit_budget + 1 past any probe-admitted
    # budget
    lanes = 8 * (edit_budget + 1)
    buf = pltpu.VMEM((lanes, 8), jnp.int32)  # PLANT: ENV002
    return qs, buf


def packed_stage_kernel(labels):
    # narrow-dtype staging for the compressed layout: the u16 itemsize
    # must be what the scratch accounting multiplies by — 2 B/elem over
    # 2^23 rows is still past the 16 MiB VMEM capacity
    stage = pltpu.VMEM((1 << 23, 2), jnp.uint16)  # PLANT: ENV003
    return labels, stage
