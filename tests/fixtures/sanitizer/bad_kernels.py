"""Planted VMEM-scratch envelope violations (analyzed, never imported)."""

import jax.numpy as jnp
from jax.experimental import pallas as pl            # noqa: F401
from jax.experimental.pallas import tpu as pltpu


def walk_kernel(qs, cols, node, *, walk_tile=8, frontier=4):
    scratch = pltpu.VMEM((frontier, walk_tile), jnp.int32)  # PLANT: ENV002 ENV003
    return qs, cols, node, scratch
