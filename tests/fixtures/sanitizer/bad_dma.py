"""Planted DMA-discipline violations (analyzed, never imported)."""

import jax
from jax.experimental import pallas as pl            # noqa: F401
from jax.experimental.pallas import tpu as pltpu     # noqa: F401


def unwaited_start(src, dst, sem):
    pltpu.make_async_copy(src.at[0], dst.at[0], sem.at[0]).start()  # PLANT: DMA001
    return 0


def wait_without_start(src, dst, sem):
    pltpu.make_async_copy(src.at[0], dst.at[0], sem.at[0]).wait()  # PLANT: DMA002
    return 0


def read_races_dma(src, dst, sem):
    pltpu.make_async_copy(src.at[0], dst.at[0], sem.at[0]).start()
    x = dst[0]  # PLANT: DMA003
    pltpu.make_async_copy(src.at[0], dst.at[0], sem.at[0]).wait()
    return x


def broken_rotation(n: int, make_dmas):
    def body(j, _):
        for dma in make_dmas(j, j % 2):
            dma.start()  # PLANT: DMA004
        for dma in make_dmas(j, j % 2):
            dma.wait()
        return 0

    jax.lax.fori_loop(0, n, body, 0)
