"""Fixture config: deliberately NOT a frozen dataclass.

This corpus is analyzed, never imported.  Each ``# PLANT: RULE-ID``
comment marks a line the sanitizer must report with exactly that rule.
"""

from dataclasses import dataclass


@dataclass
class EngineConfig:  # PLANT: KEY002
    frontier: int = 4
    gens: int = 16
    expand: int = 4
    walk_tile: int = 8
    emit_tile: int = 8
    memory_budget: int = 0
    edit_budget: int = 0
