"""Query-mode coverage: bounded-edit and multi-term completion.

Deterministic matrices (the random-draw counterparts live in
``test_differential.py``):

- bounded-edit (``edit_budget`` e in {0,1,2}): jnp == pallas-resident ==
  pallas-streamed bit-identically, and the end-to-end lookup equals both
  the edit-aware ``OracleIndex`` and an inline brute-force
  prefix-edit-distance scan;
- multi-term: last-token completion conditioned on the previous tokens
  answers identically through ``complete``, ``Session`` and the
  scheduler's slab path;
- empty-prefix audit: ``complete([b""])``, a fresh ``Session`` and a
  depth-0 scheduler lane must all return the whole-dictionary top-k,
  also on an index with uncompacted overlay mutations;
- ``Session.backspace`` over multi-byte UTF-8 (the keystroke state is
  per *byte*, a user backspace removes a *codepoint*).
"""

from dataclasses import replace

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import IndexSpec, build_index
from repro.core import engine as eng
from repro.core import make_rules
from repro.core.alphabet import pad_queries
from repro.core.oracle import OracleIndex
from repro.serving import CompletionService

SEQ_LEN = 8
K = 3
# edit mode multiplies live frontier states by the budget dimension, so
# these matrices run wider than the exact-match differential SPEC
SPEC = dict(frontier=16, gens=16, expand=2, max_steps=64)

STRINGS = [b"andy pavlo", b"android", b"andrew", b"banana", b"sand",
           b"andyp"]
SCORES = [60, 50, 40, 30, 20, 10]
RULES = [("andy", "andrew"), ("ny", "new york")]
EDIT_QUERIES = [b"andy", b"andt", b"xndy", b"ady", b"anddy", b"ba", b"ny",
                b"sund", b""]


def edit_distance(a: bytes, b: bytes) -> int:
    m, n = len(a), len(b)
    d = list(range(n + 1))
    for i in range(1, m + 1):
        prev, d[0] = d[0], i
        for j in range(1, n + 1):
            prev, d[j] = d[j], min(d[j] + 1, d[j - 1] + 1,
                                   prev + (a[i - 1] != b[j - 1]))
    return d[n]


def brute_edit_topk(strings, scores, p: bytes, e: int, k: int):
    """Reference semantics: s matches iff some prefix of s is within
    edit distance e of p (rules aside — use on rule-free indexes)."""
    hits = [(sc, s.decode()) for s, sc in zip(strings, scores)
            if any(edit_distance(p, s[:i]) <= e
                   for i in range(len(s) + 1))]
    hits.sort(key=lambda t: (-t[0], t[1]))
    return hits[:k]


def _run(idx, cfg, sub_name, qs, qlens):
    sub = eng.get_substrate(sub_name)
    s, i, e = eng.complete_batch(idx.device, cfg, qs, qlens, K, sub)
    return np.asarray(s), np.asarray(i), np.asarray(e)


# -- bounded-edit -------------------------------------------------------------


@pytest.mark.streamed
@pytest.mark.parametrize("compression", ["none", "packed"])
@pytest.mark.parametrize("e", [0, 1, 2])
def test_bounded_edit_substrates_bit_identical(e, compression):
    """jnp == pallas-resident == pallas-streamed on an edit-budget index
    with synonym rules, bit for bit (scores, sids AND exact flags)."""
    idx = build_index(STRINGS, SCORES, make_rules(RULES),
                      IndexSpec(kind="et", edit_budget=e,
                                compression=compression, **SPEC))
    qs, qlens = pad_queries(EDIT_QUERIES, SEQ_LEN)
    qs, qlens = jnp.asarray(qs), jnp.asarray(qlens)

    sub = eng.get_substrate("pallas")
    cfg_res = idx.cfg
    cfg_str = replace(idx.cfg,
                      memory_budget=sub.min_streamed_budget(idx.device))
    assert sub.walk_variant(idx.device, cfg_res, SEQ_LEN) == "resident"
    assert sub.walk_variant(idx.device, cfg_str, SEQ_LEN) == "streamed"

    ref = _run(idx, cfg_res, "jnp", qs, qlens)
    for label, cfg in (("resident", cfg_res), ("streamed", cfg_str)):
        got = _run(idx, cfg, "pallas", qs, qlens)
        for a, b, nm in zip(got, ref, ("scores", "sids", "exact")):
            np.testing.assert_array_equal(
                a, b, err_msg=f"e={e}/{compression}/{label}/{nm}")


@pytest.mark.parametrize("e", [0, 1, 2])
def test_bounded_edit_matches_oracles(e):
    """End-to-end: the edit-aware OracleIndex with rules, and the
    brute-force prefix-edit-distance scan on a rule-free index."""
    rules = make_rules(RULES)
    idx = build_index(STRINGS, SCORES, rules,
                      IndexSpec(kind="et", edit_budget=e, **SPEC))
    oracle = OracleIndex(STRINGS, SCORES, rules, edit_budget=e)
    for q, row in zip(EDIT_QUERIES, idx.complete(EDIT_QUERIES, k=K)):
        want = [(s, b.decode()) for s, b in oracle.complete(q, K)]
        assert row == want, (q, e)

    plain = build_index(STRINGS, SCORES, make_rules([]),
                        IndexSpec(kind="plain", edit_budget=e, **SPEC))
    for q, row in zip(EDIT_QUERIES, plain.complete(EDIT_QUERIES, k=K)):
        assert row == brute_edit_topk(STRINGS, SCORES, q, e, K), (q, e)


def test_edit_budget_is_a_runtime_reconfigure_field():
    """edit_budget rides reconfigure (no rebuild): the same built trie
    answers exact at e=0 and typo-tolerantly at e=1."""
    idx = build_index(STRINGS, SCORES, make_rules([]),
                      IndexSpec(kind="plain", **SPEC))
    assert idx.complete([b"andt"], k=K)[0] == []
    relaxed = idx.reconfigure(edit_budget=1)
    assert relaxed.complete([b"andt"], k=K)[0] == \
        brute_edit_topk(STRINGS, SCORES, b"andt", 1, K)


# -- multi-term ---------------------------------------------------------------


MT_STRINGS = [b"the new york times", b"new york", b"san francisco giants",
              b"the giants", b"new jersey", b"times square"]
MT_SCORES = [60, 50, 40, 30, 20, 10]
# query -> expected completions: the last token completes against any
# token whose preceding tokens match, skipping up to multiterm_gap
# interior tokens
MT_EXPECT = {
    b"the t": [(60, "the new york times")],
    b"the times": [(60, "the new york times")],
    b"the york t": [(60, "the new york times")],
    b"new y": [(50, "new york")],
    b"san g": [(40, "san francisco giants")],
    b"the g": [(30, "the giants")],
    b"t": [(60, "the new york times"), (30, "the giants"),
           (10, "times square")],
}


@pytest.fixture(scope="module")
def mt_idx():
    return build_index(MT_STRINGS, MT_SCORES, make_rules([]),
                       IndexSpec(kind="multiterm", frontier=32, gens=32,
                                 expand=4, max_steps=128, multiterm_gap=2))


def test_multiterm_complete(mt_idx):
    queries = list(MT_EXPECT)
    for q, row in zip(queries, mt_idx.complete(queries, k=K)):
        assert row == MT_EXPECT[q], q


def test_multiterm_session_parity(mt_idx):
    """The incremental Session must answer every multi-term query the
    way the one-shot path does, per keystroke."""
    for q, want in MT_EXPECT.items():
        sess = mt_idx.session(k=K)
        assert sess.type(q.decode()) == want, q
        # and the intermediate backspace state stays consistent
        assert sess.backspace(1) == \
            mt_idx.complete([q[:-1]], k=K)[0], q


@pytest.mark.streamed
@pytest.mark.parametrize("compression", ["none", "packed"])
def test_multiterm_substrates_bit_identical(compression):
    """jnp == pallas-resident == pallas-streamed on a multiterm index
    (the synthesized token-skip teleports ride the same planes the
    kernel already fuses)."""
    idx = build_index(MT_STRINGS, MT_SCORES, make_rules([]),
                      IndexSpec(kind="multiterm", frontier=32, gens=32,
                                expand=4, max_steps=128, multiterm_gap=2,
                                compression=compression))
    queries = list(MT_EXPECT)
    seq_len = 16
    qs, qlens = pad_queries(queries, seq_len)
    qs, qlens = jnp.asarray(qs), jnp.asarray(qlens)

    sub = eng.get_substrate("pallas")
    cfg_res = idx.cfg
    cfg_str = replace(idx.cfg,
                      memory_budget=sub.min_streamed_budget(idx.device))
    assert sub.walk_variant(idx.device, cfg_res, seq_len) == "resident"
    assert sub.walk_variant(idx.device, cfg_str, seq_len) == "streamed"

    def run(cfg, sub_name):
        s = eng.get_substrate(sub_name)
        out = eng.complete_batch(idx.device, cfg, qs, qlens, K, s)
        return tuple(np.asarray(x) for x in out)

    ref = run(cfg_res, "jnp")
    for label, cfg in (("resident", cfg_res), ("streamed", cfg_str)):
        got = run(cfg, "pallas")
        for a, b, nm in zip(got, ref, ("scores", "sids", "exact")):
            np.testing.assert_array_equal(
                a, b, err_msg=f"{compression}/{label}/{nm}")


def test_multiterm_scheduler_parity(mt_idx):
    """Multi-term queries through the batched slab path == one-shot."""
    svc = CompletionService(mt_idx, batching=True, block=2,
                            max_wait_ms=100.0)
    a, b = svc.open_session(k=K), svc.open_session(k=K)
    got_a = a.type("the t")
    got_b = b.type("san g")
    assert got_a == MT_EXPECT[b"the t"]
    assert got_b == MT_EXPECT[b"san g"]
    a.close(), b.close()


# -- empty prefix -------------------------------------------------------------


def _whole_dict_topk(strings, scores, k):
    ranked = sorted(((sc, s.decode()) for s, sc in zip(strings, scores)),
                    key=lambda t: (-t[0], t[1]))
    return ranked[:k]


def test_empty_prefix_all_paths_agree():
    """complete([b""]), a fresh Session and a depth-0 scheduler lane all
    return the whole-dictionary top-k (== oracle)."""
    rules = make_rules(RULES)
    idx = build_index(STRINGS, SCORES, rules, IndexSpec(kind="et", **SPEC))
    oracle = OracleIndex(STRINGS, SCORES, rules)
    want = [(s, b.decode()) for s, b in oracle.complete(b"", K)]
    assert want == _whole_dict_topk(STRINGS, SCORES, K)

    assert idx.complete([b""], k=K)[0] == want
    assert idx.session(k=K).topk() == want

    svc = CompletionService(idx, batching=True, block=2, max_wait_ms=100.0)
    lane = svc.open_session(k=K)
    assert lane._session.topk() == want      # depth-0 reset-only flush
    lane.close()


def test_empty_prefix_on_mutated_overlay():
    """The audit must hold on an index with uncompacted mutations: the
    overlay-merged one-shot path backs every empty-prefix answer."""
    idx = build_index(STRINGS, SCORES, make_rules([]),
                      IndexSpec(kind="plain", **SPEC))
    idx.insert(b"zeta", 99)
    idx.delete(b"banana")
    strings = [s for s in STRINGS if s != b"banana"] + [b"zeta"]
    scores = [sc for s, sc in zip(STRINGS, SCORES) if s != b"banana"] + [99]
    want = _whole_dict_topk(strings, scores, K)

    assert idx.complete([b""], k=K)[0] == want
    assert idx.session(k=K).topk() == want

    svc = CompletionService(idx, batching=True, block=2, max_wait_ms=100.0)
    lane = svc.open_session(k=K)
    assert lane._session.topk() == want
    lane.close()


def test_empty_prefix_edit_budget_stays_whole_dict():
    """At the empty prefix every string already matches exactly; an edit
    budget must not perturb the answer (deletes only widen the reach)."""
    idx = build_index(STRINGS, SCORES, make_rules([]),
                      IndexSpec(kind="plain", edit_budget=2, **SPEC))
    assert idx.complete([b""], k=K)[0] == \
        _whole_dict_topk(STRINGS, SCORES, K)


# -- UTF-8 backspace ----------------------------------------------------------


def test_session_backspace_multibyte():
    """backspace() removes whole codepoints, not single bytes: deleting
    one byte of a 2- or 3-byte UTF-8 char would leave a dangling head
    whose loci match nothing."""
    strings = ["café", "cafe", "caf", "日本語", "日本", "日記"]
    scores = [60, 50, 40, 30, 20, 10]
    idx = build_index(strings, scores, make_rules([]),
                      IndexSpec(kind="plain", **SPEC))

    sess = idx.session(k=K)
    sess.type("café")                         # é = 2 bytes
    assert sess.backspace() == idx.complete(["caf"], k=K)[0]
    assert sess.prefix == "caf"

    sess = idx.session(k=K)
    sess.type("日本語")                        # 3 bytes per char
    assert sess.backspace() == idx.complete(["日本"], k=K)[0]
    assert sess.prefix == "日本"
    assert sess.backspace(2) == idx.complete([""], k=K)[0]
    assert sess.prefix == ""

    # n spanning mixed widths, and over-deleting clamps at empty
    sess = idx.session(k=K)
    sess.type("café日")
    assert sess.backspace(2) == idx.complete(["caf"], k=K)[0]
    assert sess.prefix == "caf"
    assert sess.backspace(99) == idx.complete([""], k=K)[0]
    assert sess.prefix == ""
