"""Unit tests for benchmarks/trajectory.py --check / append / render.

The perf-trajectory gate has only ever been exercised implicitly by CI;
these tests pin its semantics directly: the median-gate math, the
warn-only treatment of jnp reference rows, the fresh-history-on-
path-change rule (a row whose fused/streamed flags change starts a new
history instead of being gated against a different code path), and the
single-sample warm-up rule.
"""

import json

import pytest

from benchmarks import trajectory as tj


def _row(engine="beam", kind="et", substrate="pallas", us=100.0, **flags):
    row = {"engine": engine, "kind": kind, "substrate": substrate,
           "backend": "cpu", "us_per_q": us,
           "fused_walk": True, "fused_beam": True,
           "streamed_walk": False, "streamed_beam": False}
    row.update(flags)
    return row


def _write_history(path, entries):
    path.write_text(json.dumps(entries))


def _write_smoke(path, rows):
    path.write_text(json.dumps({"benchmark": "substrates", "backend": "cpu",
                                "smoke": True, "rows": rows}))


def _hist_entry(commit, rows, ts=0.0):
    return {"timestamp": ts, "commit": commit, "backend": "cpu",
            "smoke": True, "rows": rows}


def _check(tmp_path, hist_rows_by_commit, smoke_rows, threshold=1.5):
    hist = tmp_path / "hist.json"
    smoke = tmp_path / "smoke.json"
    _write_history(hist, [_hist_entry(c, rows)
                          for c, rows in hist_rows_by_commit])
    _write_smoke(smoke, smoke_rows)
    return tj.check_run(str(smoke), str(hist), commit="fresh",
                        threshold=threshold)


# -- median-gate math ---------------------------------------------------------


def test_check_fails_pallas_row_beyond_threshold(tmp_path):
    hist = [("c1", [_row(us=100.0)]), ("c2", [_row(us=120.0)])]
    fails, warns = _check(tmp_path, hist, [_row(us=180.0)])   # median 110
    assert len(fails) == 1 and not warns
    assert "1.64x" in fails[0]
    fails, warns = _check(tmp_path, hist, [_row(us=160.0)])   # 1.45x: ok
    assert not fails and not warns


def test_check_threshold_is_exclusive(tmp_path):
    """us == threshold * median passes; the gate fires strictly above."""
    hist = [("c1", [_row(us=100.0)]), ("c2", [_row(us=100.0)])]
    fails, warns = _check(tmp_path, hist, [_row(us=150.0)])
    assert not fails and not warns
    fails, _ = _check(tmp_path, hist, [_row(us=150.1)])
    assert len(fails) == 1


def test_check_median_not_mean(tmp_path):
    """One outlier run must not drag the baseline: gate on the median."""
    hist = [("c1", [_row(us=100.0)]), ("c2", [_row(us=100.0)]),
            ("c3", [_row(us=10_000.0)])]
    fails, warns = _check(tmp_path, hist, [_row(us=140.0)])  # median 100
    assert not fails and not warns
    fails, _ = _check(tmp_path, hist, [_row(us=151.0)])
    assert len(fails) == 1


def test_check_excludes_own_commit_history(tmp_path):
    """The current commit's (just-appended) entry must not gate itself."""
    hist = tmp_path / "hist.json"
    smoke = tmp_path / "smoke.json"
    _write_history(hist, [_hist_entry("c1", [_row(us=100.0)]),
                          _hist_entry("c2", [_row(us=100.0)]),
                          _hist_entry("fresh", [_row(us=500.0)])])
    _write_smoke(smoke, [_row(us=500.0)])
    fails, _ = tj.check_run(str(smoke), str(hist), commit="fresh")
    assert len(fails) == 1           # gated vs c1/c2 only, not itself


# -- warn-only jnp rows -------------------------------------------------------


def test_check_jnp_rows_warn_only(tmp_path):
    hist = [("c1", [_row(substrate="jnp", us=100.0, fused_walk=False,
                         fused_beam=False)]),
            ("c2", [_row(substrate="jnp", us=100.0, fused_walk=False,
                         fused_beam=False)])]
    fails, warns = _check(tmp_path, hist, [
        _row(substrate="jnp", us=400.0, fused_walk=False,
             fused_beam=False)])
    assert not fails and len(warns) == 1


# -- fresh history on path change ---------------------------------------------


@pytest.mark.parametrize("flag", ["fused_walk", "fused_beam",
                                  "streamed_walk", "streamed_beam"])
def test_check_path_change_starts_fresh_history(tmp_path, flag):
    """A row whose claimed kernel path changes (a kernel landing, or the
    budget moving it to the DMA-streamed tier) measures different code —
    it must not be gated against the old path's timings."""
    old = _row(us=100.0)
    new = _row(us=10_000.0)
    new[flag] = not new[flag]
    hist = [("c1", [old]), ("c2", [old])]
    fails, warns = _check(tmp_path, hist, [new])
    assert not fails and not warns


def test_check_rows_predating_streamed_flags_keep_their_key(tmp_path):
    """History rows written before the streamed columns existed read the
    missing flags as False — a fresh non-streamed row still gates
    against them."""
    old = {k: v for k, v in _row(us=100.0).items()
           if k not in ("streamed_walk", "streamed_beam")}
    hist = [("c1", [old]), ("c2", [old])]
    fails, _ = _check(tmp_path, hist, [_row(us=200.0)])
    assert len(fails) == 1


# -- single-sample histories --------------------------------------------------


def test_check_single_sample_warns_instead_of_failing(tmp_path):
    hist = [("c1", [_row(us=100.0)])]
    fails, warns = _check(tmp_path, hist, [_row(us=1000.0)])
    assert not fails and len(warns) == 1
    # second sample arms the gate
    hist = [("c1", [_row(us=100.0)]), ("c2", [_row(us=100.0)])]
    fails, warns = _check(tmp_path, hist, [_row(us=1000.0)])
    assert len(fails) == 1 and not warns


def test_check_no_history_no_gate(tmp_path):
    fails, warns = _check(tmp_path, [], [_row(us=10_000.0)])
    assert not fails and not warns


# -- append / render ----------------------------------------------------------


def test_append_run_dedups_by_commit(tmp_path):
    hist = tmp_path / "hist.json"
    smoke = tmp_path / "smoke.json"
    _write_smoke(smoke, [_row(us=100.0)])
    tj.append_run(str(smoke), str(hist), commit="c1", timestamp=1.0)
    _write_smoke(smoke, [_row(us=120.0)])
    out = tj.append_run(str(smoke), str(hist), commit="c1", timestamp=2.0)
    assert len(out) == 1 and out[0]["rows"][0]["us_per_q"] == 120.0
    out = tj.append_run(str(smoke), str(hist), commit="c2", timestamp=3.0)
    assert [e["commit"] for e in out] == ["c1", "c2"]


def test_append_folds_multiple_smoke_files_into_one_entry(tmp_path):
    """Smoke files from different benchmarks (substrates + serving) of the
    same CI run must land as ONE trajectory entry: entries are replaced
    per commit, so appending them one call at a time would leave only the
    last file's rows."""
    hist = tmp_path / "hist.json"
    sub = tmp_path / "substrates.json"
    srv = tmp_path / "serving.json"
    _write_smoke(sub, [_row(us=100.0)])
    srv.write_text(json.dumps({
        "benchmark": "serving", "backend": "cpu", "smoke": True,
        "rows": [_row(engine="serving_batch", substrate="jnp", us=90.0,
                      fused_walk=False, fused_beam=False)]}))
    out = tj.append_run([str(sub), str(srv)], str(hist), commit="c1",
                        timestamp=1.0)
    assert len(out) == 1
    assert [r["engine"] for r in out[0]["rows"]] == ["beam", "serving_batch"]
    # re-running the same commit still replaces, not duplicates
    out = tj.append_run([str(sub), str(srv)], str(hist), commit="c1",
                        timestamp=2.0)
    assert len(out) == 1 and len(out[0]["rows"]) == 2


def test_check_reads_multiple_smoke_files(tmp_path):
    """--check flattens rows across all smoke files; serving rows are
    substrate=jnp, so their regressions warn instead of failing CI."""
    hist = tmp_path / "hist.json"
    sub = tmp_path / "substrates.json"
    srv = tmp_path / "serving.json"
    serving = lambda us: _row(engine="serving_batch", substrate="jnp",
                              us=us, fused_walk=False, fused_beam=False)
    _write_history(hist, [_hist_entry("c1", [_row(us=100.0), serving(90.0)]),
                          _hist_entry("c2", [_row(us=100.0), serving(90.0)])])
    _write_smoke(sub, [_row(us=400.0)])
    srv.write_text(json.dumps({"benchmark": "serving", "backend": "cpu",
                               "smoke": True, "rows": [serving(400.0)]}))
    fails, warns = tj.check_run([str(sub), str(srv)], str(hist),
                                commit="fresh")
    assert len(fails) == 1          # the pallas substrates row
    assert len(warns) == 1          # the serving row warns only
    assert "serving_batch" in warns[0]


def test_render_labels_streamed_rows(tmp_path):
    hist = [_hist_entry("c1", [
        _row(us=100.0),
        _row(us=900.0, streamed_walk=True, streamed_beam=True)])]
    md = tj.render_markdown(hist)
    assert "beam/et/pallas [fw+fb]" in md
    assert "beam/et/pallas [fw+fb+sw+sb]" in md
    assert "900" in md and "100" in md
