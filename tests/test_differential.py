"""Cross-substrate differential harness.

Random dictionaries x rule sets x query streams flow through every
execution path the engine has for the same lookup — the host-side Python
oracle, the jnp reference substrate, and the pallas substrate in both of
its tiers (VMEM-resident kernels and the DMA-streamed HBM tier) — and
the device paths must agree **bit-identically** (scores, sids AND exact
flags), while the end-to-end retry path must agree with the oracle's
top-k score multiset.

The hypothesis profile is derandomized (tests/strategies.py): CI and
local runs draw identical examples, so a red run reproduces from the
test id alone.  ``DIFF_MAX_EXAMPLES`` bounds the per-property example
count — interpret-mode kernel compiles dominate the cost, so CI pins a
small value.  Index kinds are covered by parametrization, not by random
draws, so all four kinds run on both substrates every time.
"""

import numpy as np
import pytest

import jax.numpy as jnp

import strategies as strat
from strategies import given, settings, st

from repro.api import IndexSpec, build_index
from repro.core import engine as eng
from repro.core import make_rules
from repro.core.alphabet import pad_queries
from repro.core.oracle import OracleIndex

pytestmark = [pytest.mark.streamed, strat.needs_hypothesis]

# small static widths so the kernels' fixed-trip loops stay cheap in
# interpret mode; wide enough that most examples stay exact (the retry
# path has its own deterministic coverage)
SPEC = dict(frontier=8, gens=8, expand=2, max_steps=48)
SEQ_LEN = 8
K = 3


def _force_streamed_budget(idx):
    """A VMEM budget that evicts every dictionary-sized table (forcing
    the streamed tier) while keeping the rule trie resident — the
    streamed locus kernel's only residency requirement."""
    return eng.get_substrate("pallas").min_streamed_budget(idx.device)


def _run(idx, cfg, sub_name, qs, qlens):
    sub = eng.get_substrate(sub_name)
    s, i, e = eng.complete_batch(idx.device, cfg, qs, qlens, K, sub)
    return np.asarray(s), np.asarray(i), np.asarray(e)


if strat.HAVE_HYPOTHESIS:
    diff_settings = settings(
        settings.get_profile("differential"),
        max_examples=strat.max_examples(6))

    @pytest.mark.parametrize("kind", strat.ALL_KINDS)
    @diff_settings
    @given(strings=strat.dictionaries, scores_seed=strat.score_seeds,
           rules=strat.rule_sets, queries=strat.query_streams)
    def test_differential_engine_paths(kind, strings, scores_seed, rules,
                                       queries):
        """jnp == pallas-resident == pallas-streamed, bit for bit."""
        from dataclasses import replace

        rules = make_rules(strat.clean_rules(rules))
        rng = np.random.default_rng(scores_seed)
        scores = rng.integers(1, 1000, len(strings)).tolist()
        idx = build_index(strings, scores, rules,
                          IndexSpec(kind=kind, **SPEC))
        qs, qlens = pad_queries(queries, SEQ_LEN)
        qs, qlens = jnp.asarray(qs), jnp.asarray(qlens)

        sub = eng.get_substrate("pallas")
        cfg_res = idx.cfg
        cfg_str = replace(idx.cfg, memory_budget=_force_streamed_budget(idx))
        # the probe must actually claim the paths this test says it covers
        assert sub.walk_variant(idx.device, cfg_res, SEQ_LEN) == "resident"
        assert sub.beam_variant(idx.device, cfg_res, K) == "resident"
        assert sub.walk_variant(idx.device, cfg_str, SEQ_LEN) == "streamed"

        ref = _run(idx, cfg_res, "jnp", qs, qlens)
        for label, cfg in (("resident", cfg_res), ("streamed", cfg_str)):
            got = _run(idx, cfg, "pallas", qs, qlens)
            for a, b, nm in zip(got, ref, ("scores", "sids", "exact")):
                np.testing.assert_array_equal(
                    a, b, err_msg=f"{kind}/{label}/{nm}")

    @pytest.mark.parametrize("kind", strat.ALL_KINDS)
    @diff_settings
    @given(strings=strat.dictionaries, scores_seed=strat.score_seeds,
           rules=strat.rule_sets, queries=strat.query_streams)
    def test_differential_oracle_end_to_end(kind, strings, scores_seed,
                                            rules, queries):
        """The full lookup (exactness retry included) on the streamed
        tier returns the oracle's top-k score multiset."""
        rules = make_rules(strat.clean_rules(rules))
        rng = np.random.default_rng(scores_seed)
        scores = rng.integers(1, 1000, len(strings)).tolist()
        oracle = OracleIndex(strings, scores,
                             rules if kind != "plain" else [])
        idx = build_index(strings, scores, rules,
                          IndexSpec(kind=kind, **SPEC))
        idx.set_memory_budget(_force_streamed_budget(idx))
        idx.set_substrate("pallas")
        got = idx.complete(queries, k=K)
        for q, row in zip(queries, got):
            assert [s for s, _ in row] == oracle.topk_scores(q, K), \
                (q, kind)
            valid = oracle.matches(q)
            for _, s in row:
                assert s.encode() in valid, (q, s, kind)
    # bounded-edit mode: the frontier carries (node, edits-used) states,
    # so it runs wider than the exact-match SPEC; gens >= frontier is a
    # beam seeding requirement (loci fill the generator pool)
    EDIT_SPEC = dict(frontier=16, gens=16, expand=2, max_steps=64)

    def _edit_distance(a: bytes, b: bytes) -> int:
        m, n = len(a), len(b)
        d = list(range(n + 1))
        for i in range(1, m + 1):
            prev, d[0] = d[0], i
            for j in range(1, n + 1):
                prev, d[j] = d[j], min(d[j] + 1, d[j - 1] + 1,
                                       prev + (a[i - 1] != b[j - 1]))
        return d[n]

    @pytest.mark.parametrize("compression", ["none", "packed"])
    @pytest.mark.parametrize("e", [0, 1, 2])
    @diff_settings
    @given(strings=strat.dictionaries, scores_seed=strat.score_seeds,
           rules=strat.rule_sets, queries=strat.edit_query_streams)
    def test_differential_bounded_edit(e, compression, strings,
                                       scores_seed, rules, queries):
        """Bounded-edit walks agree bit-identically across jnp /
        pallas-resident / pallas-streamed on both on-device layouts, and
        end-to-end with the edit-aware oracle; on rule-free indexes the
        oracle itself is cross-checked against brute-force
        prefix-edit-distance."""
        from dataclasses import replace

        rules = make_rules(strat.clean_rules(rules))
        rng = np.random.default_rng(scores_seed)
        scores = rng.integers(1, 1000, len(strings)).tolist()
        idx = build_index(strings, scores, rules,
                          IndexSpec(kind="et", edit_budget=e,
                                    compression=compression, **EDIT_SPEC))
        qs, qlens = pad_queries(queries, SEQ_LEN)
        qs, qlens = jnp.asarray(qs), jnp.asarray(qlens)

        sub = eng.get_substrate("pallas")
        cfg_res = idx.cfg
        cfg_str = replace(idx.cfg, memory_budget=_force_streamed_budget(idx))
        assert sub.walk_variant(idx.device, cfg_res, SEQ_LEN) == "resident"
        assert sub.walk_variant(idx.device, cfg_str, SEQ_LEN) == "streamed"

        ref = _run(idx, cfg_res, "jnp", qs, qlens)
        for label, cfg in (("resident", cfg_res), ("streamed", cfg_str)):
            got = _run(idx, cfg, "pallas", qs, qlens)
            for a, b, nm in zip(got, ref, ("scores", "sids", "exact")):
                np.testing.assert_array_equal(
                    a, b, err_msg=f"e={e}/{compression}/{label}/{nm}")

        oracle = OracleIndex(strings, scores, rules, edit_budget=e)
        for q, row in zip(queries, idx.complete(queries, k=K)):
            assert [s for s, _ in row] == oracle.topk_scores(q, K), (q, e)

        if not rules:   # rule-free draw: pin the oracle itself to the
            by = {s.encode(): sc for s, sc in zip(strings, scores)}
            for q in queries:   # brute-force edit-distance definition
                p = q.encode()
                want = {s for s in by
                        if any(_edit_distance(p, s[:i]) <= e
                               for i in range(len(s) + 1))}
                assert oracle.matches(q) == want, (q, e)
else:  # hypothesis absent: explicit skips, not collection errors
    @strat.needs_hypothesis
    def test_differential_engine_paths():
        pass

    @strat.needs_hypothesis
    def test_differential_oracle_end_to_end():
        pass

    @strat.needs_hypothesis
    def test_differential_bounded_edit():
        pass
