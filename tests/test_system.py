"""End-to-end behaviour tests for the paper's system: build -> serve ->
rerank, through the public API (CompletionIndex + CompletionService), on a
paper-shaped workload."""

import numpy as np

from repro.core import CompletionIndex, OracleIndex, make_rules
from repro.data.strings import make_dblp, make_usps, make_workload
from repro.serving import CompletionService


def test_end_to_end_usps_serving():
    """Build a USPS-like index, replay a synonym workload, verify every
    returned suggestion against the oracle and check service accounting."""
    ds = make_usps(n=2000, seed=0)
    rules = make_rules(ds.rules)
    oracle = OracleIndex(ds.strings, ds.scores, rules)
    idx = CompletionIndex.build(ds.strings, ds.scores, rules, kind="ht",
                                alpha=0.5, cache_k=16)
    svc = CompletionService(idx)
    queries = make_workload(ds, 64, seed=3, max_len=12)
    results = svc.complete(queries, k=10)
    hits = 0
    for q, rows in zip(queries, results):
        expect = oracle.topk_scores(q, 10)
        assert [s for s, _ in rows] == expect, q
        valid = oracle.matches(q)
        for _, s in rows:
            assert s.encode() in valid, (q, s)
        hits += bool(rows)
    assert hits / len(queries) > 0.5          # the workload hits the index
    assert svc.stats.n_queries == len(queries)
    assert svc.stats.mean_latency_ms > 0


def test_end_to_end_synonym_value():
    """The point of the paper: synonym-aware completion answers queries a
    plain prefix trie cannot."""
    ds = make_dblp(n=800, seed=1)
    rules = make_rules(ds.rules)
    syn = CompletionIndex.build(ds.strings, ds.scores, rules, kind="et")
    plain = CompletionIndex.build(ds.strings, ds.scores, [], kind="plain")
    # take dictionary strings and rewrite their first word to its variant
    inv = {}
    for lhs, rhs in ds.rules:
        inv.setdefault(rhs, lhs)
    queries = []
    for s in ds.strings:
        head = s.split(" ")[0]
        if head in inv:
            queries.append(inv[head] + " " + s.split(" ")[1][:2])
        if len(queries) == 20:
            break
    assert len(queries) >= 5
    got_syn = syn.complete(queries, k=5)
    got_plain = plain.complete(queries, k=5)
    syn_hits = sum(bool(r) for r in got_syn)
    plain_hits = sum(bool(r) for r in got_plain)
    assert syn_hits > plain_hits  # synonyms recover matches prefix-only loses


def test_end_to_end_rerank_changes_order():
    strings = ["alpha item", "beta item", "gamma item"]
    idx = CompletionIndex.build(strings, [30, 20, 10], make_rules([]),
                                kind="et")

    def rerank(_q, cands):
        return sorted(cands, key=lambda t: t[1])   # alphabetical, not score

    svc = CompletionService(idx, reranker=rerank, overfetch=2)
    out = svc.complete(["a"], k=3)
    assert [s for _, s in out[0]] == ["alpha item"]
    out = svc.complete(["b"], k=3)
    assert [s for _, s in out[0]] == ["beta item"]


def test_index_survives_rebuild_roundtrip():
    """Deterministic construction: same inputs -> same structure sizes and
    same answers (the property restart/rebuild correctness rests on)."""
    ds = make_dblp(n=300, seed=2)
    rules = make_rules(ds.rules)
    a = CompletionIndex.build(ds.strings, ds.scores, rules, kind="ht",
                              alpha=0.3)
    b = CompletionIndex.build(ds.strings, ds.scores, rules, kind="ht",
                              alpha=0.3)
    assert a.stats.n_nodes == b.stats.n_nodes
    assert a.stats.n_links == b.stats.n_links
    qs = make_workload(ds, 16, seed=4)
    assert a.complete(qs, 5) == b.complete(qs, 5)
