"""Per-architecture smoke tests (deliverable f): reduced config of the same
family, one forward/train step on CPU, asserting output shapes + no NaNs.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs
from repro.configs.cells import make_train_step
from repro.data.graph import make_molecule_batch, make_random_graph
from repro.data.lm import LMDataConfig, TokenStream
from repro.data.recsys import ClickStream, RecsysDataConfig
from repro.models import gnn, recsys, transformer as tf
from repro.optim import init_optimizer

ARCHS = all_archs()
LM_IDS = [a for a, s in ARCHS.items() if s.family == "lm"]
REC_IDS = [a for a, s in ARCHS.items() if s.family == "recsys"]


def _finite(tree):
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch_id", LM_IDS)
def test_lm_smoke_train_step(arch_id):
    spec = ARCHS[arch_id]
    cfg = spec.make_smoke_config()
    # structural features of the full config must be present in the smoke one
    full = spec.make_config()
    assert cfg.is_moe == full.is_moe
    assert (cfg.window is None) == (full.window is None)
    assert cfg.qkv_bias == full.qkv_bias
    assert cfg.moe_dense_residual == full.moe_dense_residual

    params, _ = tf.init_lm(jax.random.PRNGKey(0), cfg)
    opt = init_optimizer(spec.optimizer, params)
    stream = TokenStream(LMDataConfig(vocab=cfg.vocab, seq_len=32,
                                      global_batch=4))
    batch = jax.tree.map(jnp.asarray, stream.next_batch())
    step = jax.jit(make_train_step(tf.loss_fn, cfg, spec.optimizer))
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(params2), arch_id
    # one decode step
    logits, cache = tf.prefill(params, batch["tokens"][:, :16], cfg,
                               max_len=24, cache_dtype=jnp.float32)
    assert logits.shape == (4, cfg.padded_vocab)
    nxt = jnp.argmax(logits, axis=-1)
    assert int(nxt.max()) < cfg.vocab  # padded logits are masked
    logits2, cache = tf.decode_step(params, cache, nxt, cfg)
    assert logits2.shape == (4, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits2[:, : cfg.vocab])).all()


def test_gin_smoke_full_graph():
    spec = ARCHS["gin-tu"]
    base = spec.make_smoke_config()
    cfg = gnn.GINConfig(name=base.name, n_layers=base.n_layers,
                        d_hidden=base.d_hidden, d_feat=12, n_classes=4)
    g = make_random_graph(60, 240, 12, 4, seed=0)
    params, _ = gnn.init_gin(jax.random.PRNGKey(0), cfg)
    opt = init_optimizer(spec.optimizer, params)
    batch = {"feats": jnp.asarray(g.feats), "src": jnp.asarray(g.src),
             "dst": jnp.asarray(g.dst), "labels": jnp.asarray(g.labels),
             "label_mask": jnp.ones((60,), bool)}
    step = jax.jit(make_train_step(gnn.loss_full_graph, cfg, spec.optimizer))
    p2, _, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])) and _finite(p2)
    logits = gnn.forward_full_graph(params, batch["feats"], batch["src"],
                                    batch["dst"], cfg)
    assert logits.shape == (60, 4)


def test_gin_smoke_molecule():
    spec = ARCHS["gin-tu"]
    base = spec.make_smoke_config()
    cfg = gnn.GINConfig(name=base.name, n_layers=base.n_layers,
                        d_hidden=base.d_hidden, d_feat=8, n_classes=2,
                        graph_level=True)
    batch = jax.tree.map(jnp.asarray,
                         make_molecule_batch(8, 10, 20, 8, 2, seed=0))
    loss, _ = gnn.loss_batched_graphs(
        gnn.init_gin(jax.random.PRNGKey(0), cfg)[0], batch, cfg)
    assert np.isfinite(float(loss))


_REC = {
    "dlrm-rm2": (recsys.init_dlrm, recsys.dlrm_loss, "next_dlrm", {}),
    "din": (recsys.init_din, recsys.din_loss, "next_seq", {}),
    "sasrec": (recsys.init_sasrec, recsys.sasrec_loss, "next_seq", {}),
    "mind": (recsys.init_mind, recsys.mind_loss, "next_seq",
             {"with_negatives": 8}),
}


@pytest.mark.parametrize("arch_id", REC_IDS)
def test_recsys_smoke_train_step(arch_id):
    spec = ARCHS[arch_id]
    cfg = spec.make_smoke_config()
    init, loss_fn, batch_kind, kw = _REC[arch_id]
    params, _ = init(jax.random.PRNGKey(0), cfg)
    opt = init_optimizer(spec.optimizer, params)
    dcfg = RecsysDataConfig(n_items=cfg.vocab, batch=16,
                            seq_len=getattr(cfg, "seq_len", 12))
    stream = ClickStream(dcfg)
    raw = getattr(stream, batch_kind)(**kw)
    if arch_id == "sasrec":
        raw = {"hist": raw["hist"], "pos": raw["pos"], "neg": raw["neg_seq"]}
    batch = jax.tree.map(jnp.asarray, raw)
    step = jax.jit(make_train_step(loss_fn, cfg, spec.optimizer))
    p2, _, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), arch_id
    assert _finite(p2), arch_id


@pytest.mark.parametrize("arch_id", REC_IDS)
def test_recsys_retrieval_topk(arch_id):
    spec = ARCHS[arch_id]
    cfg = spec.make_smoke_config()
    init, _, batch_kind, kw = _REC[arch_id]
    params, _ = init(jax.random.PRNGKey(0), cfg)
    from repro.configs.cells import _REC_FNS
    user_fn = _REC_FNS[arch_id][3]
    table = _REC_FNS[arch_id][4]
    dcfg = RecsysDataConfig(n_items=cfg.vocab, batch=4,
                            seq_len=getattr(cfg, "seq_len", 12))
    raw = getattr(ClickStream(dcfg), batch_kind)(**kw)
    if arch_id == "sasrec":
        raw = {"hist": raw["hist"]}
    batch = jax.tree.map(jnp.asarray, raw)
    u = user_fn(params, batch, cfg)
    cand = params[table]
    if cand.ndim == 3:
        cand = cand[0]
    scores, ids = recsys.retrieval_topk(u, cand, k=10)
    assert scores.shape == (4, 10) and ids.shape == (4, 10)
    assert bool((np.diff(np.asarray(scores), axis=1) <= 1e-5).all())


def test_all_assigned_archs_registered():
    from repro.configs.registry import ASSIGNED
    for a in ASSIGNED:
        assert a in ARCHS, a
        spec = ARCHS[a]
        assert len(spec.shapes) == 4, a  # four cells each


def test_shape_cells_count_40():
    from repro.configs.registry import ASSIGNED
    cells = [(a, s) for a in ASSIGNED for s in ARCHS[a].shapes]
    assert len(cells) == 40
    skips = [(a, s) for a, s in cells if ARCHS[a].shapes[s].skip]
    # exactly the four pure-full-attention long_500k cells are skipped
    assert sorted(skips) == sorted([
        ("granite-moe-1b-a400m", "long_500k"), ("arctic-480b", "long_500k"),
        ("mistral-nemo-12b", "long_500k"), ("qwen2.5-14b", "long_500k")])
