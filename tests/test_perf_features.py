"""Correctness tests for the §Perf hillclimb features: int8 KV cache,
dst-partitioned GNN aggregation, microbatched gradient accumulation."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cells import make_train_step
from repro.data.graph import make_random_graph, partition_edges_by_dst
from repro.models import gnn, layers as L
from repro.models import transformer as tf
from repro.optim import OptimizerConfig, init_optimizer


def test_int8_kv_cache_matches_fp32():
    cfg = tf.TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv=2,
                               d_head=16, d_ff=128, vocab=97, loss_chunk=8)
    cfg8 = dataclasses.replace(cfg, cache_dtype="int8")
    params, _ = tf.init_lm(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 24), 0, 97)
    lg_f, cache_f = tf.prefill(params, tokens, cfg, max_len=32,
                               cache_dtype=jnp.float32)
    lg_q, cache_q = tf.prefill(params, tokens, cfg8, max_len=32)
    assert cache_q["k"].dtype == jnp.int8
    nxt = jnp.argmax(lg_f, axis=-1)
    d_f, cache_f = tf.decode_step(params, cache_f, nxt, cfg)
    d_q, cache_q = tf.decode_step(params, cache_q, nxt, cfg8)
    rel = float(jnp.abs(d_f[:, :97] - d_q[:, :97]).max()
                / jnp.abs(d_f[:, :97]).max())
    assert rel < 0.05, rel
    assert bool((jnp.argmax(d_f, -1) == jnp.argmax(d_q, -1)).all())


def test_quantize_kv_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(2, 3, 5, 16)).astype(np.float32))
    q, s = L.quantize_kv(x)
    back = L.dequantize_kv(q, s, jnp.float32)
    err = np.abs(np.asarray(back - x))
    bound = np.asarray(s)[..., None] * 0.51 + 1e-6
    assert (err <= bound).all()


def test_partitioned_aggregation_single_device(rng):
    """partitioned path == baseline on one device (no mesh)."""
    cfg = gnn.GINConfig(n_layers=2, d_feat=8, d_hidden=16, n_classes=4)
    cfgp = dataclasses.replace(cfg, partitioned_edges=True)
    g = make_random_graph(64, 256, 8, 4, seed=0)
    params, _ = gnn.init_gin(jax.random.PRNGKey(0), cfg)
    a = gnn.forward_full_graph(params, jnp.asarray(g.feats),
                               jnp.asarray(g.src), jnp.asarray(g.dst), cfg)
    b = gnn.forward_full_graph(params, jnp.asarray(g.feats),
                               jnp.asarray(g.src), jnp.asarray(g.dst), cfgp)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_partition_edges_by_dst_layout():
    g = make_random_graph(100, 1000, 4, 2, seed=1)
    src, dst, dropped = partition_edges_by_dst(g, 4, capacity_factor=2.0)
    assert dropped == 0
    cap = len(src) // 4
    n_local = -(-g.n_nodes // 4)
    for i in range(4):
        d = dst[i * cap : (i + 1) * cap]
        d = d[d >= 0]
        assert ((d // n_local) == i).all()
    # edge multiset preserved
    real = sorted(zip(g.src.tolist(), g.dst.tolist()))
    got = sorted((s, d) for s, d in zip(src.tolist(), dst.tolist()) if s >= 0)
    assert real == got


def test_gradient_accumulation_equivalence():
    """accum=2 must produce (nearly) the same update as accum=1 on the same
    total batch (identical for a linear model / deterministic loss)."""
    cfg = tf.TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv=1,
                               d_head=16, d_ff=64, vocab=50, loss_chunk=8,
                               remat=False)
    params, _ = tf.init_lm(jax.random.PRNGKey(0), cfg)
    oc1 = OptimizerConfig(name="sgd", lr=1e-2, clip_norm=0, accum_steps=1)
    oc2 = dataclasses.replace(oc1, accum_steps=2)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 50),
        "targets": jax.random.randint(jax.random.PRNGKey(3), (8, 16), 0, 50),
        "mask": jnp.ones((8, 16), bool),
    }
    s1 = init_optimizer(oc1, params)
    s2 = init_optimizer(oc2, params)
    p1, _, m1 = jax.jit(make_train_step(tf.loss_fn, cfg, oc1))(params, s1, batch)
    p2, _, m2 = jax.jit(make_train_step(tf.loss_fn, cfg, oc2))(params, s2, batch)
    # micro-batch losses are means over halves; total loss must agree
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)
