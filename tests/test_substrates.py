"""Substrate tests: optimizer, checkpoint/restart, fault tolerance,
straggler watchdog, data pipelines, serving engine, compression."""

import os
import glob

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.graph import NeighborSampler, make_random_graph
from repro.data.lm import LMDataConfig, TokenStream
from repro.data.recsys import ClickStream, RecsysDataConfig
from repro.data.strings import make_dblp, make_workload
from repro.distributed.compression import quantize_int8, dequantize_int8
from repro.distributed.fault_tolerance import (StragglerWatchdog,
                                               TrainSupervisor)
from repro.models.transformer import TransformerConfig, init_lm, loss_fn
from repro.optim import (OptimizerConfig, apply_updates, init_optimizer,
                         lr_at)
from repro.serving import LMServer, Request


# -- optimizer ----------------------------------------------------------------


def _tiny_lm():
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv=1,
                            d_head=16, d_ff=64, vocab=64, loss_chunk=8)
    params, _ = init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgd"])
def test_optimizer_decreases_loss(name):
    cfg, params = _tiny_lm()
    oc = OptimizerConfig(name=name, lr=3e-3, warmup_steps=2, decay_steps=100)
    state = init_optimizer(oc, params)
    stream = TokenStream(LMDataConfig(vocab=64, seq_len=32, global_batch=8))

    @jax.jit
    def step(params, state, batch):
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg)
        p, s, _ = apply_updates(oc, params, g, state)
        return p, s, l

    losses = []
    for _ in range(20):
        batch = jax.tree.map(jnp.asarray, stream.next_batch())
        params, state, l = step(params, state, batch)
        losses.append(float(l))
    assert losses[-1] < losses[0], (name, losses[0], losses[-1])


def test_lr_schedule():
    oc = OptimizerConfig(lr=1.0, warmup_steps=10, decay_steps=110,
                         min_lr_ratio=0.1)
    assert float(lr_at(oc, 0)) == 0.0
    assert abs(float(lr_at(oc, 10)) - 1.0) < 1e-6
    assert float(lr_at(oc, 200)) == pytest.approx(0.1, rel=1e-3)


# -- checkpoint + supervisor ---------------------------------------------------


def test_checkpoint_roundtrip_and_crc(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    _, params = _tiny_lm()
    state = {"params": params, "count": jnp.int32(5)}
    mgr.save(10, state, extra={"data": 10}, block=True)
    mgr.save(20, state, extra={"data": 20}, block=True)
    # corrupt the newest
    victim = sorted(glob.glob(str(tmp_path / "step_00000020" / "*.npy")))[0]
    with open(victim, "wb") as f:
        f.write(b"junk")
    step, restored, extra = mgr.restore(state)
    assert step == 10 and extra == {"data": 10}
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["embed"]),
        np.asarray(params["embed"]))


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.arange(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state, block=True)
    assert mgr.list_steps() == [3, 4]


def test_supervisor_restarts_from_checkpoint(tmp_path):
    """Inject failures; the supervisor must resume from the checkpoint and
    complete all steps."""
    sup = TrainSupervisor(str(tmp_path), ckpt_every=5, max_restarts=5)
    fail_at = {7, 13}

    def step_fn(state, step):
        if step in fail_at:
            fail_at.discard(step)          # fail once per step
            raise RuntimeError("injected node failure")
        return {"x": state["x"] + 1}

    final, report = sup.run(init_state={"x": jnp.int32(0)}, step_fn=step_fn,
                            n_steps=20)
    assert report.restarts == 2
    assert int(final["x"]) == 20  # every step ran exactly once post-restore


def test_straggler_watchdog():
    w = StragglerWatchdog(slack=2.0)
    for i in range(10):
        w.observe(i, 0.1)
    w.observe(10, 0.5)   # 5x the EWMA -> event
    assert len(w.events) == 1
    assert w.events[0]["step"] == 10


# -- data pipelines -------------------------------------------------------------


def test_token_stream_deterministic_resume():
    c = LMDataConfig(seq_len=16, global_batch=2, seed=3)
    a = TokenStream(c)
    for _ in range(4):
        a.next_batch()
    state = a.state()
    want = a.next_batch()
    b = TokenStream(c, start_step=state)
    np.testing.assert_array_equal(b.next_batch()["tokens"], want["tokens"])


def test_neighbor_sampler_edges_exist():
    g = make_random_graph(300, 2000, 8, 4, seed=1)
    samp = NeighborSampler(g, seed=0)
    sub = samp.sample(np.arange(10), (5, 3), n_pad=300, e_pad=500)
    # every sampled edge must be a real (renumbered) graph edge
    real = set(zip(g.src.tolist(), g.dst.tolist()))
    feats = sub["feats"]
    for s, d in zip(sub["src"], sub["dst"]):
        if s < 0:
            continue
        assert (feats[s] != 0).any() or True  # node materialized
    valid = (sub["src"] >= 0).sum()
    assert valid > 0
    assert sub["label_mask"][:10].all()


def test_click_stream_batches():
    c = RecsysDataConfig(n_items=1000, batch=8, seq_len=10)
    s = ClickStream(c)
    b1 = s.next_dlrm()
    assert b1["dense"].shape == (8, 13) and b1["sparse"].shape == (8, 26)
    assert b1["sparse"].max() < 1000
    b2 = s.next_seq(with_negatives=4)
    assert b2["hist"].shape == (8, 10) and b2["neg"].shape == (8, 4)
    # padding is -1 suffix
    assert ((b2["hist"] >= -1) & (b2["hist"] < 1000)).all()


def test_string_workload_queries_hit_index():
    ds = make_dblp(n=300, seed=0)
    qs = make_workload(ds, 50, seed=1)
    from repro.core import CompletionIndex, make_rules
    idx = CompletionIndex.build(ds.strings, ds.scores,
                                make_rules(ds.rules), kind="et")
    res = idx.complete(qs, k=10)
    hit = sum(bool(r) for r in res)
    assert hit / len(qs) > 0.5  # workload mirrors the dictionary


# -- serving ---------------------------------------------------------------------


def test_lm_server_continuous_batching():
    cfg, params = _tiny_lm()
    server = LMServer(params, cfg, n_slots=2, max_len=48)
    for i in range(5):
        server.scheduler.submit(Request(
            rid=i, prompt=np.arange(3 + i) % 64, max_new_tokens=4))
    done = server.run()
    assert len(done) == 5
    assert all(len(r.tokens) == 4 for r in done)
    assert all(max(r.tokens) < 64 for r in done)


def test_lm_server_matches_lockstep_decode():
    """Continuous batching must produce the same tokens as a standalone
    prefill+decode of each request."""
    from repro.models import transformer as tf

    cfg, params = _tiny_lm()
    prompts = [np.arange(4) % 64, (np.arange(6) * 3) % 64]
    # reference: one at a time
    want = []
    for p in prompts:
        logits, cache = tf.prefill(params, jnp.asarray(p)[None], cfg,
                                   max_len=32, cache_dtype=jnp.float32)
        toks = []
        cur = jnp.argmax(logits, -1)
        for _ in range(4):
            toks.append(int(cur[0]))
            logits, cache = tf.decode_step(params, cache, cur, cfg)
            cur = jnp.argmax(logits, -1)
        want.append(toks)
    server = LMServer(params, cfg, n_slots=2, max_len=32)
    for i, p in enumerate(prompts):
        server.scheduler.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    done = sorted(server.run(), key=lambda r: r.rid)
    assert [r.tokens for r in done] == want


# -- compression -----------------------------------------------------------------


def test_int8_quantization_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=256).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x)).max()
    assert err <= float(s) * 0.5 + 1e-7
