"""Kernel-sanitizer tests: the planted fixture corpus, the clean pass
over the real tree, waiver semantics, the CLI gate, and the runtime
mirrors of the static checks (stream asserts, probe tile bounds, and
compile-cache-key completeness)."""

import re
from dataclasses import replace
from pathlib import Path
from types import SimpleNamespace

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.__main__ import main as analysis_main
from repro.analysis.runner import default_root, run_all
from repro.api.compile_cache import CompileCache
from repro.core.engine.structs import EngineConfig
from repro.core.engine.substrate import PallasSubstrate
from repro.kernels.stream import StreamTable, pipelined_dma

FIXTURES = Path(__file__).parent / "fixtures" / "sanitizer"

_PLANT_RE = re.compile(r"#\s*PLANT:\s*([A-Z0-9 ]+?)\s*$")


def _planted() -> set[tuple[str, str, int]]:
    out = set()
    for path in sorted(FIXTURES.glob("*.py")):
        for i, line in enumerate(path.read_text().splitlines(), start=1):
            m = _PLANT_RE.search(line)
            if m:
                for rule in m.group(1).split():
                    out.add((rule, path.name, i))
    return out


# ---------------------------------------------------------------------------
# fixture corpus: every plant reported, nothing else
# ---------------------------------------------------------------------------


def test_fixture_corpus_has_all_rule_families():
    families = {rule[:3] for rule, _, _ in _planted()}
    assert {"DMA", "KEY", "ENV", "TRC"} <= families


def test_every_planted_violation_reported_with_rule_and_location():
    findings = run_all(FIXTURES)
    reported = {(f.rule, f.file, f.line) for f in findings if not f.waived}
    planted = _planted()
    assert planted, "fixture corpus lost its PLANT markers"
    missing = planted - reported
    assert not missing, f"planted violations not reported: {sorted(missing)}"


def test_fixture_corpus_reports_nothing_unplanted():
    findings = run_all(FIXTURES)
    reported = {(f.rule, f.file, f.line) for f in findings if not f.waived}
    extra = reported - _planted()
    assert not extra, f"unplanted findings (analyzer noise): {sorted(extra)}"


def test_each_dma_rule_planted_individually():
    findings = run_all(FIXTURES)
    rules = {f.rule for f in findings}
    for rule in ("DMA001", "DMA002", "DMA003", "DMA004",
                 "KEY001", "KEY002", "KEY003",
                 "ENV001", "ENV002", "ENV003", "ENV004",
                 "TRC001", "TRC002"):
        assert rule in rules, f"rule {rule} never fired on its fixture"


# ---------------------------------------------------------------------------
# clean pass + CLI gate
# ---------------------------------------------------------------------------


def test_real_tree_is_clean():
    findings = [f for f in run_all(default_root()) if not f.waived]
    assert not findings, "sanitizer findings on src/repro:\n" + \
        "\n".join(f.format() for f in findings)


def test_serving_tree_is_scanned_and_clean():
    """The serving layer (scheduler, service, engine) and the mutation
    overlay must be inside the sanitizer's default scan set — a clean
    default pass that silently skipped them would prove nothing."""
    from repro.analysis.astutil import load_tree

    scanned = {sf.rel for sf in load_tree(default_root())}
    for mod in ("serving/scheduler.py", "serving/completion_service.py",
                "serving/engine.py", "core/engine/overlay.py"):
        assert mod in scanned, f"{mod} missing from sanitizer scan set"
    findings = [f for f in run_all(default_root() / "serving")
                if not f.waived]
    assert not findings, "sanitizer findings on src/repro/serving:\n" + \
        "\n".join(f.format() for f in findings)


def test_cli_gate_fails_on_fixtures_and_passes_on_repo(capsys):
    assert analysis_main([str(FIXTURES), "--fail-on-findings"]) == 1
    capsys.readouterr()
    assert analysis_main(["--fail-on-findings"]) == 0
    out = capsys.readouterr().out
    assert "0 finding(s)" in out


# ---------------------------------------------------------------------------
# waivers
# ---------------------------------------------------------------------------


def _write_tree(tmp_path, source: str) -> Path:
    (tmp_path / "mod.py").write_text(source)
    return tmp_path


def test_waiver_with_reason_suppresses(tmp_path):
    root = _write_tree(tmp_path, (
        "from jax.experimental import pallas as pl\n"
        "import jax\n"
        "def f(cond, body, x):\n"
        "    # sanitizer: waive[TRC002] reference path, traced on purpose\n"
        "    return jax.lax.while_loop(cond, body, x)\n"))
    findings = run_all(root)
    assert [f.rule for f in findings] == ["TRC002"]
    assert findings[0].waived


def test_waiver_only_covers_its_rule(tmp_path):
    root = _write_tree(tmp_path, (
        "from jax.experimental import pallas as pl\n"
        "import jax\n"
        "def f(cond, body, x):\n"
        "    # sanitizer: waive[TRC001] wrong rule id\n"
        "    return jax.lax.while_loop(cond, body, x)\n"))
    active = [f for f in run_all(root) if not f.waived]
    assert [f.rule for f in active] == ["TRC002"]


def test_waiver_without_reason_is_itself_reported(tmp_path):
    root = _write_tree(tmp_path, (
        "from jax.experimental import pallas as pl\n"
        "import jax\n"
        "def f(cond, body, x):\n"
        "    # sanitizer: waive[TRC002]\n"
        "    return jax.lax.while_loop(cond, body, x)\n"))
    findings = run_all(root)
    rules = {f.rule: f.waived for f in findings}
    assert rules.get("WAIV01") is False      # active finding
    assert rules.get("TRC002") is True       # still suppressed


# ---------------------------------------------------------------------------
# satellite: compile-cache keys stay complete (one regression per field)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("field,value", [
    ("memory_budget", 1 << 15),
    ("tele_width", 3),
    ("term_width", 3),
    ("walk_tile", 16),
    ("emit_tile", 16),
    ("link_tile", 16),
    ("compression", "packed"),
    ("table_widths", (("c_tout", "uint16"),)),
    ("edit_budget", 1),
    ("branch_width", 4),
])
def test_config_field_changes_produce_distinct_cache_entries(field, value):
    cache = CompileCache(maxsize=8)
    base = EngineConfig()
    changed = replace(base, **{field: value})
    assert base != changed
    cache.get(("batch", 8, 16, 10, base), lambda: object())
    cache.get(("batch", 8, 16, 10, changed), lambda: object())
    assert len(cache) == 2, \
        f"EngineConfig.{field} change reused a stale cache entry"
    assert cache.misses == 2


def test_index_recompiles_when_memory_budget_changes():
    from repro.core import CompletionIndex

    strings = ["alpha", "alphabet", "beta"]
    idx = CompletionIndex.build(strings, [3, 2, 1], [], kind="plain")
    idx.complete(["al"], k=2)
    before = idx._compile_cache.misses
    idx.set_memory_budget(1 << 14)
    idx.complete(["al"], k=2)
    assert idx._compile_cache.misses > before, \
        "memory_budget change did not re-key the compiled entry point"


# ---------------------------------------------------------------------------
# satellite: stream.py runtime asserts mirror the static checks
# ---------------------------------------------------------------------------


def test_pipelined_dma_rejects_traced_trip_count():
    with pytest.raises(TypeError, match="static Python int"):
        pipelined_dma(jnp.int32(4), lambda j, s: [])


def test_stream_table_rejects_non_pow2_width_on_flat_tables():
    hbm = np.zeros((64,), np.int32)
    buf = np.zeros((4, 8), np.int32)
    with pytest.raises(ValueError, match="power of two"):
        StreamTable(hbm, buf, None, width=6)


def test_stream_table_allows_arbitrary_width_row_planes():
    hbm = np.zeros((16, 6), np.int32)                # 2-D plane, width 6
    buf = np.zeros((4, 8), np.int32)
    t = StreamTable(hbm, buf, None, width=6)
    assert t.width == 6


def test_stream_table_rejects_narrow_staging_buffer():
    hbm = np.zeros((64,), np.int32)
    buf = np.zeros((4, 4), np.int32)
    with pytest.raises(ValueError, match="narrower than the window"):
        StreamTable(hbm, buf, None, width=8)


def test_stream_table_rejects_nonpositive_width():
    hbm = np.zeros((64,), np.int32)
    buf = np.zeros((4, 8), np.int32)
    with pytest.raises(ValueError, match="positive"):
        StreamTable(hbm, buf, None, width=0)


def test_stream_windows_reject_more_stages_than_staging_rows():
    hbm = np.zeros((64,), np.int32)
    buf = np.zeros((4, 8), np.int32)
    t = StreamTable(hbm, buf, None, width=8)
    with pytest.raises(ValueError, match="staging row"):
        t.windows(np.zeros((5,), np.int32))


# ---------------------------------------------------------------------------
# satellite: probe bounds mirror the scratch envelope
# ---------------------------------------------------------------------------


def _fake_trie(n: int, rule_free: bool = True) -> SimpleNamespace:
    fields = {}
    for f in (PallasSubstrate._WALK_STREAM_FIELDS
              + PallasSubstrate._WALK_RESIDENT_FIELDS
              + PallasSubstrate._PREFIX_FIELDS
              + PallasSubstrate._BEAM_FIELDS
              + PallasSubstrate._CACHE_FIELDS):
        fields[f] = np.zeros((n,), np.int32)
    fields["s_edge_child"] = np.zeros((0 if rule_free else n,), np.int32)
    return SimpleNamespace(**fields)


def test_budget_is_clamped_to_physical_vmem():
    sub = PallasSubstrate()
    assert sub._budget(EngineConfig(memory_budget=1 << 30)) == \
        PallasSubstrate._VMEM_BYTES
    assert sub._budget(EngineConfig(memory_budget=1 << 10)) == 1 << 10


def test_walk_variant_rejects_oversized_stream_tile():
    sub = PallasSubstrate()
    t = _fake_trie(1 << 20)                          # tables over budget
    cfg = EngineConfig(memory_budget=1 << 10)
    assert sub.walk_variant(t, cfg, 16) == "streamed"
    wide = replace(cfg, walk_tile=PallasSubstrate._STREAM_MAX_TILE * 2)
    assert sub.walk_variant(t, wide, 16) is None
    wide = replace(cfg, link_tile=PallasSubstrate._STREAM_MAX_TILE * 2)
    assert sub.walk_variant(t, wide, 16) is None


def test_beam_variant_rejects_oversized_emit_tile():
    sub = PallasSubstrate()
    t = _fake_trie(1 << 20)
    cfg = EngineConfig(memory_budget=1 << 10)
    assert sub.beam_variant(t, cfg, 10) == "streamed"
    wide = replace(cfg, emit_tile=PallasSubstrate._STREAM_MAX_TILE * 2)
    assert sub.beam_variant(t, wide, 10) is None


def test_fuse_envelope_bounds_rule_plane_widths():
    sub = PallasSubstrate()
    assert sub._fuse_shapes_ok(EngineConfig(), 16)
    assert not sub._fuse_shapes_ok(
        EngineConfig(tele_width=PallasSubstrate._FUSE_MAX_TELEPORTS + 1), 16)
    assert not sub._fuse_shapes_ok(
        EngineConfig(term_width=PallasSubstrate._FUSE_MAX_TERMS + 1), 16)
    # bounded-edit mode: the budget and the dict-fanout window are config
    # symbols that size kernel work; both must be envelope-gated
    assert sub._fuse_shapes_ok(
        EngineConfig(edit_budget=PallasSubstrate._FUSE_MAX_EDITS), 16)
    assert not sub._fuse_shapes_ok(
        EngineConfig(edit_budget=PallasSubstrate._FUSE_MAX_EDITS + 1), 16)
    assert sub._fuse_shapes_ok(
        EngineConfig(branch_width=PallasSubstrate._FUSE_MAX_BRANCH), 16)
    assert not sub._fuse_shapes_ok(
        EngineConfig(branch_width=PallasSubstrate._FUSE_MAX_BRANCH + 1), 16)
