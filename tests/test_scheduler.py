"""Continuous-batching keystroke scheduler: coalesced micro-batch results
must be bit-identical to sequential per-session replay (across substrates
and on-device layouts), deadline flushes must honor the latency budget,
and overload must surface as backpressure instead of unbounded queues."""

import numpy as np
import pytest

from repro.api import IndexSpec, build_index
from repro.core import make_rules
from repro.data.strings import make_keystroke_events, make_usps
from repro.launch.serve import _replay_batched, _replay_sequential
from repro.serving import CompletionService, SchedulerOverloaded
from repro.serving.scheduler import KeystrokeScheduler


@pytest.fixture(scope="module")
def paper_idx():
    strings = ["andrew pavlo", "andrew parker", "andrew packard",
               "william smith", "bill of rights"]
    scores = [50, 40, 30, 20, 10]
    rules = make_rules([("andy", "andrew"), ("bill", "william")])
    return build_index(strings, scores, rules,
                       IndexSpec(kind="et", cache_k=4))


@pytest.fixture(scope="module")
def usps():
    return make_usps(n=400, seed=0)


class FakeClock:
    """Injectable monotonic clock so deadline tests never sleep."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


def _scheduler(index, **kw):
    kw.setdefault("max_wait_ms", 1e6)   # only explicit flushes unless asked
    return KeystrokeScheduler(index, **kw)


# -- determinism vs sequential replay -----------------------------------------


@pytest.mark.parametrize("substrate", ["jnp", "pallas"])
@pytest.mark.parametrize("compression", ["none", "packed"])
def test_batched_bit_identical_to_sequential(usps, substrate, compression):
    """The full serving stack: one interleaved Zipf keystroke stream
    replayed per-keystroke (Session dispatches) and through the
    scheduler's coalesced blocks must produce identical per-keystroke
    top-k, including inexact-fallback lanes."""
    ds = usps
    idx = build_index(ds.strings, ds.scores, make_rules(ds.rules),
                      IndexSpec(kind="et", cache_k=4, substrate=substrate,
                                compression=compression))
    sessions = 4
    events = make_keystroke_events(ds, sessions, n_queries=10, seed=2,
                                   max_len=10)
    seq = CompletionService(idx)
    bat = CompletionService(idx, batching=True, block=sessions,
                            max_wait_ms=100.0, max_queue=8 * sessions)
    assert _replay_sequential(seq, events, sessions, k=5) == \
        _replay_batched(bat, events, sessions, k=5)
    st = bat.scheduler.stats
    assert st.n_keystrokes == sum(c >= 0 for _, c in events)
    assert st.mean_occupancy > 1.0      # keystrokes really coalesced


def test_partial_block_flushes_stay_deterministic(usps):
    """max_wait_ms=0 forces a deadline flush per submit — every block is
    partial, exercising idle-lane padding — results must not change."""
    ds = usps
    idx = build_index(ds.strings, ds.scores, make_rules(ds.rules),
                      IndexSpec(kind="et", cache_k=4))
    sessions = 3
    events = make_keystroke_events(ds, sessions, n_queries=6, seed=5,
                                   max_len=8)
    seq = CompletionService(idx)
    bat = CompletionService(idx, batching=True, block=sessions,
                            max_wait_ms=0.0, max_queue=64)
    assert _replay_sequential(seq, events, sessions, k=5) == \
        _replay_batched(bat, events, sessions, k=5)
    assert bat.scheduler.stats.deadline_flushes > 0


def test_mixed_k_demux_matches_oneshot(paper_idx):
    """Lanes with different k in one flush each get their own batched
    top-k group; every lane must land on the one-shot answer."""
    sched = _scheduler(paper_idx, block=4)
    a, b = sched.open(k=3), sched.open(k=5)
    ta = [a.submit(c, want_topk=(i == 3)) for i, c in enumerate(b"andy")]
    tb = [b.submit(c, want_topk=(i == 3)) for i, c in enumerate(b"bill")]
    sched.drain()
    assert all(t.done for t in ta + tb)
    assert ta[-1].results == paper_idx.complete(["andy"], k=3)[0]
    assert tb[-1].results == paper_idx.complete(["bill"], k=5)[0]
    # advance-only keystrokes resolve without results
    assert ta[0].results is None and ta[0].done
    assert a.topk() == paper_idx.complete(["andy"], k=3)[0]


def test_backspace_reset_and_reopen(paper_idx):
    sched = _scheduler(paper_idx, block=2)
    s = sched.open(k=3)
    assert s.type("andy pa") == paper_idx.complete(["andy pa"], k=3)[0]
    assert s.backspace(3) == paper_idx.complete(["andy"], k=3)[0]
    assert s.prefix == "andy"
    s.reset()
    assert s.type("bill") == paper_idx.complete(["bill"], k=3)[0]
    s.close()
    with pytest.raises(RuntimeError, match="closed"):
        s.submit(b"x")
    # the freed lane is recycled and re-initialized for the next session
    s2 = sched.open(k=3)
    assert s2.lane == s.lane
    assert s2.type("an") == paper_idx.complete(["an"], k=3)[0]


# -- deadline flushes ----------------------------------------------------------


def test_deadline_flush_fires_on_latency_budget(paper_idx):
    clock = FakeClock()
    sched = KeystrokeScheduler(paper_idx, block=2, max_wait_ms=2.0,
                               clock=clock)
    idle = sched.open(k=3)          # occupied lane with nothing queued
    s = sched.open(k=3)
    t = s.submit(b"a")
    # not a full block (idle lane has no keystroke) and the budget has
    # not elapsed: no flush may fire
    assert sched.pump() == 0
    assert sched.stats.n_flushes == 0 and not t.done
    clock.t += 0.0015
    assert sched.pump() == 0        # 1.5ms < 2ms budget
    clock.t += 0.001
    assert sched.pump() == 1        # 2.5ms: deadline flush of a partial block
    assert sched.stats.deadline_flushes == 1
    sched.drain()                   # settle the pipelined demux
    assert t.done
    assert t.results == paper_idx.complete(["a"], k=3)[0]
    assert t.latency_s == pytest.approx(clock.t - t.created)
    idle.close()


def test_full_block_flushes_immediately(paper_idx):
    clock = FakeClock()
    sched = KeystrokeScheduler(paper_idx, block=2, max_wait_ms=1e6,
                               clock=clock)
    a, b = sched.open(k=3), sched.open(k=3)
    a.submit(b"a")
    assert sched.stats.n_flushes == 0       # waiting on lane b
    b.submit(b"b")                          # every occupied lane ready
    assert sched.stats.full_flushes == 1    # fired inside submit's pump


def test_poll_settles_idle_pipeline(paper_idx):
    """The idle-starvation regression: the demux is pipelined one flush
    deep, so after the LAST flush its results sit stashed on device and
    ``pump()`` alone never resolves them (nothing is pending, so no
    further flush fires).  A non-blocking driver looping on pump() and
    checking ticket.done would spin forever; ``poll()`` must settle the
    stash once the queue is empty."""
    clock = FakeClock()
    sched = KeystrokeScheduler(paper_idx, block=2, max_wait_ms=2.0,
                               clock=clock)
    idle = sched.open(k=3)          # keeps the block partial
    s = sched.open(k=3)
    t = s.submit(b"a")
    clock.t += 0.010
    assert sched.pump() == 1        # deadline flush consumed the keystroke
    # the flush computed the result but stashed it: pump() can never
    # finish the job from here
    assert sched.pending == 0 and not t.done
    assert sched.pump() == 0 and not t.done
    assert sched.poll() == 0        # fires nothing...
    assert t.done                   # ...but settles the stashed demux
    assert t.results == paper_idx.complete(["a"], k=3)[0]
    idle.close()


def test_service_poll_delegates(paper_idx):
    """CompletionService.poll() is the event-loop entry point: pump plus
    idle settling in batching mode, a no-op otherwise."""
    svc = CompletionService(paper_idx, batching=True, block=2,
                            max_wait_ms=0.0)
    a, b = svc.open_session(k=3), svc.open_session(k=3)
    ta, tb = a.submit(b"a"), b.submit(b"b")
    svc.pump()                      # consume anything still queued
    assert svc.scheduler.pending == 0
    assert svc.poll() == 0
    assert ta.done and tb.done      # poll settled the pipeline's tail
    a.close(), b.close()
    assert CompletionService(paper_idx).poll() == 0   # unbatched no-op


# -- backpressure --------------------------------------------------------------


def test_admission_queue_backpressure(paper_idx):
    clock = FakeClock()
    sched = KeystrokeScheduler(paper_idx, block=2, max_wait_ms=1e6,
                               max_queue=2, clock=clock)
    idle = sched.open(k=3)          # keeps full-flush from firing
    s = sched.open(k=3)
    s.submit(b"a")
    s.submit(b"n")
    with pytest.raises(SchedulerOverloaded, match="admission queue full"):
        s.submit(b"d")
    assert sched.stats.rejected == 1
    # a rejected submit must not corrupt the session's prefix
    assert s.prefix == "an"
    # one forced flush makes room (one ticket per lane per flush)
    sched.flush()
    t = s.submit(b"d")
    sched.drain()
    assert t.results == paper_idx.complete(["and"], k=3)[0]
    assert s.prefix == "and"
    idle.close()


def test_lane_table_exhaustion(paper_idx):
    sched = _scheduler(paper_idx, block=2)
    a, b = sched.open(k=3), sched.open(k=3)
    with pytest.raises(SchedulerOverloaded, match="lanes"):
        sched.open(k=3)
    a.close()
    c = sched.open(k=3)             # freed lane is reusable
    assert c.lane == a.lane
    b.close()
    c.close()


def test_close_with_queued_keystrokes_defers_release(paper_idx):
    """Closing a session with keystrokes in flight must not force partial
    flushes: the lane drains through normal flushes, then frees."""
    sched = _scheduler(paper_idx, block=2)
    a, b = sched.open(k=3), sched.open(k=3)
    tickets = [a.submit(c) for c in b"an"]
    a.close()
    assert sched._draining[a.lane]          # lane still held by the drain
    out = b.type("bil")                     # normal traffic drains lane a
    sched.drain()
    assert out == paper_idx.complete(["bil"], k=3)[0]
    assert all(t.done for t in tickets)
    assert tickets[-1].results == paper_idx.complete(["an"], k=3)[0]
    assert sched._lanes[a.lane] is None     # release completed
    assert not sched._draining[a.lane]
    b.close()


def test_ready_occupied_counters_track_scans(paper_idx):
    """The O(1) pump counters must agree with full lane scans through a
    mixed open/submit/close/flush workload."""
    sched = _scheduler(paper_idx, block=3)
    def check():
        assert sched._n_ready == len(sched._ready_lanes())
        assert sched._n_occupied == sched._occupied()
    sessions = [sched.open(k=3) for _ in range(3)]
    check()
    sessions[0].submit(b"a"); check()
    sessions[0].submit(b"n"); check()
    sessions[1].submit(b"b"); check()
    sessions[2].submit(b"w"); check()       # full block -> auto flush
    sessions[1].close(); check()
    sched.drain(); check()
    sessions[0].close(); sessions[2].close(); check()
    assert sched._n_occupied == 0 and sched._n_ready == 0


# -- service integration -------------------------------------------------------


def test_service_batched_sessions_share_stats(paper_idx):
    svc = CompletionService(paper_idx, batching=True, block=2,
                            max_wait_ms=100.0)
    a, b = svc.open_session(k=3), svc.open_session(k=3)
    ra = [a.submit(c) for c in b"andy"]
    rb = [b.submit(c) for c in b"bill"]
    svc.drain()
    assert ra[-1].result(svc.scheduler) == \
        paper_idx.complete(["andy"], k=3)[0]
    assert rb[-1].result(svc.scheduler) == \
        paper_idx.complete(["bill"], k=3)[0]
    assert svc.stats.n_keystrokes == 8      # scheduler demux hook fed stats
    assert svc.stats.p99_keystroke_ms() >= svc.stats.p50_keystroke_ms() >= 0
    a.close(); b.close()


def test_unbatched_submit_raises(paper_idx):
    svc = CompletionService(paper_idx)
    sess = svc.open_session(k=3)
    with pytest.raises(RuntimeError, match="batching"):
        sess.submit(b"a")
