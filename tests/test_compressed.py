"""Compressed on-device layout (format v4): bit-identity, persistence
migration, corruption rejection, and the space/tier wins.

The packed layout keeps logical node ids unchanged, so every observable
result — loci, scores, string ids, exactness — must be bit-identical to
the uncompressed layout across the oracle, the jnp reference, and both
pallas tiers (VMEM-resident and DMA-streamed, interpret mode on CPU).
The space side is the acceptance gate of the layout itself: bytes/string
must drop >= 4x and at least one workload must flip from the streamed
tier to resident at an unchanged VMEM budget.
"""

import json

import numpy as np
import pytest

from repro.api import CompletionIndex, IndexSpec, Session, build_index
from repro.core import engine as eng
from repro.core import make_rules
from repro.core.engine import packed as pk
from repro.core.oracle import OracleIndex

KINDS = ["plain", "tt", "et", "ht"]

QUERIES = ["", "a", "ap", "app", "appl", "b", "ban", "c", "j", "jc",
           "jcp", "m", "mid", "midd", "do", "hou", "hound", "z", "q",
           "xyz", "j c", "j c p"]


@pytest.fixture(scope="module")
def corpus():
    strings = ["apple", "application", "apply", "banana", "band",
               "bandana", "cat", "catalog", "dog", "dodge", "middle",
               "midline", "midnight", "j c penney", "jcp", "pennies",
               "zebra", "zebu", "a", "ab"]
    scores = [50, 40, 30, 60, 20, 10, 70, 15, 80, 5, 33, 44, 55, 90, 25,
              35, 12, 8, 3, 99]
    rules = make_rules([("jcp", "j c penney"), ("j c penney", "jcp"),
                        ("mid", "middle"), ("dog", "hound")])
    return strings, scores, rules


@pytest.fixture(scope="module")
def big_corpus():
    """~2000 strings with heavy prefix sharing: big enough that the
    uncompressed index overflows a 1 MiB VMEM budget while the packed
    one fits (the tier-flip regime the benchmark's FLIP_BUDGET row
    measures)."""
    syll = ["an", "ber", "cor", "dal", "el", "fin", "gor", "hal", "in",
            "jor", "kel", "lor", "min", "nor", "ol", "per"]
    rng = np.random.default_rng(7)
    strings = []
    for i in range(2000):
        n = 3 + int(rng.integers(0, 4))
        strings.append("".join(syll[int(j)]
                               for j in rng.integers(0, len(syll), n)))
    strings = sorted(set(strings))
    scores = [int(s) for s in rng.integers(1, 10_000, len(strings))]
    rules = make_rules([("an", "ander"), ("kel", "kelvin")])
    return strings, scores, rules


def _pair(corpus, kind, **kw):
    """(uncompressed, packed) twins of one spec."""
    strings, scores, rules = corpus
    r = rules if kind != "plain" else []
    base = IndexSpec(kind=kind, **kw)
    return (build_index(strings, scores, r, base),
            build_index(strings, scores, r,
                        base.replace(compression="packed")))


# -- bit-identity across substrates and tiers ---------------------------------


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("cache_k", [0, 4])
def test_packed_matches_unpacked_and_oracle(corpus, kind, cache_k):
    ix_u, ix_p = _pair(corpus, kind, cache_k=cache_k)
    ref = ix_u.complete(QUERIES, k=5)
    assert ix_p.complete(QUERIES, k=5) == ref
    strings, scores, rules = corpus
    oracle = OracleIndex(strings, scores, rules if kind != "plain" else [])
    for q, row in zip(QUERIES, ref):
        assert [s for s, _ in row] == \
            [s for s, _ in oracle.complete(q, 5)], q


@pytest.mark.parametrize("kind", KINDS)
def test_packed_parity_on_pallas_tiers(corpus, kind):
    ix_u, _ = _pair(corpus, kind, cache_k=4)
    ref = ix_u.complete(QUERIES, k=5)
    sub = eng.get_substrate("pallas")
    for streamed in (False, True):
        _, ix_p = _pair(corpus, kind, cache_k=4,
                        substrate="pallas")
        if streamed:
            ix_p.set_memory_budget(sub.min_streamed_budget(ix_p.device))
        variant = sub.walk_variant(ix_p.device, ix_p.cfg, 8)
        assert variant == ("streamed" if streamed else "resident")
        assert ix_p.complete(QUERIES, k=5) == ref, (kind, variant)


def test_packed_session_parity(corpus):
    ix_u, ix_p = _pair(corpus, "et", cache_k=4)
    s_u, s_p = Session(ix_u, k=5), Session(ix_p, k=5)
    for ch in "midd":
        expect = s_u.type(ch)
        assert s_p.type(ch) == expect
    assert s_p.backspace() == s_u.backspace()
    assert s_p.topk() == s_u.topk()


def test_packed_device_elides_dense_planes(corpus):
    _, ix_p = _pair(corpus, "ht", cache_k=4)
    t = ix_p.device
    assert pk.is_packed(t)
    # the dense per-node planes ride as zero-size dummies on device
    assert int(t.first_child.shape[0]) == 0
    assert int(t.edge_char.shape[0]) == 0
    # dtype tiers are recorded as static metadata on the config
    assert dict(ix_p.cfg.table_widths)
    assert ix_p.cfg.compression == "packed"


# -- space + tier wins --------------------------------------------------------


@pytest.mark.parametrize("kind", KINDS)
def test_bytes_per_string_drops_4x(big_corpus, kind):
    ix_u, ix_p = _pair(big_corpus, kind)
    ratio = ix_u.stats.bytes_per_string / ix_p.stats.bytes_per_string
    assert ratio >= 4.0, \
        f"{kind}: packed only {ratio:.2f}x smaller " \
        f"({ix_u.stats.bytes_per_string:.0f} -> " \
        f"{ix_p.stats.bytes_per_string:.0f} B/string)"


def test_packed_flips_streamed_to_resident_at_same_budget(big_corpus):
    ix_u, ix_p = _pair(big_corpus, "et", cache_k=0, substrate="pallas")
    sub = eng.get_substrate("pallas")
    du, dp = ix_u.device, ix_p.device
    u_walk_fields = (sub._PREFIX_FIELDS if sub._rule_free(du, ix_u.cfg)
                     else sub._WALK_STREAM_FIELDS
                     + sub._WALK_RESIDENT_FIELDS)
    u_need = min(sub._table_bytes(du, u_walk_fields),
                 sub._table_bytes(du, sub._BEAM_FIELDS))
    p_need = max(
        sub._table_bytes(dp, sub._WALK_STREAM_FIELDS_PACKED
                         + sub._WALK_RESIDENT_FIELDS_PACKED),
        sub._table_bytes(dp, sub._BEAM_FIELDS_PACKED))
    # the layout's whole point: the packed footprint clears the
    # residency bar the uncompressed one misses
    assert p_need < u_need
    budget = (p_need + u_need) // 2
    ix_u.set_memory_budget(budget)
    ix_p.set_memory_budget(budget)
    assert sub.walk_variant(ix_u.device, ix_u.cfg, 8) == "streamed"
    assert sub.walk_variant(ix_p.device, ix_p.cfg, 8) == "resident"
    assert sub.beam_variant(ix_u.device, ix_u.cfg, 5) == "streamed"
    assert sub.beam_variant(ix_p.device, ix_p.cfg, 5) == "resident"


# -- persistence: v4 round-trip + v1/v2/v3 migration --------------------------


def _rewrite(path, version, request_packed):
    """Stamp a saved (uncompressed) container as an older format and
    optionally flip its spec to ask for compression — the load path must
    re-pack it to the v4 layout on the fly."""
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    meta = json.loads(arrays["__meta__"].tobytes().decode())
    meta["format_version"] = version
    if version < 2:   # pre-rule-plane container shape
        for k in ("trie__tele_plane", "trie__link_ptr",
                  "rule_trie__term_plane"):
            arrays.pop(k, None)
        for key in ("tele_width", "term_width"):
            meta["cfg"].pop(key, None)
    if request_packed:
        meta["spec"]["compression"] = "packed"
        # the stale cfg keeps its uncompressed identity: the on-load
        # re-pack must recompute the dtype tiers itself
        meta["cfg"]["compression"] = "none"
        meta["cfg"]["table_widths"] = []
    arrays["__meta__"] = np.frombuffer(json.dumps(meta).encode(),
                                       dtype=np.uint8)
    np.savez_compressed(path, **arrays)


@pytest.mark.parametrize("version,kind", [
    (1, "ht"), (1, "tt"), (2, "et"), (3, "ht"), (3, "et"),
])
def test_old_container_repacks_to_v4_on_load(corpus, tmp_path,
                                             version, kind):
    ix_u, ix_p = _pair(corpus, kind, cache_k=4)
    ref = ix_u.complete(QUERIES, k=5)
    path = str(tmp_path / "idx.npz")
    ix_u.save(path)
    _rewrite(path, version, request_packed=True)
    loaded = CompletionIndex.load(path)
    assert loaded.cfg.compression == "packed"
    assert loaded.trie.has_packed
    assert loaded.cfg.table_widths == ix_p.cfg.table_widths
    assert loaded.complete(QUERIES, k=5) == ref


@pytest.mark.parametrize("kind", KINDS)
def test_packed_save_load_roundtrip(corpus, tmp_path, kind):
    ix_u, ix_p = _pair(corpus, kind, cache_k=4)
    ref = ix_u.complete(QUERIES, k=5)
    path = str(tmp_path / "packed.npz")
    ix_p.save(path)
    loaded = CompletionIndex.load(path)
    assert loaded.cfg.compression == "packed"
    assert loaded.cfg.table_widths == ix_p.cfg.table_widths
    assert loaded.complete(QUERIES, k=5) == ref
    for substrate in ("jnp", "pallas"):
        assert loaded.set_substrate(substrate).complete(QUERIES, k=5) \
            == ref


def test_packed_container_elides_dense_planes(corpus, tmp_path):
    ix_u, ix_p = _pair(corpus, "ht", cache_k=4)
    pu, pp = str(tmp_path / "u.npz"), str(tmp_path / "p.npz")
    ix_u.save(pu)
    ix_p.save(pp)
    with np.load(pp) as z:
        names = set(z.files)
    assert "trie__p_labels" in names
    assert "trie__first_child" not in names
    assert "trie__emit_node" not in names
    import os
    assert os.path.getsize(pp) < os.path.getsize(pu)


# -- corruption / width-mismatch rejection ------------------------------------


def _tamper(path, fn):
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    fn(arrays)
    np.savez_compressed(path, **arrays)


def _saved_packed(corpus, tmp_path):
    _, ix_p = _pair(corpus, "ht", cache_k=4)
    path = str(tmp_path / "packed.npz")
    ix_p.save(path)
    return path


def test_load_rejects_truncated_side_table(corpus, tmp_path):
    path = _saved_packed(corpus, tmp_path)
    _tamper(path, lambda a: a.update(
        trie__c_tout=a["trie__c_tout"][:-1]))
    with pytest.raises(ValueError, match="side column length"):
        CompletionIndex.load(path)


def test_load_rejects_unsorted_packed_ids(corpus, tmp_path):
    path = _saved_packed(corpus, tmp_path)

    def swap(a):
        ids = a["trie__c_ids"].copy()
        assert len(ids) >= 2
        ids[0], ids[1] = ids[1], ids[0]
        a["trie__c_ids"] = ids
    _tamper(path, swap)
    with pytest.raises(ValueError, match="not sorted"):
        CompletionIndex.load(path)


def test_load_rejects_dtype_tier_mismatch(corpus, tmp_path):
    path = _saved_packed(corpus, tmp_path)

    def widen(a):
        meta = json.loads(a["__meta__"].tobytes().decode())
        widths = dict(meta["cfg"]["table_widths"])
        assert "c_escore" in widths
        widths["c_escore"] = "int32"      # array on disk stays narrow
        meta["cfg"]["table_widths"] = sorted(widths.items())
        a["__meta__"] = np.frombuffer(json.dumps(meta).encode(),
                                      dtype=np.uint8)
    _tamper(path, widen)
    with pytest.raises(ValueError, match="width mismatch"):
        CompletionIndex.load(path)


def test_build_rejects_unknown_compression():
    with pytest.raises(ValueError, match="compression"):
        IndexSpec(kind="et", compression="zip").validate()


# -- distributed: packed shards are rejected, not silently broken -------------


def test_stack_shards_rejects_packed(corpus):
    from repro.core.distributed import stack_shards

    _, ix_p = _pair(corpus, "et")
    with pytest.raises(NotImplementedError, match="packed"):
        stack_shards([ix_p, ix_p])
