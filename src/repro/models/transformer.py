"""Decoder-only transformer: dense / GQA / SWA / MoE variants.

Covers all five assigned LM architectures from one config dataclass:
granite-moe-1b-a400m (MoE 32e top-8), arctic-480b (MoE 128e top-2 + dense
residual), mistral-nemo-12b, h2o-danube-1.8b (SWA), qwen2.5-14b (QKV bias).

Layers are stacked with a leading L dim and executed with lax.scan (small
HLO, fast compile at 48 layers); each layer body can be rematerialized.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed import sharding as sh
from repro.models import layers as L


@dataclass(frozen=True)
class TransformerConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv: int = 2
    d_head: int = 32
    d_ff: int = 256
    vocab: int = 256
    qkv_bias: bool = False
    window: int | None = None          # sliding-window attention
    moe_experts: int = 0               # 0 = dense
    moe_top_k: int = 0
    moe_dense_residual: bool = False   # arctic-style parallel dense FFN
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    remat: bool = True
    aux_loss_weight: float = 0.01
    loss_chunk: int = 512
    param_dtype: str = "float32"
    vocab_pad_to: int = 128    # table rows padded for even vocab sharding
    tp_heads: int = 1          # model-axis size for the padded head layout
    activation_dtype: str = "float32"   # full configs use bfloat16
    cache_dtype: str = "bfloat16"        # serving KV cache; "int8" = KIVI-
                                         # style quantized cache (§Perf)
    seq_parallel: bool = True  # Megatron-SP: residual stream sharded over
                               # `model` between layers (memory: carry/tp)

    @property
    def padded_vocab(self) -> int:
        m = max(self.vocab_pad_to, 1)
        return (self.vocab + m - 1) // m * m

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    def param_count(self) -> int:
        d, f = self.d_model, self.d_ff
        attn = 2 * d * self.d_head * (self.n_heads + self.n_kv)
        if self.is_moe:
            ffn = 3 * d * f * self.moe_experts + d * self.moe_experts
            if self.moe_dense_residual:
                ffn += 3 * d * f
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d

    def active_param_count(self) -> int:
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        attn = 2 * d * self.d_head * (self.n_heads + self.n_kv)
        ffn = 3 * d * f * self.moe_top_k + d * self.moe_experts
        if self.moe_dense_residual:
            ffn += 3 * d * f
        per_layer = attn + ffn + 2 * d
        return self.n_layers * per_layer + 2 * self.vocab * d + d


def init_layer(key, cfg: TransformerConfig):
    ks = jax.random.split(key, 4)
    attn_p, attn_a = L.init_attention(ks[0], cfg.d_model, cfg.n_heads,
                                      cfg.n_kv, cfg.d_head, cfg.qkv_bias,
                                      tp=cfg.tp_heads)
    p = {"attn": attn_p,
         "ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,))}
    a = {"attn": attn_a, "ln1": (None,), "ln2": (None,)}
    if cfg.is_moe:
        p["moe"], a["moe"] = L.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                        cfg.moe_experts)
        if cfg.moe_dense_residual:
            p["mlp"], a["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff)
    else:
        p["mlp"], a["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff)
    return p, a


def init_lm(key, cfg: TransformerConfig):
    """Returns (params, logical-axes tree). Layer params are stacked [L, ...]."""
    k_embed, k_layers, k_out = jax.random.split(key, 3)
    dtype = jnp.dtype(cfg.param_dtype)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    p_layers = jax.vmap(lambda k: init_layer(k, cfg)[0])(layer_keys)
    _, a_layer = init_layer(k_layers, cfg)
    a_layers = jax.tree.map(lambda ax: (None,) + ax, a_layer,
                            is_leaf=lambda x: isinstance(x, tuple))
    vp = cfg.padded_vocab
    params = {
        "embed": jax.random.normal(k_embed, (vp, cfg.d_model)) * 0.02,
        "layers": p_layers,
        "final_ln": jnp.ones((cfg.d_model,)),
        "unembed": jax.random.normal(k_out, (cfg.d_model, vp)) * 0.02,
    }
    params = jax.tree.map(lambda x: x.astype(dtype), params)
    axes = {
        "embed": ("vocab", "fsdp"),
        "layers": a_layers,
        "final_ln": (None,),
        "unembed": ("fsdp", "vocab"),
    }
    return params, axes


def _seq_constrain(x, cfg: TransformerConfig):
    """Sequence-parallel residual stream: the per-layer carry (the only
    tensor the remat'd scan saves) is sharded over `model`, cutting saved-
    activation memory by tp at the cost of one gather per layer."""
    mesh = sh.current_mesh()
    if (cfg.seq_parallel and mesh is not None and x.ndim == 3
            and sh.model_size(mesh) > 1
            and x.shape[1] % sh.model_size(mesh) == 0 and x.shape[1] > 1):
        return sh.constrain(x, "batch", "seq", None)
    return sh.constrain(x, "batch", None, None)


def _layer_fwd(cfg: TransformerConfig, x, lp, positions):
    h, _ = L.attention(lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
                       n_heads=cfg.n_heads, n_kv=cfg.n_kv,
                       rope_theta=cfg.rope_theta, window=cfg.window,
                       positions=positions, tp=cfg.tp_heads)
    x = x + h
    hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    aux = jnp.float32(0)
    if cfg.is_moe:
        mo, aux = L.moe_ffn(lp["moe"], hn, n_experts=cfg.moe_experts,
                            top_k=cfg.moe_top_k)
        if cfg.moe_dense_residual:
            mo = mo + L.mlp(lp["mlp"], hn)
        x = x + mo
    else:
        x = x + L.mlp(lp["mlp"], hn)
    x = x.astype(jnp.dtype(cfg.activation_dtype))
    return _seq_constrain(x, cfg), aux


def forward(params, tokens, cfg: TransformerConfig):
    """tokens [B, S] -> final hidden [B, S, D] (+ mean aux loss)."""
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens)
    x = _seq_constrain(x.astype(jnp.dtype(cfg.activation_dtype)), cfg)
    positions = jnp.arange(S)[None, :]

    body = partial(_layer_fwd, cfg)
    if cfg.remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    def scan_fn(x, lp):
        x, aux = body(x, lp, positions)
        return x, aux

    x, auxes = jax.lax.scan(scan_fn, x, params["layers"])
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    return x, auxes.mean()


def loss_fn(params, batch, cfg: TransformerConfig):
    """batch: dict(tokens [B,S], targets [B,S], mask [B,S])."""
    x, aux = forward(params, batch["tokens"], cfg)
    nll = L.xent_loss_chunked(x, params["unembed"], batch["targets"],
                              batch.get("mask"), chunk=cfg.loss_chunk,
                              vocab_real=cfg.vocab)
    loss = nll + cfg.aux_loss_weight * aux
    return loss, {"nll": nll, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: TransformerConfig, batch: int, max_len: int,
               dtype=None):
    span = min(max_len, cfg.window) if cfg.window else max_len
    shape = (cfg.n_layers, batch, cfg.n_kv, span, cfg.d_head)
    quantized = cfg.cache_dtype == "int8"
    dtype = dtype if dtype is not None else (
        jnp.int8 if quantized else jnp.dtype(cfg.cache_dtype))
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
             "pos": jnp.zeros((), jnp.int32)}
    if quantized and dtype == jnp.int8:
        cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
        cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
    return cache


def cache_axes(quantized: bool = False):
    # sequence-sharded cache (flash-decoding, layers._flash_decode_sharded)
    out = {"k": (None, "batch", None, "seq", None),
           "v": (None, "batch", None, "seq", None), "pos": ()}
    if quantized:
        out["k_scale"] = (None, "batch", None, "seq")
        out["v_scale"] = (None, "batch", None, "seq")
    return out


def _mask_pad_vocab(logits, cfg: TransformerConfig):
    if cfg.padded_vocab == cfg.vocab:
        return logits
    cols = jnp.arange(logits.shape[-1])
    return jnp.where(cols[None, :] < cfg.vocab, logits, -1e30)


def _layer_decode(cfg: TransformerConfig, x, lp, cache_layer, pos):
    positions = pos[:, None] if jnp.ndim(pos) else pos[None, None]
    h, new_cache = L.attention(
        lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, rope_theta=cfg.rope_theta,
        window=cfg.window, positions=positions,
        cache=cache_layer, cache_pos=pos, tp=cfg.tp_heads)
    x = x + h
    hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        mo, _ = L.moe_ffn(lp["moe"], hn, n_experts=cfg.moe_experts,
                          top_k=cfg.moe_top_k)
        if cfg.moe_dense_residual:
            mo = mo + L.mlp(lp["mlp"], hn)
        x = x + mo
    else:
        x = x + L.mlp(lp["mlp"], hn)
    return x, new_cache


def decode_step(params, cache, tokens, cfg: TransformerConfig):
    """tokens [B] -> (logits [B, V], new cache). One decode position.

    cache["pos"] may be a scalar (lockstep decode) or an int32[B] vector of
    per-slot positions (continuous batching)."""
    x = L.embed_lookup(params["embed"], tokens[:, None])
    pos = cache["pos"]
    quantized = "k_scale" in cache

    def scan_fn(x, lp_kv):
        if quantized:
            lp, ck, cv, ksc, vsc = lp_kv
            x, nc = _layer_decode(cfg, x, lp, (ck, cv, ksc, vsc), pos)
        else:
            lp, ck, cv = lp_kv
            x, nc = _layer_decode(cfg, x, lp, (ck, cv), pos)
        return x, nc

    xs = (params["layers"], cache["k"], cache["v"])
    if quantized:
        xs = xs + (cache["k_scale"], cache["v_scale"])
    x, ncs = jax.lax.scan(scan_fn, x, xs)
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = (x[:, 0] @ params["unembed"]).astype(jnp.float32)
    logits = _mask_pad_vocab(logits, cfg)
    logits = sh.constrain(logits, "batch", "vocab")
    new_cache = {"k": ncs[0], "v": ncs[1], "pos": pos + 1}
    if quantized:
        new_cache["k_scale"] = ncs[2]
        new_cache["v_scale"] = ncs[3]
    return logits, new_cache


def prefill(params, tokens, cfg: TransformerConfig, max_len: int,
            cache_dtype=jnp.bfloat16):
    """Run the prompt, build the KV cache, return last-position logits."""
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens)
    x = _seq_constrain(x.astype(jnp.dtype(cfg.activation_dtype)), cfg)
    positions = jnp.arange(S)[None, :]
    cache = init_cache(cfg, B, max_len, cache_dtype)
    span = cache["k"].shape[3]

    def scan_fn(x, lp):
        h, (k, v) = L.attention(
            lp["attn"], L.rms_norm(x, lp["ln1"], cfg.norm_eps),
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, rope_theta=cfg.rope_theta,
            window=cfg.window, positions=positions, tp=cfg.tp_heads)
        x = x + h
        hn = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            mo, _ = L.moe_ffn(lp["moe"], hn, n_experts=cfg.moe_experts,
                              top_k=cfg.moe_top_k)
            if cfg.moe_dense_residual:
                mo = mo + L.mlp(lp["mlp"], hn)
            x = x + mo
        else:
            x = x + L.mlp(lp["mlp"], hn)
        x = _seq_constrain(x.astype(jnp.dtype(cfg.activation_dtype)), cfg)
        # keep the last `span` positions, placed at slot = position % span
        # (ring layout for SWA; identity when S <= span)
        if S < span:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, span - S), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, span - S), (0, 0)))
            ck, cv = k, v
        else:
            ck = jnp.roll(k[:, :, -span:, :], shift=S % span, axis=2)
            cv = jnp.roll(v[:, :, -span:, :], shift=S % span, axis=2)
        return x, (ck.astype(cache_dtype), cv.astype(cache_dtype))

    x, (ks, vs) = jax.lax.scan(scan_fn, x, params["layers"])
    x = L.rms_norm(x, params["final_ln"], cfg.norm_eps)
    logits = (x[:, -1] @ params["unembed"]).astype(jnp.float32)
    logits = _mask_pad_vocab(logits, cfg)
    cache = {"k": ks, "v": vs, "pos": jnp.full((), S, jnp.int32)}
    if cfg.cache_dtype == "int8" and ks.dtype != jnp.int8:
        kq, ksc = jax.vmap(L.quantize_kv)(ks)
        vq, vsc = jax.vmap(L.quantize_kv)(vs)
        cache = {"k": kq, "v": vq, "k_scale": ksc, "v_scale": vsc,
                 "pos": cache["pos"]}
    return sh.constrain(logits, "batch", "vocab"), cache
