"""RecSys models: DLRM (dot interaction), DIN (target attention), SASRec
(self-attentive sequential), MIND (multi-interest capsule routing).

The hot path is the huge sparse embedding lookup. JAX has no EmbeddingBag /
CSR — lookups are built from take + segment_sum (kernels/embedding_bag.py is
the Pallas version). Tables are ROW-sharded over the `model` axis: each
shard gathers the ids it owns and one psum combines (shard_map island);
`retrieval_topk` shards candidates over `model` with a local top-k +
all_gather merge (same machinery as the paper's distributed trie merge).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh

# ---------------------------------------------------------------------------
# sharded embedding lookup
# ---------------------------------------------------------------------------


def embedding_lookup(table, ids):
    """table [V, D] row-sharded over `model`; ids int32[...] -> [..., D]."""
    mesh = sh.current_mesh()
    if (mesh is None or "model" not in mesh.axis_names or mesh.size == 1
            or table.shape[0] % max(sh.model_size(mesh), 1) != 0):
        return jnp.take(table, ids, axis=0)
    dp = sh.dp_axes(mesh) if ids.shape[0] % max(sh.dp_size(mesh), 1) == 0 \
        else ()
    id_spec = P(dp if dp else None, *([None] * (ids.ndim - 1)))

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(P("model", None), id_spec),
             out_specs=P(dp if dp else None, *([None] * ids.ndim)),
             check_vma=False)
    def run(tab_l, ids_l):
        v_l = tab_l.shape[0]
        off = jax.lax.axis_index("model") * v_l
        loc = ids_l - off
        ok = (loc >= 0) & (loc < v_l)
        e = jnp.take(tab_l, jnp.clip(loc, 0, v_l - 1), axis=0)
        e = e * ok[..., None]
        return jax.lax.psum(e, "model")

    return run(table, ids)


def stacked_embedding_lookup(tables, ids):
    """tables [F, V, D] row-sharded; ids int32[B, F] -> [B, F, D]."""
    mesh = sh.current_mesh()
    if (mesh is None or "model" not in mesh.axis_names or mesh.size == 1
            or tables.shape[1] % max(sh.model_size(mesh), 1) != 0):
        return jax.vmap(lambda t, i: t[i], in_axes=(0, 1), out_axes=1)(tables, ids)
    dp = sh.dp_axes(mesh) if ids.shape[0] % max(sh.dp_size(mesh), 1) == 0 \
        else None

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(P(None, "model", None), P(dp, None)),
             out_specs=P(dp, None, None), check_vma=False)
    def run(tab_l, ids_l):
        v_l = tab_l.shape[1]
        off = jax.lax.axis_index("model") * v_l
        loc = ids_l - off                                    # [B, F]
        ok = (loc >= 0) & (loc < v_l)
        gather = jax.vmap(lambda t, i: t[i], in_axes=(0, 1), out_axes=1)
        e = gather(tab_l, jnp.clip(loc, 0, v_l - 1))          # [B, F, D]
        e = e * ok[..., None]
        return jax.lax.psum(e, "model")

    return run(tables, ids)


def retrieval_topk(user, cand, k: int):
    """user [B, D] (or [B, K, D] multi-interest); cand [C, D] sharded over
    `model`. Returns (scores [B, k], ids [B, k])."""
    multi = user.ndim == 3
    mesh = sh.current_mesh()

    def score(u, c):
        s = jnp.einsum("bd,cd->bc", u, c) if not multi else \
            jnp.einsum("bkd,cd->bkc", u, c).max(axis=1)
        return s

    if (mesh is None or "model" not in mesh.axis_names or mesh.size == 1
            or cand.shape[0] % max(sh.model_size(mesh), 1) != 0):
        s = score(user, cand)
        top, idx = jax.lax.top_k(s, k)
        return top, idx.astype(jnp.int32)

    dp = sh.dp_axes(mesh) if user.shape[0] % max(sh.dp_size(mesh), 1) == 0 \
        else None
    u_spec = P(dp, *([None] * (user.ndim - 1)))

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(u_spec, P("model", None)),
             out_specs=(P(dp, None), P(dp, None)), check_vma=False)
    def run(u_l, c_l):
        s = score(u_l, c_l)
        top, idx = jax.lax.top_k(s, k)
        off = jax.lax.axis_index("model") * c_l.shape[0]
        gids = idx.astype(jnp.int32) + off
        all_s = jax.lax.all_gather(top, "model")   # [S, b, k]
        all_i = jax.lax.all_gather(gids, "model")
        S = all_s.shape[0]
        fs = jnp.moveaxis(all_s, 0, 1).reshape(top.shape[0], S * k)
        fi = jnp.moveaxis(all_i, 0, 1).reshape(top.shape[0], S * k)
        ts, ti = jax.lax.top_k(fs, k)
        return ts, jnp.take_along_axis(fi, ti, axis=1)

    return run(user, cand)


def _mlp_init(key, dims, scale=None):
    ks = jax.random.split(key, len(dims) - 1)
    ws, bs = [], []
    for i, kk in enumerate(ks):
        s = scale or (dims[i] ** -0.5)
        ws.append(jax.random.normal(kk, (dims[i], dims[i + 1])) * s)
        bs.append(jnp.zeros((dims[i + 1],)))
    return {"w": ws, "b": bs}


def _mlp_axes(dims):
    return {"w": [(None, None)] * (len(dims) - 1),
            "b": [(None,)] * (len(dims) - 1)}


def _mlp_apply(p, x, final_act=False):
    n = len(p["w"])
    for i in range(n):
        x = x @ p["w"][i] + p["b"][i]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def _bce(logit, label):
    return jnp.mean(
        jnp.maximum(logit, 0) - logit * label
        + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# ---------------------------------------------------------------------------
# DLRM  [arXiv:1906.00091]
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    d_embed: int = 64
    vocab: int = 1_000_000
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 512, 256, 1)


def init_dlrm(key, cfg: DLRMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    n_inter = (cfg.n_sparse + 1) * cfg.n_sparse // 2
    top_in = n_inter + cfg.bot_mlp[-1]
    params = {
        "tables": jax.random.normal(
            k1, (cfg.n_sparse, cfg.vocab, cfg.d_embed)) * (cfg.d_embed ** -0.5),
        "bot": _mlp_init(k2, (cfg.n_dense,) + cfg.bot_mlp),
        "top": _mlp_init(k3, (top_in,) + cfg.top_mlp),
    }
    axes = {
        "tables": (None, "rows", None),
        "bot": _mlp_axes((cfg.n_dense,) + cfg.bot_mlp),
        "top": _mlp_axes((top_in,) + cfg.top_mlp),
    }
    return params, axes


def dlrm_forward(params, batch, cfg: DLRMConfig):
    dense = sh.constrain(batch["dense"], "batch", None)
    d = _mlp_apply(params["bot"], dense, final_act=True)       # [B, 64]
    e = stacked_embedding_lookup(params["tables"], batch["sparse"])
    z = jnp.concatenate([d[:, None, :], e], axis=1)            # [B, 27, D]
    inter = jnp.einsum("bif,bjf->bij", z, z)
    iu, ju = np.triu_indices(z.shape[1], k=1)
    tri = inter[:, iu, ju]                                     # [B, 351]
    x = jnp.concatenate([d, tri], axis=1)
    logit = _mlp_apply(params["top"], x)[:, 0]
    return sh.constrain(logit, "batch")


def dlrm_loss(params, batch, cfg: DLRMConfig):
    logit = dlrm_forward(params, batch, cfg)
    loss = _bce(logit, batch["label"].astype(jnp.float32))
    return loss, {"logit_mean": logit.mean()}


def dlrm_user_embedding(params, batch, cfg: DLRMConfig):
    return _mlp_apply(params["bot"], batch["dense"], final_act=True)


# ---------------------------------------------------------------------------
# DIN  [arXiv:1706.06978]
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    d_embed: int = 18
    seq_len: int = 100
    vocab: int = 1_000_000
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)


def init_din(key, cfg: DINConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_embed
    params = {
        "items": jax.random.normal(k1, (cfg.vocab, d)) * (d ** -0.5),
        "attn": _mlp_init(k2, (4 * d,) + cfg.attn_mlp + (1,)),
        "out": _mlp_init(k3, (3 * d,) + cfg.mlp + (1,)),
    }
    axes = {
        "items": ("rows", None),
        "attn": _mlp_axes((4 * d,) + cfg.attn_mlp + (1,)),
        "out": _mlp_axes((3 * d,) + cfg.mlp + (1,)),
    }
    return params, axes


def din_user_embedding(params, batch, cfg: DINConfig):
    e_h = embedding_lookup(params["items"], batch["hist"])      # [B, T, D]
    e_t = embedding_lookup(params["items"], batch["target"])    # [B, D]
    et = jnp.broadcast_to(e_t[:, None, :], e_h.shape)
    a_in = jnp.concatenate([e_h, et, e_h - et, e_h * et], axis=-1)
    a = _mlp_apply(params["attn"], a_in)[..., 0]                # [B, T]
    a = jnp.where(batch["hist"] >= 0, a, -1e30)
    a = jax.nn.sigmoid(a) * (batch["hist"] >= 0)                # DIN: no softmax
    return (a[..., None] * e_h).sum(axis=1), e_t                # [B, D]


def din_forward(params, batch, cfg: DINConfig):
    user, e_t = din_user_embedding(params, batch, cfg)
    x = jnp.concatenate([user, e_t, user * e_t], axis=-1)
    return _mlp_apply(params["out"], x)[:, 0]


def din_loss(params, batch, cfg: DINConfig):
    logit = din_forward(params, batch, cfg)
    return _bce(logit, batch["label"].astype(jnp.float32)), {}


# ---------------------------------------------------------------------------
# SASRec  [arXiv:1808.09781]
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SASRecConfig:
    name: str = "sasrec"
    d_embed: int = 50
    n_blocks: int = 2
    n_heads: int = 1
    seq_len: int = 50
    vocab: int = 1_000_000


def init_sasrec(key, cfg: SASRecConfig):
    ks = jax.random.split(key, 2 + 4 * cfg.n_blocks)
    d = cfg.d_embed
    blocks = []
    for i in range(cfg.n_blocks):
        kq, kv, k1, k2 = ks[2 + 4 * i : 6 + 4 * i]
        blocks.append({
            "wqkv": jax.random.normal(kq, (d, 3 * d)) * (d ** -0.5),
            "wo": jax.random.normal(kv, (d, d)) * (d ** -0.5),
            "ln1": jnp.ones((d,)), "ln2": jnp.ones((d,)),
            "w1": jax.random.normal(k1, (d, d)) * (d ** -0.5),
            "b1": jnp.zeros((d,)),
            "w2": jax.random.normal(k2, (d, d)) * (d ** -0.5),
            "b2": jnp.zeros((d,)),
        })
    params = {
        "items": jax.random.normal(ks[0], (cfg.vocab, d)) * (d ** -0.5),
        "pos": jax.random.normal(ks[1], (cfg.seq_len, d)) * 0.02,
        "blocks": blocks,
    }
    axes = {
        "items": ("rows", None),
        "pos": (None, None),
        "blocks": [{k: tuple([None] * np.ndim(v)) for k, v in b.items()}
                   for b in blocks],
    }
    return params, axes


def _ln(x, w):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * w


def sasrec_hidden(params, hist, cfg: SASRecConfig):
    """hist int32[B, T] (-1 pad) -> hidden states [B, T, D]."""
    B, T = hist.shape
    d = cfg.d_embed
    mask = hist >= 0
    h = embedding_lookup(params["items"], jnp.maximum(hist, 0)) * np.sqrt(d)
    h = h + params["pos"][None, :T]
    h = h * mask[..., None]
    causal = jnp.tril(jnp.ones((T, T), bool))
    for blk in params["blocks"]:
        q, k, v = jnp.split(_ln(h, blk["ln1"]) @ blk["wqkv"], 3, axis=-1)
        s = jnp.einsum("bqd,bkd->bqk", q, k) / np.sqrt(d)
        s = jnp.where(causal[None] & mask[:, None, :], s, -1e30)
        att = jax.nn.softmax(s, axis=-1)
        h = h + (jnp.einsum("bqk,bkd->bqd", att, v) @ blk["wo"])
        hn = _ln(h, blk["ln2"])
        h = h + jax.nn.relu(hn @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
        h = h * mask[..., None]
    return h


def sasrec_loss(params, batch, cfg: SASRecConfig):
    """batch: hist [B,T], pos [B,T], neg [B,T] (next-item targets + negatives)."""
    h = sasrec_hidden(params, batch["hist"], cfg)
    e_p = embedding_lookup(params["items"], jnp.maximum(batch["pos"], 0))
    e_n = embedding_lookup(params["items"], jnp.maximum(batch["neg"], 0))
    m = (batch["pos"] >= 0).astype(jnp.float32)
    lp = jnp.einsum("btd,btd->bt", h, e_p)
    ln_ = jnp.einsum("btd,btd->bt", h, e_n)
    loss = -(jax.nn.log_sigmoid(lp) + jax.nn.log_sigmoid(-ln_)) * m
    return loss.sum() / jnp.maximum(m.sum(), 1), {}


def sasrec_user_embedding(params, batch, cfg: SASRecConfig):
    h = sasrec_hidden(params, batch["hist"], cfg)
    return h[:, -1]


# ---------------------------------------------------------------------------
# MIND  [arXiv:1904.08030]
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    d_embed: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    seq_len: int = 50
    vocab: int = 1_000_000
    pow_p: float = 2.0


def init_mind(key, cfg: MINDConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_embed
    params = {
        "items": jax.random.normal(k1, (cfg.vocab, d)) * (d ** -0.5),
        "S": jax.random.normal(k2, (d, d)) * (d ** -0.5),   # shared bilinear
        "b_init": jax.random.normal(k3, (cfg.seq_len, cfg.n_interests)) * 1.0,
    }
    axes = {"items": ("rows", None), "S": (None, None), "b_init": (None, None)}
    return params, axes


def _squash(x):
    n2 = jnp.sum(x * x, axis=-1, keepdims=True)
    return (n2 / (1 + n2)) * x * jax.lax.rsqrt(n2 + 1e-9)


def mind_interests(params, hist, cfg: MINDConfig):
    """Dynamic routing (behavior -> interest capsules). hist [B,T] -> [B,K,D]."""
    mask = (hist >= 0)
    e = embedding_lookup(params["items"], jnp.maximum(hist, 0))   # [B,T,D]
    eh = e @ params["S"]                                          # [B,T,D]
    b = jnp.broadcast_to(params["b_init"][None, : hist.shape[1]],
                         (hist.shape[0],) + params["b_init"][: hist.shape[1]].shape)
    for it in range(cfg.capsule_iters):
        w = jax.nn.softmax(b, axis=-1) * mask[..., None]          # [B,T,K]
        c = _squash(jnp.einsum("btk,btd->bkd", w, eh))            # [B,K,D]
        if it < cfg.capsule_iters - 1:
            b = b + jnp.einsum("btd,bkd->btk", eh, c)
    return c


def mind_loss(params, batch, cfg: MINDConfig):
    """batch: hist [B,T], target [B], neg [B, N]."""
    caps = mind_interests(params, batch["hist"], cfg)             # [B,K,D]
    e_t = embedding_lookup(params["items"], batch["target"])      # [B,D]
    # label-aware attention over interests
    att = jax.nn.softmax(
        cfg.pow_p * jnp.einsum("bkd,bd->bk", caps, e_t), axis=-1)
    u = jnp.einsum("bk,bkd->bd", att, caps)
    e_n = embedding_lookup(params["items"], batch["neg"])         # [B,N,D]
    lp = jnp.einsum("bd,bd->b", u, e_t)
    ln_ = jnp.einsum("bd,bnd->bn", u, e_n)
    loss = -(jax.nn.log_sigmoid(lp).mean()
             + jax.nn.log_sigmoid(-ln_).mean())
    return loss, {}


def mind_user_embedding(params, batch, cfg: MINDConfig):
    return mind_interests(params, batch["hist"], cfg)             # [B,K,D]
