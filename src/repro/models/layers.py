"""Shared NN layers (pure jnp, mesh-agnostic via logical sharding names).

Includes the memory-critical pieces a real framework needs at scale:
  - flash-style chunked attention (online softmax over KV blocks) so 32k+
    prefill never materializes an [S, S] score matrix,
  - sliding-window attention with *true* sub-quadratic compute (per query
    block only window+block keys are sliced in),
  - expert-parallel MoE as a shard_map island (tokens sharded over dp axes,
    experts over `model`, capacity-bounded dispatch, psum combine),
  - chunked cross-entropy (never materializes [B, S, V] logits).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh

# ---------------------------------------------------------------------------
# init helpers: params and logical-axes trees share structure
# ---------------------------------------------------------------------------


def dense_init(key, shape, axes, scale=0.02, dtype=jnp.float32):
    w = jax.random.normal(key, shape, dtype) * scale
    return w, axes


def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float):
    return theta ** (-jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head)


def apply_rope(x, positions, theta: float):
    """x: [..., S, d_head]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                        # [d/2]
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA + causal + optional sliding window), flash-style chunking
# ---------------------------------------------------------------------------


def _online_softmax_block(q, k, v, mask, m, l, acc, scale):
    """One KV block of online-softmax attention.

    q [B,N,bq,hd], k/v [B,N,bk,hd], mask [.., bq, bk] bool (True=keep)."""
    s = jnp.einsum("bnqh,bnkh->bnqk", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + p.sum(axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bnqk,bnkh->bnqh", p.astype(v.dtype), v).astype(jnp.float32)
    return m_new, l_new, acc_new


def flash_attention(q, k, v, *, causal=True, window: int | None = None,
                    block_q: int = 512, block_kv: int = 512,
                    q_offset: int = 0):
    """Chunked attention. q [B,N,Sq,hd], k/v [B,N,Skv,hd] (N = query heads;
    callers fold GQA groups into N by repeating KV).

    q_offset: absolute position of q[0] relative to k[0] (prefill: 0 with
    Sq == Skv; continuation chunks use > 0).
    """
    B, N, Sq, hd = q.shape
    Skv = k.shape[2]
    scale = 1.0 / np.sqrt(hd)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    nq = (Sq + block_q - 1) // block_q
    pad_q = nq * block_q - Sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))

    if window is not None:
        # sub-quadratic: per q block slice [lo, lo + window + block_q) keys
        span = window + block_q
        kp = jnp.pad(k, ((0, 0), (0, 0), (span, span), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (span, span), (0, 0)))

        @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
        def q_block(i):
            qb = jax.lax.dynamic_slice_in_dim(q, i * block_q, block_q, axis=2)
            q_pos = q_offset + i * block_q + jnp.arange(block_q)
            lo = q_offset + i * block_q + block_q - span  # in original coords
            kb = jax.lax.dynamic_slice_in_dim(kp, lo + span, span, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(vp, lo + span, span, axis=2)
            k_pos = lo + jnp.arange(span)
            mask = (k_pos[None, :] <= q_pos[:, None])
            mask &= (k_pos[None, :] > q_pos[:, None] - window)
            mask &= (k_pos[None, :] >= 0) & (k_pos[None, :] < Skv)
            m = jnp.full((B, N, block_q), -1e30, jnp.float32)
            l = jnp.zeros((B, N, block_q), jnp.float32)
            acc = jnp.zeros((B, N, block_q, hd), jnp.float32)
            m, l, acc = _online_softmax_block(qb, kb, vb, mask, m, l, acc, scale)
            return acc / jnp.maximum(l, 1e-30)[..., None]

        out = jax.lax.map(q_block, jnp.arange(nq))
        out = jnp.moveaxis(out, 0, 2).reshape(B, N, nq * block_q, hd)
        return out[:, :, :Sq].astype(q.dtype)

    nkv = (Skv + block_kv - 1) // block_kv
    pad_kv = nkv * block_kv - Skv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))

    def q_block(i):
        qb = jax.lax.dynamic_slice_in_dim(q, i * block_q, block_q, axis=2)
        q_pos = q_offset + i * block_q + jnp.arange(block_q)

        @partial(jax.checkpoint,
                 policy=jax.checkpoint_policies.nothing_saveable)
        def kv_block(carry, j):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(k, j * block_kv, block_kv, axis=2)
            vb = jax.lax.dynamic_slice_in_dim(v, j * block_kv, block_kv, axis=2)
            k_pos = j * block_kv + jnp.arange(block_kv)
            mask = k_pos[None, :] < Skv
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            m, l, acc = _online_softmax_block(qb, kb, vb, mask, m, l, acc, scale)
            return (m, l, acc), None

        m = jnp.full((B, N, block_q), -1e30, jnp.float32)
        l = jnp.zeros((B, N, block_q), jnp.float32)
        acc = jnp.zeros((B, N, block_q, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m, l, acc),
                                      jnp.arange(nkv))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(q_block, jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 2).reshape(B, N, nq * block_q, hd)
    return out[:, :, :Sq].astype(q.dtype)


def head_layout(n_heads: int, n_kv: int, tp: int):
    """Pad the query-head dim so it tiles evenly over a tp-way model axis.

    KV heads are *logically replicated* (weights stay [KV]; activations are
    repeated), and query heads are padded with inert slots whose output is
    masked to zero — mathematically exact GQA(H, KV) at any tp (DESIGN §6).
    Returns (Hp padded q-heads, head_mask bool[Hp]).
    """
    g = n_heads // n_kv
    assert n_heads % n_kv == 0, "q heads must divide evenly into kv groups"
    if tp <= 1:
        return n_heads, np.ones(n_heads, bool)
    if n_kv >= tp:
        assert n_kv % tp == 0, (n_kv, tp)
        r = 1
    else:
        assert tp % n_kv == 0, (n_kv, tp)
        r = tp // n_kv
    gp = -(-g // r)                    # q heads per replicated kv slot
    hp = n_kv * r * gp
    mask = np.zeros(hp, bool)
    for j in range(n_kv * r):          # kv' slot j = copy (j % r) of kv j//r
        c = j % r
        gi = g // r + (1 if c < g % r else 0)
        mask[j * gp : j * gp + gi] = True
    assert int(mask.sum()) == n_heads
    return hp, mask


def init_attention(key, d_model, n_heads, n_kv, d_head, qkv_bias=False,
                   tp: int = 1):
    hp, _ = head_layout(n_heads, n_kv, tp)
    ks = jax.random.split(key, 4)
    p = {
        "wq": jax.random.normal(ks[0], (d_model, hp, d_head)) * 0.02,
        "wk": jax.random.normal(ks[1], (d_model, n_kv, d_head)) * 0.02,
        "wv": jax.random.normal(ks[2], (d_model, n_kv, d_head)) * 0.02,
        "wo": jax.random.normal(ks[3], (hp, d_head, d_model)) * 0.02,
    }
    a = {
        "wq": ("fsdp", "heads", None),
        "wk": ("fsdp", None, None),   # KV dim may not divide tp: replicate
        "wv": ("fsdp", None, None),
        "wo": ("heads", None, "fsdp"),
    }
    if qkv_bias:
        p |= {"bq": jnp.zeros((hp, d_head)),
              "bk": jnp.zeros((n_kv, d_head)),
              "bv": jnp.zeros((n_kv, d_head))}
        a |= {"bq": ("heads", None), "bk": (None, None), "bv": (None, None)}
    return p, a


def attention(p, x, *, n_heads, n_kv, rope_theta, window=None,
              positions=None, cache=None, cache_pos=None, tp: int = 1):
    """GQA attention. Train/prefill: x [B,S,D], cache None -> (out, (k, v)).
    Decode: x [B,1,D] with cache (k,v) [B,KV,Sc,hd] -> (out, (k, v)).

    Query heads use the tp-padded layout (head_layout); the inert padded
    slots are masked out of wo, so this is exact GQA(H, KV) at any tp."""
    B, S, D = x.shape
    hp, hmask = head_layout(n_heads, n_kv, tp)
    g = hp // n_kv
    q = jnp.einsum("bsd,dnh->bnsh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bnsh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bnsh", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    q = sh.constrain(q, "batch", "heads", None, None)
    k = sh.constrain(k, "batch", None, None, None)
    v = sh.constrain(v, "batch", None, None, None)

    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions[:, None, :], rope_theta)
    k = apply_rope(k, positions[:, None, :], rope_theta)

    if cache is None:
        kk = sh.constrain(jnp.repeat(k, g, axis=1),
                          "batch", "heads", None, None)
        vv = sh.constrain(jnp.repeat(v, g, axis=1),
                          "batch", "heads", None, None)
        out = flash_attention(q, kk, vv, causal=True, window=window)
        new_cache = (k, v)
    else:
        quantized = len(cache) == 4
        if quantized:
            ck, cv, ksc, vsc = cache   # int8 caches + fp32 scales [B,KV,Sc]
            kq, ks_new = quantize_kv(k)
            vq, vs_new = quantize_kv(v)
        else:
            ck, cv = cache             # [B, KV, Sc, hd]
            ksc = vsc = None
        B_, KV_, Sc, _ = ck.shape
        pos = jnp.asarray(cache_pos)
        mesh = sh.current_mesh()
        if (mesh is not None and "model" in mesh.axis_names
                and sh.model_size(mesh) > 1
                and Sc % sh.model_size(mesh) == 0
                and Sc >= sh.model_size(mesh)):
            out, new_cache = _flash_decode_sharded(
                q, (kq, ks_new) if quantized else k,
                (vq, vs_new) if quantized else v,
                ck, cv, pos, window, mesh, scales=(ksc, vsc))
            out = sh.constrain(out, "batch", None, None, None)
            if not hmask.all():
                out = out * jnp.asarray(hmask, out.dtype)[None, :, None, None]
            y = jnp.einsum("bnsh,nhd->bsd", out, p["wo"])
            return sh.constrain(y, "batch", None, None), new_cache
        slot = pos % Sc if window is not None else pos
        if quantized:
            k_store, v_store = kq, vq
        else:
            k_store, v_store = k, v
        if pos.ndim == 0:
            ck = _cache_set(ck, k_store, slot)
            cv = _cache_set(cv, v_store, slot)
            if quantized:
                ksc = jax.lax.dynamic_update_slice(ksc, ks_new, (0, 0, slot))
                vsc = jax.lax.dynamic_update_slice(vsc, vs_new, (0, 0, slot))
        else:  # per-slot positions (continuous batching)
            bi = jnp.arange(B_)
            sl = jnp.clip(slot, 0, Sc - 1)
            ck = ck.at[bi, :, sl, :].set(k_store[:, :, 0, :].astype(ck.dtype))
            cv = cv.at[bi, :, sl, :].set(v_store[:, :, 0, :].astype(cv.dtype))
            if quantized:
                ksc = ksc.at[bi, :, sl].set(ks_new[:, :, 0])
                vsc = vsc.at[bi, :, sl].set(vs_new[:, :, 0])
        if quantized:
            kk = jnp.repeat(dequantize_kv(ck, ksc, q.dtype), g, axis=1)
            vv = jnp.repeat(dequantize_kv(cv, vsc, q.dtype), g, axis=1)
        else:
            kk = jnp.repeat(ck, g, axis=1)
            vv = jnp.repeat(cv, g, axis=1)
        kpos = jnp.arange(Sc)
        posb = pos if pos.ndim else pos[None]           # [B] or [1]
        slotb = slot if pos.ndim else slot[None]
        if window is not None:
            # ring buffer: valid entries are the last min(pos+1, Sc)
            age = (slotb[:, None] - kpos[None, :]) % Sc
            valid = age <= jnp.minimum(posb, Sc - 1)[:, None]
        else:
            valid = kpos[None, :] <= posb[:, None]      # [B or 1, Sc]
        s = jnp.einsum("bnqh,bnkh->bnqk", q, kk).astype(jnp.float32)
        s = s / np.sqrt(q.shape[-1])
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        out = jnp.einsum("bnqk,bnkh->bnqh", w, vv)
        new_cache = (ck, cv, ksc, vsc) if quantized else (ck, cv)

    out = sh.constrain(out, "batch", "heads", None, None)
    if not hmask.all():  # zero the inert padded head slots
        out = out * jnp.asarray(hmask, out.dtype)[None, :, None, None]
    y = jnp.einsum("bnsh,nhd->bsd", out, p["wo"])
    return sh.constrain(y, "batch", None, None), new_cache


def _cache_set(cache, kv, slot):
    """cache [B,KV,Sc,hd]; kv [B,KV,1,hd]; write at dynamic slot."""
    return jax.lax.dynamic_update_slice(
        cache, kv.astype(cache.dtype), (0, 0, slot, 0))


def quantize_kv(x):
    """Per-(batch, head, position) int8 KV quantization (KIVI-flavoured;
    §Perf decode hillclimb: halves resident cache bytes vs bf16; on TPU the
    dequant fuses into the attention read). x [B,KV,S,hd] ->
    (int8 [B,KV,S,hd], fp32 scale [B,KV,S])."""
    scale = jnp.maximum(jnp.abs(x).max(axis=-1), 1e-8).astype(jnp.float32) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None].astype(x.dtype)), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _flash_decode_sharded(q, k_new, v_new, ck, cv, pos, window, mesh,
                          scales=(None, None)):
    """Flash-decoding: KV cache sequence-sharded over `model`; each rank
    attends its chunk, a pmax/psum online-softmax merge combines. The kv=8
    head dim never needs to divide tp, and cache memory scales 1/tp.

    Quantized mode: k_new/v_new are (int8 [B,KV,1,hd], fp32 scale [B,KV,1])
    pairs and `scales` holds the fp32 cache scales [B,KV,Sc] (int8 KV cache,
    §Perf). Returns (out, new_cache) where new_cache is (ck, cv) or
    (ck, cv, ksc, vsc)."""
    from jax.sharding import PartitionSpec as P

    ksc, vsc = scales
    quantized = ksc is not None
    if quantized:
        kq, ks_new = k_new
        vq, vs_new = v_new
    else:
        kq, vq = k_new, v_new
        ks_new = vs_new = jnp.zeros((q.shape[0], ck.shape[1], 1), jnp.float32)
        ksc = vsc = jnp.zeros(ck.shape[:3], jnp.float32)

    B, HP, _, hd = q.shape
    KV, Sc = ck.shape[1], ck.shape[2]
    g = HP // KV
    n_model = sh.model_size(mesh)
    chunk = Sc // n_model
    dpa = sh.dp_axes(mesh)
    b_spec = dpa if (dpa and B % sh.dp_size(mesh) == 0) else None
    vec_pos = jnp.ndim(pos) > 0
    pos_spec = P(b_spec) if vec_pos else P()
    kv_spec = P(b_spec, None, "model", None)
    sc_spec = P(b_spec, None, "model")
    x_spec = P(b_spec, None, None, None)
    sn_spec = P(b_spec, None, None)

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(x_spec, x_spec, x_spec, kv_spec, kv_spec, pos_spec,
                       sc_spec, sc_spec, sn_spec, sn_spec),
             out_specs=(x_spec, kv_spec, kv_spec, sc_spec, sc_spec),
             check_vma=False)
    def run(q, kn, vn, ck, cv, pos, ksc, vsc, ksn, vsn):
        b = q.shape[0]
        base = jax.lax.axis_index("model") * chunk
        posb = pos if vec_pos else pos[None]
        slot = (posb % Sc) if window is not None else posb
        loc = slot - base
        ok = (loc >= 0) & (loc < chunk)
        locc = jnp.clip(loc, 0, chunk - 1)
        bi = jnp.arange(b)
        up_k = jnp.where(ok[:, None, None], kn[:, :, 0, :].astype(ck.dtype),
                         ck[bi, :, locc, :])
        up_v = jnp.where(ok[:, None, None], vn[:, :, 0, :].astype(cv.dtype),
                         cv[bi, :, locc, :])
        ck = ck.at[bi, :, locc, :].set(up_k)
        cv = cv.at[bi, :, locc, :].set(up_v)
        if quantized:
            ksc = ksc.at[bi, :, locc].set(
                jnp.where(ok[:, None], ksn[:, :, 0], ksc[bi, :, locc]))
            vsc = vsc.at[bi, :, locc].set(
                jnp.where(ok[:, None], vsn[:, :, 0], vsc[bi, :, locc]))
            kk = jnp.repeat(dequantize_kv(ck, ksc, q.dtype), g, axis=1)
            vv = jnp.repeat(dequantize_kv(cv, vsc, q.dtype), g, axis=1)
        else:
            kk = jnp.repeat(ck, g, axis=1)
            vv = jnp.repeat(cv, g, axis=1)
        s = jnp.einsum("bnqh,bnkh->bnqk", q, kk).astype(jnp.float32)
        s = s / np.sqrt(hd)
        kpos = base + jnp.arange(chunk)
        if window is not None:
            age = (slot[:, None] - kpos[None, :]) % Sc
            valid = age <= jnp.minimum(posb, Sc - 1)[:, None]
        else:
            valid = kpos[None, :] <= posb[:, None]
        s = jnp.where(valid[:, None, None, :], s, -1e30)
        m_l = s.max(axis=-1)                                  # [b, HP, 1]
        p = jnp.exp(s - m_l[..., None])
        p = jnp.where(valid[:, None, None, :], p, 0.0)
        l_l = p.sum(axis=-1)
        acc_l = jnp.einsum("bnqk,bnkh->bnqh", p.astype(q.dtype),
                           vv).astype(jnp.float32)
        m = jax.lax.pmax(m_l, "model")
        corr = jnp.exp(m_l - m)
        l = jax.lax.psum(l_l * corr, "model")
        acc = jax.lax.psum(acc_l * corr[..., None], "model")
        out = (acc / jnp.maximum(l, 1e-30)[..., None])
        return out.astype(q.dtype), ck, cv, ksc, vsc

    out, ck, cv, ksc, vsc = run(q, kq, vq, ck, cv, pos, ksc, vsc,
                                ks_new, vs_new)
    if quantized:
        return out, (ck, cv, ksc, vsc)
    return out, (ck, cv)


# ---------------------------------------------------------------------------
# MLP (SwiGLU) and MoE
# ---------------------------------------------------------------------------


def init_mlp(key, d_model, d_ff):
    ks = jax.random.split(key, 3)
    p = {
        "w_gate": jax.random.normal(ks[0], (d_model, d_ff)) * 0.02,
        "w_up": jax.random.normal(ks[1], (d_model, d_ff)) * 0.02,
        "w_down": jax.random.normal(ks[2], (d_ff, d_model)) * 0.02,
    }
    a = {"w_gate": ("fsdp", "mlp"), "w_up": ("fsdp", "mlp"),
         "w_down": ("mlp", "fsdp")}
    return p, a


def mlp(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = sh.constrain(h, "batch", None, "mlp")
    return sh.constrain(h @ p["w_down"], "batch", None, None)


def init_moe(key, d_model, d_ff, n_experts):
    ks = jax.random.split(key, 4)
    p = {
        "router": jax.random.normal(ks[0], (d_model, n_experts)) * 0.02,
        "w_gate": jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * 0.02,
        "w_up": jax.random.normal(ks[2], (n_experts, d_model, d_ff)) * 0.02,
        "w_down": jax.random.normal(ks[3], (n_experts, d_ff, d_model)) * 0.02,
    }
    a = {
        "router": (None, None),
        "w_gate": ("expert", "fsdp", None),
        "w_up": ("expert", "fsdp", None),
        "w_down": ("expert", "fsdp", None),
    }
    return p, a


def _moe_local(p_local, x, *, top_k, n_experts, expert_offset, n_local,
               capacity_factor=1.25, norm_topk=True, axis=None):
    """Per-device MoE: x [T, D] local tokens, p_local holds n_local experts.

    Token-choice top-k with per-expert capacity; combine is a psum over the
    expert-parallel axis when `axis` is set.
    """
    T, D = x.shape
    logits = (x @ p_local["router"]).astype(jnp.float32)      # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k selection (k rounds of argmax)
    pr = probs
    sel_idx, sel_p = [], []
    for _ in range(top_k):
        i = jnp.argmax(pr, axis=-1)
        sel_idx.append(i)
        sel_p.append(jnp.take_along_axis(pr, i[:, None], axis=1)[:, 0])
        pr = pr.at[jnp.arange(T), i].set(-1.0)
    sel_idx = jnp.stack(sel_idx, axis=1)                      # [T, k]
    sel_p = jnp.stack(sel_p, axis=1)                          # [T, k]
    if norm_topk:
        sel_p = sel_p / jnp.maximum(sel_p.sum(axis=1, keepdims=True), 1e-9)

    # per-LOCAL-expert chosen mask + gate
    le = sel_idx - expert_offset                              # [T, k]
    in_local = (le >= 0) & (le < n_local)
    chosen = jnp.zeros((T, n_local), bool)
    gate = jnp.zeros((T, n_local), jnp.float32)
    for kk in range(top_k):
        lek = jnp.clip(le[:, kk], 0, n_local - 1)
        upd = in_local[:, kk]
        chosen = chosen.at[jnp.arange(T), lek].max(upd)
        gate = gate.at[jnp.arange(T), lek].add(
            jnp.where(upd, sel_p[:, kk], 0.0))

    capacity = max(int(T * top_k * capacity_factor / n_experts), 4)
    pos = jnp.cumsum(chosen.astype(jnp.int32), axis=0) - 1    # [T, E_l]
    slot_ok = chosen & (pos < capacity)
    # token table [E_l, capacity]: invalid writes go out of bounds + drop
    flat = jnp.where(slot_ok,
                     jnp.arange(n_local)[None, :] * capacity + pos,
                     n_local * capacity)
    table = jnp.full((n_local * capacity,), -1, jnp.int32)
    tok_ids = jnp.broadcast_to(
        jnp.arange(T, dtype=jnp.int32)[:, None], (T, n_local))
    table = table.at[flat.reshape(-1)].set(tok_ids.reshape(-1), mode="drop")
    table = table.reshape(n_local, capacity)

    tvalid = table >= 0
    tsafe = jnp.clip(table, 0, T - 1)
    xin = x[tsafe] * tvalid[..., None]                         # [E_l, C, D]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p_local["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xin, p_local["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p_local["w_down"])       # [E_l, C, D]

    g = gate[tsafe, jnp.arange(n_local)[:, None]]              # [E_l, C]
    y = y * (g * tvalid)[..., None]
    out = jnp.zeros((T, D), y.dtype).at[tsafe.reshape(-1)].add(
        y.reshape(-1, D) * tvalid.reshape(-1)[:, None])

    # load-balance aux loss (global over the expert axis)
    frac_tokens = jnp.zeros((n_experts,), jnp.float32).at[
        jnp.clip(sel_idx.reshape(-1), 0, n_experts - 1)].add(1.0) / (T * top_k)
    mean_prob = probs.mean(axis=0)
    aux = n_experts * jnp.sum(frac_tokens * mean_prob)
    if axis is not None:
        out = jax.lax.psum(out, axis)
        aux = jax.lax.pmean(aux, axis)
    return out.astype(x.dtype), aux


def moe_ffn(p, x, *, n_experts, top_k, capacity_factor=1.25, norm_topk=True):
    """x [B, S, D] -> (y [B, S, D], aux loss). Expert-parallel over `model`
    when a mesh is active; single-device fallback otherwise."""
    B, S, D = x.shape
    mesh = sh.current_mesh()

    if (mesh is None or "model" not in mesh.axis_names or mesh.size == 1
            or n_experts % sh.model_size(mesh) != 0):
        # no EP (single device, or expert count does not tile the model
        # axis — e.g. reduced smoke configs): replicated expert compute
        y, aux = _moe_local(p, x.reshape(B * S, D), top_k=top_k,
                            n_experts=n_experts, expert_offset=0,
                            n_local=n_experts,
                            capacity_factor=capacity_factor,
                            norm_topk=norm_topk)
        return y.reshape(B, S, D), aux

    n_model = sh.model_size(mesh)
    n_local = max(n_experts // n_model, 1)
    dp = sh.dp_axes(mesh)

    pspec = {
        "router": P(None, None),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(pspec, P(dp, None, None)),
             out_specs=(P(dp, None, None), P()),
             check_vma=False)
    def run(p_l, x_l):
        b, s, d = x_l.shape
        off = jax.lax.axis_index("model") * n_local
        y, aux = _moe_local(p_l, x_l.reshape(b * s, d), top_k=top_k,
                            n_experts=n_experts, expert_offset=off,
                            n_local=n_local, capacity_factor=capacity_factor,
                            norm_topk=norm_topk, axis="model")
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)
        return y.reshape(b, s, d), aux

    return run(p, x)


# ---------------------------------------------------------------------------
# embedding + chunked cross-entropy
# ---------------------------------------------------------------------------


def embed_lookup(table, tokens):
    out = jnp.take(table, tokens, axis=0)
    return sh.constrain(out, "batch", None, None)


def xent_loss_chunked(x, w_unembed, targets, mask=None, chunk: int = 512,
                      vocab_real: int | None = None, reduce: str = "mean"):
    """Mean next-token cross entropy without materializing [B,S,V].

    x [B,S,D], w_unembed [D,V], targets [B,S] (already shifted), mask [B,S].
    vocab_real: when the vocab dim is padded for sharding, logits beyond it
    are masked out of the softmax.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n = (S + chunk - 1) // chunk
    pad = n * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None else \
            jnp.pad(jnp.ones((B, S), bool), ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), bool)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, i):
        tot, cnt = carry
        xc = jax.lax.dynamic_slice_in_dim(x, i * chunk, chunk, axis=1)
        tc = jax.lax.dynamic_slice_in_dim(targets, i * chunk, chunk, axis=1)
        mc = jax.lax.dynamic_slice_in_dim(mask, i * chunk, chunk, axis=1)
        logits = (xc @ w_unembed).astype(jnp.float32)
        logits = sh.constrain(logits, "batch", None, "vocab")
        if vocab_real is not None and vocab_real < w_unembed.shape[-1]:
            cols = jnp.arange(w_unembed.shape[-1])
            logits = jnp.where(cols < vocab_real, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * mc
        return (tot + nll.sum(), cnt + mc.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                 jnp.arange(n))
    if reduce == "sum":
        return tot, cnt
    return tot / jnp.maximum(cnt, 1)
