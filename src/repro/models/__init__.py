from repro.models import gnn, layers, recsys, transformer

__all__ = ["gnn", "layers", "recsys", "transformer"]
