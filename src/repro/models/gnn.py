"""GIN (Graph Isomorphism Network, arXiv:1810.00826) on segment-sum message
passing — the JAX-native sparse path (no CSR SpMM in JAX; scatter-add over an
edge index IS the kernel, per the assignment notes).

Supports the four assigned shape regimes:
  full_graph_sm / ogb_products : one big graph, node classification;
                                 edges sharded over every mesh axis
                                 (partial segment_sum + psum).
  minibatch_lg                 : sampled subgraph (neighbor sampler in
                                 repro.data.graph), loss on seed nodes.
  molecule                     : dense batch of small graphs, sum readout.

Adaptation note (DESIGN §2.4 spirit): GIN's BatchNorm is replaced by
LayerNorm to stay functional/stateless; eps stays learnable.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh


@dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_feat: int = 64
    d_hidden: int = 64
    n_classes: int = 16
    learnable_eps: bool = True
    graph_level: bool = False     # molecule regime: per-graph readout
    partitioned_edges: bool = False  # §Perf: edges pre-partitioned by dst
                                     # shard -> aggregation needs NO psum
                                     # (AG of h replaces AR of aggregates)


def init_gin(key, cfg: GINConfig):
    ks = jax.random.split(key, cfg.n_layers * 2 + 2)
    layers = []
    d_in = cfg.d_feat
    for i in range(cfg.n_layers):
        k1, k2 = ks[2 * i], ks[2 * i + 1]
        layers.append({
            "w1": jax.random.normal(k1, (d_in, cfg.d_hidden)) * (d_in ** -0.5),
            "b1": jnp.zeros((cfg.d_hidden,)),
            "w2": jax.random.normal(k2, (cfg.d_hidden, cfg.d_hidden))
            * (cfg.d_hidden ** -0.5),
            "b2": jnp.zeros((cfg.d_hidden,)),
            "ln": jnp.ones((cfg.d_hidden,)),
            "eps": jnp.zeros(()),
        })
        d_in = cfg.d_hidden
    params = {
        "layers": layers,
        "head": jax.random.normal(ks[-1], (cfg.d_hidden, cfg.n_classes))
        * (cfg.d_hidden ** -0.5),
    }
    axes = {
        "layers": [
            {"w1": (None, None), "b1": (None,), "w2": (None, None),
             "b2": (None,), "ln": (None,), "eps": ()}
            for _ in range(cfg.n_layers)
        ],
        "head": (None, None),
    }
    return params, axes


def _aggregate(h, src, dst, n_nodes):
    """sum-aggregate messages h[src] into dst; edges may be sharded over the
    whole mesh (partial segment_sum + psum)."""
    mesh = sh.current_mesh()
    valid = (src >= 0) & (dst >= 0)
    srcc = jnp.where(valid, src, 0)
    dstc = jnp.where(valid, dst, 0)

    if mesh is None or mesh.size == 1:
        msg = h[srcc] * valid[:, None]
        return jax.ops.segment_sum(msg, dstc, num_segments=n_nodes)

    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(P(), P(axes), P(axes)),
             out_specs=P(), check_vma=False)
    def run(h_l, src_l, dst_l):
        v = (src_l >= 0) & (dst_l >= 0)
        msg = h_l[jnp.where(v, src_l, 0)] * v[:, None]
        agg = jax.ops.segment_sum(msg, jnp.where(v, dst_l, 0),
                                  num_segments=n_nodes)
        for ax in axes:
            agg = jax.lax.psum(agg, ax)
        return agg

    src = jnp.where(valid, src, -1)
    dst = jnp.where(valid, dst, -1)
    pad = (-src.shape[0]) % mesh.size
    if pad:  # edge list must tile evenly over the whole mesh
        src = jnp.pad(src, (0, pad), constant_values=-1)
        dst = jnp.pad(dst, (0, pad), constant_values=-1)
    return run(h, src, dst)


def _aggregate_partitioned(h, src, dst, n_nodes):
    """Locality-aware aggregation (§Perf hillclimb, DistDGL-style): the data
    pipeline partitions edges so shard i's edges all have dst in node range
    [i*n_local, (i+1)*n_local). segment_sum lands directly in the local node
    shard — NO all-reduce; the only collective is the all_gather of h that
    feeds the next layer's src gathers (half the bytes of the baseline AR,
    and it shrinks further with src-locality-aware partitioners)."""
    mesh = sh.current_mesh()
    valid = (src >= 0) & (dst >= 0)
    if mesh is None or mesh.size == 1:
        msg = h[jnp.where(valid, src, 0)] * valid[:, None]
        return jax.ops.segment_sum(msg, jnp.where(valid, dst, 0),
                                   num_segments=n_nodes)
    axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    assert n_nodes % mesh.size == 0
    n_local = n_nodes // mesh.size

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(P(), P(axes), P(axes)),
             out_specs=P(axes, None), check_vma=False)
    def run(h_l, src_l, dst_l):
        rank = jnp.int32(0)
        for ax in axes:
            rank = rank * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
        base = rank * n_local
        v = (src_l >= 0) & (dst_l >= 0)
        loc = jnp.where(v, dst_l - base, 0)
        v &= (loc >= 0) & (loc < n_local)
        msg = h_l[jnp.where(v, src_l, 0)] * v[:, None]
        return jax.ops.segment_sum(msg, jnp.where(v, loc, 0),
                                   num_segments=n_local)

    src = jnp.where(valid, src, -1)
    dst = jnp.where(valid, dst, -1)
    pad = (-src.shape[0]) % mesh.size
    if pad:
        src = jnp.pad(src, (0, pad), constant_values=-1)
        dst = jnp.pad(dst, (0, pad), constant_values=-1)
    return run(h, src, dst)


def _layer(lp, h, agg, eps_on: bool):
    x = (1.0 + lp["eps"]) * h + agg if eps_on else h + agg
    x = x @ lp["w1"] + lp["b1"]
    x = jax.nn.relu(x)
    x = x @ lp["w2"] + lp["b2"]
    # stateless LayerNorm in place of GIN's BatchNorm
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + 1e-5) * lp["ln"]
    return jax.nn.relu(x)


def _node_constrain(h):
    """Shard the node dim across the whole mesh for the per-node MLPs so
    saved activations scale 1/mesh; the aggregate step re-gathers."""
    mesh = sh.current_mesh()
    if mesh is not None and h.shape[0] % mesh.size == 0:
        return sh.constrain(h, "nodes", None)
    return h


def forward_full_graph(params, feats, src, dst, cfg: GINConfig):
    """feats [N, d_feat]; src/dst int32[E] (-1 padded). Node logits [N, C]."""
    n = feats.shape[0]
    h = feats
    agg_fn = _aggregate_partitioned if cfg.partitioned_edges else _aggregate
    for lp in params["layers"]:
        agg = agg_fn(h, src, dst, n)
        h = _layer(lp, _node_constrain(h), _node_constrain(agg),
                   cfg.learnable_eps)
        h = _node_constrain(h)
    return h @ params["head"]


def forward_batched_graphs(params, feats, src, dst, cfg: GINConfig):
    """Dense small-graph batch: feats [G, Nn, d], src/dst [G, Ne] (-1 pad).
    Returns graph logits [G, C] (sum readout)."""
    def one(f, s, d):
        h = f
        nn = f.shape[0]
        for lp in params["layers"]:
            v = (s >= 0) & (d >= 0)
            msg = h[jnp.where(v, s, 0)] * v[:, None]
            agg = jax.ops.segment_sum(msg, jnp.where(v, d, 0), num_segments=nn)
            h = _layer(lp, h, agg, cfg.learnable_eps)
        return h.sum(axis=0)

    pooled = jax.vmap(one)(feats, src, dst)
    pooled = sh.constrain(pooled, "batch", None)
    return pooled @ params["head"]


def loss_full_graph(params, batch, cfg: GINConfig):
    """batch: feats [N,d], src/dst [E], labels [N], label_mask [N]."""
    logits = forward_full_graph(params, batch["feats"], batch["src"],
                                batch["dst"], cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
    m = batch["label_mask"]
    loss = (nll * m).sum() / jnp.maximum(m.sum(), 1)
    acc = ((logits.argmax(-1) == batch["labels"]) * m).sum() / jnp.maximum(m.sum(), 1)
    return loss, {"acc": acc}


def loss_batched_graphs(params, batch, cfg: GINConfig):
    """batch: feats [G,Nn,d], src/dst [G,Ne], labels [G]."""
    logits = forward_batched_graphs(params, batch["feats"], batch["src"],
                                    batch["dst"], cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=1)[:, 0]
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return nll.mean(), {"acc": acc}
