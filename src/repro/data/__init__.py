from repro.data import graph, lm, recsys, strings

__all__ = ["graph", "lm", "recsys", "strings"]
