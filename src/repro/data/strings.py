"""String corpora + synonym-rule generators mirroring the paper's datasets
(Table 1): DBLP (publication titles + CS abbreviations), USPS (addresses +
nickname/state rules), SPROT (gene/protein records + term-variation rules).

Offline environment => faithful *synthetic* regeneration with matched
statistics: string counts/lengths, rule counts, and rules-per-string in the
paper's reported ranges; scores uniform in [1, 50000] as in §7.1.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

_WORDS = """system database management query optimization transaction neural
network learning deep graph stream processing distributed parallel storage
index structure algorithm efficient scalable adaptive dynamic incremental
approximate probabilistic semantic knowledge information retrieval search
ranking completion string synonym abbreviation entity resolution join
similarity vector spatial temporal crowd privacy secure federated quantum
""".split()

_FIRST = """james mary john patricia robert jennifer michael linda william
elizabeth david barbara richard susan joseph jessica thomas sarah charles
karen christopher nancy daniel lisa matthew betty anthony margaret mark
sandra donald ashley steven kimberly paul emily andrew donna joshua michelle
kenneth carol kevin amanda brian melissa george deborah""".split()

_NICK = {
    "james": "jim", "john": "jack", "robert": "bob", "michael": "mike",
    "william": "bill", "david": "dave", "richard": "dick", "joseph": "joe",
    "thomas": "tom", "charles": "chuck", "christopher": "chris",
    "daniel": "dan", "matthew": "matt", "anthony": "tony", "donald": "don",
    "steven": "steve", "kenneth": "ken", "kevin": "kev", "andrew": "andy",
    "joshua": "josh", "elizabeth": "liz", "jennifer": "jen",
    "patricia": "pat", "margaret": "peggy", "deborah": "deb",
    "kimberly": "kim", "jessica": "jess", "sandra": "sandy",
}

_STATES = {
    "texas": "tx", "california": "ca", "new york": "ny", "florida": "fl",
    "illinois": "il", "ohio": "oh", "georgia": "ga", "michigan": "mi",
    "virginia": "va", "washington": "wa", "arizona": "az", "oregon": "or",
    "colorado": "co", "nevada": "nv", "montana": "mt", "utah": "ut",
}

_STREET = "main oak pine maple cedar elm park lake hill river sunset".split()
_STYPE = {"street": "st", "avenue": "ave", "boulevard": "blvd",
          "drive": "dr", "road": "rd", "court": "ct", "lane": "ln"}


@dataclass
class StringDataset:
    name: str
    strings: list[str]
    scores: np.ndarray
    rules: list[tuple[str, str]]   # (query-side lhs, dictionary-side rhs)


def _scores(rng, n):
    return rng.integers(1, 50_001, n).astype(np.int32)


def make_dblp(n: int = 24_810, seed: int = 0) -> StringDataset:
    """Titles from a CS word vocabulary; rules = abbreviation -> word."""
    rng = np.random.default_rng(seed)
    strings = set()
    while len(strings) < n:
        k = rng.integers(4, 10)
        strings.add(" ".join(rng.choice(_WORDS, k)))
    strings = sorted(strings)
    rules = []
    for w in sorted(set(_WORDS)):
        if len(w) >= 6:
            rules.append((w[:3] + ".", w))       # "dat." -> "database"
        if len(w) >= 8:
            rules.append((w[:4], w))             # "data" -> "database"-ish
    rules = sorted(set(rules))[:214]
    return StringDataset("DBLP", strings, _scores(rng, len(strings)), rules)


def make_usps(n: int = 1_000_000, seed: int = 0) -> StringDataset:
    """person name + street + city + state records; nickname/state rules."""
    rng = np.random.default_rng(seed)
    firsts = np.array(_FIRST)
    streets = np.array(_STREET)
    stypes = np.array(list(_STYPE.keys()))
    states = np.array(list(_STATES.keys()))
    f = firsts[rng.integers(0, len(firsts), n)]
    l = firsts[rng.integers(0, len(firsts), n)]
    num = rng.integers(1, 9999, n)
    st = streets[rng.integers(0, len(streets), n)]
    ty = stypes[rng.integers(0, len(stypes), n)]
    ct = streets[rng.integers(0, len(streets), n)]
    sa = states[rng.integers(0, len(states), n)]
    strings = [f"{a} {b} {c} {d} {e} {g}ville {h}"
               for a, b, c, d, e, g, h in zip(f, l, num, st, ty, ct, sa)]
    rules = [(v, k) for k, v in _NICK.items()]
    rules += [(v, k) for k, v in _STATES.items()]
    rules += [(v, k) for k, v in _STYPE.items()]
    # common misspellings / short forms of street words
    rules += [(w[:3], w) for w in _STREET if len(w) >= 5]
    rules = sorted(set(rules))[:341]
    return StringDataset("USPS", strings, _scores(rng, len(strings)), rules)


def make_sprot(n: int = 1_000_000, seed: int = 0) -> StringDataset:
    """entry name + protein + gene + organism; acronym/variation rules."""
    rng = np.random.default_rng(seed)
    prots = ["kinase", "receptor", "transferase", "hydrolase", "ligase",
             "polymerase", "phosphatase", "synthase", "reductase", "protease"]
    orgs = ["human", "mouse", "yeast", "ecoli", "zebrafish", "drosophila"]
    entry = rng.integers(0, 10**6, n)
    p1 = np.array(prots)[rng.integers(0, len(prots), n)]
    num = rng.integers(1, 99, n)
    gene = rng.integers(0, 26**3, n)
    org = np.array(orgs)[rng.integers(0, len(orgs), n)]

    def g3(x):
        return (chr(97 + x // 676) + chr(97 + (x // 26) % 26)
                + chr(97 + x % 26))

    strings = [f"q{e:06d} interleukin-{k} {p} {g3(g)} {o}"
               for e, k, p, g, o in zip(entry, num, p1, gene, org)]
    rules = [(f"il-{k}", f"interleukin-{k}") for k in range(1, 99)]
    rules += [(f"il{k}", f"interleukin-{k}") for k in range(1, 99)]
    rules += [(p[:4], p) for p in prots]
    rules += [(p + "s", p) for p in prots]
    rules += [(f"{o[:3]}.", o) for o in orgs]
    # pad with numbered variant rules to reach ~1000 like the paper
    k = 0
    while len(rules) < 1000:
        rules.append((f"v{k:03d}", f"variant-{k:03d}"))
        k += 1
    return StringDataset("SPROT", strings, _scores(rng, len(strings)),
                         sorted(set(rules))[:1000])


def make_workload(ds: StringDataset, n_queries: int, seed: int = 0,
                  min_len: int = 2, max_len: int = 24) -> list[str]:
    """Paper §7.3 workload: apply rules to dictionary strings (dict-side ->
    query-side rewriting), then take a prefix of the rewritten string."""
    rng = np.random.default_rng(seed)
    inv = {}  # dictionary-side rhs -> query-side lhs choices
    for lhs, rhs in ds.rules:
        inv.setdefault(rhs, []).append(lhs)
    rhs_keys = sorted(inv)
    queries = []
    n_strings = len(ds.strings)
    while len(queries) < n_queries:
        s = ds.strings[int(rng.integers(0, n_strings))]
        # rewrite up to 2 applicable dictionary-side substrings
        for _ in range(2):
            hits = [r for r in rhs_keys if r in s]
            if not hits or rng.random() < 0.3:
                break
            r = hits[int(rng.integers(0, len(hits)))]
            lhs = inv[r][int(rng.integers(0, len(inv[r])))]
            i = s.find(r)
            s = s[:i] + lhs + s[i + len(r):]
        ln = int(rng.integers(min_len, max_len + 1))
        q = s[:ln].rstrip()
        if q:
            queries.append(q)
    return queries


def make_zipf_queries(ds: StringDataset, n_queries: int, seed: int = 0,
                      a: float = 1.3, min_len: int = 2,
                      max_len: int = 24) -> list[str]:
    """Zipf-skewed prefix queries: real autocomplete traffic concentrates
    on hot strings, so strings are drawn by Zipf rank (parameter ``a``)
    instead of uniformly; rule rewriting and prefix truncation match
    :func:`make_workload`."""
    rng = np.random.default_rng(seed)
    inv = {}
    for lhs, rhs in ds.rules:
        inv.setdefault(rhs, []).append(lhs)
    rhs_keys = sorted(inv)
    n_strings = len(ds.strings)
    queries = []
    while len(queries) < n_queries:
        rank = min(int(rng.zipf(a)), n_strings) - 1
        s = ds.strings[rank]
        for _ in range(2):
            hits = [r for r in rhs_keys if r in s]
            if not hits or rng.random() < 0.3:
                break
            r = hits[int(rng.integers(0, len(hits)))]
            lhs = inv[r][int(rng.integers(0, len(inv[r])))]
            i = s.find(r)
            s = s[:i] + lhs + s[i + len(r):]
        ln = int(rng.integers(min_len, max_len + 1))
        q = s[:ln].rstrip()
        if q:
            queries.append(q)
    return queries


def make_keystroke_events(ds: StringDataset, n_sessions: int,
                          n_queries: int, seed: int = 0, a: float = 1.3,
                          min_len: int = 2, max_len: int = 24
                          ) -> list[tuple[int, int]]:
    """Interleaved multi-session keystroke stream for the serving layer.

    Zipf-skewed queries (:func:`make_zipf_queries`) are dealt to
    ``n_sessions`` concurrent typists balancing total keystroke count
    (each query goes to the least-loaded session, so streams end together
    instead of staggering with the heavy-tailed query lengths), each
    query preceded by a session reset; the per-session typing is then
    interleaved by a random schedule, so at any instant several sessions
    have a keystroke in flight — the shape continuous batching coalesces.

    Returns ``[(session, char), ...]`` where ``char`` is a byte value and
    ``-1`` marks a session reset (a new query starts).
    """
    rng = np.random.default_rng(seed)
    queries = make_zipf_queries(ds, n_queries, seed=seed + 1, a=a,
                                min_len=min_len, max_len=max_len)
    pending: list[list[int]] = [[] for _ in range(n_sessions)]
    for q in queries:
        s = min(range(n_sessions), key=lambda i: len(pending[i]))
        pending[s].append(-1)
        pending[s].extend(q.encode())
    events = []
    cursors = [0] * n_sessions
    live = [s for s in range(n_sessions) if pending[s]]
    while live:
        s = live[int(rng.integers(0, len(live)))]
        events.append((s, pending[s][cursors[s]]))
        cursors[s] += 1
        if cursors[s] == len(pending[s]):
            live.remove(s)
    return events


DATASETS = {"dblp": make_dblp, "usps": make_usps, "sprot": make_sprot}
