"""Graph data: generators for the four GIN shape regimes + a real neighbor
sampler (CSR adjacency, uniform fanout, padded renumbered subgraphs) as the
assignment requires for minibatch_lg.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class Graph:
    n_nodes: int
    src: np.ndarray      # int32[E]
    dst: np.ndarray      # int32[E]
    feats: np.ndarray    # float32[N, d]
    labels: np.ndarray   # int32[N]
    row_ptr: np.ndarray | None = None   # CSR over incoming edges (dst-major)
    col_idx: np.ndarray | None = None


def make_random_graph(n_nodes: int, n_edges: int, d_feat: int,
                      n_classes: int, seed: int = 0,
                      build_csr: bool = True) -> Graph:
    """Power-law-ish random graph with class-correlated features."""
    rng = np.random.default_rng(seed)
    # preferential-attachment flavoured endpoints
    w = rng.zipf(1.6, n_nodes).astype(np.float64)
    p = w / w.sum()
    src = rng.choice(n_nodes, size=n_edges, p=p).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = centers[labels] + rng.normal(
        scale=1.0, size=(n_nodes, d_feat)).astype(np.float32)
    g = Graph(n_nodes, src, dst, feats, labels)
    if build_csr:
        order = np.argsort(dst, kind="stable")
        g.col_idx = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        g.row_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return g


class NeighborSampler:
    """GraphSAGE-style uniform neighbor sampler over CSR adjacency.

    sample(seeds, fanouts) returns a renumbered, padded subgraph:
      feats [N_pad, d], src/dst int32[E_pad] (-1 pad), seed nodes are the
      first len(seeds) rows, labels/mask aligned.
    """

    def __init__(self, g: Graph, seed: int = 0):
        assert g.row_ptr is not None, "graph needs CSR"
        self.g = g
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray, fanouts: tuple[int, ...],
               n_pad: int | None = None, e_pad: int | None = None):
        g = self.g
        seeds = np.asarray(seeds, np.int64)
        frontier = seeds
        nodes = list(seeds)
        node_pos = {int(v): i for i, v in enumerate(seeds)}
        edges_src: list[int] = []
        edges_dst: list[int] = []
        for f in fanouts:
            deg = g.row_ptr[frontier + 1] - g.row_ptr[frontier]
            nxt = []
            for v, d in zip(frontier, deg):
                if d == 0:
                    continue
                take = min(f, int(d))
                offs = self.rng.choice(int(d), size=take,
                                       replace=int(d) < take)
                neigh = g.col_idx[g.row_ptr[v] + offs]
                for u in neigh:
                    u = int(u)
                    if u not in node_pos:
                        node_pos[u] = len(nodes)
                        nodes.append(u)
                        nxt.append(u)
                    edges_src.append(node_pos[u])
                    edges_dst.append(node_pos[int(v)])
            frontier = np.asarray(nxt, np.int64) if nxt else \
                np.zeros((0,), np.int64)
        nodes = np.asarray(nodes, np.int64)
        n_pad = n_pad or len(nodes)
        e_pad = e_pad or max(len(edges_src), 1)
        feats = np.zeros((n_pad, g.feats.shape[1]), np.float32)
        feats[: len(nodes)] = g.feats[nodes[:n_pad]]
        labels = np.zeros((n_pad,), np.int32)
        labels[: len(nodes)] = g.labels[nodes[:n_pad]]
        mask = np.zeros((n_pad,), bool)
        mask[: len(seeds)] = True
        src = np.full((e_pad,), -1, np.int32)
        dst = np.full((e_pad,), -1, np.int32)
        ne = min(len(edges_src), e_pad)
        src[:ne] = np.asarray(edges_src[:ne], np.int32)
        dst[:ne] = np.asarray(edges_dst[:ne], np.int32)
        return {"feats": feats, "src": src, "dst": dst,
                "labels": labels, "label_mask": mask}


def partition_edges_by_dst(g: Graph, n_shards: int,
                           capacity_factor: float = 1.2):
    """Locality-aware edge layout (§Perf): shard i owns edges whose dst is in
    node range [i*n_local, (i+1)*n_local). Returns (src, dst) int32 arrays of
    length n_shards*cap (-1 padded per shard; drops beyond capacity are
    counted and returned)."""
    n_local = -(-g.n_nodes // n_shards)
    owner = g.dst // n_local
    order = np.argsort(owner, kind="stable")
    src, dst = g.src[order], g.dst[order]
    counts = np.bincount(owner, minlength=n_shards)
    cap = int(counts.mean() * capacity_factor) + 1
    out_src = np.full((n_shards, cap), -1, np.int32)
    out_dst = np.full((n_shards, cap), -1, np.int32)
    starts = np.concatenate([[0], np.cumsum(counts)])
    dropped = 0
    for i in range(n_shards):
        e = src[starts[i]:starts[i + 1]]
        d = dst[starts[i]:starts[i + 1]]
        take = min(len(e), cap)
        dropped += len(e) - take
        out_src[i, :take] = e[:take]
        out_dst[i, :take] = d[:take]
    return out_src.reshape(-1), out_dst.reshape(-1), dropped


def make_molecule_batch(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                        n_classes: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    feats = rng.normal(size=(batch, n_nodes, d_feat)).astype(np.float32)
    src = rng.integers(0, n_nodes, (batch, n_edges)).astype(np.int32)
    dst = rng.integers(0, n_nodes, (batch, n_edges)).astype(np.int32)
    labels = rng.integers(0, n_classes, (batch,)).astype(np.int32)
    return {"feats": feats, "src": src, "dst": dst, "labels": labels}
