"""Synthetic-but-structured LM token pipeline.

Deterministic, seekable (state = step index), so checkpoint/restart resumes
the exact stream. The generator is a char-level Markov-ish mixture so the
loss actually decreases during the examples' short training runs.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class LMDataConfig:
    vocab: int = 256
    seq_len: int = 512
    global_batch: int = 8
    seed: int = 0


class TokenStream:
    """Yields {tokens, targets, mask} batches; `state` is the step index."""

    def __init__(self, cfg: LMDataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        # fixed bigram transition structure (low-entropy => learnable)
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        self._hot = rng.integers(0, v, size=(v, 4))

    def state(self) -> int:
        return self.step

    def next_batch(self) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, self.step]))
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab, b)
        noise = rng.random((b, s))
        choice = rng.integers(0, 4, (b, s))
        uni = rng.integers(0, cfg.vocab, (b, s))
        for t in range(s):
            follow = self._hot[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.85, follow, uni[:, t])
        self.step += 1
        return {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:].copy(),
            "mask": np.ones((b, s), bool),
        }

    def __iter__(self):
        while True:
            yield self.next_batch()
