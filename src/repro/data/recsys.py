"""RecSys click-log generator: zipf item popularity, per-user taste vectors,
deterministic + seekable like the LM stream."""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class RecsysDataConfig:
    n_items: int = 1_000_000
    n_dense: int = 13
    n_sparse: int = 26
    seq_len: int = 50
    batch: int = 256
    seed: int = 0


class ClickStream:
    def __init__(self, cfg: RecsysDataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step

    def state(self) -> int:
        return self.step

    def _rng(self):
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, self.step]))

    def _zipf_items(self, rng, shape):
        z = rng.zipf(1.3, shape).astype(np.int64)
        return (z % self.cfg.n_items).astype(np.int32)

    def next_dlrm(self) -> dict:
        cfg = self.cfg
        rng = self._rng()
        self.step += 1
        dense = rng.normal(size=(cfg.batch, cfg.n_dense)).astype(np.float32)
        sparse = self._zipf_items(rng, (cfg.batch, cfg.n_sparse))
        # label correlated with features so training can learn
        w = np.linspace(-1, 1, cfg.n_dense, dtype=np.float32)
        logit = dense @ w + 0.001 * (sparse.sum(1) % 97 - 48)
        label = (logit + rng.normal(size=cfg.batch) > 0).astype(np.float32)
        return {"dense": dense, "sparse": sparse, "label": label}

    def next_seq(self, with_negatives: int = 0) -> dict:
        """For DIN / SASRec / MIND: histories + target (+ negatives)."""
        cfg = self.cfg
        rng = self._rng()
        self.step += 1
        hist = self._zipf_items(rng, (cfg.batch, cfg.seq_len))
        lens = rng.integers(cfg.seq_len // 4, cfg.seq_len + 1, cfg.batch)
        pad = np.arange(cfg.seq_len)[None, :] >= lens[:, None]
        hist = np.where(pad, -1, hist)
        target = self._zipf_items(rng, (cfg.batch,))
        label = rng.integers(0, 2, cfg.batch).astype(np.float32)
        out = {"hist": hist, "target": target, "label": label}
        if with_negatives:
            out["neg"] = self._zipf_items(rng, (cfg.batch, with_negatives))
        # sasrec-style per-position next-item targets
        out["pos"] = np.where(pad, -1, np.roll(hist, -1, axis=1))
        out["neg_seq"] = self._zipf_items(rng, (cfg.batch, cfg.seq_len))
        return out
