"""Continuous-batching keystroke scheduler for the completion hot path.

``CompletionService`` was call-in/answer-out: every keystroke of every
session paid its own device dispatch.  At serving scale the dispatch — not
the kernel — is the bottleneck, so this module makes the serving layer
itself the batcher, generalizing the vLLM-style ``SlotScheduler`` of
:mod:`repro.serving.engine` from the LM decode loop to the trie path:

- a :class:`KeystrokeScheduler` owns a fixed-shape *slab*: a stacked
  :class:`~repro.core.engine.LocusState` with ``block`` lanes (the jit
  shape).  Each open :class:`BatchSession` pins one lane;
- submitted keystrokes enter a bounded admission queue (per-lane FIFOs —
  a session's chars are sequentially dependent, so one flush consumes at
  most one keystroke per lane but coalesces keystrokes *across* lanes);
- a *flush* assembles one padded micro-batch block — chars[block] with
  ``-1`` for idle lanes, a reset mask folded into the same dispatch — and
  runs one batched ``advance_loci_batch`` step plus (when any consumed
  keystroke wants results) one batched ``topk_from_loci_batch``, then
  demuxes scores/sids per lane.  The demux is pipelined one flush deep:
  a flush dispatches its own device work first and then settles the
  *previous* flush's stashed handles, so the host-side decode overlaps
  device compute instead of leaving the device idle;
- flushes fire when every occupied lane has a keystroke queued (a *full*
  block) or when the oldest queued keystroke would exceed its latency
  budget (``max_wait_ms`` — a *deadline* flush of a partial block), or on
  an explicit :meth:`~KeystrokeScheduler.drain`;
- the admission queue is bounded (``max_queue``): a submit beyond it
  raises :class:`SchedulerOverloaded` so overload surfaces as
  backpressure at the edge instead of unbounded memory.

Per-lane results are bit-identical to replaying the same keystrokes
through a sequential :class:`repro.api.session.Session`: lanes never
interact inside the vmapped advance, the batched phase 2 is per-row, and
the inexact-result fallback goes through the same widened one-shot path
(:func:`repro.api.session.resolve_topk`).

The scheduler is cooperatively driven (no background thread — JAX
dispatch from one thread keeps flush order, and therefore latency
accounting, deterministic): ``submit`` auto-flushes full blocks,
``pump()`` fires deadline flushes, and blocking helpers
(``BatchSession.type``, ``Ticket.result``, ``drain``) flush until their
work resolves.  Throughput comes from many sessions in flight — a lone
blocking session degrades to sequential dispatch by construction.
"""

from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field

import numpy as np

import jax

from repro.api.session import resolve_topk


class SchedulerOverloaded(RuntimeError):
    """Backpressure: the admission queue (or lane table) is full."""


@dataclass
class Ticket:
    """One keystroke in flight through the batcher."""

    lane: int
    char: int                     # byte value; -1 = reset-only flush filler
    want_topk: bool
    k: int
    created: float
    prefix: bytes = b""           # lane prefix *after* this keystroke —
                                  # snapshotted at submit because the
                                  # session may type further before the
                                  # flush that consumes this ticket lands
    reset_first: bool = False     # re-init the lane before the char step
    results: list | None = None   # (score, string) pairs once resolved
    done: bool = False
    latency_s: float | None = None

    def result(self, scheduler: "KeystrokeScheduler") -> list:
        """Block (cooperatively) until this keystroke's flush lands."""
        while not self.done:
            scheduler.flush()
        return self.results


@dataclass
class BatchStats:
    """Flush accounting for one scheduler."""

    n_keystrokes: int = 0
    n_flushes: int = 0
    full_flushes: int = 0          # every occupied lane advanced
    deadline_flushes: int = 0      # fired by the latency budget
    forced_flushes: int = 0        # drain()/result() forced a partial block
    rejected: int = 0              # submits refused by backpressure
    fallbacks: int = 0             # inexact lanes resolved via one-shot path
    sum_occupancy: int = 0         # lanes consumed across all flushes
    migrations: int = 0            # slab rebuilds onto a new index epoch

    @property
    def mean_occupancy(self) -> float:
        return self.sum_occupancy / max(self.n_flushes, 1)


class BatchSession:
    """One typing stream riding the scheduler's shared micro-batches.

    API-compatible with :class:`repro.api.session.Session` for the
    ``type``/``backspace``/``reset``/``topk``/``prefix`` surface, plus the
    non-blocking ``submit`` that makes cross-session coalescing possible.
    """

    def __init__(self, scheduler: "KeystrokeScheduler", lane: int, k: int):
        self.scheduler = scheduler
        self.lane = lane
        self.k = k
        self._prefix = bytearray()
        self._reset_pending = False
        self._closed = False

    @property
    def prefix(self) -> str:
        return bytes(self._prefix).decode("utf-8", errors="replace")

    @property
    def prefix_bytes(self) -> bytes:
        return bytes(self._prefix)

    # -- non-blocking path -------------------------------------------------

    def submit(self, char: int | bytes | str, want_topk: bool = True
               ) -> Ticket:
        """Enqueue one keystroke; returns its :class:`Ticket`.

        Raises :class:`SchedulerOverloaded` when the admission queue is
        full — callers shed load or flush and retry."""
        if self._closed:
            raise RuntimeError("session is closed")
        if isinstance(char, str):
            char = char.encode()
        if isinstance(char, (bytes, bytearray)):
            if len(char) != 1:
                raise ValueError("submit takes exactly one keystroke")
            char = char[0]
        # mutate only after admission: a backpressure rejection must leave
        # the session's prefix and reset flag exactly as they were
        prefix = bytes(self._prefix) + bytes([int(char)])
        ticket = self.scheduler._enqueue(
            self, int(char), want_topk, self._reset_pending, prefix)
        self._reset_pending = False
        self._prefix.append(int(char))
        return ticket

    # -- blocking Session-compatible surface -------------------------------

    def type(self, text: str | bytes) -> list[tuple[int, str]]:
        """Feed keystrokes and return the top-k for the new prefix.

        Each char is one scheduler ticket (chars of one session are
        sequentially dependent, so they ride consecutive flushes); the
        call blocks until the last one resolves."""
        data = text.encode() if isinstance(text, str) else bytes(text)
        if not data:
            return self.topk()
        tickets = [self.submit(bytes([b])) for b in data]
        return tickets[-1].result(self.scheduler)

    def topk(self, k: int | None = None) -> list[tuple[int, str]]:
        """Top-k for the current prefix (a reset-only/no-op flush when
        nothing is pending on this lane)."""
        if k is not None and k != self.k:
            return self.scheduler.index.complete(
                [bytes(self._prefix)], k=k)[0]
        ticket = self.scheduler._enqueue(self, -1, True,
                                         self._reset_pending,
                                         bytes(self._prefix))
        self._reset_pending = False
        return ticket.result(self.scheduler)

    def backspace(self, n: int = 1) -> list[tuple[int, str]]:
        """Remove the last ``n`` keystrokes.

        The slab holds only the newest frontier per lane (no per-keystroke
        history — that is the memory price of packing sessions into a
        fixed slab), so backspace replays the shortened prefix through the
        batch path."""
        kept = bytes(self._prefix[:max(len(self._prefix) - n, 0)])
        self.reset()
        if not kept:
            return self.topk()
        return self.type(kept)

    def reset(self) -> None:
        """Restart at the empty prefix.

        Free at submit time: the reset rides the next ticket's flush as a
        lane re-init mask folded into the same batched advance dispatch."""
        self._prefix.clear()
        self._reset_pending = True

    def close(self) -> None:
        """Release the lane back to the scheduler."""
        if not self._closed:
            self.scheduler._release(self)
            self._closed = True


class KeystrokeScheduler:
    """Admission queue + fixed-shape slab + micro-batch flush loop."""

    def __init__(self, index, *, block: int = 8, max_wait_ms: float = 2.0,
                 max_queue: int | None = None, on_keystroke=None,
                 clock=time.perf_counter):
        """index: a CompletionIndex (needs the slab entry points).
        block: lanes per slab = the fixed jit batch shape = max
            concurrent sessions.
        max_wait_ms: latency budget; a queued keystroke older than this
            triggers a partial-block deadline flush on the next
            submit/pump.
        max_queue: admission-queue bound across all lanes (default
            ``4 * block``); beyond it submits raise SchedulerOverloaded.
        on_keystroke: optional callable(latency_seconds) invoked per
            resolved result-bearing keystroke (the service's stats hook).
        clock: injectable monotonic clock (tests drive deadlines with a
            fake one)."""
        if block < 1:
            raise ValueError("block must be >= 1")
        self.index = index
        self.block = block
        self.max_wait_ms = max_wait_ms
        self.max_queue = 4 * block if max_queue is None else max_queue
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        self.on_keystroke = on_keystroke
        self.clock = clock
        self.stats = BatchStats()
        self._init_fn, self._advance_fn = index._slab_fns(block)
        self._slab = jax.block_until_ready(self._init_fn())
        self._topk_fns: dict[int, object] = {}   # k -> jitted slab top-k
        # one stashed flush of un-demuxed results: [(k, tickets, device
        # handles)] — settled after the NEXT flush's dispatch so host-side
        # decode overlaps device compute (see _flush)
        self._unsettled: list | None = None
        self._lanes: list[BatchSession | None] = [None] * block
        self._queues: list[collections.deque[Ticket]] = [
            collections.deque() for _ in range(block)]
        self._draining = [False] * block
        # per-lane prefix as of the last *consumed* ticket (== what the
        # slab's frontier actually encodes — the session's own _prefix
        # runs ahead of it by whatever is still queued); this is the
        # replay source for epoch migration
        self._consumed: list[bytes] = [b""] * block
        self._epoch = index.epoch
        self._pending = 0
        # O(1) mirrors of _ready_lanes()/_occupied() for the per-submit
        # pump hot path (scanning every lane per keystroke is measurable)
        self._n_ready = 0
        self._n_occupied = 0

    # -- sessions ----------------------------------------------------------

    def open(self, k: int = 10) -> BatchSession:
        """Pin a free lane to a new session (its state starts at the
        slab's empty-prefix init, so no device work is needed here)."""
        for lane, owner in enumerate(self._lanes):
            if owner is None:
                session = BatchSession(self, lane, k)
                # a recycled lane may carry the previous owner's frontier;
                # re-init rides the first ticket's flush like reset()
                session._reset_pending = True
                self._lanes[lane] = session
                self._consumed[lane] = b""
                self._n_occupied += 1
                return session
        raise SchedulerOverloaded(
            f"all {self.block} lanes are held by open sessions; close "
            f"one or build the scheduler with a larger block")

    def _release(self, session: BatchSession) -> None:
        # deferred release: in-flight keystrokes keep riding normal
        # flushes (forcing partial flushes here would collapse occupancy
        # every time a session ends); the lane frees once its queue
        # empties, and meanwhile it stops counting toward the full-flush
        # condition via _occupied
        if self._queues[session.lane]:
            self._draining[session.lane] = True
        else:
            self._lanes[session.lane] = None
            self._consumed[session.lane] = b""
            self._n_occupied -= 1

    # -- admission ---------------------------------------------------------

    def _enqueue(self, session: BatchSession, char: int, want_topk: bool,
                 reset_first: bool, prefix: bytes) -> Ticket:
        if self._lanes[session.lane] is not session:
            raise RuntimeError("session does not own its lane (closed?)")
        if self._pending >= self.max_queue:
            self.stats.rejected += 1
            raise SchedulerOverloaded(
                f"admission queue full ({self._pending} pending >= "
                f"max_queue={self.max_queue}); drain or shed load")
        ticket = Ticket(lane=session.lane, char=char, want_topk=want_topk,
                        k=session.k, created=self.clock(), prefix=prefix,
                        reset_first=reset_first)
        self._queues[session.lane].append(ticket)
        if len(self._queues[session.lane]) == 1:
            self._n_ready += 1
        self._pending += 1
        self.pump()
        return ticket

    # -- flush machinery ---------------------------------------------------

    @property
    def pending(self) -> int:
        """Queued keystrokes not yet consumed by a flush."""
        return self._pending

    def _occupied(self) -> int:
        return sum(o is not None for o in self._lanes)

    def _ready_lanes(self) -> list[int]:
        return [i for i, q in enumerate(self._queues) if q]

    def _oldest_age_ms(self, now: float) -> float:
        heads = [q[0].created for q in self._queues if q]
        return (now - min(heads)) * 1e3 if heads else 0.0

    def pump(self, now: float | None = None) -> int:
        """Fire due flushes: full blocks immediately, partial blocks once
        the oldest queued keystroke ages past ``max_wait_ms``.  Returns
        the number of flushes fired.  Drivers interleaving many sessions
        call this in their event loop; ``submit`` calls it internally."""
        fired = 0
        while self._pending:
            # "full" = every occupied lane has a keystroke queued: waiting
            # longer cannot raise this flush's occupancy (each lane
            # contributes at most one char), so fire immediately
            if self._n_ready > 0 and self._n_ready == self._n_occupied:
                self._flush(kind="full")
                fired += 1
                continue
            now_ = self.clock() if now is None else now
            if self._oldest_age_ms(now_) >= self.max_wait_ms:
                self._flush(kind="deadline")
                fired += 1
                continue
            break
        return fired

    def poll(self, now: float | None = None) -> int:
        """Non-blocking driver tick: :meth:`pump`, plus idle settling.

        ``pump`` alone starves the pipeline's tail — the last flush's
        results stay stashed (computed on device, never demuxed) until
        another flush or an explicit ``flush()``/``drain()``, so a driver
        looping on ``pump()`` and checking ``ticket.done`` spins forever
        once the queue empties.  ``poll`` settles the stash as soon as
        nothing is queued, making it the one call an event loop needs."""
        fired = self.pump(now)
        if not self._pending:
            self._settle()
        return fired

    def flush(self) -> None:
        """Force one partial-block flush (drain/result paths); settles
        stashed results when nothing is queued."""
        if self._pending:
            self._flush(kind="forced")
        else:
            self._settle()

    def drain(self) -> None:
        """Flush until no keystroke is queued or awaiting demux."""
        while self._pending:
            self._flush(kind="forced")
        self._settle()

    def _flush(self, kind: str) -> None:
        if self._epoch != self.index.epoch:
            self._migrate()
        # one ticket per lane, FIFO within the lane
        taken: list[Ticket] = []
        chars = np.full((self.block,), -1, np.int32)
        resets = np.zeros((self.block,), bool)
        for lane in self._ready_lanes():
            t = self._queues[lane].popleft()
            taken.append(t)
            chars[lane] = t.char
            resets[lane] = t.reset_first
            # t.prefix is the lane prefix after this keystroke (resets
            # included), which is exactly what the slab encodes once this
            # flush's advance lands
            self._consumed[lane] = t.prefix
            if not self._queues[lane]:
                self._n_ready -= 1
                if self._draining[lane]:
                    self._lanes[lane] = None   # deferred close completes
                    self._draining[lane] = False
                    self._consumed[lane] = b""
                    self._n_occupied -= 1
        self._pending -= len(taken)
        self._slab = self._advance_fn(self._slab, chars, resets)
        st = self.stats
        st.n_flushes += 1
        st.sum_occupancy += len(taken)
        st.n_keystrokes += sum(t.char >= 0 for t in taken)
        if kind == "full":
            st.full_flushes += 1
        elif kind == "deadline":
            st.deadline_flushes += 1
        else:
            st.forced_flushes += 1
        now = self.clock()
        for t in taken:
            if not t.want_topk:     # advance-only keystrokes resolve here
                t.done = True
                t.latency_s = now - t.created
        # pipeline: dispatch this flush's top-k (one batched call per
        # distinct k — usually one; jax dispatch is async so these return
        # device handles immediately), stash it, and only then settle the
        # *previous* flush — its device_get is nearly free by now and the
        # host-side demux/decode runs while this flush computes on device
        prev = self._unsettled
        self._unsettled = None
        wanting = [t for t in taken if t.want_topk]
        if wanting:
            by_k: dict[int, list[Ticket]] = {}
            for t in wanting:
                by_k.setdefault(t.k, []).append(t)
            stash = []
            for k, tickets in sorted(by_k.items()):
                topk_fn = self._topk_fns.get(k)
                if topk_fn is None:
                    topk_fn = self._topk_fns[k] = \
                        self.index._slab_topk_fn(self.block, k)
                stash.append((k, tickets, topk_fn(self._slab)))
            self._unsettled = stash
        if prev:
            self._settle_handles(prev)

    def _migrate(self) -> None:
        """Rebuild the slab on the index's current epoch (hot-swap /
        reconfigure migration at the flush boundary).

        The stashed flush settles first — its handles are plain device
        arrays computed on the old tables, still valid to demux.  Then
        the slab fns are refetched (the swap cleared the compile cache /
        the reconfigure changed the cfg key) and every lane's *consumed*
        prefix is replayed column-wise: one batched advance per step of
        the longest prefix, idle lanes riding as -1 no-ops.  Queued
        keystrokes are untouched — they consume from the rebuilt slab on
        the flushes that follow, so nothing is lost or reordered."""
        self._settle()
        self._init_fn, self._advance_fn = self.index._slab_fns(self.block)
        self._topk_fns = {}
        slab = self._init_fn()
        no_reset = np.zeros((self.block,), bool)
        for step in range(max(map(len, self._consumed), default=0)):
            chars = np.full((self.block,), -1, np.int32)
            for lane, p in enumerate(self._consumed):
                if step < len(p):
                    chars[lane] = p[step]
            slab = self._advance_fn(slab, chars, no_reset)
        self._slab = jax.block_until_ready(slab)
        self._epoch = self.index.epoch
        self.stats.migrations += 1

    def _settle(self) -> None:
        """Resolve the stashed flush, if any (the pipeline's tail)."""
        prev = self._unsettled
        self._unsettled = None
        if prev:
            self._settle_handles(prev)

    def _settle_handles(self, stash) -> None:
        # each stashed entry holds the full [block, k] slab result; the
        # lanes wanting that k are picked out
        for k, tickets, handles in stash:
            scores, sids, exact = jax.device_get(handles)
            for t in tickets:
                if not bool(exact[t.lane]):
                    self.stats.fallbacks += 1
                t.results = resolve_topk(
                    self.index, scores[t.lane], sids[t.lane],
                    exact[t.lane], t.prefix, k)
                t.done = True
                t.latency_s = self.clock() - t.created
                if self.on_keystroke is not None:
                    self.on_keystroke(t.latency_s)
