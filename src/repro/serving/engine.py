"""Serving engine: request batcher + continuous-batching LM decode loop.

SlotScheduler keeps a fixed decode batch (the jit shape) and swaps finished
requests for queued ones between steps — vLLM-style continuous batching
mapped onto fixed-shape JAX: per-slot KV caches live in one stacked cache
pytree, positions are a per-slot vector, and a slot is recycled by
prefilling the new prompt into its cache lane.
"""

from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as tf


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # int32[prompt_len]
    max_new_tokens: int = 16
    created: float = field(default_factory=time.perf_counter)
    tokens: list = field(default_factory=list)
    done: bool = False
    first_token_at: float | None = None
    finished_at: float | None = None


class SlotScheduler:
    """Continuous batching over `n_slots` decode lanes."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.slots: list[Request | None] = [None] * n_slots
        self.completed: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def admit(self) -> list[tuple[int, Request]]:
        new = []
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                r = self.queue.popleft()
                self.slots[i] = r
                new.append((i, r))
        return new

    def record(self, slot_tokens: np.ndarray, eos_id: int | None = None):
        now = time.perf_counter()
        for i, r in enumerate(self.slots):
            if r is None:
                continue
            tok = int(slot_tokens[i])
            if r.first_token_at is None:
                r.first_token_at = now
            r.tokens.append(tok)
            if len(r.tokens) >= r.max_new_tokens or \
                    (eos_id is not None and tok == eos_id):
                r.done = True
                r.finished_at = now
                self.completed.append(r)
                self.slots[i] = None

    @property
    def active(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)


class LMServer:
    """Batched prefill + continuous-batching greedy decode."""

    def __init__(self, params, cfg: tf.TransformerConfig, *, n_slots: int,
                 max_len: int):
        self.params = params
        self.cfg = cfg
        self.scheduler = SlotScheduler(n_slots)
        self.max_len = max_len
        self.cache = tf.init_cache(cfg, n_slots, max_len, jnp.float32)
        self.cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        self.cur = np.zeros((n_slots,), np.int32)
        self.live = np.zeros((n_slots,), bool)

        @jax.jit
        def prefill_into_slot(params, cache, tokens, slot):
            logits, new = tf.prefill(params, tokens[None], cfg, max_len,
                                     cache_dtype=jnp.float32)
            k = jax.lax.dynamic_update_slice(
                cache["k"], new["k"].astype(cache["k"].dtype),
                (0, slot, 0, 0, 0))
            v = jax.lax.dynamic_update_slice(
                cache["v"], new["v"].astype(cache["v"].dtype),
                (0, slot, 0, 0, 0))
            pos = cache["pos"].at[slot].set(tokens.shape[0])
            return logits[0], {"k": k, "v": v, "pos": pos}

        @jax.jit
        def decode(params, cache, tokens):
            return tf.decode_step(params, cache, tokens, cfg)

        self._prefill = prefill_into_slot
        self._decode = decode

    def run(self, eos_id: int | None = None, max_steps: int = 100_000):
        sched = self.scheduler
        steps = 0
        while sched.active and steps < max_steps:
            for slot, req in sched.admit():
                logits, self.cache = self._prefill(
                    self.params, self.cache, jnp.asarray(req.prompt),
                    slot)
                self.cur[slot] = int(np.argmax(np.asarray(logits)))
                self.live[slot] = True
            if not self.live.any():
                break
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self.cur))
            emitted = self.cur.copy()
            nxt = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
            sched.record(emitted, eos_id)
            self.cur = nxt
            for i, r in enumerate(sched.slots):
                if r is None:
                    self.live[i] = False
            steps += 1
        return sched.completed
