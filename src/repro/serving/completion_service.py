"""CompletionService: the paper's technique as the serving front-end.

Wraps a (sharded or local) completion index; optionally re-ranks the trie's
top-k candidates with any model from the zoo (LM log-prob or recsys user
affinity) — trie proposes cheaply, the model spends FLOPs only on k
candidates (DESIGN §3.1).

``open_session`` exposes the incremental per-keystroke path: a
:class:`ServiceSession` advances the index's resumable locus frontier one
char at a time and folds per-keystroke latency into the service stats.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


LATENCY_WINDOW = 4096  # bound per-request/per-keystroke latency history


def _percentile(xs: list, q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(int(len(s) * q), len(s) - 1)]


def _record(xs: list, value_ms: float) -> None:
    """Append keeping only the trailing LATENCY_WINDOW samples, so stats on
    a long-lived service stay O(window) in memory and percentile cost."""
    xs.append(value_ms)
    if len(xs) > LATENCY_WINDOW:
        del xs[:len(xs) - LATENCY_WINDOW]


@dataclass
class ServiceStats:
    n_queries: int = 0
    total_seconds: float = 0.0
    batches: int = 0
    latencies_ms: list = field(default_factory=list)
    # incremental (per-keystroke) accounting
    n_keystrokes: int = 0
    keystroke_seconds: float = 0.0
    keystroke_latencies_ms: list = field(default_factory=list)

    @property
    def mean_latency_ms(self) -> float:
        return (self.total_seconds / max(self.n_queries, 1)) * 1e3

    @property
    def mean_keystroke_ms(self) -> float:
        return (self.keystroke_seconds / max(self.n_keystrokes, 1)) * 1e3

    def p99_ms(self) -> float:
        return _percentile(self.latencies_ms, 0.99)

    def p99_keystroke_ms(self) -> float:
        return _percentile(self.keystroke_latencies_ms, 0.99)

    def reset_keystrokes(self) -> None:
        """Discard keystroke accounting (e.g. after jit warmup)."""
        self.n_keystrokes = 0
        self.keystroke_seconds = 0.0
        self.keystroke_latencies_ms.clear()


class ServiceSession:
    """One user's typing stream through the service (stats + reranking)."""

    def __init__(self, service: "CompletionService", k: int):
        self.service = service
        self.k = k
        fetch_k = k * (service.overfetch if service.reranker else 1)
        self._session = service.index.session(k=fetch_k)

    @property
    def prefix(self) -> str:
        return self._session.prefix

    def type(self, text: str | bytes) -> list[tuple[float, str]]:
        """Feed keystrokes; returns (re-ranked) top-k for the new prefix."""
        data = text.encode() if isinstance(text, str) else bytes(text)
        if not data:
            results = self._session.topk()
        for i in range(len(data)):
            t0 = time.perf_counter()
            results = self._session.type(data[i:i + 1])
            dt = time.perf_counter() - t0
            stats = self.service.stats
            stats.n_keystrokes += 1
            stats.keystroke_seconds += dt
            _record(stats.keystroke_latencies_ms, dt * 1e3)
        if self.service.reranker is not None:
            results = self.service.reranker(self.prefix, results)
        return results[:self.k]

    def backspace(self, n: int = 1) -> list[tuple[float, str]]:
        results = self._session.backspace(n)
        if self.service.reranker is not None:
            results = self.service.reranker(self.prefix, results)
        return results[:self.k]

    def reset(self) -> None:
        self._session.reset()


class CompletionService:
    def __init__(self, index, reranker=None, overfetch: int = 4):
        """index: CompletionIndex or ShardedCompletionIndex.
        reranker: callable(query, [(score, string)]) -> [(score, string)].
        overfetch: fetch overfetch*k trie candidates before reranking."""
        self.index = index
        self.reranker = reranker
        self.overfetch = overfetch
        self.stats = ServiceStats()

    def complete(self, queries: list[str], k: int = 10):
        t0 = time.perf_counter()
        fetch_k = k * (self.overfetch if self.reranker else 1)
        results = self.index.complete(queries, k=fetch_k)
        if self.reranker is not None:
            results = [self.reranker(q, r)[:k] for q, r in zip(queries, results)]
        else:
            results = [r[:k] for r in results]
        dt = time.perf_counter() - t0
        self.stats.n_queries += len(queries)
        self.stats.total_seconds += dt
        self.stats.batches += 1
        _record(self.stats.latencies_ms, dt / max(len(queries), 1) * 1e3)
        return results

    def open_session(self, k: int = 10) -> ServiceSession:
        """Start a stateful per-keystroke session (requires an index with
        ``.session()``, i.e. a local CompletionIndex)."""
        return ServiceSession(self, k)
