"""CompletionService: the paper's technique as the serving front-end.

Wraps a (sharded or local) completion index; optionally re-ranks the trie's
top-k candidates with any model from the zoo (LM log-prob or recsys user
affinity) — trie proposes cheaply, the model spends FLOPs only on k
candidates (DESIGN §3.1).

``open_session`` exposes the incremental per-keystroke path: a
:class:`ServiceSession` advances the index's resumable locus frontier one
char at a time and folds per-keystroke latency into the service stats.
With ``batching=True`` the service owns a
:class:`~repro.serving.scheduler.KeystrokeScheduler` and sessions ride
shared fixed-shape micro-batches instead of paying one dispatch per
keystroke — same results (bit-identical demux), one batched advance/top-k
per coalesced block.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


LATENCY_WINDOW = 4096  # bound per-request/per-keystroke latency history


def _percentile(xs: list, q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(int(len(s) * q), len(s) - 1)]


def _record(xs: list, value_ms: float) -> None:
    """Append keeping only the trailing LATENCY_WINDOW samples, so stats on
    a long-lived service stay O(window) in memory and percentile cost."""
    xs.append(value_ms)
    if len(xs) > LATENCY_WINDOW:
        del xs[:len(xs) - LATENCY_WINDOW]


@dataclass
class ServiceStats:
    n_queries: int = 0
    total_seconds: float = 0.0
    batches: int = 0
    latencies_ms: list = field(default_factory=list)
    # incremental (per-keystroke) accounting
    n_keystrokes: int = 0
    keystroke_seconds: float = 0.0
    keystroke_latencies_ms: list = field(default_factory=list)

    @property
    def mean_latency_ms(self) -> float:
        return (self.total_seconds / max(self.n_queries, 1)) * 1e3

    @property
    def mean_keystroke_ms(self) -> float:
        return (self.keystroke_seconds / max(self.n_keystrokes, 1)) * 1e3

    @property
    def p50_latency_ms(self) -> float:
        return _percentile(self.latencies_ms, 0.50)

    @property
    def p99_latency_ms(self) -> float:
        return _percentile(self.latencies_ms, 0.99)

    def p99_ms(self) -> float:
        return self.p99_latency_ms

    def p50_keystroke_ms(self) -> float:
        return _percentile(self.keystroke_latencies_ms, 0.50)

    def p99_keystroke_ms(self) -> float:
        return _percentile(self.keystroke_latencies_ms, 0.99)

    def record_keystroke(self, seconds: float) -> None:
        """Fold one per-keystroke latency sample in (the scheduler's demux
        hook and the sequential session's timer share this path)."""
        self.n_keystrokes += 1
        self.keystroke_seconds += seconds
        _record(self.keystroke_latencies_ms, seconds * 1e3)

    def reset_keystrokes(self) -> None:
        """Discard keystroke accounting (e.g. after jit warmup)."""
        self.n_keystrokes = 0
        self.keystroke_seconds = 0.0
        self.keystroke_latencies_ms.clear()


class ServiceSession:
    """One user's typing stream through the service (stats + reranking)."""

    def __init__(self, service: "CompletionService", k: int):
        self.service = service
        self.k = k
        fetch_k = k * (service.overfetch if service.reranker else 1)
        if service.batching:
            # batched sessions share the scheduler's slab; per-keystroke
            # latency (queue wait + flush + demux) is recorded by the
            # scheduler's demux hook, not a wall timer here
            self._session = service._scheduler().open(k=fetch_k)
            self._timed = False
        else:
            self._session = service.index.session(k=fetch_k)
            self._timed = True

    @property
    def prefix(self) -> str:
        return self._session.prefix

    def submit(self, char: int | bytes | str, want_topk: bool = True):
        """Non-blocking enqueue of one keystroke (batching mode only);
        returns the scheduler Ticket.  This is the entry point drivers use
        to keep many sessions in flight so keystrokes coalesce."""
        if not self.service.batching:
            raise RuntimeError(
                "submit() needs a batching service; construct "
                "CompletionService(..., batching=True) or use type()")
        return self._session.submit(char, want_topk=want_topk)

    def type(self, text: str | bytes) -> list[tuple[float, str]]:
        """Feed keystrokes; returns (re-ranked) top-k for the new prefix."""
        data = text.encode() if isinstance(text, str) else bytes(text)
        if not data:
            results = self._session.topk()
        for i in range(len(data)):
            t0 = time.perf_counter()
            results = self._session.type(data[i:i + 1])
            if self._timed:
                self.service.stats.record_keystroke(
                    time.perf_counter() - t0)
        if self.service.reranker is not None:
            results = self.service.reranker(self.prefix, results)
        return results[:self.k]

    def backspace(self, n: int = 1) -> list[tuple[float, str]]:
        results = self._session.backspace(n)
        if self.service.reranker is not None:
            results = self.service.reranker(self.prefix, results)
        return results[:self.k]

    def reset(self) -> None:
        self._session.reset()

    def close(self) -> None:
        """Release the session's scheduler lane (no-op when unbatched)."""
        close = getattr(self._session, "close", None)
        if close is not None:
            close()


class CompletionService:
    def __init__(self, index, reranker=None, overfetch: int = 4, *,
                 batching: bool = False, block: int = 8,
                 max_wait_ms: float = 2.0, max_queue: int | None = None):
        """index: CompletionIndex or ShardedCompletionIndex.
        reranker: callable(query, [(score, string)]) -> [(score, string)].
        overfetch: fetch overfetch*k trie candidates before reranking.
        batching: route per-keystroke sessions through the continuous-
            batching scheduler (block/max_wait_ms/max_queue are its
            micro-batch width, latency budget, and admission bound)."""
        self.index = index
        self.reranker = reranker
        self.overfetch = overfetch
        self.batching = batching
        self.block = block
        self.max_wait_ms = max_wait_ms
        self.max_queue = max_queue
        self.stats = ServiceStats()
        self.scheduler = None

    def complete(self, queries: list[str], k: int = 10):
        t0 = time.perf_counter()
        fetch_k = k * (self.overfetch if self.reranker else 1)
        results = self.index.complete(queries, k=fetch_k)
        if self.reranker is not None:
            results = [self.reranker(q, r)[:k] for q, r in zip(queries, results)]
        else:
            results = [r[:k] for r in results]
        dt = time.perf_counter() - t0
        self.stats.n_queries += len(queries)
        self.stats.total_seconds += dt
        self.stats.batches += 1
        # every request in a synchronous batch waits the full batch wall
        # time, so each gets the true dt sample — a single dt/batch mean
        # would understate the tail by the batch width
        for _ in queries:
            _record(self.stats.latencies_ms, dt * 1e3)
        return results

    def _scheduler(self):
        if self.scheduler is None:
            from repro.serving.scheduler import KeystrokeScheduler

            self.scheduler = KeystrokeScheduler(
                self.index, block=self.block, max_wait_ms=self.max_wait_ms,
                max_queue=self.max_queue,
                on_keystroke=self.stats.record_keystroke)
        return self.scheduler

    def pump(self) -> int:
        """Fire due scheduler flushes (batching mode drivers call this in
        their event loop); returns the number of flushes fired."""
        return self._scheduler().pump() if self.batching else 0

    def poll(self) -> int:
        """Like :meth:`pump` but also settles the scheduler's pipelined
        result stash once the queue is empty, so the last flush's tickets
        resolve even when no further keystrokes arrive.  Event-loop
        drivers should prefer this over ``pump``."""
        return self._scheduler().poll() if self.batching else 0

    def flush(self) -> None:
        """Force one partial-block flush (e.g. to make room after a
        SchedulerOverloaded rejection without collapsing the queue)."""
        if self.batching and self.scheduler is not None:
            self.scheduler.flush()

    def drain(self) -> None:
        """Flush the scheduler until no keystroke is in flight."""
        if self.batching and self.scheduler is not None:
            self.scheduler.drain()

    def compact(self, handoff_path: str | None = None
                ) -> "CompletionService":
        """Fold the index's pending mutations into a fresh index and
        hot-swap it under the live sessions.

        The swap bumps the index epoch; sequential sessions and the
        scheduler's slab migrate at their next keystroke boundary by
        replaying their retained prefixes, so no open session drops a
        keystroke or loses its prefix.  ``handoff_path`` routes the swap
        through the npz container (restart-without-downtime shape)."""
        compact = getattr(self.index, "compact", None)
        if not callable(compact):
            from repro.core.distributed import UnsupportedOnShardedIndex
            raise UnsupportedOnShardedIndex(
                f"compact() needs a local CompletionIndex; "
                f"{type(self.index).__name__} has no mutation overlay — "
                f"mutate and compact the per-shard indexes instead")
        compact(handoff_path)
        return self

    def open_session(self, k: int = 10) -> ServiceSession:
        """Start a stateful per-keystroke session.

        Requires an index with the incremental session entry points (a
        local :class:`~repro.api.index.CompletionIndex`).  With
        ``batching=True`` the session transparently rides the service's
        shared micro-batches."""
        if not callable(getattr(self.index, "session", None)) or \
                not callable(getattr(self.index, "_slab_fns", None)):
            from repro.core.distributed import UnsupportedOnShardedIndex
            raise UnsupportedOnShardedIndex(
                f"per-keystroke sessions need a local CompletionIndex; "
                f"{type(self.index).__name__} does not support them yet "
                f"(sharded sessions would need a resumable cross-shard "
                f"frontier — use complete() for batch lookups instead)")
        return ServiceSession(self, k)
