"""CompletionService: the paper's technique as the serving front-end.

Wraps a (sharded or local) completion index; optionally re-ranks the trie's
top-k candidates with any model from the zoo (LM log-prob or recsys user
affinity) — trie proposes cheaply, the model spends FLOPs only on k
candidates (DESIGN §3.1).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field


@dataclass
class ServiceStats:
    n_queries: int = 0
    total_seconds: float = 0.0
    batches: int = 0
    latencies_ms: list = field(default_factory=list)

    @property
    def mean_latency_ms(self) -> float:
        return (self.total_seconds / max(self.n_queries, 1)) * 1e3

    def p99_ms(self) -> float:
        if not self.latencies_ms:
            return 0.0
        xs = sorted(self.latencies_ms)
        return xs[min(int(len(xs) * 0.99), len(xs) - 1)]


class CompletionService:
    def __init__(self, index, reranker=None, overfetch: int = 4):
        """index: CompletionIndex or ShardedCompletionIndex.
        reranker: callable(query, [(score, string)]) -> [(score, string)].
        overfetch: fetch overfetch*k trie candidates before reranking."""
        self.index = index
        self.reranker = reranker
        self.overfetch = overfetch
        self.stats = ServiceStats()

    def complete(self, queries: list[str], k: int = 10):
        t0 = time.perf_counter()
        fetch_k = k * (self.overfetch if self.reranker else 1)
        results = self.index.complete(queries, k=fetch_k)
        if self.reranker is not None:
            results = [self.reranker(q, r)[:k] for q, r in zip(queries, results)]
        else:
            results = [r[:k] for r in results]
        dt = time.perf_counter() - t0
        self.stats.n_queries += len(queries)
        self.stats.total_seconds += dt
        self.stats.batches += 1
        self.stats.latencies_ms.append(dt / max(len(queries), 1) * 1e3)
        return results
