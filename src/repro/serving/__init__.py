from repro.serving.completion_service import CompletionService, ServiceStats
from repro.serving.engine import LMServer, Request, SlotScheduler

__all__ = ["CompletionService", "ServiceStats", "LMServer", "Request",
           "SlotScheduler"]
