from repro.serving.completion_service import (CompletionService,
                                              ServiceSession, ServiceStats)
from repro.serving.engine import LMServer, Request, SlotScheduler

__all__ = ["CompletionService", "ServiceSession", "ServiceStats", "LMServer",
           "Request", "SlotScheduler"]
