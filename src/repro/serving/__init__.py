from repro.serving.completion_service import (CompletionService,
                                              ServiceSession, ServiceStats)
from repro.serving.engine import LMServer, Request, SlotScheduler
from repro.serving.scheduler import (BatchSession, BatchStats,
                                     KeystrokeScheduler, SchedulerOverloaded,
                                     Ticket)

__all__ = ["CompletionService", "ServiceSession", "ServiceStats", "LMServer",
           "Request", "SlotScheduler", "KeystrokeScheduler", "BatchSession",
           "BatchStats", "SchedulerOverloaded", "Ticket"]
