from repro.optim.optimizers import (OptimizerConfig, init_optimizer,
                                    apply_updates, lr_at)

__all__ = ["OptimizerConfig", "init_optimizer", "apply_updates", "lr_at"]
