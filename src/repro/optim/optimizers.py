"""Optimizers: AdamW, Adafactor (factored second moment — what lets
arctic-480b's optimizer state fit a pod), SGD; warmup+cosine schedule,
global-norm clipping, gradient accumulation helper.

States are pytrees mirroring params, so the same logical-axes sharding tree
shards optimizer state (ZeRO-1-style when the rules spread rows over dp).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"            # adamw | adafactor | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    accum_steps: int = 1
    # adafactor
    factored_dims_min: int = 2
    decay_rate: float = 0.8


def lr_at(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def _is_factored(shape, cfg):
    return len(shape) >= cfg.factored_dims_min and min(shape[-2:]) >= 2


def init_optimizer(cfg: OptimizerConfig, params):
    if cfg.name == "adamw":
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }
    if cfg.name == "adafactor":
        def vr(p):
            if _is_factored(p.shape, cfg):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros_like(p, jnp.float32)

        def vc(p):
            if _is_factored(p.shape, cfg):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((1,), jnp.float32)

        return {
            "vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "step": jnp.zeros((), jnp.int32),
        }
    if cfg.name == "sgd":
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                "step": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale, grads), g


def apply_updates(cfg: OptimizerConfig, params, grads, state):
    """One optimizer step. Returns (new_params, new_state, metrics).

    Gradients are cast to fp32 *per leaf inside the update* (never as a
    whole tree) so the peak live set is one leaf's temporaries, not an
    entire second gradient tree — this is what lets arctic-480b's step fit
    HBM (EXPERIMENTS.md §Perf)."""
    gnorm = global_norm(grads)
    scale = jnp.float32(1.0)
    if cfg.clip_norm > 0:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state["step"] + 1
    lr = lr_at(cfg, step)

    if cfg.name == "adamw":
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m_, v_):
            g = g.astype(jnp.float32) * scale
            m2 = cfg.b1 * m_ + (1 - cfg.b1) * g
            v2 = cfg.b2 * v_ + (1 - cfg.b2) * g * g
            u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        leaves, tdef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = tdef.unflatten([l[0] for l in leaves])
        m = tdef.unflatten([l[1] for l in leaves])
        v = tdef.unflatten([l[2] for l in leaves])
        return new_params, {"m": m, "v": v, "step": step}, \
            {"lr": lr, "grad_norm": gnorm}

    if cfg.name == "adafactor":
        beta2 = 1.0 - step.astype(jnp.float32) ** (-cfg.decay_rate)

        def upd(p, g, vr, vc):
            g = g.astype(jnp.float32) * scale
            g2 = g * g + 1e-30
            if _is_factored(p.shape, cfg):
                vr2 = beta2 * vr + (1 - beta2) * g2.mean(axis=-1)
                vc2 = beta2 * vc + (1 - beta2) * g2.mean(axis=-2)
                r_factor = vr2 / jnp.maximum(
                    vr2.mean(axis=-1, keepdims=True), 1e-30)
                u = g * jax.lax.rsqrt(r_factor)[..., None] \
                    * jax.lax.rsqrt(vc2 / 1.0)[..., None, :]
            else:
                vr2 = beta2 * vr + (1 - beta2) * g2
                vc2 = vc
                u = g * jax.lax.rsqrt(vr2)
            # update clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            u = u + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr2, vc2

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_vr = tdef.flatten_up_to(state["vr"])
        flat_vc = tdef.flatten_up_to(state["vc"])
        out = [upd(p, g, vr, vc) for p, g, vr, vc
               in zip(flat_p, flat_g, flat_vr, flat_vc)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_vr = tdef.unflatten([o[1] for o in out])
        new_vc = tdef.unflatten([o[2] for o in out])
        return new_params, {"vr": new_vr, "vc": new_vc, "step": step}, \
            {"lr": lr, "grad_norm": gnorm}

    if cfg.name == "sgd":
        m = jax.tree.map(
            lambda m_, g: cfg.b1 * m_ + g.astype(jnp.float32) * scale,
            state["m"], grads)
        new_params = jax.tree.map(
            lambda p, m_: (p.astype(jnp.float32) - lr * m_).astype(p.dtype),
            params, m)
        return new_params, {"m": m, "step": step}, \
            {"lr": lr, "grad_norm": gnorm}
    raise ValueError(cfg.name)
