"""CompletionIndex: the queryable, persistable, *mutable* completion index.

Construction lives in :mod:`repro.api.build` (driven by an
:class:`~repro.api.spec.IndexSpec`); this module owns the device arrays,
the bounded compile cache, batched lookup with the exactness-retry guard,
persistence, the session entry points, and the online-mutation surface:

- ``insert``/``delete``/``update_score`` absorb changes into a
  :class:`~repro.core.engine.overlay.DeltaOverlay` (tombstones + a small
  side-index) merged into results at top-k time — no rebuild per change;
- ``compact()`` folds the overlay into a freshly built index and
  hot-swaps it in place.  The index is *epoch-versioned*: every swap (and
  every :meth:`reconfigure`) bumps ``epoch``, and live sessions /
  scheduler slabs migrate onto the new epoch at their next keystroke
  boundary by replaying their retained prefixes;
- ``reconfigure(...)`` is the single runtime-knob entry point (substrate,
  memory budget, engine widths), revalidating through ``IndexSpec``.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.build import BuildStats, build_index
from repro.api.compile_cache import CompileCache, bucket_size
from repro.api.spec import IndexSpec
from repro.core import engine as eng
from repro.core import trie_build as tb
from repro.core.alphabet import pad_queries


def _to_device(trie: tb.DictTrie, rule_trie: tb.RuleTrie) -> eng.DeviceTrie:
    j = jnp.asarray
    has_cache = trie.topk_score is not None
    dummy = np.full((1, 1), -1, np.int32)
    if trie.has_packed:
        # compressed layout: only the packed side tables (their narrow
        # dtypes preserved), the kept link store, and the rule trie go to
        # the device — the dense planes ship as 0-size dummies so the
        # NamedTuple stays a uniform pytree while costing nothing
        z1 = jnp.zeros((0,), jnp.int32)
        z2 = jnp.zeros((0, 1), jnp.int32)
        packed_kw = {f: j(getattr(trie, f)) for f in tb.PACKED_ONLY_FIELDS
                     if getattr(trie, f) is not None}
        return eng.DeviceTrie(
            depth=z1, max_score=z1, leaf_score=z1, leaf_sid=z1,
            syn_mask=jnp.zeros((0,), bool), tout=z1,
            first_child=z1, edge_char=z1, edge_child=z1,
            s_first_child=z1, s_edge_char=z1, s_edge_child=z1,
            emit_ptr=z1, emit_node=z1, emit_score=z1, emit_is_leaf=z1,
            tele_plane=z2, link_ptr=z1,
            link_rule=j(trie.link_rule), link_target=j(trie.link_target),
            r_first_child=j(rule_trie.first_child),
            r_edge_char=j(rule_trie.edge_char),
            r_edge_child=j(rule_trie.edge_child),
            r_term_plane=j(rule_trie.term_plane),
            r_rule_len=j(rule_trie.rule_len),
            topk_score=j(dummy), topk_sid=j(dummy),
            **packed_kw,
        )
    if trie.tele_plane is None or trie.link_ptr is None \
            or rule_trie.term_plane is None:
        tb.pack_rule_planes(trie, rule_trie)
    return eng.DeviceTrie(
        depth=j(trie.depth), max_score=j(trie.max_score),
        leaf_score=j(trie.leaf_score), leaf_sid=j(trie.leaf_sid),
        syn_mask=j(trie.syn_mask), tout=j(trie.tout),
        first_child=j(trie.first_child), edge_char=j(trie.edge_char),
        edge_child=j(trie.edge_child),
        s_first_child=j(trie.s_first_child), s_edge_char=j(trie.s_edge_char),
        s_edge_child=j(trie.s_edge_child),
        emit_ptr=j(trie.emit_ptr), emit_node=j(trie.emit_node),
        emit_score=j(trie.emit_score), emit_is_leaf=j(trie.emit_is_leaf),
        tele_plane=j(trie.tele_plane),
        link_ptr=j(trie.link_ptr), link_rule=j(trie.link_rule),
        link_target=j(trie.link_target),
        r_first_child=j(rule_trie.first_child), r_edge_char=j(rule_trie.edge_char),
        r_edge_child=j(rule_trie.edge_child),
        r_term_plane=j(rule_trie.term_plane), r_rule_len=j(rule_trie.rule_len),
        topk_score=j(trie.topk_score if has_cache else dummy),
        topk_sid=j(trie.topk_sid if has_cache else dummy),
    )


#: IndexSpec fields :meth:`CompletionIndex.reconfigure` may change at
#: runtime — they ride ``EngineConfig`` (and thus every compile-cache
#: key), so flipping them never touches the built structures.
RUNTIME_FIELDS = ("substrate", "memory_budget", "frontier", "gens",
                  "expand", "max_steps", "edit_budget")
#: fields baked into the built structures at construction time; changing
#: them means a rebuild (``build_index`` or the next ``compact()``).
BUILD_FIELDS = ("kind", "alpha", "cache_k", "compression", "multiterm_gap")


@dataclass
class PreparedCompaction:
    """A compaction ready to hot-swap: the freshly built index plus the
    {string: score} snapshot it was built from (mutations landing after
    the snapshot are re-applied as a new overlay at apply time)."""

    index: "CompletionIndex"
    snapshot: dict


class CompletionIndex:
    """A synonym-aware top-k completion index (TT, ET, HT or plain)."""

    def __init__(self, spec: IndexSpec, trie, rule_trie, rules, strings,
                 scores, cfg: eng.EngineConfig, stats: BuildStats,
                 compile_cache_size: int = 32, epoch: int = 0):
        self.spec = spec
        self.trie = trie
        self.rule_trie = rule_trie
        self.rules = rules
        self.strings = strings          # sorted; leaf_sid indexes this
        self.scores = scores
        self.cfg = cfg
        self.stats = stats
        self.device = _to_device(trie, rule_trie)
        self._compile_cache = CompileCache(maxsize=compile_cache_size)
        #: bumped by every hot-swap (compact) and reconfigure; sessions
        #: and scheduler slabs compare against it to know when to migrate
        self.epoch = epoch
        self._overlay: eng.DeltaOverlay | None = None

    @property
    def kind(self) -> str:
        return self.spec.kind

    @property
    def substrate(self) -> str:
        """The resolved execution substrate lookups run on."""
        return self.cfg.substrate

    @property
    def compression(self) -> str:
        """On-device layout: "none" (full-width) or "packed" (format v4)."""
        return self.cfg.compression

    @property
    def memory_budget(self) -> int:
        """VMEM byte budget for table residency (0 = substrate default)."""
        return self.cfg.memory_budget

    # -- runtime reconfiguration -------------------------------------------

    def reconfigure(self, **changes) -> "CompletionIndex":
        """Change runtime knobs in one validated step; returns ``self``.

        Accepts the :data:`RUNTIME_FIELDS` subset of ``IndexSpec``
        (``substrate``, ``memory_budget``, ``frontier``, ``gens``,
        ``expand``, ``max_steps``, ``edit_budget``), revalidates the
        resulting spec like a
        build would, and folds the changes into ``EngineConfig`` — which
        keys every jit/compile-cache entry, so stale executables can
        never be hit while ones for the old configuration stay cached.
        Any actual change bumps :attr:`epoch`: compiled sessions hold
        closures over the old config and re-derive their state at the
        next keystroke boundary, exactly like a hot-swap.

        Build-time fields (:data:`BUILD_FIELDS`) are rejected — rebuild
        via ``build_index`` or fold them into the next :meth:`compact`.
        """
        build_time = set(changes) & set(BUILD_FIELDS)
        if build_time:
            raise ValueError(
                f"{sorted(build_time)} are build-time fields baked into "
                f"the index structures; rebuild with build_index(...) or "
                f"fold the change into the next compact()")
        unknown = set(changes) - set(RUNTIME_FIELDS)
        if unknown:
            raise ValueError(
                f"unknown reconfigure field(s) {sorted(unknown)}; "
                f"runtime knobs are {RUNTIME_FIELDS}")
        changes = {k: v for k, v in changes.items()
                   if getattr(self.spec, k) != v}
        if not changes:
            return self
        spec = self.spec.replace(**changes).validate()
        cfg_kw = dict(changes)
        if "substrate" in cfg_kw:
            cfg_kw["substrate"] = eng.resolve_substrate(cfg_kw["substrate"])
        self.spec = spec
        self.cfg = replace(self.cfg, **cfg_kw)
        self.epoch += 1
        return self

    def set_substrate(self, name: str) -> "CompletionIndex":
        """Deprecated alias of ``reconfigure(substrate=...)``."""
        warnings.warn(
            "CompletionIndex.set_substrate() is deprecated; use "
            "reconfigure(substrate=...)", DeprecationWarning, stacklevel=2)
        return self.reconfigure(substrate=name)

    def set_memory_budget(self, n: int) -> "CompletionIndex":
        """Deprecated alias of ``reconfigure(memory_budget=...)``."""
        warnings.warn(
            "CompletionIndex.set_memory_budget() is deprecated; use "
            "reconfigure(memory_budget=...)", DeprecationWarning,
            stacklevel=2)
        return self.reconfigure(memory_budget=n)

    # -- construction ------------------------------------------------------

    @staticmethod
    def build(strings, scores, rules, kind: str = "et", *,
              alpha: float = 0.5, cache_k: int = 0,
              frontier: int = 32, gens: int = 48, expand: int = 8,
              max_steps: int = 512, compression: str = "none",
              edit_budget: int = 0,
              multiterm_gap: int = 2) -> "CompletionIndex":
        """Back-compat keyword constructor; equivalent to
        ``build_index(strings, scores, rules, IndexSpec(...))``."""
        spec = IndexSpec(kind=kind, alpha=alpha, cache_k=cache_k,
                         frontier=frontier, gens=gens, expand=expand,
                         max_steps=max_steps, compression=compression,
                         edit_budget=edit_budget,
                         multiterm_gap=multiterm_gap)
        return build_index(strings, scores, rules, spec)

    @staticmethod
    def from_spec(strings, scores, rules,
                  spec: IndexSpec) -> "CompletionIndex":
        return build_index(strings, scores, rules, spec)

    # -- persistence -------------------------------------------------------

    def save(self, path: str) -> None:
        """Write a versioned npz container; ``CompletionIndex.load(path)``
        restores it without re-running trie construction.

        The container holds only the built base structures, so saving
        with uncompacted mutations would silently drop them — fold them
        first (``compact()``, or ``compact(handoff_path=...)`` to write
        the folded container in the same step)."""
        if self.has_mutations:
            raise ValueError(
                "index has uncompacted mutations that save() would drop; "
                "call compact() first — compact(handoff_path=path) writes "
                "the folded container as part of the swap")
        from repro.api.persist import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path: str) -> "CompletionIndex":
        from repro.api.persist import load_index_parts
        p = load_index_parts(path)
        return cls(p["spec"], p["trie"], p["rule_trie"], p["rules"],
                   p["strings"], p["scores"], p["cfg"], p["stats"],
                   epoch=p["epoch"])

    # -- mutations (delta overlay) -----------------------------------------

    @staticmethod
    def _as_key(string) -> bytes:
        b = string.encode() if isinstance(string, str) else bytes(string)
        if not b:
            raise ValueError("cannot mutate the empty string")
        return b

    def _overlay_mut(self) -> eng.DeltaOverlay:
        if self._overlay is None:
            self._overlay = eng.DeltaOverlay()
        return self._overlay

    @property
    def has_mutations(self) -> bool:
        """True while the overlay holds uncompacted mutations; queries
        route through the merged path and sessions fall back to the
        one-shot lookup until :meth:`compact` folds them away."""
        ov = self._overlay
        return ov is not None and ov.active

    @property
    def mutation_backlog(self) -> int:
        """Pending overlay entries (inserts/re-scores + tombstones) — the
        serving loop's compaction trigger."""
        ov = self._overlay
        return 0 if ov is None else len(ov.added) + len(ov.tombstones)

    def insert(self, string, score: int) -> "CompletionIndex":
        """Insert (upsert: re-score if already present) without rebuild."""
        if score < 0:
            raise ValueError("scores are non-negative")
        self._overlay_mut().upsert(self.strings, self._as_key(string),
                                   int(score))
        return self

    def delete(self, string) -> "CompletionIndex":
        """Delete a live string; raises KeyError when it is not live."""
        self._overlay_mut().remove(self.strings, self._as_key(string))
        return self

    def update_score(self, string, score: int) -> "CompletionIndex":
        """Re-score a live string; raises KeyError when it is not live
        (unlike the upserting :meth:`insert`)."""
        b = self._as_key(string)
        ov = self._overlay
        live = (ov.is_live(self.strings, b) if ov is not None
                else eng.DeltaOverlay._base_sid(self.strings, b) >= 0)
        if not live:
            raise KeyError(f"{b!r} is not in the index; use insert()")
        return self.insert(b, score)

    @property
    def live_strings(self) -> list:
        """The current dictionary (base − deletions + inserts), sorted;
        merged-path sids index this list exactly as base sids index
        :attr:`strings`."""
        if not self.has_mutations:
            return self.strings
        self._overlay.refresh(self)
        return self._overlay.live

    def live_items(self) -> dict:
        """{string: score} of the current dictionary contents."""
        live = {s: int(r) for s, r in zip(
            self.strings, np.asarray(self.scores).tolist())}
        ov = self._overlay
        if ov is not None:
            for s in ov.tombstones:
                live.pop(s, None)
            live.update(ov.added)
        return live

    # -- compaction / hot-swap ---------------------------------------------

    def prepare_compaction(self) -> PreparedCompaction:
        """Fold the current contents into a freshly built index.

        The expensive half of a compaction, safe to run off-thread: it
        reads one consistent snapshot and mutates nothing; the cheap
        :meth:`apply_compaction` swaps it in at a convenient boundary."""
        snapshot = self.live_items()
        strings = sorted(snapshot)
        scores = [snapshot[s] for s in strings]
        fresh = build_index(strings, scores, self.rules, self.spec)
        return PreparedCompaction(index=fresh, snapshot=snapshot)

    def apply_compaction(
            self, prepared: PreparedCompaction) -> "CompletionIndex":
        """Hot-swap a prepared compaction in place (cheap, synchronous).

        Adopts the fresh structures, drops the compile cache — its
        closures captured the old epoch's device tables under keys that
        do not name the epoch — and bumps :attr:`epoch` so live sessions
        re-derive their state at the next keystroke boundary.  Mutations
        that landed after the snapshot survive: they are diffed against
        it and re-applied as a new overlay on the fresh base."""
        current = self.live_items()
        fresh = prepared.index
        desired_spec = self.spec
        self.spec = fresh.spec
        self.trie, self.rule_trie = fresh.trie, fresh.rule_trie
        self.rules = fresh.rules
        self.strings, self.scores = fresh.strings, fresh.scores
        self.cfg, self.stats = fresh.cfg, fresh.stats
        self.device = fresh.device
        self._compile_cache = CompileCache(
            maxsize=self._compile_cache.maxsize)
        self._overlay = None
        self.epoch += 1
        snap = prepared.snapshot
        for s, sc in current.items():
            if snap.get(s) != sc:
                self.insert(s, sc)
        for s in snap:
            if s not in current:
                self.delete(s)
        if desired_spec != self.spec:
            # a reconfigure() raced the prepare; re-apply its runtime
            # knobs on top of the adopted spec (build-time fields cannot
            # diverge — reconfigure rejects them)
            runtime = {f: getattr(desired_spec, f) for f in RUNTIME_FIELDS
                       if getattr(desired_spec, f) != getattr(self.spec, f)}
            if runtime:
                self.reconfigure(**runtime)
        return self

    def compact(self, handoff_path: str | None = None) -> "CompletionIndex":
        """Fold the overlay into a fresh index and hot-swap it in place.

        ``handoff_path`` routes the swap through the versioned npz
        container (save + load) — the restart-without-downtime shape: the
        folded index lands on disk as a side effect, and what is swapped
        in is bit-for-bit what a restarting process would load."""
        prepared = self.prepare_compaction()
        if handoff_path is not None:
            prepared.index.save(handoff_path)
            prepared = PreparedCompaction(
                index=CompletionIndex.load(handoff_path),
                snapshot=prepared.snapshot)
        return self.apply_compaction(prepared)

    # -- lookup ------------------------------------------------------------

    def _fn(self, batch: int, length: int, k: int, cfg: eng.EngineConfig):
        key = ("batch", batch, length, k, cfg)

        def factory():
            dev = self.device

            @jax.jit
            def run(qs, qlens):
                return eng.complete_batch(dev, cfg, qs, qlens, k)

            return run

        return self._compile_cache.get(key, factory)

    def _session_fns(self, k: int):
        """(init, advance-one-char, topk) jitted for this index's cfg."""
        key = ("session", k, self.cfg)

        def factory():
            dev, cfg = self.device, self.cfg
            init = jax.jit(lambda: eng.init_locus_state(dev, cfg))
            adv = jax.jit(
                lambda state, c: eng.advance_locus_state(dev, cfg, state, c))
            topk = jax.jit(
                lambda state: eng.topk_from_loci(dev, cfg, state, k))
            return init, adv, topk

        return self._compile_cache.get(key, factory)

    def _slab_fns(self, block: int):
        """(init, advance) jitted at the fixed ``[block]`` lane shape.

        The continuous-batching scheduler's hot pair: ``init()`` builds a
        stacked empty-prefix LocusState slab, ``advance(slab, chars,
        resets)`` re-initializes lanes flagged in ``resets`` and then
        advances every lane whose char is >= 0, all in one dispatch.
        """
        key = ("slab", block, self.cfg)

        def factory():
            dev, cfg = self.device, self.cfg
            init = jax.jit(lambda: eng.init_locus_batch(dev, cfg, block))

            def _advance(slab, chars, resets):
                fresh = eng.init_locus_state(dev, cfg)
                slab = jax.tree.map(
                    lambda s, z: jnp.where(
                        resets.reshape((block,) + (1,) * (s.ndim - 1)),
                        z, s),
                    slab, fresh)
                return eng.advance_loci_batch(dev, cfg, slab, chars)

            # the slab is threaded flush-to-flush and never read after the
            # advance, so donating it lets XLA update lanes in place
            return init, jax.jit(_advance, donate_argnums=0)

        return self._compile_cache.get(key, factory)

    def _slab_topk_fn(self, block: int, k: int):
        """Batched top-k over a state slab, jitted per (block, k)."""
        key = ("slab_topk", block, k, self.cfg)

        def factory():
            dev, cfg = self.device, self.cfg
            return jax.jit(
                lambda slab: eng.topk_from_loci_batch(dev, cfg, slab, k))

        return self._compile_cache.get(key, factory)

    def session(self, k: int = 10):
        """Open a stateful incremental-typing session (see
        :class:`repro.api.session.Session`)."""
        from repro.api.session import Session
        return Session(self, k=k)

    def complete_batch_padded(self, qs: np.ndarray, qlens: np.ndarray,
                              k: int):
        """Device entry point: qs int32[B, L] (-1 padded). Shapes are
        bucketed to powers of two before jit so drifting batch sizes share
        executables. Retries inexact queries with widened search (exactness
        guard of §2.2).

        With pending mutations the answer is the overlay-merged one
        (:meth:`_complete_mutated`) and the returned sids index
        :attr:`live_strings`; otherwise they index :attr:`strings` — the
        two coincide exactly when :attr:`has_mutations` is False."""
        if self.has_mutations:
            return self._complete_mutated(qs, qlens, k)
        return self._complete_base(qs, qlens, k)

    def _complete_base(self, qs: np.ndarray, qlens: np.ndarray, k: int):
        B, L = qs.shape
        Bb, Lb = bucket_size(B, minimum=1), bucket_size(L)
        if (Bb, Lb) != (B, L):
            qs = np.pad(qs, ((0, Bb - B), (0, Lb - L)), constant_values=-1)
            qlens = np.pad(qlens, (0, Bb - B))
        cfg = self.cfg
        fn = self._fn(Bb, Lb, k, cfg)
        scores, sids, exact = jax.tree.map(np.asarray, fn(qs, qlens))
        bad = ~exact
        bad[B:] = False
        if bad.any():   # np.asarray views of jit output are read-only
            scores, sids = scores.copy(), sids.copy()
        tries = 0
        while bad.any() and tries < 3:
            # the widened config re-dispatches through the substrate, so
            # each retry round re-probes can_beam_batch: the first round
            # (gens x4) re-enters the fused beam kernel at the default
            # widths; rounds that outgrow its envelope fall back to the
            # jnp reference with identical results
            cfg = replace(cfg, frontier=cfg.frontier * 2, gens=cfg.gens * 4,
                          max_steps=cfg.max_steps * 4, use_cache=False)
            sub = np.nonzero(bad)[0]
            Sb = bucket_size(len(sub), minimum=1)
            pad_sub = np.pad(sub, (0, Sb - len(sub)))  # repeat row 0: harmless
            fn2 = self._fn(Sb, Lb, k, cfg)
            s2, i2, e2 = jax.tree.map(
                np.asarray, fn2(qs[pad_sub], qlens[pad_sub]))
            scores[sub], sids[sub] = s2[:len(sub)], i2[:len(sub)]
            bad2 = np.zeros_like(bad)
            bad2[sub] = ~e2[:len(sub)]
            bad = bad2
            tries += 1
        return scores[:B], sids[:B]

    def _merge_fn(self, B: int, C: int, k: int):
        """Jitted overlay merge (sort-by-grank + substrate top-k), cached
        per candidate shape like every other compiled entry point."""
        key = ("overlay_merge", B, C, k, self.cfg)

        def factory():
            sub = eng.get_substrate(self.cfg.substrate)
            return jax.jit(
                lambda s, g: eng.merge_overlay_topk(s, g, k, sub))

        return self._compile_cache.get(key, factory)

    def _complete_mutated(self, qs: np.ndarray, qlens: np.ndarray, k: int):
        """Merged lookup under pending mutations.

        Base is over-fetched to k + D' (D' = tombstone count bucketed to
        a power of two, so a growing backlog reuses executables) — every
        result row can lose at most every tombstone — then tombstoned
        hits are masked out host-side and both candidate sets are
        relabeled to *global ranks* (their sid in a from-scratch rebuild;
        see :mod:`repro.core.engine.overlay`).  One substrate-routed
        fused selection returns the top-k bit-identical to that rebuild,
        and the grank "sids" decode against :attr:`live_strings`."""
        ov = self._overlay
        ov.refresh(self)
        n_dead = int(ov.base_dead.sum())
        k_base = k + (bucket_size(n_dead, minimum=1) if n_dead else 0)
        b_scores, b_sids = self._complete_base(qs, qlens, k_base)
        valid = b_sids >= 0
        sid0 = np.where(valid, b_sids, 0)
        keep = valid & ~ov.base_dead[sid0]
        cand_s = np.where(keep, b_scores, -1).astype(np.int32)
        cand_g = np.where(keep, ov.base_grank[sid0],
                          eng.INT_MAX).astype(np.int32)
        if ov.index is not None:
            o_scores, o_sids = ov.index.complete_batch_padded(qs, qlens, k)
            o_valid = o_sids >= 0
            o_sid0 = np.where(o_valid, o_sids, 0)
            cand_s = np.concatenate(
                [cand_s, np.where(o_valid, o_scores, -1).astype(np.int32)],
                axis=1)
            cand_g = np.concatenate(
                [cand_g, np.where(o_valid, ov.ov_grank[o_sid0],
                                  eng.INT_MAX).astype(np.int32)], axis=1)
        fn = self._merge_fn(cand_s.shape[0], cand_s.shape[1], k)
        scores, granks = jax.tree.map(np.asarray, fn(cand_s, cand_g))
        return scores, granks

    def complete(self, queries: list[str | bytes], k: int = 10):
        """Top-k completions for a batch of query strings.

        Returns a list (per query) of (score, suggestion string) pairs.
        """
        max_len = max((len(q.encode() if isinstance(q, str) else q)
                       for q in queries), default=1)
        qs, qlens = pad_queries(queries, max(max_len, 1))
        scores, sids = self.complete_batch_padded(qs, qlens, k)
        out = []
        for b in range(len(queries)):
            out.append(self._decode_row(scores[b], sids[b]))
        return out

    def _decode_row(self, scores, sids) -> list[tuple[int, str]]:
        # tolist() converts the row in one C pass: the per-keystroke
        # serving paths decode thousands of these, and looping numpy
        # scalars costs more than the decode itself
        row = []
        strings = self.live_strings
        for score, sid in zip(np.asarray(scores).tolist(),
                              np.asarray(sids).tolist()):
            if score < 0 or sid < 0:
                continue
            row.append((score, strings[sid].decode(
                "utf-8", errors="replace")))
        return row
