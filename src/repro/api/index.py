"""CompletionIndex: the queryable, persistable completion index.

Construction lives in :mod:`repro.api.build` (driven by an
:class:`~repro.api.spec.IndexSpec`); this module owns the device arrays,
the bounded compile cache, batched lookup with the exactness-retry guard,
persistence, and the session entry points.
"""

from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.build import BuildStats, build_index
from repro.api.compile_cache import CompileCache, bucket_size
from repro.api.spec import IndexSpec
from repro.core import engine as eng
from repro.core import trie_build as tb
from repro.core.alphabet import pad_queries


def _to_device(trie: tb.DictTrie, rule_trie: tb.RuleTrie) -> eng.DeviceTrie:
    j = jnp.asarray
    has_cache = trie.topk_score is not None
    dummy = np.full((1, 1), -1, np.int32)
    if trie.has_packed:
        # compressed layout: only the packed side tables (their narrow
        # dtypes preserved), the kept link store, and the rule trie go to
        # the device — the dense planes ship as 0-size dummies so the
        # NamedTuple stays a uniform pytree while costing nothing
        z1 = jnp.zeros((0,), jnp.int32)
        z2 = jnp.zeros((0, 1), jnp.int32)
        packed_kw = {f: j(getattr(trie, f)) for f in tb.PACKED_ONLY_FIELDS
                     if getattr(trie, f) is not None}
        return eng.DeviceTrie(
            depth=z1, max_score=z1, leaf_score=z1, leaf_sid=z1,
            syn_mask=jnp.zeros((0,), bool), tout=z1,
            first_child=z1, edge_char=z1, edge_child=z1,
            s_first_child=z1, s_edge_char=z1, s_edge_child=z1,
            emit_ptr=z1, emit_node=z1, emit_score=z1, emit_is_leaf=z1,
            tele_plane=z2, link_ptr=z1,
            link_rule=j(trie.link_rule), link_target=j(trie.link_target),
            r_first_child=j(rule_trie.first_child),
            r_edge_char=j(rule_trie.edge_char),
            r_edge_child=j(rule_trie.edge_child),
            r_term_plane=j(rule_trie.term_plane),
            r_rule_len=j(rule_trie.rule_len),
            topk_score=j(dummy), topk_sid=j(dummy),
            **packed_kw,
        )
    if trie.tele_plane is None or trie.link_ptr is None \
            or rule_trie.term_plane is None:
        tb.pack_rule_planes(trie, rule_trie)
    return eng.DeviceTrie(
        depth=j(trie.depth), max_score=j(trie.max_score),
        leaf_score=j(trie.leaf_score), leaf_sid=j(trie.leaf_sid),
        syn_mask=j(trie.syn_mask), tout=j(trie.tout),
        first_child=j(trie.first_child), edge_char=j(trie.edge_char),
        edge_child=j(trie.edge_child),
        s_first_child=j(trie.s_first_child), s_edge_char=j(trie.s_edge_char),
        s_edge_child=j(trie.s_edge_child),
        emit_ptr=j(trie.emit_ptr), emit_node=j(trie.emit_node),
        emit_score=j(trie.emit_score), emit_is_leaf=j(trie.emit_is_leaf),
        tele_plane=j(trie.tele_plane),
        link_ptr=j(trie.link_ptr), link_rule=j(trie.link_rule),
        link_target=j(trie.link_target),
        r_first_child=j(rule_trie.first_child), r_edge_char=j(rule_trie.edge_char),
        r_edge_child=j(rule_trie.edge_child),
        r_term_plane=j(rule_trie.term_plane), r_rule_len=j(rule_trie.rule_len),
        topk_score=j(trie.topk_score if has_cache else dummy),
        topk_sid=j(trie.topk_sid if has_cache else dummy),
    )


class CompletionIndex:
    """A synonym-aware top-k completion index (TT, ET, HT or plain)."""

    def __init__(self, spec: IndexSpec, trie, rule_trie, rules, strings,
                 scores, cfg: eng.EngineConfig, stats: BuildStats,
                 compile_cache_size: int = 32):
        self.spec = spec
        self.trie = trie
        self.rule_trie = rule_trie
        self.rules = rules
        self.strings = strings          # sorted; leaf_sid indexes this
        self.scores = scores
        self.cfg = cfg
        self.stats = stats
        self.device = _to_device(trie, rule_trie)
        self._compile_cache = CompileCache(maxsize=compile_cache_size)

    @property
    def kind(self) -> str:
        return self.spec.kind

    @property
    def substrate(self) -> str:
        """The resolved execution substrate lookups run on."""
        return self.cfg.substrate

    def set_substrate(self, name: str) -> "CompletionIndex":
        """Switch the execution substrate ("jnp", "pallas", or "auto").

        Cheap: host/device structures are untouched; the substrate rides
        ``EngineConfig`` (and thus every compile-cache key), so the next
        lookup compiles through the new substrate while executables for
        the old one stay cached.  Returns ``self`` for chaining.
        """
        resolved = eng.resolve_substrate(name)
        self.spec = self.spec.replace(substrate=name)
        self.cfg = replace(self.cfg, substrate=resolved)
        return self

    @property
    def compression(self) -> str:
        """On-device layout: "none" (full-width) or "packed" (format v4)."""
        return self.cfg.compression

    @property
    def memory_budget(self) -> int:
        """VMEM byte budget for table residency (0 = substrate default)."""
        return self.cfg.memory_budget

    def set_memory_budget(self, n: int) -> "CompletionIndex":
        """Set the VMEM byte budget for table residency (0 = substrate
        default).  Cheap, like :meth:`set_substrate`: the budget rides
        ``EngineConfig`` (and thus every compile-cache key), so the next
        lookup re-probes resident vs DMA-streamed kernel variants while
        executables for the old budget stay cached.  Returns ``self``."""
        if n < 0:
            raise ValueError("memory_budget must be >= 0")
        self.spec = self.spec.replace(memory_budget=n)
        self.cfg = replace(self.cfg, memory_budget=n)
        return self

    # -- construction ------------------------------------------------------

    @staticmethod
    def build(strings, scores, rules, kind: str = "et", *,
              alpha: float = 0.5, cache_k: int = 0,
              frontier: int = 32, gens: int = 48, expand: int = 8,
              max_steps: int = 512,
              compression: str = "none") -> "CompletionIndex":
        """Back-compat keyword constructor; equivalent to
        ``build_index(strings, scores, rules, IndexSpec(...))``."""
        spec = IndexSpec(kind=kind, alpha=alpha, cache_k=cache_k,
                         frontier=frontier, gens=gens, expand=expand,
                         max_steps=max_steps, compression=compression)
        return build_index(strings, scores, rules, spec)

    @staticmethod
    def from_spec(strings, scores, rules,
                  spec: IndexSpec) -> "CompletionIndex":
        return build_index(strings, scores, rules, spec)

    # -- persistence -------------------------------------------------------

    def save(self, path: str) -> None:
        """Write a versioned npz container; ``CompletionIndex.load(path)``
        restores it without re-running trie construction."""
        from repro.api.persist import save_index
        save_index(self, path)

    @classmethod
    def load(cls, path: str) -> "CompletionIndex":
        from repro.api.persist import load_index_parts
        p = load_index_parts(path)
        return cls(p["spec"], p["trie"], p["rule_trie"], p["rules"],
                   p["strings"], p["scores"], p["cfg"], p["stats"])

    # -- lookup ------------------------------------------------------------

    def _fn(self, batch: int, length: int, k: int, cfg: eng.EngineConfig):
        key = ("batch", batch, length, k, cfg)

        def factory():
            dev = self.device

            @jax.jit
            def run(qs, qlens):
                return eng.complete_batch(dev, cfg, qs, qlens, k)

            return run

        return self._compile_cache.get(key, factory)

    def _session_fns(self, k: int):
        """(init, advance-one-char, topk) jitted for this index's cfg."""
        key = ("session", k, self.cfg)

        def factory():
            dev, cfg = self.device, self.cfg
            init = jax.jit(lambda: eng.init_locus_state(dev, cfg))
            adv = jax.jit(
                lambda state, c: eng.advance_locus_state(dev, cfg, state, c))
            topk = jax.jit(
                lambda state: eng.topk_from_loci(dev, cfg, state, k))
            return init, adv, topk

        return self._compile_cache.get(key, factory)

    def _slab_fns(self, block: int):
        """(init, advance) jitted at the fixed ``[block]`` lane shape.

        The continuous-batching scheduler's hot pair: ``init()`` builds a
        stacked empty-prefix LocusState slab, ``advance(slab, chars,
        resets)`` re-initializes lanes flagged in ``resets`` and then
        advances every lane whose char is >= 0, all in one dispatch.
        """
        key = ("slab", block, self.cfg)

        def factory():
            dev, cfg = self.device, self.cfg
            init = jax.jit(lambda: eng.init_locus_batch(dev, cfg, block))

            def _advance(slab, chars, resets):
                fresh = eng.init_locus_state(dev, cfg)
                slab = jax.tree.map(
                    lambda s, z: jnp.where(
                        resets.reshape((block,) + (1,) * (s.ndim - 1)),
                        z, s),
                    slab, fresh)
                return eng.advance_loci_batch(dev, cfg, slab, chars)

            # the slab is threaded flush-to-flush and never read after the
            # advance, so donating it lets XLA update lanes in place
            return init, jax.jit(_advance, donate_argnums=0)

        return self._compile_cache.get(key, factory)

    def _slab_topk_fn(self, block: int, k: int):
        """Batched top-k over a state slab, jitted per (block, k)."""
        key = ("slab_topk", block, k, self.cfg)

        def factory():
            dev, cfg = self.device, self.cfg
            return jax.jit(
                lambda slab: eng.topk_from_loci_batch(dev, cfg, slab, k))

        return self._compile_cache.get(key, factory)

    def session(self, k: int = 10):
        """Open a stateful incremental-typing session (see
        :class:`repro.api.session.Session`)."""
        from repro.api.session import Session
        return Session(self, k=k)

    def complete_batch_padded(self, qs: np.ndarray, qlens: np.ndarray,
                              k: int):
        """Device entry point: qs int32[B, L] (-1 padded). Shapes are
        bucketed to powers of two before jit so drifting batch sizes share
        executables. Retries inexact queries with widened search (exactness
        guard of §2.2)."""
        B, L = qs.shape
        Bb, Lb = bucket_size(B, minimum=1), bucket_size(L)
        if (Bb, Lb) != (B, L):
            qs = np.pad(qs, ((0, Bb - B), (0, Lb - L)), constant_values=-1)
            qlens = np.pad(qlens, (0, Bb - B))
        cfg = self.cfg
        fn = self._fn(Bb, Lb, k, cfg)
        scores, sids, exact = jax.tree.map(np.asarray, fn(qs, qlens))
        bad = ~exact
        bad[B:] = False
        if bad.any():   # np.asarray views of jit output are read-only
            scores, sids = scores.copy(), sids.copy()
        tries = 0
        while bad.any() and tries < 3:
            # the widened config re-dispatches through the substrate, so
            # each retry round re-probes can_beam_batch: the first round
            # (gens x4) re-enters the fused beam kernel at the default
            # widths; rounds that outgrow its envelope fall back to the
            # jnp reference with identical results
            cfg = replace(cfg, frontier=cfg.frontier * 2, gens=cfg.gens * 4,
                          max_steps=cfg.max_steps * 4, use_cache=False)
            sub = np.nonzero(bad)[0]
            Sb = bucket_size(len(sub), minimum=1)
            pad_sub = np.pad(sub, (0, Sb - len(sub)))  # repeat row 0: harmless
            fn2 = self._fn(Sb, Lb, k, cfg)
            s2, i2, e2 = jax.tree.map(
                np.asarray, fn2(qs[pad_sub], qlens[pad_sub]))
            scores[sub], sids[sub] = s2[:len(sub)], i2[:len(sub)]
            bad2 = np.zeros_like(bad)
            bad2[sub] = ~e2[:len(sub)]
            bad = bad2
            tries += 1
        return scores[:B], sids[:B]

    def complete(self, queries: list[str | bytes], k: int = 10):
        """Top-k completions for a batch of query strings.

        Returns a list (per query) of (score, suggestion string) pairs.
        """
        max_len = max((len(q.encode() if isinstance(q, str) else q)
                       for q in queries), default=1)
        qs, qlens = pad_queries(queries, max(max_len, 1))
        scores, sids = self.complete_batch_padded(qs, qlens, k)
        out = []
        for b in range(len(queries)):
            out.append(self._decode_row(scores[b], sids[b]))
        return out

    def _decode_row(self, scores, sids) -> list[tuple[int, str]]:
        # tolist() converts the row in one C pass: the per-keystroke
        # serving paths decode thousands of these, and looping numpy
        # scalars costs more than the decode itself
        row = []
        strings = self.strings
        for score, sid in zip(np.asarray(scores).tolist(),
                              np.asarray(sids).tolist()):
            if score < 0 or sid < 0:
                continue
            row.append((score, strings[sid].decode(
                "utf-8", errors="replace")))
        return row
