"""Declarative index specification + pluggable builder registry.

An :class:`IndexSpec` captures *what* index to build (kind, HT space budget,
cache depth) and the static engine widths that become the jit shape key —
replacing the keyword soup of the old ``CompletionIndex.build(...)``.  The
per-kind rule-partitioning policies (``tt`` / ``et`` / ``ht`` / ``plain``)
register themselves in a builder registry, so a new index kind is an
additive ``@register_builder("<kind>")`` away.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core import trie_build as tb


@dataclass(frozen=True)
class IndexSpec:
    """Everything needed to (re)build a completion index, minus the data.

    kind: "tt" (twin tries), "et" (expansion trie), "ht" (hybrid), or
        "plain" (prefix-only, no synonym support) — or any kind added to
        the registry via :func:`register_builder`.
    alpha: HT space ratio in [0, 1] (paper Fig. 8); ignored by other kinds.
    cache_k: materialize per-node top-K lists (0 = off; beyond-paper).
    frontier/gens/expand/max_steps: static engine widths (jit shape key).
    substrate: execution substrate — "jnp" (reference), "pallas" (tuned
        kernels; interpret mode off-TPU), or "auto" (pallas on TPU, jnp
        elsewhere).  Resolved at build/load time against the substrate
        registry in :mod:`repro.core.engine.substrate`.
    memory_budget: VMEM bytes the pallas substrate may spend keeping
        tables resident; tries whose tables exceed it run the
        DMA-streamed kernel tier (HBM-resident tables) instead of
        falling back to jnp.  0 = substrate default.
    compression: on-device table layout — "none" keeps the uniform-i32
        arrays; "packed" builds the compressed layout
        (:func:`repro.core.trie_build.pack_compressed`): narrow dtype
        tiers, chain-collapsed unary paths, elided empty planes, and a
        quantized top-K cache.  Bit-identical results, ~an order of
        magnitude fewer bytes/string; persisted as format v4.
    """

    kind: str = "et"
    alpha: float = 0.5
    cache_k: int = 0
    frontier: int = 32
    gens: int = 48
    expand: int = 8
    max_steps: int = 512
    substrate: str = "auto"
    memory_budget: int = 0
    compression: str = "none"
    # bounded-edit (typo-tolerant) matching: up to edit_budget
    # substitutions/insertions/deletions may be spent on the literal
    # characters of a query (rule lhs and synonym-variant characters must
    # still be typed exactly).  Static — joins EngineConfig and every
    # compile-cache key; runtime-reconfigurable via
    # ``CompletionIndex.reconfigure(edit_budget=...)``.  0 = exact.
    edit_budget: int = 0
    # multi-term mode (kind="multiterm"): max token-gap bridged by the
    # synthesized skip rules — typing a space may skip up to this many
    # dictionary tokens, so the last token completes conditioned on an
    # earlier-token context.  Ignored by other kinds.
    multiterm_gap: int = 2

    def validate(self) -> "IndexSpec":
        if self.kind not in _BUILDERS:
            raise ValueError(
                f"unknown index kind {self.kind!r}; registered kinds: "
                f"{registered_kinds()}")
        if not 0.0 <= self.alpha <= 1.0:
            raise ValueError(f"alpha must be in [0, 1], got {self.alpha}")
        from repro.core.engine.substrate import available_substrates
        if self.substrate != "auto" and \
                self.substrate not in available_substrates():
            raise ValueError(
                f"unknown substrate {self.substrate!r}; expected 'auto' or "
                f"one of {available_substrates()}")
        if self.compression not in ("none", "packed"):
            raise ValueError(
                f"unknown compression {self.compression!r}; expected "
                "'none' or 'packed'")
        for name in ("cache_k", "memory_budget"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0")
        for name in ("frontier", "gens", "expand", "max_steps",
                     "multiterm_gap"):
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be positive")
        if not 0 <= self.edit_budget <= 2:
            raise ValueError(
                f"edit_budget must be in [0, 2], got {self.edit_budget}")
        return self

    def validate_sharded(self) -> "IndexSpec":
        """Validate for use across ``ShardedCompletionIndex`` shards.

        The packed (format v4) layout cannot be stacked: shard stacking
        pads every table to the widest shard, which breaks the packed
        side tables' sorted-rank invariants.  Rejecting the spec here
        surfaces the problem at construction time with the workaround,
        instead of a ``NotImplementedError`` deep in ``stack_shards``."""
        self.validate()
        if self.compression != "none":
            raise ValueError(
                f"compression={self.compression!r} is unsupported on "
                f"sharded indexes: stacking pads the packed side tables "
                f"and breaks their sorted-rank invariants. Build shards "
                f"with compression='none'; to keep large shards off VMEM "
                f"set memory_budget so they run the DMA-streamed tier")
        return self

    def replace(self, **kw) -> "IndexSpec":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "IndexSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known}).validate()


@dataclass
class BuildContext:
    """What a kind-specific builder sees: the pure dictionary trie plus the
    full link candidate set (anchor, rule, target) found on it."""

    spec: IndexSpec
    trie: tb.DictTrie
    rules: list[tb.SynonymRule]
    anchors: np.ndarray  # int32[L]
    rids: np.ndarray     # int32[L]
    targets: np.ndarray  # int32[L]


# A builder decides, per rule, whether it is expanded into synonym branches
# (ET side) and/or kept in the link store (TT side).
Builder = Callable[[BuildContext], tuple[np.ndarray, np.ndarray]]

_BUILDERS: dict[str, Builder] = {}


def register_builder(kind: str):
    """Register a rule-partitioning policy for an index kind.

    The decorated function maps a :class:`BuildContext` to boolean masks
    ``(expand_mask[R], keep_links[R])`` over rule ids.
    """

    def deco(fn: Builder) -> Builder:
        if kind in _BUILDERS:
            raise ValueError(f"index kind {kind!r} already registered")
        _BUILDERS[kind] = fn
        return fn

    return deco


# Optional per-kind rule synthesizers: run before link finding, they map
# (spec, strings, user rules) to extra SynonymRules the kind derives from
# the corpus itself (e.g. the multiterm token-skip rules).
Synthesizer = Callable[[IndexSpec, list, list], list]

_SYNTHESIZERS: dict[str, Synthesizer] = {}


def register_rule_synthesizer(kind: str):
    """Register a corpus-driven rule synthesizer for an index kind."""

    def deco(fn: Synthesizer) -> Synthesizer:
        if kind in _SYNTHESIZERS:
            raise ValueError(f"synthesizer for kind {kind!r} already "
                             "registered")
        _SYNTHESIZERS[kind] = fn
        return fn

    return deco


def get_synthesizer(kind: str) -> Synthesizer | None:
    return _SYNTHESIZERS.get(kind)


def get_builder(kind: str) -> Builder:
    try:
        return _BUILDERS[kind]
    except KeyError:
        raise ValueError(
            f"unknown index kind {kind!r}; registered kinds: "
            f"{registered_kinds()}") from None


def registered_kinds() -> list[str]:
    return sorted(_BUILDERS)
