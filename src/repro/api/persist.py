"""Versioned npz container for completion indexes.

``save_index`` writes every host array of the built structures (dict trie,
rule trie, rules, sorted strings, scores) plus a JSON metadata blob (format
version, IndexSpec, EngineConfig, BuildStats, trie scalars) into a single
compressed ``.npz``.  ``load_index_parts`` reverses it without re-running
trie construction — a serving process restarts in milliseconds instead of
paying the multi-second rebuild.

Format history:

- v1 (PR 1): dict/rule-trie CSRs + metadata.
- v2 (PR 3): adds the packed rule plane (``trie__tele_plane``,
  ``trie__link_ptr``, ``rule_trie__term_plane``) and the static plane
  widths on the persisted EngineConfig.  v1 containers still load — the
  planes are rebuilt from the CSRs on the fly (a few ms of numpy) and the
  widths recomputed, so old on-disk indexes keep working unchanged.
- v3 (PR 5): the flat CSR / emission / link tables are stored in
  the tile-aligned stream layout (``trie_build.pack_stream_tiles``) with
  the static tile widths in the metadata, so the DMA-streamed kernel
  tier can window them without a re-layout on load.  v1/v2 containers
  still load — the tiles are re-packed on the fly and the widths
  recomputed (real lengths come from the CSR ptr totals).
- v4 (this version): compressed on-device layout.  When the spec says
  ``compression="packed"`` the container stores only the packed side
  tables (``trie_build.pack_compressed``) plus the kept link store —
  the dense per-node planes are elided, shrinking the container itself
  alongside the device footprint — and the dtype tiers ride the
  persisted ``EngineConfig.table_widths``.  Uncompressed indexes are
  byte-compatible with v3; v1-v3 containers still load, and are
  re-packed on the fly if their spec asks for compression.
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.api.build import BuildStats
from repro.api.spec import IndexSpec
from repro.core import engine as eng
from repro.core import trie_build as tb

FORMAT_VERSION = 4
_SUPPORTED_VERSIONS = (1, 2, 3, 4)
_META_KEY = "__meta__"


def _pack_bytes(items: list[bytes]) -> tuple[np.ndarray, np.ndarray]:
    blob = np.frombuffer(b"".join(items), dtype=np.uint8)
    offsets = np.zeros(len(items) + 1, dtype=np.int64)
    np.cumsum([len(s) for s in items], out=offsets[1:])
    return blob, offsets


def _unpack_bytes(blob: np.ndarray, offsets: np.ndarray) -> list[bytes]:
    raw = blob.tobytes()
    return [raw[offsets[i]:offsets[i + 1]] for i in range(len(offsets) - 1)]


def save_index(index, path: str) -> None:
    """Serialize a built CompletionIndex to ``path`` (.npz appended by numpy
    if missing)."""
    trie: tb.DictTrie = index.trie
    rule_trie: tb.RuleTrie = index.rule_trie
    arrays: dict[str, np.ndarray] = {}
    # packed indexes persist only the compressed side tables + the kept
    # link store: the dense per-node planes are rebuilt on neither save
    # nor load, so the container shrinks with the device footprint
    packed_keep = (set(tb.PACKED_ONLY_FIELDS) | set(tb.PACKED_KEEP_FIELDS)
                   if trie.has_packed else None)
    for f in dataclasses.fields(trie):
        v = getattr(trie, f.name)
        if isinstance(v, np.ndarray) and \
                (packed_keep is None or f.name in packed_keep):
            arrays[f"trie__{f.name}"] = v
    for f in dataclasses.fields(rule_trie):
        v = getattr(rule_trie, f.name)
        if isinstance(v, np.ndarray):
            arrays[f"rule_trie__{f.name}"] = v
    (arrays["strings__blob"], arrays["strings__offsets"]) = _pack_bytes(
        index.strings)
    arrays["scores"] = np.asarray(index.scores, dtype=np.int32)
    (arrays["rules__lhs_blob"], arrays["rules__lhs_offsets"]) = _pack_bytes(
        [r.lhs for r in index.rules])
    (arrays["rules__rhs_blob"], arrays["rules__rhs_offsets"]) = _pack_bytes(
        [r.rhs for r in index.rules])

    meta = {
        "format_version": FORMAT_VERSION,
        "spec": index.spec.to_dict(),
        # hot-swap generation: a restarting service resumes epoch
        # numbering instead of rewinding live sessions' comparisons
        "epoch": getattr(index, "epoch", 0),
        "cfg": dataclasses.asdict(index.cfg),
        "stats": dataclasses.asdict(index.stats),
        "trie_scalars": {"max_depth": trie.max_depth,
                         "max_syn_targets": trie.max_syn_targets,
                         "walk_tile": trie.walk_tile,
                         "emit_tile": trie.emit_tile,
                         "link_tile": trie.link_tile,
                         "has_cache": trie.topk_score is not None
                         or trie.pc_score is not None},
        "rule_trie_scalars": {
            "max_lhs_len": rule_trie.max_lhs_len,
            "max_matches_per_pos": rule_trie.max_matches_per_pos,
            "max_terms_per_node": rule_trie.max_terms_per_node,
        },
    }
    arrays[_META_KEY] = np.frombuffer(
        json.dumps(meta).encode(), dtype=np.uint8)
    np.savez_compressed(path, **arrays)


def load_index_parts(path: str) -> dict:
    """Load the container back into constructor-ready parts."""
    import os
    if not os.path.exists(path) and os.path.exists(path + ".npz"):
        path = path + ".npz"   # np.savez appended the suffix on save
    with np.load(path) as z:
        if _META_KEY not in z:
            raise ValueError(f"{path}: not a repro completion-index container")
        meta = json.loads(z[_META_KEY].tobytes().decode())
        version = meta.get("format_version")
        if version not in _SUPPORTED_VERSIONS:
            raise ValueError(
                f"{path}: unsupported index format version {version!r} "
                f"(this build reads versions {_SUPPORTED_VERSIONS})")

        def group(prefix: str) -> dict[str, np.ndarray]:
            return {k[len(prefix):]: z[k] for k in z.files
                    if k.startswith(prefix)}

        trie_arrays = group("trie__")
        rt_arrays = group("rule_trie__")
        ts = meta["trie_scalars"]
        if not ts["has_cache"]:
            trie_arrays.pop("topk_score", None)
            trie_arrays.pop("topk_sid", None)
        trie = tb.DictTrie(**trie_arrays,
                           max_depth=ts["max_depth"],
                           max_syn_targets=ts["max_syn_targets"],
                           walk_tile=ts.get("walk_tile", 0),
                           emit_tile=ts.get("emit_tile", 0),
                           link_tile=ts.get("link_tile", 0))
        rule_trie = tb.RuleTrie(**rt_arrays, **meta["rule_trie_scalars"])
        if version < 2:   # pre-rule-plane container: rebuild from the CSRs
            tb.pack_rule_planes(trie, rule_trie)
        if version < 3:   # pre-stream-layout container: re-pack the tiles
            tb.pack_stream_tiles(trie, rule_trie)
        # a pre-v4 container whose spec asks for compression (or a v4 one
        # saved before packing) is re-packed on the fly; the dtype tiers
        # recomputed here overwrite whatever the stale metadata carried
        repacked_widths = None
        if meta["spec"].get("compression", "none") == "packed" \
                and not trie.has_packed:
            repacked_widths = tb.pack_compressed(trie)
        strings = _unpack_bytes(z["strings__blob"], z["strings__offsets"])
        scores = z["scores"]
        rules = [tb.SynonymRule(lhs, rhs) for lhs, rhs in zip(
            _unpack_bytes(z["rules__lhs_blob"], z["rules__lhs_offsets"]),
            _unpack_bytes(z["rules__rhs_blob"], z["rules__rhs_offsets"]))]

    spec = IndexSpec.from_dict(meta["spec"])
    known = {f.name for f in dataclasses.fields(eng.EngineConfig)}
    cfg = eng.EngineConfig(
        **{k: v for k, v in meta["cfg"].items() if k in known})
    # the substrate is a property of the *host* we load on, not the one
    # that saved: re-resolve the spec's (possibly "auto") choice here.
    # Plane/tile widths come from the (possibly just re-packed) structures
    # themselves (v1/v2 metadata predates them) and are cross-checked
    # before anything reaches the device.  A packed container elides the
    # dense planes, so its widths can only come from the (always-v4)
    # metadata; table_widths round-trips JSON as nested lists and must be
    # re-frozen to stay hashable in compile-cache keys.
    replace_kw = dict(
        substrate=eng.resolve_substrate(spec.substrate),
        term_width=rule_trie.term_plane.shape[1],
        table_widths=tuple((str(n), str(d)) for n, d in cfg.table_widths))
    # branch_width (max dict fanout; sizes the bounded-edit child windows)
    # is recomputed from the structures so pre-edit-mode containers load
    # with a correct value instead of the dataclass default
    if trie.first_child is not None:
        bw = int(np.diff(trie.first_child).max(initial=0))
    else:
        # packed container without the dense CSR: branch rows carry every
        # fanout >= 2 node; any DICT_UNARY flag means fanout 1 exists
        bw = int(np.diff(trie.b_ptr.astype(np.int64)).max(initial=0))
        if (trie.p_flags & tb.PACK_DICT_UNARY).any():
            bw = max(bw, 1)
    replace_kw["branch_width"] = max(bw, 1)
    if trie.tele_plane is not None:
        replace_kw.update(
            tele_width=trie.tele_plane.shape[1],
            walk_tile=trie.walk_tile, emit_tile=trie.emit_tile,
            link_tile=trie.link_tile)
    if repacked_widths is not None:
        replace_kw.update(
            compression="packed",
            table_widths=tuple(sorted(repacked_widths.items())))
    cfg = dataclasses.replace(cfg, **replace_kw)
    from repro.api.build import validate_rule_planes
    validate_rule_planes(trie, rule_trie, cfg)
    return {
        "spec": spec,
        "trie": trie,
        "rule_trie": rule_trie,
        "rules": rules,
        "strings": strings,
        "scores": scores,
        "cfg": cfg,
        "stats": BuildStats(**meta["stats"]),
        "epoch": int(meta.get("epoch", 0)),   # pre-mutation containers: 0
    }
