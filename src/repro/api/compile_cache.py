"""Bounded, bucketed cache for jit-compiled engine entry points.

The old ``CompletionIndex._compiled`` dict grew one entry per exact
(batch, length, k, cfg) tuple — unbounded under production traffic where
batch sizes drift.  Here shapes are first *bucketed* (batch and query
length rounded up to powers of two) so nearby shapes share an executable,
and the executables live in an LRU with a fixed capacity so a long-lived
serving process cannot accumulate compilations without bound.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Hashable


def bucket_size(n: int, minimum: int = 8) -> int:
    """Round ``n`` up to the next power of two (at least ``minimum``)."""
    n = max(int(n), 1)
    return max(minimum, 1 << (n - 1).bit_length())


class CompileCache:
    """LRU over compiled callables, keyed by hashable shape/config keys."""

    def __init__(self, maxsize: int = 32):
        if maxsize <= 0:
            raise ValueError("maxsize must be positive")
        self.maxsize = maxsize
        self._entries: OrderedDict[Hashable, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key: Hashable, factory: Callable[[], object]):
        """Return the cached value for ``key``, building it via ``factory``
        on a miss (evicting the least-recently-used entry when full)."""
        try:
            value = self._entries[key]
            self._entries.move_to_end(key)
            self.hits += 1
            return value
        except KeyError:
            self.misses += 1
        value = factory()
        self._entries[key] = value
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
            self.evictions += 1
        return value

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._entries

    def clear(self) -> None:
        self._entries.clear()

    def stats(self) -> dict:
        return {"size": len(self._entries), "maxsize": self.maxsize,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}
