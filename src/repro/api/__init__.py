"""repro.api — the public completion-index surface (v2).

Layers:

- :class:`IndexSpec` — declarative build specification + pluggable builder
  registry (``tt`` / ``et`` / ``ht`` / ``plain`` register themselves;
  new kinds are additive via :func:`register_builder`).
- :func:`build_index` / :class:`CompletionIndex` — construction, batched
  top-k lookup with a bounded bucketed compile cache, and versioned
  ``save``/``load`` persistence.
- :class:`Session` — stateful per-keystroke completion reusing the locus
  frontier across calls.

The old ``repro.core.api`` module re-exports this surface for back-compat.
"""

from repro.api.build import BuildStats, build_index
from repro.api.compile_cache import CompileCache, bucket_size
from repro.api.index import CompletionIndex
from repro.api.session import Session
from repro.api.spec import (IndexSpec, get_builder, register_builder,
                            registered_kinds)

__all__ = [
    "BuildStats",
    "CompileCache",
    "CompletionIndex",
    "IndexSpec",
    "Session",
    "bucket_size",
    "build_index",
    "get_builder",
    "register_builder",
    "registered_kinds",
]
