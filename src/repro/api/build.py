"""Index construction: shared host-side pipeline + per-kind rule policies.

``build_index(strings, scores, rules, spec)`` runs Alg. 1 / 3 / 5 of the
paper (array-encoded): build the dictionary trie, find all rule links,
ask the spec's registered builder which rules to expand (ET side) vs keep
in the link store (TT side), then materialize edges, rule trie, optional
top-K cache, and byte accounting.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

from repro.api.spec import (BuildContext, IndexSpec, get_builder,
                            get_synthesizer, register_builder,
                            register_rule_synthesizer)
from repro.core import engine as eng
from repro.core import knapsack as ks
from repro.core import trie_build as tb


@dataclass
class BuildStats:
    kind: str
    n_strings: int
    n_nodes: int
    n_syn_nodes: int
    n_links: int
    n_rules_expanded: int
    build_seconds: float
    bytes_total: int
    bytes_dict_nodes: int
    bytes_syn_nodes: int
    bytes_rule_side: int
    bytes_cache: int

    @property
    def bytes_per_string(self) -> float:
        return self.bytes_total / max(self.n_strings, 1)


# ---------------------------------------------------------------------------
# kind-specific rule partitioning (the pluggable part)
# ---------------------------------------------------------------------------


@register_builder("plain")
def _build_plain(ctx: BuildContext):
    n = len(ctx.rules)
    return np.zeros(n, bool), np.zeros(n, bool)


@register_builder("tt")
def _build_tt(ctx: BuildContext):
    n = len(ctx.rules)
    return np.zeros(n, bool), np.ones(n, bool)


@register_builder("et")
def _build_et(ctx: BuildContext):
    n = len(ctx.rules)
    return np.ones(n, bool), np.zeros(n, bool)


@register_builder("ht")
def _build_ht(ctx: BuildContext):
    items = ks.analyze_rules(ctx.rules, ctx.anchors, ctx.rids)
    s_et = int(items.w_orig.sum())  # node-count proxy for S_ET - S_TT
    budget = int(round(ctx.spec.alpha * s_et))
    expand_mask = ks.solve_knapsack(items, budget)
    return expand_mask, ~expand_mask


@register_builder("multiterm")
def _build_multiterm(ctx: BuildContext):
    # multi-term completion = ET-style expansion of the synthesized
    # token-skip rules (plus any user rules): every rule becomes synonym
    # branches with teleports, so a typed space fans out to the
    # gram-skipping targets through the ordinary teleport plane — a
    # vectorized gather, not a per-rule link-store loop (the synthesized
    # rules all share the one-byte lhs b" ", which would otherwise make
    # every space position match every rule)
    n = len(ctx.rules)
    return np.ones(n, bool), np.zeros(n, bool)


def multiterm_rules(strings, gap: int, existing=()) -> list[tb.SynonymRule]:
    """Token-skip rules for multi-term completion.

    For every contiguous run of 1..``gap`` interior tokens ``G`` that
    appears between spaces in some dictionary string, emit the rule
    ``b" " -> b" " + G + b" "``: typing a space may skip those tokens, so
    the *last* typed token completes conditioned on an earlier-token
    context ("the t" -> "the new york times").  Grams are deduplicated
    corpus-wide and against ``existing`` rules (so re-building from an
    index's persisted rule list does not double up).
    """
    seen = {(r.lhs, r.rhs) for r in existing}
    out: list[tb.SynonymRule] = []
    for s in strings:
        s = s.encode() if isinstance(s, str) else bytes(s)
        toks = [t for t in s.split(b" ") if t]
        # a gram must sit strictly between tokens: a space precedes it and
        # a completable token follows it
        for i in range(1, len(toks)):
            for n in range(1, gap + 1):
                if i + n > len(toks) - 1:
                    break
                gram = b" ".join(toks[i:i + n])
                key = (b" ", b" " + gram + b" ")
                if key not in seen:
                    seen.add(key)
                    out.append(tb.SynonymRule(*key))
    return out


@register_rule_synthesizer("multiterm")
def _synthesize_multiterm(spec: IndexSpec, strings, rules):
    return multiterm_rules(strings, spec.multiterm_gap, existing=rules)


# ---------------------------------------------------------------------------
# shared pipeline
# ---------------------------------------------------------------------------


def build_index(strings, scores, rules, spec: IndexSpec | None = None,
                **spec_kwargs):
    """Build a :class:`repro.api.CompletionIndex` from a spec.

    Either pass a ready ``spec`` or IndexSpec keyword fields (``kind=...``,
    ``alpha=...``, ...) — not both.
    """
    from repro.api.index import CompletionIndex

    if spec is None:
        spec = IndexSpec(**spec_kwargs)
    elif spec_kwargs:
        raise TypeError("pass either spec= or IndexSpec kwargs, not both")
    spec.validate()
    builder = get_builder(spec.kind)

    t0 = time.perf_counter()
    rules = list(rules)
    synthesizer = get_synthesizer(spec.kind)
    if synthesizer is not None:
        rules = rules + list(synthesizer(spec, strings, rules))
    trie, ss, sc = tb.build_dict_trie(strings, scores)
    anchors, rids, targets = tb.find_links(trie, rules)
    n_rules = len(rules)
    n_links = len(anchors)

    if n_rules == 0:
        expand_mask = np.zeros(0, dtype=bool)
        keep_links = np.zeros(0, dtype=bool)
    else:
        ctx = BuildContext(spec=spec, trie=trie, rules=rules,
                           anchors=anchors, rids=rids, targets=targets)
        expand_mask, keep_links = builder(ctx)
        expand_mask = np.asarray(expand_mask, dtype=bool)
        keep_links = np.asarray(keep_links, dtype=bool)

    n_syn = 0
    if expand_mask.any():
        n_syn = tb.expand_synonyms(trie, rules, anchors, rids, targets,
                                   expand_mask)
    else:
        tb.rebuild_edges(trie)

    link_sel = keep_links[rids] if n_links else np.zeros(0, bool)
    tb.set_link_store(trie, anchors[link_sel], rids[link_sel],
                      targets[link_sel])
    # rule trie holds only rules that still live on the rule side
    active = np.zeros(n_rules, dtype=bool)
    if n_links:
        active[np.unique(rids[link_sel])] = True
    rule_trie = tb.build_rule_trie(rules, active)

    if spec.cache_k > 0:
        tb.build_topk_cache(trie, spec.cache_k)
    tb.pack_rule_planes(trie, rule_trie)
    tb.pack_stream_tiles(trie, rule_trie)
    widths = tb.pack_compressed(trie) if spec.compression == "packed" else {}

    has_rule_side = bool(active.any())
    cfg = eng.EngineConfig(
        frontier=spec.frontier, gens=spec.gens, expand=spec.expand,
        max_steps=spec.max_steps,
        rule_matches=rule_trie.max_matches_per_pos if has_rule_side else 0,
        max_lhs_len=rule_trie.max_lhs_len if has_rule_side else 0,
        max_terms_per_node=rule_trie.max_terms_per_node,
        teleports=trie.max_syn_targets,
        tele_width=trie.tele_plane.shape[1],
        term_width=rule_trie.term_plane.shape[1],
        edit_budget=spec.edit_budget,
        branch_width=max(int(np.diff(trie.first_child).max(initial=0)), 1),
        walk_tile=trie.walk_tile, emit_tile=trie.emit_tile,
        link_tile=trie.link_tile,
        memory_budget=spec.memory_budget,
        use_cache=spec.cache_k > 0, cache_k=spec.cache_k,
        substrate=eng.resolve_substrate(spec.substrate),
        compression=spec.compression,
        table_widths=tuple(sorted(widths.items())),
    )
    validate_rule_planes(trie, rule_trie, cfg)
    stats = _make_stats(spec, trie, rule_trie, n_syn, link_sel, expand_mask,
                        len(ss), time.perf_counter() - t0)
    return CompletionIndex(spec, trie, rule_trie, rules, ss, sc, cfg, stats)


def validate_rule_planes(trie, rule_trie, cfg) -> None:
    """Cross-check the packed rule plane against the static widths the
    engine was configured with (the jit shape key).  Runs at build time and
    again when a persisted container is loaded, so a stale or hand-edited
    container fails loudly instead of mis-gathering on device."""
    if (cfg.compression == "packed") != trie.has_packed:
        raise ValueError(
            f"compression mismatch: cfg says {cfg.compression!r} but the "
            f"trie {'carries' if trie.has_packed else 'lacks'} the packed "
            "layout; rebuild the index (or re-save the container) with "
            "this version")
    if cfg.compression == "packed":
        validate_packed_layout(trie, cfg)
    if trie.tele_plane is None:
        # packed container with the dense planes elided: the rule trie is
        # kept intact, so its plane is still checked; everything dict-side
        # was covered by validate_packed_layout above
        want = (rule_trie.n_nodes, cfg.term_width)
        if rule_trie.term_plane is None or \
                tuple(rule_trie.term_plane.shape) != want:
            raise ValueError(
                f"rule plane 'term_plane' has shape "
                f"{None if rule_trie.term_plane is None else tuple(rule_trie.term_plane.shape)}, "
                f"expected {want}; rebuild the index with this version")
        return
    n = trie.n_nodes
    checks = [
        ("tele_plane", trie.tele_plane, (n, cfg.tele_width)),
        ("link_ptr", trie.link_ptr, (n + 1,)),
        ("term_plane", rule_trie.term_plane,
         (rule_trie.n_nodes, cfg.term_width)),
    ]
    for name, arr, want in checks:
        if arr is None or tuple(arr.shape) != want:
            got = None if arr is None else tuple(arr.shape)
            raise ValueError(
                f"rule plane {name!r} has shape {got}, expected {want}; "
                "rebuild the index (or re-save the container) with this "
                "version")
    # the plane widths are derived statics: they must agree with the
    # engine widths the DP actually loops over
    if cfg.tele_width != max(cfg.teleports, 1):
        raise ValueError(
            f"rule plane width mismatch: tele_width={cfg.tele_width} but "
            f"teleports={cfg.teleports}")
    if cfg.term_width != max(cfg.max_terms_per_node, 1):
        raise ValueError(
            f"rule plane width mismatch: term_width={cfg.term_width} but "
            f"max_terms_per_node={cfg.max_terms_per_node}")
    if int(trie.link_ptr[-1]) > len(trie.link_rule):
        raise ValueError("link_ptr does not cover the link store rows")
    validate_stream_tiles(trie, cfg)


def validate_stream_tiles(trie, cfg) -> None:
    """Cross-check the tile-aligned stream layout against the static tile
    widths the engine was configured with.  A window of ``tile`` elements
    anchored at any row start must cover the whole row and stay in
    bounds; a container violating either would make the DMA-streamed
    kernels read out of bounds or truncate rows, so it fails loudly here
    (at build time and again on load)."""
    groups = [
        ("walk_tile", cfg.walk_tile, trie.walk_tile,
         [(trie.first_child, trie.edge_char), (trie.first_child,
                                               trie.edge_child),
          (trie.s_first_child, trie.s_edge_char),
          (trie.s_first_child, trie.s_edge_child)]),
        ("emit_tile", cfg.emit_tile, trie.emit_tile,
         [(trie.emit_ptr, trie.emit_node), (trie.emit_ptr, trie.emit_score),
          (trie.emit_ptr, trie.emit_is_leaf)]),
        ("link_tile", cfg.link_tile, trie.link_tile,
         [(trie.link_ptr, trie.link_rule), (trie.link_ptr,
                                            trie.link_target)]),
    ]
    for name, want, got, pairs in groups:
        if want != got:
            raise ValueError(
                f"stream tile mismatch: cfg.{name}={want} but the trie "
                f"was packed with {got}; rebuild the index (or re-save "
                "the container) with this version")
        for ptr, arr in pairs:
            real = int(ptr[-1])
            if int(np.diff(ptr).max(initial=0)) > want:
                raise ValueError(
                    f"stream tile {name}={want} narrower than the longest "
                    "CSR row; rebuild the index with this version")
            expect = 0 if real == 0 else tb._tiled_len(real, want)
            if len(arr) != expect:
                raise ValueError(
                    f"stream layout under {name} has flat length "
                    f"{len(arr)}, expected {expect} for {real} rows; "
                    "rebuild the index (or re-save the container) with "
                    "this version")


def validate_packed_layout(trie, cfg) -> None:
    """Cross-check the compressed layout's side tables and recorded dtype
    tiers.  A corrupt container (truncated table, non-monotone pointers)
    or one whose dtype tier disagrees with ``cfg.table_widths`` (the
    compile-cache key) fails loudly here instead of mis-decoding on
    device.  Runs at build time and again on load."""
    if not trie.has_packed:
        raise ValueError(
            "compression='packed' but the trie has no packed layout; "
            "rebuild the index (or re-save the container) with this "
            "version")
    n = trie.n_nodes
    if len(trie.p_labels) != n or len(trie.p_flags) != n:
        raise ValueError(
            f"packed label/flag planes cover {len(trie.p_labels)} nodes, "
            f"expected {n}")
    groups = [
        ("c", trie.c_ids, trie.c_eptr,
         [trie.c_enode, trie.c_escore, trie.c_eleaf],
         [trie.c_tout, trie.c_maxscore]),
        ("b", trie.b_ids, trie.b_ptr, [trie.b_char, trie.b_child], []),
        ("sb", trie.sb_ids, trie.sb_ptr, [trie.sb_char, trie.sb_child], []),
        ("la", trie.la_ids, trie.la_ptr, [], []),
    ]
    for name, ids, ptr, rows, sides in groups:
        if len(ptr) != len(ids) + 1:
            raise ValueError(
                f"packed table {name!r}: pointer length {len(ptr)} does "
                f"not fit {len(ids)} ids")
        if len(ids) and not (np.diff(ids.astype(np.int64)) > 0).all():
            raise ValueError(f"packed table {name!r}: ids not sorted")
        if len(ptr) and (np.diff(ptr.astype(np.int64)) < 0).any():
            raise ValueError(f"packed table {name!r}: pointers not "
                             "monotone")
        for arr in rows:
            if len(arr) != (int(ptr[-1]) if len(ptr) else 0):
                raise ValueError(
                    f"packed table {name!r}: flat rows length {len(arr)} "
                    f"!= pointer total {int(ptr[-1]) if len(ptr) else 0}")
        for arr in sides:
            if len(arr) != len(ids):
                raise ValueError(
                    f"packed table {name!r}: side column length "
                    f"{len(arr)} != {len(ids)} ids")
    if tuple(trie.t_plane.shape) != (len(trie.t_ids), cfg.tele_width):
        raise ValueError(
            f"packed teleport plane has shape {tuple(trie.t_plane.shape)}, "
            f"expected ({len(trie.t_ids)}, {cfg.tele_width})")
    if len(trie.la_ptr) and trie.link_rule is not None and \
            int(trie.la_ptr[-1]) > len(trie.link_rule):
        raise ValueError("packed link spans exceed the link store rows")
    if len(trie.l_ids) != len(trie.l_sid):
        raise ValueError("packed terminal table column lengths differ")
    widths = dict(cfg.table_widths)
    tiered = ["c_maxscore", "c_escore", "l_sid"]
    if cfg.use_cache:
        tiered += ["pc_score", "pc_sid"]
        want = (len(trie.c_ids), cfg.cache_k)
        for name in ("pc_score", "pc_sid"):
            arr = getattr(trie, name)
            if arr is None or tuple(arr.shape) != want:
                raise ValueError(
                    f"packed cache plane {name!r} has shape "
                    f"{None if arr is None else tuple(arr.shape)}, "
                    f"expected {want}")
        if len(trie.pc_base) != len(trie.c_ids):
            raise ValueError("packed cache base column length mismatch")
    for name in tiered:
        arr = getattr(trie, name)
        if name not in widths:
            raise ValueError(
                f"packed table {name!r} missing from the recorded dtype "
                "tiers (cfg.table_widths)")
        if arr is None or str(arr.dtype) != widths[name]:
            got = None if arr is None else str(arr.dtype)
            raise ValueError(
                f"packed table {name!r} width mismatch: stored dtype "
                f"{got} but cfg.table_widths records {widths[name]!r}; "
                "rebuild the index (or re-save the container) with this "
                "version")


def _make_stats(spec, trie, rule_trie, n_syn, link_sel, expand_mask,
                n_strings, seconds) -> BuildStats:
    """Byte accounting (paper Table 2 / Fig. 5 breakdown)."""
    n_nodes = trie.n_nodes
    node_bytes = sum(getattr(trie, n).nbytes for n in (
        "parent", "depth", "chr_", "max_score", "leaf_score", "leaf_sid",
        "syn_mask", "tout"))
    edge_bytes = sum(getattr(trie, n).nbytes for n in (
        "first_child", "edge_char", "edge_child", "emit_ptr", "emit_node",
        "emit_score", "emit_is_leaf"))
    syn_edge_bytes = sum(getattr(trie, n).nbytes for n in (
        "s_first_child", "s_edge_char", "s_edge_child", "syn_ptr",
        "syn_tgt", "tele_plane"))
    link_bytes = sum(getattr(trie, n).nbytes for n in (
        "link_anchor", "link_rule", "link_target", "link_ptr"))
    cache_bytes = (trie.topk_score.nbytes + trie.topk_sid.nbytes
                   if trie.topk_score is not None else 0)
    syn_frac = n_syn / max(n_nodes, 1)
    if trie.has_packed:
        # what actually ships to the device is the packed layout + the
        # (kept) link store + rule trie — account those, not the host-side
        # build intermediates
        cache_bytes = sum(
            getattr(trie, f).nbytes for f in ("pc_score", "pc_base",
                                              "pc_sid")
            if getattr(trie, f) is not None)
        link_bytes = sum(
            getattr(trie, f).nbytes for f in ("link_rule", "link_target",
                                              "la_ids", "la_ptr"))
        node_edge = trie.packed_nbytes(include_cache=False) - link_bytes
        return BuildStats(
            kind=spec.kind, n_strings=n_strings, n_nodes=n_nodes,
            n_syn_nodes=n_syn,
            n_links=int(link_sel.sum()) if len(link_sel) else 0,
            n_rules_expanded=int(expand_mask.sum()),
            build_seconds=seconds,
            bytes_total=trie.packed_nbytes() + rule_trie.nbytes(),
            bytes_dict_nodes=int(node_edge * (1 - syn_frac)),
            bytes_syn_nodes=int(node_edge * syn_frac),
            bytes_rule_side=link_bytes + rule_trie.nbytes(),
            bytes_cache=cache_bytes,
        )
    return BuildStats(
        kind=spec.kind, n_strings=n_strings, n_nodes=n_nodes,
        n_syn_nodes=n_syn,
        n_links=int(link_sel.sum()) if len(link_sel) else 0,
        n_rules_expanded=int(expand_mask.sum()),
        build_seconds=seconds,
        bytes_total=node_bytes + edge_bytes + syn_edge_bytes + link_bytes
        + rule_trie.nbytes() + cache_bytes,
        bytes_dict_nodes=int((node_bytes + edge_bytes) * (1 - syn_frac)),
        bytes_syn_nodes=int((node_bytes + edge_bytes) * syn_frac)
        + syn_edge_bytes,
        bytes_rule_side=link_bytes + rule_trie.nbytes(),
        bytes_cache=cache_bytes,
    )
