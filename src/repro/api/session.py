"""Stateful incremental typing sessions.

A real autocomplete workload is per-keystroke: each query extends the
previous prefix by one char.  ``Session`` carries the engine's resumable
:class:`~repro.core.engine.LocusState` across keystrokes, so typing
``"Andy P"`` then ``"a"`` advances the existing locus frontier by one
char-step instead of re-running the full locus DP over the prefix.

A state snapshot is kept per typed char, so ``backspace()`` restores the
previous frontier without replay.  When the fixed-width frontier ever
overflowed (state inexact), top-k falls back to the one-shot
``index.complete`` path, which widens the search until exact.
"""

from __future__ import annotations

import numpy as np

import jax


def resolve_topk(index, scores, sids, exact, prefix: bytes, k: int):
    """Decode a session's device top-k into (score, string) pairs.

    When the result is inexact (frontier overflow or a failed beam bound)
    the widened one-shot ``index.complete`` path recovers exactness from
    the raw prefix — the single exactness contract shared by the
    sequential :class:`Session` and the batched scheduler demux.  Pending
    mutations take the same escape hatch: the compiled session top-k sees
    only the base epoch's tables, so the overlay-merged one-shot path
    answers from the raw prefix until the next ``compact()``."""
    if getattr(index, "has_mutations", False) or not bool(exact):
        return index.complete([bytes(prefix)], k=k)[0]
    return index._decode_row(scores, sids)


class Session:
    """Per-user incremental completion session over a CompletionIndex."""

    def __init__(self, index, k: int = 10):
        self.index = index
        self.k = k
        self._init, self._advance, self._topk = index._session_fns(k)
        self._prefix = bytearray()
        self._states = [jax.block_until_ready(self._init())]
        self._epoch = index.epoch

    def _sync_epoch(self) -> None:
        """Migrate onto the index's current epoch.

        After a hot-swap (``compact``) or ``reconfigure`` the compiled
        fns hold closures over the previous epoch's tables/config, so
        refetch them and re-derive the whole per-char state history by
        replaying the retained prefix — the keystroke-boundary migration
        the epoch versioning exists for."""
        if self._epoch == self.index.epoch:
            return
        self._init, self._advance, self._topk = \
            self.index._session_fns(self.k)
        states = [self._init()]
        for byte in self._prefix:
            states.append(self._advance(states[-1], np.int32(byte)))
        jax.block_until_ready(states[-1])
        self._states = states
        self._epoch = self.index.epoch

    # -- typing ------------------------------------------------------------

    @property
    def prefix(self) -> str:
        return bytes(self._prefix).decode("utf-8", errors="replace")

    def type(self, text: str | bytes) -> list[tuple[int, str]]:
        """Append keystrokes and return the top-k for the new prefix."""
        self._sync_epoch()
        data = text.encode() if isinstance(text, str) else bytes(text)
        for byte in data:
            self._states.append(
                self._advance(self._states[-1], np.int32(byte)))
            self._prefix.append(byte)
        return self.topk()

    def backspace(self, n: int = 1) -> list[tuple[int, str]]:
        """Remove the last ``n`` *characters* (restores the saved
        frontier).

        The prefix is a byte string with one engine state per byte, but a
        user-facing backspace removes a codepoint: deleting single bytes
        would leave a dangling multi-byte UTF-8 head whose loci match
        nothing (and which ``prefix`` can't even render).  Each character
        removed pops its full byte run — a continuation byte is
        ``0b10xxxxxx``, so scanning back over them finds the head."""
        self._sync_epoch()
        nbytes = 0
        for _ in range(n):
            if nbytes >= len(self._prefix):
                break
            # skip the character's continuation bytes, then its head
            while nbytes < len(self._prefix) - 1 and \
                    0x80 <= self._prefix[len(self._prefix) - 1 - nbytes] \
                    < 0xC0:
                nbytes += 1
            nbytes += 1
        if nbytes:
            del self._states[len(self._states) - nbytes:]
            del self._prefix[len(self._prefix) - nbytes:]
        return self.topk()

    def reset(self) -> None:
        del self._states[1:]
        self._prefix.clear()

    # -- lookup ------------------------------------------------------------

    def topk(self, k: int | None = None) -> list[tuple[int, str]]:
        """Top-k (score, suggestion) pairs for the current prefix."""
        if k is not None and k != self.k:
            # different k: no compiled session fn for it; one-shot path
            return self.index.complete([bytes(self._prefix)], k=k)[0]
        self._sync_epoch()
        scores, sids, exact = jax.tree.map(
            np.asarray, self._topk(self._states[-1]))
        return resolve_topk(self.index, scores, sids, exact,
                            bytes(self._prefix), self.k)
