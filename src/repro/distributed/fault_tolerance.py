"""Fault tolerance + straggler mitigation for the training loop.

On a real cluster the controller restarts failed workers from the latest
checkpoint; in-process we model exactly the host-side policies a controller
drives:

- TrainSupervisor.run: step loop with periodic async checkpoints; any
  exception inside a step (injected in tests; device loss in production)
  triggers restore-from-latest-valid and continues, up to max_restarts.
- StragglerWatchdog: per-step deadline (EWMA of recent step times x slack);
  overruns are recorded and surfaced so the orchestration layer can
  re-shard / evict the slow host. Mitigation action is a callback.
- Elastic restarts: restore() re-shards onto the current mesh (checkpoints
  are mesh-agnostic), so a restart may use a different device count.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from repro.checkpoint import CheckpointManager


@dataclass
class StragglerWatchdog:
    slack: float = 3.0
    ewma: float | None = None
    events: list = field(default_factory=list)
    on_straggler: object = None

    def observe(self, step: int, dt: float):
        if self.ewma is None:
            self.ewma = dt
            return
        deadline = self.ewma * self.slack
        if dt > deadline and step > 2:
            self.events.append({"step": step, "dt": dt, "deadline": deadline})
            if self.on_straggler is not None:
                self.on_straggler(step, dt, deadline)
        self.ewma = 0.9 * self.ewma + 0.1 * dt


@dataclass
class SupervisorReport:
    steps_run: int = 0
    restarts: int = 0
    restored_steps: list = field(default_factory=list)
    straggler_events: list = field(default_factory=list)


class TrainSupervisor:
    def __init__(self, ckpt_dir: str, ckpt_every: int = 50,
                 max_restarts: int = 3, watchdog_slack: float = 3.0):
        self.manager = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.watchdog = StragglerWatchdog(slack=watchdog_slack)

    def run(self, *, init_state, step_fn, n_steps: int,
            state_shardings=None, extra_from_state=None) -> tuple:
        """Run `n_steps` of `step_fn(state, step) -> state` with checkpoint/
        restart. Returns (final state, SupervisorReport)."""
        report = SupervisorReport()
        report.straggler_events = self.watchdog.events
        state = init_state
        step0, restored, extra = self.manager.restore(init_state,
                                                      state_shardings)
        start = 0
        if restored is not None:
            state, start = restored, step0
            report.restored_steps.append(step0)

        step = start
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                state = step_fn(state, step)
                self.watchdog.observe(step, time.perf_counter() - t0)
                step += 1
                report.steps_run += 1
                if step % self.ckpt_every == 0 or step == n_steps:
                    extra = (extra_from_state(state)
                             if extra_from_state else {})
                    self.manager.save(step, state, extra=extra)
            except Exception:
                if report.restarts >= self.max_restarts:
                    raise
                report.restarts += 1
                self.manager.wait()
                step0, restored, _ = self.manager.restore(init_state,
                                                          state_shardings)
                if restored is None:
                    state, step = init_state, 0
                else:
                    state, step = restored, step0
                report.restored_steps.append(step)
        self.manager.wait()
        return state, report
