"""Gradient compression: mixed reduce-scatter + int8 all-gather with error
feedback.

A ring fp32 all-reduce moves ~8 bytes/element of wire traffic. We split it:
  1. fp32 reduce_scatter (psum_scatter): ~4 B/elem — the sum must stay
     high-precision,
  2. int8 all_gather of the reduced chunk (+ one fp32 scale per chunk):
     ~1 B/elem instead of ~4.
Net ~5 B/elem vs ~8 (a 1.6x cut on the dp gradient exchange; the broadcast
phase alone is 4x smaller). The chunk owner keeps its quantization error
and re-injects it next step (error feedback, Karimireddy et al. 2019), so
convergence is preserved. At 1000+ nodes the dp all-reduce dominates
collective bytes (§Roofline) — this is the knob that moves it.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_allreduce_mean(x, axis_name: str):
    """Mean over `axis_name` via fp32 reduce_scatter + int8 all_gather.

    x: fp32[M] with M divisible by the axis size (caller pads).
    Returns (mean[M], local quantization error [M/n] scattered at this
    rank's chunk — zero elsewhere is implied by the caller's layout).
    """
    n = jax.lax.psum(1, axis_name)
    part = jax.lax.psum_scatter(x, axis_name, scatter_dimension=0,
                                tiled=True) / n            # fp32, M/n
    q, scale = quantize_int8(part)
    err = part - dequantize_int8(q, scale)                  # EF residual
    qg = jax.lax.all_gather(q, axis_name, tiled=True)       # int8, M
    sg = jax.lax.all_gather(scale, axis_name)               # fp32, n
    chunk = x.shape[0] // n
    scales = jnp.repeat(sg, chunk)
    out = qg.astype(jnp.float32) * scales
    return out, err


def compress_grads(grads, err_state, dp_axis: str = "data"):
    """int8+EF dp-mean of a gradient pytree (shard_map island).

    grads: replicated pytree; err_state: per-leaf fp32 residual of this
    rank's chunk [ceil(size/n)]. Returns (new grads, new err_state).
    """
    mesh = sh.current_mesh()
    if mesh is None or dp_axis not in mesh.axis_names:
        return grads, err_state
    n = dict(zip(mesh.axis_names, mesh.devices.shape))[dp_axis]

    def one(g, e):
        shape = g.shape
        flat = g.astype(jnp.float32).reshape(-1)
        pad = (-flat.shape[0]) % n
        flat = jnp.pad(flat, (0, pad))

        @partial(jax.shard_map, mesh=mesh, in_specs=(P(None), P(None)),
                 out_specs=(P(None), P(None)), check_vma=False)
        def run(x, e_prev):
            # error feedback: adding the (replicated) full residual on every
            # rank shifts the *mean* by exactly e_prev
            x = x + e_prev
            out, err = compressed_allreduce_mean(x, dp_axis)
            # store the residual replicated: gather every rank's chunk error
            return out, jax.lax.all_gather(err, dp_axis, tiled=True)

        out, err_full = run(flat, e)
        return (out[: g.size].reshape(shape).astype(g.dtype), err_full)

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))


def init_error_state(grads, dp_axis: str = "data"):
    mesh = sh.current_mesh()
    n = 1
    if mesh is not None and dp_axis in mesh.axis_names:
        n = dict(zip(mesh.axis_names, mesh.devices.shape))[dp_axis]

    def zeros(g):
        size = g.size
        return jnp.zeros((size + (-size) % n,), jnp.float32)

    return jax.tree.map(zeros, grads)
