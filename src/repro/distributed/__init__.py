from repro.distributed import compression, fault_tolerance, sharding
from repro.distributed.sharding import (constrain, current_mesh, dp_axes,
                                        sharding_for, tree_shardings,
                                        use_mesh)

__all__ = ["compression", "fault_tolerance", "sharding", "constrain",
           "current_mesh", "dp_axes", "sharding_for", "tree_shardings",
           "use_mesh"]
