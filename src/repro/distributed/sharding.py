"""Logical-axis sharding: models annotate tensors with *logical* names; the
active mesh maps them to physical axes.

Rules (DESIGN §6): batch-like dims spread over ("pod", "data"); tensor /
expert / vocab / embedding-row / candidate dims over "model". A mesh without
a "pod" axis (single pod) simply drops it. Axes not in the rules replicate.

Models call `constrain(x, "batch", None, "heads", None)` and stay mesh-
agnostic; launchers activate a mesh with `use_mesh(mesh)`.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

def missing_sharding_apis() -> list[str]:
    """Manual-sharding APIs the shard_map paths need but older jax
    releases only ship under experimental spellings.  Shared by the
    feature-detection flags in repro.core.distributed and
    repro.distributed.pipeline (tests skip on them)."""
    return [
        name for name, ok in [
            ("jax.shard_map", hasattr(jax, "shard_map")),
            ("jax.sharding.AxisType", hasattr(jax.sharding, "AxisType")),
        ] if not ok
    ]


LOGICAL_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "edges": ("pod", "data", "model"),   # GNN full-graph edge lists
    "heads": ("model",),
    "kv": ("model",),
    "mlp": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "rows": ("model",),     # embedding-table rows
    "cand": ("model",),     # retrieval candidates
    "seq": ("model",),      # sequence parallelism (long-context)
    "fsdp": ("data",),      # ZeRO-3-style weight sharding over the dp axis
                            # (weights re-gathered per scan step)
    "nodes": ("pod", "data", "model"),  # GNN node dim for full-graph MLPs
}

_state = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    """Activate `mesh` for constrain()/sharding() and XLA lowering."""
    prev = getattr(_state, "mesh", None)
    _state.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state.mesh = prev


@contextlib.contextmanager
def exclude_axes(*axes: str):
    """Drop physical axes from rule resolution — used inside shard_map
    islands where those axes are manual (e.g. 'pod' inside the pipeline-
    parallel island: 'batch' must map to ('data',) only there)."""
    prev = getattr(_state, "excluded", frozenset())
    _state.excluded = prev | set(axes)
    try:
        yield
    finally:
        _state.excluded = prev


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


def spec_for(logical: tuple, mesh: Mesh) -> P:
    names = set(mesh.axis_names) - getattr(_state, "excluded", frozenset())
    parts = []
    for ax in logical:
        if ax is None:
            parts.append(None)
            continue
        rule = LOGICAL_RULES.get(ax, ())
        phys = tuple(a for a in rule if a in names)
        parts.append(phys if phys else None)
    return P(*parts)


def sharding_for(logical: tuple, mesh: Mesh | None = None) -> NamedSharding:
    mesh = mesh or current_mesh()
    return NamedSharding(mesh, spec_for(logical, mesh))


def constrain(x: jax.Array, *logical):
    """with_sharding_constraint under the active mesh (no-op without one).

    Uses the *context* abstract mesh when tracing inside a shard_map island
    (its manual axes differ from the registered mesh; excluded axes are
    already dropped from the spec by exclude_axes)."""
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = spec_for(logical, mesh)
    abstract = jax.sharding.get_abstract_mesh()
    target = abstract if (abstract is not None
                          and getattr(abstract, "shape_tuple", None)) else mesh
    return jax.lax.with_sharding_constraint(x, NamedSharding(target, spec))


def tree_shardings(axes_tree, mesh: Mesh | None = None):
    """Map a pytree of logical-axes tuples to NamedShardings."""
    mesh = mesh or current_mesh()
    return jax.tree.map(
        lambda ax: sharding_for(ax, mesh), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def dp_axes(mesh: Mesh | None = None) -> tuple[str, ...]:
    mesh = mesh or current_mesh()
    excluded = getattr(_state, "excluded", frozenset())
    return tuple(a for a in ("pod", "data")
                 if a in mesh.axis_names and a not in excluded)


def dp_size(mesh: Mesh | None = None) -> int:
    mesh = mesh or current_mesh()
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = 1
    for a in dp_axes(mesh):
        out *= shape[a]
    return out


def model_size(mesh: Mesh | None = None) -> int:
    mesh = mesh or current_mesh()
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    return shape.get("model", 1)
