"""Pipeline parallelism (GPipe schedule) over the `pod` axis.

On the 2-pod mesh, the two pods become two pipeline stages: layers are
split [n_stages, L/n_stages, ...] and sharded over `pod`; microbatches
stream through ticks of a lax.scan; stage boundaries exchange activations
with collective_permute (ppermute). DP/TP/EP keep working *inside* the
island: shard_map is manual only over `pod` (axis_names={"pod"}), so GSPMD
still shards data/model within each stage (sharding.exclude_axes drops
`pod` from the logical rules inside).

Fill/drain bubble = (n_stages - 1) / (n_micro + n_stages - 1) — reported,
not hidden: invalid ticks still execute (masked), exactly like hardware.
Backward flows through ppermute automatically (its transpose is the
reverse permute), so jax.grad of the pipelined loss is 1F1B-equivalent
GPipe-with-recompute when the stage body is rematerialized.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as sh
from repro.models import layers as L
from repro.models import transformer as tf

# Feature detection mirroring repro.core.distributed: the pipelined loss
# needs top-level jax.shard_map (and meshes built with
# jax.sharding.AxisType); on older jax the tests skip on this flag.
_MISSING_SHARDING_APIS = sh.missing_sharding_apis()
HAS_MODERN_SHARDING = not _MISSING_SHARDING_APIS
SHARDING_SKIP_REASON = (
    "container jax lacks " + ", ".join(_MISSING_SHARDING_APIS)
    + " (pipeline parallelism needs a newer jax)"
) if _MISSING_SHARDING_APIS else ""


def stack_stages(params, n_stages: int):
    """Reshape layer-stacked leaves [L, ...] -> [n_stages, L/n_stages, ...]."""
    def reshape(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape((n_stages, l // n_stages) + x.shape[1:])

    out = dict(params)
    out["layers"] = jax.tree.map(reshape, params["layers"])
    return out


def stage_axes(axes, n_stages: int):
    out = dict(axes)
    out["layers"] = jax.tree.map(
        lambda ax: ("pp",) + ax, axes["layers"],
        is_leaf=lambda x: isinstance(x, tuple))
    return out


def make_pp_loss_fn(cfg: tf.TransformerConfig, n_micro: int):
    """Pipelined loss over the `pod` axis. params must be stage-stacked
    (stack_stages); batch as usual {tokens,targets,mask} [B, S]."""

    def loss_fn(params, batch, _cfg=None):
        if not HAS_MODERN_SHARDING:
            raise RuntimeError(SHARDING_SKIP_REASON)
        mesh = sh.current_mesh()
        assert mesh is not None and "pod" in mesh.axis_names, \
            "pipeline mode needs a mesh with a 'pod' axis"
        n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]
        B, S = batch["tokens"].shape
        assert B % n_micro == 0
        mb = B // n_micro

        stage_spec = jax.tree.map(lambda _: P("pod"), params["layers"])
        rest_spec = P()  # embed/unembed/final_ln replicated over pod

        @partial(jax.shard_map, mesh=mesh, axis_names={"pod"},
                 in_specs=({"layers": stage_spec, "embed": rest_spec,
                            "unembed": rest_spec, "final_ln": rest_spec},
                           P(), P(), P()),
                 out_specs=(P(), P()), check_vma=False)
        def run(p, tokens, targets, mask):
            with sh.exclude_axes("pod"):
                stage = jax.lax.axis_index("pod")
                layers = jax.tree.map(lambda x: x[0], p["layers"])
                positions = jnp.arange(S)[None, :]

                def stage_fwd(x):
                    def body(x, lp):
                        x, aux = tf._layer_fwd(cfg, x, lp, positions)
                        return x, aux

                    if cfg.remat:
                        body = jax.checkpoint(
                            body,
                            policy=jax.checkpoint_policies.nothing_saveable)
                    x, auxes = jax.lax.scan(body, x, layers)
                    return x, auxes.mean()

                fwd_perm = [(i, i + 1) for i in range(n_stages - 1)]

                def tick(carry, t):
                    x_prev, nll_sum, tok_sum, aux_sum = carry
                    x_recv = jax.lax.ppermute(x_prev, "pod", fwd_perm)
                    m_in = jnp.clip(t - stage, 0, n_micro - 1)
                    tok = jax.lax.dynamic_slice_in_dim(
                        tokens, m_in * mb, mb, axis=0)
                    x0 = L.embed_lookup(p["embed"], tok).astype(
                        jnp.dtype(cfg.activation_dtype))
                    x_in = jnp.where(stage == 0, x0, x_recv)
                    y, aux = stage_fwd(x_in)

                    # last stage computes the loss for its current microbatch
                    m_out = t - (n_stages - 1)
                    mo = jnp.clip(m_out, 0, n_micro - 1)
                    tgt = jax.lax.dynamic_slice_in_dim(
                        targets, mo * mb, mb, axis=0)
                    msk = jax.lax.dynamic_slice_in_dim(
                        mask, mo * mb, mb, axis=0)
                    yn = L.rms_norm(y, p["final_ln"], cfg.norm_eps)
                    nll, cnt = L.xent_loss_chunked(
                        yn, p["unembed"], tgt, msk, chunk=cfg.loss_chunk,
                        vocab_real=cfg.vocab, reduce="sum")
                    valid = ((m_out >= 0) & (m_out < n_micro)
                             & (stage == n_stages - 1)).astype(jnp.float32)
                    mvalid = ((t - stage >= 0) & (t - stage < n_micro))
                    return (y, nll_sum + nll * valid,
                            tok_sum + cnt * valid,
                            aux_sum + aux * mvalid.astype(jnp.float32)), None

                x0 = jnp.zeros((mb, S, cfg.d_model),
                               jnp.dtype(cfg.activation_dtype))
                ticks = jnp.arange(n_micro + n_stages - 1)
                (_, nll_sum, tok_sum, aux_sum), _ = jax.lax.scan(
                    tick, (x0, jnp.float32(0), jnp.float32(0),
                           jnp.float32(0)), ticks)
                # share sums across stages (only the last stage contributed)
                nll_sum = jax.lax.psum(nll_sum, "pod")
                tok_sum = jax.lax.psum(tok_sum, "pod")
                aux_sum = jax.lax.psum(aux_sum, "pod") / (n_stages * n_micro)
                loss = nll_sum / jnp.maximum(tok_sum, 1.0)
                return loss + cfg.aux_loss_weight * aux_sum, aux_sum

        loss, aux = run(params, batch["tokens"], batch["targets"],
                        batch["mask"])
        return loss, {"nll": loss, "aux": aux}

    return loss_fn


def pipeline_bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)
