"""Fault-tolerant checkpointing.

- atomic commit: write to step_XXXX.tmp/, fsync, rename; a crash mid-write
  never corrupts the latest checkpoint,
- CRC32 per array + manifest; restore skips corrupt checkpoints and falls
  back to the newest valid one (this is the "node failure" recovery path),
- async save thread (training never blocks on disk),
- elastic restore: arrays are stored host-complete with their logical axes;
  loading re-shards onto whatever mesh is active, so a 512-chip checkpoint
  restarts on 256 chips (and vice versa).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    leaves, _ = jax.tree.flatten(tree)
    keys = [".".join(str(p) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]
    return dict(zip([f"{i:05d}:{k}" for i, k in enumerate(keys)], leaves))


def _unflatten(flat: dict, proto):
    _, treedef = jax.tree.flatten(proto)
    leaves = [flat[k] for k in sorted(flat)]
    return jax.tree.unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, extra: dict | None = None,
             block: bool = False):
        flat = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        if self._thread is not None:
            self._thread.join()  # one in flight at a time
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}))
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict):
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "arrays": {}}
        for k, v in flat.items():
            fn = k.split(":", 1)[0] + ".npy"
            np.save(os.path.join(tmp, fn), v)
            with open(os.path.join(tmp, fn), "rb") as f:
                crc = zlib.crc32(f.read())
            manifest["arrays"][k] = {
                "file": fn, "crc": crc, "shape": list(v.shape),
                "dtype": str(v.dtype)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = sorted(self.list_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -- restore -------------------------------------------------------------

    def list_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def _verify_and_load(self, step: int):
        path = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat = {}
        for k, meta in manifest["arrays"].items():
            fp = os.path.join(path, meta["file"])
            with open(fp, "rb") as f:
                if zlib.crc32(f.read()) != meta["crc"]:
                    raise IOError(f"CRC mismatch in {fp}")
            flat[k] = np.load(fp)
        return manifest, flat

    def restore(self, proto_tree, shardings=None):
        """Newest valid checkpoint -> (step, tree, extra); (None, None, None)
        if nothing usable. `shardings`: optional pytree of NamedShardings
        (same structure) for elastic re-placement."""
        for step in reversed(self.list_steps()):
            try:
                manifest, flat = self._verify_and_load(step)
            except Exception:
                continue  # corrupt -> fall back to an older checkpoint
            tree = _unflatten(flat, proto_tree)
            if shardings is not None:
                tree = jax.tree.map(
                    lambda x, s: jax.device_put(x, s), tree, shardings)
            return manifest["step"], tree, manifest["extra"]
        return None, None, None


def restore_latest(directory: str, proto_tree, shardings=None):
    return CheckpointManager(directory).restore(proto_tree, shardings)
