"""Rule family KEY: compile-cache-key completeness.

The engine's compile cache (``api/compile_cache.py``) memoizes jitted
executables under key tuples the call sites assemble by hand, and the
kernel wrappers pass ``EngineConfig`` fields to jit-decorated builders as
keyword statics.  A config field that changes traced behavior but not
the key silently serves stale executables.

- ``KEY001`` *field missing from a cache key*: an ``EngineConfig`` field
  is read on a jitted/kernel-building code path (``core/engine/``,
  ``kernels/``) but the key tuple passed to ``*_compile_cache.get``
  neither contains the whole config object nor that field.
- ``KEY002`` *config not hashable-by-value*: ``EngineConfig`` is not a
  ``@dataclass(frozen=True)`` — an unfrozen config hashes by identity
  (or not at all), so equal configs stop sharing cache entries.
- ``KEY003`` *config-derived static not in static_argnames*: a call
  passes ``cfg.<field>`` (directly or through a ``dict(...)`` splat) as
  a keyword to a jit-decorated function whose ``static_argnames`` does
  not list that keyword — the field arrives as a traced value and stops
  specializing the executable.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import (SourceFile, call_callee, class_defs,
                                    decorator_static_argnames, dotted_name,
                                    import_map, iter_functions,
                                    top_level_functions)
from repro.analysis.findings import Finding

_CONFIG_CLASS = "EngineConfig"


def config_fields(files: list[SourceFile]) -> tuple[SourceFile | None,
                                                    ast.ClassDef | None,
                                                    set[str]]:
    """Locate the ``EngineConfig`` dataclass and its field names."""
    for sf in files:
        cls = class_defs(sf.tree).get(_CONFIG_CLASS)
        if cls is not None:
            fields = {n.target.id for n in cls.body
                      if isinstance(n, ast.AnnAssign)
                      and isinstance(n.target, ast.Name)}
            return sf, cls, fields
    return None, None, set()


def resolve_callee(sf: SourceFile, files: list[SourceFile],
                   callee: str) -> ast.FunctionDef | None:
    """Resolve a dotted callee through the file's imports to a top-level
    function in the scanned tree (same file first)."""
    parts = callee.split(".")
    local = top_level_functions(sf.tree).get(parts[0])
    if local is not None and len(parts) == 1:
        return local
    imports = import_map(sf.tree)
    by_mod: dict[str, SourceFile] = {}
    for f in files:
        mod = f.rel[:-3].replace("/", ".")
        by_mod[mod] = f
        by_mod["repro." + mod] = f
    if parts[0] in imports:
        mod, orig = imports[parts[0]]
        if len(parts) == 1:
            target = by_mod.get(mod)
            return (top_level_functions(target.tree).get(orig)
                    if target is not None else None)
        target = by_mod.get(f"{mod}.{orig}")
        return (top_level_functions(target.tree).get(parts[1])
                if target is not None else None)
    return None


def _is_frozen_dataclass(cls: ast.ClassDef) -> bool:
    for dec in cls.decorator_list:
        if isinstance(dec, ast.Call) and (call_callee(dec) or "") \
                .split(".")[-1] == "dataclass":
            for kw in dec.keywords:
                if kw.arg == "frozen" \
                        and isinstance(kw.value, ast.Constant) \
                        and kw.value.value is True:
                    return True
    return False


def _cfg_field_reads(tree: ast.AST, fields: set[str]) -> dict[str, int]:
    """``cfg.<field>`` reads (base named ``cfg`` / ``*.cfg``) -> first
    line."""
    out: dict[str, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and node.attr in fields:
            base = dotted_name(node.value)
            if base is not None and (base == "cfg"
                                     or base.endswith(".cfg")):
                out.setdefault(node.attr, node.lineno)
    return out


def _jit_scope_files(files: list[SourceFile]) -> list[SourceFile]:
    """The jitted/kernel-building scope whose config reads must be keyed:
    ``core/engine/`` and ``kernels/`` when present, else the whole tree
    (fixture corpora are flat)."""
    scoped = [sf for sf in files
              if sf.rel.startswith(("core/engine", "kernels"))]
    return scoped or files


def _key_sites(sf: SourceFile) -> list[tuple[ast.expr, int]]:
    """(key expression, line) of every ``*compile_cache*.get(key, ...)``
    call, with ``key`` resolved through a local assignment."""
    out: list[tuple[ast.expr, int]] = []
    for _, fn in iter_functions(sf.tree):
        assigns: dict[str, ast.expr] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                assigns[node.targets[0].id] = node.value
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call) and node.args):
                continue
            callee = call_callee(node)
            if callee is None or not callee.endswith(".get") \
                    or "compile_cache" not in callee:
                continue
            key = node.args[0]
            if isinstance(key, ast.Name):
                key = assigns.get(key.id, key)
            out.append((key, key.lineno if hasattr(key, "lineno")
                        else node.lineno))
    return out


def _key_coverage(key: ast.expr, fields: set[str]) -> tuple[bool, set[str]]:
    """(covers whole config, explicitly covered field names)."""
    covers_all = False
    covered: set[str] = set()
    # a "cfg" appearing only as the base of a field access (cfg.walk_tile)
    # puts that *field* in the key, not the whole object
    bases = {id(node.value) for node in ast.walk(key)
             if isinstance(node, ast.Attribute)}
    for node in ast.walk(key):
        if isinstance(node, ast.Attribute) and node.attr in fields:
            covered.add(node.attr)
        if id(node) in bases:
            continue
        name = dotted_name(node) if isinstance(
            node, (ast.Name, ast.Attribute)) else None
        if name is not None and (name == "cfg" or name.endswith(".cfg")):
            covers_all = True
    return covers_all, covered


def _cfg_derived_kwargs(call: ast.Call, fn: ast.FunctionDef,
                        fields: set[str]) -> list[tuple[str, str, int]]:
    """(kwarg name, config field, line) for every keyword of ``call``
    whose value reads ``cfg.<field>``, expanding ``**d`` splats through a
    local ``d = dict(...)`` assignment."""
    out: list[tuple[str, str, int]] = []

    def value_fields(expr: ast.expr) -> dict[str, int]:
        return _cfg_field_reads(expr, fields)

    dict_assigns: dict[str, ast.Call] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Call) \
                and call_callee(node.value) == "dict":
            dict_assigns[node.targets[0].id] = node.value

    for kw in call.keywords:
        if kw.arg is not None:
            for field, line in value_fields(kw.value).items():
                out.append((kw.arg, field, line))
        elif isinstance(kw.value, ast.Name) \
                and kw.value.id in dict_assigns:
            for inner in dict_assigns[kw.value.id].keywords:
                if inner.arg is None:
                    continue
                for field, line in value_fields(inner.value).items():
                    out.append((inner.arg, field, line))
    return out


def check(files: list[SourceFile]) -> list[Finding]:
    cfg_sf, cfg_cls, fields = config_fields(files)
    if cfg_cls is None or cfg_sf is None:
        return []
    out: list[Finding] = []

    if not _is_frozen_dataclass(cfg_cls):
        out.append(Finding(
            "KEY002", cfg_sf.rel, cfg_cls.lineno,
            f"{_CONFIG_CLASS} is not a frozen dataclass — it must hash "
            "by value to serve as a jit/compile-cache key component"))

    # KEY001: every field read on the jitted scope vs every key site
    reads: dict[str, tuple[str, int]] = {}
    for sf in _jit_scope_files(files):
        for field, line in _cfg_field_reads(sf.tree, fields).items():
            reads.setdefault(field, (sf.rel, line))
    for sf in files:
        for key, line in _key_sites(sf):
            covers_all, covered = _key_coverage(key, fields)
            if covers_all:
                continue
            for field in sorted(set(reads) - covered):
                rf, rl = reads[field]
                out.append(Finding(
                    "KEY001", sf.rel, line,
                    f"compile-cache key omits EngineConfig.{field}, "
                    f"which is read on a jitted path ({rf}:{rl}) — "
                    "changing it would reuse a stale executable"))

    # KEY003: cfg-derived keyword statics at jitted call sites
    for sf in files:
        for _, fn in iter_functions(sf.tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                derived = _cfg_derived_kwargs(node, fn, fields)
                if not derived:
                    continue
                callee = call_callee(node)
                if callee is None:
                    continue
                target = resolve_callee(sf, files, callee)
                if target is None:
                    continue
                statics = decorator_static_argnames(target)
                if statics is None:
                    continue        # not jit-decorated: nothing to ride
                for kwarg, field, line in derived:
                    if kwarg not in statics:
                        out.append(Finding(
                            "KEY003", sf.rel, line,
                            f"EngineConfig.{field} is passed as keyword "
                            f"{kwarg!r} to jitted {target.name!r} but "
                            f"{kwarg!r} is not in its static_argnames — "
                            "the field arrives traced and stops "
                            "specializing the executable"))
    return out
