"""CLI: ``python -m repro.analysis [paths...] [--fail-on-findings]``.

Prints one ``RULE-ID file:line message`` per finding and a summary.
Without ``--fail-on-findings`` the run is informational (exit 0 either
way); with it — the CI gate — any unwaived finding exits 1.
"""

from __future__ import annotations

import argparse
import sys

from repro.analysis.findings import Finding
from repro.analysis.runner import default_root, run_all


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Kernel sanitizer: static DMA-discipline, cache-key, "
                    "probe-envelope and traced-code checks.")
    p.add_argument("paths", nargs="*",
                   help="source trees to scan (default: the installed "
                        "repro package)")
    p.add_argument("--fail-on-findings", action="store_true",
                   help="exit 1 when any unwaived finding remains "
                        "(the CI gate)")
    p.add_argument("--show-waived", action="store_true",
                   help="also print findings suppressed by waivers")
    args = p.parse_args(argv)

    roots = args.paths or [default_root()]
    findings: list[Finding] = []
    for root in roots:
        findings += run_all(root)
    active = [f for f in findings if not f.waived]
    waived = [f for f in findings if f.waived]
    shown = findings if args.show_waived else active
    for f in shown:
        print(f.format())
    print(f"{len(active)} finding(s), {len(waived)} waived "
          f"({len(roots)} tree(s) scanned)")
    if active and args.fail_on_findings:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
