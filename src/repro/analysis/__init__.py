"""Kernel sanitizer: static analysis for the invariants the kernel and
substrate layers enforce by convention.

The fused Pallas kernels and their capability probes rest on
hand-maintained invariants — every started ``make_async_copy`` is waited
before its destination is read, every ``EngineConfig`` field that reaches
traced code rides the compile-cache key, every ``can_*``/``*_variant``
probe claims exactly the envelope its kernel can honor, and kernel bodies
never branch in Python on tracer values.  Nothing at runtime checks any
of this: a missed wait or a stale cache key is a silent wrong-results
bug.  This package verifies the invariants mechanically, over the AST,
without importing (let alone executing) the checked code.

Four rule families (see the rule modules for the per-rule contracts):

- :mod:`repro.analysis.dma`      — ``DMA001``-``DMA004``: DMA discipline
  in the streamed kernel tier (start/wait pairing, destination reads,
  double-buffer slot rotation);
- :mod:`repro.analysis.cachekey` — ``KEY001``-``KEY003``: compile-cache
  key completeness (config fields read under jit vs fields in the key,
  config hashability, config-derived statics at kernel call sites);
- :mod:`repro.analysis.envelope` — ``ENV001``-``ENV004``: probe/envelope
  consistency (byte-accounting field coverage, bounded scratch symbols,
  scratch bytes at the envelope maximum, structural pool guards);
- :mod:`repro.analysis.hygiene`  — ``TRC001``-``TRC002``: traced-code
  hygiene inside kernel bodies (no data-dependent Python ``if``/``while``,
  no dynamic trip counts).

Run it as ``python -m repro.analysis`` (add ``--fail-on-findings`` for
the CI gate).  A finding on a line carrying — or directly below — a
waiver comment ``# sanitizer: waive[RULE-ID] <reason>`` is suppressed;
the reason is mandatory and the waiver covers exactly one rule id (or
``*``).
"""

from __future__ import annotations

from repro.analysis.findings import Finding, Waiver, scan_waivers
from repro.analysis.runner import run_all

__all__ = ["Finding", "Waiver", "run_all", "scan_waivers"]
