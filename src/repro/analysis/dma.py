"""Rule family DMA: async-copy discipline in the streamed kernel tier.

Scope: every function in a module that uses ``make_async_copy`` (the
streamed kernels and :mod:`repro.kernels.stream` itself).  The analysis
is an AST-level dataflow pass per top-level function (nested defs are
analyzed as part of their parent — the pipeline driver splits starts and
waits across closures):

- ``DMA001`` *unwaited start*: a copy descriptor is ``.start()``-ed but
  no matching ``.wait()`` exists in the function.  Descriptors match by
  identity key: the normalized (src, dst) argument pair of an explicit
  ``make_async_copy`` call (the semaphore slot is deliberately ignored —
  re-creating the descriptor for the wait is the documented pattern), or
  the producer callable for descriptors obtained by calling/iterating a
  maker (``for dma in make_dmas(...)``).
- ``DMA002`` *wait without start*: the inverse — a wait whose descriptor
  was never started; it would block forever (or mask a missing
  transfer).
- ``DMA003`` *destination read before wait*: between a start and its
  wait (in source order), the destination ref of the in-flight copy is
  read — the read races the DMA.  Tracked for explicit descriptors whose
  destination is a named ref.
- ``DMA004`` *slot-rotation collision*: inside one loop body, a start
  and a wait on the same descriptor key resolve to the same semaphore
  slot for every trip parity — the double buffer degenerates to a single
  slot and the "next" transfer overwrites the one being consumed.  Slot
  expressions are taken from the last argument of maker calls (the
  ``make_dmas(j, slot)`` convention), from starter-helper call sites,
  or from the ``sem.at[slot]`` index of explicit descriptors, and are
  evaluated at both trip parities.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass

from repro.analysis.astutil import (SourceFile, call_callee, dotted_name,
                                    eval_int, iter_functions)
from repro.analysis.findings import Finding

_MAKER = "make_async_copy"


@dataclass
class _Event:
    kind: str              # "start" | "wait"
    key: str               # descriptor identity
    line: int
    slot: ast.expr | None  # semaphore slot expression, when recoverable
    dst: str | None        # destination base name (explicit descriptors)


def _norm(node: ast.expr) -> str:
    return ast.dump(node)


def _desc_key(call: ast.Call) -> tuple[str, str | None]:
    """Identity key + destination base name of an explicit
    ``make_async_copy(src, dst, sem)`` call (slot-independent)."""
    src = _norm(call.args[0]) if len(call.args) > 0 else ""
    dst = _norm(call.args[1]) if len(call.args) > 1 else ""
    dst_base: str | None = None
    if len(call.args) > 1:
        base: ast.expr = call.args[1]
        while isinstance(base, (ast.Attribute, ast.Subscript)):
            if isinstance(base, ast.Attribute) and base.attr == "at":
                base = base.value
                break
            base = base.value
        dst_base = dotted_name(base)
    return f"desc:{src}|{dst}", dst_base


def _desc_slot(call: ast.Call) -> ast.expr | None:
    """The ``sem.at[slot]`` index of an explicit descriptor."""
    if len(call.args) > 2:
        sem = call.args[2]
        if isinstance(sem, ast.Subscript):
            return sem.slice
    return None


def _is_maker(call: ast.Call) -> bool:
    callee = call_callee(call)
    return callee is not None and callee.split(".")[-1] == _MAKER


def _producer_key(call: ast.Call) -> str:
    callee = call_callee(call) or "<dynamic>"
    return f"prod:{callee.split('.')[-1]}"


class _Region:
    """Start/wait events of one top-level function (incl. nested defs)."""

    def __init__(self, fn: ast.FunctionDef) -> None:
        self.fn = fn
        self.assigns: dict[str, ast.expr] = {}
        self.loop_iters: dict[str, ast.expr] = {}
        self.events: list[_Event] = []
        self._collect(fn)
        self._inline_helpers()

    # -- event collection --------------------------------------------------

    def _collect(self, root: ast.AST) -> None:
        for node in ast.walk(root):
            if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                self.assigns[node.targets[0].id] = node.value
            elif isinstance(node, ast.For) \
                    and isinstance(node.target, ast.Name):
                self.loop_iters[node.target.id] = node.iter
        for node in ast.walk(root):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("start", "wait"):
                ev = self._event_for(node.func.attr, node.func.value,
                                     node.lineno)
                if ev is not None:
                    self.events.append(ev)
        self.events.sort(key=lambda e: e.line)

    def _event_for(self, kind: str, target: ast.expr,
                   line: int) -> _Event | None:
        """Resolve ``<target>.start()`` / ``.wait()`` to a descriptor."""
        if isinstance(target, ast.Call):
            return self._event_from_call(kind, target, line)
        if isinstance(target, ast.Name):
            expr = self.assigns.get(target.id)
            if isinstance(expr, ast.Call):
                return self._event_from_call(kind, expr, line)
            it = self.loop_iters.get(target.id)
            if isinstance(it, ast.Call):
                if _is_maker(it):
                    key, dst = _desc_key(it)
                    return _Event(kind, key, line, _desc_slot(it), dst)
                slot = it.args[-1] if it.args else None
                return _Event(kind, _producer_key(it), line, slot, None)
        return None

    def _event_from_call(self, kind: str, call: ast.Call,
                         line: int) -> _Event:
        if _is_maker(call):
            key, dst = _desc_key(call)
            return _Event(kind, key, line, _desc_slot(call), dst)
        slot = call.args[-1] if call.args else None
        return _Event(kind, _producer_key(call), line, slot, None)

    # -- starter-helper inlining (for slot rotation) -----------------------

    def _inline_helpers(self) -> None:
        """A nested def that only *starts* descriptors (e.g. the pipeline
        prologue helper) makes its call sites start events, with the slot
        argument mapped through the helper's slot parameter."""
        helpers: dict[str, tuple[str, int]] = {}
        for child in ast.walk(self.fn):
            if not isinstance(child, ast.FunctionDef) or child is self.fn:
                continue
            sub = _collect_events_only(child, self)
            starts = [e for e in sub if e.kind == "start"]
            waits = [e for e in sub if e.kind == "wait"]
            if not starts or waits:
                continue
            params = [a.arg for a in child.args.args]
            slot_idx = -1
            for e in starts:
                if isinstance(e.slot, ast.Name) and e.slot.id in params:
                    slot_idx = params.index(e.slot.id)
            helpers[child.name] = (starts[0].key, slot_idx)
        if not helpers:
            return
        for node in ast.walk(self.fn):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id in helpers:
                key, slot_idx = helpers[node.func.id]
                slot = node.args[slot_idx] \
                    if 0 <= slot_idx < len(node.args) else None
                self.events.append(
                    _Event("start", key, node.lineno, slot, None))
        self.events.sort(key=lambda e: e.line)


def _collect_events_only(fn: ast.FunctionDef, parent: _Region) -> list[_Event]:
    """Events of a nested def, resolved against the parent's bindings."""
    out: list[_Event] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("start", "wait"):
            ev = parent._event_for(node.func.attr, node.func.value,
                                   node.lineno)
            if ev is not None:
                out.append(ev)
    return out


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


def _pairing_findings(sf: SourceFile, region: _Region) -> list[Finding]:
    out: list[Finding] = []
    started = {e.key for e in region.events if e.kind == "start"}
    waited = {e.key for e in region.events if e.kind == "wait"}
    for e in region.events:
        if e.kind == "start" and e.key not in waited:
            out.append(Finding(
                "DMA001", sf.rel, e.line,
                "async copy started but never waited in this function — "
                "the destination may be read while the DMA is in flight"))
        if e.kind == "wait" and e.key not in started:
            out.append(Finding(
                "DMA002", sf.rel, e.line,
                "async-copy wait without a matching start — the wait "
                "blocks on a transfer that was never issued"))
    return out


def _read_before_wait(sf: SourceFile, region: _Region) -> list[Finding]:
    out: list[Finding] = []
    waits = [e for e in region.events if e.kind == "wait"]
    for s in region.events:
        if s.kind != "start" or s.dst is None:
            continue
        w_lines = [w.line for w in waits if w.key == s.key
                   and w.line > s.line]
        if not w_lines:
            continue                    # DMA001 reports the missing wait
        first_wait = min(w_lines)
        for node in ast.walk(region.fn):
            if isinstance(node, ast.Subscript) \
                    and isinstance(node.ctx, ast.Load) \
                    and s.line < node.lineno < first_wait \
                    and dotted_name(node.value) == s.dst:
                out.append(Finding(
                    "DMA003", sf.rel, node.lineno,
                    f"destination ref {s.dst!r} of the copy started at "
                    f"line {s.line} is read before its wait at line "
                    f"{first_wait} — the read races the DMA"))
    return out


def _slot_rotation(sf: SourceFile, region: _Region) -> list[Finding]:
    out: list[Finding] = []
    for ctx in ast.walk(region.fn):
        if not isinstance(ctx, (ast.FunctionDef, ast.For, ast.While)):
            continue
        if ctx is region.fn:
            # the top-level function itself is not a trip context —
            # straight-line start-then-wait on one slot is the legal
            # sequential pattern; rotation only matters inside loop
            # bodies (ast loops and the nested fori-body closures)
            continue
        lines = {n.lineno for n in ast.walk(ctx)
                 if hasattr(n, "lineno")}
        evs = [e for e in region.events if e.line in lines]
        starts = [e for e in evs if e.kind == "start" and e.slot is not None]
        waits = [e for e in evs if e.kind == "wait" and e.slot is not None]
        loop_vars = _loop_vars(ctx)
        for s in starts:
            for w in waits:
                if s.key != w.key or s.line == w.line:
                    continue
                if _always_same_parity(s.slot, w.slot, loop_vars):
                    out.append(Finding(
                        "DMA004", sf.rel, s.line,
                        "double-buffer slot rotation broken: the start at "
                        f"line {s.line} and the wait at line {w.line} "
                        "resolve to the same semaphore slot at every trip "
                        "parity — the in-flight transfer overwrites the "
                        "one being consumed"))
    return _dedup(out)


def _loop_vars(ctx: ast.AST) -> list[str]:
    if isinstance(ctx, ast.FunctionDef) and ctx.args.args:
        return [ctx.args.args[0].arg]
    if isinstance(ctx, ast.For) and isinstance(ctx.target, ast.Name):
        return [ctx.target.id]
    return []


def _always_same_parity(a: ast.expr | None, b: ast.expr | None,
                        loop_vars: list[str]) -> bool:
    if a is None or b is None:
        return False
    for trip in (0, 1):
        env = {v: trip for v in loop_vars}
        va, vb = eval_int(a, env), eval_int(b, env)
        if va is None or vb is None or (va % 2) != (vb % 2):
            return False
    return True


def _dedup(findings: list[Finding]) -> list[Finding]:
    seen: set[tuple[str, str, int]] = set()
    out: list[Finding] = []
    for f in findings:
        k = (f.rule, f.file, f.line)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out


def check(files: list[SourceFile]) -> list[Finding]:
    out: list[Finding] = []
    for sf in files:
        if _MAKER not in sf.source:
            continue
        seen_fns: set[int] = set()
        for qual, fn in iter_functions(sf.tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            if "<locals>" in qual:
                continue                # analyzed as part of the parent
            if id(fn) in seen_fns:
                continue
            seen_fns.add(id(fn))
            region = _Region(fn)
            if not region.events:
                continue
            out += _pairing_findings(sf, region)
            out += _read_before_wait(sf, region)
            out += _slot_rotation(sf, region)
    return _dedup(out)
