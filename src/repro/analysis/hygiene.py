"""Rule family TRC: traced-code hygiene in kernel modules.

Scope: every module that imports ``jax.experimental.pallas`` — the
kernel bodies and their helpers all trace under pallas, where Python
control flow must depend only on *static* values (kwonly statics,
annotated scalar params, shapes, module constants, and arithmetic over
those).  Branching on a tracer either crashes the trace or — worse —
freezes one branch into the compiled kernel.

- ``TRC001`` *tracer-dependent Python branch*: an ``if``/``while``/
  ternary whose test has a non-static leaf.  The sanctioned forms are
  ``jnp.where`` masking and ``pl.when``.
- ``TRC002`` *dynamic trip count*: a Python ``for`` over a ``range``
  with a non-static bound, a ``lax.fori_loop`` whose trip bounds are
  non-static, or any ``lax.while_loop`` — kernel loops must be masked
  fixed-trip loops (the beam kernel's ``max_steps`` pattern).

Staticness is a syntactic whitelist, evaluated per function in source
order with nested functions inheriting the enclosing static set:
module-level names, kwonly parameters, parameters annotated
``int``/``bool``/``str``/``float``, ``.shape`` attribute chains,
``int``/``len``/``max``/``min``/``bool``/``abs``/``isinstance`` calls
over statics, arithmetic/comparisons over statics, and targets of
``for _ in range(<static>)`` (trace-time-unrolled trip indices).
"""

from __future__ import annotations

import ast
import re

from repro.analysis.astutil import SourceFile, call_callee
from repro.analysis.findings import Finding

_PALLAS_IMPORT_RE = re.compile(
    r"from\s+jax\.experimental(\.pallas)?\s+import\s+.*pallas"
    r"|from\s+jax\.experimental\.pallas"
    r"|import\s+jax\.experimental\.pallas")

_STATIC_ANNOTATIONS = {"int", "bool", "str", "float"}
_STATIC_CALLS = {"int", "len", "max", "min", "bool", "abs", "str",
                 "tuple", "isinstance", "range"}
_BUILTINS = {"int", "bool", "str", "float", "len", "max", "min", "abs",
             "range", "tuple", "list", "dict", "set", "isinstance",
             "type", "TypeError", "ValueError", "RuntimeError",
             "AssertionError", "NotImplementedError"}


def _module_statics(tree: ast.Module) -> set[str]:
    out: set[str] = set(_BUILTINS)
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            out.add(node.name)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
        elif isinstance(node, ast.AnnAssign) \
                and isinstance(node.target, ast.Name):
            out.add(node.target.id)
        elif isinstance(node, ast.Import):
            for a in node.names:
                out.add((a.asname or a.name).split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                out.add(a.asname or a.name)
    return out


def _annotation_name(node: ast.expr | None) -> str | None:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.BitOr):
        # "int | None" style: static if either side is
        left = _annotation_name(node.left)
        return left if left in _STATIC_ANNOTATIONS \
            else _annotation_name(node.right)
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


class _Scope:
    def __init__(self, statics: set[str]) -> None:
        self.statics = set(statics)

    def is_static(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Constant):
            return True
        if isinstance(node, ast.Name):
            return node.id in self.statics
        if isinstance(node, ast.Attribute):
            # any chain through .shape is a trace-time-concrete size;
            # otherwise the root name must be static (module constants,
            # jnp dtypes, ...)
            parts = []
            cur: ast.expr = node
            while isinstance(cur, ast.Attribute):
                parts.append(cur.attr)
                cur = cur.value
            if "shape" in parts:
                return True
            return isinstance(cur, ast.Name) and cur.id in self.statics
        if isinstance(node, ast.Subscript):
            return self.is_static(node.value) and \
                self.is_static(node.slice)
        if isinstance(node, ast.Tuple):
            return all(self.is_static(e) for e in node.elts)
        if isinstance(node, (ast.BinOp,)):
            return self.is_static(node.left) and self.is_static(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.is_static(node.operand)
        if isinstance(node, ast.BoolOp):
            return all(self.is_static(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return self.is_static(node.left) and \
                all(self.is_static(c) for c in node.comparators)
        if isinstance(node, ast.Call):
            callee = call_callee(node)
            if callee is None or callee.split(".")[-1] not in _STATIC_CALLS:
                return False
            return all(self.is_static(a) for a in node.args) and \
                all(self.is_static(k.value) for k in node.keywords)
        if isinstance(node, ast.IfExp):
            return self.is_static(node.test) and \
                self.is_static(node.body) and self.is_static(node.orelse)
        return False


def _fn_scope(fn: ast.FunctionDef, outer: _Scope) -> _Scope:
    scope = _Scope(outer.statics)
    a = fn.args
    for arg in a.kwonlyargs:
        scope.statics.add(arg.arg)
    for arg in a.args + a.posonlyargs:
        ann = _annotation_name(arg.annotation)
        if ann in _STATIC_ANNOTATIONS:
            scope.statics.add(arg.arg)
    scope.statics.add(fn.name)
    return scope


def _bind_targets(tgt: ast.expr, static: bool, scope: _Scope) -> None:
    names = [n.id for n in ast.walk(tgt) if isinstance(n, ast.Name)]
    if static:
        scope.statics |= set(names)
    else:
        scope.statics -= set(names)


def _check_embedded_ifexp(stmt: ast.stmt, scope: _Scope, sf: SourceFile,
                          out: list[Finding]) -> None:
    for node in ast.walk(stmt):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return      # nested defs get their own pass
        if isinstance(node, ast.IfExp) and not scope.is_static(node.test):
            out.append(Finding(
                "TRC001", sf.rel, node.lineno,
                "conditional expression on a traced value in kernel "
                "code — use jnp.where (or pl.when) instead of a Python "
                "branch"))


def _check_loop_calls(stmt: ast.stmt, scope: _Scope, sf: SourceFile,
                      out: list[Finding]) -> None:
    for node in ast.walk(stmt):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return
        if not isinstance(node, ast.Call):
            continue
        callee = call_callee(node)
        if callee is None:
            continue
        base = callee.split(".")[-1]
        if base == "while_loop":
            out.append(Finding(
                "TRC002", sf.rel, node.lineno,
                "lax.while_loop in kernel code has a data-dependent "
                "trip count — use a masked fixed-trip fori_loop "
                "(the max_steps pattern)"))
        elif base == "fori_loop" and len(node.args) >= 2:
            for bound in node.args[:2]:
                if not scope.is_static(bound):
                    out.append(Finding(
                        "TRC002", sf.rel, node.lineno,
                        "fori_loop trip bound is not static in kernel "
                        "code — dynamic trip counts must become masked "
                        "fixed-trip loops"))
                    break


def _walk_body(body: list[ast.stmt], scope: _Scope, sf: SourceFile,
               out: list[Finding]) -> None:
    for stmt in body:
        _check_embedded_ifexp(stmt, scope, sf, out)
        _check_loop_calls(stmt, scope, sf, out)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if isinstance(stmt, ast.FunctionDef):
                inner = _fn_scope(stmt, scope)
                _walk_body(stmt.body, inner, sf, out)
            scope.statics.add(stmt.name)
        elif isinstance(stmt, ast.Assign):
            static = scope.is_static(stmt.value)
            if isinstance(stmt.value, ast.Attribute) \
                    and stmt.value.attr == "shape":
                static = True       # x, y = a.shape unpacks to statics
            for t in stmt.targets:
                _bind_targets(t, static, scope)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            _bind_targets(stmt.target, scope.is_static(stmt.value), scope)
        elif isinstance(stmt, ast.AugAssign):
            if not scope.is_static(stmt.value):
                _bind_targets(stmt.target, False, scope)
        elif isinstance(stmt, ast.If):
            if not scope.is_static(stmt.test):
                out.append(Finding(
                    "TRC001", sf.rel, stmt.lineno,
                    "Python `if` on a traced value in kernel code — "
                    "the branch freezes at trace time; use jnp.where "
                    "or pl.when"))
            _walk_body(stmt.body, scope, sf, out)
            _walk_body(stmt.orelse, scope, sf, out)
        elif isinstance(stmt, ast.While):
            if not scope.is_static(stmt.test):
                out.append(Finding(
                    "TRC001", sf.rel, stmt.lineno,
                    "Python `while` on a traced value in kernel code — "
                    "use a masked fixed-trip loop"))
            _walk_body(stmt.body, scope, sf, out)
        elif isinstance(stmt, ast.For):
            it = stmt.iter
            it_callee = call_callee(it) if isinstance(it, ast.Call) else None
            static_range = it_callee is not None \
                and it_callee.split(".")[-1] == "range"
            if static_range and isinstance(it, ast.Call):
                bad = [a for a in it.args if not scope.is_static(a)]
                if bad:
                    out.append(Finding(
                        "TRC002", sf.rel, stmt.lineno,
                        "Python `for` over a non-static range in kernel "
                        "code — the trip count must be static (masked "
                        "fixed-trip loop)"))
                _bind_targets(stmt.target, not bad, scope)
            else:
                _bind_targets(stmt.target, False, scope)
            _walk_body(stmt.body, scope, sf, out)
            _walk_body(stmt.orelse, scope, sf, out)
        elif isinstance(stmt, (ast.With, ast.Try)):
            for sub in ast.iter_child_nodes(stmt):
                if isinstance(sub, ast.stmt):
                    _walk_body([sub], scope, sf, out)
        elif isinstance(stmt, ast.ClassDef):
            for m in stmt.body:
                if isinstance(m, ast.FunctionDef):
                    inner = _fn_scope(m, scope)
                    _walk_body(m.body, inner, sf, out)
            scope.statics.add(stmt.name)


def check(files: list[SourceFile]) -> list[Finding]:
    out: list[Finding] = []
    for sf in files:
        if not _PALLAS_IMPORT_RE.search(sf.source):
            continue
        module_scope = _Scope(_module_statics(sf.tree))
        for node in sf.tree.body:
            if isinstance(node, ast.FunctionDef):
                _walk_body(node.body, _fn_scope(node, module_scope),
                           sf, out)
            elif isinstance(node, ast.ClassDef):
                for m in node.body:
                    if isinstance(m, ast.FunctionDef):
                        _walk_body(m.body, _fn_scope(m, module_scope),
                                   sf, out)
    seen: set[tuple[str, str, int]] = set()
    uniq: list[Finding] = []
    for f in out:
        key = (f.rule, f.file, f.line)
        if key not in seen:
            seen.add(key)
            uniq.append(f)
    return uniq
