"""Findings and inline waivers.

A :class:`Finding` is one rule violation anchored to ``file:line``.  The
waiver syntax is deliberately narrow: ``# sanitizer: waive[RULE-ID]
<reason>`` on the flagged line or the line directly above it, one rule id
per waiver (``*`` waives every rule on that line), reason mandatory.
Waivers without a reason are themselves reported (``WAIV01``), so a
waiver is always a reviewed, justified artifact rather than a mute
button.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from pathlib import Path

_WAIVER_RE = re.compile(
    r"#\s*sanitizer:\s*waive\[(?P<rule>[A-Z]+[0-9]*|\*)\]\s*(?P<reason>.*)$")


@dataclass(frozen=True)
class Finding:
    """One rule violation at ``file:line``."""

    rule: str
    file: str
    line: int
    message: str
    waived: bool = False

    def format(self) -> str:
        tag = " (waived)" if self.waived else ""
        return f"{self.rule} {self.file}:{self.line} {self.message}{tag}"


@dataclass(frozen=True)
class Waiver:
    """An inline ``# sanitizer: waive[RULE]`` comment."""

    rule: str
    line: int
    reason: str


@dataclass
class FileWaivers:
    """All waivers of one file, indexed by the lines they cover."""

    path: str
    waivers: list[Waiver] = field(default_factory=list)

    def covers(self, rule: str, line: int) -> Waiver | None:
        """The waiver covering ``rule`` at ``line``, if any.  A waiver
        covers its own line and the line directly below it (the
        waiver-above-the-statement form)."""
        for w in self.waivers:
            if w.line in (line, line - 1) and w.rule in (rule, "*"):
                return w
        return None


def scan_waivers(path: Path, source: str | None = None) -> FileWaivers:
    """Parse every waiver comment of one file."""
    if source is None:
        source = path.read_text()
    out = FileWaivers(path=str(path))
    for i, text in enumerate(source.splitlines(), start=1):
        m = _WAIVER_RE.search(text)
        if m is not None:
            out.waivers.append(Waiver(rule=m.group("rule"), line=i,
                                      reason=m.group("reason").strip()))
    return out


def apply_waivers(findings: list[Finding],
                  waivers: dict[str, FileWaivers]) -> list[Finding]:
    """Mark findings covered by a waiver; report reason-less waivers.

    Returns the finding list with covered entries flagged ``waived=True``
    plus one ``WAIV01`` finding per waiver missing its justification.
    """
    out: list[Finding] = []
    for f in findings:
        fw = waivers.get(f.file)
        w = fw.covers(f.rule, f.line) if fw is not None else None
        if w is not None:
            out.append(Finding(f.rule, f.file, f.line, f.message,
                               waived=True))
        else:
            out.append(f)
    for fw in waivers.values():
        for w in fw.waivers:
            if not w.reason:
                out.append(Finding(
                    "WAIV01", fw.path, w.line,
                    f"waiver for {w.rule} has no justification — "
                    "a waiver must say why the invariant holds anyway"))
    return out
