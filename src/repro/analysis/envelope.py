"""Rule family ENV: probe/envelope consistency.

Each ``PallasSubstrate`` capability probe (``walk_variant`` /
``beam_variant`` / the ``cached_topk_batch`` budget check) promises that
the shapes it admits fit the kernel it dispatches to.  The analyzer
reconstructs both sides statically — the probe's claimed envelope from
its byte-accounting field tuples (``_*_FIELDS``) and comparison guards,
the kernel's demand from the ``DeviceTrie`` fields it reads and the
``pltpu.VMEM`` scratch it allocates — and verifies claim ⊇ demand:

- ``ENV001`` *byte accounting misses a table*: the dispatch path reads a
  ``DeviceTrie`` field that no ``_*_FIELDS`` tuple referenced by the
  probe family accounts for — the probe under-counts VMEM demand and
  admits tries that do not fit.
- ``ENV002`` *unbounded scratch symbol*: a config-derived symbol sizes a
  ``pltpu.VMEM`` scratch shape but no probe comparison bounds it — a
  caller can legally configure scratch past any budget.
- ``ENV003`` *scratch exceeds VMEM at the envelope maximum*: the total
  scratch bytes of one kernel builder, evaluated with every symbol at
  its probe bound, exceed physical VMEM (``_VMEM_BYTES``, 16 MiB
  default) — the envelope admits shapes the hardware cannot host.
- ``ENV004`` *missing structural guard*: a kernel shape subtracts one
  config symbol from another (``W - f``: the pool must hold the seed
  antichain), but the family's probe has no comparison relating those
  two fields — out-of-order configs reach the kernel with a negative
  dimension.

Convention glue (kept here, in one place): dispatch methods read the
trie as ``t.<field>``; whole-``t`` calls are resolved one level into the
scanned tree; kernel parameters map to config fields by name plus
``_PARAM_ALIASES``; array-shape dims map via ``_SHAPE_ALIASES``.
"""

from __future__ import annotations

import ast

from repro.analysis.astutil import (SourceFile, call_callee, class_defs,
                                    class_int_constants, class_str_tuples,
                                    dotted_name, eval_int, import_map,
                                    methods_of, top_level_functions)
from repro.analysis.cachekey import config_fields, resolve_callee
from repro.analysis.findings import Finding

_DEFAULT_VMEM_BYTES = 16 << 20

# kernel parameter name -> EngineConfig field it carries
_PARAM_ALIASES = {
    "max_terms": "max_terms_per_node",
    "tile": "walk_tile",
}

# (array parameter, axis) -> EngineConfig field that sets the dim
_SHAPE_ALIASES = {
    ("tele_plane", 1): "tele_width",
    ("r_term_plane", 1): "term_width",
    ("loci", 1): "frontier",
}

_DTYPE_BYTES = {"int8": 1, "uint8": 1, "int16": 2, "uint16": 2,
                "bfloat16": 2, "float16": 2, "int32": 4, "uint32": 4,
                "float32": 4, "int64": 8, "float64": 8}


def _trie_fields(files: list[SourceFile]) -> set[str]:
    for sf in files:
        cls = class_defs(sf.tree).get("DeviceTrie")
        if cls is not None:
            return {n.target.id for n in cls.body
                    if isinstance(n, ast.AnnAssign)
                    and isinstance(n.target, ast.Name)}
    return set()


def _substrate_classes(files: list[SourceFile]) -> list[
        tuple[SourceFile, ast.ClassDef]]:
    out: list[tuple[SourceFile, ast.ClassDef]] = []
    for sf in files:
        for cls in class_defs(sf.tree).values():
            names = set(methods_of(cls))
            if any(n.endswith("_variant") for n in names) \
                    or any("_table_bytes" in ast.dump(m)
                           for m in methods_of(cls).values()):
                out.append((sf, cls))
    return out


def _probe_bounds(classes: list[tuple[SourceFile, ast.ClassDef]],
                  cfg_fields: set[str]) -> dict[str, int]:
    """Config symbols bounded by a probe comparison against a constant
    limit.  Both probe styles count: the reject form ``sym > LIMIT``
    (and ``LIMIT < sym``) and the accept form ``sym <= LIMIT`` (and
    ``LIMIT >= sym``) — either way the symbol never exceeds LIMIT on a
    kernel path, which is what the scratch-size evaluation needs."""
    bounds: dict[str, int] = {}
    for _, cls in classes:
        env = class_int_constants(cls)
        for m in methods_of(cls).values():
            for node in ast.walk(m):
                if not (isinstance(node, ast.Compare)
                        and len(node.ops) == 1
                        and isinstance(node.ops[0],
                                       (ast.Lt, ast.LtE, ast.Gt, ast.GtE))):
                    continue
                for sym_side, lim_side in (
                        (node.left, node.comparators[0]),
                        (node.comparators[0], node.left)):
                    sym = _config_sym(sym_side, cfg_fields)
                    limit = eval_int(lim_side, env)
                    if sym is not None and limit is not None:
                        bounds[sym] = max(bounds.get(sym, 0), limit)
                        break
    return bounds


def _config_sym(node: ast.expr, cfg_fields: set[str]) -> str | None:
    """``cfg.frontier`` / bare ``seq_len`` / ``k`` -> the config symbol."""
    if isinstance(node, ast.Attribute) and node.attr in cfg_fields:
        return node.attr
    if isinstance(node, ast.Name):
        sym = _PARAM_ALIASES.get(node.id, node.id)
        if sym in cfg_fields or node.id in ("k", "seq_len"):
            return sym if sym in cfg_fields else node.id
    return None


# ---------------------------------------------------------------------------
# ENV001: byte-accounting field coverage per probe family
# ---------------------------------------------------------------------------


def _families(cls: ast.ClassDef) -> dict[str, list[ast.FunctionDef]]:
    """Probe family -> its methods.  ``X_variant`` seeds family ``X``
    (probe + ``can_X_batch`` + the ``X*_batch`` dispatch); a dispatch
    that does its own ``_table_bytes`` check (``cached_topk_batch``) is
    its own family."""
    meths = methods_of(cls)
    fams: dict[str, list[ast.FunctionDef]] = {}
    for name, m in meths.items():
        if name.endswith("_variant"):
            fam = name[: -len("_variant")]
            members = [m]
            for other, om in meths.items():
                if other != name and (
                        other == f"can_{fam}_batch"
                        or (other.startswith(fam)
                            and other.endswith("_batch"))):
                    members.append(om)
            fams[fam] = members
    for name, m in meths.items():
        if name.endswith("_batch") \
                and not any(m in v for v in fams.values()) \
                and "_table_bytes" in ast.dump(m):
            fams[name] = [m]
    return fams


def _claimed_fields(members: list[ast.FunctionDef],
                    tuples: dict[str, tuple[str, ...]]) -> set[str]:
    claimed: set[str] = set()
    for m in members:
        for node in ast.walk(m):
            if isinstance(node, ast.Attribute) and node.attr in tuples:
                claimed |= set(tuples[node.attr])
            elif isinstance(node, ast.Name) and node.id in tuples:
                claimed |= set(tuples[node.id])
    return claimed


def _used_fields(sf: SourceFile, members: list[ast.FunctionDef],
                 files: list[SourceFile],
                 trie_fields: set[str]) -> set[str]:
    """``t.<field>`` reads in the family methods plus (one level deep)
    in functions the dispatch passes the whole ``t`` into."""
    used: set[str] = set()

    def t_reads(tree: ast.AST) -> set[str]:
        return {n.attr for n in ast.walk(tree)
                if isinstance(n, ast.Attribute) and n.attr in trie_fields
                and isinstance(n.value, ast.Name) and n.value.id == "t"}

    for m in members:
        used |= t_reads(m)
        for node in ast.walk(m):
            if isinstance(node, ast.Call) and any(
                    isinstance(a, ast.Name) and a.id == "t"
                    for a in node.args):
                callee = call_callee(node)
                if callee is None:
                    continue
                target = resolve_callee(sf, files, callee)
                if target is not None:
                    used |= t_reads(target)
    return used


# ---------------------------------------------------------------------------
# ENV002/ENV003: VMEM scratch vs the probe bounds
# ---------------------------------------------------------------------------


def _local_env(fn: ast.FunctionDef) -> dict[str, ast.expr]:
    out: dict[str, ast.expr] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            out[node.targets[0].id] = node.value
    return out


def _shape_alias(node: ast.expr) -> str | None:
    """``<param>.shape[<i>]`` (optionally int()-wrapped) -> config field."""
    if isinstance(node, ast.Call) and call_callee(node) == "int" \
            and len(node.args) == 1:
        node = node.args[0]
    if isinstance(node, ast.Subscript) \
            and isinstance(node.value, ast.Attribute) \
            and node.value.attr == "shape" \
            and isinstance(node.value.value, ast.Name) \
            and isinstance(node.slice, ast.Constant):
        return _SHAPE_ALIASES.get(
            (node.value.value.id, node.slice.value))
    return None


def _dim_symbols(node: ast.expr, locals_: dict[str, ast.expr],
                 cfg_fields: set[str], depth: int = 0) -> set[str]:
    """Config symbols a shape dim depends on (through local assigns)."""
    out: set[str] = set()
    if depth > 6:
        return out
    alias = _shape_alias(node)
    if alias is not None:
        return {alias}
    for leaf in ast.walk(node):
        if isinstance(leaf, ast.Name):
            if leaf.id in locals_:
                out |= _dim_symbols(locals_[leaf.id], locals_,
                                    cfg_fields, depth + 1)
            else:
                sym = _config_sym(leaf, cfg_fields)
                if sym is not None:
                    out.add(sym)
        elif isinstance(leaf, ast.Subscript):
            a = _shape_alias(leaf)
            if a is not None:
                out.add(a)
    return out


def _eval_dim(node: ast.expr, env: dict[str, int],
              locals_: dict[str, ast.expr], depth: int = 0) -> int | None:
    if depth > 6:
        return None
    alias = _shape_alias(node)
    if alias is not None:
        return env.get(alias)
    if isinstance(node, ast.Name) and node.id in locals_ \
            and node.id not in env:
        return _eval_dim(locals_[node.id], env, locals_, depth + 1)
    if isinstance(node, ast.BinOp):
        lhs = _eval_dim(node.left, env, locals_, depth + 1)
        rhs = _eval_dim(node.right, env, locals_, depth + 1)
        if lhs is None or rhs is None:
            return None
        fake = ast.BinOp(ast.Constant(lhs), node.op, ast.Constant(rhs))
        return eval_int(fake, {})
    if isinstance(node, ast.Call):
        callee = call_callee(node)
        if callee in ("max", "min", "int"):
            vals = [_eval_dim(a, env, locals_, depth + 1)
                    for a in node.args]
            if any(v is None for v in vals) or not vals:
                return None
            ints = [v for v in vals if v is not None]
            return (max(ints) if callee == "max"
                    else min(ints) if callee == "min" else ints[0])
        return None
    return eval_int(node, env)


def _param_env(fn: ast.FunctionDef, bounds: dict[str, int],
               cfg_fields: set[str]) -> dict[str, int]:
    """Parameter values at the envelope maximum: probe bound when the
    param aliases a bounded config symbol, else the signature default."""
    env: dict[str, int] = dict(bounds)
    args = fn.args
    every = args.args + args.kwonlyargs
    defaults = dict(zip([a.arg for a in args.args[len(args.args)
                                                  - len(args.defaults):]],
                        args.defaults))
    defaults.update({a.arg: d for a, d in zip(args.kwonlyargs,
                                              args.kw_defaults)
                     if d is not None})
    for a in every:
        sym = _PARAM_ALIASES.get(a.arg, a.arg)
        if sym in bounds:
            env[a.arg] = bounds[sym]
        elif a.arg in defaults:
            v = eval_int(defaults[a.arg], {})
            if v is not None:
                env[a.arg] = v
    return env


def _vmem_calls(fn: ast.FunctionDef) -> list[ast.Call]:
    out: list[ast.Call] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            callee = call_callee(node)
            if callee is not None and callee.split(".")[-1] == "VMEM":
                out.append(node)
    return out


def _dtype_bytes(node: ast.expr | None) -> int:
    if node is not None:
        name = dotted_name(node)
        if name is not None:
            return _DTYPE_BYTES.get(name.split(".")[-1], 4)
    return 4


# ---------------------------------------------------------------------------
# ENV004: structural requirements from subtractive shape dims
# ---------------------------------------------------------------------------


def _structural_requirements(fn: ast.FunctionDef,
                             cfg_fields: set[str]) -> list[
                                 tuple[str, str, int]]:
    """(bigger, smaller, line) for every shape dim ``A - B`` inside an
    array constructor — the kernel requires A >= B."""
    locals_ = _tuple_locals(fn)
    out: list[tuple[str, str, int]] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        callee = call_callee(node)
        if callee is None or callee.split(".")[-1] not in (
                "full", "zeros", "ones", "empty"):
            continue
        if not (node.args and isinstance(node.args[0], ast.Tuple)):
            continue
        for dim in node.args[0].elts:
            if isinstance(dim, ast.BinOp) and isinstance(dim.op, ast.Sub):
                a = _resolve_sym(dim.left, locals_, cfg_fields)
                b = _resolve_sym(dim.right, locals_, cfg_fields)
                if a is not None and b is not None and a != b:
                    out.append((a, b, node.lineno))
    return out


def _tuple_locals(fn: ast.FunctionDef) -> dict[str, ast.expr]:
    """Locals including tuple-unpacked ones; ``x, y = a.shape`` targets
    map to synthetic ``a.shape[i]`` subscripts."""
    out: dict[str, ast.expr] = {}
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        tgt, val = node.targets[0], node.value
        if isinstance(tgt, ast.Name):
            out[tgt.id] = val
        elif isinstance(tgt, ast.Tuple) \
                and all(isinstance(e, ast.Name) for e in tgt.elts):
            if isinstance(val, ast.Tuple) \
                    and len(val.elts) == len(tgt.elts):
                for e, v in zip(tgt.elts, val.elts):
                    out[e.id] = v       # type: ignore[union-attr]
            elif isinstance(val, ast.Attribute) and val.attr == "shape":
                for i, e in enumerate(tgt.elts):
                    sub = ast.Subscript(value=val, slice=ast.Constant(i),
                                        ctx=ast.Load())
                    out[e.id] = sub     # type: ignore[union-attr]
    return out


def _resolve_sym(node: ast.expr, locals_: dict[str, ast.expr],
                 cfg_fields: set[str], depth: int = 0) -> str | None:
    if depth > 6:
        return None
    alias = _shape_alias(node)
    if alias is not None:
        return alias
    if isinstance(node, ast.Name):
        sym = _config_sym(node, cfg_fields)
        if sym is not None:
            return sym
        if node.id in locals_:
            return _resolve_sym(locals_[node.id], locals_, cfg_fields,
                                depth + 1)
    return None


def _family_kernel_files(sf: SourceFile, members: list[ast.FunctionDef],
                         files: list[SourceFile]) -> list[SourceFile]:
    """Kernel modules a family dispatches into: the modules imported (at
    module level or inside the function) by every resolved callee the
    dispatch methods reach, plus the family's own file."""
    by_mod: dict[str, SourceFile] = {}
    for f in files:
        mod = f.rel[:-3].replace("/", ".")
        by_mod[mod] = f
        by_mod["repro." + mod] = f
    out: dict[str, SourceFile] = {sf.rel: sf}

    def add_imports(tree: ast.AST) -> None:
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module:
                target = by_mod.get(node.module)
                if target is not None:
                    out[target.rel] = target

    for m in members:
        for node in ast.walk(m):
            if isinstance(node, ast.Call):
                callee = call_callee(node)
                if callee is None:
                    continue
                target = resolve_callee(sf, files, callee)
                if target is not None:
                    add_imports(target)
    return list(out.values())


def _probe_relates(probe: ast.FunctionDef, a: str, b: str) -> bool:
    """True when some comparison in the probe mentions both fields."""
    for node in ast.walk(probe):
        if isinstance(node, ast.Compare):
            tails = {n.attr for n in ast.walk(node)
                     if isinstance(n, ast.Attribute)} | \
                    {n.id for n in ast.walk(node)
                     if isinstance(n, ast.Name)}
            if a in tails and b in tails:
                return True
    return False


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def check(files: list[SourceFile]) -> list[Finding]:
    _, _, cfg_fields = config_fields(files)
    trie_fields = _trie_fields(files)
    classes = _substrate_classes(files)
    if not classes:
        return []
    bounds = _probe_bounds(classes, cfg_fields)
    capacity = _DEFAULT_VMEM_BYTES
    for _, cls in classes:
        consts = class_int_constants(cls)
        if "_VMEM_BYTES" in consts:
            capacity = consts["_VMEM_BYTES"]
    out: list[Finding] = []

    # ENV001 + ENV004 per probe family
    for sf, cls in classes:
        tuples = class_str_tuples(cls)
        for fam, members in _families(cls).items():
            dispatch = members[-1]
            if trie_fields and tuples:
                claimed = _claimed_fields(members, tuples)
                used = _used_fields(sf, members, files, trie_fields)
                for field in sorted(used - claimed):
                    out.append(Finding(
                        "ENV001", sf.rel, dispatch.lineno,
                        f"probe family {fam!r} reads DeviceTrie.{field} "
                        "on its dispatch path but no _*_FIELDS byte "
                        "accounting includes it — the probe under-counts "
                        "VMEM demand"))
            probe = members[0]
            for kf in _family_kernel_files(sf, members, files):
                for kfn in top_level_functions(kf.tree).values():
                    for a, b, line in _structural_requirements(
                            kfn, cfg_fields):
                        if not _probe_relates(probe, a, b):
                            out.append(Finding(
                                "ENV004", kf.rel, line,
                                f"kernel shape requires {a} >= {b} but "
                                f"the {fam!r} probe has no comparison "
                                "relating them — out-of-order configs "
                                "reach the kernel with a negative "
                                "dimension"))

    # ENV002/ENV003 per kernel builder
    for sf in files:
        for fn in top_level_functions(sf.tree).values():
            vmems = _vmem_calls(fn)
            if not vmems:
                continue
            locals_ = _local_env(fn)
            env = _param_env(fn, bounds, cfg_fields)
            total = 0
            evaluated_all = True
            for call in vmems:
                shape = call.args[0] if call.args else None
                if not isinstance(shape, ast.Tuple):
                    evaluated_all = False
                    continue
                for dim in shape.elts:
                    for sym in sorted(_dim_symbols(dim, locals_,
                                                   cfg_fields)):
                        if sym not in bounds:
                            out.append(Finding(
                                "ENV002", sf.rel, call.lineno,
                                f"VMEM scratch dimension depends on "
                                f"config symbol {sym!r} but no probe "
                                "comparison bounds it — scratch can be "
                                "configured past any budget"))
                nbytes = _dtype_bytes(call.args[1]
                                      if len(call.args) > 1 else None)
                for dim in shape.elts:
                    v = _eval_dim(dim, env, locals_)
                    if v is None:
                        evaluated_all = False
                        nbytes = 0
                        break
                    nbytes *= v
                total += nbytes
            if evaluated_all and total > capacity:
                out.append(Finding(
                    "ENV003", sf.rel, vmems[0].lineno,
                    f"scratch of {fn.name!r} at the envelope maximum is "
                    f"{total} bytes, over the {capacity}-byte VMEM "
                    "capacity — the probe admits shapes the hardware "
                    "cannot host"))
    # one finding per (rule, file, line)
    seen: set[tuple[str, str, int]] = set()
    uniq: list[Finding] = []
    for f in out:
        k = (f.rule, f.file, f.line)
        if k not in seen:
            seen.add(k)
            uniq.append(f)
    return uniq
