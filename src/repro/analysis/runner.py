"""Orchestrates the rule families over a source tree and applies waivers.

``run_all(root)`` parses every ``.py`` under ``root`` (default: the
installed ``repro`` package source), runs the four rule families, and
applies the inline waiver comments.  The analyzer never imports the
checked code — a tree that fails to *parse* raises, but one that fails
to import analyzes fine.
"""

from __future__ import annotations

from pathlib import Path

from repro.analysis import cachekey, dma, envelope, hygiene
from repro.analysis.astutil import load_tree
from repro.analysis.findings import (Finding, FileWaivers, apply_waivers,
                                     scan_waivers)

#: relative path prefixes excluded from the scan: the analyzer does not
#: police itself (its sources quote the patterns it matches)
_EXCLUDE_PREFIXES = ("analysis",)


def default_root() -> Path:
    """The ``repro`` package source directory this module ships in."""
    return Path(__file__).resolve().parents[1]


def run_all(root: Path | str | None = None) -> list[Finding]:
    """Run every rule family over ``root``; returns findings sorted by
    location, with waived entries flagged (not dropped — the CLI and CI
    gate decide what to show and what to fail on)."""
    root = Path(root) if root is not None else default_root()
    files = [sf for sf in load_tree(root)
             if not sf.rel.startswith(_EXCLUDE_PREFIXES)]
    findings: list[Finding] = []
    findings += dma.check(files)
    findings += cachekey.check(files)
    findings += envelope.check(files)
    findings += hygiene.check(files)
    waivers: dict[str, FileWaivers] = {}
    for sf in files:
        fw = scan_waivers(sf.path, sf.source)
        fw.path = sf.rel
        if fw.waivers:
            waivers[sf.rel] = fw
    findings = apply_waivers(findings, waivers)
    return sorted(findings, key=lambda f: (f.file, f.line, f.rule))
