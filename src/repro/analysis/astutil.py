"""Shared AST machinery for the sanitizer rules.

Every rule works on parsed source (``ast``) — the checked modules are
never imported, so the sanitizer runs identically with or without jax
present and cannot be fooled by import-time behavior.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path
from typing import Iterator


@dataclass
class SourceFile:
    """One parsed source file (path relative to the scan root)."""

    path: Path
    rel: str
    source: str
    tree: ast.Module


def load_tree(root: Path) -> list[SourceFile]:
    """Parse every ``.py`` file under ``root`` (or the file itself)."""
    paths = [root] if root.is_file() else sorted(root.rglob("*.py"))
    base = root.parent if root.is_file() else root
    out: list[SourceFile] = []
    for p in paths:
        src = p.read_text()
        out.append(SourceFile(path=p, rel=str(p.relative_to(base)),
                              source=src, tree=ast.parse(src)))
    return out


def dotted_name(node: ast.expr) -> str | None:
    """``a.b.c`` -> "a.b.c"; None for anything not a pure name chain."""
    parts: list[str] = []
    cur: ast.expr = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return None


def call_callee(node: ast.Call) -> str | None:
    """The dotted callee name of a call, if it is a plain name chain."""
    return dotted_name(node.func)


def iter_functions(tree: ast.Module) -> Iterator[
        tuple[str, ast.FunctionDef | ast.AsyncFunctionDef]]:
    """Yield (qualname, node) for every function/method, including
    nested ones (qualnames are dotted: ``Class.method``,
    ``outer.<locals>.inner``)."""

    def walk(node: ast.AST, prefix: str) -> Iterator[
            tuple[str, ast.FunctionDef | ast.AsyncFunctionDef]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from walk(child, f"{q}.<locals>.")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)

    yield from walk(tree, "")


def top_level_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    """Module-level ``def``s by name (no methods, no nested defs)."""
    return {n.name: n for n in tree.body if isinstance(n, ast.FunctionDef)}


def class_defs(tree: ast.Module) -> dict[str, ast.ClassDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.ClassDef)}


def methods_of(cls: ast.ClassDef) -> dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body if isinstance(n, ast.FunctionDef)}


def class_int_constants(cls: ast.ClassDef) -> dict[str, int]:
    """Integer class attributes (``_BEAM_MAX_GENS = 256`` and
    ``_X = 8 << 20`` forms)."""
    out: dict[str, int] = {}
    for n in cls.body:
        if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                and isinstance(n.targets[0], ast.Name):
            v = eval_const_int(n.value)
            if v is not None:
                out[n.targets[0].id] = v
    return out


def class_str_tuples(cls: ast.ClassDef) -> dict[str, tuple[str, ...]]:
    """String-tuple class attributes (the probe field lists)."""
    out: dict[str, tuple[str, ...]] = {}
    for n in cls.body:
        if isinstance(n, ast.Assign) and len(n.targets) == 1 \
                and isinstance(n.targets[0], ast.Name) \
                and isinstance(n.value, (ast.Tuple, ast.List)):
            elts = n.value.elts
            if elts and all(isinstance(e, ast.Constant)
                            and isinstance(e.value, str) for e in elts):
                out[n.targets[0].id] = tuple(
                    e.value for e in elts
                    if isinstance(e, ast.Constant)
                    and isinstance(e.value, str))
    return out


def eval_const_int(node: ast.expr) -> int | None:
    """Evaluate a constant integer expression (literals, + - * // % << >>
    and unary minus); None when not constant."""
    return eval_int(node, {})


def eval_int(node: ast.expr, env: dict[str, int]) -> int | None:
    """Evaluate an integer expression over an environment binding plain
    and dotted names to ints.  Supports arithmetic, ``max``/``min``/
    ``int`` calls, and conditional expressions whose test is decidable.
    Returns None when any leaf is unbound."""
    if isinstance(node, ast.Constant):
        return node.value if isinstance(node.value, int) else None
    if isinstance(node, (ast.Name, ast.Attribute)):
        name = dotted_name(node)
        if name is None:
            return None
        if name in env:
            return env[name]
        tail = name.rsplit(".", 1)[-1]
        return env.get(tail)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = eval_int(node.operand, env)
        return None if v is None else -v
    if isinstance(node, ast.BinOp):
        lhs = eval_int(node.left, env)
        rhs = eval_int(node.right, env)
        if lhs is None or rhs is None:
            return None
        op = node.op
        if isinstance(op, ast.Add):
            return lhs + rhs
        if isinstance(op, ast.Sub):
            return lhs - rhs
        if isinstance(op, ast.Mult):
            return lhs * rhs
        if isinstance(op, ast.FloorDiv):
            return lhs // rhs if rhs else None
        if isinstance(op, ast.Mod):
            return lhs % rhs if rhs else None
        if isinstance(op, ast.LShift):
            return lhs << rhs
        if isinstance(op, ast.RShift):
            return lhs >> rhs
        return None
    if isinstance(node, ast.Call):
        callee = call_callee(node)
        args = [eval_int(a, env) for a in node.args]
        if any(a is None for a in args):
            return None
        vals = [a for a in args if a is not None]
        if callee == "max" and vals:
            return max(vals)
        if callee == "min" and vals:
            return min(vals)
        if callee == "int" and len(vals) == 1:
            return vals[0]
        return None
    return None


def import_map(tree: ast.Module) -> dict[str, tuple[str, str]]:
    """``from pkg.mod import name as alias`` bindings (module- and
    function-local): alias -> (pkg.mod, name)."""
    out: dict[str, tuple[str, str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                out[a.asname or a.name] = (node.module, a.name)
    return out


def decorator_static_argnames(fn: ast.FunctionDef) -> set[str] | None:
    """The ``static_argnames`` of a ``functools.partial(jax.jit, ...)``
    (or bare ``jax.jit(..., static_argnames=...)``) decorator; None when
    the function is not jit-decorated."""
    for dec in fn.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        callee = call_callee(dec)
        is_partial_jit = callee is not None \
            and callee.endswith("partial") and dec.args \
            and dotted_name(dec.args[0]) in ("jax.jit", "jit")
        is_direct_jit = callee in ("jax.jit", "jit")
        if not (is_partial_jit or is_direct_jit):
            continue
        for kw in dec.keywords:
            if kw.arg == "static_argnames" \
                    and isinstance(kw.value, (ast.Tuple, ast.List)):
                return {e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
        return set()
    return None


def contains_call(tree: ast.AST, suffixes: tuple[str, ...]) -> bool:
    """True when any call in ``tree`` has a callee ending in one of the
    dotted ``suffixes``."""
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            callee = call_callee(node)
            if callee is not None and any(
                    callee == s or callee.endswith("." + s)
                    for s in suffixes):
                return True
    return False
