"""Production mesh definitions (DESIGN §6).

Functions, not module-level constants: importing this module never touches
jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh_for_devices(n: int, model: int | None = None):
    """Small meshes for tests/examples on whatever devices exist."""
    model = model or (2 if n % 2 == 0 and n > 1 else 1)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def mesh_chip_count(mesh) -> int:
    return mesh.size
