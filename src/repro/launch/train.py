"""Training launcher: `python -m repro.launch.train --arch <id> [--smoke]`.

Runs the real thing end-to-end on whatever devices exist (CPU here; the
same code path drives TPU pods — mesh size is the only difference):
data pipeline -> jit'd train step (sharded) -> metrics; checkpoint/restart
via TrainSupervisor (fault tolerance), straggler watchdog, resumable data
state.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import all_archs
from repro.configs.cells import make_train_step
from repro.data.graph import NeighborSampler, make_random_graph
from repro.data.lm import LMDataConfig, TokenStream
from repro.data.recsys import ClickStream, RecsysDataConfig
from repro.distributed import sharding as sh
from repro.distributed.fault_tolerance import TrainSupervisor
from repro.launch.mesh import make_mesh_for_devices
from repro.models import gnn as gnn_m
from repro.models import recsys as rec_m
from repro.models import transformer as tf
from repro.optim import init_optimizer


def _lm_setup(spec, smoke, batch, seq):
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    params, _ = tf.init_lm(jax.random.PRNGKey(0), cfg)
    stream = TokenStream(LMDataConfig(vocab=cfg.vocab, seq_len=seq,
                                      global_batch=batch))
    step = make_train_step(tf.loss_fn, cfg, spec.optimizer)
    return cfg, params, stream.next_batch, step, stream


def _gnn_setup(spec, smoke, batch, seq):
    base = spec.make_smoke_config() if smoke else spec.make_config()
    cfg = gnn_m.GINConfig(name=base.name, n_layers=base.n_layers,
                          d_hidden=base.d_hidden, d_feat=32, n_classes=8)
    g = make_random_graph(2000, 12000, 32, 8, seed=0)
    sampler = NeighborSampler(g, seed=0)

    def next_batch():
        seeds = np.random.default_rng(sampler.rng.integers(2**31)).choice(
            g.n_nodes, batch, replace=False)
        return sampler.sample(seeds, (10, 5), n_pad=batch * 61,
                              e_pad=batch * 60)

    params, _ = gnn_m.init_gin(jax.random.PRNGKey(0), cfg)
    step = make_train_step(gnn_m.loss_full_graph, cfg, spec.optimizer)
    return cfg, params, next_batch, step, None


def _recsys_setup(spec, smoke, batch, seq):
    cfg = spec.make_smoke_config() if smoke else spec.make_config()
    from repro.configs.cells import _REC_FNS
    init, loss_fn = _REC_FNS[spec.arch_id][0], _REC_FNS[spec.arch_id][1]
    params, _ = init(jax.random.PRNGKey(0), cfg)
    stream = ClickStream(RecsysDataConfig(
        n_items=cfg.vocab, batch=batch, seq_len=getattr(cfg, "seq_len", 50)))

    def next_batch():
        if spec.arch_id == "dlrm-rm2":
            return stream.next_dlrm()
        raw = stream.next_seq(with_negatives=8)
        if spec.arch_id == "sasrec":
            return {"hist": raw["hist"], "pos": raw["pos"],
                    "neg": raw["neg_seq"]}
        return {k: raw[k] for k in
                ("hist", "target", "label", "neg")
                if k in raw} if spec.arch_id == "mind" else \
            {k: raw[k] for k in ("hist", "target", "label")}

    step = make_train_step(loss_fn, cfg, spec.optimizer)
    return cfg, params, next_batch, step, stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    spec = all_archs()[args.arch]
    setup = {"lm": _lm_setup, "gnn": _gnn_setup,
             "recsys": _recsys_setup}[spec.family]
    cfg, params, next_batch, step_fn, stream = setup(
        spec, args.smoke, args.batch, args.seq)

    mesh = make_mesh_for_devices(len(jax.devices()))
    opt_state = init_optimizer(spec.optimizer, params)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    sup = TrainSupervisor(f"{args.ckpt_dir}/{args.arch}",
                          ckpt_every=args.ckpt_every)
    hist = []

    def one_step(state, i):
        params, opt_state = state["params"], state["opt"]
        batch = jax.tree.map(jnp.asarray, next_batch())
        t0 = time.perf_counter()
        params, opt_state, metrics = jit_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        if i % args.log_every == 0:
            dt = time.perf_counter() - t0
            print(f"step {i:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):7.3f} {dt*1e3:7.1f}ms",
                  flush=True)
        hist.append(loss)
        return {"params": params, "opt": opt_state}

    with sh.use_mesh(mesh):
        state, report = sup.run(
            init_state={"params": params, "opt": opt_state},
            step_fn=one_step, n_steps=args.steps)

    print(json.dumps({
        "arch": args.arch, "steps": args.steps,
        "first_loss": hist[0] if hist else None,
        "last_loss": hist[-1] if hist else None,
        "restarts": report.restarts,
        "stragglers": len(report.straggler_events),
    }))


if __name__ == "__main__":
    main()
