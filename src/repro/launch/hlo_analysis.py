"""Loop-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE regardless of
trip count, so anything under lax.scan (layer stacks, flash-attention KV
blocks, loss chunks) is undercounted by the trip count. This module parses
the optimized per-device HLO text, reconstructs the computation call graph
(while bodies x trip counts, fusions, calls), and produces loop-aware
totals:

  - dot_flops:          2 * prod(out dims) * contraction, per execution
  - collective_bytes:   output bytes per collective kind
  - dot_bytes:          operand+output bytes of dot ops (memory-term proxy
                        for the MXU path; fusions' elementwise traffic is
                        not attributable from text and is reported separately
                        by cost_analysis)

Trip counts come from the canonical JAX lowering: the while condition
compares the induction variable with a `constant(N)`.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_SHAPE_RE = re.compile(r"([a-z]\w*?)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls=|to_apply=|condition=|body=)%?([\w.\-]+)")
_COLL_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_CONST_CMP = re.compile(r"constant\((\d+)\)")
_DOT_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^\n]*\bdot\(")
_DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*(\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"dot\(\s*%([\w.\-]+)")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclass
class Computation:
    name: str
    lines: list = field(default_factory=list)
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: dict = field(default_factory=dict)
    children: list = field(default_factory=list)   # (name, kind)
    while_bodies: list = field(default_factory=list)  # (cond, body)


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _COMP_RE.match(line) or (_COMP_RE.match(stripped)
                                     if stripped.endswith("{") else None)
        if m and ("->" in line):
            cur = Computation(m.group(1))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        cur.lines.append(line)
    return comps


def _analyze_line(comp: Computation, line: str, symtab: dict):
    # dot flops: out shape x contraction size (lhs shape via symbol table —
    # optimized HLO does not inline operand types)
    dm = _DOT_RE.search(line)
    if dm and "lhs_contracting_dims" in line:
        out_dtype, out_dims = dm.group(1), dm.group(2)
        out_elems = _shape_elems(out_dims)
        om = _OPERAND_RE.search(line)
        lhs_info = symtab.get(om.group(1)) if om else None
        cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        if lhs_info and cdims:
            lhs_dtype, lhs_dims = lhs_info
            lhs = [int(d) for d in lhs_dims.split(",") if d]
            contr = 1
            for ci in cdims.group(1).split(","):
                if ci and int(ci) < len(lhs):
                    contr *= lhs[int(ci)]
            comp.dot_flops += 2.0 * out_elems * contr
            comp.dot_bytes += _DTYPE_BYTES.get(out_dtype, 4) * out_elems
            comp.dot_bytes += _DTYPE_BYTES.get(lhs_dtype, 4) \
                * _shape_elems(lhs_dims)
            # rhs bytes ~ contraction x (out/lhs-batch) — approximate with
            # lhs-sized traffic again (upper bound is fine for a proxy)
            comp.dot_bytes += _DTYPE_BYTES.get(lhs_dtype, 4) \
                * _shape_elems(lhs_dims)
    # collectives
    cm = _COLL_RE.search(line)
    if cm and cm.group(2) != "-done":
        eq = line.find("=")
        seg = line[eq + 1: cm.start()] if eq >= 0 else line[: cm.start()]
        total = 0
        for dt, dims in _SHAPE_RE.findall(seg):
            bb = _DTYPE_BYTES.get(dt)
            if bb:
                total += bb * _shape_elems(dims)
        if total:
            comp.coll_bytes[cm.group(1)] = \
                comp.coll_bytes.get(cm.group(1), 0.0) + total
    # call graph
    wm = _WHILE_RE.search(line)
    if wm:
        comp.while_bodies.append((wm.group(1), wm.group(2)))
    else:
        for name in _CALL_RE.findall(line):
            comp.children.append(name)


def _trip_count(cond: Computation | None) -> int:
    if cond is None:
        return 1
    for line in cond.lines:
        if "compare" in line and "direction=LT" in line:
            consts = _CONST_CMP.findall(" ".join(cond.lines))
            if consts:
                return max(int(c) for c in consts)
    consts = _CONST_CMP.findall(" ".join(cond.lines))
    return max((int(c) for c in consts), default=1)


def analyze_hlo(hlo: str, entry: str | None = None) -> dict:
    comps = parse_computations(hlo)
    symtab: dict[str, tuple[str, str]] = {}
    for c in comps.values():
        for line in c.lines:
            dmm = _DEF_RE.search(line)
            if dmm:
                symtab[dmm.group(1)] = (dmm.group(2), dmm.group(3))
    for c in comps.values():
        for line in c.lines:
            _analyze_line(c, line, symtab)

    # entry: computation marked ENTRY (first line contains "ENTRY %name")
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
        entry = m.group(1) if m else next(iter(comps), None)

    flops_total = 0.0
    dot_bytes_total = 0.0
    coll_total: dict[str, float] = {}
    seen_stack: set[str] = set()

    def visit(name: str, mult: float):
        nonlocal flops_total, dot_bytes_total
        comp = comps.get(name)
        if comp is None or name in seen_stack:
            return
        seen_stack.add(name)
        flops_total += comp.dot_flops * mult
        dot_bytes_total += comp.dot_bytes * mult
        for k, v in comp.coll_bytes.items():
            coll_total[k] = coll_total.get(k, 0.0) + v * mult
        for cond, body in comp.while_bodies:
            trips = _trip_count(comps.get(cond))
            visit(body, mult * trips)
            seen_stack.discard(body)
        for child in comp.children:
            if child in (b for _, b in comp.while_bodies):
                continue
            if child in (c for c, _ in comp.while_bodies):
                continue
            visit(child, mult)
            seen_stack.discard(child)
        seen_stack.discard(name)

    if entry:
        visit(entry, 1.0)
    return {
        "dot_flops": flops_total,
        "dot_bytes": dot_bytes_total,
        "collective_bytes": coll_total,
    }
