"""Serving launcher.

  python -m repro.launch.serve --arch autocomplete-usps --queries 1000
  python -m repro.launch.serve --arch autocomplete-usps --workload keystroke
  python -m repro.launch.serve --arch qwen2.5-14b --smoke   (LM decode)

For autocomplete archs this is the paper's end-to-end system: build (or
``--load-index``) the index, replay a workload — one-shot batches or an
incremental per-keystroke stream through stateful sessions — and report
latency/throughput (Fig. 7-style numbers).  ``--substrate`` picks the
execution substrate (jnp reference vs Pallas kernels; ``auto`` resolves
to pallas on TPU only).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.api import IndexSpec, build_index
from repro.core import CompletionIndex, make_rules
from repro.configs import all_archs
from repro.data.strings import DATASETS, make_keystroke_events, make_workload
from repro.serving import (BatchStats, CompletionService, LMServer, Request,
                           SchedulerOverloaded)


def _make_index(spec, args):
    """Build the index from the arch's dataset, or restore a saved one."""
    name = spec.arch_id.split("-")[-1]
    cfg = spec.make_config()
    n = min(cfg.n_strings, args.n_strings)
    ds = DATASETS[name](n=n, seed=0)
    t0 = time.perf_counter()
    if args.load_index:
        # loading re-resolves the *saved* spec's substrate for this host;
        # only an explicit --substrate flag overrides it
        idx = CompletionIndex.load(args.load_index)
        overrides = {}
        if args.substrate is not None:
            overrides["substrate"] = args.substrate
        if args.memory_budget is not None:
            overrides["memory_budget"] = args.memory_budget
        if overrides:
            idx.reconfigure(**overrides)
    else:
        idx = build_index(
            ds.strings, ds.scores, make_rules(ds.rules),
            IndexSpec(kind=args.index_kind, cache_k=args.cache_k,
                      substrate=args.substrate or "auto",
                      memory_budget=args.memory_budget or 0,
                      compression=args.compression))
    build_s = time.perf_counter() - t0
    if args.save_index:
        idx.save(args.save_index)
    return ds, idx, build_s


def serve_autocomplete(spec, args):
    ds, idx, build_s = _make_index(spec, args)
    svc = CompletionService(idx)
    queries = make_workload(ds, args.queries, seed=1)
    # warmup + timed batches
    svc.complete(queries[:32], k=10)
    t0 = time.perf_counter()
    bs = args.batch
    results = []
    for i in range(0, len(queries), bs):
        results.extend(svc.complete(queries[i : i + bs], k=10))
    dt = time.perf_counter() - t0
    hit = sum(bool(r) for r in results) / max(len(results), 1)
    out = {
        "arch": spec.arch_id, "kind": idx.kind,
        "substrate": idx.substrate,
        "compression": idx.compression,
        "memory_budget": idx.memory_budget,
        "workload": "batch",
        "n_strings": idx.stats.n_strings,
        "bytes_per_string": round(idx.stats.bytes_per_string, 1),
        "build_seconds": round(build_s, 2),
        "queries": len(results),
        "us_per_completion": round(dt / max(len(results), 1) * 1e6, 1),
        "hit_rate": round(hit, 3),
    }
    print(json.dumps(out))
    return out


def serve_keystroke(spec, args):
    """Incremental replay: every query is typed char-by-char through a
    stateful session, i.e. the per-keystroke serving contract."""
    ds, idx, build_s = _make_index(spec, args)
    svc = CompletionService(idx)
    queries = make_workload(ds, args.queries, seed=1)
    sess = svc.open_session(k=10)
    sess.type(queries[0])                         # compile/warmup
    svc.stats.reset_keystrokes()
    hits = 0
    for q in queries:
        sess.reset()
        rows = sess.type(q)
        hits += bool(rows)
    st = svc.stats
    out = {
        "arch": spec.arch_id, "kind": idx.kind,
        "substrate": idx.substrate,
        "compression": idx.compression,
        "memory_budget": idx.memory_budget,
        "workload": "keystroke",
        "n_strings": idx.stats.n_strings,
        "build_seconds": round(build_s, 2),
        "queries": len(queries),
        "keystrokes": st.n_keystrokes,
        "us_per_keystroke": round(st.mean_keystroke_ms * 1e3, 1),
        "p99_keystroke_ms": round(st.p99_keystroke_ms(), 3),
        "hit_rate": round(hits / max(len(queries), 1), 3),
    }
    print(json.dumps(out))
    return out


def _replay_sequential(svc, events, n_sessions, k=10):
    """One device dispatch per keystroke: the pre-batching serving shape."""
    sessions = [svc.open_session(k=k) for _ in range(n_sessions)]
    out = []
    for s, c in events:
        if c < 0:
            sessions[s].reset()
        else:
            out.append(sessions[s].type(bytes([c])))
    for sess in sessions:
        sess.close()
    return out


def _replay_batched(svc, events, n_sessions, k=10):
    """Keystrokes submitted non-blocking so concurrent sessions coalesce
    into shared micro-batches.  Backpressure sheds load with one forced
    flush; a session whose stream ends is closed immediately so its idle
    lane stops holding back the full-flush condition."""
    remaining = [0] * n_sessions
    for s, _ in events:
        remaining[s] += 1
    sessions = [svc.open_session(k=k) for _ in range(n_sessions)]
    tickets = []
    for s, c in events:
        if c < 0:
            sessions[s].reset()
        else:
            try:
                tickets.append(sessions[s].submit(c))
            except SchedulerOverloaded:
                svc.flush()
                tickets.append(sessions[s].submit(c))
        remaining[s] -= 1
        if remaining[s] == 0:
            sessions[s].close()
    svc.drain()
    return [t.results for t in tickets]


def serve_zipf(spec, args):
    """Multi-session Zipf keystroke load, sequential per-session dispatch
    vs the continuous-batching scheduler — same events, bit-identity
    checked, speedup and tail latency reported."""
    ds, idx, build_s = _make_index(spec, args)
    events = make_keystroke_events(ds, args.sessions, args.queries, seed=1)
    seq = CompletionService(idx)
    bat = CompletionService(idx, batching=True, block=args.block,
                            max_wait_ms=args.max_wait_ms,
                            max_queue=args.max_queue)

    # untimed warmup replay through both paths so every jit shape (session
    # fns, slab fns, fallback buckets) is compiled before timing
    seq_results = _replay_sequential(seq, events, args.sessions)
    bat_results = _replay_batched(bat, events, args.sessions)

    def timed(svc, replay):
        svc.stats.reset_keystrokes()
        if svc.batching:
            svc.scheduler.stats = BatchStats()
        t0 = time.perf_counter()
        replay(svc, events, args.sessions)
        return time.perf_counter() - t0

    # best-of with the repeats interleaved, so ambient machine drift hits
    # both paths alike instead of biasing whichever ran second (the
    # sequential path's thousands of tiny dispatches are the noisy one)
    seq_dt = bat_dt = float("inf")
    for _ in range(args.repeats):
        seq_dt = min(seq_dt, timed(seq, _replay_sequential))
        bat_dt = min(bat_dt, timed(bat, _replay_batched))

    n = len(seq_results)
    bstats = bat.scheduler.stats
    out = {
        "arch": spec.arch_id, "kind": idx.kind,
        "substrate": idx.substrate,
        "compression": idx.compression,
        "workload": "zipf",
        "n_strings": idx.stats.n_strings,
        "build_seconds": round(build_s, 2),
        "sessions": args.sessions, "block": args.block,
        "queries": args.queries, "keystrokes": n,
        "bit_identical": seq_results == bat_results,
        "seq_us_per_keystroke": round(seq_dt / max(n, 1) * 1e6, 1),
        "batch_us_per_keystroke": round(bat_dt / max(n, 1) * 1e6, 1),
        "speedup": round(seq_dt / max(bat_dt, 1e-9), 2),
        "seq_p50_ms": round(seq.stats.p50_keystroke_ms(), 3),
        "seq_p99_ms": round(seq.stats.p99_keystroke_ms(), 3),
        "batch_p50_ms": round(bat.stats.p50_keystroke_ms(), 3),
        "batch_p99_ms": round(bat.stats.p99_keystroke_ms(), 3),
        "flushes": bstats.n_flushes,
        "mean_occupancy": round(bstats.mean_occupancy, 2),
        "deadline_flushes": bstats.deadline_flushes,
        "fallbacks": bstats.fallbacks,
    }
    print(json.dumps(out))
    return out


def serve_churn(spec, args):
    """Zipf keystroke stream with the dictionary churning underneath it.

    Every ``--churn-every`` keystrokes a mutation batch lands on the live
    index (one trending insert, one delete, one re-score); once the
    overlay backlog reaches ``--compact-at`` the service compacts —
    rebuilding in the background shape and hot-swapping under the open
    scheduler lanes, which migrate at their next flush.  Reports
    keystroke throughput alongside mutation/compaction cost, and
    verifies zero lost keystrokes plus probe-query agreement between the
    final overlay-merged answers and the post-compaction rebuilt index.
    """
    ds, idx, build_s = _make_index(spec, args)
    events = make_keystroke_events(ds, args.sessions, args.queries, seed=1)
    svc = CompletionService(idx, batching=True, block=args.block,
                            max_wait_ms=args.max_wait_ms,
                            max_queue=args.max_queue)
    rng = np.random.default_rng(2)
    base_strings = list(idx.strings)
    deleted: set[bytes] = set()
    remaining = [0] * args.sessions
    for s, _ in events:
        remaining[s] += 1
    sessions = [svc.open_session(k=10) for _ in range(args.sessions)]
    tickets = []
    mutations = {"insert": 0, "delete": 0, "rescore": 0}
    compactions = n_hot = 0
    mut_s = compact_s = 0.0
    t0 = time.perf_counter()
    for i, (s, c) in enumerate(events):
        if i and i % args.churn_every == 0:
            m0 = time.perf_counter()
            idx.insert(b"zz~trending-%d" % n_hot,
                       int(rng.integers(1, 1000)))
            n_hot += 1
            mutations["insert"] += 1
            victim = base_strings[int(rng.integers(len(base_strings)))]
            if victim not in deleted:
                idx.delete(victim)
                deleted.add(victim)
                mutations["delete"] += 1
            target = base_strings[int(rng.integers(len(base_strings)))]
            if target not in deleted:
                idx.update_score(target, int(rng.integers(1, 1000)))
                mutations["rescore"] += 1
            mut_s += time.perf_counter() - m0
            if idx.mutation_backlog >= args.compact_at:
                c0 = time.perf_counter()
                svc.compact()
                compact_s += time.perf_counter() - c0
                compactions += 1
        if c < 0:
            sessions[s].reset()
        else:
            try:
                tickets.append(sessions[s].submit(c))
            except SchedulerOverloaded:
                svc.flush()
                tickets.append(sessions[s].submit(c))
        remaining[s] -= 1
        if remaining[s] == 0:
            sessions[s].close()
    svc.drain()
    dt = time.perf_counter() - t0
    lost = sum(t.results is None for t in tickets)
    # verification: the overlay-merged answers must survive the fold —
    # compact() rebuilds from scratch internally, so pre/post agreement
    # on a probe batch is a merged-path-vs-rebuild differential for free
    probe = sorted({bytes(t.prefix)[:3] for t in tickets})[:24]
    pre = idx.complete(probe, k=10)
    c0 = time.perf_counter()
    svc.compact()
    compact_s += time.perf_counter() - c0
    compactions += 1
    post = idx.complete(probe, k=10)
    bstats = svc.scheduler.stats
    n = len(tickets)
    out = {
        "arch": spec.arch_id, "kind": idx.kind,
        "substrate": idx.substrate,
        "compression": idx.compression,
        "workload": "churn",
        "n_strings": idx.stats.n_strings,
        "build_seconds": round(build_s, 2),
        "sessions": args.sessions, "block": args.block,
        "keystrokes": n,
        "us_per_keystroke": round(dt / max(n, 1) * 1e6, 1),
        "p50_ms": round(svc.stats.p50_keystroke_ms(), 3),
        "p99_ms": round(svc.stats.p99_keystroke_ms(), 3),
        "mutations": mutations,
        "mutation_ms_mean": round(
            mut_s / max(sum(mutations.values()), 1) * 1e3, 3),
        "compactions": compactions,
        "compact_ms_mean": round(compact_s / max(compactions, 1) * 1e3, 1),
        "migrations": bstats.migrations,
        "final_epoch": idx.epoch,
        "lost_keystrokes": lost,
        "verified": pre == post,
        "flushes": bstats.n_flushes,
        "mean_occupancy": round(bstats.mean_occupancy, 2),
    }
    print(json.dumps(out))
    return out


def serve_lm(spec, args):
    from repro.models import transformer as tf

    cfg = spec.make_smoke_config()
    params, _ = tf.init_lm(jax.random.PRNGKey(0), cfg)
    server = LMServer(params, cfg, n_slots=args.batch, max_len=96)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.queries):
        server.scheduler.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, 8 + i % 8),
            max_new_tokens=16))
    done = server.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens) for r in done)
    ttfts = [r.first_token_at - r.created for r in done]
    out = {
        "arch": spec.arch_id, "requests": len(done),
        "tokens": toks, "tok_per_s": round(toks / dt, 1),
        "mean_ttft_ms": round(float(np.mean(ttfts)) * 1e3, 1),
    }
    print(json.dumps(out))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--queries", type=int, default=512)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--n-strings", type=int, default=100_000)
    ap.add_argument("--index-kind", default="et",
                    choices=["tt", "et", "ht", "plain"])
    ap.add_argument("--cache-k", type=int, default=0)
    ap.add_argument("--compression", default="none",
                    choices=["none", "packed"],
                    help="on-device index layout; packed = format-v4 "
                         "compressed tables (narrow dtypes, elided "
                         "planes, collapsed unary chains). Ignored with "
                         "--load-index (the container records it)")
    ap.add_argument("--substrate", default=None,
                    choices=["jnp", "pallas", "auto"],
                    help="execution substrate; auto = pallas on TPU, jnp "
                         "elsewhere (interpret-mode pallas is opt-in). "
                         "Default: auto when building, the saved choice "
                         "when --load-index")
    ap.add_argument("--memory-budget", type=int, default=None,
                    help="VMEM bytes the pallas substrate may spend "
                         "keeping tables resident; larger tables stream "
                         "from HBM (0/unset = substrate default). Applies "
                         "to built and --load-index'd indexes, batch and "
                         "keystroke workloads alike")
    ap.add_argument("--workload", default="batch",
                    choices=["batch", "keystroke", "zipf", "churn"],
                    help="batch = one-shot query batches; keystroke = one "
                         "session typing char-by-char; zipf = many "
                         "concurrent sessions under Zipf-skewed traffic, "
                         "sequential vs continuous-batching comparison; "
                         "churn = zipf traffic with live insert/delete/"
                         "re-score batches and periodic compaction "
                         "hot-swaps under the open sessions")
    ap.add_argument("--churn-every", type=int, default=64,
                    help="keystrokes between mutation batches for "
                         "--workload churn")
    ap.add_argument("--compact-at", type=int, default=48,
                    help="overlay backlog (pending inserts+tombstones) "
                         "that triggers a compaction hot-swap for "
                         "--workload churn")
    ap.add_argument("--sessions", type=int, default=8,
                    help="concurrent typing sessions for --workload zipf")
    ap.add_argument("--block", type=int, default=8,
                    help="scheduler micro-batch lanes (the slab jit shape)")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="scheduler latency budget before a partial-block "
                         "deadline flush")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="scheduler admission-queue bound (default "
                         "4*block); deeper queues trade keystroke latency "
                         "for fuller micro-batches")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed replays per path for --workload zipf "
                         "(interleaved best-of)")
    ap.add_argument("--save-index", default=None,
                    help="persist the built index to this .npz path")
    ap.add_argument("--load-index", default=None,
                    help="restore an index instead of rebuilding")
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    spec = all_archs()[args.arch]
    if spec.family == "autocomplete":
        if args.workload == "keystroke":
            serve_keystroke(spec, args)
        elif args.workload == "zipf":
            serve_zipf(spec, args)
        elif args.workload == "churn":
            serve_churn(spec, args)
        else:
            serve_autocomplete(spec, args)
    elif spec.family == "lm":
        serve_lm(spec, args)
    else:
        raise SystemExit(f"no serve mode for family {spec.family}")


if __name__ == "__main__":
    main()
