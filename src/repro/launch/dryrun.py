import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell: jit(step).lower(specs).compile()
on the single-pod (16,16) mesh AND the 2-pod (2,16,16) mesh, record
memory_analysis / cost_analysis / per-collective bytes to
results/dryrun_<mesh>.json. Any failure here is a bug in the system.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-14b
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

jnp_bf16 = jnp.bfloat16

from repro.configs import all_archs, get_arch  # noqa: E402
from repro.configs.cells import build_cell  # noqa: E402
from repro.distributed import sharding as sh  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d+(?:e\d+m\d+(?:fn)?)?|pred|bf16)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def collective_bytes(hlo_text: str) -> dict:
    """Per-device HLO: sum output bytes of every collective op (tuple
    outputs included; async start/done pairs counted once)."""
    out: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group(2) == "-done":
            continue
        kind = m.group(1)
        eq = line.find("=")
        seg = line[eq + 1 : m.start()] if eq >= 0 else line[: m.start()]
        total = 0
        for sm in _SHAPE_RE.finditer(seg):
            b = _DTYPE_BYTES.get(sm.group(1))
            if b is None:
                continue
            n = 1
            for d in sm.group(2).split(","):
                if d:
                    n *= int(d)
            total += n * b
        if total:
            out[kind] = out.get(kind, 0.0) + total
    return out


def cpu_bf16_convert_bytes(hlo_text: str, args, mesh,
                           min_bytes: int = 64 << 20) -> int:
    """XLA *CPU* upcasts bf16 matmul operands to f32 (hoisted out of scans
    when the operand is a loop-constant weight). These buffers do not exist
    on TPU (native bf16 MXU). We detect them as f32 HLO buffers whose shape
    equals the per-device shard shape of a bf16 input leaf, and report them
    so the TPU-adjusted temp memory is visible (EXPERIMENTS.md §Dry-run)."""
    import numpy as np

    shapes = set()
    for leaf in jax.tree.leaves(args):
        if getattr(leaf, "dtype", None) != jnp_bf16:
            continue
        shard = leaf.sharding.shard_shape(leaf.shape) \
            if leaf.sharding is not None else leaf.shape
        if int(np.prod(shard)) * 4 >= min_bytes:
            shapes.add(",".join(str(d) for d in shard))
    total = 0
    for s in shapes:
        if re.search(rf"=\s*f32\[{re.escape(s)}\]", hlo_text):
            n = 1
            for d in s.split(","):
                n *= int(d)
            total += n * 4
    return total


def run_cell(spec, shape_name: str, mesh, smoke: bool = False) -> dict:
    cell = spec.shapes[shape_name]
    if cell.skip:
        return {"arch": spec.arch_id, "shape": shape_name, "status": "SKIP",
                "reason": cell.skip}
    t0 = time.perf_counter()
    with sh.use_mesh(mesh):
        built = build_cell(spec, shape_name, mesh, smoke=smoke)
        fn = jax.jit(built.step_fn, donate_argnums=built.donate)
        lowered = fn.lower(*built.args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0] if ca else {}
        hlo = compiled.as_text()
        colls = collective_bytes(hlo)          # loop-UNAWARE (raw)
        loop_aware = analyze_hlo(hlo)          # x while-loop trip counts
        cvt = cpu_bf16_convert_bytes(hlo, built.args, mesh)
    n_dev = mesh.size
    return {
        "arch": spec.arch_id,
        "shape": shape_name,
        "status": "OK",
        "desc": built.desc,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": n_dev,
        "seconds": round(time.perf_counter() - t0, 2),
        "model_flops": built.model_flops,
        "flops_per_device": float(ca.get("flops", 0.0)),
        "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
        "collective_bytes_per_device": colls,
        "loop_aware": {
            "dot_flops_per_device": loop_aware["dot_flops"],
            "dot_bytes_per_device": loop_aware["dot_bytes"],
            "collective_bytes_per_device": loop_aware["collective_bytes"],
        },
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "cpu_bf16_convert_bytes": cvt,
            "temp_bytes_tpu_adjusted": max(mem.temp_size_in_bytes - cvt, 0),
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI sanity, not the deliverable)")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    archs = all_archs()
    ids = list(archs) if (args.all or not args.arch) else [args.arch]
    meshes = []
    if args.both_meshes:
        meshes = [False, True]
    else:
        meshes = [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        tag = "multipod" if multi_pod else "singlepod"
        results = []
        n_ok = n_skip = n_fail = 0
        for aid in ids:
            spec = archs[aid]
            shapes = [args.shape] if args.shape else list(spec.shapes)
            for s in shapes:
                try:
                    r = run_cell(spec, s, mesh, smoke=args.smoke)
                except Exception as e:  # a failure IS a bug — surface it
                    r = {"arch": aid, "shape": s, "status": "FAIL",
                         "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc()[-2000:]}
                results.append(r)
                st = r["status"]
                n_ok += st == "OK"
                n_skip += st == "SKIP"
                n_fail += st == "FAIL"
                msg = r.get("desc", r.get("reason", r.get("error", "")))
                print(f"[{tag}] {aid:>24s} {s:<16s} {st:<5s} "
                      f"{r.get('seconds', '')}s {msg}", flush=True)
        path = os.path.join(args.out,
                            f"dryrun_{tag}{'_smoke' if args.smoke else ''}.json")
        with open(path, "w") as f:
            json.dump(results, f, indent=1)
        print(f"[{tag}] OK={n_ok} SKIP={n_skip} FAIL={n_fail} -> {path}")
        if n_fail:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
