"""Core: the paper's contribution — synonym-aware top-k string completion."""

from repro.core.engine import DeviceTrie, EngineConfig
from repro.core.oracle import OracleIndex
from repro.core.trie_build import SynonymRule, make_rules

# The index/API layer lives in repro.api, which itself builds on the
# submodules above — resolve those names lazily (PEP 562) so importing
# repro.core.trie_build from repro.api doesn't recurse through this package.
# Resolution goes straight to repro.api (not the deprecated repro.core.api
# shim), so `from repro.core import CompletionIndex` stays warning-free.
_API_NAMES = ("BuildStats", "CompletionIndex", "IndexSpec", "Session",
              "build_index")

__all__ = [
    "BuildStats",
    "CompletionIndex",
    "DeviceTrie",
    "EngineConfig",
    "IndexSpec",
    "OracleIndex",
    "Session",
    "SynonymRule",
    "build_index",
    "make_rules",
]


def __getattr__(name):
    if name in _API_NAMES:
        from repro import api as _api
        return getattr(_api, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
