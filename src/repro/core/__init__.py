"""Core: the paper's contribution — synonym-aware top-k string completion."""

from repro.core.api import BuildStats, CompletionIndex
from repro.core.engine import DeviceTrie, EngineConfig
from repro.core.oracle import OracleIndex
from repro.core.trie_build import SynonymRule, make_rules

__all__ = [
    "BuildStats",
    "CompletionIndex",
    "DeviceTrie",
    "EngineConfig",
    "OracleIndex",
    "SynonymRule",
    "make_rules",
]
