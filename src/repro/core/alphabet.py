"""Byte-alphabet helpers for the completion engine.

Strings are handled as uint8 byte sequences (|sigma| = 256).  Device-side
queries are padded int32 matrices with -1 padding.
"""

from __future__ import annotations

import numpy as np

SIGMA = 256
PAD = -1


def encode(s: str | bytes) -> np.ndarray:
    """Encode a string to a uint8 numpy array."""
    if isinstance(s, str):
        s = s.encode("utf-8")
    return np.frombuffer(bytes(s), dtype=np.uint8)


def decode(a: np.ndarray) -> str:
    return bytes(a[a >= 0].astype(np.uint8)).decode("utf-8", errors="replace")


def pad_queries(queries: list[str | bytes], max_len: int) -> tuple[np.ndarray, np.ndarray]:
    """Encode and pad a batch of queries.

    Returns (chars[B, max_len] int32 with PAD fill, lengths[B] int32).
    Queries longer than max_len are truncated (and reported via length).
    """
    batch = len(queries)
    out = np.full((batch, max_len), PAD, dtype=np.int32)
    lens = np.zeros((batch,), dtype=np.int32)
    for i, q in enumerate(queries):
        e = encode(q)[:max_len]
        out[i, : len(e)] = e.astype(np.int32)
        lens[i] = len(e)
    return out, lens
