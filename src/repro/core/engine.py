"""Device-side top-k completion engine (pure jnp over array tries).

The paper's best-first heap search (Alg. 2 / Alg. 4) is re-cast for TPU as:

  phase 1 — *locus DP*: a fixed-width frontier sweep over query positions.
      reach[pos] = set of trie nodes reachable by consuming p[:pos] under
      some rewriting.  Transitions: literal char step (dict + synonym-branch
      children), synonym teleports (ET/HT expanded rules), and rule steps
      through the link store (TT/HT unexpanded rules).  All fixed shapes.

  phase 2 — *top-k*: either
      (a) beam generators: each locus becomes a lazy generator over its
          score-sorted emission list; every step pops the best P emissions
          across all generators (lax.top_k) and re-arms them.  This is the
          paper's priority queue, vectorized P-at-a-time, with the same
          admissible bound (max descendant score).  Exactness is tracked:
          if the width-bounded pools ever dropped a candidate better than
          the k-th result, the query is flagged for a host-side retry with
          doubled widths.
      (b) cached top-K (beyond-paper, cf. Li et al. [9]): gather the
          materialized per-node top-K lists of the locus antichain and merge.
          O(1) lookups, no while_loop; exact for k <= K.

Everything here lowers under jit/vmap/shard_map with ShapeDtypeStruct
inputs, which is what the multi-pod dry-run exercises.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INT_MAX = np.int32(2**31 - 1)
NEG_ONE = np.int32(-1)


class DeviceTrie(NamedTuple):
    # dict-trie node arrays
    depth: jax.Array        # int32[N]
    max_score: jax.Array    # int32[N]
    leaf_score: jax.Array   # int32[N]
    leaf_sid: jax.Array     # int32[N]
    syn_mask: jax.Array     # bool[N]
    tout: jax.Array         # int32[N]
    # dict child CSR
    first_child: jax.Array  # int32[N+1]
    edge_char: jax.Array    # int32[E]
    edge_child: jax.Array   # int32[E]
    # synonym child CSR
    s_first_child: jax.Array
    s_edge_char: jax.Array
    s_edge_child: jax.Array
    # emissions
    emit_ptr: jax.Array
    emit_node: jax.Array
    emit_score: jax.Array
    emit_is_leaf: jax.Array
    # teleports
    syn_ptr: jax.Array
    syn_tgt: jax.Array
    # link store
    link_anchor: jax.Array
    link_rule: jax.Array
    link_target: jax.Array
    # rule trie
    r_first_child: jax.Array
    r_edge_char: jax.Array
    r_edge_child: jax.Array
    r_term_ptr: jax.Array
    r_term_rule: jax.Array
    r_rule_len: jax.Array
    # materialized per-node top-K (dummy (1,1) when disabled)
    topk_score: jax.Array
    topk_sid: jax.Array


@dataclass(frozen=True)
class EngineConfig:
    """Static engine shape parameters (hashable; part of the jit key)."""

    frontier: int = 32          # F: locus DP width
    gens: int = 48              # W: generator pool width (beam phase)
    expand: int = 8             # P: emissions popped per beam step
    max_steps: int = 256        # beam step cap
    rule_matches: int = 0       # M: max lhs matches per query position
    max_lhs_len: int = 0        # rule-trie walk depth
    max_terms_per_node: int = 1
    teleports: int = 0          # Ts: max teleport targets per node
    use_cache: bool = False     # phase-2 via materialized top-K
    cache_k: int = 0


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def _iters_for(n: int) -> int:
    return max(1, int(math.ceil(math.log2(max(n, 1) + 1))))


def _lower_bound(arr: jax.Array, lo, hi, x, iters: int):
    """First index in [lo, hi) with arr[idx] >= x (vectorized, fixed iters)."""
    size = max(int(arr.shape[0]), 1)
    for _ in range(iters):
        cont = lo < hi
        mid = (lo + hi) >> 1
        v = arr[jnp.clip(mid, 0, size - 1)]
        go_right = v < x
        lo = jnp.where(cont & go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
    return lo


def _csr_child_lookup(ptr, chars, children, nodes, ch, iters: int):
    """children[nodes] labelled ch via binary search in each CSR row; -1 if
    absent. nodes may contain -1 entries (propagated)."""
    if int(chars.shape[0]) == 0:
        return jnp.full(jnp.broadcast_shapes(nodes.shape, jnp.shape(ch)),
                        NEG_ONE, jnp.int32)
    valid = nodes >= 0
    n = jnp.where(valid, nodes, 0)
    lo = ptr[n]
    hi = ptr[n + 1]
    pos = _lower_bound(chars, lo, hi, ch, iters)
    size = max(int(chars.shape[0]), 1)
    found = (pos < hi) & (chars[jnp.clip(pos, 0, size - 1)] == ch) & valid & (ch >= 0)
    return jnp.where(found, children[jnp.clip(pos, 0, size - 1)], NEG_ONE)


def _dedup_pad(vec: jax.Array, width: int):
    """Unique ids of vec (-1 = empty), first `width` kept (ascending id order).

    Returns (out[width] int32 with -1 pad, n_dropped int32).

    §Perf iteration: one sort + O(n) scatter compaction (rank = running
    count of kept) instead of the original sort-mask-sort — on TPU the
    second bitonic sort was the locus DP's hottest op."""
    big = jnp.where(vec < 0, INT_MAX, vec)
    s = jnp.sort(big)
    idx = jnp.arange(s.shape[0], dtype=jnp.int32)
    keep = (idx == 0) | (s != jnp.roll(s, 1))
    keep &= s != INT_MAX
    rank = jnp.cumsum(keep) - 1                       # position among kept
    n_uniq = (rank[-1] + 1).astype(jnp.int32)
    dst = jnp.where(keep & (rank < width), rank, width)  # width = drop slot
    out = jnp.full((width + 1,), NEG_ONE, jnp.int32)
    out = out.at[dst].set(s, mode="drop")
    out = jnp.where(out == INT_MAX, NEG_ONE, out)[:width]
    dropped = jnp.maximum(n_uniq - width, 0)
    return out, dropped


# ---------------------------------------------------------------------------
# phase 1: locus DP
# ---------------------------------------------------------------------------


def _match_table(t: DeviceTrie, cfg: EngineConfig, q: jax.Array):
    """All full-lhs rule matches per query position.

    Returns (rule[L, M], end[L, M]) with -1 padding; end = pos + len(lhs).
    """
    L = q.shape[0]
    M = cfg.rule_matches
    if M == 0:
        z = jnp.full((L, 1), NEG_ONE, jnp.int32)
        return z, z
    iters = _iters_for(int(t.r_edge_char.shape[0]))
    qx = jnp.concatenate([q, jnp.full((cfg.max_lhs_len,), NEG_ONE, jnp.int32)])

    def at_pos(i):
        rules = jnp.full((M,), NEG_ONE, jnp.int32)
        ends = jnp.full((M,), NEG_ONE, jnp.int32)
        node = jnp.int32(0)
        cnt = jnp.int32(0)
        for j in range(cfg.max_lhs_len):
            c = jax.lax.dynamic_index_in_dim(qx, i + j, keepdims=False)
            node = _csr_child_lookup(
                t.r_first_child, t.r_edge_char, t.r_edge_child,
                node[None], c[None], iters)[0]
            ok = node >= 0
            nn = jnp.where(ok, node, 0)
            t_lo = t.r_term_ptr[nn]
            t_hi = t.r_term_ptr[nn + 1]
            for j2 in range(cfg.max_terms_per_node):
                has = ok & (t_lo + j2 < t_hi) & (cnt < M)
                rid = t.r_term_rule[jnp.clip(t_lo + j2, 0, max(int(t.r_term_rule.shape[0]), 1) - 1)]
                slot = jnp.clip(cnt, 0, M - 1)
                rules = jnp.where(has, rules.at[slot].set(rid), rules)
                ends = jnp.where(has, ends.at[slot].set(i + j + 1), ends)
                cnt = jnp.where(has, cnt + 1, cnt)
        return rules, ends

    return jax.vmap(at_pos)(jnp.arange(L, dtype=jnp.int32))


def _teleport_expand(t: DeviceTrie, cfg: EngineConfig, row: jax.Array):
    """row [F] -> row plus teleport targets, dedup'd back to [F]."""
    if cfg.teleports == 0:
        return row, jnp.int32(0)
    F = row.shape[0]
    valid = row >= 0
    n = jnp.where(valid, row, 0)
    lo = t.syn_ptr[n]
    hi = t.syn_ptr[n + 1]
    size = max(int(t.syn_tgt.shape[0]), 1)
    offs = jnp.arange(cfg.teleports, dtype=jnp.int32)
    idx = lo[:, None] + offs[None, :]
    ok = (idx < hi[:, None]) & valid[:, None]
    tgt = jnp.where(ok, t.syn_tgt[jnp.clip(idx, 0, size - 1)], NEG_ONE)
    merged = jnp.concatenate([row, tgt.reshape(-1)])
    return _dedup_pad(merged, F)


def _link_lookup(t: DeviceTrie, anchors: jax.Array, rid: jax.Array):
    """Link-store search: (anchor, rule) -> target or -1. anchors [F]."""
    n_link = int(t.link_anchor.shape[0])
    if n_link == 0:
        return jnp.full(anchors.shape, NEG_ONE, jnp.int32)
    iters = _iters_for(n_link)
    valid = anchors >= 0
    a = jnp.where(valid, anchors, 0)
    zero = jnp.zeros_like(a)
    full = jnp.full_like(a, n_link)
    lo = _lower_bound(t.link_anchor, zero, full, a, iters)
    hi = _lower_bound(t.link_anchor, zero, full, a + 1, iters)
    pos = _lower_bound(t.link_rule, lo, hi, rid, iters)
    found = (pos < hi) & (t.link_rule[jnp.clip(pos, 0, n_link - 1)] == rid) & valid
    return jnp.where(found, t.link_target[jnp.clip(pos, 0, n_link - 1)], NEG_ONE)


def finalize_loci(t: DeviceTrie, row: jax.Array) -> jax.Array:
    """Turn a (teleport-expanded) frontier row into the final locus antichain:
    drop mid-variant synonym nodes, dedup, and remove covered descendants."""
    F = row.shape[0]
    # strict semantics: drop mid-variant (synonym) loci
    is_syn = t.syn_mask[jnp.where(row >= 0, row, 0)]
    row = jnp.where((row >= 0) & ~is_syn, row, NEG_ONE)
    row, _ = _dedup_pad(row, F)
    # antichain reduction via preorder intervals: drop descendants
    tin = jnp.where(row >= 0, row, NEG_ONE)
    to = t.tout[jnp.where(row >= 0, row, 0)]
    covered = (
        (tin[None, :] <= tin[:, None]) & (tin[:, None] < to[None, :])
        & (jnp.arange(F)[None, :] != jnp.arange(F)[:, None])
        & (row[None, :] >= 0) & (row[:, None] >= 0)
    ).any(axis=1)
    # ties: identical ids already removed by dedup; strict ancestor covers
    return jnp.where(covered, NEG_ONE, row)


def locus_dp(t: DeviceTrie, cfg: EngineConfig, q: jax.Array, qlen: jax.Array):
    """Locus set after consuming the whole query under all rewritings.

    q: int32[L] (-1 padded), qlen: int32 scalar.
    Returns (loci[F] dict-node ids, -1 padded; overflow count int32).
    """
    L = int(q.shape[0])
    F = cfg.frontier
    d_iters = _iters_for(int(t.edge_char.shape[0]))
    s_iters = _iters_for(int(t.s_edge_char.shape[0]))
    has_syn_edges = int(t.s_edge_child.shape[0]) > 0
    M = cfg.rule_matches

    mrule, mend = _match_table(t, cfg, q)

    buf = jnp.full((L + 1, F), NEG_ONE, jnp.int32)
    buf = buf.at[0, 0].set(0)
    overflow = jnp.int32(0)

    def step(i, carry):
        buf, overflow = carry
        row = jax.lax.dynamic_slice(buf, (i, 0), (1, F))[0]
        row, drop = _teleport_expand(t, cfg, row)
        overflow += drop
        c = jax.lax.dynamic_index_in_dim(q, i, keepdims=False)

        # literal char step: dict children + synonym-branch children
        nd = _csr_child_lookup(t.first_child, t.edge_char, t.edge_child,
                               row, c, d_iters)
        parts = [nd]
        if has_syn_edges:
            ns = _csr_child_lookup(t.s_first_child, t.s_edge_char,
                                   t.s_edge_child, row, c, s_iters)
            parts.append(ns)
        nxt_row = jax.lax.dynamic_slice(buf, (i + 1, 0), (1, F))[0]
        merged, drop = _dedup_pad(jnp.concatenate([nxt_row] + parts), F)
        overflow += drop
        buf = jax.lax.dynamic_update_slice(buf, merged[None], (i + 1, 0))

        # rule steps through the link store (anchors must be dict nodes)
        if M > 0:
            anchor_ok = row >= 0
            anchor_ok &= ~t.syn_mask[jnp.where(row >= 0, row, 0)]
            anchors = jnp.where(anchor_ok, row, NEG_ONE)
            for m in range(M):
                rid = mrule[i, m]
                end = mend[i, m]
                tgt = _link_lookup(t, anchors, rid)
                tgt = jnp.where((rid >= 0), tgt, NEG_ONE)
                j = jnp.clip(jnp.where(end >= 0, end, 0), 0, L)
                dst = jax.lax.dynamic_slice(buf, (j, 0), (1, F))[0]
                merged, drop = _dedup_pad(jnp.concatenate([dst, tgt]), F)
                any_tgt = jnp.any(tgt >= 0)
                merged = jnp.where(any_tgt, merged, dst)
                overflow += jnp.where(any_tgt, drop, 0)
                buf = jax.lax.dynamic_update_slice(buf, merged[None], (j, 0))
        return buf, overflow

    buf, overflow = jax.lax.fori_loop(0, L, step, (buf, overflow))

    row = jax.lax.dynamic_slice(buf, (jnp.clip(qlen, 0, L), 0), (1, F))[0]
    row, drop = _teleport_expand(t, cfg, row)
    overflow += drop
    return finalize_loci(t, row), overflow


# ---------------------------------------------------------------------------
# phase 1': incremental locus DP (stateful per-keystroke sessions)
# ---------------------------------------------------------------------------


class LocusState(NamedTuple):
    """Resumable locus-DP state after consuming some prefix.

    rows[0] is the teleport-expanded frontier for the full prefix; rows[j]
    (j < max_lhs_len) is the frontier j keystrokes ago.  The history window
    is required because a synonym rule whose lhs ends at the newest char
    anchors at the frontier of the position where the lhs *started*.
    rnodes[j] is the rule-trie node for the walk over the last j+1 chars
    (-1 once the walk dies), so full-lhs matches ending at the newest char
    are recognised without rescanning the prefix.
    """

    rows: jax.Array      # int32[H, F] expanded frontier rows, newest first
    rnodes: jax.Array    # int32[H]   rule-trie suffix walks, shortest first
    overflow: jax.Array  # int32      accumulated frontier drops (0 => exact)
    length: jax.Array    # int32      chars consumed


def init_locus_state(t: DeviceTrie, cfg: EngineConfig) -> LocusState:
    """State for the empty prefix (locus = expanded root)."""
    F = cfg.frontier
    H = max(cfg.max_lhs_len, 1)
    row = jnp.full((F,), NEG_ONE, jnp.int32).at[0].set(0)
    row, drop = _teleport_expand(t, cfg, row)
    rows = jnp.full((H, F), NEG_ONE, jnp.int32).at[0].set(row)
    return LocusState(rows=rows,
                      rnodes=jnp.full((H,), NEG_ONE, jnp.int32),
                      overflow=jnp.int32(0) + drop,
                      length=jnp.int32(0))


def advance_locus_state(t: DeviceTrie, cfg: EngineConfig, state: LocusState,
                        c) -> LocusState:
    """One keystroke: extend the frontier by char ``c`` (no-op when c < 0).

    Equivalent to one step of ``locus_dp`` — literal dict/synonym-branch
    children of the current frontier, plus link-store steps for every rule
    whose lhs ends exactly at the new char — but reuses the carried frontier
    instead of rescanning the prefix.
    """
    F = cfg.frontier
    H = state.rows.shape[0]
    c = jnp.asarray(c, jnp.int32)
    row = state.rows[0]

    d_iters = _iters_for(int(t.edge_char.shape[0]))
    parts = [_csr_child_lookup(t.first_child, t.edge_char, t.edge_child,
                               row, c, d_iters)]
    if int(t.s_edge_child.shape[0]) > 0:
        s_iters = _iters_for(int(t.s_edge_char.shape[0]))
        parts.append(_csr_child_lookup(t.s_first_child, t.s_edge_char,
                                       t.s_edge_child, row, c, s_iters))

    rnodes = state.rnodes
    if cfg.rule_matches > 0 and cfg.max_lhs_len > 0:
        r_iters = _iters_for(int(t.r_edge_char.shape[0]))
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  state.rnodes[:-1]])
        rnodes = _csr_child_lookup(t.r_first_child, t.r_edge_char,
                                   t.r_edge_child, starts, c, r_iters)
        r_size = max(int(t.r_term_rule.shape[0]), 1)
        for j in range(H):
            node = rnodes[j]
            ok = node >= 0
            nn = jnp.where(ok, node, 0)
            t_lo = t.r_term_ptr[nn]
            t_hi = t.r_term_ptr[nn + 1]
            # lhs of length j+1 anchors at the frontier j keystrokes back
            anchor_row = state.rows[j]
            anchor_ok = anchor_row >= 0
            anchor_ok &= ~t.syn_mask[jnp.where(anchor_row >= 0, anchor_row, 0)]
            anchors = jnp.where(anchor_ok, anchor_row, NEG_ONE)
            for j2 in range(cfg.max_terms_per_node):
                has = ok & (t_lo + j2 < t_hi)
                rid = t.r_term_rule[jnp.clip(t_lo + j2, 0, r_size - 1)]
                tgt = _link_lookup(t, anchors, rid)
                parts.append(jnp.where(has, tgt, NEG_ONE))

    merged, d1 = _dedup_pad(jnp.concatenate(parts), F)
    merged, d2 = _teleport_expand(t, cfg, merged)
    new_rows = jnp.concatenate([merged[None], state.rows[:-1]], axis=0)
    ok = c >= 0
    return LocusState(
        rows=jnp.where(ok, new_rows, state.rows),
        rnodes=jnp.where(ok, rnodes, state.rnodes),
        overflow=state.overflow + jnp.where(ok, d1 + d2, 0),
        length=state.length + jnp.where(ok, 1, 0),
    )


def advance_loci(t: DeviceTrie, cfg: EngineConfig, state: LocusState,
                 chars: jax.Array) -> LocusState:
    """Extend the state by a fixed-shape char vector (-1 entries ignored)."""
    def step(s, c):
        return advance_locus_state(t, cfg, s, c), None

    state, _ = jax.lax.scan(step, state, jnp.asarray(chars, jnp.int32))
    return state


def topk_from_loci(t: DeviceTrie, cfg: EngineConfig, state: LocusState,
                   k: int):
    """Top-k for the prefix carried by ``state`` (scores, sids, exact)."""
    loci = finalize_loci(t, state.rows[0])
    scores, sids, exact = topk_phase2(t, cfg, loci, k)
    return scores, sids, exact & (state.overflow == 0)


# ---------------------------------------------------------------------------
# phase 2a: beam top-k (paper-faithful priority search, vectorized)
# ---------------------------------------------------------------------------


def beam_topk(t: DeviceTrie, cfg: EngineConfig, loci: jax.Array, k: int):
    """Top-k leaves under the locus antichain.

    Returns (scores[k], sids[k], exact bool). scores are -1 padded.
    """
    W, P = cfg.gens, cfg.expand
    F = loci.shape[0]
    if int(t.emit_node.shape[0]) == 0:  # degenerate empty dictionary
        return (jnp.full((k,), NEG_ONE, jnp.int32),
                jnp.full((k,), NEG_ONE, jnp.int32), jnp.bool_(True))
    e_size = max(int(t.emit_node.shape[0]), 1)

    def emit_bound(nodes, cursors):
        valid = nodes >= 0
        n = jnp.where(valid, nodes, 0)
        e = t.emit_ptr[n] + cursors
        ok = valid & (e < t.emit_ptr[n + 1])
        score = t.emit_score[jnp.clip(e, 0, e_size - 1)]
        return jnp.where(ok, score, NEG_ONE)

    # generator pool seeded with loci
    gn = jnp.full((W,), NEG_ONE, jnp.int32)
    gc = jnp.zeros((W,), jnp.int32)
    gn = jax.lax.dynamic_update_slice(gn, loci, (0,))
    gb = emit_bound(gn, gc)
    gn = jnp.where(gb >= 0, gn, NEG_ONE)

    ls = jnp.full((k,), NEG_ONE, jnp.int32)   # leaf scores desc
    li = jnp.full((k,), NEG_ONE, jnp.int32)   # leaf sids
    dropped_max = NEG_ONE
    steps = jnp.int32(0)

    def cond(state):
        gn, gc, gb, ls, li, dropped_max, steps = state
        best = jnp.max(gb)
        kth = ls[k - 1]
        return (best >= 0) & (kth < best) & (steps < cfg.max_steps)

    def body(state):
        gn, gc, gb, ls, li, dropped_max, steps = state
        topb, topi = jax.lax.top_k(gb, P)
        sel_valid = topb >= 0
        sel_n = jnp.where(sel_valid, gn[topi], 0)
        e = t.emit_ptr[sel_n] + gc[topi]
        e = jnp.clip(e, 0, e_size - 1)
        em_node = t.emit_node[e]
        em_score = t.emit_score[e]
        em_leaf = t.emit_is_leaf[e]

        # leaves -> result buffer
        leaf_ok = sel_valid & em_leaf
        new_ls = jnp.where(leaf_ok, em_score, NEG_ONE)
        new_li = jnp.where(leaf_ok, t.leaf_sid[jnp.where(leaf_ok, em_node, 0)],
                           NEG_ONE)
        cat_s = jnp.concatenate([ls, new_ls])
        cat_i = jnp.concatenate([li, new_li])
        top_s, idx = jax.lax.top_k(cat_s, k)
        ls2, li2 = top_s, cat_i[idx]

        # internal emissions -> new generators
        int_ok = sel_valid & ~em_leaf
        new_n = jnp.where(int_ok, em_node, NEG_ONE)
        new_c = jnp.zeros((P,), jnp.int32)
        new_b = emit_bound(new_n, new_c)
        new_n = jnp.where(new_b >= 0, new_n, NEG_ONE)

        # advance selected generators
        gc2 = gc.at[topi].add(jnp.where(sel_valid, 1, 0))
        gb2 = emit_bound(gn, gc2)
        gn2 = jnp.where(gb2 >= 0, gn, NEG_ONE)

        # merge pools, keep top-W by bound
        pool_n = jnp.concatenate([gn2, new_n])
        pool_c = jnp.concatenate([gc2, new_c])
        pool_b = jnp.concatenate([gb2, new_b])
        keep_b, keep_i = jax.lax.top_k(pool_b, W)
        drop_mask = jnp.ones((W + P,), bool).at[keep_i].set(False)
        drop_best = jnp.max(jnp.where(drop_mask, pool_b, NEG_ONE))
        dropped_max2 = jnp.maximum(dropped_max, drop_best)
        return (pool_n[keep_i], pool_c[keep_i], keep_b, ls2, li2,
                dropped_max2, steps + 1)

    state = (gn, gc, gb, ls, li, dropped_max, steps)
    gn, gc, gb, ls, li, dropped_max, steps = jax.lax.while_loop(cond, body, state)
    finished = ~((jnp.max(gb) >= 0) & (ls[k - 1] < jnp.max(gb)))
    exact = (ls[k - 1] >= dropped_max) & finished
    return ls, li, exact


# ---------------------------------------------------------------------------
# phase 2b: cached top-k (beyond-paper)
# ---------------------------------------------------------------------------


def cached_topk(t: DeviceTrie, cfg: EngineConfig, loci: jax.Array, k: int):
    assert cfg.use_cache and k <= cfg.cache_k, "cache disabled or k too large"
    valid = loci >= 0
    n = jnp.where(valid, loci, 0)
    sc = jnp.where(valid[:, None], t.topk_score[n], NEG_ONE)
    si = jnp.where(valid[:, None], t.topk_sid[n], NEG_ONE)
    flat_s = sc.reshape(-1)
    flat_i = si.reshape(-1)
    top_s, idx = jax.lax.top_k(flat_s, k)
    return top_s, flat_i[idx], jnp.bool_(True)


# ---------------------------------------------------------------------------
# public single-query / batched entry points
# ---------------------------------------------------------------------------


def topk_phase2(t: DeviceTrie, cfg: EngineConfig, loci: jax.Array, k: int):
    """Phase-2 dispatch: cached merge when materialized and k fits, else beam."""
    if cfg.use_cache and k <= cfg.cache_k:
        return cached_topk(t, cfg, loci, k)
    return beam_topk(t, cfg, loci, k)


def complete_one(t: DeviceTrie, cfg: EngineConfig, q: jax.Array,
                 qlen: jax.Array, k: int):
    loci, overflow = locus_dp(t, cfg, q, qlen)
    scores, sids, exact = topk_phase2(t, cfg, loci, k)
    exact &= overflow == 0
    return scores, sids, exact


def complete_batch(t: DeviceTrie, cfg: EngineConfig, qs: jax.Array,
                   qlens: jax.Array, k: int):
    """qs: int32[B, L]; qlens: int32[B] -> (scores[B,k], sids[B,k], exact[B])."""
    return jax.vmap(lambda q, ql: complete_one(t, cfg, q, ql, k))(qs, qlens)
