"""Host-side construction of array-encoded tries (numpy).

The paper's pointer tries become structure-of-arrays tries:

- child lookup CSR sorted by char within each node (binary-searchable),
- per-node *emission lists* sorted by max-descendant-score descending
  (the paper orders children by highest descendant score; we additionally
  interleave the node's own leaf so the beam engine emits in exact score
  order),
- synonym teleports (ET/HT expanded rules): CSR node -> dictionary target,
- rule-link store (TT/HT unexpanded rules): sorted (anchor, rule) -> target.

Construction is offline/host-side (like data loading in a training job);
lookup runs on device from these arrays alone.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from repro.core.alphabet import SIGMA, encode

ROOT = 0


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SynonymRule:
    """A rule ``lhs -> rhs``: applying it to a *query* replaces an occurrence
    of ``lhs`` with ``rhs`` (the dictionary-side form)."""

    lhs: bytes
    rhs: bytes

    def __post_init__(self):
        if len(self.lhs) == 0 or len(self.rhs) == 0:
            raise ValueError("synonym rule sides must be non-empty")


def make_rules(pairs) -> list[SynonymRule]:
    out = []
    for lhs, rhs in pairs:
        lhs = lhs.encode() if isinstance(lhs, str) else bytes(lhs)
        rhs = rhs.encode() if isinstance(rhs, str) else bytes(rhs)
        out.append(SynonymRule(lhs, rhs))
    return out


# ---------------------------------------------------------------------------
# Array tries
# ---------------------------------------------------------------------------


@dataclass
class DictTrie:
    """Array-encoded dictionary trie (+ synonym structures)."""

    # per-node
    parent: np.ndarray      # int32[N]
    depth: np.ndarray       # int32[N]
    chr_: np.ndarray        # int32[N]  label of incoming edge (-1 for root)
    max_score: np.ndarray   # int32[N]  max dictionary-leaf score in subtree
    leaf_score: np.ndarray  # int32[N]  score if terminal else -1
    leaf_sid: np.ndarray    # int32[N]  string id (sorted order) if terminal else -1
    syn_mask: np.ndarray    # bool [N]  True for pure synonym nodes
    tout: np.ndarray        # int32[N]  dict nodes: subtree id range is [id, tout)

    # dictionary-child lookup CSR (within-node sorted by char)
    first_child: np.ndarray  # int32[N+1]
    edge_char: np.ndarray    # int32[E]
    edge_child: np.ndarray   # int32[E]

    # synonym-child lookup CSR (branches live in their own edge set so that
    # a dictionary node and a synonym branch may both continue with the same
    # character, and so that teleports can only be reached by literally typed
    # variant characters — rule output never participates in a later rule)
    s_first_child: np.ndarray  # int32[N+1]
    s_edge_char: np.ndarray    # int32[Es]
    s_edge_child: np.ndarray   # int32[Es]

    # emission lists (within-node sorted by score desc; excludes syn children)
    emit_ptr: np.ndarray     # int32[N+1]
    emit_node: np.ndarray    # int32[M]
    emit_score: np.ndarray   # int32[M]
    emit_is_leaf: np.ndarray  # bool[M]   True => emit leaf of emit_node

    # synonym teleports (node -> dict target), CSR
    syn_ptr: np.ndarray      # int32[N+1]
    syn_tgt: np.ndarray      # int32[S]

    # unexpanded-rule link store, sorted by (anchor, rule)
    link_anchor: np.ndarray  # int32[L]
    link_rule: np.ndarray    # int32[L]
    link_target: np.ndarray  # int32[L]

    # packed rule plane (see pack_rule_planes): dense, padded relayouts of
    # the rule-side CSRs that the device engine and the fused locus-DP
    # kernel consume directly
    tele_plane: np.ndarray | None = None  # int32[N, Tw] teleports, -1 pad
    link_ptr: np.ndarray | None = None    # int32[N+1] anchor -> link rows

    # tile-aligned stream layout (see pack_stream_tiles): static window
    # widths for the DMA-streamed kernel tier; 0 until packed
    walk_tile: int = 0
    emit_tile: int = 0
    link_tile: int = 0

    # optional materialized per-node top-K (dict leaves only)
    topk_score: np.ndarray | None = None  # int32[N, K]
    topk_sid: np.ndarray | None = None    # int32[N, K]

    # static metadata
    max_depth: int = 0
    max_syn_targets: int = 0

    @property
    def n_nodes(self) -> int:
        return len(self.parent)

    @property
    def n_edges(self) -> int:
        return len(self.edge_char)

    def nbytes(self, include_cache: bool = True) -> int:
        total = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                if not include_cache and f.name.startswith("topk_"):
                    continue
                total += v.nbytes
        return total


@dataclass
class RuleTrie:
    """Array-encoded trie over the query-side (lhs) strings of rules."""

    first_child: np.ndarray  # int32[N+1]
    edge_char: np.ndarray    # int32[E]
    edge_child: np.ndarray   # int32[E]
    depth: np.ndarray        # int32[N]
    term_ptr: np.ndarray     # int32[N+1]  node -> rule ids terminating here
    term_rule: np.ndarray    # int32[T]
    rule_len: np.ndarray     # int32[R]    lhs length per rule id
    # packed rule plane (see pack_rule_planes): term lists as a dense,
    # -1-padded [N, term_width] plane (term_width >= 1 even when empty,
    # so device gathers never need a degenerate-shape guard)
    term_plane: np.ndarray | None = None  # int32[N, Tw]
    max_lhs_len: int = 0
    max_matches_per_pos: int = 0  # max #terminals on any root path
    max_terms_per_node: int = 1   # max #rules terminating at one node

    @property
    def n_nodes(self) -> int:
        return len(self.depth)

    def nbytes(self) -> int:
        total = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                total += v.nbytes
        return total


# ---------------------------------------------------------------------------
# Dictionary trie construction (sorted-strings + LCP sweep)
# ---------------------------------------------------------------------------


def _sorted_unique(strings: list[bytes], scores: np.ndarray):
    order = sorted(range(len(strings)), key=lambda i: strings[i])
    sorted_strings: list[bytes] = []
    sorted_scores: list[int] = []
    for i in order:
        s = strings[i]
        if sorted_strings and sorted_strings[-1] == s:
            sorted_scores[-1] = max(sorted_scores[-1], int(scores[i]))
        else:
            sorted_strings.append(s)
            sorted_scores.append(int(scores[i]))
    return sorted_strings, np.asarray(sorted_scores, dtype=np.int32)


def _lcp(a: bytes, b: bytes) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


def build_dict_trie(strings: list[bytes | str], scores) -> tuple[DictTrie, list[bytes], np.ndarray]:
    """Build the dictionary trie. Returns (trie, sorted_strings, sorted_scores).

    String ids (leaf_sid) index into the *sorted* string list.
    """
    raw = [s.encode() if isinstance(s, str) else bytes(s) for s in strings]
    scores = np.asarray(scores)
    assert len(raw) == len(scores)
    ss, sc = _sorted_unique(raw, scores)
    n_str = len(ss)

    # --- node creation sweep (nodes are created in DFS preorder) ---
    parent_chunks: list[np.ndarray] = [np.array([-1], dtype=np.int32)]
    char_chunks: list[np.ndarray] = [np.array([-1], dtype=np.int32)]
    depth_chunks: list[np.ndarray] = [np.array([0], dtype=np.int32)]
    next_id = 1
    max_len = max((len(s) for s in ss), default=0)
    path = np.zeros(max_len + 1, dtype=np.int64)  # node id at each depth
    leaf_nodes = np.zeros(n_str, dtype=np.int32)
    prev = b""
    for i, s in enumerate(ss):
        d0 = _lcp(prev, s)
        cnt = len(s) - d0
        if cnt > 0:
            ids = np.arange(next_id, next_id + cnt, dtype=np.int32)
            parents = np.empty(cnt, dtype=np.int32)
            parents[0] = path[d0]
            parents[1:] = ids[:-1]
            chars = np.frombuffer(s[d0:], dtype=np.uint8).astype(np.int32)
            depths = np.arange(d0 + 1, len(s) + 1, dtype=np.int32)
            parent_chunks.append(parents)
            char_chunks.append(chars)
            depth_chunks.append(depths)
            path[d0 + 1 : len(s) + 1] = ids
            next_id += cnt
        leaf_nodes[i] = path[len(s)]
        prev = s

    parent = np.concatenate(parent_chunks)
    chr_ = np.concatenate(char_chunks)
    depth = np.concatenate(depth_chunks)
    n = next_id

    leaf_score = np.full(n, -1, dtype=np.int32)
    leaf_sid = np.full(n, -1, dtype=np.int32)
    leaf_score[leaf_nodes] = sc
    leaf_sid[leaf_nodes] = np.arange(n_str, dtype=np.int32)

    syn_mask = np.zeros(n, dtype=bool)
    max_score = _propagate_max_scores(parent, depth, leaf_score)
    tout = _compute_tout(parent, depth)

    trie = DictTrie(
        parent=parent,
        depth=depth,
        chr_=chr_,
        max_score=max_score,
        leaf_score=leaf_score,
        leaf_sid=leaf_sid,
        syn_mask=syn_mask,
        tout=tout,
        first_child=np.zeros(n + 1, np.int32),
        edge_char=np.zeros(0, np.int32),
        edge_child=np.zeros(0, np.int32),
        s_first_child=np.zeros(n + 1, np.int32),
        s_edge_char=np.zeros(0, np.int32),
        s_edge_child=np.zeros(0, np.int32),
        emit_ptr=np.zeros(n + 1, np.int32),
        emit_node=np.zeros(0, np.int32),
        emit_score=np.zeros(0, np.int32),
        emit_is_leaf=np.zeros(0, bool),
        syn_ptr=np.zeros(n + 1, np.int32),
        syn_tgt=np.zeros(0, np.int32),
        link_anchor=np.zeros(0, np.int32),
        link_rule=np.zeros(0, np.int32),
        link_target=np.zeros(0, np.int32),
        max_depth=int(depth.max(initial=0)),
    )
    rebuild_edges(trie)
    return trie, ss, sc


def _compute_tout(parent, depth) -> np.ndarray:
    """Dictionary nodes are created in DFS preorder, so subtree(v) is the
    contiguous id range [v, tout[v]). Enables O(1) ancestor tests (used to
    reduce locus sets to an antichain so top-k never double-counts)."""
    n = len(parent)
    tout = np.arange(1, n + 1, dtype=np.int32)
    if n == 0:
        return tout
    order = np.argsort(depth, kind="stable")
    max_d = int(depth.max(initial=0))
    bounds = np.searchsorted(depth[order], np.arange(max_d + 2))
    for d in range(max_d, 0, -1):
        ids = order[bounds[d] : bounds[d + 1]]
        if len(ids) == 0:
            continue
        np.maximum.at(tout, parent[ids], tout[ids])
    return tout


def _propagate_max_scores(parent, depth, leaf_score) -> np.ndarray:
    """max_score[v] = max leaf_score over v's subtree (dict leaves only)."""
    n = len(parent)
    max_score = leaf_score.copy()
    if n == 0:
        return max_score
    max_d = int(depth.max(initial=0))
    # group node ids by depth once
    order = np.argsort(depth, kind="stable")
    bounds = np.searchsorted(depth[order], np.arange(max_d + 2))
    for d in range(max_d, 0, -1):
        ids = order[bounds[d] : bounds[d + 1]]
        if len(ids) == 0:
            continue
        np.maximum.at(max_score, parent[ids], max_score[ids])
    return max_score


def rebuild_edges(trie: DictTrie) -> None:
    """(Re)build dict/syn child CSRs + emission lists from parent/chr arrays."""
    n = trie.n_nodes
    all_ids = np.arange(n, dtype=np.int32)
    is_child = all_ids != ROOT

    for syn in (False, True):
        sel = is_child & (trie.syn_mask == syn)
        ids = all_ids[sel]
        p = trie.parent[ids]
        c = trie.chr_[ids]
        order = np.lexsort((c, p))
        ids, p, c = ids[order], p[order], c[order]
        counts = np.bincount(p, minlength=n).astype(np.int32)
        ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
        if syn:
            trie.s_first_child = ptr
            trie.s_edge_char = c.astype(np.int32)
            trie.s_edge_child = ids.astype(np.int32)
        else:
            trie.first_child = ptr
            trie.edge_char = c.astype(np.int32)
            trie.edge_child = ids.astype(np.int32)

    # emission lists: dictionary children (ranked by max_score) + own leaf
    ids = all_ids[is_child & ~trie.syn_mask]
    p = trie.parent[ids]
    order = np.lexsort((trie.chr_[ids], p))
    ids, p = ids[order], p[order]
    e_par = p
    e_node = ids
    e_score = trie.max_score[e_node]
    e_leaf = np.zeros(len(e_node), dtype=bool)
    term = np.nonzero(trie.leaf_score >= 0)[0].astype(np.int32)
    e_par = np.concatenate([e_par, term])
    e_node = np.concatenate([e_node, term])
    e_score = np.concatenate([e_score, trie.leaf_score[term]])
    e_leaf = np.concatenate([e_leaf, np.ones(len(term), dtype=bool)])
    order = np.lexsort((-e_score, e_par))
    e_par, e_node, e_score, e_leaf = (
        e_par[order], e_node[order], e_score[order], e_leaf[order])
    counts = np.bincount(e_par, minlength=n).astype(np.int32)
    trie.emit_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    trie.emit_node = e_node.astype(np.int32)
    trie.emit_score = e_score.astype(np.int32)
    trie.emit_is_leaf = e_leaf


# ---------------------------------------------------------------------------
# Host-side edge lookup (int64 keys, vectorized)
# ---------------------------------------------------------------------------


class _EdgeIndex:
    def __init__(self, trie: DictTrie):
        key = trie.edge_child  # children ids
        self.keys = trie.parent[key].astype(np.int64) * SIGMA + trie.chr_[key]
        order = np.argsort(self.keys, kind="stable")
        self.keys = self.keys[order]
        self.children = key[order].astype(np.int32)

    def lookup(self, nodes: np.ndarray, char: int) -> np.ndarray:
        k = nodes.astype(np.int64) * SIGMA + char
        i = np.searchsorted(self.keys, k)
        i = np.minimum(i, len(self.keys) - 1) if len(self.keys) else i * 0
        ok = (len(self.keys) > 0) & (self.keys[i] == k) if len(self.keys) else np.zeros(len(k), bool)
        return np.where(ok, self.children[i] if len(self.keys) else -1, -1).astype(np.int32)


# ---------------------------------------------------------------------------
# Rule trie + links
# ---------------------------------------------------------------------------


def build_rule_trie(rules: list[SynonymRule], active: np.ndarray | None = None) -> RuleTrie:
    """Trie over lhs strings of *active* rules (rule ids are global)."""
    n_rules = len(rules)
    if active is None:
        active = np.ones(n_rules, dtype=bool)
    items = sorted((rules[i].lhs, i) for i in range(n_rules) if active[i])

    parent = [np.array([-1], np.int32)]
    chr_ = [np.array([-1], np.int32)]
    depth = [np.array([0], np.int32)]
    next_id = 1
    max_len = max((len(s) for s, _ in items), default=0)
    path = np.zeros(max_len + 1, dtype=np.int64)
    terms: list[tuple[int, int]] = []  # (node, rule)
    prev = b""
    for s, rid in items:
        d0 = _lcp(prev, s)
        cnt = len(s) - d0
        if cnt > 0:
            ids = np.arange(next_id, next_id + cnt, dtype=np.int32)
            pp = np.empty(cnt, np.int32)
            pp[0] = path[d0]
            pp[1:] = ids[:-1]
            parent.append(pp)
            chr_.append(np.frombuffer(s[d0:], np.uint8).astype(np.int32))
            depth.append(np.arange(d0 + 1, len(s) + 1, dtype=np.int32))
            path[d0 + 1 : len(s) + 1] = ids
            next_id += cnt
        terms.append((int(path[len(s)]), rid))
        prev = s

    parent = np.concatenate(parent)
    chr_ = np.concatenate(chr_)
    depth = np.concatenate(depth)
    n = next_id

    ids = np.arange(1, n, dtype=np.int32)
    order = np.lexsort((chr_[ids], parent[ids]))
    ids = ids[order]
    counts = np.bincount(parent[ids], minlength=n).astype(np.int32)
    first_child = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    term_node = np.array([t for t, _ in terms], dtype=np.int32)
    term_rid = np.array([r for _, r in terms], dtype=np.int32)
    t_order = np.argsort(term_node, kind="stable")
    term_node, term_rid = term_node[t_order], term_rid[t_order]
    t_counts = np.bincount(term_node, minlength=n).astype(np.int32)
    term_ptr = np.concatenate([[0], np.cumsum(t_counts)]).astype(np.int32)

    # max #terminals along any root path = max over terminal nodes of
    # (#ancestors incl. self that are terminal); bounded by walking parents
    is_term = t_counts > 0
    max_matches = 0
    for t in term_node:
        cnt, v = 0, int(t)
        while v != -1:
            if is_term[v]:
                cnt += int(t_counts[v])
            v = int(parent[v]) if v != ROOT else -1
        max_matches = max(max_matches, cnt)

    rule_len = np.array([len(r.lhs) for r in rules], dtype=np.int32)
    return RuleTrie(
        first_child=first_child,
        edge_char=chr_[ids].astype(np.int32),
        edge_child=ids.astype(np.int32),
        depth=depth,
        term_ptr=term_ptr,
        term_rule=term_rid,
        rule_len=rule_len,
        max_lhs_len=int(max((len(s) for s, _ in items), default=0)),
        max_matches_per_pos=max_matches,
        max_terms_per_node=int(t_counts.max(initial=1)),
    )


def find_links(trie: DictTrie, rules: list[SynonymRule]):
    """All (anchor, rule, target) with target = walk(anchor, rule.rhs).

    Must be called on the pure dictionary trie (pre-expansion): rule
    applications may not anchor inside generated synonym text.
    """
    idx = _EdgeIndex(trie)
    anchors, rids, targets = [], [], []
    # group candidate starts by first char of rhs
    child_ids = trie.edge_child
    by_char: dict[int, np.ndarray] = {}
    for ch in np.unique(trie.edge_char):
        sel = trie.edge_char == ch
        by_char[int(ch)] = child_ids[sel]
    for rid, rule in enumerate(rules):
        rhs = np.frombuffer(rule.rhs, np.uint8).astype(np.int32)
        first = by_char.get(int(rhs[0]))
        if first is None:
            continue
        anchor = trie.parent[first]
        cur = first.copy()
        ok = np.ones(len(cur), dtype=bool)
        for c in rhs[1:]:
            nxt = idx.lookup(cur, int(c))
            ok &= nxt >= 0
            cur = np.where(ok, nxt, 0)
            if not ok.any():
                break
        if not ok.any():
            continue
        anchors.append(anchor[ok])
        targets.append(cur[ok])
        rids.append(np.full(int(ok.sum()), rid, dtype=np.int32))
    if anchors:
        return (np.concatenate(anchors).astype(np.int32),
                np.concatenate(rids).astype(np.int32),
                np.concatenate(targets).astype(np.int32))
    z = np.zeros(0, np.int32)
    return z, z, z


def set_link_store(trie: DictTrie, anchors, rids, targets) -> None:
    order = np.lexsort((rids, anchors))
    trie.link_anchor = anchors[order].astype(np.int32)
    trie.link_rule = rids[order].astype(np.int32)
    trie.link_target = targets[order].astype(np.int32)


def _csr_to_plane(ptr: np.ndarray, data: np.ndarray, width: int) -> np.ndarray:
    """Dense [len(ptr)-1, width] plane of a CSR, -1 padded, row order kept."""
    n = len(ptr) - 1
    plane = np.full((n, max(width, 1)), -1, dtype=np.int32)
    counts = np.diff(ptr)
    if len(data):
        rows = np.repeat(np.arange(n, dtype=np.int64), counts)
        cols = np.arange(len(data), dtype=np.int64) - np.repeat(
            ptr[:-1].astype(np.int64), counts)
        plane[rows, cols] = data
    return plane


def pack_rule_planes(trie: DictTrie, rule_trie: RuleTrie) -> None:
    """Relayout the rule-side structures into the packed *rule plane*.

    The frontier sweep's three rule-side lookups each pay for CSR
    indirection in the sweep's hot loop; this packs them into the dense,
    padded forms the device engine (and the fused locus-DP kernel) consume
    with one vectorized gather / one binary search each:

    - ``trie.tele_plane`` int32[N, tele_width]: teleport targets per node,
      -1 padded (replaces the syn_ptr/syn_tgt gather chain);
    - ``trie.link_ptr`` int32[N+1]: per-anchor CSR over the (rule-sorted)
      ``link_rule``/``link_target`` rows (replaces two binary searches over
      ``link_anchor`` with one pointer load);
    - ``rule_trie.term_plane`` int32[Nr, term_width]: rule ids terminating
      at each rule-trie node, -1 padded.  Width >= 1 always, so gathers
      need no degenerate-shape clamp even for rule-free builds.

    Plane widths are static (recorded as ``EngineConfig.tele_width`` /
    ``term_width`` at build time) and ride the npz container from format
    version 2 on; loading an older container rebuilds them here.
    Must run after ``set_link_store`` / the final ``rebuild_edges``.
    """
    n = trie.n_nodes
    trie.tele_plane = _csr_to_plane(trie.syn_ptr, trie.syn_tgt,
                                    trie.max_syn_targets)
    trie.link_ptr = np.searchsorted(
        trie.link_anchor, np.arange(n + 1, dtype=np.int64)).astype(np.int32)
    rule_trie.term_plane = _csr_to_plane(rule_trie.term_ptr,
                                         rule_trie.term_rule,
                                         rule_trie.max_terms_per_node)


def _tile_width(max_row: int, minimum: int = 8) -> int:
    """Smallest power-of-two window >= the longest CSR row (min 8): one
    DMA of this width always covers a whole row."""
    w = minimum
    while w < max_row:
        w *= 2
    return w


def _tiled_len(real: int, tile: int) -> int:
    """Padded flat length for a ``real``-row table under ``tile``-wide
    windows: a multiple of ``tile`` that is >= real + tile, so a window
    starting at any in-range offset (including ``real`` itself, the empty
    row at the very end) stays in bounds."""
    return (real + 2 * tile - 1) // tile * tile


def _pad_tiled(arr: np.ndarray, real: int, tile: int, fill) -> np.ndarray:
    """Pad ``arr[:real]`` to ``_tiled_len(real, tile)`` with ``fill``.
    Re-slicing from ``real`` (the CSR ptr total) makes re-packing
    idempotent.  Empty tables stay empty: every ``shape[0] > 0``
    feature probe in the engine keeps its meaning."""
    if real == 0:
        return arr[:0]
    out = np.full(_tiled_len(real, tile), fill, dtype=arr.dtype)
    out[:real] = arr[:real]
    return out


def pack_stream_tiles(trie: DictTrie, rule_trie: RuleTrie) -> None:
    """Relayout the flat tables into the tile-aligned *stream layout*.

    The DMA-streamed kernel tier (``kernels/stream.py``) reads CSR child
    rows, emission rows and link-store rows with fixed-width windowed
    ``make_async_copy`` slices ``[start, start + tile)`` instead of
    holding the whole table in VMEM.  For those windows to be legal the
    layout must guarantee two statics, both recorded on the trie (and in
    ``EngineConfig`` at build time):

    - a *tile width* per table family — a power of two covering the
      longest row, so one window always spans a whole CSR row;
    - a *tail pad* — each flat array grows to a tile multiple at least
      one tile past its real length, so a window anchored at any row
      start (even the empty row at the very end) stays in bounds.

    Pad values are inert by construction (chars -1 never match a query
    byte, scores -1 never beat a live emission, child/target ids 0 are
    only read masked-off), and the real lengths stay recoverable from the
    CSR ptr totals, which makes re-packing idempotent.  Empty tables are
    left empty so ``shape[0] > 0`` feature probes keep working.  The
    resident kernels and the jnp reference engine confine every search to
    ``[ptr[n], ptr[n+1])`` and so return bit-identical results on the
    padded layout.  Must run after ``pack_rule_planes`` (needs
    ``link_ptr``) and any final ``rebuild_edges``.  Persisted as npz
    format v3; older containers re-pack here on load.
    """
    assert trie.link_ptr is not None, \
        "pack_stream_tiles requires pack_rule_planes to have run"
    fanout = int(np.diff(trie.first_child).max(initial=0))
    s_fanout = int(np.diff(trie.s_first_child).max(initial=0))
    trie.walk_tile = _tile_width(max(fanout, s_fanout))
    trie.emit_tile = _tile_width(int(np.diff(trie.emit_ptr).max(initial=0)))
    trie.link_tile = _tile_width(int(np.diff(trie.link_ptr).max(initial=0)))

    e = int(trie.first_child[-1])
    trie.edge_char = _pad_tiled(trie.edge_char, e, trie.walk_tile, -1)
    trie.edge_child = _pad_tiled(trie.edge_child, e, trie.walk_tile, 0)
    es = int(trie.s_first_child[-1])
    trie.s_edge_char = _pad_tiled(trie.s_edge_char, es, trie.walk_tile, -1)
    trie.s_edge_child = _pad_tiled(trie.s_edge_child, es, trie.walk_tile, 0)
    m = int(trie.emit_ptr[-1])
    trie.emit_node = _pad_tiled(trie.emit_node, m, trie.emit_tile, 0)
    trie.emit_score = _pad_tiled(trie.emit_score, m, trie.emit_tile, -1)
    trie.emit_is_leaf = _pad_tiled(trie.emit_is_leaf, m, trie.emit_tile,
                                   False)
    lk = int(trie.link_ptr[-1])
    trie.link_rule = _pad_tiled(trie.link_rule, lk, trie.link_tile, -1)
    trie.link_target = _pad_tiled(trie.link_target, lk, trie.link_tile, 0)


# ---------------------------------------------------------------------------
# Synonym expansion (ET / HT)
# ---------------------------------------------------------------------------


def expand_synonyms(trie: DictTrie, rules: list[SynonymRule],
                    anchors: np.ndarray, rids: np.ndarray, targets: np.ndarray,
                    expand_mask: np.ndarray) -> int:
    """Expand the links of rules selected by ``expand_mask`` into the trie as
    zero-score synonym branches; terminal branch nodes teleport to the link
    target. Mutates ``trie`` in place; returns #new nodes created.

    Branch nodes are always fresh synonym nodes (never reused dictionary
    nodes): a teleport may only be reached by literally typing the variant,
    which enforces the paper's rule that generated text cannot participate
    in a subsequent rule application. Branches with a shared anchor and a
    shared lhs prefix share nodes (the knapsack "item interaction").
    """
    sel = expand_mask[rids]
    items = sorted(
        (int(a), rules[int(r)].lhs, int(t))
        for a, r, t in zip(anchors[sel], rids[sel], targets[sel])
    )
    new_parent: list[int] = []
    new_char: list[int] = []
    new_depth: list[int] = []
    syn_edges: dict[tuple[int, int], int] = {}
    tele: dict[int, list[int]] = {}
    next_id = trie.n_nodes
    n0 = next_id

    def depth_of(v: int) -> int:
        return int(trie.depth[v]) if v < n0 else new_depth[v - n0]

    for anchor, lhs, target in items:
        cur = anchor
        cur_depth = depth_of(anchor)
        for c in lhs:
            nxt = syn_edges.get((cur, c), -1)
            if nxt < 0:
                nxt = next_id
                next_id += 1
                new_parent.append(cur)
                new_char.append(c)
                new_depth.append(cur_depth + 1)
                syn_edges[(cur, c)] = nxt
            cur = nxt
            cur_depth += 1
        tele.setdefault(cur, []).append(target)

    n_new = next_id - n0
    if n_new:
        trie.parent = np.concatenate([trie.parent, np.array(new_parent, np.int32)])
        trie.chr_ = np.concatenate([trie.chr_, np.array(new_char, np.int32)])
        trie.depth = np.concatenate([trie.depth, np.array(new_depth, np.int32)])
        trie.max_score = np.concatenate([trie.max_score, np.zeros(n_new, np.int32)])
        trie.leaf_score = np.concatenate([trie.leaf_score, np.full(n_new, -1, np.int32)])
        trie.leaf_sid = np.concatenate([trie.leaf_sid, np.full(n_new, -1, np.int32)])
        trie.syn_mask = np.concatenate([trie.syn_mask, np.ones(n_new, bool)])
        trie.tout = np.concatenate(
            [trie.tout, np.arange(n0 + 1, next_id + 1, dtype=np.int32)])
        if trie.topk_score is not None:
            k = trie.topk_score.shape[1]
            trie.topk_score = np.concatenate(
                [trie.topk_score, np.full((n_new, k), -1, np.int32)])
            trie.topk_sid = np.concatenate(
                [trie.topk_sid, np.full((n_new, k), -1, np.int32)])
        trie.max_depth = int(trie.depth.max(initial=0))

    # teleports CSR (merge with any existing)
    n = trie.n_nodes
    old_nodes = np.repeat(np.arange(len(trie.syn_ptr) - 1, dtype=np.int32),
                          np.diff(trie.syn_ptr))
    old_tgt = trie.syn_tgt
    add_nodes = np.array([v for v, ts in tele.items() for _ in ts], np.int32)
    add_tgt = np.array([t for ts in tele.values() for t in ts], np.int32)
    nodes = np.concatenate([old_nodes, add_nodes])
    tgts = np.concatenate([old_tgt, add_tgt])
    # dedup (node, target)
    if len(nodes):
        key = nodes.astype(np.int64) * n + tgts
        _, uniq = np.unique(key, return_index=True)
        nodes, tgts = nodes[uniq], tgts[uniq]
    order = np.argsort(nodes, kind="stable")
    nodes, tgts = nodes[order], tgts[order]
    counts = np.bincount(nodes, minlength=n).astype(np.int32)
    trie.syn_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    trie.syn_tgt = tgts.astype(np.int32)
    trie.max_syn_targets = int(counts.max(initial=0))

    rebuild_edges(trie)
    return n_new


# ---------------------------------------------------------------------------
# Materialized per-node top-K cache (beyond-paper optimization, cf. Li[9])
# ---------------------------------------------------------------------------


def build_topk_cache(trie: DictTrie, k: int) -> None:
    """Bottom-up merge of per-node top-k dictionary leaves."""
    n = trie.n_nodes
    score = np.full((n, k), -1, dtype=np.int32)
    sid = np.full((n, k), -1, dtype=np.int32)
    term = trie.leaf_score >= 0
    score[term, 0] = trie.leaf_score[term]
    sid[term, 0] = trie.leaf_sid[term]

    order = np.argsort(trie.depth, kind="stable")
    max_d = int(trie.depth.max(initial=0))
    bounds = np.searchsorted(trie.depth[order], np.arange(max_d + 2))
    for d in range(max_d, 0, -1):
        ids = order[bounds[d] : bounds[d + 1]]
        if len(ids) == 0:
            continue
        ids = ids[~trie.syn_mask[ids]]
        if len(ids) == 0:
            continue
        par = trie.parent[ids]
        # merge children into parents slot-group by slot-group: group children
        # of the same parent and fold them in chunks
        o = np.argsort(par, kind="stable")
        ids, par = ids[o], par[o]
        grp_start = np.concatenate([[True], par[1:] != par[:-1]])
        slot = np.arange(len(ids)) - np.maximum.accumulate(
            np.where(grp_start, np.arange(len(ids)), 0))
        max_slot = int(slot.max(initial=0))
        for j in range(max_slot + 1):
            m = slot == j
            pj, cj = par[m], ids[m]
            cat_score = np.concatenate([score[pj], score[cj]], axis=1)
            cat_sid = np.concatenate([sid[pj], sid[cj]], axis=1)
            top = np.argsort(-cat_score, axis=1, kind="stable")[:, :k]
            rows = np.arange(len(pj))[:, None]
            score[pj] = cat_score[rows, top]
            sid[pj] = cat_sid[rows, top]
    trie.topk_score = score
    trie.topk_sid = sid
