"""Host-side construction of array-encoded tries (numpy).

The paper's pointer tries become structure-of-arrays tries:

- child lookup CSR sorted by char within each node (binary-searchable),
- per-node *emission lists* sorted by max-descendant-score descending
  (the paper orders children by highest descendant score; we additionally
  interleave the node's own leaf so the beam engine emits in exact score
  order),
- synonym teleports (ET/HT expanded rules): CSR node -> dictionary target,
- rule-link store (TT/HT unexpanded rules): sorted (anchor, rule) -> target.

Construction is offline/host-side (like data loading in a training job);
lookup runs on device from these arrays alone.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

import numpy as np

from repro.core.alphabet import SIGMA, encode

ROOT = 0

# p_flags bits of the compressed layout (see pack_compressed)
PACK_DICT_UNARY = 1   # exactly one dict child, and it is v + 1
PACK_SYN_UNARY = 2    # exactly one syn child, and it is v + 1
PACK_IS_SYN = 4       # pure synonym node (== syn_mask)
PACK_HAS_LEAF = 8     # terminal node (leaf_score >= 0)

# fields that exist only in the compressed layout / that a packed (format
# v4) container keeps from the uncompressed layout
PACKED_ONLY_FIELDS = (
    "p_labels", "p_flags",
    "c_ids", "c_tout", "c_maxscore", "c_eptr", "c_enode", "c_escore",
    "c_eleaf",
    "b_ids", "b_ptr", "b_char", "b_child",
    "sb_ids", "sb_ptr", "sb_char", "sb_child",
    "l_ids", "l_sid", "t_ids", "t_plane", "la_ids", "la_ptr",
    "pc_score", "pc_base", "pc_sid",
)
PACKED_KEEP_FIELDS = ("link_rule", "link_target")


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SynonymRule:
    """A rule ``lhs -> rhs``: applying it to a *query* replaces an occurrence
    of ``lhs`` with ``rhs`` (the dictionary-side form)."""

    lhs: bytes
    rhs: bytes

    def __post_init__(self):
        if len(self.lhs) == 0 or len(self.rhs) == 0:
            raise ValueError("synonym rule sides must be non-empty")


def make_rules(pairs) -> list[SynonymRule]:
    out = []
    for lhs, rhs in pairs:
        lhs = lhs.encode() if isinstance(lhs, str) else bytes(lhs)
        rhs = rhs.encode() if isinstance(rhs, str) else bytes(rhs)
        out.append(SynonymRule(lhs, rhs))
    return out


# ---------------------------------------------------------------------------
# Array tries
# ---------------------------------------------------------------------------


@dataclass
class DictTrie:
    """Array-encoded dictionary trie (+ synonym structures).

    Every field is optional with a ``None`` default so that a
    ``compression="packed"`` container (format v4) — which persists only
    the compressed side tables plus the link store — can round-trip
    through ``DictTrie(**saved_arrays)``; builders always populate the
    uncompressed fields.
    """

    # per-node
    parent: np.ndarray | None = None      # int32[N]
    depth: np.ndarray | None = None       # int32[N]
    chr_: np.ndarray | None = None        # int32[N]  incoming edge (-1 root)
    max_score: np.ndarray | None = None   # int32[N]  max leaf score in subtree
    leaf_score: np.ndarray | None = None  # int32[N]  score if terminal else -1
    leaf_sid: np.ndarray | None = None    # int32[N]  sorted string id or -1
    syn_mask: np.ndarray | None = None    # bool [N]  True for syn nodes
    tout: np.ndarray | None = None        # int32[N]  subtree range [id, tout)

    # dictionary-child lookup CSR (within-node sorted by char)
    first_child: np.ndarray | None = None  # int32[N+1]
    edge_char: np.ndarray | None = None    # int32[E]
    edge_child: np.ndarray | None = None   # int32[E]

    # synonym-child lookup CSR (branches live in their own edge set so that
    # a dictionary node and a synonym branch may both continue with the same
    # character, and so that teleports can only be reached by literally typed
    # variant characters — rule output never participates in a later rule)
    s_first_child: np.ndarray | None = None  # int32[N+1]
    s_edge_char: np.ndarray | None = None    # int32[Es]
    s_edge_child: np.ndarray | None = None   # int32[Es]

    # emission lists (within-node sorted by score desc; excludes syn children)
    emit_ptr: np.ndarray | None = None     # int32[N+1]
    emit_node: np.ndarray | None = None    # int32[M]
    emit_score: np.ndarray | None = None   # int32[M]
    emit_is_leaf: np.ndarray | None = None  # bool[M] True => leaf of emit_node

    # synonym teleports (node -> dict target), CSR
    syn_ptr: np.ndarray | None = None      # int32[N+1]
    syn_tgt: np.ndarray | None = None      # int32[S]

    # unexpanded-rule link store, sorted by (anchor, rule)
    link_anchor: np.ndarray | None = None  # int32[L]
    link_rule: np.ndarray | None = None    # int32[L]
    link_target: np.ndarray | None = None  # int32[L]

    # packed rule plane (see pack_rule_planes): dense, padded relayouts of
    # the rule-side CSRs that the device engine and the fused locus-DP
    # kernel consume directly
    tele_plane: np.ndarray | None = None  # int32[N, Tw] teleports, -1 pad
    link_ptr: np.ndarray | None = None    # int32[N+1] anchor -> link rows

    # tile-aligned stream layout (see pack_stream_tiles): static window
    # widths for the DMA-streamed kernel tier; 0 until packed
    walk_tile: int = 0
    emit_tile: int = 0
    link_tile: int = 0

    # optional materialized per-node top-K (dict leaves only)
    topk_score: np.ndarray | None = None  # int32[N, K]
    topk_sid: np.ndarray | None = None    # int32[N, K]

    # compressed on-device layout (see pack_compressed): logical node ids
    # unchanged, per-node data chain-collapsed into sparse side tables at
    # the stored (chain-representative) nodes; None until packed
    p_labels: np.ndarray | None = None    # u8[N]  incoming-edge byte (root 0)
    p_flags: np.ndarray | None = None     # u8[N]  PACK_* bits
    c_ids: np.ndarray | None = None       # i32[C] stored dict nodes, sorted
    c_tout: np.ndarray | None = None      # i32[C]
    c_maxscore: np.ndarray | None = None  # u16/i32[C]
    c_eptr: np.ndarray | None = None      # i32[C+1] emission spans
    c_enode: np.ndarray | None = None     # i32[Me]
    c_escore: np.ndarray | None = None    # u16/i32[Me]
    c_eleaf: np.ndarray | None = None     # u8[Me]
    b_ids: np.ndarray | None = None       # i32[B]  dict fanout >= 2, sorted
    b_ptr: np.ndarray | None = None       # i32[B+1]
    b_char: np.ndarray | None = None      # u8[Eb]
    b_child: np.ndarray | None = None     # i32[Eb]
    sb_ids: np.ndarray | None = None      # i32[Sb] non-unary syn rows, sorted
    sb_ptr: np.ndarray | None = None      # i32[Sb+1]
    sb_char: np.ndarray | None = None     # u8[Esb]
    sb_child: np.ndarray | None = None    # i32[Esb]
    l_ids: np.ndarray | None = None       # i32[S]  terminal nodes, sorted
    l_sid: np.ndarray | None = None       # u16/i32[S]
    t_ids: np.ndarray | None = None       # i32[Tn] teleport-bearing, sorted
    t_plane: np.ndarray | None = None     # i32[Tn, tele_width], -1 pad
    la_ids: np.ndarray | None = None      # i32[La] link anchors, sorted
    la_ptr: np.ndarray | None = None      # i32[La+1] spans into link_rule
    pc_score: np.ndarray | None = None    # u16/i32[C, K] (+1-biased if u16)
    pc_base: np.ndarray | None = None     # i32[C] per-row score base
    pc_sid: np.ndarray | None = None      # u16/i32[C, K] (+1-biased if u16)

    # static metadata
    max_depth: int = 0
    max_syn_targets: int = 0

    @property
    def has_packed(self) -> bool:
        return self.p_labels is not None

    @property
    def n_nodes(self) -> int:
        if self.parent is not None:
            return len(self.parent)
        return len(self.p_labels)

    @property
    def n_edges(self) -> int:
        return len(self.edge_char)

    def nbytes(self, include_cache: bool = True) -> int:
        total = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                if not include_cache and f.name.startswith("topk_"):
                    continue
                total += v.nbytes
        return total

    def packed_nbytes(self, include_cache: bool = True) -> int:
        """Device bytes of the compressed layout alone (what a packed
        container persists and what the device holds)."""
        total = 0
        for name in PACKED_ONLY_FIELDS + PACKED_KEEP_FIELDS:
            v = getattr(self, name)
            if isinstance(v, np.ndarray):
                if not include_cache and name.startswith("pc_"):
                    continue
                total += v.nbytes
        return total


@dataclass
class RuleTrie:
    """Array-encoded trie over the query-side (lhs) strings of rules."""

    first_child: np.ndarray  # int32[N+1]
    edge_char: np.ndarray    # int32[E]
    edge_child: np.ndarray   # int32[E]
    depth: np.ndarray        # int32[N]
    term_ptr: np.ndarray     # int32[N+1]  node -> rule ids terminating here
    term_rule: np.ndarray    # int32[T]
    rule_len: np.ndarray     # int32[R]    lhs length per rule id
    # packed rule plane (see pack_rule_planes): term lists as a dense,
    # -1-padded [N, term_width] plane (term_width >= 1 even when empty,
    # so device gathers never need a degenerate-shape guard)
    term_plane: np.ndarray | None = None  # int32[N, Tw]
    max_lhs_len: int = 0
    max_matches_per_pos: int = 0  # max #terminals on any root path
    max_terms_per_node: int = 1   # max #rules terminating at one node

    @property
    def n_nodes(self) -> int:
        return len(self.depth)

    def nbytes(self) -> int:
        total = 0
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if isinstance(v, np.ndarray):
                total += v.nbytes
        return total


# ---------------------------------------------------------------------------
# Dictionary trie construction (sorted-strings + LCP sweep)
# ---------------------------------------------------------------------------


def _sorted_unique(strings: list[bytes], scores: np.ndarray):
    order = sorted(range(len(strings)), key=lambda i: strings[i])
    sorted_strings: list[bytes] = []
    sorted_scores: list[int] = []
    for i in order:
        s = strings[i]
        if sorted_strings and sorted_strings[-1] == s:
            sorted_scores[-1] = max(sorted_scores[-1], int(scores[i]))
        else:
            sorted_strings.append(s)
            sorted_scores.append(int(scores[i]))
    return sorted_strings, np.asarray(sorted_scores, dtype=np.int32)


def _lcp(a: bytes, b: bytes) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


def build_dict_trie(strings: list[bytes | str], scores) -> tuple[DictTrie, list[bytes], np.ndarray]:
    """Build the dictionary trie. Returns (trie, sorted_strings, sorted_scores).

    String ids (leaf_sid) index into the *sorted* string list.
    """
    raw = [s.encode() if isinstance(s, str) else bytes(s) for s in strings]
    scores = np.asarray(scores)
    assert len(raw) == len(scores)
    ss, sc = _sorted_unique(raw, scores)
    n_str = len(ss)

    # --- node creation sweep (nodes are created in DFS preorder) ---
    parent_chunks: list[np.ndarray] = [np.array([-1], dtype=np.int32)]
    char_chunks: list[np.ndarray] = [np.array([-1], dtype=np.int32)]
    depth_chunks: list[np.ndarray] = [np.array([0], dtype=np.int32)]
    next_id = 1
    max_len = max((len(s) for s in ss), default=0)
    path = np.zeros(max_len + 1, dtype=np.int64)  # node id at each depth
    leaf_nodes = np.zeros(n_str, dtype=np.int32)
    prev = b""
    for i, s in enumerate(ss):
        d0 = _lcp(prev, s)
        cnt = len(s) - d0
        if cnt > 0:
            ids = np.arange(next_id, next_id + cnt, dtype=np.int32)
            parents = np.empty(cnt, dtype=np.int32)
            parents[0] = path[d0]
            parents[1:] = ids[:-1]
            chars = np.frombuffer(s[d0:], dtype=np.uint8).astype(np.int32)
            depths = np.arange(d0 + 1, len(s) + 1, dtype=np.int32)
            parent_chunks.append(parents)
            char_chunks.append(chars)
            depth_chunks.append(depths)
            path[d0 + 1 : len(s) + 1] = ids
            next_id += cnt
        leaf_nodes[i] = path[len(s)]
        prev = s

    parent = np.concatenate(parent_chunks)
    chr_ = np.concatenate(char_chunks)
    depth = np.concatenate(depth_chunks)
    n = next_id

    leaf_score = np.full(n, -1, dtype=np.int32)
    leaf_sid = np.full(n, -1, dtype=np.int32)
    leaf_score[leaf_nodes] = sc
    leaf_sid[leaf_nodes] = np.arange(n_str, dtype=np.int32)

    syn_mask = np.zeros(n, dtype=bool)
    max_score = _propagate_max_scores(parent, depth, leaf_score)
    tout = _compute_tout(parent, depth)

    trie = DictTrie(
        parent=parent,
        depth=depth,
        chr_=chr_,
        max_score=max_score,
        leaf_score=leaf_score,
        leaf_sid=leaf_sid,
        syn_mask=syn_mask,
        tout=tout,
        first_child=np.zeros(n + 1, np.int32),
        edge_char=np.zeros(0, np.int32),
        edge_child=np.zeros(0, np.int32),
        s_first_child=np.zeros(n + 1, np.int32),
        s_edge_char=np.zeros(0, np.int32),
        s_edge_child=np.zeros(0, np.int32),
        emit_ptr=np.zeros(n + 1, np.int32),
        emit_node=np.zeros(0, np.int32),
        emit_score=np.zeros(0, np.int32),
        emit_is_leaf=np.zeros(0, bool),
        syn_ptr=np.zeros(n + 1, np.int32),
        syn_tgt=np.zeros(0, np.int32),
        link_anchor=np.zeros(0, np.int32),
        link_rule=np.zeros(0, np.int32),
        link_target=np.zeros(0, np.int32),
        max_depth=int(depth.max(initial=0)),
    )
    rebuild_edges(trie)
    return trie, ss, sc


def _compute_tout(parent, depth) -> np.ndarray:
    """Dictionary nodes are created in DFS preorder, so subtree(v) is the
    contiguous id range [v, tout[v]). Enables O(1) ancestor tests (used to
    reduce locus sets to an antichain so top-k never double-counts)."""
    n = len(parent)
    tout = np.arange(1, n + 1, dtype=np.int32)
    if n == 0:
        return tout
    order = np.argsort(depth, kind="stable")
    max_d = int(depth.max(initial=0))
    bounds = np.searchsorted(depth[order], np.arange(max_d + 2))
    for d in range(max_d, 0, -1):
        ids = order[bounds[d] : bounds[d + 1]]
        if len(ids) == 0:
            continue
        np.maximum.at(tout, parent[ids], tout[ids])
    return tout


def _propagate_max_scores(parent, depth, leaf_score) -> np.ndarray:
    """max_score[v] = max leaf_score over v's subtree (dict leaves only)."""
    n = len(parent)
    max_score = leaf_score.copy()
    if n == 0:
        return max_score
    max_d = int(depth.max(initial=0))
    # group node ids by depth once
    order = np.argsort(depth, kind="stable")
    bounds = np.searchsorted(depth[order], np.arange(max_d + 2))
    for d in range(max_d, 0, -1):
        ids = order[bounds[d] : bounds[d + 1]]
        if len(ids) == 0:
            continue
        np.maximum.at(max_score, parent[ids], max_score[ids])
    return max_score


def rebuild_edges(trie: DictTrie) -> None:
    """(Re)build dict/syn child CSRs + emission lists from parent/chr arrays."""
    n = trie.n_nodes
    all_ids = np.arange(n, dtype=np.int32)
    is_child = all_ids != ROOT

    for syn in (False, True):
        sel = is_child & (trie.syn_mask == syn)
        ids = all_ids[sel]
        p = trie.parent[ids]
        c = trie.chr_[ids]
        order = np.lexsort((c, p))
        ids, p, c = ids[order], p[order], c[order]
        counts = np.bincount(p, minlength=n).astype(np.int32)
        ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
        if syn:
            trie.s_first_child = ptr
            trie.s_edge_char = c.astype(np.int32)
            trie.s_edge_child = ids.astype(np.int32)
        else:
            trie.first_child = ptr
            trie.edge_char = c.astype(np.int32)
            trie.edge_child = ids.astype(np.int32)

    # emission lists: dictionary children (ranked by max_score) + own leaf
    ids = all_ids[is_child & ~trie.syn_mask]
    p = trie.parent[ids]
    order = np.lexsort((trie.chr_[ids], p))
    ids, p = ids[order], p[order]
    e_par = p
    e_node = ids
    e_score = trie.max_score[e_node]
    e_leaf = np.zeros(len(e_node), dtype=bool)
    term = np.nonzero(trie.leaf_score >= 0)[0].astype(np.int32)
    e_par = np.concatenate([e_par, term])
    e_node = np.concatenate([e_node, term])
    e_score = np.concatenate([e_score, trie.leaf_score[term]])
    e_leaf = np.concatenate([e_leaf, np.ones(len(term), dtype=bool)])
    order = np.lexsort((-e_score, e_par))
    e_par, e_node, e_score, e_leaf = (
        e_par[order], e_node[order], e_score[order], e_leaf[order])
    counts = np.bincount(e_par, minlength=n).astype(np.int32)
    trie.emit_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    trie.emit_node = e_node.astype(np.int32)
    trie.emit_score = e_score.astype(np.int32)
    trie.emit_is_leaf = e_leaf


# ---------------------------------------------------------------------------
# Host-side edge lookup (int64 keys, vectorized)
# ---------------------------------------------------------------------------


class _EdgeIndex:
    def __init__(self, trie: DictTrie):
        key = trie.edge_child  # children ids
        self.keys = trie.parent[key].astype(np.int64) * SIGMA + trie.chr_[key]
        order = np.argsort(self.keys, kind="stable")
        self.keys = self.keys[order]
        self.children = key[order].astype(np.int32)

    def lookup(self, nodes: np.ndarray, char: int) -> np.ndarray:
        k = nodes.astype(np.int64) * SIGMA + char
        i = np.searchsorted(self.keys, k)
        i = np.minimum(i, len(self.keys) - 1) if len(self.keys) else i * 0
        ok = (len(self.keys) > 0) & (self.keys[i] == k) if len(self.keys) else np.zeros(len(k), bool)
        return np.where(ok, self.children[i] if len(self.keys) else -1, -1).astype(np.int32)


# ---------------------------------------------------------------------------
# Rule trie + links
# ---------------------------------------------------------------------------


def build_rule_trie(rules: list[SynonymRule], active: np.ndarray | None = None) -> RuleTrie:
    """Trie over lhs strings of *active* rules (rule ids are global)."""
    n_rules = len(rules)
    if active is None:
        active = np.ones(n_rules, dtype=bool)
    items = sorted((rules[i].lhs, i) for i in range(n_rules) if active[i])

    parent = [np.array([-1], np.int32)]
    chr_ = [np.array([-1], np.int32)]
    depth = [np.array([0], np.int32)]
    next_id = 1
    max_len = max((len(s) for s, _ in items), default=0)
    path = np.zeros(max_len + 1, dtype=np.int64)
    terms: list[tuple[int, int]] = []  # (node, rule)
    prev = b""
    for s, rid in items:
        d0 = _lcp(prev, s)
        cnt = len(s) - d0
        if cnt > 0:
            ids = np.arange(next_id, next_id + cnt, dtype=np.int32)
            pp = np.empty(cnt, np.int32)
            pp[0] = path[d0]
            pp[1:] = ids[:-1]
            parent.append(pp)
            chr_.append(np.frombuffer(s[d0:], np.uint8).astype(np.int32))
            depth.append(np.arange(d0 + 1, len(s) + 1, dtype=np.int32))
            path[d0 + 1 : len(s) + 1] = ids
            next_id += cnt
        terms.append((int(path[len(s)]), rid))
        prev = s

    parent = np.concatenate(parent)
    chr_ = np.concatenate(chr_)
    depth = np.concatenate(depth)
    n = next_id

    ids = np.arange(1, n, dtype=np.int32)
    order = np.lexsort((chr_[ids], parent[ids]))
    ids = ids[order]
    counts = np.bincount(parent[ids], minlength=n).astype(np.int32)
    first_child = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)

    term_node = np.array([t for t, _ in terms], dtype=np.int32)
    term_rid = np.array([r for _, r in terms], dtype=np.int32)
    t_order = np.argsort(term_node, kind="stable")
    term_node, term_rid = term_node[t_order], term_rid[t_order]
    t_counts = np.bincount(term_node, minlength=n).astype(np.int32)
    term_ptr = np.concatenate([[0], np.cumsum(t_counts)]).astype(np.int32)

    # max #terminals along any root path = max over terminal nodes of
    # (#ancestors incl. self that are terminal); bounded by walking parents
    is_term = t_counts > 0
    max_matches = 0
    for t in term_node:
        cnt, v = 0, int(t)
        while v != -1:
            if is_term[v]:
                cnt += int(t_counts[v])
            v = int(parent[v]) if v != ROOT else -1
        max_matches = max(max_matches, cnt)

    rule_len = np.array([len(r.lhs) for r in rules], dtype=np.int32)
    return RuleTrie(
        first_child=first_child,
        edge_char=chr_[ids].astype(np.int32),
        edge_child=ids.astype(np.int32),
        depth=depth,
        term_ptr=term_ptr,
        term_rule=term_rid,
        rule_len=rule_len,
        max_lhs_len=int(max((len(s) for s, _ in items), default=0)),
        max_matches_per_pos=max_matches,
        max_terms_per_node=int(t_counts.max(initial=1)),
    )


def find_links(trie: DictTrie, rules: list[SynonymRule]):
    """All (anchor, rule, target) with target = walk(anchor, rule.rhs).

    Must be called on the pure dictionary trie (pre-expansion): rule
    applications may not anchor inside generated synonym text.
    """
    idx = _EdgeIndex(trie)
    anchors, rids, targets = [], [], []
    # group candidate starts by first char of rhs
    child_ids = trie.edge_child
    by_char: dict[int, np.ndarray] = {}
    for ch in np.unique(trie.edge_char):
        sel = trie.edge_char == ch
        by_char[int(ch)] = child_ids[sel]
    for rid, rule in enumerate(rules):
        rhs = np.frombuffer(rule.rhs, np.uint8).astype(np.int32)
        first = by_char.get(int(rhs[0]))
        if first is None:
            continue
        anchor = trie.parent[first]
        cur = first.copy()
        ok = np.ones(len(cur), dtype=bool)
        for c in rhs[1:]:
            nxt = idx.lookup(cur, int(c))
            ok &= nxt >= 0
            cur = np.where(ok, nxt, 0)
            if not ok.any():
                break
        if not ok.any():
            continue
        anchors.append(anchor[ok])
        targets.append(cur[ok])
        rids.append(np.full(int(ok.sum()), rid, dtype=np.int32))
    if anchors:
        return (np.concatenate(anchors).astype(np.int32),
                np.concatenate(rids).astype(np.int32),
                np.concatenate(targets).astype(np.int32))
    z = np.zeros(0, np.int32)
    return z, z, z


def set_link_store(trie: DictTrie, anchors, rids, targets) -> None:
    order = np.lexsort((rids, anchors))
    trie.link_anchor = anchors[order].astype(np.int32)
    trie.link_rule = rids[order].astype(np.int32)
    trie.link_target = targets[order].astype(np.int32)


def _csr_to_plane(ptr: np.ndarray, data: np.ndarray, width: int) -> np.ndarray:
    """Dense [len(ptr)-1, width] plane of a CSR, -1 padded, row order kept."""
    n = len(ptr) - 1
    plane = np.full((n, max(width, 1)), -1, dtype=np.int32)
    counts = np.diff(ptr)
    if len(data):
        rows = np.repeat(np.arange(n, dtype=np.int64), counts)
        cols = np.arange(len(data), dtype=np.int64) - np.repeat(
            ptr[:-1].astype(np.int64), counts)
        plane[rows, cols] = data
    return plane


def pack_rule_planes(trie: DictTrie, rule_trie: RuleTrie) -> None:
    """Relayout the rule-side structures into the packed *rule plane*.

    The frontier sweep's three rule-side lookups each pay for CSR
    indirection in the sweep's hot loop; this packs them into the dense,
    padded forms the device engine (and the fused locus-DP kernel) consume
    with one vectorized gather / one binary search each:

    - ``trie.tele_plane`` int32[N, tele_width]: teleport targets per node,
      -1 padded (replaces the syn_ptr/syn_tgt gather chain);
    - ``trie.link_ptr`` int32[N+1]: per-anchor CSR over the (rule-sorted)
      ``link_rule``/``link_target`` rows (replaces two binary searches over
      ``link_anchor`` with one pointer load);
    - ``rule_trie.term_plane`` int32[Nr, term_width]: rule ids terminating
      at each rule-trie node, -1 padded.  Width >= 1 always, so gathers
      need no degenerate-shape clamp even for rule-free builds.

    Plane widths are static (recorded as ``EngineConfig.tele_width`` /
    ``term_width`` at build time) and ride the npz container from format
    version 2 on; loading an older container rebuilds them here.
    Must run after ``set_link_store`` / the final ``rebuild_edges``.
    """
    n = trie.n_nodes
    trie.tele_plane = _csr_to_plane(trie.syn_ptr, trie.syn_tgt,
                                    trie.max_syn_targets)
    trie.link_ptr = np.searchsorted(
        trie.link_anchor, np.arange(n + 1, dtype=np.int64)).astype(np.int32)
    rule_trie.term_plane = _csr_to_plane(rule_trie.term_ptr,
                                         rule_trie.term_rule,
                                         rule_trie.max_terms_per_node)


def _tile_width(max_row: int, minimum: int = 8) -> int:
    """Smallest power-of-two window >= the longest CSR row (min 8): one
    DMA of this width always covers a whole row."""
    w = minimum
    while w < max_row:
        w *= 2
    return w


def _tiled_len(real: int, tile: int) -> int:
    """Padded flat length for a ``real``-row table under ``tile``-wide
    windows: a multiple of ``tile`` that is >= real + tile, so a window
    starting at any in-range offset (including ``real`` itself, the empty
    row at the very end) stays in bounds."""
    return (real + 2 * tile - 1) // tile * tile


def _pad_tiled(arr: np.ndarray, real: int, tile: int, fill) -> np.ndarray:
    """Pad ``arr[:real]`` to ``_tiled_len(real, tile)`` with ``fill``.
    Re-slicing from ``real`` (the CSR ptr total) makes re-packing
    idempotent.  Empty tables stay empty: every ``shape[0] > 0``
    feature probe in the engine keeps its meaning."""
    if real == 0:
        return arr[:0]
    out = np.full(_tiled_len(real, tile), fill, dtype=arr.dtype)
    out[:real] = arr[:real]
    return out


def pack_stream_tiles(trie: DictTrie, rule_trie: RuleTrie) -> None:
    """Relayout the flat tables into the tile-aligned *stream layout*.

    The DMA-streamed kernel tier (``kernels/stream.py``) reads CSR child
    rows, emission rows and link-store rows with fixed-width windowed
    ``make_async_copy`` slices ``[start, start + tile)`` instead of
    holding the whole table in VMEM.  For those windows to be legal the
    layout must guarantee two statics, both recorded on the trie (and in
    ``EngineConfig`` at build time):

    - a *tile width* per table family — a power of two covering the
      longest row, so one window always spans a whole CSR row;
    - a *tail pad* — each flat array grows to a tile multiple at least
      one tile past its real length, so a window anchored at any row
      start (even the empty row at the very end) stays in bounds.

    Pad values are inert by construction (chars -1 never match a query
    byte, scores -1 never beat a live emission, child/target ids 0 are
    only read masked-off), and the real lengths stay recoverable from the
    CSR ptr totals, which makes re-packing idempotent.  Empty tables are
    left empty so ``shape[0] > 0`` feature probes keep working.  The
    resident kernels and the jnp reference engine confine every search to
    ``[ptr[n], ptr[n+1])`` and so return bit-identical results on the
    padded layout.  Must run after ``pack_rule_planes`` (needs
    ``link_ptr``) and any final ``rebuild_edges``.  Persisted as npz
    format v3; older containers re-pack here on load.
    """
    assert trie.link_ptr is not None, \
        "pack_stream_tiles requires pack_rule_planes to have run"
    fanout = int(np.diff(trie.first_child).max(initial=0))
    s_fanout = int(np.diff(trie.s_first_child).max(initial=0))
    trie.walk_tile = _tile_width(max(fanout, s_fanout))
    trie.emit_tile = _tile_width(int(np.diff(trie.emit_ptr).max(initial=0)))
    trie.link_tile = _tile_width(int(np.diff(trie.link_ptr).max(initial=0)))

    e = int(trie.first_child[-1])
    trie.edge_char = _pad_tiled(trie.edge_char, e, trie.walk_tile, -1)
    trie.edge_child = _pad_tiled(trie.edge_child, e, trie.walk_tile, 0)
    es = int(trie.s_first_child[-1])
    trie.s_edge_char = _pad_tiled(trie.s_edge_char, es, trie.walk_tile, -1)
    trie.s_edge_child = _pad_tiled(trie.s_edge_child, es, trie.walk_tile, 0)
    m = int(trie.emit_ptr[-1])
    trie.emit_node = _pad_tiled(trie.emit_node, m, trie.emit_tile, 0)
    trie.emit_score = _pad_tiled(trie.emit_score, m, trie.emit_tile, -1)
    trie.emit_is_leaf = _pad_tiled(trie.emit_is_leaf, m, trie.emit_tile,
                                   False)
    lk = int(trie.link_ptr[-1])
    trie.link_rule = _pad_tiled(trie.link_rule, lk, trie.link_tile, -1)
    trie.link_target = _pad_tiled(trie.link_target, lk, trie.link_tile, 0)


# ---------------------------------------------------------------------------
# Synonym expansion (ET / HT)
# ---------------------------------------------------------------------------


def expand_synonyms(trie: DictTrie, rules: list[SynonymRule],
                    anchors: np.ndarray, rids: np.ndarray, targets: np.ndarray,
                    expand_mask: np.ndarray) -> int:
    """Expand the links of rules selected by ``expand_mask`` into the trie as
    zero-score synonym branches; terminal branch nodes teleport to the link
    target. Mutates ``trie`` in place; returns #new nodes created.

    Branch nodes are always fresh synonym nodes (never reused dictionary
    nodes): a teleport may only be reached by literally typing the variant,
    which enforces the paper's rule that generated text cannot participate
    in a subsequent rule application. Branches with a shared anchor and a
    shared lhs prefix share nodes (the knapsack "item interaction").
    """
    sel = expand_mask[rids]
    items = sorted(
        (int(a), rules[int(r)].lhs, int(t))
        for a, r, t in zip(anchors[sel], rids[sel], targets[sel])
    )
    new_parent: list[int] = []
    new_char: list[int] = []
    new_depth: list[int] = []
    syn_edges: dict[tuple[int, int], int] = {}
    tele: dict[int, list[int]] = {}
    next_id = trie.n_nodes
    n0 = next_id

    def depth_of(v: int) -> int:
        return int(trie.depth[v]) if v < n0 else new_depth[v - n0]

    for anchor, lhs, target in items:
        cur = anchor
        cur_depth = depth_of(anchor)
        for c in lhs:
            nxt = syn_edges.get((cur, c), -1)
            if nxt < 0:
                nxt = next_id
                next_id += 1
                new_parent.append(cur)
                new_char.append(c)
                new_depth.append(cur_depth + 1)
                syn_edges[(cur, c)] = nxt
            cur = nxt
            cur_depth += 1
        tele.setdefault(cur, []).append(target)

    n_new = next_id - n0
    if n_new:
        trie.parent = np.concatenate([trie.parent, np.array(new_parent, np.int32)])
        trie.chr_ = np.concatenate([trie.chr_, np.array(new_char, np.int32)])
        trie.depth = np.concatenate([trie.depth, np.array(new_depth, np.int32)])
        trie.max_score = np.concatenate([trie.max_score, np.zeros(n_new, np.int32)])
        trie.leaf_score = np.concatenate([trie.leaf_score, np.full(n_new, -1, np.int32)])
        trie.leaf_sid = np.concatenate([trie.leaf_sid, np.full(n_new, -1, np.int32)])
        trie.syn_mask = np.concatenate([trie.syn_mask, np.ones(n_new, bool)])
        trie.tout = np.concatenate(
            [trie.tout, np.arange(n0 + 1, next_id + 1, dtype=np.int32)])
        if trie.topk_score is not None:
            k = trie.topk_score.shape[1]
            trie.topk_score = np.concatenate(
                [trie.topk_score, np.full((n_new, k), -1, np.int32)])
            trie.topk_sid = np.concatenate(
                [trie.topk_sid, np.full((n_new, k), -1, np.int32)])
        trie.max_depth = int(trie.depth.max(initial=0))

    # teleports CSR (merge with any existing)
    n = trie.n_nodes
    old_nodes = np.repeat(np.arange(len(trie.syn_ptr) - 1, dtype=np.int32),
                          np.diff(trie.syn_ptr))
    old_tgt = trie.syn_tgt
    add_nodes = np.array([v for v, ts in tele.items() for _ in ts], np.int32)
    add_tgt = np.array([t for ts in tele.values() for t in ts], np.int32)
    nodes = np.concatenate([old_nodes, add_nodes])
    tgts = np.concatenate([old_tgt, add_tgt])
    # dedup (node, target)
    if len(nodes):
        key = nodes.astype(np.int64) * n + tgts
        _, uniq = np.unique(key, return_index=True)
        nodes, tgts = nodes[uniq], tgts[uniq]
    order = np.argsort(nodes, kind="stable")
    nodes, tgts = nodes[order], tgts[order]
    counts = np.bincount(nodes, minlength=n).astype(np.int32)
    trie.syn_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int32)
    trie.syn_tgt = tgts.astype(np.int32)
    trie.max_syn_targets = int(counts.max(initial=0))

    rebuild_edges(trie)
    return n_new


# ---------------------------------------------------------------------------
# Materialized per-node top-K cache (beyond-paper optimization, cf. Li[9])
# ---------------------------------------------------------------------------


def build_topk_cache(trie: DictTrie, k: int) -> None:
    """Bottom-up merge of per-node top-k dictionary leaves."""
    n = trie.n_nodes
    score = np.full((n, k), -1, dtype=np.int32)
    sid = np.full((n, k), -1, dtype=np.int32)
    term = trie.leaf_score >= 0
    score[term, 0] = trie.leaf_score[term]
    sid[term, 0] = trie.leaf_sid[term]

    order = np.argsort(trie.depth, kind="stable")
    max_d = int(trie.depth.max(initial=0))
    bounds = np.searchsorted(trie.depth[order], np.arange(max_d + 2))
    for d in range(max_d, 0, -1):
        ids = order[bounds[d] : bounds[d + 1]]
        if len(ids) == 0:
            continue
        ids = ids[~trie.syn_mask[ids]]
        if len(ids) == 0:
            continue
        par = trie.parent[ids]
        # merge children into parents slot-group by slot-group: group children
        # of the same parent and fold them in chunks
        o = np.argsort(par, kind="stable")
        ids, par = ids[o], par[o]
        grp_start = np.concatenate([[True], par[1:] != par[:-1]])
        slot = np.arange(len(ids)) - np.maximum.accumulate(
            np.where(grp_start, np.arange(len(ids)), 0))
        max_slot = int(slot.max(initial=0))
        for j in range(max_slot + 1):
            m = slot == j
            pj, cj = par[m], ids[m]
            cat_score = np.concatenate([score[pj], score[cj]], axis=1)
            cat_sid = np.concatenate([sid[pj], sid[cj]], axis=1)
            top = np.argsort(-cat_score, axis=1, kind="stable")[:, :k]
            rows = np.arange(len(pj))[:, None]
            score[pj] = cat_score[rows, top]
            sid[pj] = cat_sid[rows, top]
    trie.topk_score = score
    trie.topk_sid = sid


# ---------------------------------------------------------------------------
# Compressed on-device layout (format v4, IndexSpec.compression="packed")
# ---------------------------------------------------------------------------


def _tier_u16(arr: np.ndarray) -> np.ndarray:
    """Narrowest dtype tier for a non-negative value table: u16 when every
    value fits, i32 otherwise (the device widens back to i32 in-register,
    so the choice is lossless either way)."""
    a = np.asarray(arr)
    if a.size == 0 or (int(a.min()) >= 0 and int(a.max()) <= 0xFFFF):
        return a.astype(np.uint16)
    return a.astype(np.int32)


def _csr_select(ptr: np.ndarray, rows: np.ndarray):
    """Compact the CSR rows ``rows`` (ascending) of a ``ptr``-indexed flat
    table: returns (new_ptr int32[len(rows)+1], take int64[...]) where
    ``take`` indexes the surviving entries of the flat arrays."""
    lo = ptr[rows].astype(np.int64)
    cnt = (ptr[rows + 1] - ptr[rows]).astype(np.int64)
    new_ptr = np.concatenate([[0], np.cumsum(cnt)]).astype(np.int32)
    total = int(cnt.sum())
    take = np.repeat(lo, cnt) + (
        np.arange(total, dtype=np.int64)
        - np.repeat(new_ptr[:-1].astype(np.int64), cnt))
    return new_ptr, take


def pack_compressed(trie: DictTrie) -> dict[str, str]:
    """Build the compressed on-device layout (persisted as format v4).

    Logical node ids are *unchanged* — loci, overflow counts and every
    downstream result stay bit-identical to the uncompressed layout.  The
    space comes from three sources:

    - **chain collapse**: dictionary nodes are created in DFS preorder,
      so a unary non-terminal node ``v`` has its single child at
      ``v + 1``, and its ``tout`` / ``max_score`` / emission list / top-K
      cache row are all equal to that child's (verified below, not
      assumed).  Per-node data is therefore stored only at *stored*
      nodes (fanout != 1, terminals, plus any verification stragglers);
      everything else derives from the next stored id — one binary
      search over ``c_ids``.  The only dense [N] arrays left are the u8
      ``p_labels`` / ``p_flags``.
    - **empty-plane elision**: teleports and link anchors become sparse
      id-keyed tables (``t_ids``/``t_plane``, ``la_ids``/``la_ptr``)
      that vanish for rule-free tries instead of dense [N]-row planes.
    - **narrow dtype tiers**: labels/flags/chars are u8; scores, string
      ids and the quantized top-K cache drop to u16 when every value
      fits (cache scores as ``base + enc - 1`` with a per-row i32 base,
      ``enc == 0`` meaning empty — lossless by the tier condition).

    Returns the ``{table: dtype}`` width map for the tier-variable tables
    (recorded as ``EngineConfig.table_widths`` so compiled entry points
    re-key when a rebuild lands in a different tier).  Requires
    ``pack_rule_planes`` (for ``link_ptr``).
    """
    assert trie.link_ptr is not None, \
        "pack_compressed requires pack_rule_planes to have run"
    n = trie.n_nodes
    ids = np.arange(n, dtype=np.int64)
    syn = trie.syn_mask
    leaf = trie.leaf_score >= 0
    d_cnt = np.diff(trie.first_child)
    s_cnt = np.diff(trie.s_first_child)

    # labels / flags: the two dense per-node arrays of the layout
    labels = trie.chr_.copy()
    labels[ROOT] = 0                  # no incoming edge; slot never read
    trie.p_labels = labels.astype(np.uint8)

    d_unary = d_cnt == 1
    if d_unary.any():
        first = trie.edge_child[trie.first_child[:-1][d_unary]]
        assert (first == ids[d_unary] + 1).all(), \
            "preorder invariant broken: unary dict child is not v+1"
    s_child0 = np.full(n, -1, np.int64)
    if len(trie.s_edge_child):
        has_s = s_cnt > 0
        s_child0[has_s] = trie.s_edge_child[trie.s_first_child[:-1][has_s]]
    s_unary = (s_cnt == 1) & (s_child0 == ids + 1)
    trie.p_flags = (
        d_unary.astype(np.uint8) * PACK_DICT_UNARY
        | s_unary.astype(np.uint8) * PACK_SYN_UNARY
        | syn.astype(np.uint8) * PACK_IS_SYN
        | leaf.astype(np.uint8) * PACK_HAS_LEAF)

    # stored (chain-representative) dict nodes.  An unstored node derives
    # every per-node value from the next stored id; the loop *verifies*
    # the chain-constancy invariants and promotes any node that breaks
    # them, so correctness never rests on the preorder argument alone.
    is_dict = ~syn
    stored = is_dict & (~d_unary | leaf)
    e_size = max(len(trie.emit_node), 1)
    while True:
        stored_ids = np.nonzero(stored)[0]
        u = ids[is_dict & ~stored]
        if len(u) == 0:
            break
        rep = stored_ids[np.searchsorted(stored_ids, u)]
        e0 = np.minimum(trie.emit_ptr[u].astype(np.int64), e_size - 1)
        ok = ((trie.tout[u] == trie.tout[rep])
              & (trie.max_score[u] == trie.max_score[rep])
              & ((trie.emit_ptr[u + 1] - trie.emit_ptr[u]) == 1)
              & (trie.emit_node[e0] == u + 1)
              & (trie.emit_score[e0] == trie.max_score[u])
              & ~trie.emit_is_leaf[e0])
        if trie.topk_score is not None:
            ok &= (trie.topk_score[u] == trie.topk_score[rep]).all(axis=1)
            ok &= (trie.topk_sid[u] == trie.topk_sid[rep]).all(axis=1)
        if ok.all():
            break
        stored[u[~ok]] = True

    c_ids = np.nonzero(stored)[0].astype(np.int64)
    trie.c_ids = c_ids.astype(np.int32)
    trie.c_tout = trie.tout[c_ids].astype(np.int32)
    trie.c_maxscore = _tier_u16(trie.max_score[c_ids])
    trie.c_eptr, take = _csr_select(trie.emit_ptr, c_ids)
    trie.c_enode = trie.emit_node[take].astype(np.int32)
    trie.c_escore = _tier_u16(trie.emit_score[take])
    trie.c_eleaf = trie.emit_is_leaf[take].astype(np.uint8)

    # dict branch rows (fanout >= 2) and non-unary syn rows as sparse CSRs
    b_ids = np.nonzero(d_cnt >= 2)[0]
    trie.b_ids = b_ids.astype(np.int32)
    trie.b_ptr, take = _csr_select(trie.first_child, b_ids)
    trie.b_char = trie.edge_char[take].astype(np.uint8)
    trie.b_child = trie.edge_child[take].astype(np.int32)
    sb_ids = np.nonzero((s_cnt >= 2) | ((s_cnt == 1) & ~s_unary))[0]
    trie.sb_ids = sb_ids.astype(np.int32)
    trie.sb_ptr, take = _csr_select(trie.s_first_child, sb_ids)
    trie.sb_char = trie.s_edge_char[take].astype(np.uint8)
    trie.sb_child = trie.s_edge_child[take].astype(np.int32)

    # terminal data: exact binary search over l_ids at query time
    l_ids = np.nonzero(leaf)[0]
    trie.l_ids = l_ids.astype(np.int32)
    trie.l_sid = _tier_u16(trie.leaf_sid[l_ids])

    # sparse teleport plane and link-anchor spans (empty-plane elision)
    t_ids = np.nonzero(np.diff(trie.syn_ptr) > 0)[0]
    trie.t_ids = t_ids.astype(np.int32)
    tw = max(trie.max_syn_targets, 1)
    t_ptr, take = _csr_select(trie.syn_ptr, t_ids)
    plane = np.full((len(t_ids), tw), -1, np.int32)
    if len(take):
        rows = np.repeat(np.arange(len(t_ids), dtype=np.int64),
                         np.diff(t_ptr))
        cols = np.arange(len(take), dtype=np.int64) - np.repeat(
            t_ptr[:-1].astype(np.int64), np.diff(t_ptr))
        plane[rows, cols] = trie.syn_tgt[take]
    trie.t_plane = plane
    la_ids = np.nonzero(np.diff(trie.link_ptr) > 0)[0]
    trie.la_ids = la_ids.astype(np.int32)
    trie.la_ptr = np.append(trie.link_ptr[la_ids],
                            trie.link_ptr[-1]).astype(np.int32)

    # quantized top-K cache: u16 (base + enc - 1, enc 0 = empty) when the
    # whole table fits the tier, raw i32 rows otherwise
    if trie.topk_score is not None:
        cs = trie.topk_score[c_ids]
        ci = trie.topk_sid[c_ids]
        real = cs >= 0
        row_min = np.where(real, cs, np.iinfo(np.int32).max).min(
            axis=1, initial=np.iinfo(np.int32).max)
        base = np.where(real.any(axis=1), row_min, 0).astype(np.int32)
        enc = np.where(real, cs.astype(np.int64) - base[:, None] + 1, 0)
        trie.pc_score = (enc.astype(np.uint16)
                         if enc.size == 0 or int(enc.max()) <= 0xFFFF
                         else cs.astype(np.int32))
        trie.pc_base = base
        enc_i = np.where(ci >= 0, ci.astype(np.int64) + 1, 0)
        trie.pc_sid = (enc_i.astype(np.uint16)
                       if enc_i.size == 0 or int(enc_i.max()) <= 0xFFFF
                       else ci.astype(np.int32))

    widths = {name: str(getattr(trie, name).dtype)
              for name in ("c_maxscore", "c_escore", "l_sid")}
    if trie.pc_score is not None:
        widths["pc_score"] = str(trie.pc_score.dtype)
        widths["pc_sid"] = str(trie.pc_sid.dtype)
    return widths
