"""Distributed completion index: dictionary sharded across the `model` axis.

Strings are hash-partitioned into shards; each shard is an independent
TT/ET/HT over its subset with the (small) rule set replicated.  A query
batch is sharded over the data axes and replicated over `model`; every
device answers from its local sub-trie and a single all_gather + fused
top-k merge produces the global answer.  This is how the paper's
1M-string dictionaries scale to billions of strings across pods.

The cross-shard merge routes through ``Substrate.topk_with_payload``
(:func:`merge_shard_topk`) — the same seam the per-shard phase 2 uses —
so on the pallas substrate the [S*k]-candidate reduction runs the fused
top-k selection kernel instead of a host-side concat-and-sort.  Two
execution paths share that merge:

- :func:`sharded_complete`: ``jax.shard_map`` over a device mesh (needs
  the modern sharding APIs; feature-gated by ``HAS_MODERN_SHARDING``);
- :meth:`ShardedCompletionIndex._complete_local`: a single-process path
  that answers every shard from the stacked trie and fuses the merge in
  one jitted dispatch — the serving shape for one host carrying many
  shards, and the path that keeps the sharded index fully exercised on
  jax builds without ``shard_map`` (construct with ``mesh=None``).
"""

from __future__ import annotations

import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.api import CompletionIndex, IndexSpec, build_index
from repro.api.compile_cache import CompileCache, bucket_size
from repro.core import engine as eng

# Feature detection: the manual-sharding APIs this module (and the mesh
# tests) rely on moved to the jax top level in newer releases.  Tests
# skip on the flag instead of CI hard-deselecting them.
from repro.distributed.sharding import missing_sharding_apis

_MISSING_SHARDING_APIS = missing_sharding_apis()
HAS_MODERN_SHARDING = not _MISSING_SHARDING_APIS
SHARDING_SKIP_REASON = (
    "container jax lacks " + ", ".join(_MISSING_SHARDING_APIS)
    + " (simulated-mesh paths need a newer jax)"
) if _MISSING_SHARDING_APIS else ""


class UnsupportedOnShardedIndex(NotImplementedError):
    """An operation that needs a local :class:`CompletionIndex` was called
    on a :class:`ShardedCompletionIndex` (or a service wrapping one).

    Raised instead of a bare ``NotImplementedError`` so callers can catch
    the *category* — per-keystroke sessions, mutation/compaction — and
    the message always names the local-mode alternative."""


def require_modern_sharding() -> None:
    """Raise a clear error (instead of an AttributeError mid-trace) when
    the running jax cannot execute the shard_map paths."""
    if not HAS_MODERN_SHARDING:
        raise RuntimeError(SHARDING_SKIP_REASON)


def shard_strings(strings, scores, n_shards: int):
    """Hash-partition (deterministic, seed-free) strings into shards."""
    import zlib

    buckets = [([], []) for _ in range(n_shards)]
    for s, r in zip(strings, scores):
        b = s.encode() if isinstance(s, str) else bytes(s)
        h = zlib.crc32(b) % n_shards
        buckets[h][0].append(s)
        buckets[h][1].append(r)
    return buckets


def _pad_to(a: np.ndarray, shape) -> np.ndarray:
    pad = [(0, t - s) for s, t in zip(a.shape, shape)]
    if a.dtype == bool:
        return np.pad(a, pad, constant_values=False)
    if a.ndim == 1 and a.shape[0] > 0:
        return np.pad(a, pad, mode="edge")
    # 2-D planes (tele_plane, r_term_plane, topk_*) use -1 = empty, so
    # width padding across shards must stay inert, not point at node 0
    return np.pad(a, pad, constant_values=-1)


def stack_shards(indexes: list[CompletionIndex]):
    """Stack per-shard DeviceTries into one pytree with a leading shard dim.

    CSR pointer arrays are padded by repeating the last pointer (empty rows),
    data arrays by edge padding (never addressed past the real pointers).
    Returns (stacked DeviceTrie of numpy arrays, merged EngineConfig, stride).
    """
    devs = [ix.device for ix in indexes]
    fields = eng.DeviceTrie._fields
    cfgs = [ix.cfg for ix in indexes]
    if any(getattr(c, "compression", "none") != "none" for c in cfgs):
        raise NotImplementedError(
            "stack_shards does not support the compressed (packed) layout: "
            "padding would break the sorted side-table rank invariants — "
            "build shards with compression='none'")
    # the merged stream-tile widths are maxima over the shards, so every
    # streamable flat table keeps one merged tile of tail slack past the
    # longest shard — a streamed-tier window anchored at any real row
    # start stays in bounds on the stacked layout too (the same maxima
    # become the merged EngineConfig widths below, so the two stay
    # consistent by construction)
    walk_tile = max(c.walk_tile for c in cfgs)
    emit_tile = max(c.emit_tile for c in cfgs)
    link_tile = max(c.link_tile for c in cfgs)
    tile_slack = {
        "edge_char": walk_tile, "edge_child": walk_tile,
        "s_edge_char": walk_tile, "s_edge_child": walk_tile,
        "emit_node": emit_tile, "emit_score": emit_tile,
        "emit_is_leaf": emit_tile,
        "link_rule": link_tile, "link_target": link_tile,
    }
    stacked = {}
    for f in fields:
        vals = [getattr(d, f) for d in devs]
        if any(v is None for v in vals):
            # elided packed-only planes (always None once compression is
            # rejected above) — keep them None in the stacked trie too
            stacked[f] = None
            continue
        arrs = [np.asarray(v) for v in vals]
        tgt = tuple(max(a.shape[i] for a in arrs) for i in range(arrs[0].ndim))
        tgt = tuple(max(t, 1) for t in tgt)
        if f in tile_slack and tgt[0] > 1:
            tgt = (tgt[0] + tile_slack[f],) + tgt[1:]
        arrs = [_pad_to(a if a.size else np.zeros(tuple(1 for _ in tgt), a.dtype), tgt)
                for a in arrs]
        stacked[f] = np.stack(arrs)
    merged = eng.EngineConfig(
        frontier=max(c.frontier for c in cfgs),
        gens=max(c.gens for c in cfgs),
        expand=max(c.expand for c in cfgs),
        max_steps=max(c.max_steps for c in cfgs),
        rule_matches=max(c.rule_matches for c in cfgs),
        max_lhs_len=max(c.max_lhs_len for c in cfgs),
        max_terms_per_node=max(c.max_terms_per_node for c in cfgs),
        teleports=max(c.teleports for c in cfgs),
        tele_width=max(c.tele_width for c in cfgs),
        term_width=max(c.term_width for c in cfgs),
        walk_tile=walk_tile, emit_tile=emit_tile, link_tile=link_tile,
        memory_budget=max(c.memory_budget for c in cfgs),
        use_cache=all(c.use_cache for c in cfgs),
        cache_k=min(c.cache_k for c in cfgs),
        substrate=cfgs[0].substrate,   # shards share one IndexSpec
    )
    stride = max(len(ix.strings) for ix in indexes)
    return eng.DeviceTrie(**stacked), merged, stride


def merge_shard_topk(all_scores: jax.Array, all_gsids: jax.Array, k: int,
                     sub: eng.Substrate):
    """Fuse per-shard answers [S, B, k] into the global (scores[B, k],
    gsids[B, k]) with one substrate-routed top-k-with-payload.

    The candidate relayout is a device-side transpose+reshape feeding the
    substrate's selection (the fused ``topk_select`` kernel on pallas);
    score ties resolve toward the lower shard index then the lower
    per-shard rank — the same deterministic order on every substrate, so
    the shard_map and single-process paths agree bitwise."""
    S, B = all_scores.shape[0], all_scores.shape[1]
    flat_s = jnp.moveaxis(all_scores, 0, 1).reshape(B, S * k)
    flat_i = jnp.moveaxis(all_gsids, 0, 1).reshape(B, S * k)
    return sub.topk_with_payload(flat_s, flat_i, k)


def sharded_complete(stacked: eng.DeviceTrie, cfg: eng.EngineConfig,
                     qs: jax.Array, qlens: jax.Array, k: int, *,
                     mesh: jax.sharding.Mesh, sid_stride: int,
                     data_axes=("data",), model_axis: str = "model"):
    """Global top-k under shard_map: local per-shard top-k, then one
    all_gather over the model axis and the fused substrate merge.

    stacked: DeviceTrie with leading shard dim == mesh size along model axis.
    qs: int32[B, L] global batch; qlens int32[B].
    Returns (scores[B, k], global_sids[B, k]).
    """
    require_modern_sharding()
    sub = eng.get_substrate(cfg.substrate)
    trie_spec = jax.tree.map(lambda _: P(model_axis), stacked,
                             is_leaf=lambda x: not isinstance(x, tuple))
    q_spec = P(data_axes)

    @partial(jax.shard_map, mesh=mesh,
             in_specs=(trie_spec, q_spec, q_spec),
             out_specs=(P(data_axes), P(data_axes)),
             check_vma=False)
    def run(trie, qs_l, qlens_l):
        local = jax.tree.map(lambda x: x[0], trie)  # drop unit shard dim
        scores, sids, _ = eng.complete_batch(local, cfg, qs_l, qlens_l, k,
                                             sub)
        shard = jax.lax.axis_index(model_axis)
        gsids = jnp.where(sids >= 0, sids + shard * sid_stride, -1)
        # merge across shards: all_gather to [S, b, k], then the fused
        # substrate top-k — still on-device, replicated over model
        all_scores = jax.lax.all_gather(scores, model_axis)   # [S, b, k]
        all_sids = jax.lax.all_gather(gsids, model_axis)
        return merge_shard_topk(all_scores, all_sids, k, sub)

    return run(stacked, qs, qlens)


class ShardedCompletionIndex:
    """Host-facing wrapper: build shards, stack, serve over a mesh.

    Shards share one :class:`IndexSpec`; ``save``/``load`` persist every
    shard's npz container so a serving process restarts without rebuilding
    any sub-trie.
    """

    def __init__(self, strings, scores, rules, *, mesh=None, n_shards=None,
                 kind=None, model_axis="model", data_axes=("data",),
                 spec=None, **build_kwargs):
        if spec is None:
            spec = IndexSpec(kind=kind or "et", **build_kwargs)
        elif kind is not None or build_kwargs:
            raise TypeError("pass either spec= or IndexSpec kwargs, not both")
        spec.validate_sharded()   # before any shard is built, not after
        if mesh is not None:
            n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[model_axis]
        elif n_shards is None:
            raise TypeError(
                "pass mesh= (device-sharded serving) or n_shards= "
                "(single-process local mode)")
        buckets = shard_strings(strings, scores, n_shards)
        shards = [
            build_index(b[0] if b[0] else [""], b[1] if b[1] else [1],
                        rules, spec=spec)
            for b in buckets
        ]
        self._init_from_shards(shards, mesh=mesh, model_axis=model_axis,
                               data_axes=data_axes, spec=spec)

    def _init_from_shards(self, shards, *, mesh, model_axis, data_axes,
                          spec):
        self.mesh = mesh
        self.model_axis = model_axis
        self.data_axes = data_axes
        # fail unsupported-on-sharded spec combinations (packed layout)
        # here, with the workaround in the message, instead of deep in
        # stack_shards — every construction path funnels through this
        self.spec = spec.validate_sharded()
        self.shards = shards
        stacked, self.cfg, self.stride = stack_shards(self.shards)
        if mesh is not None:
            sharding = NamedSharding(mesh, P(model_axis))
            put = lambda x: jax.device_put(x, sharding)
        else:
            put = jnp.asarray  # local mode: whole stacked trie on one device
        self.device_tries = jax.tree.map(
            put, stacked, is_leaf=lambda x: isinstance(x, np.ndarray))
        self._local_cache = CompileCache(maxsize=16)

    @classmethod
    def from_shards(cls, shards, *, mesh=None, model_axis="model",
                    data_axes=("data",), spec=None):
        """Wrap already-built per-shard indexes (skips construction)."""
        self = cls.__new__(cls)
        self._init_from_shards(shards, mesh=mesh, model_axis=model_axis,
                               data_axes=data_axes,
                               spec=spec or shards[0].spec)
        return self

    # -- persistence -------------------------------------------------------

    def save(self, path: str) -> None:
        """Write a directory: meta.json + one npz container per shard."""
        os.makedirs(path, exist_ok=True)
        for i, shard in enumerate(self.shards):
            shard.save(os.path.join(path, f"shard_{i:04d}.npz"))
        meta = {"format_version": 1, "n_shards": len(self.shards),
                "spec": self.spec.to_dict()}
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump(meta, f)

    @classmethod
    def load(cls, path: str, *, mesh=None, model_axis="model",
             data_axes=("data",)) -> "ShardedCompletionIndex":
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        n_shards = meta["n_shards"]
        if mesh is not None:
            mesh_shards = dict(zip(mesh.axis_names,
                                   mesh.devices.shape))[model_axis]
            if n_shards != mesh_shards:
                raise ValueError(
                    f"saved index has {n_shards} shards but mesh axis "
                    f"{model_axis!r} has {mesh_shards} devices")
        shards = [CompletionIndex.load(os.path.join(path, f"shard_{i:04d}.npz"))
                  for i in range(n_shards)]
        return cls.from_shards(shards, mesh=mesh, model_axis=model_axis,
                               data_axes=data_axes,
                               spec=IndexSpec.from_dict(meta["spec"]))

    def lookup_string(self, gsid: int) -> str:
        shard, sid = divmod(int(gsid), self.stride)
        return self.shards[shard].strings[sid].decode("utf-8", errors="replace")

    def _local_fn(self, B: int, L: int, k: int):
        """Jitted single-process answer: loop the static shard count over
        the stacked trie, rebase sids to global ids, fuse the merge — one
        dispatch per (bucketed) batch shape, LRU-cached."""
        key = (B, L, k, self.cfg)

        def factory():
            cfg, stride, S = self.cfg, self.stride, len(self.shards)
            sub = eng.get_substrate(cfg.substrate)

            def run(trie, qs, qlens):
                per_s, per_i = [], []
                for s in range(S):
                    local = jax.tree.map(lambda x: x[s], trie)
                    scores, sids, _ = eng.complete_batch(
                        local, cfg, qs, qlens, k, sub)
                    per_s.append(scores)
                    per_i.append(jnp.where(sids >= 0, sids + s * stride, -1))
                return merge_shard_topk(
                    jnp.stack(per_s), jnp.stack(per_i), k, sub)

            return jax.jit(run)

        return self._local_cache.get(key, factory)

    def _complete_local(self, qs: np.ndarray, qlens: np.ndarray, k: int,
                        n_real: int):
        """Answer a padded query batch without a mesh (see module docstring);
        batch is bucketed up to a power of two so shapes re-hit the cache."""
        B = bucket_size(n_real)
        qs_p = np.zeros((B, qs.shape[1]), np.int32)
        qlens_p = np.zeros((B,), np.int32)
        qs_p[:n_real], qlens_p[:n_real] = qs, qlens
        fn = self._local_fn(B, qs.shape[1], k)
        scores, gsids = fn(self.device_tries, jnp.asarray(qs_p),
                           jnp.asarray(qlens_p))
        return scores[:n_real], gsids[:n_real]

    def complete(self, queries, k: int = 10):
        from repro.core.alphabet import pad_queries

        max_len = max((len(q) for q in queries), default=1)
        L = max(8, 1 << (max_len - 1).bit_length())
        qs, qlens = pad_queries(queries, L)
        if self.mesh is not None and HAS_MODERN_SHARDING:
            scores, gsids = sharded_complete(
                self.device_tries, self.cfg, jnp.asarray(qs),
                jnp.asarray(qlens), k, mesh=self.mesh,
                sid_stride=self.stride, data_axes=self.data_axes,
                model_axis=self.model_axis)
        else:
            scores, gsids = self._complete_local(
                np.asarray(qs), np.asarray(qlens), k, len(queries))
        scores, gsids = np.asarray(scores), np.asarray(gsids)
        out = []
        for b in range(len(queries)):
            row = [(int(s), self.lookup_string(g))
                   for s, g in zip(scores[b], gsids[b]) if s >= 0 and g >= 0]
            out.append(row)
        return out

    def session(self, k: int = 10):
        raise UnsupportedOnShardedIndex(
            "ShardedCompletionIndex has no per-keystroke session: a "
            "resumable locus frontier would have to live on every shard "
            "and merge per keystroke — use complete() for batch lookups, "
            "or a local CompletionIndex for incremental typing")

    def open_session(self, k: int = 10):
        raise UnsupportedOnShardedIndex(
            "ShardedCompletionIndex has no per-keystroke session: a "
            "resumable locus frontier would have to live on every shard "
            "and merge per keystroke — use complete() for batch lookups, "
            "or a local CompletionIndex for incremental typing")
