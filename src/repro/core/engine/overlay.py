"""Delta overlay: mutation-without-rebuild for a completion index.

The base index is immutable (its device tables, caches and packed planes
are all derived from the full sorted dictionary), so online
``insert``/``delete``/``update_score`` land in a :class:`DeltaOverlay`
instead:

- ``added`` maps overlay strings to scores — brand-new strings *and*
  re-scored base strings (a re-score tombstones the base entry and
  carries the new score here, so the base tables never lie);
- ``tombstones`` holds base strings masked out of query results
  (deletions and the base half of every re-score).

At query time the index answers from **base top-(k + D)** (D bounds the
tombstones a result row can lose) plus **overlay top-k** — the overlay is
itself a small index built through the normal pipeline, so synonym rules
apply to mutated entries identically — and fuses the two candidate sets
with :func:`merge_overlay_topk` through the substrate's
``topk_with_payload`` seam.  The fused kernels never see the overlay.

**Global ranks.** Merged results must be bit-identical to a from-scratch
rebuild, including score-tie order (the oracle contract: score desc,
string asc — and sids are lexicographic ranks because the dictionary is
stored sorted).  ``refresh`` therefore assigns every live string its
*global rank*: the sid it would have in the rebuilt index.  Candidates
enter the merge sorted by grank, so the substrate's
ties-toward-lower-index selection reproduces the rebuilt tie order, and
the returned "sids" are already rebuilt-index sids (they decode against
``live`` rather than the base string list).

``refresh`` is O(N + overlay) on the host and runs once per mutation
batch (results are reused until the next mutation, spec change or
epoch); folding the overlay away entirely is ``CompletionIndex.compact``.
"""

from __future__ import annotations

import bisect

import jax
import numpy as np

from repro.core.engine.structs import INT_MAX


def merge_overlay_topk(scores: jax.Array, granks: jax.Array, k: int, sub):
    """Select the global top-k from base+overlay candidate rows.

    scores int32[B, C] / granks int32[B, C]; invalid slots carry score -1
    and grank INT_MAX.  Rows are pre-sorted ascending by grank so the
    substrate's ``topk_with_payload`` — which breaks score ties toward
    the lower candidate index — lands ties on the lexicographically
    smaller string, i.e. the rebuilt index's order.  Returns
    (scores[B, k], granks[B, k]).
    """
    granks_sorted, scores_sorted = jax.lax.sort((granks, scores),
                                                num_keys=1)
    return sub.topk_with_payload(scores_sorted, granks_sorted, k)


class DeltaOverlay:
    """Mutable side-state over an immutable base index (see module doc).

    Mutation entry points take the base's sorted string list explicitly —
    the overlay never holds a reference to its index, so a compaction can
    simply drop it.
    """

    def __init__(self):
        self.added: dict[bytes, int] = {}
        self.tombstones: set[bytes] = set()
        self.mutations = 0            # monotonic; dirties compiled state
        # refresh() products (None / stale until the token matches)
        self._token = None
        self.index = None             # side-index over `added`, or None
        self.live: list[bytes] = []   # sorted live strings == rebuilt dict
        self.base_dead = None         # bool[N]: base sid is tombstoned
        self.base_grank = None        # int32[N]: base sid -> global rank
        self.ov_grank = None          # int32[max(Nov,1)]: overlay sid -> rank

    # -- membership --------------------------------------------------------

    @property
    def active(self) -> bool:
        return bool(self.added or self.tombstones)

    @staticmethod
    def _base_sid(base_strings: list[bytes], s: bytes) -> int:
        i = bisect.bisect_left(base_strings, s)
        if i < len(base_strings) and base_strings[i] == s:
            return i
        return -1

    def is_live(self, base_strings: list[bytes], s: bytes) -> bool:
        return s in self.added or (
            s not in self.tombstones
            and self._base_sid(base_strings, s) >= 0)

    # -- mutations ---------------------------------------------------------

    def upsert(self, base_strings: list[bytes], s: bytes,
               score: int) -> None:
        """Insert or re-score: a base entry is tombstoned and re-carried
        here, a pure-overlay entry just changes score."""
        if self._base_sid(base_strings, s) >= 0:
            self.tombstones.add(s)
        self.added[s] = score
        self._touch()

    def remove(self, base_strings: list[bytes], s: bytes) -> None:
        """Delete a live string; raises KeyError when it is not live."""
        in_overlay = s in self.added
        in_base = self._base_sid(base_strings, s) >= 0
        if not in_overlay and (not in_base or s in self.tombstones):
            raise KeyError(f"{s!r} is not in the index")
        if in_overlay:
            del self.added[s]
        if in_base:
            self.tombstones.add(s)
        self._touch()

    def _touch(self) -> None:
        self.mutations += 1

    # -- compiled-state refresh --------------------------------------------

    def refresh(self, base) -> None:
        """(Re)build the side-index and rank tables for the current
        mutation set against ``base`` (a CompletionIndex).  Idempotent
        until the next mutation / spec change / epoch."""
        token = (self.mutations, base.spec, base.epoch)
        if token == self._token:
            return
        base_strings = base.strings
        n = len(base_strings)
        dead = np.zeros(max(n, 1), dtype=bool)
        for s in self.tombstones:
            sid = self._base_sid(base_strings, s)
            if sid >= 0:
                dead[sid] = True
        ov_strings = sorted(self.added)
        live = sorted(
            {s for i, s in enumerate(base_strings) if not dead[i]}
            | self.added.keys())
        rank = {s: i for i, s in enumerate(live)}
        base_grank = np.full(max(n, 1), INT_MAX, dtype=np.int32)
        for i, s in enumerate(base_strings):
            if not dead[i]:
                base_grank[i] = rank[s]
        ov_grank = np.asarray(
            [rank[s] for s in ov_strings] or [int(INT_MAX)],
            dtype=np.int32)
        if ov_strings:
            # build through the normal pipeline so synonym rules apply to
            # mutated entries too; the packed layout buys nothing on a
            # dictionary this small, so the side-index stays full-width
            from repro.api.build import build_index
            self.index = build_index(
                ov_strings, [self.added[s] for s in ov_strings],
                base.rules, base.spec.replace(compression="none"))
        else:
            self.index = None
        self.live = live
        self.base_dead = dead
        self.base_grank = base_grank
        self.ov_grank = ov_grank
        self._token = token
