"""Phase 2b — cached top-k (beyond-paper, cf. Li et al. [9]).

Gather the materialized per-node top-K lists of the locus antichain and
merge.  O(1) lookups, no while_loop; exact for k <= K.

The gather+merge is the substrate seam's batched hot primitive
(``Substrate.cached_topk_batch``): the jnp reference below flattens and
runs lax.top_k; the Pallas substrate fuses gather and k-round selection in
one kernel (:mod:`repro.kernels.locus_merge`).  Both orders candidates
loci-major/K-minor, so ties resolve identically.

When k outgrows the materialized K — and on the widened exactness-retry
rounds, which disable the cache outright — phase 2 drops to the beam
(``Substrate.beam_topk_batch``), which the pallas substrate likewise
serves with a fused kernel (:mod:`repro.kernels.beam_topk`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import packed as pk
from repro.core.engine.structs import DeviceTrie, EngineConfig, NEG_ONE


def gather_cached(t: DeviceTrie, loci: jax.Array):
    """Flatten the per-node top-K lists of a locus row/batch.

    loci int32[..., F] -> (scores[..., F*K], sids[..., F*K]), -1 where the
    locus slot is empty, loci-major/K-minor candidate order.
    """
    if pk.is_packed(t):
        return pk.gather_cached(t, loci)
    valid = loci >= 0
    n = jnp.where(valid, loci, 0)
    sc = jnp.where(valid[..., None], t.topk_score[n], NEG_ONE)
    si = jnp.where(valid[..., None], t.topk_sid[n], NEG_ONE)
    flat = loci.shape[:-1] + (-1,)
    return sc.reshape(flat), si.reshape(flat)


def cached_topk(t: DeviceTrie, cfg: EngineConfig, loci: jax.Array, k: int):
    """Single-row reference merge: loci [F] -> (scores[k], sids[k], exact)."""
    assert cfg.use_cache and k <= cfg.cache_k, "cache disabled or k too large"
    flat_s, flat_i = gather_cached(t, loci)
    top_s, idx = jax.lax.top_k(flat_s, k)
    return top_s, flat_i[idx], jnp.bool_(True)
