"""Shape-static primitives shared by every engine phase.

These are the reference (pure-jnp) implementations of the operations the
:class:`~repro.core.engine.substrate.Substrate` protocol exposes as its
overridable seam: vectorized CSR binary search / child lookup and the
dedup-compaction that keeps locus frontiers canonical.  Substrates default
to these; a kernel-backed substrate overrides the batched entry points it
has tuned code for.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.engine.structs import INT_MAX, NEG_ONE


def resolve_sub(cfg, sub):
    """Substrate threading helper: explicit ``sub`` wins, else the registry
    entry named by ``cfg.substrate`` (late import: the registry module
    imports this one)."""
    if sub is not None:
        return sub
    from repro.core.engine.substrate import get_substrate
    return get_substrate(cfg.substrate)


def iters_for(n: int) -> int:
    return max(1, int(math.ceil(math.log2(max(n, 1) + 1))))


def lower_bound(arr: jax.Array, lo, hi, x, iters: int):
    """First index in [lo, hi) with arr[idx] >= x (vectorized, fixed iters)."""
    size = max(int(arr.shape[0]), 1)
    for _ in range(iters):
        cont = lo < hi
        mid = (lo + hi) >> 1
        v = arr[jnp.clip(mid, 0, size - 1)]
        go_right = v < x
        lo = jnp.where(cont & go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
    return lo


def csr_child_lookup(ptr, chars, children, nodes, ch, iters: int):
    """children[nodes] labelled ch via binary search in each CSR row; -1 if
    absent. nodes may contain -1 entries (propagated)."""
    if int(chars.shape[0]) == 0:
        return jnp.full(jnp.broadcast_shapes(nodes.shape, jnp.shape(ch)),
                        NEG_ONE, jnp.int32)
    valid = nodes >= 0
    n = jnp.where(valid, nodes, 0)
    lo = ptr[n]
    hi = ptr[n + 1]
    pos = lower_bound(chars, lo, hi, ch, iters)
    size = max(int(chars.shape[0]), 1)
    found = (pos < hi) & (chars[jnp.clip(pos, 0, size - 1)] == ch) & valid & (ch >= 0)
    return jnp.where(found, children[jnp.clip(pos, 0, size - 1)], NEG_ONE)


def dedup_pad(vec: jax.Array, width: int):
    """Unique ids of vec (-1 = empty), first `width` kept (ascending id order).

    Returns (out[width] int32 with -1 pad, n_dropped int32).

    §Perf iteration: one sort + O(n) scatter compaction (rank = running
    count of kept) instead of the original sort-mask-sort — on TPU the
    second bitonic sort was the locus DP's hottest op."""
    big = jnp.where(vec < 0, INT_MAX, vec)
    s = jnp.sort(big)
    idx = jnp.arange(s.shape[0], dtype=jnp.int32)
    keep = (idx == 0) | (s != jnp.roll(s, 1))
    keep &= s != INT_MAX
    rank = jnp.cumsum(keep) - 1                       # position among kept
    n_uniq = (rank[-1] + 1).astype(jnp.int32)
    dst = jnp.where(keep & (rank < width), rank, width)  # width = drop slot
    out = jnp.full((width + 1,), NEG_ONE, jnp.int32)
    out = out.at[dst].set(s, mode="drop")
    out = jnp.where(out == INT_MAX, NEG_ONE, out)[:width]
    dropped = jnp.maximum(n_uniq - width, 0)
    return out, dropped
