"""Engine data structures: the device-resident trie and the static config.

:class:`DeviceTrie` is the array encoding of a built TT/ET/HT/plain index
(one NamedTuple of jax arrays, a valid pytree for jit/vmap/shard_map).
:class:`EngineConfig` holds every static shape parameter — it is frozen and
hashable so it can join jit/compile-cache keys, and it names the execution
``substrate`` (see :mod:`repro.core.engine.substrate`) that the entry
points dispatch through.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import numpy as np

INT_MAX = np.int32(2**31 - 1)
NEG_ONE = np.int32(-1)


class DeviceTrie(NamedTuple):
    # dict-trie node arrays
    depth: jax.Array        # int32[N]
    max_score: jax.Array    # int32[N]
    leaf_score: jax.Array   # int32[N]
    leaf_sid: jax.Array     # int32[N]
    syn_mask: jax.Array     # bool[N]
    tout: jax.Array         # int32[N]
    # dict child CSR
    first_child: jax.Array  # int32[N+1]
    edge_char: jax.Array    # int32[E]
    edge_child: jax.Array   # int32[E]
    # synonym child CSR
    s_first_child: jax.Array
    s_edge_char: jax.Array
    s_edge_child: jax.Array
    # emissions
    emit_ptr: jax.Array
    emit_node: jax.Array
    emit_score: jax.Array
    emit_is_leaf: jax.Array
    # -- packed rule plane (built by trie_build.pack_rule_planes) --------
    # teleports: dense per-node target plane, -1 padded
    tele_plane: jax.Array   # int32[N, tele_width]
    # link store: per-anchor CSR over rule-sorted rows
    link_ptr: jax.Array     # int32[N+1]
    link_rule: jax.Array    # int32[Lk]
    link_target: jax.Array  # int32[Lk]
    # rule trie (CSR children + dense term plane)
    r_first_child: jax.Array
    r_edge_char: jax.Array
    r_edge_child: jax.Array
    r_term_plane: jax.Array  # int32[Nr, term_width], -1 padded
    r_rule_len: jax.Array
    # materialized per-node top-K (dummy (1,1) when disabled)
    topk_score: jax.Array
    topk_sid: jax.Array
    # -- compressed layout (trie_build.pack_compressed) ------------------
    # (0,)-shaped dummies when compression="none"; when packed, the dense
    # per-node arrays above become the dummies instead and every engine
    # accessor routes through these sparse, narrow-dtype side tables
    # (repro.core.engine.packed).  Ids stay logical, results bit-identical.
    p_labels: jax.Array = None      # u8[N]
    p_flags: jax.Array = None       # u8[N]
    c_ids: jax.Array = None         # i32[C]
    c_tout: jax.Array = None        # i32[C]
    c_maxscore: jax.Array = None    # u16/i32[C]
    c_eptr: jax.Array = None        # i32[C+1]
    c_enode: jax.Array = None       # i32[Me]
    c_escore: jax.Array = None      # u16/i32[Me]
    c_eleaf: jax.Array = None       # u8[Me]
    b_ids: jax.Array = None         # i32[B]
    b_ptr: jax.Array = None         # i32[B+1]
    b_char: jax.Array = None        # u8[Eb]
    b_child: jax.Array = None       # i32[Eb]
    sb_ids: jax.Array = None        # i32[Sb]
    sb_ptr: jax.Array = None        # i32[Sb+1]
    sb_char: jax.Array = None       # u8[Esb]
    sb_child: jax.Array = None      # i32[Esb]
    l_ids: jax.Array = None         # i32[S]
    l_sid: jax.Array = None         # u16/i32[S]
    t_ids: jax.Array = None         # i32[Tn]
    t_plane: jax.Array = None       # i32[Tn, tele_width]
    la_ids: jax.Array = None        # i32[La]
    la_ptr: jax.Array = None        # i32[La+1]
    pc_score: jax.Array = None      # u16/i32[C, K]
    pc_base: jax.Array = None       # i32[C]
    pc_sid: jax.Array = None        # u16/i32[C, K]


@dataclass(frozen=True)
class EngineConfig:
    """Static engine shape parameters (hashable; part of the jit key)."""

    frontier: int = 32          # F: locus DP width
    gens: int = 48              # W: generator pool width (beam phase)
    expand: int = 8             # P: emissions popped per beam step
    max_steps: int = 256        # beam step cap
    rule_matches: int = 0       # M: max lhs matches per query position
    max_lhs_len: int = 0        # rule-trie walk depth
    # bounded-edit mode: a frontier entry is a packed state
    # ``node * (edit_budget + 1) + edits_used`` and the sweep gains
    # substitute / insert / delete transitions on the dictionary side.
    # 0 = exact matching (the packing degenerates to plain node ids and
    # the edit transitions trace away, so results are bit-identical to
    # the pre-edit engine).  Static: part of every compile-cache key.
    edit_budget: int = 0        # E: max edits spent rewriting the query
    # static upper bound on a dict-CSR row length (max node fanout),
    # recorded at build/load time; sizes the substitute/delete child
    # windows of the bounded-edit sweep.  <= walk_tile by construction.
    branch_width: int = 1
    max_terms_per_node: int = 1
    teleports: int = 0          # Ts: max teleport targets per node
    # static widths of the packed rule plane (tele_plane / r_term_plane
    # column counts; always >= 1, validated against the arrays at
    # build/load time — see api.build.validate_rule_planes)
    tele_width: int = 1
    term_width: int = 1
    # static stream-tile widths of the tile-aligned table layout
    # (trie_build.pack_stream_tiles): the DMA-streamed kernel tier slices
    # fixed-width windows [start, start+tile) off the flat CSR / emission
    # tables, so each tile must cover the longest row and the builder pads
    # the flat arrays to a tile multiple.  Validated against the arrays at
    # build/load time — see api.build.validate_rule_planes.
    walk_tile: int = 8          # dict + synonym child-CSR window
    emit_tile: int = 8          # emission-list window
    link_tile: int = 8          # link-store (per-anchor) window
    # VMEM byte budget for table residency: tables at or under the budget
    # run the VMEM-resident kernels, larger ones stream from HBM via the
    # DMA tier (0 = substrate default; see PallasSubstrate)
    memory_budget: int = 0
    use_cache: bool = False     # phase-2 via materialized top-K
    cache_k: int = 0
    substrate: str = "jnp"      # execution substrate ("jnp" | "pallas")
    # compressed on-device layout (trie_build.pack_compressed): "none"
    # keeps the uniform-i32 tables; "packed" routes every accessor
    # through the chain-collapsed sparse side tables.  table_widths
    # records the tier-variable dtypes as a sorted (name, dtype) tuple —
    # hashable, and part of every compile-cache key so a rebuild landing
    # in a different tier re-traces instead of reusing a stale entry.
    compression: str = "none"
    table_widths: tuple = ()
