"""Phase 1 — locus DP: a fixed-width frontier sweep over query positions.

reach[pos] = set of trie nodes reachable by consuming p[:pos] under some
rewriting.  Transitions: literal char step (dict + synonym-branch
children), synonym teleports (ET/HT expanded rules), and rule steps
through the link store (TT/HT unexpanded rules).  All fixed shapes; the
rule-side lookups read the packed rule plane
(:func:`repro.core.trie_build.pack_rule_planes`): dense ``tele_plane``
rows for teleports, ``link_ptr`` + one binary search for link steps, and
``r_term_plane`` rows for full-lhs matches.

Bounded-edit mode (``cfg.edit_budget`` = E > 0) generalizes the frontier:
each entry becomes the packed state ``node * (E + 1) + edits_used`` and
the sweep gains three extra transition families on the dictionary side —
*substitute* (consume a query char into any non-matching dict child at
d+1), *insert* (consume a query char staying put at d+1) and *delete*
(take any dict child without consuming a query char, applied as an
E-round closure when a position's row completes).  Synonym-branch chars
and rule lhs occurrences must still be typed exactly; teleports and rule
steps carry the edit count through unchanged.  At E = 0 the packing and
every edit transition degenerate to the exact pre-edit computation, so
results (including overflow counts) are bit-identical.

Every inner CSR lookup / dedup-compaction routes through the active
:class:`~repro.core.engine.substrate.Substrate` (threaded as ``sub``), so
kernel-backed substrates can replace the primitives without touching the
DP structure.  Substrates may also replace this whole sweep at batch
granularity (``Substrate.walk_batch``) — the Pallas trie-walk kernel
handles the rule-free prefix case and the fused locus-DP kernel
(:mod:`repro.kernels.locus_dp`) the rule-bearing tt/et/ht case, both
bit-identical to this reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import packed as pk
from repro.core.engine.primitives import (dedup_pad, iters_for, lower_bound,
                                          resolve_sub)
from repro.core.engine.structs import DeviceTrie, EngineConfig, NEG_ONE


def match_table(t: DeviceTrie, cfg: EngineConfig, q: jax.Array, sub=None):
    """All full-lhs rule matches per query position.

    Returns (rule[L, M], end[L, M]) with -1 padding; end = pos + len(lhs).
    """
    sub = resolve_sub(cfg, sub)
    L = q.shape[0]
    M = cfg.rule_matches
    if M == 0:
        z = jnp.full((L, 1), NEG_ONE, jnp.int32)
        return z, z
    iters = iters_for(int(t.r_edge_char.shape[0]))
    qx = jnp.concatenate([q, jnp.full((cfg.max_lhs_len,), NEG_ONE, jnp.int32)])

    def at_pos(i):
        rules = jnp.full((M,), NEG_ONE, jnp.int32)
        ends = jnp.full((M,), NEG_ONE, jnp.int32)
        node = jnp.int32(0)
        cnt = jnp.int32(0)
        for j in range(cfg.max_lhs_len):
            c = jax.lax.dynamic_index_in_dim(qx, i + j, keepdims=False)
            node = sub.csr_child_lookup(
                t.r_first_child, t.r_edge_char, t.r_edge_child,
                node[None], c[None], iters)[0]
            ok = node >= 0
            nn = jnp.where(ok, node, 0)
            terms = t.r_term_plane[nn]          # [term_width], -1 padded
            for j2 in range(cfg.max_terms_per_node):
                rid = terms[j2]
                has = ok & (rid >= 0) & (cnt < M)
                slot = jnp.clip(cnt, 0, M - 1)
                rules = jnp.where(has, rules.at[slot].set(rid), rules)
                ends = jnp.where(has, ends.at[slot].set(i + j + 1), ends)
                cnt = jnp.where(has, cnt + 1, cnt)
        return rules, ends

    return jax.vmap(at_pos)(jnp.arange(L, dtype=jnp.int32))


def encode_states(nodes: jax.Array, d, E: int) -> jax.Array:
    """Pack (node, edits-used d) into one frontier entry: node*(E+1)+d.
    Identity at E=0, so exact-mode traces are untouched; -1 stays -1."""
    if E == 0:
        return nodes
    return jnp.where(nodes < 0, NEG_ONE, nodes * (E + 1) + d)


def decode_states(states: jax.Array, E: int):
    """Inverse of :func:`encode_states`: (nodes, d); -1 -> (-1, 0)."""
    if E == 0:
        return states, jnp.zeros_like(states)
    nodes = jnp.where(states < 0, NEG_ONE, states // (E + 1))
    d = jnp.where(states < 0, 0, states % (E + 1))
    return nodes, d


def dict_child_window(t: DeviceTrie, cfg: EngineConfig, nodes: jax.Array):
    """All dict children of each node: (chars, children) [..., BW] with
    BW = cfg.branch_width (static max dict fanout), -1 padded.  Feeds the
    substitute/delete edit transitions, which need *every* child rather
    than the one matching a char."""
    if pk.is_packed(t):
        return pk.dict_child_window(t, nodes, cfg.branch_width)
    BW = cfg.branch_width
    shape = tuple(nodes.shape) + (BW,)
    if int(t.edge_char.shape[0]) == 0:
        z = jnp.full(shape, NEG_ONE, jnp.int32)
        return z, z
    valid = nodes >= 0
    n = jnp.where(valid, nodes, 0)
    lo = t.first_child[n]
    cnt = jnp.where(valid, t.first_child[n + 1] - lo, 0)
    js = jnp.arange(BW, dtype=jnp.int32)
    idx = jnp.clip(lo[..., None] + js, 0, int(t.edge_char.shape[0]) - 1)
    m = js < cnt[..., None]
    chars = jnp.where(m, t.edge_char[idx], NEG_ONE)
    children = jnp.where(m, t.edge_child[idx], NEG_ONE)
    return chars, children


def teleport_expand(t: DeviceTrie, cfg: EngineConfig, row: jax.Array,
                    sub=None):
    """row [F] -> row plus teleport targets, dedup'd back to [F].  In
    bounded-edit mode the row carries packed states: targets inherit the
    source state's edit count."""
    if cfg.teleports == 0:
        return row, jnp.int32(0)
    sub = resolve_sub(cfg, sub)
    F = row.shape[0]
    E = cfg.edit_budget
    nodes, d = decode_states(row, E)
    if pk.is_packed(t):
        tgt = pk.tele_rows(t, nodes)
    else:
        valid = nodes >= 0
        n = jnp.where(valid, nodes, 0)
        tgt = jnp.where(valid[:, None], t.tele_plane[n], NEG_ONE)
    tgt = encode_states(tgt, d[:, None], E)
    merged = jnp.concatenate([row, tgt.reshape(-1)])
    return sub.dedup_compact(merged, F)


def delete_close(t: DeviceTrie, cfg: EngineConfig, row: jax.Array,
                 sub=None):
    """Bounded-edit delete closure: E rounds of "take any dict child at
    d+1 without consuming a query char" over a frontier row.  E static
    rounds reach the fixpoint because each round raises d and d < E gates
    the step.  No-op (0 drops) at E=0."""
    E = cfg.edit_budget
    if E == 0:
        return row, jnp.int32(0)
    sub = resolve_sub(cfg, sub)
    F = row.shape[0]
    drop_total = jnp.int32(0)
    for _ in range(E):
        nodes, d = decode_states(row, E)
        _, children = dict_child_window(t, cfg, nodes)
        ok = (children >= 0) & (d < E)[:, None]
        tgt = encode_states(jnp.where(ok, children, NEG_ONE),
                            (d + 1)[:, None], E)
        row, drop = sub.dedup_compact(
            jnp.concatenate([row, tgt.reshape(-1)]), F)
        drop_total += drop
    return row, drop_total


def expand_frontier(t: DeviceTrie, cfg: EngineConfig, row: jax.Array,
                    sub=None):
    """Teleport expansion then delete closure — the combined fixpoint a
    row needs once all its position's contributions have landed.
    Teleports attach only to synonym nodes and delete steps only descend
    dict children (which never carry teleports), so one expansion
    followed by E delete rounds reaches the joint fixpoint."""
    sub = resolve_sub(cfg, sub)
    row, drop = teleport_expand(t, cfg, row, sub)
    row, drop2 = delete_close(t, cfg, row, sub)
    return row, drop + drop2


def link_lookup(t: DeviceTrie, anchors: jax.Array, rid: jax.Array):
    """Link-store search: (anchor, rule) -> target or -1. anchors [F].

    The packed ``link_ptr`` CSR bounds each anchor's (rule-sorted) row
    range with one pointer load, so the whole lookup is a single binary
    search over ``link_rule`` instead of the pre-relayout three."""
    if pk.is_packed(t):
        return pk.link_lookup(t, anchors, rid)
    n_link = int(t.link_rule.shape[0])
    if n_link == 0:
        return jnp.full(anchors.shape, NEG_ONE, jnp.int32)
    iters = iters_for(n_link)
    valid = anchors >= 0
    a = jnp.where(valid, anchors, 0)
    lo = t.link_ptr[a]
    hi = t.link_ptr[a + 1]
    pos = lower_bound(t.link_rule, lo, hi, rid, iters)
    found = (pos < hi) & (t.link_rule[jnp.clip(pos, 0, n_link - 1)] == rid) & valid
    return jnp.where(found, t.link_target[jnp.clip(pos, 0, n_link - 1)], NEG_ONE)


def finalize_loci(t: DeviceTrie, row: jax.Array) -> jax.Array:
    """Turn a (teleport-expanded) frontier row into the final locus antichain:
    drop mid-variant synonym nodes, dedup, and remove covered descendants."""
    F = row.shape[0]
    packed = pk.is_packed(t)
    # strict semantics: drop mid-variant (synonym) loci
    n0 = jnp.where(row >= 0, row, 0)
    is_syn = pk.syn_mask_of(t, n0) if packed else t.syn_mask[n0]
    row = jnp.where((row >= 0) & ~is_syn, row, NEG_ONE)
    row, _ = dedup_pad(row, F)
    # antichain reduction via preorder intervals: drop descendants
    tin = jnp.where(row >= 0, row, NEG_ONE)
    n0 = jnp.where(row >= 0, row, 0)
    to = pk.tout_of(t, n0) if packed else t.tout[n0]
    covered = (
        (tin[None, :] <= tin[:, None]) & (tin[:, None] < to[None, :])
        & (jnp.arange(F)[None, :] != jnp.arange(F)[:, None])
        & (row[None, :] >= 0) & (row[:, None] >= 0)
    ).any(axis=1)
    # ties: identical ids already removed by dedup; strict ancestor covers
    return jnp.where(covered, NEG_ONE, row)


def locus_dp(t: DeviceTrie, cfg: EngineConfig, q: jax.Array, qlen: jax.Array,
             sub=None):
    """Locus set after consuming the whole query under all rewritings.

    q: int32[L] (-1 padded), qlen: int32 scalar.
    Returns (loci[F] dict-node ids, -1 padded; overflow count int32).
    """
    sub = resolve_sub(cfg, sub)
    L = int(q.shape[0])
    F = cfg.frontier
    E = cfg.edit_budget
    packed = pk.is_packed(t)
    if packed:
        has_syn_edges = pk.has_syn_edges(t)
        d_iters = s_iters = 0
    else:
        d_iters = iters_for(int(t.edge_char.shape[0]))
        s_iters = iters_for(int(t.s_edge_char.shape[0]))
        has_syn_edges = int(t.s_edge_child.shape[0]) > 0
    M = cfg.rule_matches

    mrule, mend = match_table(t, cfg, q, sub)

    # write-back sweep: each completed row is expanded (teleports + delete
    # closure) exactly once, as the last write of the step that completes
    # it, so step i reads buf[i] ready-made.  Equivalent (content and
    # overflow) to expanding at read time: every write into row i+1 —
    # char/edit parts of step i, rule steps from positions <= i — has
    # landed by the end of step i, and re-expanding an expanded row
    # changes nothing and drops nothing.
    buf = jnp.full((L + 1, F), NEG_ONE, jnp.int32)
    buf = buf.at[0, 0].set(0)   # root at d=0 encodes to 0 for any E
    row0, drop0 = expand_frontier(t, cfg, buf[0], sub)
    buf = buf.at[0].set(row0)
    overflow = drop0

    def step(i, carry):
        buf, overflow = carry
        row = jax.lax.dynamic_slice(buf, (i, 0), (1, F))[0]
        c = jax.lax.dynamic_index_in_dim(q, i, keepdims=False)
        nodes, d = decode_states(row, E)

        # literal char step: dict children + synonym-branch children
        if packed:
            nd = pk.dict_children(t, nodes, c)
        else:
            nd = sub.csr_child_lookup(t.first_child, t.edge_char,
                                      t.edge_child, nodes, c, d_iters)
        parts = [encode_states(nd, d, E)]
        if has_syn_edges:
            if packed:
                ns = pk.syn_children(t, nodes, c)
            else:
                ns = sub.csr_child_lookup(t.s_first_child, t.s_edge_char,
                                          t.s_edge_child, nodes, c, s_iters)
            parts.append(encode_states(ns, d, E))
        if E > 0:
            # substitute: any dict child whose edge char differs from c,
            # at d+1 (matching children already ride the literal part)
            wchars, wchildren = dict_child_window(t, cfg, nodes)
            can = (c >= 0) & (d < E)
            s_ok = can[:, None] & (wchildren >= 0) & (wchars != c)
            parts.append(encode_states(
                jnp.where(s_ok, wchildren, NEG_ONE),
                (d + 1)[:, None], E).reshape(-1))
            # insert: the query has an extra char; stay put at d+1.
            # Synonym-branch chars must be typed exactly, so mid-variant
            # nodes don't absorb inserted chars
            n0 = jnp.where(nodes >= 0, nodes, 0)
            is_syn = pk.syn_mask_of(t, n0) if packed else t.syn_mask[n0]
            i_ok = can & (nodes >= 0) & ~is_syn
            parts.append(encode_states(
                jnp.where(i_ok, nodes, NEG_ONE), d + 1, E))
        nxt_row = jax.lax.dynamic_slice(buf, (i + 1, 0), (1, F))[0]
        merged, drop = sub.dedup_compact(jnp.concatenate([nxt_row] + parts), F)
        overflow += drop
        buf = jax.lax.dynamic_update_slice(buf, merged[None], (i + 1, 0))

        # rule steps through the link store (anchors must be dict nodes;
        # the lhs is typed exactly and the edit count carries through)
        if M > 0:
            anchor_ok = nodes >= 0
            ar = jnp.where(anchor_ok, nodes, 0)
            anchor_ok &= ~(pk.syn_mask_of(t, ar) if packed else t.syn_mask[ar])
            anchors = jnp.where(anchor_ok, nodes, NEG_ONE)
            for m in range(M):
                rid = mrule[i, m]
                end = mend[i, m]
                tgt = link_lookup(t, anchors, rid)
                tgt = jnp.where((rid >= 0), tgt, NEG_ONE)
                tgt = encode_states(tgt, d, E)
                j = jnp.clip(jnp.where(end >= 0, end, 0), 0, L)
                dst = jax.lax.dynamic_slice(buf, (j, 0), (1, F))[0]
                merged, drop = sub.dedup_compact(jnp.concatenate([dst, tgt]), F)
                any_tgt = jnp.any(tgt >= 0)
                merged = jnp.where(any_tgt, merged, dst)
                overflow += jnp.where(any_tgt, drop, 0)
                buf = jax.lax.dynamic_update_slice(buf, merged[None], (j, 0))

        # write-back: row i+1 is complete (rule ends are > i), expand it
        nxt = jax.lax.dynamic_slice(buf, (i + 1, 0), (1, F))[0]
        nxt, drop = expand_frontier(t, cfg, nxt, sub)
        overflow += drop
        buf = jax.lax.dynamic_update_slice(buf, nxt[None], (i + 1, 0))
        return buf, overflow

    buf, overflow = jax.lax.fori_loop(0, L, step, (buf, overflow))

    row = jax.lax.dynamic_slice(buf, (jnp.clip(qlen, 0, L), 0), (1, F))[0]
    return finalize_loci(t, decode_states(row, E)[0]), overflow
