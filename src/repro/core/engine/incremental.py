"""Phase 1' — incremental locus DP (stateful per-keystroke sessions).

One keystroke extends the carried frontier by a single char-step instead
of re-running the full locus DP over the prefix.  All inner lookups and
compactions thread through the active substrate, so a session opened on a
``pallas``-substrate index runs its per-keystroke top-k through the same
kernels as the one-shot batch path.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import packed as pk
from repro.core.engine.locus import (decode_states, dict_child_window,
                                     encode_states, expand_frontier,
                                     finalize_loci, link_lookup)
from repro.core.engine.primitives import iters_for, resolve_sub
from repro.core.engine.structs import DeviceTrie, EngineConfig, NEG_ONE


class LocusState(NamedTuple):
    """Resumable locus-DP state after consuming some prefix.

    rows[0] is the teleport-expanded frontier for the full prefix; rows[j]
    (j < max_lhs_len) is the frontier j keystrokes ago.  The history window
    is required because a synonym rule whose lhs ends at the newest char
    anchors at the frontier of the position where the lhs *started*.
    rnodes[j] is the rule-trie node for the walk over the last j+1 chars
    (-1 once the walk dies), so full-lhs matches ending at the newest char
    are recognised without rescanning the prefix.
    """

    rows: jax.Array      # int32[H, F] expanded frontier rows, newest first
    rnodes: jax.Array    # int32[H]   rule-trie suffix walks, shortest first
    overflow: jax.Array  # int32      accumulated frontier drops (0 => exact)
    length: jax.Array    # int32      chars consumed


def init_locus_state(t: DeviceTrie, cfg: EngineConfig, sub=None) -> LocusState:
    """State for the empty prefix (locus = expanded root)."""
    sub = resolve_sub(cfg, sub)
    F = cfg.frontier
    H = max(cfg.max_lhs_len, 1)
    row = jnp.full((F,), NEG_ONE, jnp.int32).at[0].set(0)
    row, drop = expand_frontier(t, cfg, row, sub)
    rows = jnp.full((H, F), NEG_ONE, jnp.int32).at[0].set(row)
    return LocusState(rows=rows,
                      rnodes=jnp.full((H,), NEG_ONE, jnp.int32),
                      overflow=jnp.int32(0) + drop,
                      length=jnp.int32(0))


def advance_locus_state(t: DeviceTrie, cfg: EngineConfig, state: LocusState,
                        c, sub=None) -> LocusState:
    """One keystroke: extend the frontier by char ``c`` (no-op when c < 0).

    Equivalent to one step of ``locus_dp`` — literal dict/synonym-branch
    children of the current frontier, plus link-store steps for every rule
    whose lhs ends exactly at the new char — but reuses the carried frontier
    instead of rescanning the prefix.
    """
    sub = resolve_sub(cfg, sub)
    F = cfg.frontier
    E = cfg.edit_budget
    H = state.rows.shape[0]
    c = jnp.asarray(c, jnp.int32)
    row = state.rows[0]
    nodes, d = decode_states(row, E)

    packed = pk.is_packed(t)
    if packed:
        parts = [encode_states(pk.dict_children(t, nodes, c), d, E)]
        if pk.has_syn_edges(t):
            parts.append(encode_states(pk.syn_children(t, nodes, c), d, E))
    else:
        d_iters = iters_for(int(t.edge_char.shape[0]))
        parts = [encode_states(
            sub.csr_child_lookup(t.first_child, t.edge_char,
                                 t.edge_child, nodes, c, d_iters), d, E)]
        if int(t.s_edge_child.shape[0]) > 0:
            s_iters = iters_for(int(t.s_edge_char.shape[0]))
            parts.append(encode_states(
                sub.csr_child_lookup(t.s_first_child, t.s_edge_char,
                                     t.s_edge_child, nodes, c, s_iters),
                d, E))
    if E > 0:
        # bounded-edit keystroke transitions (mirror locus_dp's step):
        # substitute into any non-matching dict child / insert in place,
        # both at d+1; delete closure rides expand_frontier below
        wchars, wchildren = dict_child_window(t, cfg, nodes)
        can = (c >= 0) & (d < E)
        s_ok = can[:, None] & (wchildren >= 0) & (wchars != c)
        parts.append(encode_states(
            jnp.where(s_ok, wchildren, NEG_ONE),
            (d + 1)[:, None], E).reshape(-1))
        n0 = jnp.where(nodes >= 0, nodes, 0)
        is_syn = pk.syn_mask_of(t, n0) if packed else t.syn_mask[n0]
        i_ok = can & (nodes >= 0) & ~is_syn
        parts.append(encode_states(
            jnp.where(i_ok, nodes, NEG_ONE), d + 1, E))

    rnodes = state.rnodes
    if cfg.rule_matches > 0 and cfg.max_lhs_len > 0:
        r_iters = iters_for(int(t.r_edge_char.shape[0]))
        starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                  state.rnodes[:-1]])
        rnodes = sub.csr_child_lookup(t.r_first_child, t.r_edge_char,
                                      t.r_edge_child, starts, c, r_iters)
        for j in range(H):
            node = rnodes[j]
            ok = node >= 0
            nn = jnp.where(ok, node, 0)
            terms = t.r_term_plane[nn]          # [term_width], -1 padded
            # lhs of length j+1 anchors at the frontier j keystrokes back
            anchor_row = state.rows[j]
            a_nodes, a_d = decode_states(anchor_row, E)
            anchor_ok = a_nodes >= 0
            an = jnp.where(anchor_ok, a_nodes, 0)
            anchor_ok &= ~(pk.syn_mask_of(t, an) if packed
                           else t.syn_mask[an])
            anchors = jnp.where(anchor_ok, a_nodes, NEG_ONE)
            for j2 in range(cfg.max_terms_per_node):
                rid = terms[j2]
                has = ok & (rid >= 0)
                tgt = link_lookup(t, anchors, rid)
                parts.append(encode_states(
                    jnp.where(has, tgt, NEG_ONE), a_d, E))

    merged, d1 = sub.dedup_compact(jnp.concatenate(parts), F)
    merged, d2 = expand_frontier(t, cfg, merged, sub)
    new_rows = jnp.concatenate([merged[None], state.rows[:-1]], axis=0)
    ok = c >= 0
    return LocusState(
        rows=jnp.where(ok, new_rows, state.rows),
        rnodes=jnp.where(ok, rnodes, state.rnodes),
        overflow=state.overflow + jnp.where(ok, d1 + d2, 0),
        length=state.length + jnp.where(ok, 1, 0),
    )


def advance_loci(t: DeviceTrie, cfg: EngineConfig, state: LocusState,
                 chars: jax.Array, sub=None) -> LocusState:
    """Extend the state by a fixed-shape char vector (-1 entries ignored)."""
    sub = resolve_sub(cfg, sub)

    def step(s, c):
        return advance_locus_state(t, cfg, s, c, sub), None

    state, _ = jax.lax.scan(step, state, jnp.asarray(chars, jnp.int32))
    return state


def init_locus_batch(t: DeviceTrie, cfg: EngineConfig, batch: int,
                     sub=None) -> LocusState:
    """Stacked state [batch, ...] of ``batch`` empty-prefix sessions.

    The continuous-batching scheduler's *slab*: every lane starts at the
    expanded root, bit-identical to ``init_locus_state`` per lane."""
    state = init_locus_state(t, cfg, sub)
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (batch,) + x.shape), state)


def advance_loci_batch(t: DeviceTrie, cfg: EngineConfig, states: LocusState,
                       chars: jax.Array, sub=None) -> LocusState:
    """One keystroke per lane across a stacked state batch.

    ``states`` is a LocusState whose leaves carry a leading batch dim;
    ``chars`` is int32[B].  Lanes with ``chars < 0`` are untouched (the
    single-state no-op contract of :func:`advance_locus_state`), so a
    partially filled micro-batch block advances only its live lanes in
    one dispatch.  Per-lane results are bit-identical to the sequential
    :func:`advance_locus_state` — lanes never interact (pure vmap)."""
    sub = resolve_sub(cfg, sub)
    return jax.vmap(
        lambda s, c: advance_locus_state(t, cfg, s, c, sub))(
        states, jnp.asarray(chars, jnp.int32))


def topk_from_loci(t: DeviceTrie, cfg: EngineConfig, state: LocusState,
                   k: int, sub=None):
    """Top-k for the prefix carried by ``state`` (scores, sids, exact)."""
    from repro.core.engine.substrate import topk_phase2

    sub = resolve_sub(cfg, sub)
    loci = finalize_loci(t, decode_states(state.rows[0], cfg.edit_budget)[0])
    scores, sids, exact = topk_phase2(t, cfg, loci, k, sub)
    return scores, sids, exact & (state.overflow == 0)


def topk_from_loci_batch(t: DeviceTrie, cfg: EngineConfig,
                         states: LocusState, k: int, sub=None):
    """Top-k for every lane of a stacked state batch in one dispatch:
    (scores[B, k], sids[B, k], exact[B]).

    Phase 2 goes through the substrate's natively batched path
    (``beam_topk_batch`` / ``cached_topk_batch``) — the same kernels the
    one-shot ``complete_batch`` uses — so a coalesced micro-batch of
    keystrokes pays one kernel launch instead of B."""
    from repro.core.engine.substrate import topk_phase2_batch

    sub = resolve_sub(cfg, sub)
    loci = jax.vmap(lambda row: finalize_loci(
        t, decode_states(row, cfg.edit_budget)[0]))(states.rows[:, 0])
    scores, sids, exact = topk_phase2_batch(t, cfg, loci, k, sub)
    return scores, sids, exact & (states.overflow == 0)
