"""Accessors for the compressed (packed) on-device layout.

The packed layout (:func:`repro.core.trie_build.pack_compressed`) keeps
logical node ids unchanged and replaces the dense per-node arrays with u8
labels/flags plus sparse side tables keyed by sorted node id.  Each
accessor here mirrors one uncompressed engine read bit-for-bit:

- child lookup: a unary node's single child is ``v + 1`` (DFS preorder),
  read straight off the flag + label; branching rows binary-search
  ``b_ids``/``sb_ids`` and then the row, exactly like
  ``primitives.csr_child_lookup`` over the dense CSR;
- per-node data (``tout``, ``max_score``, emission lists, cache rows) of
  an unstored node equals its chain representative's — the first stored
  id at or after it, one ``lower_bound`` over ``c_ids``;
- narrow (u8/u16) values widen to i32 in-register at the read, so every
  comparison and merge downstream sees the same i32 values as the
  uncompressed path.

The jnp engine branches to these functions whenever :func:`is_packed`
holds; the Pallas kernels implement the same forms behind their
table-accessor seams (``kernels/locus_dp.py`` / ``kernels/beam_topk.py``).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine.primitives import iters_for, lower_bound
from repro.core.engine.structs import NEG_ONE

# p_flags bits (mirror trie_build.PACK_*; plain ints for kernel tracing)
DICT_UNARY = 1
SYN_UNARY = 2
IS_SYN = 4
HAS_LEAF = 8


def is_packed(t) -> bool:
    """True when the DeviceTrie carries the packed layout (the dense
    arrays are the dummies then, not the side tables).  Duck-typed so
    probe fakes that predate the packed fields read as unpacked."""
    labels = getattr(t, "p_labels", None)
    return labels is not None and int(labels.shape[0]) > 0


def has_syn_edges(t) -> bool:
    """Static synonym-branch probe for packed tries: synonym nodes exist
    iff teleports do (every expanded branch ends in one) or a non-unary
    syn row was stored."""
    return int(t.t_ids.shape[0]) > 0 or int(t.sb_child.shape[0]) > 0


def _rank(ids_arr, nodes):
    """Position of each node in a sorted id table: (clipped_rank, exact)."""
    size = int(ids_arr.shape[0])
    if size == 0:
        return jnp.zeros_like(nodes), jnp.zeros(nodes.shape, bool)
    pos = lower_bound(ids_arr, jnp.zeros_like(nodes),
                      jnp.full_like(nodes, size), nodes, iters_for(size))
    rc = jnp.clip(pos, 0, size - 1)
    return rc, (pos < size) & (ids_arr[rc] == nodes)


def _children(t, ids_arr, ptr, chars, children, unary_bit, nodes, ch):
    """Shared unary-flag + sparse-row child lookup (dict and syn forms).
    Semantics identical to ``csr_child_lookup`` over the dense CSR."""
    n_nodes = int(t.p_labels.shape[0])
    valid = nodes >= 0
    n = jnp.where(valid, nodes, 0)
    fl = t.p_flags[n].astype(jnp.int32)
    lbl = t.p_labels[jnp.clip(n + 1, 0, n_nodes - 1)].astype(jnp.int32)
    ok_u = ((fl & unary_bit) != 0) & (lbl == ch) & valid & (ch >= 0)
    u_child = jnp.where(ok_u, n + 1, NEG_ONE)
    if int(ids_arr.shape[0]) == 0:
        return u_child
    rc, isrow = _rank(ids_arr, n)
    lo = ptr[rc]
    hi = jnp.where(isrow, ptr[rc + 1], lo)
    e_size = max(int(chars.shape[0]), 1)
    pos = lower_bound(chars, lo, hi, ch, iters_for(int(chars.shape[0])))
    posc = jnp.clip(pos, 0, e_size - 1)
    found = (pos < hi) & (chars[posc].astype(jnp.int32) == ch) \
        & valid & (ch >= 0)
    row_child = jnp.where(found, children[posc], NEG_ONE)
    return jnp.where(isrow, row_child, u_child)


def dict_children(t, nodes, ch):
    return _children(t, t.b_ids, t.b_ptr, t.b_char, t.b_child,
                     DICT_UNARY, nodes, ch)


def syn_children(t, nodes, ch):
    return _children(t, t.sb_ids, t.sb_ptr, t.sb_char, t.sb_child,
                     SYN_UNARY, nodes, ch)


def dict_child_window(t, nodes, width: int):
    """All dict children of each node: (chars, children) [..., width],
    -1 padded — the packed mirror of the dense ``first_child`` row window
    feeding the bounded-edit substitute/delete transitions.  A unary
    node's window is its single (label, v+1) pair in column 0; branching
    nodes read their sparse ``b_*`` row."""
    n_nodes = int(t.p_labels.shape[0])
    valid = nodes >= 0
    n = jnp.where(valid, nodes, 0)
    fl = t.p_flags[n].astype(jnp.int32)
    lbl = t.p_labels[jnp.clip(n + 1, 0, n_nodes - 1)].astype(jnp.int32)
    js = jnp.arange(width, dtype=jnp.int32)
    u_ok = (((fl & DICT_UNARY) != 0) & valid)[..., None] & (js == 0)
    chars = jnp.where(u_ok, lbl[..., None], NEG_ONE)
    children = jnp.where(u_ok, (n + 1)[..., None], NEG_ONE)
    if int(t.b_ids.shape[0]) == 0:
        return chars, children
    rc, isrow = _rank(t.b_ids, n)
    lo = t.b_ptr[rc].astype(jnp.int32)
    cnt = jnp.where(isrow & valid,
                    t.b_ptr[rc + 1].astype(jnp.int32) - lo, 0)
    idx = jnp.clip(lo[..., None] + js, 0, int(t.b_char.shape[0]) - 1)
    m = js < cnt[..., None]
    chars = jnp.where(m, t.b_char[idx].astype(jnp.int32), chars)
    children = jnp.where(m, t.b_child[idx].astype(jnp.int32), children)
    return chars, children


def tele_rows(t, nodes):
    """Teleport-target rows [..., tele_width]; all -1 for nodes without
    teleports (== the dense ``tele_plane`` gather, rows masked by the
    caller's validity the same way)."""
    tw = int(t.t_plane.shape[1])
    valid = nodes >= 0
    n = jnp.where(valid, nodes, 0)
    if int(t.t_ids.shape[0]) == 0:
        return jnp.full(tuple(nodes.shape) + (tw,), NEG_ONE, jnp.int32)
    rc, exact = _rank(t.t_ids, n)
    return jnp.where((exact & valid)[..., None], t.t_plane[rc], NEG_ONE)


def syn_mask_of(t, nodes):
    """bool syn mask gather (callers pre-clamp nodes to >= 0)."""
    return (t.p_flags[nodes] & IS_SYN) != 0


def tout_of(t, nodes):
    """Preorder subtree end (callers pre-clamp nodes to >= 0).  Synonym
    nodes are their own chains (tout == v + 1); dict nodes read their
    chain representative's stored value."""
    fl = t.p_flags[nodes].astype(jnp.int32)
    rc, _ = _rank(t.c_ids, nodes)
    return jnp.where((fl & IS_SYN) != 0, nodes + 1, t.c_tout[rc])


def link_lookup(t, anchors, rid):
    """(anchor, rule) -> target or -1 via the sparse anchor spans
    (``la_ids``/``la_ptr``), same search as the dense ``link_ptr`` form."""
    n_link = int(t.link_rule.shape[0])
    if n_link == 0 or int(t.la_ids.shape[0]) == 0:
        return jnp.full(anchors.shape, NEG_ONE, jnp.int32)
    valid = anchors >= 0
    a = jnp.where(valid, anchors, 0)
    rc, isrow = _rank(t.la_ids, a)
    lo = t.la_ptr[rc]
    hi = jnp.where(isrow, t.la_ptr[rc + 1], lo)
    pos = lower_bound(t.link_rule, lo, hi, rid, iters_for(n_link))
    posc = jnp.clip(pos, 0, n_link - 1)
    found = (pos < hi) & (t.link_rule[posc] == rid) & valid
    return jnp.where(found, t.link_target[posc], NEG_ONE)


# ---------------------------------------------------------------------------
# beam-phase emission accessors
# ---------------------------------------------------------------------------


def emit_bound(t, nodes, cursors):
    """Admissible bound of each generator's current emission.  Stored
    nodes read their compacted emission row; an unstored (unary
    non-terminal dict) node's list is exactly ``[(v+1, max_score, False)]``
    so cursor 0 yields the representative's ``max_score`` and anything
    past it is exhausted."""
    valid = nodes >= 0
    n = jnp.where(valid, nodes, 0)
    rc, stored = _rank(t.c_ids, n)
    e = t.c_eptr[rc] + cursors
    e_size = max(int(t.c_enode.shape[0]), 1)
    ok_s = stored & (e < t.c_eptr[rc + 1])
    sc_s = t.c_escore[jnp.clip(e, 0, e_size - 1)].astype(jnp.int32)
    fl = t.p_flags[n].astype(jnp.int32)
    derived = ~stored & ((fl & IS_SYN) == 0) & (cursors == 0)
    ms = t.c_maxscore[rc].astype(jnp.int32)
    bound = jnp.where(ok_s, sc_s, jnp.where(derived, ms, NEG_ONE))
    return jnp.where(valid, bound, NEG_ONE)


def pop_emissions(t, nodes, cursors):
    """(node, score, is_leaf) of each generator's current emission
    (callers mask invalid lanes; a popped lane's cursor is in-row)."""
    rc, stored = _rank(t.c_ids, nodes)
    e_size = max(int(t.c_enode.shape[0]), 1)
    e = jnp.clip(t.c_eptr[rc] + cursors, 0, e_size - 1)
    ms = t.c_maxscore[rc].astype(jnp.int32)
    node = jnp.where(stored, t.c_enode[e], nodes + 1)
    score = jnp.where(stored, t.c_escore[e].astype(jnp.int32), ms)
    leaf = jnp.where(stored, t.c_eleaf[e] != 0, False)
    return node, score, leaf


def leaf_sid_of(t, nodes):
    """String id of terminal nodes via exact search over ``l_ids``
    (callers only use lanes where the node is a real leaf)."""
    size = max(int(t.l_ids.shape[0]), 1)
    rc, _ = _rank(t.l_ids, nodes)
    return t.l_sid[jnp.clip(rc, 0, size - 1)].astype(jnp.int32)


# ---------------------------------------------------------------------------
# cached-phase accessors
# ---------------------------------------------------------------------------


def gather_cached(t, loci):
    """Packed mirror of ``cached.gather_cached``: decode the quantized
    per-representative cache rows back to raw i32 scores/sids."""
    valid = loci >= 0
    n = jnp.where(valid, loci, 0)
    rc, _ = _rank(t.c_ids, n)
    sc = decode_cache_scores(t.pc_score[rc], t.pc_base[rc])
    si = decode_cache_sids(t.pc_sid[rc])
    sc = jnp.where(valid[..., None], sc, NEG_ONE)
    si = jnp.where(valid[..., None], si, NEG_ONE)
    flat = loci.shape[:-1] + (-1,)
    return sc.reshape(flat), si.reshape(flat)


def decode_cache_scores(enc, base):
    """u16 rows hold ``score - base + 1`` (0 = empty slot); i32 rows are
    raw.  The dtype is the scheme marker — ``EngineConfig.table_widths``
    keys compiled entry points on it."""
    if enc.dtype == jnp.uint16:
        e = enc.astype(jnp.int32)
        return jnp.where(e == 0, NEG_ONE, base[..., None] + e - 1)
    return enc.astype(jnp.int32)


def decode_cache_sids(enc):
    if enc.dtype == jnp.uint16:
        e = enc.astype(jnp.int32)
        return jnp.where(e == 0, NEG_ONE, e - 1)
    return enc.astype(jnp.int32)
