"""Pluggable execution substrates + the public completion entry points.

A :class:`Substrate` bundles the engine's hot primitives behind one seam:

  - ``csr_child_lookup`` / ``dedup_compact`` — the inner locus-DP ops
    (threaded through every frontier step);
  - ``walk_batch``       — phase 1 at batch granularity, with a
    ``can_walk_batch`` capability probe naming which (trie, config)
    shapes the substrate handles natively;
  - ``topk_with_payload`` — batched small-k selection with payload;
  - ``cached_topk_batch`` — the cached-top-K locus gather+merge;
  - ``beam_topk_batch``   — phase 2a at batch granularity, with a
    ``can_beam_batch`` capability probe naming which (trie, config, k)
    shapes the substrate handles natively.

The base class *is* the reference implementation (pure jnp, registered as
``"jnp"``).  :class:`PallasSubstrate` (``"pallas"``) routes the batched
walk through :func:`repro.kernels.ops.trie_walk` (rule-free tries) or the
fused synonym-aware locus-DP kernel :func:`repro.kernels.ops.locus_walk`
(tt/et/ht), beam phase 2 through the fused generator-pool priority-search
kernel :func:`repro.kernels.ops.beam_topk`, cached merges through
:func:`repro.kernels.ops.topk_select` / ``cached_topk_merge``, and runs
in interpret mode off-TPU.  ``EngineConfig.substrate`` names the
substrate, so it rides every jit/compile-cache key;
``resolve_substrate("auto")`` picks ``pallas`` on TPU and ``jnp``
elsewhere (interpret-mode pallas is opt-in, not a default, off-TPU).

With the fused beam kernel every hot phase — walk, beam, cached merge —
is substrate-pluggable.  Each fused kernel additionally runs in one of
two *tiers*: VMEM-resident tables, or the DMA-streamed tier for tries
whose tables outgrow the VMEM budget (``EngineConfig.memory_budget``) —
the ``walk_variant``/``beam_variant`` probes pick resident vs streamed
vs jnp-fallback per call.  Remaining kernel work (dedup-compaction)
lands as an additive substrate method override, not an engine rewrite.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.engine import beam, cached, locus, packed as pk, primitives
from repro.core.engine.structs import DeviceTrie, EngineConfig, NEG_ONE


class Substrate:
    """Reference (pure-jnp) execution substrate; the protocol other
    substrates subclass.  Stateless — one shared instance per registry
    entry."""

    name = "jnp"

    # -- locus-DP inner primitives ----------------------------------------

    def csr_child_lookup(self, ptr, chars, children, nodes, ch, iters: int):
        return primitives.csr_child_lookup(ptr, chars, children, nodes, ch,
                                           iters)

    def dedup_compact(self, vec: jax.Array, width: int):
        return primitives.dedup_pad(vec, width)

    # -- phase 1: batched locus walk --------------------------------------

    def can_walk_batch(self, t: DeviceTrie, cfg: EngineConfig,
                       seq_len: int) -> bool:
        """Capability probe: True when ``walk_batch`` has a native
        (non-fallback) path for this (trie, config, query length).  The
        jnp reference DP handles everything."""
        return True

    def walk_batch(self, t: DeviceTrie, cfg: EngineConfig, qs: jax.Array,
                   qlens: jax.Array):
        """qs int32[B, L] (-1 padded), qlens int32[B] ->
        (loci[B, F], overflow[B])."""
        return jax.vmap(
            lambda q, ql: locus.locus_dp(t, cfg, q, ql, self))(qs, qlens)

    # -- phase 2: top-k ----------------------------------------------------

    def topk_with_payload(self, scores: jax.Array, payload: jax.Array,
                          k: int):
        """scores/payload int32[B, C] -> (top_s[B, k], top_p[B, k]),
        score-descending, ties toward the lower candidate index."""
        top_s, idx = jax.lax.top_k(scores, k)
        return top_s, jnp.take_along_axis(payload, idx, axis=1)

    def cached_topk_batch(self, t: DeviceTrie, cfg: EngineConfig,
                          loci: jax.Array, k: int):
        """Cached-top-K gather+merge: loci int32[B, F] ->
        (scores[B, k], sids[B, k], exact[B])."""
        assert cfg.use_cache and k <= cfg.cache_k, \
            "cache disabled or k too large"
        flat_s, flat_i = cached.gather_cached(t, loci)
        s, p = self.topk_with_payload(flat_s, flat_i, k)
        return s, p, jnp.ones(loci.shape[:-1], bool)

    def can_beam_batch(self, t: DeviceTrie, cfg: EngineConfig,
                       k: int) -> bool:
        """Capability probe: True when ``beam_topk_batch`` has a native
        (non-fallback) path for this (trie, config, k).  The vmapped jnp
        reference handles everything."""
        return True

    def beam_topk_batch(self, t: DeviceTrie, cfg: EngineConfig,
                        loci: jax.Array, k: int):
        """Beam phase 2 over a locus batch: (scores[B,k], sids[B,k],
        exact[B])."""
        return jax.vmap(lambda l: beam.beam_topk(t, cfg, l, k))(loci)


class PallasSubstrate(Substrate):
    """Kernel-backed substrate: dispatches the batched hot primitives to
    :mod:`repro.kernels` (compiled on TPU, interpret mode elsewhere).

    Phase 1 has two kernel paths: rule-free tries take the single-node
    longest-prefix walk (``trie_walk``), rule-bearing tt/et/ht tries take
    the fused synonym-aware locus DP (``locus_walk``) whenever the static
    shapes fit the kernel (``can_walk_batch``); anything else falls back
    to the inherited jnp DP, which is bit-identical by contract.  The
    DP's *inner* lookups/compactions are likewise inherited — they only
    run on the fallback path, where a pallas_call cannot be tiled.

    Phase 2a (beam) takes the fused generator-pool priority-search kernel
    (``beam_topk``) whenever (W, P, k, max_steps) fit the
    ``can_beam_batch`` envelope; outside it — including the later rounds
    of the host-side doubled-width exactness retry, whose widths grow 4x
    per round — the inherited vmapped reference answers with identical
    results.

    Each kernel runs in one of two *tiers* chosen by the VMEM byte
    budget (``cfg.memory_budget``, default ``_DEFAULT_VMEM_BUDGET``):
    tables at or under the budget stay whole in VMEM (*resident*);
    larger tables stay in HBM and the *streamed* variants double-buffer
    pointer pairs / row windows / plane rows in via ``make_async_copy``
    (:mod:`repro.kernels.stream`) — so an oversized per-shard sub-trie
    keeps its fused kernels instead of falling back to jnp.
    ``walk_variant`` / ``beam_variant`` name the chosen tier.
    """

    name = "pallas"

    # default VMEM byte budget for table residency, used when
    # cfg.memory_budget == 0: tables at or under it run the resident
    # kernels (which must also leave VMEM room for the per-block scratch),
    # larger ones the DMA-streamed tier
    _DEFAULT_VMEM_BUDGET = 8 << 20

    # physical per-core VMEM; a user-set memory_budget is clamped here —
    # a larger budget would declare tables "resident" that can never fit
    _VMEM_BYTES = 16 << 20

    # the DMA-streamed tier stages [lanes, tile] windows in VMEM scratch,
    # so the stream-tile widths (EngineConfig.walk_tile / link_tile /
    # emit_tile) and the teleport-plane width are part of the envelope:
    # beyond these the scratch alone would crowd out VMEM and the jnp
    # fallback is the right tool
    _STREAM_MAX_TILE = 1024

    # fused locus-DP static-shape envelope: beyond these the fused
    # sweep stops being a sensible single kernel (trace size / VMEM) and
    # the jnp DP is the right tool.  The per-step trip count grows as
    # max_lhs_len * max_terms_per_node, and the dedup width as
    # frontier * tele_width, so every one of those dimensions is bounded.
    # The envelope is shared by the resident and streamed tiers (the
    # sweep structure is identical; only table residency differs).
    _FUSE_MAX_SEQ = 64
    _FUSE_MAX_FRONTIER = 128
    _FUSE_MAX_RULE_MATCHES = 8
    _FUSE_MAX_LHS = 24
    _FUSE_MAX_TERMS = 4
    _FUSE_MAX_TELEPORTS = 16
    # bounded-edit additions: the edit budget multiplies the delete-closure
    # rounds per step, and the substitute/delete transitions stage
    # [lanes, branch_width] child windows in scratch, so both are bounded
    _FUSE_MAX_EDITS = 2
    _FUSE_MAX_BRANCH = 64

    # fused beam static-shape envelope: the selection network unrolls
    # W + P + k (argmax, mask) rounds per fixed-trip step, so the pool
    # and pop widths are bounded; max_steps is only the fori_loop trip
    # count but still caps the search the kernel is asked to run.  The
    # first doubled-width retry round (W x4) stays inside the envelope at
    # the default widths; later rounds fall back to the jnp reference.
    _BEAM_MAX_GENS = 256
    _BEAM_MAX_EXPAND = 32
    _BEAM_MAX_K = 64
    _BEAM_MAX_STEPS = 4096

    # table-byte accounting: the streamed locus-DP tier keeps the rule
    # trie resident (sized by the rule set, not the dictionary) and
    # streams everything dictionary-sized; the streamed beam tier
    # streams all five emission-side tables
    _WALK_STREAM_FIELDS = (
        "first_child", "edge_char", "edge_child", "s_first_child",
        "s_edge_char", "s_edge_child", "syn_mask", "tout", "tele_plane",
        "link_ptr", "link_rule", "link_target")
    _WALK_RESIDENT_FIELDS = (
        "r_first_child", "r_edge_char", "r_edge_child", "r_term_plane")
    _PREFIX_FIELDS = ("first_child", "edge_char", "edge_child")
    _BEAM_FIELDS = ("emit_ptr", "emit_node", "emit_score", "emit_is_leaf",
                    "leaf_sid")
    _CACHE_FIELDS = ("topk_score", "topk_sid")

    # compressed-layout (packed) counterparts: the streamed packed walk
    # streams only the two u8 per-node planes; every sparse side table —
    # chain representatives, branching rows, teleports, link spans — plus
    # the rule trie stays VMEM-resident (all are branch-count-sized, not
    # node-count-sized)
    _WALK_STREAM_FIELDS_PACKED = ("p_labels", "p_flags")
    _WALK_RESIDENT_FIELDS_PACKED = (
        "c_ids", "c_tout", "b_ids", "b_ptr", "b_char", "b_child",
        "sb_ids", "sb_ptr", "sb_char", "sb_child", "t_ids", "t_plane",
        "la_ids", "la_ptr", "link_rule", "link_target",
        "r_first_child", "r_edge_char", "r_edge_child", "r_term_plane")
    # p_labels rides both tuples for the is_packed layout probe even
    # though the kernels only read p_flags — the N extra u8 bytes keep
    # the accounting a (tiny) over-estimate instead of an under-count
    _BEAM_FIELDS_PACKED = (
        "p_labels", "p_flags", "c_ids", "c_eptr", "c_enode", "c_escore",
        "c_eleaf", "c_maxscore", "l_ids", "l_sid")
    _CACHE_FIELDS_PACKED = ("p_labels", "pc_score", "pc_base", "pc_sid",
                            "c_ids")

    def _budget(self, cfg: EngineConfig) -> int:
        budget = cfg.memory_budget or self._DEFAULT_VMEM_BUDGET
        return min(budget, self._VMEM_BYTES)

    @staticmethod
    def _table_bytes(t: DeviceTrie, fields) -> int:
        # itemsize-aware: the packed layout's u8/u16 tables count their
        # real footprint, which is the whole point of the compression
        return sum(math.prod(a.shape) * a.dtype.itemsize
                   for a in (getattr(t, f) for f in fields)
                   if a is not None)

    def min_streamed_budget(self, t: DeviceTrie) -> int:
        """The smallest ``memory_budget`` that still admits the streamed
        walk tier for this trie: room for the resident-side tables (the
        rule trie; for packed layouts also the sparse side tables) and
        nothing else.  Test/benchmark harnesses use it to *force* the
        streamed tier — every streamed table is over budget at this
        value."""
        fields = (self._WALK_RESIDENT_FIELDS_PACKED if pk.is_packed(t)
                  else self._WALK_RESIDENT_FIELDS)
        return max(self._table_bytes(t, fields), 1)

    @staticmethod
    def _rule_free(t: DeviceTrie, cfg: EngineConfig) -> bool:
        """True when the walk is a pure prefix descent (plain kind, or a
        rule-free build): no link store, no teleports, no synonym edges —
        the frontier then never holds more than one node.  A nonzero edit
        budget breaks the single-node invariant, so edit-mode walks always
        take the full DP (fused sweep or jnp reference)."""
        return (cfg.rule_matches == 0 and cfg.teleports == 0
                and int(t.s_edge_child.shape[0]) == 0
                and cfg.edit_budget == 0)

    def _fuse_shapes_ok(self, cfg: EngineConfig, seq_len: int) -> bool:
        """The fused locus-DP kernel's static shape envelope (both tiers)."""
        return not (seq_len > self._FUSE_MAX_SEQ
                    or cfg.frontier > self._FUSE_MAX_FRONTIER
                    or cfg.rule_matches > self._FUSE_MAX_RULE_MATCHES
                    or cfg.max_lhs_len > self._FUSE_MAX_LHS
                    or cfg.max_terms_per_node > self._FUSE_MAX_TERMS
                    or cfg.teleports > self._FUSE_MAX_TELEPORTS
                    or cfg.tele_width > self._FUSE_MAX_TELEPORTS
                    or cfg.term_width > self._FUSE_MAX_TERMS
                    or cfg.edit_budget > self._FUSE_MAX_EDITS
                    or cfg.branch_width > self._FUSE_MAX_BRANCH)

    def walk_variant(self, t: DeviceTrie, cfg: EngineConfig,
                     seq_len: int) -> str | None:
        """Which native walk path serves this (trie, config, length):
        ``"resident"`` (tables fit the VMEM budget), ``"streamed"``
        (HBM tables behind the DMA tier), or ``None`` (jnp fallback —
        static shapes outside the kernel envelope)."""
        budget = self._budget(cfg)
        if pk.is_packed(t):
            # compressed layout: always the fused locus kernel (the
            # rule-free walk shortcut's dense CSR is elided); the
            # streamed tier's windows are width-1 u8 gathers, so the
            # stream-tile envelope does not apply
            if not self._fuse_shapes_ok(cfg, seq_len):
                return None
            resident = self._table_bytes(
                t, self._WALK_RESIDENT_FIELDS_PACKED)
            total = resident + self._table_bytes(
                t, self._WALK_STREAM_FIELDS_PACKED)
            if total <= budget:
                return "resident"
            return "streamed" if resident <= budget else None
        # the streamed tier stages [lanes, tile]-wide windows in VMEM
        # scratch, so the stream-tile widths are part of its envelope
        tiles_ok = (cfg.walk_tile <= self._STREAM_MAX_TILE
                    and cfg.link_tile <= self._STREAM_MAX_TILE)
        if self._rule_free(t, cfg):
            if self._table_bytes(t, self._PREFIX_FIELDS) <= budget:
                return "resident"
            return "streamed" if tiles_ok else None
        if not self._fuse_shapes_ok(cfg, seq_len):
            return None
        total = self._table_bytes(
            t, self._WALK_STREAM_FIELDS + self._WALK_RESIDENT_FIELDS)
        if total <= budget:
            return "resident"
        if tiles_ok and \
                self._table_bytes(t, self._WALK_RESIDENT_FIELDS) <= budget:
            return "streamed"
        return None

    def can_walk_batch(self, t, cfg, seq_len):
        return self.walk_variant(t, cfg, seq_len) is not None

    def walk_batch(self, t, cfg, qs, qlens):
        from repro.kernels import ops

        variant = self.walk_variant(t, cfg, int(qs.shape[1]))
        if variant is None:
            return super().walk_batch(t, cfg, qs, qlens)
        streamed = variant == "streamed"
        if pk.is_packed(t):
            return ops.locus_walk(t, cfg, qs, qlens, streamed=streamed)
        if self._rule_free(t, cfg):
            node, depth = ops.trie_walk(t.first_child, t.edge_char,
                                        t.edge_child, qs, qlens,
                                        streamed=streamed,
                                        walk_tile=cfg.walk_tile)
            B = int(qs.shape[0])
            hit = depth == qlens    # partial walks have no completions
            loci = jnp.full((B, cfg.frontier), NEG_ONE, jnp.int32)
            loci = loci.at[:, 0].set(jnp.where(hit, node, NEG_ONE))
            return loci, jnp.zeros((B,), jnp.int32)
        return ops.locus_walk(t, cfg, qs, qlens, streamed=streamed)

    def beam_variant(self, t: DeviceTrie, cfg: EngineConfig,
                     k: int) -> str | None:
        """Which native beam path serves this (trie, config, k):
        ``"resident"``, ``"streamed"``, or ``None`` (jnp fallback).

        The kernel requires the pool to hold the seed antichain (F <= W)
        and a pop no wider than the pool (P <= W) — both preconditions
        of the reference too — plus bounded selection-network widths;
        within that envelope the VMEM budget picks the tier."""
        if cfg.gens > self._BEAM_MAX_GENS \
                or cfg.expand > self._BEAM_MAX_EXPAND \
                or k > self._BEAM_MAX_K \
                or cfg.max_steps > self._BEAM_MAX_STEPS \
                or cfg.frontier > cfg.gens \
                or cfg.expand > cfg.gens:
            return None
        if pk.is_packed(t):
            # no streamed packed beam tier: the packed emission store is
            # already branch-count-sized, so over-budget cases are rare
            # and the jnp reference answers them bit-identically
            if self._table_bytes(t, self._BEAM_FIELDS_PACKED) \
                    <= self._budget(cfg):
                return "resident"
            return None
        if self._table_bytes(t, self._BEAM_FIELDS) <= self._budget(cfg):
            return "resident"
        # the streamed tier's emit-window scratch is [lanes, emit_tile]
        if cfg.emit_tile <= self._STREAM_MAX_TILE:
            return "streamed"
        return None

    def can_beam_batch(self, t, cfg, k):
        return self.beam_variant(t, cfg, k) is not None

    def beam_topk_batch(self, t, cfg, loci, k):
        variant = self.beam_variant(t, cfg, k)
        if variant is None:
            return super().beam_topk_batch(t, cfg, loci, k)
        from repro.kernels import ops

        return ops.beam_topk(t, cfg, loci, k,
                             streamed=variant == "streamed")

    def topk_with_payload(self, scores, payload, k):
        from repro.kernels import ops

        return ops.topk_select(scores, payload, k)

    def cached_topk_batch(self, t, cfg, loci, k):
        assert cfg.use_cache and k <= cfg.cache_k, \
            "cache disabled or k too large"
        # the fused merge kernels hold the materialized (N, K) cache
        # tables whole in VMEM; there is no streamed cached tier yet
        # (ROADMAP follow-on), so caches over the budget answer through
        # the jnp reference merge instead of an unfittable kernel
        cache_fields = (self._CACHE_FIELDS_PACKED if pk.is_packed(t)
                        else self._CACHE_FIELDS)
        if self._table_bytes(t, cache_fields) > self._budget(cfg):
            return super().cached_topk_batch(t, cfg, loci, k)
        from repro.kernels import ops

        exact = jnp.ones(loci.shape[:-1], bool)
        if pk.is_packed(t):
            # quantized cache: translate loci to chain-representative
            # ranks and decode the row planes in-jit, then reuse the
            # uncompressed merge kernel unchanged
            s, p = ops.cached_topk_merge_packed(t, loci, k)
            return s, p, exact
        if self._rule_free(t, cfg):
            # single-locus rows: the gather is one row per query; merging
            # reduces to selecting from the node's own (sorted) top-K list
            sc, si = cached.gather_cached(t, loci[:, :1])
            s, p = self.topk_with_payload(sc, si, k)
            return s, p, exact
        s, p = ops.cached_topk_merge(loci, t.topk_score, t.topk_sid, k)
        return s, p, exact


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_SUBSTRATES: dict[str, Substrate] = {}


def register_substrate(name: str, substrate: Substrate) -> Substrate:
    """Register an execution substrate; a new backend is an additive
    ``register_substrate("<name>", MySubstrate())`` away."""
    if name in _SUBSTRATES:
        raise ValueError(f"substrate {name!r} already registered")
    _SUBSTRATES[name] = substrate
    return substrate


def get_substrate(name: str) -> Substrate:
    try:
        return _SUBSTRATES[name]
    except KeyError:
        raise ValueError(
            f"unknown substrate {name!r}; registered: "
            f"{available_substrates()}") from None


def available_substrates() -> list[str]:
    return sorted(_SUBSTRATES)


def resolve_substrate(name: str) -> str:
    """Resolve a user-facing substrate choice to a registry name.

    ``"auto"`` picks ``pallas`` when running on TPU and the ``jnp``
    reference elsewhere (interpret-mode pallas off-TPU is opt-in by naming
    ``"pallas"`` explicitly).  Concrete names are validated against the
    registry and passed through.
    """
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    get_substrate(name)
    return name


register_substrate("jnp", Substrate())
register_substrate("pallas", PallasSubstrate())


# ---------------------------------------------------------------------------
# public entry points (substrate-dispatched)
# ---------------------------------------------------------------------------


def _phase2_batch(t, cfg, loci, k, sub):
    """Phase-2 dispatch: cached merge when materialized and k fits, else
    beam."""
    if cfg.use_cache and k <= cfg.cache_k:
        return sub.cached_topk_batch(t, cfg, loci, k)
    return sub.beam_topk_batch(t, cfg, loci, k)


def topk_phase2(t: DeviceTrie, cfg: EngineConfig, loci: jax.Array, k: int,
                sub=None):
    """Single-row phase 2 (loci [F]); used by the incremental session."""
    sub = primitives.resolve_sub(cfg, sub)
    s, p, e = _phase2_batch(t, cfg, loci[None], k, sub)
    return s[0], p[0], e[0]


def topk_phase2_batch(t: DeviceTrie, cfg: EngineConfig, loci: jax.Array,
                      k: int, sub=None):
    """Batched phase 2 (loci [B, F]) -> (scores[B,k], sids[B,k], exact[B]);
    one dispatch for a whole coalesced micro-batch block."""
    sub = primitives.resolve_sub(cfg, sub)
    return _phase2_batch(t, cfg, loci, k, sub)


def complete_batch(t: DeviceTrie, cfg: EngineConfig, qs: jax.Array,
                   qlens: jax.Array, k: int, sub=None):
    """qs: int32[B, L]; qlens: int32[B] -> (scores[B,k], sids[B,k],
    exact[B])."""
    sub = primitives.resolve_sub(cfg, sub)
    loci, overflow = sub.walk_batch(t, cfg, qs, qlens)
    scores, sids, exact = _phase2_batch(t, cfg, loci, k, sub)
    return scores, sids, exact & (overflow == 0)


def complete_one(t: DeviceTrie, cfg: EngineConfig, q: jax.Array,
                 qlen: jax.Array, k: int, sub=None):
    scores, sids, exact = complete_batch(
        t, cfg, q[None], jnp.asarray(qlen)[None], k, sub)
    return scores[0], sids[0], exact[0]
