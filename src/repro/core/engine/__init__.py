"""Device-side top-k completion engine (array tries, substrate-dispatched).

The paper's best-first heap search (Alg. 2 / Alg. 4) is re-cast for TPU as:

  phase 1 — *locus DP* (:mod:`.locus`, :mod:`.incremental`): a fixed-width
      frontier sweep over query positions; the incremental variant carries
      the frontier across keystrokes.

  phase 2 — *top-k*: either the paper's priority search vectorized
      P-at-a-time (:mod:`.beam`) with an admissible-bound exactness flag,
      or the beyond-paper cached per-node top-K gather+merge
      (:mod:`.cached`), exact for k <= K.

Execution routes through a pluggable *substrate* (:mod:`.substrate`):
``"jnp"`` is the pure-jnp reference, ``"pallas"`` dispatches the batched
hot primitives (locus walk — rule-free and fused rule-bearing —, beam
priority search, cached gather+merge, top-k with payload) to the tuned
kernels in :mod:`repro.kernels`; every hot phase is substrate-pluggable.
The substrate name lives on :class:`EngineConfig` and therefore joins
every jit/compile-cache key.

Everything here lowers under jit/vmap/shard_map with ShapeDtypeStruct
inputs, which is what the multi-pod dry-run exercises.
"""

from repro.core.engine.structs import (DeviceTrie, EngineConfig, INT_MAX,
                                       NEG_ONE)
from repro.core.engine.primitives import (csr_child_lookup, dedup_pad,
                                          iters_for, lower_bound)
from repro.core.engine.locus import (finalize_loci, link_lookup, locus_dp,
                                     match_table, teleport_expand)
from repro.core.engine.beam import beam_topk
from repro.core.engine.cached import cached_topk, gather_cached
from repro.core.engine.incremental import (LocusState, advance_loci,
                                           advance_loci_batch,
                                           advance_locus_state,
                                           init_locus_batch,
                                           init_locus_state, topk_from_loci,
                                           topk_from_loci_batch)
from repro.core.engine.overlay import DeltaOverlay, merge_overlay_topk
# substrate last: it pulls the sibling modules above off the (partially
# initialized) package, so they must already be bound
from repro.core.engine.substrate import (PallasSubstrate, Substrate,
                                         available_substrates,
                                         complete_batch, complete_one,
                                         get_substrate, register_substrate,
                                         resolve_substrate, topk_phase2,
                                         topk_phase2_batch)

__all__ = [
    "DeviceTrie", "EngineConfig", "INT_MAX", "NEG_ONE",
    "csr_child_lookup", "dedup_pad", "iters_for", "lower_bound",
    "match_table", "teleport_expand", "link_lookup", "finalize_loci",
    "locus_dp",
    "beam_topk", "cached_topk", "gather_cached",
    "LocusState", "init_locus_state", "advance_locus_state", "advance_loci",
    "topk_from_loci", "init_locus_batch", "advance_loci_batch",
    "topk_from_loci_batch",
    "DeltaOverlay", "merge_overlay_topk",
    "Substrate", "PallasSubstrate", "register_substrate", "get_substrate",
    "available_substrates", "resolve_substrate",
    "topk_phase2", "topk_phase2_batch", "complete_one", "complete_batch",
]
