"""Phase 2a — beam top-k (paper-faithful priority search, vectorized).

Each locus becomes a lazy generator over its score-sorted emission list;
every step pops the best P emissions across all generators (lax.top_k) and
re-arms them.  This is the paper's priority queue, vectorized P-at-a-time,
with the same admissible bound (max descendant score).  Exactness is
tracked: if the width-bounded pools ever dropped a candidate better than
the k-th result, the query is flagged for a host-side retry with doubled
widths.

This is the reference implementation behind ``Substrate.beam_topk_batch``:
the generator loop is data-dependent (lax.while_loop) here, and the pallas
substrate replaces the whole search with the fused kernel in
:mod:`repro.kernels.beam_topk` (pool + heap in VMEM scratch, masked
fixed-trip loop) whenever ``can_beam_batch`` probes capable — results,
including the ``exact`` flags, are bit-identical by contract.

Exactness uses the *strict* admissible bound: only a dropped candidate
whose bound strictly exceeds the final k-th score can have displaced a
result, so an equal-bound drop (a score tie at the boundary) stays exact
and must not trigger the host-side doubled-width retry.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.engine import packed as pk
from repro.core.engine.structs import DeviceTrie, EngineConfig, NEG_ONE


def beam_topk(t: DeviceTrie, cfg: EngineConfig, loci: jax.Array, k: int):
    """Top-k leaves under the locus antichain.

    Returns (scores[k], sids[k], exact bool). scores are -1 padded.
    """
    W, P = cfg.gens, cfg.expand
    packed = pk.is_packed(t)
    degenerate = (int(t.c_enode.shape[0]) == 0 if packed
                  else int(t.emit_node.shape[0]) == 0)
    if degenerate:  # empty dictionary — no emissions anywhere
        return (jnp.full((k,), NEG_ONE, jnp.int32),
                jnp.full((k,), NEG_ONE, jnp.int32), jnp.bool_(True))

    if packed:
        emit_bound = lambda nodes, cursors: pk.emit_bound(t, nodes, cursors)
        pop = lambda nodes, cursors: pk.pop_emissions(t, nodes, cursors)
        sid_of = lambda nodes: pk.leaf_sid_of(t, nodes)
    else:
        e_size = max(int(t.emit_node.shape[0]), 1)

        def emit_bound(nodes, cursors):
            valid = nodes >= 0
            n = jnp.where(valid, nodes, 0)
            e = t.emit_ptr[n] + cursors
            ok = valid & (e < t.emit_ptr[n + 1])
            score = t.emit_score[jnp.clip(e, 0, e_size - 1)]
            return jnp.where(ok, score, NEG_ONE)

        def pop(nodes, cursors):
            e = jnp.clip(t.emit_ptr[nodes] + cursors, 0, e_size - 1)
            return t.emit_node[e], t.emit_score[e], t.emit_is_leaf[e]

        sid_of = lambda nodes: t.leaf_sid[nodes]

    # generator pool seeded with loci
    gn = jnp.full((W,), NEG_ONE, jnp.int32)
    gc = jnp.zeros((W,), jnp.int32)
    gn = jax.lax.dynamic_update_slice(gn, loci, (0,))
    gb = emit_bound(gn, gc)
    gn = jnp.where(gb >= 0, gn, NEG_ONE)

    ls = jnp.full((k,), NEG_ONE, jnp.int32)   # leaf scores desc
    li = jnp.full((k,), NEG_ONE, jnp.int32)   # leaf sids
    dropped_max = NEG_ONE
    steps = jnp.int32(0)

    def cond(state):
        gn, gc, gb, ls, li, dropped_max, steps = state
        best = jnp.max(gb)
        kth = ls[k - 1]
        return (best >= 0) & (kth < best) & (steps < cfg.max_steps)

    def body(state):
        gn, gc, gb, ls, li, dropped_max, steps = state
        topb, topi = jax.lax.top_k(gb, P)
        sel_valid = topb >= 0
        sel_n = jnp.where(sel_valid, gn[topi], 0)
        em_node, em_score, em_leaf = pop(sel_n, gc[topi])

        # leaves -> result buffer
        leaf_ok = sel_valid & em_leaf
        new_ls = jnp.where(leaf_ok, em_score, NEG_ONE)
        new_li = jnp.where(leaf_ok, sid_of(jnp.where(leaf_ok, em_node, 0)),
                           NEG_ONE)
        cat_s = jnp.concatenate([ls, new_ls])
        cat_i = jnp.concatenate([li, new_li])
        top_s, idx = jax.lax.top_k(cat_s, k)
        ls2, li2 = top_s, cat_i[idx]

        # internal emissions -> new generators
        int_ok = sel_valid & ~em_leaf
        new_n = jnp.where(int_ok, em_node, NEG_ONE)
        new_c = jnp.zeros((P,), jnp.int32)
        new_b = emit_bound(new_n, new_c)
        new_n = jnp.where(new_b >= 0, new_n, NEG_ONE)

        # advance selected generators
        gc2 = gc.at[topi].add(jnp.where(sel_valid, 1, 0))
        gb2 = emit_bound(gn, gc2)
        gn2 = jnp.where(gb2 >= 0, gn, NEG_ONE)

        # merge pools, keep top-W by bound
        pool_n = jnp.concatenate([gn2, new_n])
        pool_c = jnp.concatenate([gc2, new_c])
        pool_b = jnp.concatenate([gb2, new_b])
        keep_b, keep_i = jax.lax.top_k(pool_b, W)
        drop_mask = jnp.ones((W + P,), bool).at[keep_i].set(False)
        drop_best = jnp.max(jnp.where(drop_mask, pool_b, NEG_ONE))
        dropped_max2 = jnp.maximum(dropped_max, drop_best)
        return (pool_n[keep_i], pool_c[keep_i], keep_b, ls2, li2,
                dropped_max2, steps + 1)

    state = (gn, gc, gb, ls, li, dropped_max, steps)
    gn, gc, gb, ls, li, dropped_max, steps = jax.lax.while_loop(cond, body, state)
    finished = ~((jnp.max(gb) >= 0) & (ls[k - 1] < jnp.max(gb)))
    # strict bound: inexact only when a drop strictly beat the k-th score —
    # an equal-bound drop ties at best and must not trigger a retry
    exact = (dropped_max <= ls[k - 1]) & finished
    return ls, li, exact
