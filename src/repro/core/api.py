"""Public API: build TT / ET / HT completion indexes and serve top-k queries.

`CompletionIndex.build(...)` is the host-side constructor (Alg. 1 / 3 / 5 of
the paper, array-encoded); `.complete(...)` is the device-side batched top-k
(Alg. 2 / 4, vectorized) with automatic exactness retry.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng
from repro.core import knapsack as ks
from repro.core import trie_build as tb
from repro.core.alphabet import pad_queries


def _to_device(trie: tb.DictTrie, rule_trie: tb.RuleTrie) -> eng.DeviceTrie:
    j = jnp.asarray
    has_cache = trie.topk_score is not None
    dummy = np.full((1, 1), -1, np.int32)
    return eng.DeviceTrie(
        depth=j(trie.depth), max_score=j(trie.max_score),
        leaf_score=j(trie.leaf_score), leaf_sid=j(trie.leaf_sid),
        syn_mask=j(trie.syn_mask), tout=j(trie.tout),
        first_child=j(trie.first_child), edge_char=j(trie.edge_char),
        edge_child=j(trie.edge_child),
        s_first_child=j(trie.s_first_child), s_edge_char=j(trie.s_edge_char),
        s_edge_child=j(trie.s_edge_child),
        emit_ptr=j(trie.emit_ptr), emit_node=j(trie.emit_node),
        emit_score=j(trie.emit_score), emit_is_leaf=j(trie.emit_is_leaf),
        syn_ptr=j(trie.syn_ptr), syn_tgt=j(trie.syn_tgt),
        link_anchor=j(trie.link_anchor), link_rule=j(trie.link_rule),
        link_target=j(trie.link_target),
        r_first_child=j(rule_trie.first_child), r_edge_char=j(rule_trie.edge_char),
        r_edge_child=j(rule_trie.edge_child), r_term_ptr=j(rule_trie.term_ptr),
        r_term_rule=j(rule_trie.term_rule), r_rule_len=j(rule_trie.rule_len),
        topk_score=j(trie.topk_score if has_cache else dummy),
        topk_sid=j(trie.topk_sid if has_cache else dummy),
    )


@dataclass
class BuildStats:
    kind: str
    n_strings: int
    n_nodes: int
    n_syn_nodes: int
    n_links: int
    n_rules_expanded: int
    build_seconds: float
    bytes_total: int
    bytes_dict_nodes: int
    bytes_syn_nodes: int
    bytes_rule_side: int
    bytes_cache: int

    @property
    def bytes_per_string(self) -> float:
        return self.bytes_total / max(self.n_strings, 1)


class CompletionIndex:
    """A synonym-aware top-k completion index (TT, ET or HT)."""

    def __init__(self, kind, trie, rule_trie, rules, strings, scores,
                 cfg: eng.EngineConfig, stats: BuildStats):
        self.kind = kind
        self.trie = trie
        self.rule_trie = rule_trie
        self.rules = rules
        self.strings = strings          # sorted; leaf_sid indexes this
        self.scores = scores
        self.cfg = cfg
        self.stats = stats
        self.device = _to_device(trie, rule_trie)
        self._compiled: dict = {}

    # -- construction ------------------------------------------------------

    @staticmethod
    def build(strings, scores, rules, kind: str = "et", *,
              alpha: float = 0.5, cache_k: int = 0,
              frontier: int = 32, gens: int = 48, expand: int = 8,
              max_steps: int = 512) -> "CompletionIndex":
        """Build an index.

        kind: "tt" (twin tries), "et" (expansion trie), "ht" (hybrid; alpha
        in [0,1] sets the space budget between S_TT and S_ET), or "plain"
        (no synonym support — classic prefix-only trie).
        alpha: HT space ratio (paper Fig. 8).
        cache_k: materialize per-node top-K lists (0 = off; beyond-paper).
        """
        t0 = time.perf_counter()
        rules = list(rules)
        trie, ss, sc = tb.build_dict_trie(strings, scores)
        anchors, rids, targets = tb.find_links(trie, rules)
        n_rules = len(rules)
        n_links = len(anchors)

        if kind == "plain" or n_rules == 0:
            expand_mask = np.zeros(n_rules, dtype=bool)
            keep_links = np.zeros(n_rules, dtype=bool)
        elif kind == "tt":
            expand_mask = np.zeros(n_rules, dtype=bool)
            keep_links = np.ones(n_rules, dtype=bool)
        elif kind == "et":
            expand_mask = np.ones(n_rules, dtype=bool)
            keep_links = np.zeros(n_rules, dtype=bool)
        elif kind == "ht":
            items = ks.analyze_rules(rules, anchors, rids)
            s_et = int(items.w_orig.sum())  # node-count proxy for S_ET - S_TT
            budget = int(round(alpha * s_et))
            expand_mask = ks.solve_knapsack(items, budget)
            keep_links = ~expand_mask
        else:
            raise ValueError(f"unknown index kind {kind!r}")

        n_syn = 0
        if expand_mask.any():
            n_syn = tb.expand_synonyms(trie, rules, anchors, rids, targets,
                                       expand_mask)
        else:
            tb.rebuild_edges(trie)

        link_sel = keep_links[rids] if n_links else np.zeros(0, bool)
        tb.set_link_store(trie, anchors[link_sel], rids[link_sel],
                          targets[link_sel])
        # rule trie holds only rules that still live on the rule side
        active = np.zeros(n_rules, dtype=bool)
        if n_links:
            active[np.unique(rids[link_sel])] = True
        rule_trie = tb.build_rule_trie(rules, active)

        if cache_k > 0:
            tb.build_topk_cache(trie, cache_k)

        has_rule_side = bool(active.any())
        cfg = eng.EngineConfig(
            frontier=frontier, gens=gens, expand=expand, max_steps=max_steps,
            rule_matches=rule_trie.max_matches_per_pos if has_rule_side else 0,
            max_lhs_len=rule_trie.max_lhs_len if has_rule_side else 0,
            max_terms_per_node=rule_trie.max_terms_per_node,
            teleports=trie.max_syn_targets,
            use_cache=cache_k > 0, cache_k=cache_k,
        )

        # byte accounting (paper Table 2 / Fig. 5 breakdown)
        per_node = 0
        for name in ("parent", "depth", "chr_", "max_score", "leaf_score",
                     "leaf_sid", "syn_mask", "tout"):
            per_node += getattr(trie, name).itemsize if getattr(trie, name).ndim else 0
        n_nodes = trie.n_nodes
        node_bytes = sum(getattr(trie, n).nbytes for n in (
            "parent", "depth", "chr_", "max_score", "leaf_score", "leaf_sid",
            "syn_mask", "tout"))
        edge_bytes = sum(getattr(trie, n).nbytes for n in (
            "first_child", "edge_char", "edge_child", "emit_ptr", "emit_node",
            "emit_score", "emit_is_leaf"))
        syn_edge_bytes = sum(getattr(trie, n).nbytes for n in (
            "s_first_child", "s_edge_char", "s_edge_child", "syn_ptr",
            "syn_tgt"))
        link_bytes = sum(getattr(trie, n).nbytes for n in (
            "link_anchor", "link_rule", "link_target"))
        cache_bytes = (trie.topk_score.nbytes + trie.topk_sid.nbytes
                       if trie.topk_score is not None else 0)
        syn_frac = n_syn / max(n_nodes, 1)
        stats = BuildStats(
            kind=kind, n_strings=len(ss), n_nodes=n_nodes, n_syn_nodes=n_syn,
            n_links=int(link_sel.sum()) if n_links else 0,
            n_rules_expanded=int(expand_mask.sum()),
            build_seconds=time.perf_counter() - t0,
            bytes_total=node_bytes + edge_bytes + syn_edge_bytes + link_bytes
            + rule_trie.nbytes() + cache_bytes,
            bytes_dict_nodes=int((node_bytes + edge_bytes) * (1 - syn_frac)),
            bytes_syn_nodes=int((node_bytes + edge_bytes) * syn_frac)
            + syn_edge_bytes,
            bytes_rule_side=link_bytes + rule_trie.nbytes(),
            bytes_cache=cache_bytes,
        )
        return CompletionIndex(kind, trie, rule_trie, rules, ss, sc, cfg, stats)

    # -- lookup ------------------------------------------------------------

    def _fn(self, batch: int, length: int, k: int, cfg: eng.EngineConfig):
        key = (batch, length, k, cfg)
        if key not in self._compiled:
            dev = self.device

            @jax.jit
            def run(qs, qlens):
                return eng.complete_batch(dev, cfg, qs, qlens, k)

            self._compiled[key] = run
        return self._compiled[key]

    def complete_batch_padded(self, qs: np.ndarray, qlens: np.ndarray, k: int):
        """Device entry point: qs int32[B, L] (-1 padded). Retries inexact
        queries with widened search (exactness guard of §2.2)."""
        cfg = self.cfg
        fn = self._fn(qs.shape[0], qs.shape[1], k, cfg)
        scores, sids, exact = jax.tree.map(np.asarray, fn(qs, qlens))
        bad = ~exact
        tries = 0
        while bad.any() and tries < 3:
            cfg = replace(cfg, frontier=cfg.frontier * 2, gens=cfg.gens * 4,
                          max_steps=cfg.max_steps * 4, use_cache=False)
            sub = np.nonzero(bad)[0]
            fn2 = self._fn(len(sub), qs.shape[1], k, cfg)
            s2, i2, e2 = jax.tree.map(np.asarray, fn2(qs[sub], qlens[sub]))
            scores[sub], sids[sub] = s2, i2
            bad2 = np.zeros_like(bad)
            bad2[sub] = ~e2
            bad = bad2
            tries += 1
        return scores, sids

    def complete(self, queries: list[str | bytes], k: int = 10):
        """Top-k completions for a batch of query strings.

        Returns a list (per query) of (score, suggestion string) pairs.
        """
        max_len = max((len(q.encode() if isinstance(q, str) else q)
                       for q in queries), default=1)
        L = max(8, 1 << (max_len - 1).bit_length())
        qs, qlens = pad_queries(queries, L)
        scores, sids = self.complete_batch_padded(qs, qlens, k)
        out = []
        for b in range(len(queries)):
            row = []
            for score, sid in zip(scores[b], sids[b]):
                if score < 0 or sid < 0:
                    continue
                row.append((int(score), self.strings[int(sid)].decode(
                    "utf-8", errors="replace")))
            out.append(row)
        return out
