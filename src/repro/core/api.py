"""Back-compat shim: the public API moved to :mod:`repro.api`.

``CompletionIndex.build(...)`` / ``.complete(...)`` keep working from this
import path; new code should use ``repro.api`` (IndexSpec, build_index,
Session, save/load).
"""

from repro.api.build import BuildStats, build_index
from repro.api.index import CompletionIndex, _to_device
from repro.api.session import Session
from repro.api.spec import IndexSpec

__all__ = [
    "BuildStats",
    "CompletionIndex",
    "IndexSpec",
    "Session",
    "build_index",
    "_to_device",
]
