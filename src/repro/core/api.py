"""Deprecated back-compat shim: the public API moved to :mod:`repro.api`.

Importing this module warns; the re-exports below keep PR-1-era code
(``from repro.core.api import CompletionIndex``) working for one more
release.  New code imports from ``repro.api`` (IndexSpec, build_index,
Session, save/load) — or ``repro.core``, whose lazy attributes resolve
there without touching this shim.
"""

import warnings

warnings.warn(
    "repro.core.api is deprecated and will be removed; import from "
    "repro.api instead (e.g. `from repro.api import CompletionIndex`)",
    DeprecationWarning, stacklevel=2)

from repro.api.build import BuildStats, build_index
from repro.api.index import CompletionIndex, _to_device
from repro.api.session import Session
from repro.api.spec import IndexSpec

__all__ = [
    "BuildStats",
    "CompletionIndex",
    "IndexSpec",
    "Session",
    "build_index",
    "_to_device",
]
