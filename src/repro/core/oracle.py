"""Pure-Python oracle for Problem 1 (top-k completion with synonyms).

Deliberately naive and independent of the array-trie engine: a dict-of-dicts
trie plus a (pos, node) DP over all rule rewritings of the query.  Used as
the ground truth in unit and hypothesis property tests.

Semantics implemented (exactly the paper's Problem 1):
  a dictionary string s matches query p iff some rewriting p' of p is a
  prefix of s, where a rewriting replaces zero or more non-overlapping
  occurrences of rule lhs in the *original* p by the rule's rhs (generated
  text never participates in a later application).

Bounded-edit extension (``edit_budget`` = e): up to e single-character
edits — substitutions, insertions into the query, deletions from the
query — may additionally be spent while consuming the literal (non-rule)
characters of the query.  Edits apply only on the dictionary side: rule
lhs occurrences must be typed exactly, and (in the array engine) synonym
branch characters must be typed exactly — which this oracle matches by
construction since its rule transitions are atomic.  e = 0 is exactly
the paper's semantics.
"""

from __future__ import annotations

from repro.core.trie_build import SynonymRule


class OracleIndex:
    def __init__(self, strings, scores, rules: list[SynonymRule],
                 edit_budget: int = 0):
        self.strings = [s.encode() if isinstance(s, str) else bytes(s) for s in strings]
        self.scores = [int(x) for x in scores]
        # dedup, keep max score
        best: dict[bytes, int] = {}
        for s, r in zip(self.strings, self.scores):
            best[s] = max(best.get(s, r), r)
        self.items = sorted(best.items())
        self.rules = rules
        self.edit_budget = int(edit_budget)
        # trie: node = dict char -> node; terminals marked with key -1 -> idx
        self.root: dict = {}
        for idx, (s, _) in enumerate(self.items):
            node = self.root
            for c in s:
                node = node.setdefault(c, {})
            node[-1] = idx

    # -- helpers -----------------------------------------------------------
    def _walk(self, node: dict, seq: bytes):
        for c in seq:
            node = node.get(c)
            if node is None:
                return None
        return node

    def locus_nodes(self, p: bytes | str) -> list[dict]:
        """All trie nodes reachable by consuming the full query under some
        rewriting spending at most ``edit_budget`` edits (the DP over
        (pos, id(node), edits))."""
        if isinstance(p, str):
            p = p.encode()
        E = self.edit_budget
        # per position: insertion-ordered {(id(node), d) -> node}; smaller
        # d never hurts, so states are kept per (node, d) pair and the
        # final projection to nodes dedups
        reach: list[dict[tuple[int, int], dict]] = [
            {} for _ in range(len(p) + 1)]

        def add(pos: int, node: dict, d: int):
            reach[pos].setdefault((id(node), d), node)

        add(0, self.root, 0)
        for pos in range(len(p) + 1):
            # delete closure: consume a dictionary char without a query
            # char (iterate to fixpoint; each round raises d by one)
            frontier = list(reach[pos].items())
            while frontier:
                nxt_frontier = []
                for (_, d), node in frontier:
                    if d >= E:
                        continue
                    for c, child in node.items():
                        if c == -1:
                            continue
                        key = (id(child), d + 1)
                        if key not in reach[pos]:
                            add(pos, child, d + 1)
                            nxt_frontier.append((key, child))
                frontier = nxt_frontier
            if pos == len(p):
                break
            for (_, d), node in list(reach[pos].items()):
                # literal character
                nxt = node.get(p[pos])
                if nxt is not None:
                    add(pos + 1, nxt, d)
                if d < E:
                    # substitute: any other dictionary child
                    for c, child in node.items():
                        if c != -1 and c != p[pos]:
                            add(pos + 1, child, d + 1)
                    # insert: the query has an extra char; stay put
                    add(pos + 1, node, d + 1)
                # full-lhs rule applications starting at pos (lhs typed
                # exactly; the edit count carries through unchanged)
                for rule in self.rules:
                    L = len(rule.lhs)
                    if p[pos : pos + L] == rule.lhs:
                        tgt = self._walk(node, rule.rhs)
                        if tgt is not None:
                            add(pos + L, tgt, d)
        out: list[dict] = []
        seen: set[int] = set()
        for (nid, _), node in reach[len(p)].items():
            if nid not in seen:
                seen.add(nid)
                out.append(node)
        return out

    def _leaves(self, node: dict, out: set[int]):
        for c, child in node.items():
            if c == -1:
                out.add(child)
            else:
                self._leaves(child, out)

    def complete(self, p: bytes | str, k: int) -> list[tuple[int, bytes]]:
        """Top-k (score, string) pairs; score desc, string asc tiebreak."""
        matched: set[int] = set()
        for node in self.locus_nodes(p):
            self._leaves(node, matched)
        ranked = sorted(
            ((self.items[i][1], self.items[i][0]) for i in matched),
            key=lambda t: (-t[0], t[1]),
        )
        return ranked[:k]

    def topk_scores(self, p: bytes | str, k: int) -> list[int]:
        return [s for s, _ in self.complete(p, k)]

    def matches(self, p: bytes | str) -> set[bytes]:
        matched: set[int] = set()
        for node in self.locus_nodes(p):
            self._leaves(node, matched)
        return {self.items[i][0] for i in matched}
