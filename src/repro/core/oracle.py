"""Pure-Python oracle for Problem 1 (top-k completion with synonyms).

Deliberately naive and independent of the array-trie engine: a dict-of-dicts
trie plus a (pos, node) DP over all rule rewritings of the query.  Used as
the ground truth in unit and hypothesis property tests.

Semantics implemented (exactly the paper's Problem 1):
  a dictionary string s matches query p iff some rewriting p' of p is a
  prefix of s, where a rewriting replaces zero or more non-overlapping
  occurrences of rule lhs in the *original* p by the rule's rhs (generated
  text never participates in a later application).
"""

from __future__ import annotations

from repro.core.trie_build import SynonymRule


class OracleIndex:
    def __init__(self, strings, scores, rules: list[SynonymRule]):
        self.strings = [s.encode() if isinstance(s, str) else bytes(s) for s in strings]
        self.scores = [int(x) for x in scores]
        # dedup, keep max score
        best: dict[bytes, int] = {}
        for s, r in zip(self.strings, self.scores):
            best[s] = max(best.get(s, r), r)
        self.items = sorted(best.items())
        self.rules = rules
        # trie: node = dict char -> node; terminals marked with key -1 -> idx
        self.root: dict = {}
        for idx, (s, _) in enumerate(self.items):
            node = self.root
            for c in s:
                node = node.setdefault(c, {})
            node[-1] = idx

    # -- helpers -----------------------------------------------------------
    def _walk(self, node: dict, seq: bytes):
        for c in seq:
            node = node.get(c)
            if node is None:
                return None
        return node

    def locus_nodes(self, p: bytes | str) -> list[dict]:
        """All trie nodes reachable by consuming the full query under some
        rewriting (the DP over (pos, id(node)))."""
        if isinstance(p, str):
            p = p.encode()
        reach: list[list[dict]] = [[] for _ in range(len(p) + 1)]
        seen: list[set[int]] = [set() for _ in range(len(p) + 1)]

        def add(pos: int, node: dict):
            if id(node) not in seen[pos]:
                seen[pos].add(id(node))
                reach[pos].append(node)

        add(0, self.root)
        for pos in range(len(p)):
            for node in list(reach[pos]):
                # literal character
                nxt = node.get(p[pos])
                if nxt is not None:
                    add(pos + 1, nxt)
                # full-lhs rule applications starting at pos
                for rule in self.rules:
                    L = len(rule.lhs)
                    if p[pos : pos + L] == rule.lhs:
                        tgt = self._walk(node, rule.rhs)
                        if tgt is not None:
                            add(pos + L, tgt)
        return reach[len(p)]

    def _leaves(self, node: dict, out: set[int]):
        for c, child in node.items():
            if c == -1:
                out.add(child)
            else:
                self._leaves(child, out)

    def complete(self, p: bytes | str, k: int) -> list[tuple[int, bytes]]:
        """Top-k (score, string) pairs; score desc, string asc tiebreak."""
        matched: set[int] = set()
        for node in self.locus_nodes(p):
            self._leaves(node, matched)
        ranked = sorted(
            ((self.items[i][1], self.items[i][0]) for i in matched),
            key=lambda t: (-t[0], t[1]),
        )
        return ranked[:k]

    def topk_scores(self, p: bytes | str, k: int) -> list[int]:
        return [s for s, _ in self.complete(p, k)]

    def matches(self, p: bytes | str) -> set[bytes]:
        matched: set[int] = set()
        for node in self.locus_nodes(p):
            self._leaves(node, matched)
        return {self.items[i][0] for i in matched}
