"""0/1 knapsack with item interactions (paper §6, Alg. 5) for HT construction.

Items are synonym rules; value = application frequency (number of links);
weight = synonym nodes created by expansion. Two rules *interact* when they
share an anchor node and their lhs strings share a prefix: their expansions
share branch nodes, so the marginal weight of one shrinks when the other is
already in the knapsack.

Paper-faithful pieces:
  - partition of rules into interaction groups (connected components),
  - branch & bound with a *tight upper bound* (fractional greedy over
    minimum weights, i.e. assuming every interaction is realized) and a
    *tight lower bound* (greedy over original weights, i.e. assuming no
    interaction is realized),
  - exact_weight in each branch via a scan restricted to the item's own
    partition (the paper's pairwise-min weight model).

The B&B is exact under the paper's pairwise weight model; the actual node
count of the final expansion is measured afterwards by `expand_synonyms`
(actual <= modeled, since per-anchor sharing can only help).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def _common_prefix(a: bytes, b: bytes) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


@dataclass
class KnapsackItems:
    value: np.ndarray        # int64[R]  frequency
    w_orig: np.ndarray       # int64[R]  weight with no interactions
    w_min: np.ndarray        # int64[R]  weight with all interactions realized
    part: np.ndarray         # int32[R]  partition id
    pair_save: dict          # (i, j) -> nodes saved on i when j included


def analyze_rules(rules, anchors: np.ndarray, rids: np.ndarray) -> KnapsackItems:
    n_rules = len(rules)
    lhs = [r.lhs for r in rules]
    lens = np.array([len(s) for s in lhs], dtype=np.int64)

    # group anchors by rule and by anchor
    value = np.bincount(rids, minlength=n_rules).astype(np.int64)
    w_orig = value * lens

    # anchor -> rule set; interaction when two rules share an anchor and a
    # first character
    order = np.argsort(anchors, kind="stable")
    a_sorted, r_sorted = anchors[order], rids[order]
    starts = np.concatenate([[0], np.nonzero(np.diff(a_sorted))[0] + 1, [len(a_sorted)]])

    parent = np.arange(n_rules)

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[ra] = rb

    # pairwise shared-anchor counts for interacting pairs
    pair_count: dict[tuple[int, int], int] = {}
    for s, e in zip(starts[:-1], starts[1:]):
        rs = np.unique(r_sorted[s:e])
        if len(rs) < 2:
            continue
        by_first: dict[int, list[int]] = {}
        for r in rs:
            by_first.setdefault(lhs[int(r)][0], []).append(int(r))
        for grp in by_first.values():
            for i in range(len(grp)):
                for j in range(i + 1, len(grp)):
                    a, b = grp[i], grp[j]
                    union(a, b)
                    pair_count[(a, b)] = pair_count.get((a, b), 0) + 1

    part = np.array([find(i) for i in range(n_rules)], dtype=np.int32)

    # per-pair savings and w_min
    pair_save: dict[tuple[int, int], int] = {}
    best_save = np.zeros(n_rules, dtype=np.int64)
    for (a, b), cnt in pair_count.items():
        cp = _common_prefix(lhs[a], lhs[b])
        if cp == 0:
            continue
        pair_save[(a, b)] = pair_save.get((a, b), 0) + cnt * cp
        pair_save[(b, a)] = pair_save.get((b, a), 0) + cnt * cp
    # aggregate identical pairs appearing from several anchors is handled by
    # cnt already; now best per rule
    for (a, _b), s in pair_save.items():
        best_save[a] = max(best_save[a], s)
    w_min = np.maximum(w_orig - best_save, 1)
    w_min = np.where(value > 0, w_min, 0)
    return KnapsackItems(value=value, w_orig=w_orig, w_min=w_min, part=part,
                         pair_save=pair_save)


def solve_knapsack(items: KnapsackItems, budget: int,
                   max_nodes: int = 200_000) -> np.ndarray:
    """Branch & bound; returns bool mask of included rules.

    Exact under the pairwise weight model unless the node cap fires, in
    which case the best incumbent found so far is returned (always valid).
    """
    n = len(items.value)
    usable = items.value > 0
    idx = np.nonzero(usable)[0]
    if len(idx) == 0 or budget <= 0:
        return np.zeros(n, dtype=bool)

    # order by density under minimum weights (tight-upper-bound ordering)
    dens = items.value[idx] / np.maximum(items.w_min[idx], 1)
    idx = idx[np.argsort(-dens, kind="stable")]
    m = len(idx)
    value = items.value[idx].astype(np.float64)
    w_min = items.w_min[idx].astype(np.float64)
    w_orig = items.w_orig[idx].astype(np.float64)
    pos_of = {int(r): p for p, r in enumerate(idx)}

    # suffix tables for bounds
    def upper_bound(p: int, cap: float) -> float:
        """Fractional greedy over minimum weights from position p."""
        total = 0.0
        for q in range(p, m):
            if w_min[q] <= cap:
                cap -= w_min[q]
                total += value[q]
            else:
                total += value[q] * (cap / max(w_min[q], 1e-9))
                break
        return total

    def greedy_value(p: int, cap: float, included: list[int]) -> tuple[float, list[int]]:
        """Integral greedy over exact weights (>= true optimum is not
        claimed; this is the lower bound / incumbent builder)."""
        total = 0.0
        inc = list(included)
        take: list[int] = []
        for q in range(p, m):
            w = exact_weight(q, inc)
            if w <= cap:
                cap -= w
                total += value[q]
                inc.append(q)
                take.append(q)
        return total, take

    def exact_weight(p: int, included: list[int]) -> float:
        """Paper's exact_weight: min over included items in same part of the
        pairwise-saved weight."""
        r = int(idx[p])
        w = w_orig[p]
        part = items.part[r]
        for q in included:
            r2 = int(idx[q])
            if items.part[r2] != part:
                continue
            s = items.pair_save.get((r, r2))
            if s:
                w = min(w, max(w_orig[p] - s, 1.0))
        return w

    best_val = -1.0
    best_set: list[int] = []

    # greedy incumbent first (ensures a feasible answer under the cap)
    v0, t0 = greedy_value(0, float(budget), [])
    best_val, best_set = v0, t0

    # DFS stack: (pos, cap, val, included tuple)
    stack = [(0, float(budget), 0.0, [])]
    explored = 0
    while stack and explored < max_nodes:
        pos, cap, val, inc = stack.pop()
        explored += 1
        if pos == m:
            if val > best_val:
                best_val, best_set = val, list(inc)
            continue
        if val + upper_bound(pos, cap) <= best_val:
            continue  # prune
        # lower bound improves incumbent opportunistically
        lbv, lbt = greedy_value(pos, cap, inc)
        if val + lbv > best_val:
            best_val, best_set = val + lbv, list(inc) + lbt
        # branch: exclude first so include is explored first (LIFO)
        stack.append((pos + 1, cap, val, inc))
        w = exact_weight(pos, inc)
        if w <= cap:
            stack.append((pos + 1, cap - w, val + value[pos], inc + [pos]))

    mask = np.zeros(n, dtype=bool)
    for p in best_set:
        mask[int(idx[p])] = True
    return mask
