"""mind [arXiv:1904.08030; unverified]
embed_dim=64 n_interests=4 capsule_iters=3 interaction=multi-interest."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys import MINDConfig
from repro.optim import OptimizerConfig

def make_config():
    return MINDConfig(name="mind", vocab=1_000_000)

def make_smoke_config():
    return MINDConfig(name="mind-smoke", vocab=1000, seq_len=12, d_embed=16)

SPEC = register(ArchSpec(
    arch_id="mind", family="recsys", source="arXiv:1904.08030",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=dict(RECSYS_SHAPES),
    optimizer=OptimizerConfig(name="adamw", lr=1e-3)))
