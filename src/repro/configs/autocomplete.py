"""The paper's own workloads as serving archs: autocomplete-{dblp,usps,sprot}.

The dry-run lowers the *sharded* completion serve step (DESIGN 2.5) with
synthetic trie arrays sized from the real datasets' statistics (Table 1);
benchmarks build the actual tries from repro.data.strings generators.
"""
from dataclasses import dataclass

from repro.configs.base import ArchSpec, ShapeCell, register
from repro.optim import OptimizerConfig


@dataclass(frozen=True)
class AutocompleteConfig:
    name: str
    n_strings: int
    n_rules: int
    avg_len: int
    index_kind: str = "et"
    cache_k: int = 16


def _shapes(n_strings, n_rules, avg_len, n_shards=16):
    # per-shard trie sizing: nodes ~ strings/shard * distinct-suffix factor
    nodes = max(int(n_strings / n_shards * avg_len * 0.4), 1024)
    return {
        "serve_1k": ShapeCell("serve_1k", "serve", {
            "batch": 1024, "query_len": 32, "k": 10,
            "nodes_per_shard": nodes, "edges_per_shard": nodes,
            "rule_nodes": n_rules * 8, "rules": n_rules, "cache_k": 16}),
    }


def _make(name, n_strings, n_rules, avg_len):
    cfg = AutocompleteConfig(name, n_strings, n_rules, avg_len)
    return register(ArchSpec(
        arch_id=f"autocomplete-{name}", family="autocomplete",
        source="this paper (CS.IR 2016), Table 1",
        make_config=lambda: cfg,
        make_smoke_config=lambda: AutocompleteConfig(
            name + "-smoke", 500, 24, avg_len),
        shapes=_shapes(n_strings, n_rules, avg_len),
        optimizer=OptimizerConfig(name="sgd"),
        notes="construction is offline (Alg.1/3/5); serve step is lowered"))


DBLP = _make("dblp", 24_810, 368, 60)
USPS = _make("usps", 1_000_000, 341, 25)
SPROT = _make("sprot", 1_000_000, 1_000, 20)
