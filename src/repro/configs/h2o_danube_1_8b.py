"""h2o-danube-1.8b [arXiv:2401.16818; hf]
24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000; llama+mistral mix
with sliding-window attention (window 4096) => sub-quadratic, so this is
the ONE assigned LM arch that runs long_500k (DESIGN 4.1)."""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig
from repro.optim import OptimizerConfig

def make_config():
    return TransformerConfig(
        name="h2o-danube-1.8b", n_layers=24, d_model=2560, n_heads=32,
        n_kv=8, d_head=80, d_ff=6912, vocab=32000, window=4096,
        activation_dtype="bfloat16")

def make_smoke_config():
    return TransformerConfig(
        name="danube-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=128, window=16, loss_chunk=16)

SPEC = register(ArchSpec(
    arch_id="h2o-danube-1.8b", family="lm", source="arXiv:2401.16818",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ctx_ok=True),
    optimizer=OptimizerConfig(name="adamw", lr=3e-4),
    notes="SWA: ring-buffer KV cache of window size at decode."))
