from repro.configs.base import (ArchSpec, ShapeCell, all_archs, get_arch,
                                REGISTRY)

__all__ = ["ArchSpec", "ShapeCell", "all_archs", "get_arch", "REGISTRY"]
