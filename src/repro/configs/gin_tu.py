"""gin-tu [arXiv:1810.00826; paper]
GIN: n_layers=5 d_hidden=64 aggregator=sum eps=learnable.
d_feat / n_classes come from each shape cell (cora-, reddit-, products-,
TU-molecule-sized); see base.GNN_SHAPES."""
from repro.configs.base import ArchSpec, GNN_SHAPES, register
from repro.models.gnn import GINConfig
from repro.optim import OptimizerConfig

def make_config():
    return GINConfig(name="gin-tu", n_layers=5, d_hidden=64,
                     learnable_eps=True)

def make_smoke_config():
    return GINConfig(name="gin-smoke", n_layers=2, d_hidden=16,
                     learnable_eps=True)

SPEC = register(ArchSpec(
    arch_id="gin-tu", family="gnn", source="arXiv:1810.00826",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=dict(GNN_SHAPES),
    optimizer=OptimizerConfig(name="adamw", lr=1e-3),
    notes="paper technique inapplicable to GNNs (DESIGN 4.2); "
          "implemented without it per assignment rules."))
