"""mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407; hf]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, 128k ctx,
head_dim=128 (q-proj 5120->4096), rope_theta=1e6. Full attention."""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig
from repro.optim import OptimizerConfig

def make_config():
    return TransformerConfig(
        name="mistral-nemo-12b", n_layers=40, d_model=5120, n_heads=32,
        n_kv=8, d_head=128, d_ff=14336, vocab=131072, rope_theta=1e6,
        activation_dtype="bfloat16")

def make_smoke_config():
    return TransformerConfig(
        name="nemo-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256, rope_theta=1e6, loss_chunk=16)

SPEC = register(ArchSpec(
    arch_id="mistral-nemo-12b", family="lm",
    source="hf:mistralai/Mistral-Nemo-Base-2407",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ctx_ok=False),
    optimizer=OptimizerConfig(name="adamw", lr=3e-4)))
