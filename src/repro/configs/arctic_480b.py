"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf]
35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2 +
dense residual. bf16 params + Adafactor so state fits one pod (DESIGN 6)."""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig
from repro.optim import OptimizerConfig

def make_config():
    return TransformerConfig(
        name="arctic-480b", n_layers=35, d_model=7168, n_heads=56, n_kv=8,
        d_head=128, d_ff=4864, vocab=32000, moe_experts=128, moe_top_k=2,
        moe_dense_residual=True, rope_theta=10_000.0, param_dtype="bfloat16",
        activation_dtype="bfloat16")

def make_smoke_config():
    return TransformerConfig(
        name="arctic-smoke", n_layers=2, d_model=64, n_heads=8, n_kv=2,
        d_head=8, d_ff=48, vocab=128, moe_experts=8, moe_top_k=2,
        moe_dense_residual=True, loss_chunk=16)

SPEC = register(ArchSpec(
    arch_id="arctic-480b", family="lm",
    source="hf:Snowflake/snowflake-arctic-base",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ctx_ok=False),
    optimizer=OptimizerConfig(name="adafactor", lr=1e-4),
    notes="dense-MoE hybrid: parallel dense FFN residual + 128e top-2 EP."))
