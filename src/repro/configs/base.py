"""Architecture registry: ArchSpec + shape cells.

Every assigned architecture registers an ArchSpec; launch/{train,serve,
dryrun}.py select with --arch/--shape. A cell is (arch x input-shape); the
dry-run lowers every non-skipped cell on the production meshes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

from repro.optim import OptimizerConfig


@dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str                 # train | prefill | decode | serve | retrieval
    params: dict
    skip: str | None = None   # reason when the cell is skipped (documented)


@dataclass
class ArchSpec:
    arch_id: str
    family: str               # lm | gnn | recsys | autocomplete
    source: str
    make_config: Callable[[], Any]
    make_smoke_config: Callable[[], Any]
    shapes: dict[str, ShapeCell]
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    notes: str = ""


REGISTRY: dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in REGISTRY:
        import repro.configs.registry  # noqa: F401  (populate)
    return REGISTRY[arch_id]


def all_archs() -> dict[str, ArchSpec]:
    import repro.configs.registry  # noqa: F401
    return dict(REGISTRY)


LM_SHAPES = {
    "train_4k": ShapeCell("train_4k", "train",
                          {"seq": 4096, "batch": 256}),
    "prefill_32k": ShapeCell("prefill_32k", "prefill",
                             {"seq": 32768, "batch": 32}),
    "decode_32k": ShapeCell("decode_32k", "decode",
                            {"seq": 32768, "batch": 128}),
    "long_500k": ShapeCell("long_500k", "decode",
                           {"seq": 524288, "batch": 1}),
}


def lm_shapes(long_ctx_ok: bool, skip_reason: str = "") -> dict[str, ShapeCell]:
    out = dict(LM_SHAPES)
    if not long_ctx_ok:
        c = out["long_500k"]
        out["long_500k"] = ShapeCell(c.name, c.kind, c.params,
                                     skip=skip_reason or
                                     "pure full-attention arch: 512k decode "
                                     "requires sub-quadratic attention "
                                     "(DESIGN §4.1)")
    return out


RECSYS_SHAPES = {
    "train_batch": ShapeCell("train_batch", "train", {"batch": 65536}),
    "serve_p99": ShapeCell("serve_p99", "serve", {"batch": 512}),
    "serve_bulk": ShapeCell("serve_bulk", "serve", {"batch": 262144}),
    "retrieval_cand": ShapeCell("retrieval_cand", "retrieval",
                                {"batch": 1, "n_candidates": 1_000_000}),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeCell(
        "full_graph_sm", "train",
        {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433, "n_classes": 7}),
    "minibatch_lg": ShapeCell(
        "minibatch_lg", "train",
        {"n_nodes": 232_965, "n_edges": 114_615_892, "batch_nodes": 1024,
         "fanout": (15, 10), "d_feat": 602, "n_classes": 41}),
    "ogb_products": ShapeCell(
        "ogb_products", "train",
        {"n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100,
         "n_classes": 47}),
    "molecule": ShapeCell(
        "molecule", "train",
        {"n_nodes": 30, "n_edges": 64, "batch": 128, "d_feat": 32,
         "n_classes": 2}),
}
