"""Cell builder: (arch x shape x mesh) -> jit-lowerable step function +
ShapeDtypeStruct inputs (with shardings). This is the single entry point
used by launch/dryrun.py, benchmarks/roofline.py and the smoke tests.

No device allocation happens here: parameter/optimizer/batch shapes come
from jax.eval_shape.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchSpec, ShapeCell
from repro.distributed import sharding as sh
from repro.models import gnn as gnn_m
from repro.models import recsys as rec_m
from repro.models import transformer as tf
from repro.optim import OptimizerConfig, apply_updates, init_optimizer


@dataclass
class CellBuild:
    step_fn: Callable
    args: tuple
    donate: tuple
    model_flops: float
    desc: str


def _shaped(shapes_tree, axes_tree, mesh):
    """ShapeDtypeStructs with shardings; any dim that does not divide its
    mapped mesh axes falls back to replicated (reduced smoke configs, odd
    head counts, etc. — full configs divide by construction)."""
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(s, spec):
        parts = []
        for dim, entry in zip(s.shape, tuple(spec) + (None,) * s.ndim):
            if entry is None:
                parts.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= axis_sizes[a]
            parts.append(entry if dim % n == 0 else None)
        return jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, P(*parts)))

    specs = jax.tree.map(lambda ax: sh.spec_for(ax, mesh), axes_tree,
                         is_leaf=lambda x: isinstance(x, tuple))
    return jax.tree.map(one, shapes_tree, specs)


def _sds(shape, dtype, axes, mesh):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=sh.sharding_for(axes, mesh))


def _fix_batch(axes_tree, mesh, batch: int):
    """Replace the 'batch' logical axis by None when the global batch does
    not divide the dp axes (e.g. long_500k / retrieval_cand with batch=1 —
    the sequence replicates and model parallelism does the work)."""
    if batch % max(sh.dp_size(mesh), 1) == 0:
        return axes_tree
    return jax.tree.map(
        lambda ax: tuple(None if a == "batch" else a for a in ax),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def optimizer_axes(opt_cfg: OptimizerConfig, param_axes, param_shapes):
    if opt_cfg.name in ("adamw",):
        return {"m": param_axes, "v": param_axes, "step": ()}
    if opt_cfg.name == "sgd":
        return {"m": param_axes, "step": ()}
    if opt_cfg.name == "adafactor":
        from repro.optim.optimizers import _is_factored

        def vr_ax(ax, s):
            return ax[:-1] if _is_factored(s.shape, opt_cfg) else ax

        def vc_ax(ax, s):
            return (ax[:-2] + ax[-1:]) if _is_factored(s.shape, opt_cfg) \
                else (None,)

        is_ax = lambda x: isinstance(x, tuple)
        vr = jax.tree.map(vr_ax, param_axes, param_shapes, is_leaf=is_ax)
        vc = jax.tree.map(vc_ax, param_axes, param_shapes, is_leaf=is_ax)
        return {"vr": vr, "vc": vc, "step": ()}
    raise ValueError(opt_cfg.name)


def make_train_step(loss_fn, model_cfg, opt_cfg, param_axes=None):
    """param_axes: logical-axes tree — gradients are constrained to the
    parameter sharding, forcing a reduce-scatter over the fsdp axis instead
    of an all-reduce that would leave grads replicated (ZeRO-2 semantics;
    the difference is 58 GB/device for arctic-480b, §Perf).

    opt_cfg.accum_steps > 1 runs microbatched gradient accumulation (scan
    over micro-batches, grads accumulated in param dtype): activation peak
    scales 1/accum — the standard fit-it-in-HBM knob at 480B scale."""
    accum = max(opt_cfg.accum_steps, 1)

    def constrain_grads(grads):
        if param_axes is not None and sh.current_mesh() is not None:
            shardings = sh.tree_shardings(param_axes)
            grads = jax.tree.map(jax.lax.with_sharding_constraint,
                                 grads, shardings)
        return grads

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, model_cfg)
            grads = constrain_grads(grads)
        else:
            def split(x):
                return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(acc, mb):
                (l, m), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb, model_cfg)
                g = constrain_grads(g)
                acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype),
                                   acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(jnp.zeros_like, params)
            gsum, (ls, ms) = jax.lax.scan(body, zeros, micro)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = ls.mean()
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        params, opt_state, om = apply_updates(opt_cfg, params, grads,
                                              opt_state)
        return params, opt_state, {"loss": loss, **metrics, **om}
    return train_step


def _shapes_and_axes(init, cfg):
    """(ShapeDtypeStruct params tree, logical-axes tree) with NO allocation:
    init runs under eval_shape; the axes tree (concrete python tuples) is
    captured on the side."""
    key = jax.random.PRNGKey(0)
    out = {}

    def capture():
        p, a = init(key, cfg)
        out["axes"] = a
        return p

    p_shapes = jax.eval_shape(capture)
    return p_shapes, out["axes"]


def _train_shapes(spec, cfg, init, loss_fn, batch_shapes, batch_axes, mesh):
    p_shapes, p_axes = _shapes_and_axes(init, cfg)
    opt_shapes = jax.eval_shape(
        lambda: init_optimizer(spec.optimizer, p_shapes))
    o_axes = optimizer_axes(spec.optimizer, p_axes, p_shapes)
    args = (
        _shaped(p_shapes, p_axes, mesh),
        _shaped(opt_shapes, o_axes, mesh),
        _shaped(batch_shapes, batch_axes, mesh),
    )
    step = make_train_step(loss_fn, cfg, spec.optimizer, param_axes=p_axes)
    return step, args


# -- LM ---------------------------------------------------------------------


def _lm_init(key, cfg):
    return tf.init_lm(key, cfg)


def _serve_param_axes(p_shapes, p_axes, mesh, budget_bytes=8 << 30):
    """§Perf (decode hillclimb): FSDP weight sharding is the wrong trade at
    serve time — it re-gathers every layer's weights for every decoded
    token (3.4 GB/device/token for qwen decode_32k). When the TP-resident
    copy fits the per-device budget, strip the 'fsdp' axis so weights stay
    resident; a 480B arctic keeps FSDP (cannot fit) and pays the gathers."""
    n_model = max(sh.model_size(mesh), 1)
    total = sum(s.size * s.dtype.itemsize
                for s in jax.tree.leaves(p_shapes))
    if total / n_model > budget_bytes:
        return p_axes
    return jax.tree.map(
        lambda ax: tuple(None if a == "fsdp" else a for a in ax),
        p_axes, is_leaf=lambda x: isinstance(x, tuple))


def _build_lm(spec: ArchSpec, cell: ShapeCell, mesh, cfg) -> CellBuild:
    B, S = cell.params["batch"], cell.params["seq"]
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        batch_shapes = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "mask": jax.ShapeDtypeStruct((B, S), jnp.bool_),
        }
        batch_axes = _fix_batch(
            {"tokens": ("batch", None), "targets": ("batch", None),
             "mask": ("batch", None)}, mesh, B)
        step, args = _train_shapes(spec, cfg, _lm_init, tf.loss_fn,
                                   batch_shapes, batch_axes, mesh)
        return CellBuild(step, args, (0, 1), 6.0 * n_active * B * S,
                         f"train {B}x{S}")
    if cell.kind == "prefill":
        p_shapes, p_axes = _shapes_and_axes(_lm_init, cfg)
        p_axes = _serve_param_axes(p_shapes, p_axes, mesh)
        toks = _sds((B, S), jnp.int32,
                    _fix_batch({"t": ("batch", None)}, mesh, B)["t"], mesh)

        def step(params, tokens):
            return tf.prefill(params, tokens, cfg, max_len=S)

        return CellBuild(step, (_shaped(p_shapes, p_axes, mesh), toks), (),
                         2.0 * n_active * B * S, f"prefill {B}x{S}")
    if cell.kind == "decode":
        p_shapes, p_axes = _shapes_and_axes(_lm_init, cfg)
        p_axes = _serve_param_axes(p_shapes, p_axes, mesh)
        cache_shapes = jax.eval_shape(
            lambda: tf.init_cache(cfg, B, S))
        cache_shapes["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
        c_axes = _fix_batch(
            tf.cache_axes("k_scale" in cache_shapes) | {"pos": ()}, mesh, B)
        toks = _sds((B,), jnp.int32,
                    _fix_batch({"t": ("batch",)}, mesh, B)["t"], mesh)

        def step(params, cache, tokens):
            return tf.decode_step(params, cache, tokens, cfg)

        return CellBuild(
            step,
            (_shaped(p_shapes, p_axes, mesh),
             _shaped(cache_shapes, c_axes, mesh), toks),
            (1,), 2.0 * n_active * B, f"decode B={B} ctx={S}")
    raise ValueError(cell.kind)


# -- GNN ----------------------------------------------------------------------


def _gnn_init(key, cfg):
    return gnn_m.init_gin(key, cfg)


def _gnn_cfg_for_cell(spec: ArchSpec, cell: ShapeCell, smoke=False):
    base = spec.make_smoke_config() if smoke else spec.make_config()
    p = cell.params
    return gnn_m.GINConfig(
        name=base.name, n_layers=base.n_layers, d_hidden=base.d_hidden,
        d_feat=p["d_feat"], n_classes=p["n_classes"],
        learnable_eps=base.learnable_eps,
        graph_level=(cell.name == "molecule"),
        partitioned_edges=base.partitioned_edges)


def _build_gnn(spec: ArchSpec, cell: ShapeCell, mesh, cfg) -> CellBuild:
    p = cell.params
    d, h, L_ = p["d_feat"], cfg.d_hidden, cfg.n_layers
    if cell.name == "molecule":
        G, Nn, Ne = p["batch"], p["n_nodes"], p["n_edges"]
        batch_shapes = {
            "feats": jax.ShapeDtypeStruct((G, Nn, d), jnp.float32),
            "src": jax.ShapeDtypeStruct((G, Ne), jnp.int32),
            "dst": jax.ShapeDtypeStruct((G, Ne), jnp.int32),
            "labels": jax.ShapeDtypeStruct((G,), jnp.int32),
        }
        ba = _fix_batch(
            {"feats": ("batch", None, None), "src": ("batch", None),
             "dst": ("batch", None), "labels": ("batch",)}, mesh, G)
        step, args = _train_shapes(spec, cfg, _gnn_init,
                                   gnn_m.loss_batched_graphs,
                                   batch_shapes, ba, mesh)
        flops = 2.0 * G * (Ne * h + Nn * (d * h + h * h) * 1) * L_ * 3
        return CellBuild(step, args, (0, 1), flops, f"molecule G={G}")

    if cell.name == "minibatch_lg":
        seeds = p["batch_nodes"]
        f1, f2 = p["fanout"]
        n_pad = seeds * (1 + f1 + f1 * f2)
        e_pad = seeds * (f1 + f1 * f2)
        N, E = n_pad, e_pad
    else:
        N, E = p["n_nodes"], p["n_edges"]
    E += (-E) % mesh.size  # edge list tiles evenly over the mesh (-1 pad)
    N += (-N) % mesh.size  # node dim sharded for the per-node MLPs
    batch_shapes = {
        "feats": jax.ShapeDtypeStruct((N, d), jnp.float32),
        "src": jax.ShapeDtypeStruct((E,), jnp.int32),
        "dst": jax.ShapeDtypeStruct((E,), jnp.int32),
        "labels": jax.ShapeDtypeStruct((N,), jnp.int32),
        "label_mask": jax.ShapeDtypeStruct((N,), jnp.bool_),
    }
    ba = {"feats": (None, None), "src": ("edges",), "dst": ("edges",),
          "labels": (None,), "label_mask": (None,)}
    step, args = _train_shapes(spec, cfg, _gnn_init,
                               gnn_m.loss_full_graph,
                               batch_shapes, ba, mesh)
    mm = d * h + (L_ - 1) * h * h + L_ * h * h
    flops = 2.0 * 3 * (N * mm + L_ * E * h)
    return CellBuild(step, args, (0, 1), flops, f"gnn N={N} E={E}")


# -- RecSys -------------------------------------------------------------------


_REC_FNS = {
    "dlrm-rm2": (rec_m.init_dlrm, rec_m.dlrm_loss, rec_m.dlrm_forward,
                 rec_m.dlrm_user_embedding, "tables"),
    "din": (rec_m.init_din, rec_m.din_loss, rec_m.din_forward,
            lambda p, b, c: rec_m.din_user_embedding(p, b, c)[0], "items"),
    "sasrec": (rec_m.init_sasrec, rec_m.sasrec_loss,
               lambda p, b, c: rec_m.sasrec_user_embedding(p, b, c),
               rec_m.sasrec_user_embedding, "items"),
    "mind": (rec_m.init_mind, rec_m.mind_loss,
             lambda p, b, c: rec_m.mind_user_embedding(p, b, c),
             rec_m.mind_user_embedding, "items"),
}


def _recsys_batch(arch_id: str, cfg, B: int, mesh, with_label=True):
    i32, f32 = jnp.int32, jnp.float32
    if arch_id == "dlrm-rm2":
        shapes = {"dense": jax.ShapeDtypeStruct((B, cfg.n_dense), f32),
                  "sparse": jax.ShapeDtypeStruct((B, cfg.n_sparse), i32)}
        axes = {"dense": ("batch", None), "sparse": ("batch", None)}
    elif arch_id == "din":
        shapes = {"hist": jax.ShapeDtypeStruct((B, cfg.seq_len), i32),
                  "target": jax.ShapeDtypeStruct((B,), i32)}
        axes = {"hist": ("batch", None), "target": ("batch",)}
    elif arch_id == "sasrec":
        shapes = {"hist": jax.ShapeDtypeStruct((B, cfg.seq_len), i32),
                  "pos": jax.ShapeDtypeStruct((B, cfg.seq_len), i32),
                  "neg": jax.ShapeDtypeStruct((B, cfg.seq_len), i32)}
        axes = {"hist": ("batch", None), "pos": ("batch", None),
                "neg": ("batch", None)}
    elif arch_id == "mind":
        shapes = {"hist": jax.ShapeDtypeStruct((B, cfg.seq_len), i32),
                  "target": jax.ShapeDtypeStruct((B,), i32),
                  "neg": jax.ShapeDtypeStruct((B, 8), i32)}
        axes = {"hist": ("batch", None), "target": ("batch",),
                "neg": ("batch", None)}
    else:
        raise ValueError(arch_id)
    if with_label:
        shapes["label"] = jax.ShapeDtypeStruct((B,), f32)
        axes["label"] = ("batch",)
    return shapes, _fix_batch(axes, mesh, B)


def _rec_flops(arch_id, cfg, B):
    if arch_id == "dlrm-rm2":
        mlps = sum(a * b for a, b in zip((cfg.n_dense,) + cfg.bot_mlp[:-1],
                                         cfg.bot_mlp))
        n_inter = (cfg.n_sparse + 1)
        top_in = n_inter * (n_inter - 1) // 2 + cfg.bot_mlp[-1]
        mlps += sum(a * b for a, b in zip((top_in,) + cfg.top_mlp[:-1],
                                          cfg.top_mlp))
        inter = n_inter * n_inter * cfg.d_embed
        return 2.0 * B * (mlps + inter)
    if arch_id == "din":
        d = cfg.d_embed
        attn = cfg.seq_len * (4 * d * cfg.attn_mlp[0]
                              + cfg.attn_mlp[0] * cfg.attn_mlp[1])
        out = 3 * d * cfg.mlp[0] + cfg.mlp[0] * cfg.mlp[1]
        return 2.0 * B * (attn + out)
    if arch_id == "sasrec":
        d, T = cfg.d_embed, cfg.seq_len
        per_block = T * (4 * d * d) + 2 * T * T * d + T * 2 * d * d
        return 2.0 * B * cfg.n_blocks * per_block
    if arch_id == "mind":
        d, T, K = cfg.d_embed, cfg.seq_len, cfg.n_interests
        return 2.0 * B * (T * d * d + cfg.capsule_iters * 2 * T * K * d)
    raise ValueError(arch_id)


def _build_recsys(spec: ArchSpec, cell: ShapeCell, mesh, cfg) -> CellBuild:
    init, loss, fwd, user_fn, table_name = _REC_FNS[spec.arch_id]
    B = cell.params["batch"]
    p_shapes, p_axes = _shapes_and_axes(init, cfg)
    flops = _rec_flops(spec.arch_id, cfg, B)

    if cell.kind == "train":
        bs, ba = _recsys_batch(spec.arch_id, cfg, B, mesh)
        step, args = _train_shapes(spec, cfg, init, loss, bs, ba, mesh)
        return CellBuild(step, args, (0, 1), 3 * flops, f"train B={B}")
    if cell.kind == "serve":
        bs, ba = _recsys_batch(spec.arch_id, cfg, B, mesh, with_label=False)

        def step(params, batch):
            return fwd(params, batch, cfg)

        return CellBuild(step, (_shaped(p_shapes, p_axes, mesh),
                                _shaped(bs, ba, mesh)), (),
                         flops, f"serve B={B}")
    if cell.kind == "retrieval":
        C = cell.params["n_candidates"]
        bs, ba = _recsys_batch(spec.arch_id, cfg, B, mesh, with_label=False)

        def step(params, batch):
            u = user_fn(params, batch, cfg)
            cand = params[table_name]
            if cand.ndim == 3:          # stacked dlrm tables: table 0
                cand = cand[0]
            cand = cand[:C]
            return rec_m.retrieval_topk(u, cand, k=100)

        return CellBuild(step, (_shaped(p_shapes, p_axes, mesh),
                                _shaped(bs, ba, mesh)), (),
                         flops + 2.0 * B * C * cfg.d_embed,
                         f"retrieval B={B} C={C}")
    raise ValueError(cell.kind)


# -- autocomplete (the paper's own serving workload) -------------------------


def _build_autocomplete(spec: ArchSpec, cell: ShapeCell, mesh, cfg) -> CellBuild:
    """Dry-run spec for the sharded completion index: synthetic trie arrays
    of the configured size, queries sharded over dp."""
    from repro.core import engine as eng
    from repro.core.distributed import sharded_complete

    p = cell.params
    n_model = sh.model_size(mesh)
    B, Lq, k = p["batch"], p["query_len"], p["k"]
    n = p["nodes_per_shard"]
    e = p["edges_per_shard"]
    i32 = jnp.int32

    def shard_arr(shape, dtype=i32):
        return _sds((n_model,) + shape, dtype, ("rows",) + (None,) * len(shape),
                    mesh)

    K = max(p.get("cache_k", 0), 1)
    trie = eng.DeviceTrie(
        depth=shard_arr((n,)), max_score=shard_arr((n,)),
        leaf_score=shard_arr((n,)), leaf_sid=shard_arr((n,)),
        syn_mask=shard_arr((n,), jnp.bool_), tout=shard_arr((n,)),
        first_child=shard_arr((n + 1,)), edge_char=shard_arr((e,)),
        edge_child=shard_arr((e,)),
        s_first_child=shard_arr((n + 1,)),
        s_edge_char=shard_arr((max(e // 8, 1),)),
        s_edge_child=shard_arr((max(e // 8, 1),)),
        emit_ptr=shard_arr((n + 1,)), emit_node=shard_arr((e + n,)),
        emit_score=shard_arr((e + n,)),
        emit_is_leaf=shard_arr((e + n,), jnp.bool_),
        tele_plane=shard_arr((n, 2)),
        link_ptr=shard_arr((n + 1,)),
        link_rule=shard_arr((max(e // 4, 1),)),
        link_target=shard_arr((max(e // 4, 1),)),
        r_first_child=shard_arr((p["rule_nodes"] + 1,)),
        r_edge_char=shard_arr((p["rule_nodes"],)),
        r_edge_child=shard_arr((p["rule_nodes"],)),
        r_term_plane=shard_arr((p["rule_nodes"], 2)),
        r_rule_len=shard_arr((p["rules"],)),
        topk_score=shard_arr((n, K)), topk_sid=shard_arr((n, K)),
    )
    ecfg = eng.EngineConfig(
        frontier=16, gens=32, expand=8, max_steps=64,
        rule_matches=2, max_lhs_len=12, max_terms_per_node=2, teleports=2,
        tele_width=2, term_width=2,
        use_cache=p.get("cache_k", 0) > 0, cache_k=p.get("cache_k", 0))
    qs = _sds((B, Lq), i32, ("batch", None), mesh)
    qlens = _sds((B,), i32, ("batch",), mesh)

    def step(trie, qs, qlens):
        return sharded_complete(trie, ecfg, qs, qlens, k, mesh=mesh,
                                sid_stride=10**7,
                                data_axes=sh.dp_axes(mesh))

    # locus DP gathers + beam steps: count gather/compare ops as "flops"
    flops = B * (Lq * ecfg.frontier * 64 + ecfg.max_steps * ecfg.expand * 8)
    return CellBuild(step, (trie, qs, qlens), (),
                     flops, f"autocomplete B={B} L={Lq}")


def build_cell(spec: ArchSpec, shape_name: str, mesh,
               smoke: bool = False) -> CellBuild:
    cell = spec.shapes[shape_name]
    if cell.skip:
        raise ValueError(f"cell {spec.arch_id}/{shape_name} is skipped: "
                         f"{cell.skip}")
    if spec.family == "lm":
        import dataclasses
        cfg = spec.make_smoke_config() if smoke else spec.make_config()
        cfg = dataclasses.replace(cfg, tp_heads=sh.model_size(mesh))
        return _build_lm(spec, cell, mesh, cfg)
    if spec.family == "gnn":
        cfg = _gnn_cfg_for_cell(spec, cell, smoke)
        return _build_gnn(spec, cell, mesh, cfg)
    if spec.family == "recsys":
        cfg = spec.make_smoke_config() if smoke else spec.make_config()
        return _build_recsys(spec, cell, mesh, cfg)
    if spec.family == "autocomplete":
        cfg = spec.make_smoke_config() if smoke else spec.make_config()
        return _build_autocomplete(spec, cell, mesh, cfg)
    raise ValueError(spec.family)
