"""sasrec [arXiv:1808.09781; paper]
embed_dim=50 n_blocks=2 n_heads=1 seq_len=50 interaction=self-attn-seq."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys import SASRecConfig
from repro.optim import OptimizerConfig

def make_config():
    return SASRecConfig(name="sasrec", vocab=1_000_000)

def make_smoke_config():
    return SASRecConfig(name="sasrec-smoke", vocab=1000, seq_len=12,
                        d_embed=16)

SPEC = register(ArchSpec(
    arch_id="sasrec", family="recsys", source="arXiv:1808.09781",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=dict(RECSYS_SHAPES),
    optimizer=OptimizerConfig(name="adamw", lr=1e-3)))
