"""qwen2.5-14b [hf:Qwen/Qwen2.5-14B; hf]
48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064, QKV bias."""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig
from repro.optim import OptimizerConfig

def make_config():
    return TransformerConfig(
        name="qwen2.5-14b", n_layers=48, d_model=5120, n_heads=40, n_kv=8,
        d_head=128, d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1e6,
        activation_dtype="bfloat16")

def make_smoke_config():
    return TransformerConfig(
        name="qwen-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_head=16, d_ff=128, vocab=256, qkv_bias=True, loss_chunk=16)

SPEC = register(ArchSpec(
    arch_id="qwen2.5-14b", family="lm", source="hf:Qwen/Qwen2.5-14B",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ctx_ok=False),
    optimizer=OptimizerConfig(name="adamw", lr=3e-4)))
