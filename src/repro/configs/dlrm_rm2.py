"""dlrm-rm2 [arXiv:1906.00091; paper]
n_dense=13 n_sparse=26 embed_dim=64 bot=13-512-256-64 top=512-512-256-1
interaction=dot; 26 x 1M-row tables, row-sharded over `model`."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys import DLRMConfig
from repro.optim import OptimizerConfig

def make_config():
    return DLRMConfig(name="dlrm-rm2", vocab=1_000_000)

def make_smoke_config():
    return DLRMConfig(name="dlrm-smoke", vocab=1000,
                      bot_mlp=(32, 16, 8), top_mlp=(32, 16, 1), d_embed=8)

SPEC = register(ArchSpec(
    arch_id="dlrm-rm2", family="recsys", source="arXiv:1906.00091",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=dict(RECSYS_SHAPES),
    optimizer=OptimizerConfig(name="adamw", lr=1e-3)))
