"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8."""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig
from repro.optim import OptimizerConfig

def make_config():
    return TransformerConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv=8, d_head=64, d_ff=512, vocab=49155, moe_experts=32,
        moe_top_k=8, rope_theta=10_000.0,
        activation_dtype="bfloat16")

def make_smoke_config():
    return TransformerConfig(
        name="granite-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
        d_head=16, d_ff=32, vocab=128, moe_experts=4, moe_top_k=2,
        loss_chunk=16)

SPEC = register(ArchSpec(
    arch_id="granite-moe-1b-a400m", family="lm",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=lm_shapes(long_ctx_ok=False),
    optimizer=OptimizerConfig(name="adamw", lr=3e-4),
    notes="32-expert top-8 MoE; EP over `model` via shard_map island."))
