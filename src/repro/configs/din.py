"""din [arXiv:1706.06978; paper]
embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80 interaction=target-attn."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES, register
from repro.models.recsys import DINConfig
from repro.optim import OptimizerConfig

def make_config():
    return DINConfig(name="din", vocab=1_000_000)

def make_smoke_config():
    return DINConfig(name="din-smoke", vocab=1000, seq_len=12,
                     attn_mlp=(16, 8), mlp=(24, 12))

SPEC = register(ArchSpec(
    arch_id="din", family="recsys", source="arXiv:1706.06978",
    make_config=make_config, make_smoke_config=make_smoke_config,
    shapes=dict(RECSYS_SHAPES),
    optimizer=OptimizerConfig(name="adamw", lr=1e-3)))
