"""Import every architecture so base.REGISTRY is populated."""
from repro.configs import (arctic_480b, autocomplete, din, dlrm_rm2, gin_tu,  # noqa: F401
                           granite_moe_1b_a400m, h2o_danube_1_8b, mind,
                           mistral_nemo_12b, qwen2_5_14b, sasrec)

from repro.configs.base import REGISTRY, all_archs, get_arch  # noqa: F401

ASSIGNED = [
    "granite-moe-1b-a400m", "arctic-480b", "mistral-nemo-12b",
    "h2o-danube-1.8b", "qwen2.5-14b", "gin-tu",
    "mind", "sasrec", "din", "dlrm-rm2",
]
BONUS = ["autocomplete-dblp", "autocomplete-usps", "autocomplete-sprot"]
