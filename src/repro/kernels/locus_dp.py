"""Pallas TPU kernel: fused synonym-aware locus DP (phase 1, tt/et/ht).

The paper's core walk — reach[pos] = trie nodes reachable by consuming
p[:pos] under some rewriting — fused into one kernel per query block.
The pure-jnp path (`engine/locus.py`) runs the same sweep as a vmap of a
per-query `fori_loop` whose every inner step (CSR child lookup, teleport
gather, link-store search, dedup-compaction) is a separate XLA op; this
kernel keeps the whole (L+1, F) frontier buffer resident in VMEM scratch
and executes the sweep as masked fixed-trip loops over the packed rule
plane (`trie_build.pack_rule_planes`):

- literal char step: binary-searched CSR child lookup over the dict and
  synonym-branch edge sets;
- teleports (ET/HT): one vectorized gather from the dense, -1-padded
  ``tele_plane``;
- rule steps (TT/HT): the rule-trie descent is inlined per position, so
  every full-lhs match lands at a *static* end offset and the link-store
  step (one ``link_ptr`` load + one binary search over ``link_rule``)
  merges straight into the matching frontier row;
- dedup-compaction: one sort + rank-scatter per merge, bit-identical to
  ``primitives.dedup_pad``;
- finalization: synonym-loci drop + dedup + preorder-interval antichain
  reduction, all in-block.

Every trip count (L, max_lhs_len, terms/node, frontier width, binary
search rounds) is static, so there is no data-dependent control flow —
the VPU executes the whole sweep without divergence.  Results (loci and
overflow counts) are bit-identical to the jnp reference engine; the
substrate parity suite enforces this in interpret mode on CPU.

The CSR tables and the rule plane are VMEM-resident like the trie-walk
kernel's; `PallasSubstrate.can_walk_batch` probes the static sizes and
falls back to the jnp DP when a configuration outgrows the kernel.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# plain python ints: jnp scalars would be captured as constants by the
# pallas kernel tracer
_INT_MAX = 2**31 - 1
_NEG_ONE = -1


def _iters(n: int) -> int:
    """Binary-search trip count for an n-row table (matches
    ``primitives.iters_for``)."""
    return max(1, int(math.ceil(math.log2(max(n, 1) + 1))))


def _lower_bound(arr, lo, hi, x, iters: int):
    """First index in [lo, hi) with arr[idx] >= x (fixed trips)."""
    size = max(int(arr.shape[0]), 1)
    for _ in range(iters):
        cont = lo < hi
        mid = (lo + hi) >> 1
        v = jnp.take(arr, jnp.clip(mid, 0, size - 1))
        go_right = v < x
        lo = jnp.where(cont & go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
    return lo


def _csr_children(ptr, chars, children, nodes, ch):
    """children[nodes] labelled ch; -1 propagated/absent.  nodes and ch
    broadcast together (same semantics as ``primitives.csr_child_lookup``)."""
    valid = nodes >= 0
    nn = jnp.where(valid, nodes, 0)
    lo = jnp.take(ptr, nn)
    hi = jnp.take(ptr, nn + 1)
    pos = _lower_bound(chars, lo, hi, ch, _iters(int(chars.shape[0])))
    size = max(int(chars.shape[0]), 1)
    posc = jnp.clip(pos, 0, size - 1)
    found = (pos < hi) & (jnp.take(chars, posc) == ch) & valid & (ch >= 0)
    return jnp.where(found, jnp.take(children, posc), _NEG_ONE)


def _dedup(cand, width: int):
    """Row-wise unique-compact of cand [BQ, V] to [BQ, width] ascending,
    -1 padded; returns (out, n_dropped[BQ]).  Bit-identical to
    ``primitives.dedup_pad`` per row (same sort + rank-scatter)."""
    bq, v = cand.shape
    big = jnp.where(cand < 0, _INT_MAX, cand)
    s = jnp.sort(big, axis=1)
    idx = jax.lax.broadcasted_iota(jnp.int32, (bq, v), 1)
    keep = (idx == 0) | (s != jnp.roll(s, 1, axis=1))
    keep &= s != _INT_MAX
    rank = jnp.cumsum(keep, axis=1) - 1          # position among kept
    n_uniq = (rank[:, -1] + 1).astype(jnp.int32)
    dst = jnp.where(keep & (rank < width), rank, width)  # width = drop slot
    rows = jax.lax.broadcasted_iota(jnp.int32, (bq, v), 0)
    out = jnp.full((bq, width + 1), _NEG_ONE, jnp.int32)
    out = out.at[rows, dst].set(s, mode="drop")
    out = jnp.where(out == _INT_MAX, _NEG_ONE, out)[:, :width]
    return out, jnp.maximum(n_uniq - width, 0).astype(jnp.int32)


def _plane_rows(plane, nodes):
    """Gather full plane rows for a node vector: plane [N, W], nodes
    [BQ] or [BQ, F] -> [..., W] (rows of invalid nodes read row 0 and are
    masked by the caller)."""
    w = int(plane.shape[1])
    offs = jnp.arange(w, dtype=jnp.int32)
    idx = nodes[..., None] * w + offs
    return jnp.take(plane.reshape(-1), idx)


def _tele_expand(tele_plane, row, width: int):
    """Frontier row [BQ, F] -> row plus teleport targets, dedup'd back."""
    bq, f = row.shape
    valid = row >= 0
    nn = jnp.where(valid, row, 0)
    tgt = jnp.where(valid[:, :, None], _plane_rows(tele_plane, nn), _NEG_ONE)
    return _dedup(jnp.concatenate([row, tgt.reshape(bq, -1)], axis=1), width)


def _link_lookup(link_ptr, link_rule, link_target, anchors, rid):
    """(anchor, rule) -> target or -1.  anchors [BQ, F], rid [BQ]."""
    n_link = int(link_rule.shape[0])
    valid = anchors >= 0
    a = jnp.where(valid, anchors, 0)
    lo = jnp.take(link_ptr, a)
    hi = jnp.take(link_ptr, a + 1)
    pos = _lower_bound(link_rule, lo, hi, rid[:, None], _iters(n_link))
    posc = jnp.clip(pos, 0, max(n_link, 1) - 1)
    found = (pos < hi) & (jnp.take(link_rule, posc) == rid[:, None]) & valid
    return jnp.where(found, jnp.take(link_target, posc), _NEG_ONE)


def _kernel(fc_ref, ec_ref, echild_ref,
            sfc_ref, sec_ref, sechild_ref,
            syn_mask_ref, tout_ref, tele_ref,
            lptr_ref, lrule_ref, ltgt_ref,
            rfc_ref, rec_ref, rechild_ref, rterm_ref,
            q_ref, qlen_ref,
            loci_ref, ov_ref,
            buf_ref, *,
            frontier: int, rule_matches: int, max_lhs_len: int,
            max_terms: int, has_syn: bool, has_tele: bool, has_links: bool,
            seq_len: int):
    fc, ec, echild = fc_ref[...], ec_ref[...], echild_ref[...]
    syn_mask, tout = syn_mask_ref[...], tout_ref[...]
    q = q_ref[...]                                   # [BQ, L]
    qlen = qlen_ref[...]
    bq = q.shape[0]
    F, L, M = frontier, seq_len, rule_matches

    # frontier buffer: reach[pos] for every position, resident in scratch
    buf_ref[...] = jnp.full(
        (bq, L + 1, F), _NEG_ONE, jnp.int32).at[:, 0, 0].set(0)
    overflow = jnp.zeros((bq,), jnp.int32)

    for i in range(L):
        row = buf_ref[:, i, :]
        if has_tele:
            row, drop = _tele_expand(tele_ref[...], row, F)
            overflow += drop
        c = q[:, i]

        # literal char step: dict children + synonym-branch children
        parts = [_csr_children(fc, ec, echild, row, c[:, None])]
        if has_syn:
            parts.append(_csr_children(sfc_ref[...], sec_ref[...],
                                       sechild_ref[...], row, c[:, None]))
        merged, drop = _dedup(
            jnp.concatenate([buf_ref[:, i + 1, :]] + parts, axis=1), F)
        overflow += drop
        buf_ref[:, i + 1, :] = merged

        # rule steps: inline rule-trie descent from position i; a full-lhs
        # match at depth j lands at the static frontier row i + j + 1
        if M > 0:
            amask = (row >= 0) & \
                (jnp.take(syn_mask, jnp.where(row >= 0, row, 0)) == 0)
            anchors = jnp.where(amask, row, _NEG_ONE)
            node = jnp.zeros((bq,), jnp.int32)       # rule-trie root
            cnt = jnp.zeros((bq,), jnp.int32)
            for j in range(min(max_lhs_len, L - i)):
                node = _csr_children(rfc_ref[...], rec_ref[...],
                                     rechild_ref[...], node, q[:, i + j])
                ok = node >= 0
                terms = _plane_rows(rterm_ref[...],
                                    jnp.where(ok, node, 0))  # [BQ, Tw]
                end = i + j + 1
                for j2 in range(max_terms):
                    rid = terms[:, j2]
                    has = ok & (rid >= 0) & (cnt < M)
                    cnt = jnp.where(has, cnt + 1, cnt)
                    if has_links:
                        tgt = _link_lookup(lptr_ref[...], lrule_ref[...],
                                           ltgt_ref[...], anchors, rid)
                        tgt = jnp.where(has[:, None], tgt, _NEG_ONE)
                    else:
                        tgt = jnp.full((bq, F), _NEG_ONE, jnp.int32)
                    dst = buf_ref[:, end, :]
                    merged, drop = _dedup(
                        jnp.concatenate([dst, tgt], axis=1), F)
                    any_tgt = (tgt >= 0).any(axis=1)
                    merged = jnp.where(any_tgt[:, None], merged, dst)
                    overflow += jnp.where(any_tgt, drop, 0)
                    buf_ref[:, end, :] = merged

    # final frontier: the row at each query's own length
    buf = buf_ref[...]
    sel = jnp.broadcast_to(jnp.clip(qlen, 0, L)[:, None, None], (bq, 1, F))
    row = jnp.take_along_axis(buf, sel, axis=1)[:, 0, :]
    if has_tele:
        row, drop = _tele_expand(tele_ref[...], row, F)
        overflow += drop

    # finalize: strict semantics drop mid-variant (synonym) loci, then
    # antichain reduction over preorder intervals [id, tout)
    is_syn = jnp.take(syn_mask, jnp.where(row >= 0, row, 0))
    row = jnp.where((row >= 0) & (is_syn == 0), row, _NEG_ONE)
    row, _ = _dedup(row, F)
    tin = jnp.where(row >= 0, row, _NEG_ONE)
    to = jnp.take(tout, jnp.where(row >= 0, row, 0))
    tin_i, tin_j = tin[:, :, None], tin[:, None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (bq, F, F), 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (bq, F, F), 2)
    covered = ((tin_j <= tin_i) & (tin_i < to[:, None, :]) & (ii != jj)
               & (tin_j >= 0) & (tin_i >= 0)).any(axis=2)
    loci_ref[...] = jnp.where(covered, _NEG_ONE, row)
    ov_ref[...] = overflow


@functools.partial(jax.jit, static_argnames=(
    "frontier", "rule_matches", "max_lhs_len", "max_terms", "has_syn",
    "has_tele", "has_links", "block_q", "interpret"))
def locus_dp_walk(first_child, edge_char, edge_child,
                  s_first_child, s_edge_char, s_edge_child,
                  syn_mask, tout, tele_plane,
                  link_ptr, link_rule, link_target,
                  r_first_child, r_edge_char, r_edge_child, r_term_plane,
                  queries, qlens, *,
                  frontier: int, rule_matches: int, max_lhs_len: int,
                  max_terms: int, has_syn: bool, has_tele: bool,
                  has_links: bool, block_q: int = 8, interpret: bool = True):
    """Fused locus DP over a query batch.

    queries int32[B, L] (-1 padded, B divisible by block_q; the wrapper in
    ops.py pads), qlens int32[B].  Tables are the DeviceTrie arrays with
    empties padded to length 1 (gated off by the ``has_*`` statics).
    Returns (loci[B, F] finalized antichains, overflow[B]) — bit-identical
    to ``jax.vmap(engine.locus.locus_dp)`` on the jnp substrate.
    """
    bsz, seq_len = queries.shape
    F = frontier
    grid = (bsz // block_q,)

    def full(a):
        shape = tuple(int(s) for s in a.shape)
        return pl.BlockSpec(shape, (lambda i: (0,) * len(shape)))

    kernel = functools.partial(
        _kernel, frontier=F, rule_matches=rule_matches,
        max_lhs_len=max_lhs_len, max_terms=max_terms, has_syn=has_syn,
        has_tele=has_tele, has_links=has_links, seq_len=seq_len)
    tables = [first_child, edge_char, edge_child,
              s_first_child, s_edge_char, s_edge_child,
              syn_mask, tout, tele_plane,
              link_ptr, link_rule, link_target,
              r_first_child, r_edge_char, r_edge_child, r_term_plane]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[full(a) for a in tables] + [
            pl.BlockSpec((block_q, seq_len), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, F), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, F), jnp.int32),
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, seq_len + 1, F), jnp.int32),
        ],
        interpret=interpret,
    )(*tables, queries, qlens)
