"""Pallas TPU kernel: fused synonym-aware locus DP (phase 1, tt/et/ht).

The paper's core walk — reach[pos] = trie nodes reachable by consuming
p[:pos] under some rewriting — fused into one kernel per query block.
The pure-jnp path (`engine/locus.py`) runs the same sweep as a vmap of a
per-query `fori_loop` whose every inner step (CSR child lookup, teleport
gather, link-store search, dedup-compaction) is a separate XLA op; this
kernel carries the whole (L+1, F) frontier buffer on-chip through one
block-wide position loop and executes the sweep as masked fixed-trip
loops over the packed rule plane (`trie_build.pack_rule_planes`):

- literal char step: binary-searched CSR child lookup over the dict and
  synonym-branch edge sets;
- teleports (ET/HT): one vectorized gather from the dense, -1-padded
  ``tele_plane``;
- rule steps (TT/HT): the rule-trie descent is inlined per position, so
  every full-lhs match lands at a *static* end offset and the link-store
  step (one ``link_ptr`` load + one binary search over ``link_rule``)
  merges straight into the matching frontier row;
- dedup-compaction: one sort + rank-scatter per merge, bit-identical to
  ``primitives.dedup_pad``;
- finalization: synonym-loci drop + dedup + preorder-interval antichain
  reduction, all in-block.

Every trip count (L, max_lhs_len, terms/node, frontier width, binary
search rounds) is static, so there is no data-dependent control flow —
the VPU executes the whole sweep without divergence.  Results (loci and
overflow counts) are bit-identical to the jnp reference engine; the
substrate parity suite enforces this in interpret mode on CPU.

The sweep body is written once against a small table-accessor seam and
runs in two tiers:

- *resident* (``locus_dp_walk``): every table and the rule plane live
  whole in VMEM, like the trie-walk kernel's CSRs;
- *streamed* (``locus_dp_walk_streamed``): the dictionary-sized tables
  (dict/synonym CSRs, ``syn_mask``/``tout``, teleport-plane rows and the
  link store) stay in HBM and each access double-buffers pointer pairs /
  row windows / plane rows into VMEM scratch via ``make_async_copy``
  (:mod:`repro.kernels.stream`); only the rule trie — sized by the rule
  set, thousands of entries, not the dictionary — stays VMEM-resident.
  The tile-aligned layout (``trie_build.pack_stream_tiles``) guarantees
  one window covers any CSR row, so the in-window searches probe exactly
  what the resident forms probe: both tiers are bit-identical to the
  reference DP.

`PallasSubstrate.can_walk_batch` probes the static shape envelope and
picks the tier by comparing table bytes against the VMEM budget; shapes
outside the envelope fall back to the jnp DP.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.stream import (StreamTable, row_take, stream_csr_children,
                                  window_lower_bound)

# plain python ints: jnp scalars would be captured as constants by the
# pallas kernel tracer
_INT_MAX = 2**31 - 1
_NEG_ONE = -1


def _iters(n: int) -> int:
    """Binary-search trip count for an n-row table (matches
    ``primitives.iters_for``)."""
    return max(1, int(math.ceil(math.log2(max(n, 1) + 1))))


def _lower_bound(arr, lo, hi, x, iters: int):
    """First index in [lo, hi) with arr[idx] >= x (fixed trips)."""
    size = max(int(arr.shape[0]), 1)
    for _ in range(iters):
        cont = lo < hi
        mid = (lo + hi) >> 1
        v = jnp.take(arr, jnp.clip(mid, 0, size - 1))
        go_right = v < x
        lo = jnp.where(cont & go_right, mid + 1, lo)
        hi = jnp.where(cont & ~go_right, mid, hi)
    return lo


def _csr_children(ptr, chars, children, nodes, ch):
    """children[nodes] labelled ch; -1 propagated/absent.  nodes and ch
    broadcast together (same semantics as ``primitives.csr_child_lookup``)."""
    valid = nodes >= 0
    nn = jnp.where(valid, nodes, 0)
    lo = jnp.take(ptr, nn)
    hi = jnp.take(ptr, nn + 1)
    pos = _lower_bound(chars, lo, hi, ch, _iters(int(chars.shape[0])))
    size = max(int(chars.shape[0]), 1)
    posc = jnp.clip(pos, 0, size - 1)
    found = (pos < hi) & (jnp.take(chars, posc) == ch) & valid & (ch >= 0)
    return jnp.where(found, jnp.take(children, posc), _NEG_ONE)


def _enc(nodes, d, E: int):
    """Pack (node, edits-used d) into one frontier state: node*(E+1)+d.
    Identity at E=0 (exact-mode traces untouched); -1 stays -1.  Mirrors
    ``engine.locus.encode_states``."""
    if E == 0:
        return nodes
    return jnp.where(nodes < 0, _NEG_ONE, nodes * (E + 1) + d)


def _dec(states, E: int):
    """Inverse of :func:`_enc`: (nodes, d); -1 -> (-1, 0)."""
    if E == 0:
        return states, jnp.zeros_like(states)
    nodes = jnp.where(states < 0, _NEG_ONE, states // (E + 1))
    d = jnp.where(states < 0, 0, states % (E + 1))
    return nodes, d


def _dedup(cand, width: int):
    """Row-wise unique-compact of cand [BQ, V] to [BQ, width] ascending,
    -1 padded; returns (out, n_dropped[BQ]).  Bit-identical to
    ``primitives.dedup_pad`` per row (same sort + rank-scatter)."""
    bq, v = cand.shape
    big = jnp.where(cand < 0, _INT_MAX, cand)
    s = jnp.sort(big, axis=1)
    idx = jax.lax.broadcasted_iota(jnp.int32, (bq, v), 1)
    keep = (idx == 0) | (s != jnp.roll(s, 1, axis=1))
    keep &= s != _INT_MAX
    rank = jnp.cumsum(keep, axis=1) - 1          # position among kept
    n_uniq = (rank[:, -1] + 1).astype(jnp.int32)
    dst = jnp.where(keep & (rank < width), rank, width)  # width = drop slot
    rows = jax.lax.broadcasted_iota(jnp.int32, (bq, v), 0)
    out = jnp.full((bq, width + 1), _NEG_ONE, jnp.int32)
    out = out.at[rows, dst].set(s, mode="drop")
    out = jnp.where(out == _INT_MAX, _NEG_ONE, out)[:, :width]
    return out, jnp.maximum(n_uniq - width, 0).astype(jnp.int32)


def _plane_rows(plane, nodes):
    """Gather full plane rows for a node vector: plane [N, W], nodes
    [BQ] or [BQ, F] -> [..., W] (rows of invalid nodes read row 0 and are
    masked by the caller)."""
    w = int(plane.shape[1])
    offs = jnp.arange(w, dtype=jnp.int32)
    idx = nodes[..., None] * w + offs
    return jnp.take(plane.reshape(-1), idx)


def _link_lookup(link_ptr, link_rule, link_target, anchors, rid):
    """(anchor, rule) -> target or -1.  anchors [BQ, F], rid [BQ]."""
    n_link = int(link_rule.shape[0])
    valid = anchors >= 0
    a = jnp.where(valid, anchors, 0)
    lo = jnp.take(link_ptr, a)
    hi = jnp.take(link_ptr, a + 1)
    pos = _lower_bound(link_rule, lo, hi, rid[:, None], _iters(n_link))
    posc = jnp.clip(pos, 0, max(n_link, 1) - 1)
    found = (pos < hi) & (jnp.take(link_rule, posc) == rid[:, None]) & valid
    return jnp.where(found, jnp.take(link_target, posc), _NEG_ONE)


# ---------------------------------------------------------------------------
# table-accessor seam: the sweep body is tier-agnostic
# ---------------------------------------------------------------------------


class _ResidentTables:
    """VMEM-resident table reads (the original fused kernel's forms)."""

    def __init__(self, fc, ec, echild, sfc, sec, sechild, syn_mask, tout,
                 tele_plane, lptr, lrule, ltgt):
        self.fc, self.ec, self.echild = fc, ec, echild
        self.sfc, self.sec, self.sechild = sfc, sec, sechild
        self.syn_mask, self.tout_arr = syn_mask, tout
        self.tele_plane = tele_plane
        self.lptr, self.lrule, self.ltgt = lptr, lrule, ltgt

    def dict_children(self, nodes, ch):
        return _csr_children(self.fc, self.ec, self.echild, nodes, ch)

    def syn_children(self, nodes, ch):
        return _csr_children(self.sfc, self.sec, self.sechild, nodes, ch)

    def dict_child_window(self, nodes, width: int):
        """All dict children of each node: (chars, children) [..., width],
        -1 padded — the bounded-edit substitute/delete source.  The
        tile-aligned edge arrays are padded a whole tile past their real
        length and width <= walk_tile, so the window loads stay in
        bounds."""
        valid = nodes >= 0
        nn = jnp.where(valid, nodes, 0)
        lo = jnp.take(self.fc, nn)
        cnt = jnp.where(valid, jnp.take(self.fc, nn + 1) - lo, 0)
        js = jax.lax.broadcasted_iota(
            jnp.int32, tuple(nodes.shape) + (width,), nodes.ndim)
        size = max(int(self.ec.shape[0]), 1)
        idx = jnp.clip(lo[..., None] + js, 0, size - 1)
        m = js < cnt[..., None]
        chars = jnp.where(m, jnp.take(self.ec, idx), _NEG_ONE)
        children = jnp.where(m, jnp.take(self.echild, idx), _NEG_ONE)
        return chars, children

    def tele_rows(self, nodes):
        return _plane_rows(self.tele_plane, nodes)

    def syn_mask_of(self, nodes):
        return jnp.take(self.syn_mask, nodes)

    def tout_of(self, nodes):
        return jnp.take(self.tout_arr, nodes)

    def link_lookup(self, anchors, rid):
        return _link_lookup(self.lptr, self.lrule, self.ltgt, anchors, rid)


class _StreamedTables:
    """HBM-resident tables behind double-buffered windowed DMA.

    Every lookup streams the pointer pairs / row windows / plane rows it
    touches into the shared staging buffers and computes the same values
    the resident forms compute — the window always covers the whole row,
    so the in-window searches are bit-identical.
    """

    def __init__(self, fc_t, ec_t, ek_t, sfc_t, sec_t, sek_t, mask_t,
                 tout_t, tele_t, lptr_t, lrule_t, ltgt_t,
                 walk_iters: int, link_iters: int):
        self.fc_t, self.ec_t, self.ek_t = fc_t, ec_t, ek_t
        self.sfc_t, self.sec_t, self.sek_t = sfc_t, sec_t, sek_t
        self.mask_t, self.tout_t, self.tele_t = mask_t, tout_t, tele_t
        self.lptr_t, self.lrule_t, self.ltgt_t = lptr_t, lrule_t, ltgt_t
        self.walk_iters, self.link_iters = walk_iters, link_iters

    def dict_children(self, nodes, ch):
        return stream_csr_children(self.fc_t, self.ec_t, self.ek_t,
                                   nodes, ch, self.walk_iters)

    def syn_children(self, nodes, ch):
        return stream_csr_children(self.sfc_t, self.sec_t, self.sek_t,
                                   nodes, ch, self.walk_iters)

    def dict_child_window(self, nodes, width: int):
        """Streamed form of the resident window: the (lo, hi) pointer
        pairs and the ``[lo, lo + walk_tile)`` row windows ride the same
        staging buffers as the CSR child lookups; walk_tile >= the real
        fanout, so the returned (wider) window carries the same children,
        -1 beyond each row's count — content-identical to the resident
        window for every downstream merge."""
        del width   # the staged window is walk_tile wide; extras mask off
        valid = nodes >= 0
        nn = jnp.where(valid, nodes, 0)
        lo, hi = self.fc_t.pairs(nn)
        cnt = jnp.where(valid, hi - lo, 0)
        wc = self.ec_t.windows(lo)
        wk = self.ek_t.windows(lo)
        js = jax.lax.broadcasted_iota(jnp.int32, wc.shape, wc.ndim - 1)
        m = js < cnt[..., None]
        return jnp.where(m, wc, _NEG_ONE), jnp.where(m, wk, _NEG_ONE)

    def tele_rows(self, nodes):
        return self.tele_t.windows(nodes)

    def syn_mask_of(self, nodes):
        return self.mask_t.gather(nodes)

    def tout_of(self, nodes):
        return self.tout_t.gather(nodes)

    def link_lookup(self, anchors, rid):
        valid = anchors >= 0
        ridb = jnp.broadcast_to(rid[:, None], anchors.shape)
        a = jnp.where(valid, anchors, 0)
        lo, hi = self.lptr_t.pairs(a)
        span = hi - lo
        wr = self.lrule_t.windows(lo)
        w = int(wr.shape[-1])
        pos = window_lower_bound(wr, span, ridb, self.link_iters)
        posc = jnp.clip(pos, 0, w - 1)
        found = (pos < span) & \
            (row_take(wr, posc[..., None])[..., 0] == ridb) & valid
        tgt = row_take(self.ltgt_t.windows(lo), posc[..., None])[..., 0]
        return jnp.where(found, tgt, _NEG_ONE)


def _packed_rank(ids, nodes):
    """Position of each node in a sorted id table: (clipped_rank, exact).
    Mirrors ``engine.packed._rank``; a padded single ``-1`` row (empty
    table, see ops._nonempty) never matches a node id >= 0."""
    size = int(ids.shape[0])
    lo = jnp.zeros_like(nodes)
    hi = jnp.full_like(nodes, size)
    pos = _lower_bound(ids, lo, hi, nodes, _iters(size))
    rc = jnp.clip(pos, 0, max(size, 1) - 1)
    return rc, (pos < size) & (jnp.take(ids, rc) == nodes)


# p_flags bits (mirror engine.packed; plain ints for kernel tracing)
_PK_DICT_UNARY = 1
_PK_SYN_UNARY = 2
_PK_IS_SYN = 4


class _PackedResidentTables:
    """VMEM-resident reads of the compressed layout — the same forms as
    :mod:`repro.core.engine.packed`, lowered through the seam the sweep
    already speaks.  Narrow (u8) values widen to i32 at the read."""

    def __init__(self, labels, flags, c_ids, c_tout,
                 b_ids, b_ptr, b_char, b_child,
                 sb_ids, sb_ptr, sb_char, sb_child,
                 t_ids, tele_plane, la_ids, la_ptr, lrule, ltgt):
        self.labels, self.flags = labels, flags
        self.c_ids, self.c_tout = c_ids, c_tout
        self.b_ids, self.b_ptr = b_ids, b_ptr
        self.b_char, self.b_child = b_char, b_child
        self.sb_ids, self.sb_ptr = sb_ids, sb_ptr
        self.sb_char, self.sb_child = sb_char, sb_child
        self.t_ids, self.tele_plane = t_ids, tele_plane
        self.la_ids, self.la_ptr = la_ids, la_ptr
        self.lrule, self.ltgt = lrule, ltgt
        self.n_nodes = int(labels.shape[0])

    # the two N-sized plane reads — the only loads the streamed packed
    # tier overrides
    def _flags(self, nodes):
        return jnp.take(self.flags, nodes).astype(jnp.int32)

    def _label_next(self, nodes):
        return jnp.take(self.labels,
                        jnp.clip(nodes + 1, 0,
                                 self.n_nodes - 1)).astype(jnp.int32)

    def _children(self, ids, ptr, chars, children, unary_bit, nodes, ch):
        valid = nodes >= 0
        n = jnp.where(valid, nodes, 0)
        ok_u = ((self._flags(n) & unary_bit) != 0) \
            & (self._label_next(n) == ch) & valid & (ch >= 0)
        u_child = jnp.where(ok_u, n + 1, _NEG_ONE)
        rc, isrow = _packed_rank(ids, n)
        lo = jnp.take(ptr, rc)
        hi = jnp.where(isrow, jnp.take(ptr, rc + 1), lo)
        pos = _lower_bound(chars, lo, hi, ch, _iters(int(chars.shape[0])))
        posc = jnp.clip(pos, 0, max(int(chars.shape[0]), 1) - 1)
        found = (pos < hi) & \
            (jnp.take(chars, posc).astype(jnp.int32) == ch) \
            & valid & (ch >= 0)
        row_child = jnp.where(found, jnp.take(children, posc), _NEG_ONE)
        return jnp.where(isrow, row_child, u_child)

    def dict_children(self, nodes, ch):
        return self._children(self.b_ids, self.b_ptr, self.b_char,
                              self.b_child, _PK_DICT_UNARY, nodes, ch)

    def syn_children(self, nodes, ch):
        return self._children(self.sb_ids, self.sb_ptr, self.sb_char,
                              self.sb_child, _PK_SYN_UNARY, nodes, ch)

    def dict_child_window(self, nodes, width: int):
        """Packed form of the dict-child window (mirrors
        ``engine.packed.dict_child_window``): a unary node's window is its
        single (label, v+1) pair in column 0; branching nodes read their
        sparse ``b_*`` row.  Inherited by the streamed packed tier — only
        the flag/label plane reads differ there."""
        valid = nodes >= 0
        n = jnp.where(valid, nodes, 0)
        js = jax.lax.broadcasted_iota(
            jnp.int32, tuple(nodes.shape) + (width,), nodes.ndim)
        u_ok = (((self._flags(n) & _PK_DICT_UNARY) != 0) & valid)[..., None] \
            & (js == 0)
        chars = jnp.where(u_ok, self._label_next(n)[..., None], _NEG_ONE)
        children = jnp.where(u_ok, (n + 1)[..., None], _NEG_ONE)
        rc, isrow = _packed_rank(self.b_ids, n)
        lo = jnp.take(self.b_ptr, rc).astype(jnp.int32)
        cnt = jnp.where(isrow & valid,
                        jnp.take(self.b_ptr, rc + 1).astype(jnp.int32) - lo,
                        0)
        size = max(int(self.b_char.shape[0]), 1)
        idx = jnp.clip(lo[..., None] + js, 0, size - 1)
        m = js < cnt[..., None]
        chars = jnp.where(
            m, jnp.take(self.b_char, idx).astype(jnp.int32), chars)
        children = jnp.where(
            m, jnp.take(self.b_child, idx).astype(jnp.int32), children)
        return chars, children

    def tele_rows(self, nodes):
        rc, exact = _packed_rank(self.t_ids, nodes)
        rows = _plane_rows(self.tele_plane, rc)
        return jnp.where(exact[..., None], rows, _NEG_ONE)

    def syn_mask_of(self, nodes):
        # 0/IS_SYN int; the sweep only compares against 0
        return self._flags(nodes) & _PK_IS_SYN

    def tout_of(self, nodes):
        rc, _ = _packed_rank(self.c_ids, nodes)
        return jnp.where((self._flags(nodes) & _PK_IS_SYN) != 0,
                         nodes + 1, jnp.take(self.c_tout, rc))

    def link_lookup(self, anchors, rid):
        n_link = int(self.lrule.shape[0])
        valid = anchors >= 0
        a = jnp.where(valid, anchors, 0)
        rc, isrow = _packed_rank(self.la_ids, a)
        lo = jnp.take(self.la_ptr, rc)
        hi = jnp.where(isrow, jnp.take(self.la_ptr, rc + 1), lo)
        pos = _lower_bound(self.lrule, lo, hi, rid[:, None], _iters(n_link))
        posc = jnp.clip(pos, 0, max(n_link, 1) - 1)
        found = (pos < hi) & \
            (jnp.take(self.lrule, posc) == rid[:, None]) & valid
        return jnp.where(found, jnp.take(self.ltgt, posc), _NEG_ONE)


class _PackedStreamedTables(_PackedResidentTables):
    """Packed tier with the two N-sized u8 planes (labels/flags) DMA'd
    per access; every sparse side table — branch-count-sized, tiny next
    to the planes — stays VMEM-resident.  ``StreamTable.windows`` widens
    the u8 staging rows to i32, so the reads are the resident forms'."""

    def __init__(self, lbl_t, flg_t, *side):
        self.lbl_t, self.flg_t = lbl_t, flg_t
        (self.c_ids, self.c_tout,
         self.b_ids, self.b_ptr, self.b_char, self.b_child,
         self.sb_ids, self.sb_ptr, self.sb_char, self.sb_child,
         self.t_ids, self.tele_plane,
         self.la_ids, self.la_ptr, self.lrule, self.ltgt) = side
        self.n_nodes = int(lbl_t.hbm.shape[0])

    def _flags(self, nodes):
        return self.flg_t.gather(nodes)

    def _label_next(self, nodes):
        return self.lbl_t.gather(
            jnp.clip(nodes + 1, 0, self.n_nodes - 1))


def _tele_expand(tabs, row, width: int, E: int):
    """Frontier row [BQ, F] -> row plus teleport targets, dedup'd back.
    In bounded-edit mode targets inherit the source state's edit count."""
    bq, f = row.shape
    nodes, d = _dec(row, E)
    valid = nodes >= 0
    nn = jnp.where(valid, nodes, 0)
    tgt = jnp.where(valid[:, :, None], tabs.tele_rows(nn), _NEG_ONE)
    tgt = _enc(tgt, d[:, :, None], E)
    return _dedup(jnp.concatenate([row, tgt.reshape(bq, -1)], axis=1), width)


def _expand_frontier(tabs, row, width: int, E: int, BW: int,
                     has_tele: bool):
    """Teleport expansion + E-round delete closure — mirrors
    ``engine.locus.expand_frontier`` (teleports attach only to synonym
    nodes, deletes only descend dict children, so this order reaches the
    joint fixpoint)."""
    bq = row.shape[0]
    drop_total = jnp.zeros((bq,), jnp.int32)
    if has_tele:
        row, drop = _tele_expand(tabs, row, width, E)
        drop_total += drop
    for _ in range(E):
        nodes, d = _dec(row, E)
        _, children = tabs.dict_child_window(nodes, BW)
        ok = (children >= 0) & (d < E)[..., None]
        tgt = _enc(jnp.where(ok, children, _NEG_ONE), (d + 1)[..., None], E)
        row, drop = _dedup(
            jnp.concatenate([row, tgt.reshape(bq, -1)], axis=1), width)
        drop_total += drop
    return row, drop_total


def _sweep(tabs, rfc, rec, rechild, rterm, q, qlen,
           loci_ref, ov_ref, *,
           frontier: int, rule_matches: int, max_lhs_len: int,
           max_terms: int, has_syn: bool, has_tele: bool, has_links: bool,
           seq_len: int, edit_budget: int = 0, branch_width: int = 1):
    """The fused frontier sweep, written once against the accessor seam;
    ``tabs`` is resident or streamed, the rule trie (rfc/rec/rechild/
    rterm) is always VMEM-resident.

    The position loop is a ``fori_loop`` with the (BQ, L+1, F) frontier
    buffer as carried state (XLA keeps it on-chip), so the traced step
    body — and with it every DMA pipeline of the streamed tier — appears
    once instead of L times; inside the step the rule-trie descent and
    term fan-out stay unrolled over their static widths with masked
    out-of-range lanes, exactly the reference DP's shape.
    """
    bq = q.shape[0]
    F, L, M, E = frontier, seq_len, rule_matches, edit_budget
    BW = branch_width

    # write-back discipline (mirrors the jnp reference): each completed
    # row is expanded — teleports + delete closure — exactly once, as the
    # last write of the step that completes it, so step i reads buf[:, i]
    # ready-made.  Equivalent to the old expand-at-read style: every
    # write into row i+1 has landed by the end of step i, and
    # re-expanding an expanded row changes nothing and drops nothing.
    buf0 = jnp.full((bq, L + 1, F), _NEG_ONE, jnp.int32).at[:, 0, 0].set(0)
    ov0 = jnp.zeros((bq,), jnp.int32)
    if has_tele or E > 0:
        row0, drop0 = _expand_frontier(tabs, buf0[:, 0, :], F, E, BW,
                                       has_tele)
        buf0 = buf0.at[:, 0, :].set(row0)
        ov0 += drop0
    # query extended with -1s so the rule descent can probe past the end
    # of short suffixes (a -1 char kills the walk, like the reference's)
    qx = jnp.concatenate(
        [q, jnp.full((bq, max(max_lhs_len, 1)), _NEG_ONE, jnp.int32)],
        axis=1)

    def at_col(mat, i):
        return jax.lax.dynamic_slice(mat, (0, i), (bq, 1))[:, 0]

    def buf_row(buf, i):
        return jax.lax.dynamic_slice(buf, (0, i, 0), (bq, 1, F))[:, 0, :]

    def buf_put(buf, i, row):
        return jax.lax.dynamic_update_slice(buf, row[:, None, :], (0, i, 0))

    def step(i, carry):
        buf, overflow = carry
        row = buf_row(buf, i)
        c = at_col(q, i)
        nodes, d = _dec(row, E)

        # literal char step: dict children + synonym-branch children
        parts = [_enc(tabs.dict_children(nodes, c[:, None]), d, E)]
        if has_syn:
            parts.append(_enc(tabs.syn_children(nodes, c[:, None]), d, E))
        if E > 0:
            # substitute: any dict child whose edge char differs from c,
            # at d+1 (matching children already ride the literal part)
            wchars, wchildren = tabs.dict_child_window(nodes, BW)
            can = (c[:, None] >= 0) & (d < E)
            s_ok = can[..., None] & (wchildren >= 0) \
                & (wchars != c[:, None, None])
            parts.append(_enc(jnp.where(s_ok, wchildren, _NEG_ONE),
                              (d + 1)[..., None], E).reshape(bq, -1))
            # insert: stay put at d+1; synonym-branch chars must be typed
            # exactly, so mid-variant nodes don't absorb inserted chars
            n0 = jnp.where(nodes >= 0, nodes, 0)
            i_ok = can & (nodes >= 0) & (tabs.syn_mask_of(n0) == 0)
            parts.append(_enc(jnp.where(i_ok, nodes, _NEG_ONE), d + 1, E))
        merged, drop = _dedup(
            jnp.concatenate([buf_row(buf, i + 1)] + parts, axis=1), F)
        overflow += drop
        buf = buf_put(buf, i + 1, merged)

        # rule steps: inline rule-trie descent from position i; a full-lhs
        # match at depth j lands at the frontier row i + j + 1 (descents
        # running past the query end read the -1 extension and die).
        # Anchors must be dict nodes; the edit count carries through
        if M > 0:
            amask = (nodes >= 0) & \
                (tabs.syn_mask_of(jnp.where(nodes >= 0, nodes, 0)) == 0)
            anchors = jnp.where(amask, nodes, _NEG_ONE)
            node = jnp.zeros((bq,), jnp.int32)       # rule-trie root
            cnt = jnp.zeros((bq,), jnp.int32)
            for j in range(max_lhs_len):
                node = _csr_children(rfc, rec, rechild, node,
                                     at_col(qx, i + j))
                ok = node >= 0
                terms = _plane_rows(rterm,
                                    jnp.where(ok, node, 0))  # [BQ, Tw]
                end = jnp.clip(i + j + 1, 0, L)
                for j2 in range(max_terms):
                    rid = terms[:, j2]
                    has = ok & (rid >= 0) & (cnt < M)
                    cnt = jnp.where(has, cnt + 1, cnt)
                    if has_links:
                        tgt = tabs.link_lookup(anchors, rid)
                        tgt = jnp.where(has[:, None], tgt, _NEG_ONE)
                        tgt = _enc(tgt, d, E)
                    else:
                        tgt = jnp.full((bq, F), _NEG_ONE, jnp.int32)
                    dst = buf_row(buf, end)
                    merged, drop = _dedup(
                        jnp.concatenate([dst, tgt], axis=1), F)
                    any_tgt = (tgt >= 0).any(axis=1)
                    merged = jnp.where(any_tgt[:, None], merged, dst)
                    overflow += jnp.where(any_tgt, drop, 0)
                    buf = buf_put(buf, end, merged)

        # write-back: row i+1 is complete (rule ends are > i), expand it
        if has_tele or E > 0:
            nxt = buf_row(buf, i + 1)
            nxt, drop = _expand_frontier(tabs, nxt, F, E, BW, has_tele)
            overflow += drop
            buf = buf_put(buf, i + 1, nxt)
        return buf, overflow

    buf, overflow = jax.lax.fori_loop(0, L, step, (buf0, ov0))

    # final frontier: the row at each query's own length (already
    # expanded by the write-back discipline), decoded to plain node ids
    sel = jnp.broadcast_to(jnp.clip(qlen, 0, L)[:, None, None], (bq, 1, F))
    row = jnp.take_along_axis(buf, sel, axis=1)[:, 0, :]
    row = _dec(row, E)[0]

    # finalize: strict semantics drop mid-variant (synonym) loci, then
    # antichain reduction over preorder intervals [id, tout)
    is_syn = tabs.syn_mask_of(jnp.where(row >= 0, row, 0))
    row = jnp.where((row >= 0) & (is_syn == 0), row, _NEG_ONE)
    row, _ = _dedup(row, F)
    tin = jnp.where(row >= 0, row, _NEG_ONE)
    to = tabs.tout_of(jnp.where(row >= 0, row, 0))
    tin_i, tin_j = tin[:, :, None], tin[:, None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (bq, F, F), 1)
    jj = jax.lax.broadcasted_iota(jnp.int32, (bq, F, F), 2)
    covered = ((tin_j <= tin_i) & (tin_i < to[:, None, :]) & (ii != jj)
               & (tin_j >= 0) & (tin_i >= 0)).any(axis=2)
    loci_ref[...] = jnp.where(covered, _NEG_ONE, row)
    ov_ref[...] = overflow


def _kernel(fc_ref, ec_ref, echild_ref,
            sfc_ref, sec_ref, sechild_ref,
            syn_mask_ref, tout_ref, tele_ref,
            lptr_ref, lrule_ref, ltgt_ref,
            rfc_ref, rec_ref, rechild_ref, rterm_ref,
            q_ref, qlen_ref,
            loci_ref, ov_ref, **statics):
    tabs = _ResidentTables(
        fc_ref[...], ec_ref[...], echild_ref[...],
        sfc_ref[...], sec_ref[...], sechild_ref[...],
        syn_mask_ref[...], tout_ref[...], tele_ref[...],
        lptr_ref[...], lrule_ref[...], ltgt_ref[...])
    _sweep(tabs, rfc_ref[...], rec_ref[...], rechild_ref[...], rterm_ref[...],
           q_ref[...], qlen_ref[...], loci_ref, ov_ref, **statics)


def _kernel_streamed(fc_hbm, ec_hbm, echild_hbm,
                     sfc_hbm, sec_hbm, sechild_hbm,
                     syn_mask_hbm, tout_hbm, tele_hbm,
                     lptr_hbm, lrule_hbm, ltgt_hbm,
                     rfc_ref, rec_ref, rechild_ref, rterm_ref,
                     q_ref, qlen_ref,
                     loci_ref, ov_ref,
                     pair_buf, word_buf, w1_buf, w2_buf, tele_buf,
                     sem_p, sem_w, sem_1, sem_2, sem_t, *,
                     walk_tile: int, link_tile: int, **statics):
    walk_iters = max(1, walk_tile.bit_length())
    link_iters = max(1, link_tile.bit_length())
    tw = int(tele_buf.shape[-1])
    tabs = _StreamedTables(
        StreamTable(fc_hbm, pair_buf, sem_p, 2),
        StreamTable(ec_hbm, w1_buf, sem_1, walk_tile),
        StreamTable(echild_hbm, w2_buf, sem_2, walk_tile),
        StreamTable(sfc_hbm, pair_buf, sem_p, 2),
        StreamTable(sec_hbm, w1_buf, sem_1, walk_tile),
        StreamTable(sechild_hbm, w2_buf, sem_2, walk_tile),
        StreamTable(syn_mask_hbm, word_buf, sem_w, 1),
        StreamTable(tout_hbm, word_buf, sem_w, 1),
        StreamTable(tele_hbm, tele_buf, sem_t, tw),
        StreamTable(lptr_hbm, pair_buf, sem_p, 2),
        StreamTable(lrule_hbm, w1_buf, sem_1, link_tile),
        StreamTable(ltgt_hbm, w2_buf, sem_2, link_tile),
        walk_iters, link_iters)
    _sweep(tabs, rfc_ref[...], rec_ref[...], rechild_ref[...], rterm_ref[...],
           q_ref[...], qlen_ref[...], loci_ref, ov_ref, **statics)


def _kernel_packed(lbl_ref, flg_ref, c_ids_ref, c_tout_ref,
                   b_ids_ref, b_ptr_ref, b_char_ref, b_child_ref,
                   sb_ids_ref, sb_ptr_ref, sb_char_ref, sb_child_ref,
                   t_ids_ref, tele_ref, la_ids_ref, la_ptr_ref,
                   lrule_ref, ltgt_ref,
                   rfc_ref, rec_ref, rechild_ref, rterm_ref,
                   q_ref, qlen_ref,
                   loci_ref, ov_ref, **statics):
    tabs = _PackedResidentTables(
        lbl_ref[...], flg_ref[...], c_ids_ref[...], c_tout_ref[...],
        b_ids_ref[...], b_ptr_ref[...], b_char_ref[...], b_child_ref[...],
        sb_ids_ref[...], sb_ptr_ref[...], sb_char_ref[...],
        sb_child_ref[...], t_ids_ref[...], tele_ref[...],
        la_ids_ref[...], la_ptr_ref[...], lrule_ref[...], ltgt_ref[...])
    _sweep(tabs, rfc_ref[...], rec_ref[...], rechild_ref[...], rterm_ref[...],
           q_ref[...], qlen_ref[...], loci_ref, ov_ref, **statics)


def _kernel_packed_streamed(lbl_hbm, flg_hbm, c_ids_ref, c_tout_ref,
                            b_ids_ref, b_ptr_ref, b_char_ref, b_child_ref,
                            sb_ids_ref, sb_ptr_ref, sb_char_ref,
                            sb_child_ref, t_ids_ref, tele_ref,
                            la_ids_ref, la_ptr_ref, lrule_ref, ltgt_ref,
                            rfc_ref, rec_ref, rechild_ref, rterm_ref,
                            q_ref, qlen_ref,
                            loci_ref, ov_ref,
                            lbl_buf, flg_buf, sem_l, sem_f, **statics):
    tabs = _PackedStreamedTables(
        StreamTable(lbl_hbm, lbl_buf, sem_l, 1),
        StreamTable(flg_hbm, flg_buf, sem_f, 1),
        c_ids_ref[...], c_tout_ref[...],
        b_ids_ref[...], b_ptr_ref[...], b_char_ref[...], b_child_ref[...],
        sb_ids_ref[...], sb_ptr_ref[...], sb_char_ref[...],
        sb_child_ref[...], t_ids_ref[...], tele_ref[...],
        la_ids_ref[...], la_ptr_ref[...], lrule_ref[...], ltgt_ref[...])
    _sweep(tabs, rfc_ref[...], rec_ref[...], rechild_ref[...], rterm_ref[...],
           q_ref[...], qlen_ref[...], loci_ref, ov_ref, **statics)


def _call(kernel, tables, table_specs, queries, qlens, scratch, *,
          frontier: int, block_q: int, interpret: bool):
    bsz, seq_len = queries.shape
    grid = (bsz // block_q,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=table_specs + [
            pl.BlockSpec((block_q, seq_len), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_q, frontier), lambda i: (i, 0)),
            pl.BlockSpec((block_q,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, frontier), jnp.int32),
            jax.ShapeDtypeStruct((bsz,), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(*tables, queries, qlens)


@functools.partial(jax.jit, static_argnames=(
    "frontier", "rule_matches", "max_lhs_len", "max_terms", "has_syn",
    "has_tele", "has_links", "edit_budget", "branch_width", "block_q",
    "interpret"))
def locus_dp_walk(first_child, edge_char, edge_child,
                  s_first_child, s_edge_char, s_edge_child,
                  syn_mask, tout, tele_plane,
                  link_ptr, link_rule, link_target,
                  r_first_child, r_edge_char, r_edge_child, r_term_plane,
                  queries, qlens, *,
                  frontier: int, rule_matches: int, max_lhs_len: int,
                  max_terms: int, has_syn: bool, has_tele: bool,
                  has_links: bool, edit_budget: int = 0,
                  branch_width: int = 1, block_q: int = 8,
                  interpret: bool = True):
    """Fused locus DP over a query batch (VMEM-resident tables).

    queries int32[B, L] (-1 padded, B divisible by block_q; the wrapper in
    ops.py pads), qlens int32[B].  Tables are the DeviceTrie arrays with
    empties padded to length 1 (gated off by the ``has_*`` statics).
    Returns (loci[B, F] finalized antichains, overflow[B]) — bit-identical
    to ``jax.vmap(engine.locus.locus_dp)`` on the jnp substrate.
    """
    def full(a):
        shape = tuple(int(s) for s in a.shape)
        return pl.BlockSpec(shape, (lambda i: (0,) * len(shape)))

    kernel = functools.partial(
        _kernel, frontier=frontier, rule_matches=rule_matches,
        max_lhs_len=max_lhs_len, max_terms=max_terms, has_syn=has_syn,
        has_tele=has_tele, has_links=has_links, edit_budget=edit_budget,
        branch_width=branch_width, seq_len=int(queries.shape[1]))
    tables = [first_child, edge_char, edge_child,
              s_first_child, s_edge_char, s_edge_child,
              syn_mask, tout, tele_plane,
              link_ptr, link_rule, link_target,
              r_first_child, r_edge_char, r_edge_child, r_term_plane]
    return _call(kernel, tables, [full(a) for a in tables], queries, qlens,
                 [], frontier=frontier, block_q=block_q, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "frontier", "rule_matches", "max_lhs_len", "max_terms", "has_syn",
    "has_tele", "has_links", "edit_budget", "branch_width", "walk_tile",
    "link_tile", "block_q", "interpret"))
def locus_dp_walk_streamed(first_child, edge_char, edge_child,
                           s_first_child, s_edge_char, s_edge_child,
                           syn_mask, tout, tele_plane,
                           link_ptr, link_rule, link_target,
                           r_first_child, r_edge_char, r_edge_child,
                           r_term_plane,
                           queries, qlens, *,
                           frontier: int, rule_matches: int,
                           max_lhs_len: int, max_terms: int, has_syn: bool,
                           has_tele: bool, has_links: bool,
                           edit_budget: int = 0, branch_width: int = 1,
                           walk_tile: int = 8,
                           link_tile: int = 8, block_q: int = 4,
                           interpret: bool = True):
    """HBM-resident variant of :func:`locus_dp_walk`: same contract, same
    results, but the dictionary-sized tables stay in HBM and every access
    is a double-buffered windowed DMA.  ``walk_tile``/``link_tile`` are
    the static window widths from the tile-aligned layout
    (``EngineConfig``); the rule trie stays VMEM-resident (it is sized by
    the rule set, not the dictionary)."""
    def full(a):
        shape = tuple(int(s) for s in a.shape)
        return pl.BlockSpec(shape, (lambda i: (0,) * len(shape)))

    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    kernel = functools.partial(
        _kernel_streamed, frontier=frontier, rule_matches=rule_matches,
        max_lhs_len=max_lhs_len, max_terms=max_terms, has_syn=has_syn,
        has_tele=has_tele, has_links=has_links, edit_budget=edit_budget,
        branch_width=branch_width, walk_tile=walk_tile,
        link_tile=link_tile, seq_len=int(queries.shape[1]))
    tables = [first_child, edge_char, edge_child,
              s_first_child, s_edge_char, s_edge_child,
              syn_mask, tout, tele_plane,
              link_ptr, link_rule, link_target,
              r_first_child, r_edge_char, r_edge_child, r_term_plane]
    specs = [hbm] * 12 + [full(a) for a in tables[12:]]
    lanes = block_q * frontier
    wmax = max(walk_tile, link_tile)
    scratch = [
        pltpu.VMEM((lanes, 2), jnp.int32),            # pointer-pair stage
        pltpu.VMEM((lanes, 1), jnp.int32),            # scalar gathers
        pltpu.VMEM((lanes, wmax), jnp.int32),         # char/rule windows
        pltpu.VMEM((lanes, wmax), jnp.int32),         # child/target windows
        pltpu.VMEM((lanes, int(tele_plane.shape[1])), jnp.int32),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SemaphoreType.DMA((2,)),
    ]
    return _call(kernel, tables, specs, queries, qlens, scratch,
                 frontier=frontier, block_q=block_q, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "frontier", "rule_matches", "max_lhs_len", "max_terms", "has_syn",
    "has_tele", "has_links", "edit_budget", "branch_width", "block_q",
    "interpret"))
def locus_dp_walk_packed(p_labels, p_flags, c_ids, c_tout,
                         b_ids, b_ptr, b_char, b_child,
                         sb_ids, sb_ptr, sb_char, sb_child,
                         t_ids, tele_plane, la_ids, la_ptr,
                         link_rule, link_target,
                         r_first_child, r_edge_char, r_edge_child,
                         r_term_plane, queries, qlens, *,
                         frontier: int, rule_matches: int, max_lhs_len: int,
                         max_terms: int, has_syn: bool, has_tele: bool,
                         has_links: bool, edit_budget: int = 0,
                         branch_width: int = 1, block_q: int = 8,
                         interpret: bool = True):
    """Fused locus DP over the compressed (packed) layout, every table
    VMEM-resident.  Same contract and bit-identical results as
    :func:`locus_dp_walk`; the table set is the packed one — u8
    labels/flags planes plus the sparse side tables (empties padded to
    one inert ``-1`` row by the ops wrapper, which no node id matches)."""
    def full(a):
        shape = tuple(int(s) for s in a.shape)
        return pl.BlockSpec(shape, (lambda i: (0,) * len(shape)))

    kernel = functools.partial(
        _kernel_packed, frontier=frontier, rule_matches=rule_matches,
        max_lhs_len=max_lhs_len, max_terms=max_terms, has_syn=has_syn,
        has_tele=has_tele, has_links=has_links, edit_budget=edit_budget,
        branch_width=branch_width, seq_len=int(queries.shape[1]))
    tables = [p_labels, p_flags, c_ids, c_tout,
              b_ids, b_ptr, b_char, b_child,
              sb_ids, sb_ptr, sb_char, sb_child,
              t_ids, tele_plane, la_ids, la_ptr, link_rule, link_target,
              r_first_child, r_edge_char, r_edge_child, r_term_plane]
    return _call(kernel, tables, [full(a) for a in tables], queries, qlens,
                 [], frontier=frontier, block_q=block_q, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "frontier", "rule_matches", "max_lhs_len", "max_terms", "has_syn",
    "has_tele", "has_links", "edit_budget", "branch_width", "block_q",
    "interpret"))
def locus_dp_walk_packed_streamed(p_labels, p_flags, c_ids, c_tout,
                                  b_ids, b_ptr, b_char, b_child,
                                  sb_ids, sb_ptr, sb_char, sb_child,
                                  t_ids, tele_plane, la_ids, la_ptr,
                                  link_rule, link_target,
                                  r_first_child, r_edge_char, r_edge_child,
                                  r_term_plane, queries, qlens, *,
                                  frontier: int, rule_matches: int,
                                  max_lhs_len: int, max_terms: int,
                                  has_syn: bool, has_tele: bool,
                                  has_links: bool, edit_budget: int = 0,
                                  branch_width: int = 1, block_q: int = 4,
                                  interpret: bool = True):
    """HBM-resident variant of :func:`locus_dp_walk_packed`: only the two
    N-sized u8 planes (labels/flags) stay in HBM and stream per access as
    width-1 windows through their own u8 staging buffers; the sparse side
    tables and the rule trie — branch-count-sized — stay VMEM-resident.
    No stream-tile parameter: the packed layout's windows are single
    elements, so the tile-aligned layout plays no role here."""
    def full(a):
        shape = tuple(int(s) for s in a.shape)
        return pl.BlockSpec(shape, (lambda i: (0,) * len(shape)))

    hbm = pl.BlockSpec(memory_space=pltpu.ANY)
    kernel = functools.partial(
        _kernel_packed_streamed, frontier=frontier,
        rule_matches=rule_matches, max_lhs_len=max_lhs_len,
        max_terms=max_terms, has_syn=has_syn, has_tele=has_tele,
        has_links=has_links, edit_budget=edit_budget,
        branch_width=branch_width, seq_len=int(queries.shape[1]))
    tables = [p_labels, p_flags, c_ids, c_tout,
              b_ids, b_ptr, b_char, b_child,
              sb_ids, sb_ptr, sb_char, sb_child,
              t_ids, tele_plane, la_ids, la_ptr, link_rule, link_target,
              r_first_child, r_edge_char, r_edge_child, r_term_plane]
    specs = [hbm] * 2 + [full(a) for a in tables[2:]]
    lanes = block_q * frontier
    scratch = [
        pltpu.VMEM((lanes, 1), jnp.uint8),   # label window stage
        pltpu.VMEM((lanes, 1), jnp.uint8),   # flag window stage
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.SemaphoreType.DMA((2,)),
    ]
    return _call(kernel, tables, specs, queries, qlens, scratch,
                 frontier=frontier, block_q=block_q, interpret=interpret)
