"""Pallas TPU kernel: fused candidate scoring + running top-k.

The `retrieval_cand` shape (1 query x 1M candidates) and the trie shard
merge both reduce to "dot-score a big matrix against one vector, keep the
top-k". Materializing all scores to HBM and sorting wastes bandwidth; this
kernel tiles candidates into (BC, D) VMEM blocks, scores them on the MXU,
and maintains a running top-k in the output ref across grid steps (the
output block index map is constant, so it persists).

k rounds of (max, argmax, mask) per block keep selection in-VMEM; ids are
globalized with the grid index.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -3.0e38  # python scalar: jnp constants would be captured as consts


def _kernel(q_ref, c_ref, os_ref, oi_ref, *, k: int, block_c: int):
    step = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)
    c = c_ref[...].astype(jnp.float32)
    scores = c @ q  # [BC] on the MXU

    @pl.when(step == 0)
    def _init():
        os_ref[...] = jnp.full((k,), _NEG, jnp.float32)
        oi_ref[...] = jnp.full((k,), -1, jnp.int32)

    run_s = os_ref[...]
    run_i = oi_ref[...]
    ids = step * block_c + jnp.arange(block_c, dtype=jnp.int32)
    cat_s = jnp.concatenate([run_s, scores])
    cat_i = jnp.concatenate([run_i, ids])
    # k rounds of extract-max; running entries sit first so that on equal
    # scores the earlier (lower-id) candidate wins, matching lax.top_k
    for j in range(k):
        best = jnp.argmax(cat_s)
        os_ref[j] = cat_s[best]
        oi_ref[j] = cat_i[best]
        cat_s = cat_s.at[best].set(_NEG)


@functools.partial(jax.jit, static_argnames=("k", "block_c", "interpret"))
def candidate_topk(query, candidates, k: int, *, block_c: int = 1024,
                   interpret: bool = True):
    """query float[D]; candidates float[C, D] (C divisible by block_c).

    Returns (scores[k] float32, ids[k] int32), score-descending.
    """
    cands, d = candidates.shape
    grid = (cands // block_c,)
    kernel = functools.partial(_kernel, k=k, block_c=block_c)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((block_c, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.int32),
        ],
        interpret=interpret,
    )(query, candidates)
