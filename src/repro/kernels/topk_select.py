"""Pallas TPU kernel: fused small-k top-k selection with payload.

Used by the completion engine's merge points (beam leaf buffer, cached
per-node top-K lists, cross-shard merges): candidates live in a VMEM tile
and k rounds of (max, argmax, mask) extract the result without a full sort.
For k << C this is cheaper than bitonic-sorting the whole tile and keeps
everything in registers/VMEM.

Tie behaviour matches jax.lax.top_k: equal scores resolve to the lower
candidate index (argmax picks the first maximum).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -(2**31 - 1)


def _kernel(s_ref, p_ref, os_ref, op_ref, *, k: int):
    s = s_ref[...].astype(jnp.int32)
    p = p_ref[...]
    bq, c = s.shape
    rows = jnp.arange(bq)
    for j in range(k):
        best = jnp.argmax(s, axis=1)
        os_ref[:, j] = s[rows, best]
        op_ref[:, j] = p[rows, best]
        s = s.at[rows, best].set(_NEG)


@functools.partial(jax.jit, static_argnames=("k", "block_b", "interpret"))
def topk_select(scores, payload, k: int, *, block_b: int = 8,
                interpret: bool = True):
    """scores int32[B, C], payload int32[B, C] -> (top_s[B,k], top_p[B,k])."""
    bsz, c = scores.shape
    grid = (bsz // block_b,)
    kernel = functools.partial(_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
            pl.BlockSpec((block_b, c), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, k), jnp.int32),
            jax.ShapeDtypeStruct((bsz, k), jnp.int32),
        ],
        interpret=interpret,
    )(scores, payload)
