"""Pallas TPU kernel: fused cached-top-K locus gather + merge.

Phase 2b of the completion engine with a materialized per-node top-K
cache: every query's locus antichain (up to F nodes) owns a score-sorted
top-K list; the answer is the top-k of their union.  The pure-jnp path
gathers [B, F, K] score/sid tiles to HBM, reshapes, and runs a full
lax.top_k — this kernel keeps the whole thing in VMEM: the (small,
per-shard) cache tables are VMEM-resident like the trie-walk CSR tables,
the gather is a vectorized dynamic load of F*K candidates per query, and
k rounds of (max, argmax, mask) extract the result without materializing
or sorting the union.

Candidate order is loci-major / K-minor and ties resolve to the first
maximum, so results are bit-identical to lax.top_k over the same
flattening (the jnp reference in kernels/ref.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -(2**31 - 1)


def _kernel(loci_ref, ts_ref, ti_ref, os_ref, op_ref, *, k: int):
    loci = loci_ref[...]                  # [BB, F]
    ts = ts_ref[...]                      # [N, K]
    ti = ti_ref[...]
    bb, f = loci.shape
    n_nodes, kk = ts.shape
    valid = loci >= 0
    n = jnp.where(valid, loci, 0)
    offs = jnp.arange(kk, dtype=jnp.int32)
    flat_idx = (n[:, :, None] * kk + offs[None, None, :]).reshape(bb, f * kk)
    sc = jnp.take(ts.reshape(-1), flat_idx)       # vectorized VMEM gather
    si = jnp.take(ti.reshape(-1), flat_idx)
    mask = jnp.repeat(valid, kk, axis=1)          # loci-major, K-minor
    sc = jnp.where(mask, sc, -1)                  # -1 = empty (as in jnp)
    si = jnp.where(mask, si, -1)
    rows = jnp.arange(bb)
    for j in range(k):
        best = jnp.argmax(sc, axis=1)             # ties: first maximum
        os_ref[:, j] = sc[rows, best]
        op_ref[:, j] = si[rows, best]
        sc = sc.at[rows, best].set(_NEG)


@functools.partial(jax.jit, static_argnames=("k", "block_b", "interpret"))
def locus_topk_merge(loci, topk_score, topk_sid, k: int, *, block_b: int = 8,
                     interpret: bool = True):
    """loci int32[B, F] (-1 padded, B divisible by block_b; wrapper in
    ops.py pads); topk_score/topk_sid int32[N, K] ->
    (scores[B, k], sids[B, k]), score-descending, -1 where empty."""
    bsz, f = loci.shape
    n_nodes, kk = topk_score.shape
    grid = (bsz // block_b,)
    kernel = functools.partial(_kernel, k=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, f), lambda i: (i, 0)),
            pl.BlockSpec((n_nodes, kk), lambda i: (0, 0)),
            pl.BlockSpec((n_nodes, kk), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
            pl.BlockSpec((block_b, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, k), jnp.int32),
            jax.ShapeDtypeStruct((bsz, k), jnp.int32),
        ],
        interpret=interpret,
    )(loci, topk_score, topk_sid)
