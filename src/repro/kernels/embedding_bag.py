"""Pallas TPU kernel: EmbeddingBag (ragged gather + segment reduce).

JAX has no native nn.EmbeddingBag; the recsys substrate needs one for its
multi-hot sparse features. The ops.py wrapper densifies the ragged
(indices, offsets) batch to [B, max_bag] (pad = -1), and this kernel blocks
bags into VMEM tiles, gathers rows of the (VMEM-resident) table and
reduces over the bag dimension. Per-sample weights fold into the gather.

On real hardware the table tile would be streamed per-shard (row-sharded
tables over the `model` axis, cf. DESIGN §6); gathering from a VMEM tile is
exactly the per-shard inner kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tab_ref, idx_ref, w_ref, out_ref, *, mode: str):
    tab = tab_ref[...]
    idx = idx_ref[...]
    w = w_ref[...]
    v = tab.shape[0]
    valid = idx >= 0
    rows = jnp.take(tab, jnp.clip(idx, 0, v - 1), axis=0)  # [BB, MB, D]
    rows = rows * w[..., None]
    rows = jnp.where(valid[..., None], rows, 0.0)
    out = rows.sum(axis=1)
    if mode == "mean":
        cnt = valid.sum(axis=1).astype(tab.dtype)
        out = out / jnp.maximum(cnt, 1)[:, None]
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("mode", "block_b", "interpret"))
def embedding_bag_dense(table, idx, weights, *, mode: str = "sum",
                        block_b: int = 128, interpret: bool = True):
    """table float[V, D]; idx int32[B, MB] (-1 pad); weights float[B, MB]."""
    bsz, mb = idx.shape
    v, d = table.shape
    grid = (bsz // block_b,)
    kernel = functools.partial(_kernel, mode=mode)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((v, d), lambda i: (0, 0)),
            pl.BlockSpec((block_b, mb), lambda i: (i, 0)),
            pl.BlockSpec((block_b, mb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, d), table.dtype),
        interpret=interpret,
    )(table, idx, weights)
